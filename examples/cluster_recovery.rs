//! Multi-rank cluster runtime demo — no PJRT artifacts needed.
//!
//! Four ranks checkpoint their own state partitions concurrently (per-rank
//! differential chains + two-phase global commit). One rank's storage dies
//! mid-run, tearing every epoch after it; recovery returns the consistent
//! cut — the last epoch whose global record and all per-rank objects are
//! intact — bit-for-bit. Then the cluster restarts **elastically** with 2
//! ranks: the old partition table is read from the commit record, the
//! per-rank chains are merged and flattened, and the state is resharded
//! across the new ranks, which keep training.
//!
//!   cargo run --release --example cluster_recovery -- [--ranks 4] [--steps 8]

use std::sync::Arc;

use anyhow::Result;
use lowdiff::checkpoint::format::model_signature;
use lowdiff::checkpoint::manifest::Manifest;
use lowdiff::cluster::{
    elastic_restart, partition_even, recover_cluster, Cluster, ClusterConfig,
};
use lowdiff::compress::topk_mask;
use lowdiff::optim::{Adam, ModelState};
use lowdiff::sparse::SparseGrad;
use lowdiff::storage::{FaultConfig, FaultyStore, LocalDir, Namespaced, StorageBackend};
use lowdiff::tensor::Flat;
use lowdiff::util::cli::Args;
use lowdiff::util::rng::Rng;

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let ranks: usize = args.parse_or("ranks", 4usize)?;
    let steps: u64 = args.parse_or("steps", 8u64)?;
    let n: usize = 4096;
    let sig = model_signature("cluster-demo", n);
    let adam = Adam::default();

    let dir = std::env::temp_dir().join("lowdiff-cluster-demo");
    let _ = std::fs::remove_dir_all(&dir);
    let store: Arc<dyn StorageBackend> = Arc::new(LocalDir::new(&dir)?);
    println!("cluster: {ranks} ranks, 2 shards x 2 writers each, over {}", dir.display());

    // rank `ranks-1` suffers storage death mid-run: its puts start failing
    // after the anchor and the first few diffs, so later epochs are torn
    let victim = ranks - 1;
    let grace = 1 + steps / 2; // anchor + half the diffs survive
    let shared = Arc::clone(&store);
    let cluster = Cluster::spawn_with(
        Arc::clone(&store),
        partition_even(n, ranks),
        ClusterConfig {
            model_sig: sig,
            n_shards: 2,
            writers: 2,
            gc: false, // keep every epoch visible for the demo printout
            ..ClusterConfig::default()
        },
        move |r| {
            let ns = Namespaced::new(Arc::clone(&shared), Manifest::gen_rank_prefix(0, r));
            if r == victim {
                // sharded mode: every object is 2 shard puts + 1 commit
                // record, so `grace` epochs are 3*grace passing ops
                Arc::new(FaultyStore::new(
                    ns,
                    FaultConfig {
                        put_fail: 1.0,
                        grace_ops: 3 * grace,
                        ..FaultConfig::default()
                    },
                )) as Arc<dyn StorageBackend>
            } else {
                Arc::new(ns) as Arc<dyn StorageBackend>
            }
        },
    );

    // drive a training timeline, mirroring the expected global state
    let mut rng = Rng::new(7);
    let mut state = ModelState::new(Flat(vec![0.5; n]));
    let mut timeline = vec![state.clone()];
    cluster.put_full(0, &state);
    for step in 1..=steps {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        let masked = topk_mask(&Flat(g), n / 100 + 1);
        cluster.put_diff_dense(step, &masked);
        adam.apply_sparse(&mut state, &SparseGrad::from_dense(&masked));
        timeline.push(state.clone());
    }
    let stats = cluster.finish();
    println!(
        "rank {victim} died mid-run: {} epochs committed, {} torn ({} rank objects, {})",
        stats.global_commits,
        stats.torn_commits,
        stats.total().writes,
        lowdiff::util::human_bytes(stats.total().bytes_written),
    );

    // recover the consistent cut
    let (recovered, cut) = recover_cluster(&store, sig, &adam)?;
    println!(
        "consistent cut: step {} gen {} across {} ranks ({} records seen, {} skipped)",
        cut.cut_step, cut.cut_gen, cut.ranks, cut.records_seen, cut.records_skipped
    );
    assert_eq!(recovered, timeline[cut.cut_step as usize], "cut must be bit-identical");
    println!("|params| = {:.4} — a state the run really visited", recovered.params.l2_norm());

    // elastic restart: half the ranks, same store, no old-R config needed
    let new_ranks = (ranks / 2).max(1);
    let (c2, resharded, _) = elastic_restart(
        &store,
        &adam,
        partition_even(n, new_ranks),
        ClusterConfig { model_sig: sig, ..ClusterConfig::default() },
    )?;
    assert_eq!(resharded, recovered, "reshard must preserve every coordinate");
    println!("elastic restart: {ranks} -> {new_ranks} ranks at step {}", resharded.step);

    // the resharded cluster keeps training
    let mut state2 = resharded;
    for step in cut.cut_step + 1..=cut.cut_step + 2 {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        let masked = topk_mask(&Flat(g), n / 100 + 1);
        c2.put_diff_dense(step, &masked);
        adam.apply_sparse(&mut state2, &SparseGrad::from_dense(&masked));
    }
    let stats2 = c2.finish();
    let (fin, cut2) = recover_cluster(&store, sig, &adam)?;
    assert_eq!(fin, state2, "post-reshard chain extends the cut bit-identically");
    println!(
        "resumed on {new_ranks} ranks: {} more epochs committed, recovered step {} (gc removed {})",
        stats2.global_commits, cut2.cut_step, stats2.gc_removed
    );
    Ok(())
}
