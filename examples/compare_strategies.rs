//! Exp. 1 on real hardware (this machine): train the `small` model under
//! every checkpointing strategy at per-iteration frequency and print the
//! measured training time / stall / storage table — the real-path
//! counterpart of `lowdiff exp exp1` (which simulates the paper's A100
//! testbed at full scale).
//!
//!   cargo run --release --example compare_strategies -- [--iters N]

use std::sync::Arc;

use anyhow::Result;
use lowdiff::coordinator::driver::{train, StrategyKind, TrainConfig};
use lowdiff::runtime::{artifacts_dir, ModelRuntime};
use lowdiff::storage::{LocalDir, StorageBackend, Throttled};
use lowdiff::util::cli::Args;

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &["throttle"])?;
    let iters: u64 = args.parse_or("iters", 40u64)?;
    // --throttle emulates the paper's SSD bandwidth so write costs are
    // visible even on a fast local disk
    let throttle = args.flag("throttle");

    let mrt = ModelRuntime::load(&artifacts_dir(), "small")?;
    println!(
        "comparing strategies on `small` ({} params, {} iters, per-iteration ckpt{})\n",
        mrt.n_params(),
        iters,
        if throttle { ", throttled storage" } else { "" }
    );

    let strategies = [
        StrategyKind::None,
        StrategyKind::LowDiff,
        StrategyKind::LowDiffPlus,
        StrategyKind::NaiveDc,
        StrategyKind::CheckFreq,
        StrategyKind::Gemini,
        StrategyKind::TorchSave,
    ];
    let mut rows = Vec::new();
    for strategy in strategies {
        let dir = std::env::temp_dir().join(format!("lowdiff-cmp-{}", strategy.name()));
        let _ = std::fs::remove_dir_all(&dir);
        let local = LocalDir::new(&dir)?;
        let store: Arc<dyn StorageBackend> = if throttle {
            // ~200 MB/s with 3 ms per-op latency: a slow SATA-class disk
            Arc::new(Throttled::new(local, 200e6, std::time::Duration::from_millis(3)))
        } else {
            Arc::new(local)
        };
        let cfg = TrainConfig {
            strategy,
            iters,
            // per-iteration frequency for the frequent-ckpt systems; the
            // full-state systems checkpoint every iteration too (Exp. 1)
            diff_every: 1,
            full_every: match strategy {
                StrategyKind::CheckFreq | StrategyKind::Gemini | StrategyKind::TorchSave => 1,
                _ => 20,
            },
            batch_size: 4,
            eval_every: iters,
            ..TrainConfig::default()
        };
        let report = train(&mrt, store, &cfg)?;
        println!("{}", report.row());
        rows.push((strategy.name(), report));
    }

    // summary vs the no-checkpoint upper bound
    let base = rows[0].1.wall_secs;
    println!("\nslowdown vs W/O CKPT:");
    for (name, r) in &rows {
        println!(
            "  {:<12} {:>6.1}%  (stall {:>5.2}s, queue-blocked {:>5.2}s, {} writes)",
            name,
            (r.wall_secs - base) / base * 100.0,
            r.stall_secs,
            r.queue_blocked_secs,
            r.writes
        );
    }
    println!("\ncompare_strategies OK");
    Ok(())
}
