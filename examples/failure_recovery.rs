//! Failure injection + recovery demo: trains the `small` model with an
//! aggressive MTBF so failures strike mid-run, and shows LowDiff resuming
//! from its differential chain vs LowDiff+ recovering from the CPU replica.
//!
//!   cargo run --release --example failure_recovery -- [--mtbf SECS]

use std::sync::Arc;

use anyhow::Result;
use lowdiff::coordinator::driver::{train, StrategyKind, TrainConfig};
use lowdiff::runtime::{artifacts_dir, ModelRuntime};
use lowdiff::storage::{LocalDir, StorageBackend};
use lowdiff::util::cli::Args;

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let mtbf: f64 = args.parse_or("mtbf", 15.0f64)?; // seconds of wall clock
    let iters: u64 = args.parse_or("iters", 60u64)?;

    let mrt = ModelRuntime::load(&artifacts_dir(), "small")?;
    println!("model `small`: {} params; injecting failures (MTBF {mtbf}s)\n", mrt.n_params());

    for (strategy, p_soft) in [
        (StrategyKind::LowDiff, 0.5),
        (StrategyKind::LowDiffPlus, 1.0), // software failures: in-memory recovery
        (StrategyKind::TorchSave, 0.5),
    ] {
        let dir = std::env::temp_dir().join(format!("lowdiff-fail-{}", strategy.name()));
        let _ = std::fs::remove_dir_all(&dir);
        let store: Arc<dyn StorageBackend> = Arc::new(LocalDir::new(&dir)?);
        let cfg = TrainConfig {
            strategy,
            iters,
            full_every: 10,
            batch_size: 2,
            mtbf_secs: Some(mtbf),
            p_software: p_soft,
            eval_every: 20,
            ..TrainConfig::default()
        };
        let report = train(&mrt, store, &cfg)?;
        println!("{}", report.row());
        println!(
            "   -> {} failures, {:.2}s recovering, {} iters of work lost\n",
            report.recoveries, report.recovery_secs, report.lost_iters
        );
        assert_eq!(report.iters, iters, "run must complete despite failures");
    }
    println!("failure_recovery OK");
    Ok(())
}
