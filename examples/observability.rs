//! Observability-plane demo — no PJRT artifacts needed.
//!
//! A two-rank cluster run with the full observability surface attached:
//! every pipeline stage traced into a ring buffer, every storage op
//! histogrammed per tier through the [`Observed`] middleware, per-rank
//! heartbeats feeding a failure detector, and the std-only HTTP plane
//! serving `GET /stats`, `GET /metrics` (Prometheus histograms), `GET
//! /trace`, `GET /chain`, `GET /storage` and `GET /health` live while
//! epochs commit. Three quarters of the way in, one rank's heart stops:
//! its epochs tear, the detector declares it dead, and recovery returns
//! the consistent cut — bit-for-bit. On the way out a chain scrub
//! re-verifies every committed object and the (size-capped)
//! chrome://tracing journal is persisted beside the chain.
//!
//!   cargo run --release --example observability -- \
//!       [--ranks 2] [--steps 40] [--serve 127.0.0.1:0] [--hold-secs 0]
//!
//! `--hold-secs N` keeps the HTTP server up after the run so an external
//! client (curl, a browser, the CI smoke test) can scrape the endpoints.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use lowdiff::checkpoint::format::{model_signature, PayloadCodec};
use lowdiff::cluster::{
    partition_even, recover_cluster, Cluster, ClusterConfig, Detector, HeartbeatTable,
};
use lowdiff::compress::topk_mask;
use lowdiff::control::{
    ControlView, ObsServer, ObsState, Retune, TelemetryBus, Tracer, TRACE_OBJECT,
};
use lowdiff::optim::{Adam, ModelState};
use lowdiff::pipeline::Scrubber;
use lowdiff::sparse::SparseGrad;
use lowdiff::storage::{LocalDir, Observed, StorageBackend, StorageObs};
use lowdiff::tensor::Flat;
use lowdiff::util::cli::Args;
use lowdiff::util::rng::Rng;

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let ranks: usize = args.parse_or("ranks", 2usize)?;
    let steps: u64 = args.parse_or("steps", 40u64)?;
    let hold_secs: f64 = args.parse_or("hold-secs", 0.0f64)?;
    let n: usize = 4096;
    let sig = model_signature("obs-demo", n);
    let adam = Adam::default();

    let dir = std::env::temp_dir().join("lowdiff-obs-demo");
    let _ = std::fs::remove_dir_all(&dir);
    let store: Arc<dyn StorageBackend> = Arc::new(LocalDir::new(&dir)?);

    // the observability plane: telemetry bus + trace ring + heartbeat
    // table + per-tier storage histograms, all shared with the runtime,
    // served over plain HTTP
    let bus = Arc::new(TelemetryBus::new());
    let tracer = Arc::new(Tracer::default());
    let table = Arc::new(HeartbeatTable::new(ranks));
    let storage_obs = Arc::new(StorageObs::new(50));
    let store: Arc<dyn StorageBackend> = Arc::new(
        Observed::new(store, Arc::clone(&storage_obs), "durable")
            .with_trace(Some(Arc::clone(&tracer))),
    );
    // the background chain scrubber, on-demand mode (interval 0): the
    // final notify below re-verifies every committed object's CRCs
    let scrubber = Scrubber::spawn(Arc::clone(&store), Duration::ZERO);
    let obs = Arc::new(
        ObsState::new(
            Arc::clone(&bus),
            Some(Arc::clone(&tracer)),
            Some(Arc::clone(&table)),
            Some(Arc::clone(&store)),
        )
        .with_storage_obs(Arc::clone(&storage_obs))
        .with_scrub(scrubber.live_handle())
        .with_heartbeat_timeout(0.08),
    );
    obs.set_control(ControlView {
        strategy: "lowdiff".into(),
        applied: Some(Retune {
            full_every: 0,
            batch_size: 1,
            compact_every: 4,
            codec: PayloadCodec::Raw,
        }),
        ..ControlView::default()
    });
    let mut server = ObsServer::serve(Arc::clone(&obs), args.get_or("serve", "127.0.0.1:0"))?;
    println!(
        "observability plane: http://{}/stats /metrics /trace /chain /storage /health",
        server.local_addr()
    );

    let cluster = Cluster::spawn(
        Arc::clone(&store),
        partition_even(n, ranks),
        ClusterConfig {
            model_sig: sig,
            gc: false,
            compact_every: 4,
            telemetry: Some(Arc::clone(&bus)),
            trace: Some(Arc::clone(&tracer)),
            heartbeats: Some(Arc::clone(&table)),
            ..ClusterConfig::default()
        },
    );
    let det = Detector::spawn(
        Arc::clone(&table),
        Duration::from_millis(80),
        Duration::from_millis(10),
    );

    // drive a training timeline; at 3/4 distance one rank's heart stops
    let victim = ranks - 1;
    let silence_at = steps * 3 / 4;
    let mut rng = Rng::new(7);
    let mut state = ModelState::new(Flat(vec![0.5; n]));
    let mut timeline = vec![state.clone()];
    cluster.put_full(0, &state);
    let mut detection = None;
    for step in 1..=steps {
        if step == silence_at {
            println!("step {step}: rank {victim}'s heart stops (beats and acks cease)");
            table.silence(victim, true);
        }
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        let masked = topk_mask(&Flat(g), n / 100 + 1);
        cluster.put_diff_dense(step, &masked);
        adam.apply_sparse(&mut state, &SparseGrad::from_dense(&masked));
        timeline.push(state.clone());
        if detection.is_none() {
            detection = det.take();
            if let Some(d) = detection {
                println!(
                    "step {step}: detector declared rank {} dead (last beat at step {})",
                    d.rank, d.step
                );
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // detection is activity-relative: the live ranks must keep making
    // progress for the victim's silence to age out, so keep training
    // (every epoch tears) until the detector fires
    let t0 = Instant::now();
    let mut extra = steps;
    while detection.is_none() && t0.elapsed() < Duration::from_secs(10) {
        extra += 1;
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        cluster.put_diff_dense(extra, &topk_mask(&Flat(g), n / 100 + 1));
        std::thread::sleep(Duration::from_millis(10));
        detection = det.take();
    }
    let d = detection.expect("the silent rank must be detected");
    assert_eq!(d.rank, victim);
    let stats = cluster.finish();
    println!(
        "run over: {} epochs committed, {} torn after the silence, {} written",
        stats.global_commits,
        stats.torn_commits,
        lowdiff::util::human_bytes(stats.total().bytes_written),
    );

    // recovery returns the consistent cut — the same one the detector's
    // death notice would have triggered in the driver
    let (recovered, cut) = recover_cluster(&store, sig, &adam)?;
    assert_eq!(recovered, timeline[cut.cut_step as usize], "cut must be bit-identical");
    println!(
        "recovered consistent cut: step {} (|params| = {:.4})",
        cut.cut_step,
        recovered.params.l2_norm()
    );

    // scrub the committed cover: every container CRC re-verified through
    // the same store the ranks wrote — a clean run scrubs clean
    scrubber.notify();
    let scrub = scrubber.finish();
    println!(
        "scrub: {} passes, {} objects verified, {} corrupt, {} repaired",
        scrub.passes, scrub.objects_scrubbed, scrub.corrupt, scrub.repaired
    );
    assert_eq!(scrub.corrupt, 0, "a healthy chain must scrub clean");
    for t in storage_obs.tiers() {
        println!(
            "storage tier `{}`: {} ops total, {} slow (threshold 50ms)",
            t.tier(),
            t.total_ops(),
            t.slow_ops()
        );
    }

    // persist the (size-capped) trace journal beside the chain and
    // publish the final control view for late scrapes
    store.put(TRACE_OBJECT, tracer.to_chrome_jsonl_capped(256 * 1024).as_bytes())?;
    let (recorded, dropped) = tracer.counts();
    println!(
        "trace journal: {recorded} events ({dropped} ring-dropped, {} journal-dropped) -> {TRACE_OBJECT}",
        tracer.journal_dropped()
    );
    let mut view = obs.control();
    view.detected_failures = 1;
    obs.set_control(view);

    if hold_secs > 0.0 {
        println!("holding the HTTP plane up for {hold_secs}s — scrape away");
        std::thread::sleep(Duration::from_secs_f64(hold_secs));
    }
    server.shutdown();
    Ok(())
}
