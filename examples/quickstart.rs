//! Quickstart: train the `tiny` transformer with LowDiff per-iteration
//! differential checkpointing, then kill the "job" and recover bit-exactly.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use anyhow::Result;
use lowdiff::checkpoint::format::model_signature;
use lowdiff::coordinator::driver::{train, StrategyKind, TrainConfig};
use lowdiff::coordinator::recovery::{recover, RecoveryMode};
use lowdiff::optim::Adam;
use lowdiff::runtime::{artifacts_dir, ModelRuntime};
use lowdiff::storage::{LocalDir, StorageBackend};

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let dir = std::env::temp_dir().join("lowdiff-quickstart");
    let _ = std::fs::remove_dir_all(&dir);

    // 1. load the AOT artifacts (L2 jax model + L1 Pallas kernels, compiled
    //    to HLO at build time; no Python from here on)
    let mrt = ModelRuntime::load(&artifacts_dir(), "tiny")?;
    println!(
        "model `tiny`: {} params, rho = {}, k = {}",
        mrt.n_params(),
        mrt.layout.rho,
        mrt.layout.k
    );

    // 2. train with per-iteration differential checkpoints (the paper's
    //    headline frequency) + a full checkpoint every 10 iterations
    let store: Arc<dyn StorageBackend> = Arc::new(LocalDir::new(&dir)?);
    let cfg = TrainConfig {
        strategy: StrategyKind::LowDiff,
        iters: 30,
        full_every: 10,
        batch_size: 2,
        eval_every: 5,
        ..TrainConfig::default()
    };
    let report = train(&mrt, Arc::clone(&store), &cfg)?;
    println!("\n{}", report.row());
    println!("\nloss curve:");
    for (step, loss) in &report.losses {
        println!("  step {step:>4}  loss {loss:.4}");
    }

    // 3. "crash" and recover from the checkpoint chain
    let sig = model_signature("tiny", mrt.n_params());
    let adam = Adam { lr: mrt.layout.lr as f32 };
    let (state, stats) = recover(store.as_ref(), sig, &adam, RecoveryMode::SerialReplay)?;
    println!(
        "\nrecovered to step {} from {} diff objects ({} merges, {:.1} ms)",
        state.step,
        stats.n_diff_objects,
        stats.full_merge_rounds,
        stats.wall_secs * 1e3
    );
    assert_eq!(state.step, 30, "recovery must reach the final step");

    // 4. parallel recovery (Fig. 10): log2 merge rounds
    let (pstate, pstats) = recover(store.as_ref(), sig, &adam, RecoveryMode::ParallelMerge)?;
    println!(
        "parallel recovery: {} rounds (vs {} serial), drift {:.2e}",
        pstats.full_merge_rounds,
        stats.full_merge_rounds,
        pstate.params.max_abs_diff(&state.params)
    );
    println!("\nquickstart OK");
    Ok(())
}
