//! Sharded + tiered storage engine demo — no PJRT artifacts needed.
//!
//! Drives the checkpointer through a 4-shard writer pool over a tiered
//! (memory-over-disk) backend, crashes the engine mid-batch, and shows
//! recovery reconstructing the last complete chain from the durable tier.
//!
//!   cargo run --release --example sharded_storage -- [--shards 4] [--writers 4]

use std::sync::Arc;

use anyhow::Result;
use lowdiff::checkpoint::diff::{write_diff, DiffPayload};
use lowdiff::checkpoint::format::{model_signature, PayloadCodec};
use lowdiff::checkpoint::full::write_full;
use lowdiff::checkpoint::manifest::Manifest;
use lowdiff::compress::topk_mask;
use lowdiff::coordinator::recovery::{recover, RecoveryMode};
use lowdiff::optim::{Adam, ModelState};
use lowdiff::sparse::SparseGrad;
use lowdiff::storage::{LocalDir, MemStore, Sharded, StorageBackend, Tiered};
use lowdiff::tensor::Flat;
use lowdiff::util::cli::Args;
use lowdiff::util::rng::Rng;

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let n_shards: usize = args.parse_or("shards", 4usize)?;
    let writers: usize = args.parse_or("writers", 4usize)?;
    let n: usize = 4096;
    let steps: u64 = 12;
    let sig = model_signature("demo", n);
    let adam = Adam::default();

    let dir = std::env::temp_dir().join("lowdiff-sharded-demo");
    let _ = std::fs::remove_dir_all(&dir);
    let durable: Arc<dyn StorageBackend> = Arc::new(LocalDir::new(&dir)?);
    let fast: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
    let tiered = Arc::new(Tiered::new(fast, Arc::clone(&durable)));
    let engine = Sharded::new(tiered.clone() as Arc<dyn StorageBackend>, n_shards, writers);
    println!("engine: {n_shards} shards x {writers} writers, mem tier over {}", dir.display());

    // build a training timeline and enqueue its checkpoints async
    let mut rng = Rng::new(7);
    let mut state = ModelState::new(Flat(vec![0.5; n]));
    engine.put(&Manifest::full_name(0), &write_full(&state, sig, PayloadCodec::Raw)?)?;
    let mut handles = Vec::new();
    for step in 1..=steps {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        let sparse = SparseGrad::from_dense(&topk_mask(&Flat(g), n / 100 + 1));
        adam.apply_sparse(&mut state, &sparse);
        let bytes = write_diff(&DiffPayload::Gradient(sparse), sig, step, PayloadCodec::Raw)?;
        handles.push(engine.put_async(&Manifest::diff_name(step), bytes));
    }
    // wait for half the chain, then crash the writer pool mid-batch
    for h in &handles[..steps as usize / 2] {
        h.wait().map_err(anyhow::Error::msg)?;
    }
    println!("crash! killing the writer pool with writes in flight...");
    let _ = engine.kill();
    tiered.wait_idle(); // whatever committed also finishes spilling
    drop(tiered); // the memory tier dies with the process

    // restart: read the durable tier through a fresh engine view
    let reader = Sharded::new(durable, 1, 2);
    let (recovered, stats) = recover(&reader, sig, &adam, RecoveryMode::SerialReplay)?;
    println!(
        "recovered step {} of {steps} ({} diff objects, {} dropped, {} damaged)",
        stats.recovered_step, stats.n_diff_objects, stats.dropped_diff_steps, stats.damaged_objects
    );
    assert!(recovered.step >= steps / 2, "committed prefix must survive");
    println!("|params| = {:.4} — a state the run really visited", recovered.params.l2_norm());
    Ok(())
}
