//! End-to-end driver (EXPERIMENTS.md §E2E): train the `e2e` transformer
//! (~29.5M params; pass --model gpt2s for the ~98M-param config) for a few
//! hundred steps on the synthetic corpus with LowDiff per-iteration
//! checkpointing, logging the loss curve, then verify recovery.
//!
//!   cargo run --release --example train_e2e -- [--iters N] [--model M]
//!       [--strategy S] [--full-every F] [--batch-size B]

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};
use lowdiff::checkpoint::format::model_signature;
use lowdiff::coordinator::driver::{train, StrategyKind, TrainConfig};
use lowdiff::coordinator::recovery::{recover, RecoveryMode};
use lowdiff::optim::Adam;
use lowdiff::runtime::{artifacts_dir, ModelRuntime};
use lowdiff::storage::{LocalDir, StorageBackend};
use lowdiff::util::cli::Args;

fn main() -> Result<()> {
    lowdiff::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let model = args.get_or("model", "e2e").to_string();
    let iters: u64 = args.parse_or("iters", 300u64)?;
    let strategy = StrategyKind::parse(args.get_or("strategy", "lowdiff"))
        .context("bad --strategy")?;

    let dir = std::env::temp_dir().join(format!("lowdiff-e2e-{model}"));
    let _ = std::fs::remove_dir_all(&dir);

    let t0 = Instant::now();
    let mrt = ModelRuntime::load(&artifacts_dir(), &model)?;
    println!(
        "loaded {model}: {:.2}M params ({} tensors), artifact compile {:.1}s",
        mrt.n_params() as f64 / 1e6,
        mrt.layout.n_tensors(),
        t0.elapsed().as_secs_f64()
    );

    let store: Arc<dyn StorageBackend> = Arc::new(LocalDir::new(&dir)?);
    let cfg = TrainConfig {
        strategy,
        iters,
        full_every: args.parse_or("full-every", 50u64)?,
        batch_size: args.parse_or("batch-size", 4usize)?,
        eval_every: args.parse_or("eval-every", 10u64)?,
        ..TrainConfig::default()
    };
    println!(
        "training {iters} iters with {} (full every {}, batch {})",
        strategy.name(),
        cfg.full_every,
        cfg.batch_size
    );

    let report = train(&mrt, Arc::clone(&store), &cfg)?;
    println!("\n{}", report.row());
    println!("\nloss curve (next-token CE; ln(vocab) = {:.3} at init):",
        (mrt.layout.vocab as f64).ln());
    for (step, loss) in &report.losses {
        let bar = "#".repeat((loss * 8.0) as usize);
        println!("  step {step:>6}  loss {loss:.4}  {bar}");
    }
    let first = report.losses.first().map(|(_, l)| *l).unwrap_or(0.0);
    let last = report.final_loss().unwrap_or(0.0);
    println!(
        "\nloss {first:.3} -> {last:.3} over {} iters ({:.1}% ckpt overhead, {} writes, {})",
        report.iters,
        report.overhead_ratio() * 100.0,
        report.writes,
        lowdiff::util::human_bytes(report.bytes_written)
    );
    anyhow::ensure!(last < first, "loss must decrease over the run");

    // recovery sanity on the persisted chain
    let sig = model_signature(&model, mrt.n_params());
    let adam = Adam { lr: mrt.layout.lr as f32 };
    let (state, stats) = recover(store.as_ref(), sig, &adam, RecoveryMode::SerialReplay)?;
    println!(
        "recovered step {} ({} merges, {:.2}s)",
        state.step, stats.full_merge_rounds, stats.wall_secs
    );
    println!("\ntrain_e2e OK");
    Ok(())
}
