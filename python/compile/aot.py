"""AOT lowering: every L2 computation -> artifacts/<model>.<name>.hlo.txt.

Interchange format is HLO *text*, never `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts` (build-time only; Python is never on the Rust
request path). Emits per model:

  <m>.init.hlo.txt      (seed i32[1])                  -> (params,)
  <m>.grads.hlo.txt     (params, tokens i32[B,S])      -> (loss, grads)
  <m>.eval.hlo.txt      (params, tokens)               -> (loss,)
  <m>.adam.hlo.txt      (p, m, v, g, step f32[1])      -> (p', m', v')
  <m>.compress.hlo.txt  (g, residual)                  -> (masked, res', t)
  <m>.fused.hlo.txt     (p, m, v, res, tokens, step)   -> (loss, p', m', v',
                                                           res', cgrad, t)
  <m>.layout.txt        flat-vector layer map + config for the Rust side
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

RHO = 0.01  # paper's common compression ratio (SS VIII-A)
LR = 1e-3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tuple_wrap(fn):
    """Ensure the lowered entry returns a tuple (rust unwraps tupled root)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def lower_model(cfg: M.ModelConfig, outdir: str, verbose: bool = True):
    F = M.num_params(cfg)
    f32v = jax.ShapeDtypeStruct((F,), jnp.float32)
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    seed = jax.ShapeDtypeStruct((1,), jnp.int32)
    step = jax.ShapeDtypeStruct((1,), jnp.float32)

    artifacts = {
        "init": (lambda s: (M.init_params(cfg, s),), (seed,)),
        "grads": (_tuple_wrap(M.grad_fn(cfg)), (f32v, tok)),
        "eval": (lambda p, t: (M.loss_fn(cfg, p, t),), (f32v, tok)),
        "adam": (_tuple_wrap(M.adam_step(cfg, lr=LR)), (f32v,) * 4 + (step,)),
        "compress": (_tuple_wrap(M.compress_step(cfg, rho=RHO)), (f32v, f32v)),
        "fused": (
            _tuple_wrap(M.fused_step(cfg, rho=RHO, lr=LR)),
            (f32v, f32v, f32v, f32v, tok, step),
        ),
    }
    for name, (fn, specs) in artifacts.items():
        path = os.path.join(outdir, f"{cfg.name}.{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        if verbose:
            print(f"  {path}: {len(text) / 1e6:.2f} MB", flush=True)

    write_layout(cfg, outdir)


def write_layout(cfg: M.ModelConfig, outdir: str):
    """Plain-text layout + config consumed by rust/src/model/layout.rs."""
    k = max(1, int(RHO * M.num_params(cfg)))
    lines = [
        "# lowdiff model layout v1",
        f"model {cfg.name}",
        f"n_params {M.num_params(cfg)}",
        f"vocab {cfg.vocab}",
        f"d_model {cfg.d_model}",
        f"n_layers {cfg.n_layers}",
        f"n_heads {cfg.n_heads}",
        f"d_ff {cfg.d_ff}",
        f"seq_len {cfg.seq_len}",
        f"batch {cfg.batch}",
        f"block {cfg.block}",
        f"rho {RHO}",
        f"k {k}",
        f"lr {LR}",
        "tensors",
    ]
    lines += [f"{name} {off} {n}" for name, off, n in M.layout(cfg)]
    with open(os.path.join(outdir, f"{cfg.name}.layout.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="tiny,small,e2e",
        help=f"comma-separated subset of {sorted(M.CONFIGS)}",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.models.split(","):
        cfg = M.CONFIGS[name.strip()]
        print(f"lowering {cfg.name} ({M.num_params(cfg) / 1e6:.2f}M params)")
        lower_model(cfg, args.out)
    print("artifacts complete")


if __name__ == "__main__":
    sys.exit(main())
