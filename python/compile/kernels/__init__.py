"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""
