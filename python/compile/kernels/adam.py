"""Fused Adam update as a Pallas kernel.

One grid step streams four input tiles (p, m, v, g) and writes three output
tiles (p', m', v') — 7 x 256 KiB = 1.75 MiB of VMEM per step, bandwidth
bound on the VPU with no MXU involvement. Fusing the whole update into one
pass is the TPU restatement of DeepSpeed's fused CUDA Adam: the win is one
HBM round-trip for the entire state instead of ~10 for the unfused op graph.

Bias correction factors bc1 = 1/(1-b1^t), bc2 = 1/(1-b2^t) depend on the
step and are computed at L2 (two scalar pow ops) and passed as a (2,) hyper
vector so the kernel itself stays step-agnostic and cacheable.

This same kernel is the recovery-path "diff merge" (paper Eq.(7):
C_t^D = Adam(G_t)): replaying a differential checkpoint IS an Adam
application of the stored compressed gradient.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import ADAM_MAX_BLOCK, BLOCK, INTERPRET, nblocks, pad1d

B1, B2, EPS = 0.9, 0.999, 1e-8


def _adam_kernel(lr: float):
    def kernel(p_ref, m_ref, v_ref, g_ref, h_ref, po_ref, mo_ref, vo_ref):
        g = g_ref[...]
        m2 = B1 * m_ref[...] + (1.0 - B1) * g
        v2 = B2 * v_ref[...] + (1.0 - B2) * g * g
        bc1 = h_ref[0]
        bc2 = h_ref[1]
        update = lr * (m2 * bc1) / (jnp.sqrt(v2 * bc2) + EPS)
        po_ref[...] = p_ref[...] - update
        mo_ref[...] = m2
        vo_ref[...] = v2

    return kernel


def bias_correction(step) -> jax.Array:
    """hyper = [1/(1-b1^t), 1/(1-b2^t)] for a (possibly traced) step."""
    t = jnp.asarray(step, jnp.float32)
    return jnp.stack([1.0 / (1.0 - B1**t), 1.0 / (1.0 - B2**t)])


def adam_update(p, m, v, g, step, lr: float = 1e-3, block: int = BLOCK):
    """One fused Adam step over flat f32 vectors. Returns (p', m', v').

    `step` is 1-based and may be a traced scalar.
    """
    block = min(block, ADAM_MAX_BLOCK)  # VMEM cap (common.py §Perf)
    pp, n = pad1d(p, block)
    mp, _ = pad1d(m, block)
    vp, _ = pad1d(v, block)
    gp, _ = pad1d(g, block)
    nb = nblocks(pp.shape[0], block)
    hyper = bias_correction(step)
    shape = jax.ShapeDtypeStruct(pp.shape, jnp.float32)
    po, mo, vo = pl.pallas_call(
        _adam_kernel(lr),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[shape, shape, shape],
        interpret=INTERPRET,
    )(pp, mp, vp, gp, hyper)
    return po[:n], mo[:n], vo[:n]
