"""Shared helpers for the Pallas kernels.

All kernels in this package operate on flat (1-D) f32 vectors, tiled into
VMEM-sized blocks via BlockSpec. Callers pad to a block multiple with
`pad1d` and slice the result back.

TPU adaptation note (DESIGN.md §4): block sizes are chosen so every operand
tile of the element-wise kernels fits VMEM comfortably. BLOCK=65536 f32 =
256 KiB per operand; the fused Adam kernel streams 4 inputs + 3 outputs =
1.75 MiB per grid step, far under the ~16 MiB VMEM budget, leaving room for
double-buffering the HBM<->VMEM pipeline.
"""

import jax.numpy as jnp

# Default 1-D block: 64Ki f32 elements = 256 KiB per operand tile.
BLOCK = 65536

# Per-kernel VMEM caps (§Perf iteration 1, see EXPERIMENTS.md):
# the fused Adam kernel streams 7 tiles/step — at the coarse per-model
# blocks used to bound interpret-mode HLO size, 1M-element blocks put it at
# 175% of the 16 MiB VMEM budget. Cap so the hungriest kernels stay under
# ~50% (leaving room for double-buffering); cheap kernels keep the coarse
# block (fewer grid steps).
ADAM_MAX_BLOCK = 262144      # 7 tiles -> 7.3 MB (44% VMEM)
EF_MAX_BLOCK = 524288        # 4 tiles + threshold -> 8.4 MB (50% VMEM)

# Pallas kernels MUST run interpret=True in this environment: the CPU PJRT
# plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
INTERPRET = True


def pad1d(x, block: int = BLOCK):
    """Flatten and zero-pad x to a multiple of `block`.

    Returns (padded, original_len).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n


def nblocks(n_padded: int, block: int = BLOCK) -> int:
    assert n_padded % block == 0
    return n_padded // block
