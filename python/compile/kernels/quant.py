"""Per-block symmetric int8 quantization as Pallas kernels.

The paper's other compression family (§II-C Quantization, QSGD-style [5],
8-bit [13]). Each quantization block (QBLOCK elements) gets one f32 scale =
absmax/127. The Pallas grid tile (common.BLOCK) holds BLOCK/QBLOCK
quantization blocks, so the scale reduction is a reshaped row-max inside a
single VMEM pass — no cross-tile communication, the same structure as the
per-warp absmax GPU quantizers use.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import BLOCK, INTERPRET, nblocks, pad1d

QBLOCK = 256  # elements per quantization scale


def _quant_kernel(x_ref, q_ref, s_ref):
    rows = x_ref[...].reshape(-1, QBLOCK)
    absmax = jnp.max(jnp.abs(rows), axis=1)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(rows / safe[:, None]), -127, 127)
    q_ref[...] = q.reshape(-1).astype(jnp.int8)
    s_ref[...] = scale


def quant8(x: jax.Array, block: int = BLOCK):
    """Quantize flat f32 x. Returns (q int8 [n_pad], scales f32 [n_pad/QBLOCK],
    original length n)."""
    padded, n = pad1d(x, block)
    nb = nblocks(padded.shape[0], block)
    spb = block // QBLOCK  # scales per grid tile
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((spb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(padded.shape, jnp.int8),
            jax.ShapeDtypeStruct((padded.shape[0] // QBLOCK,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(padded)
    return q, s, n


def _dequant_kernel(q_ref, s_ref, o_ref):
    rows = q_ref[...].reshape(-1, QBLOCK).astype(jnp.float32)
    o_ref[...] = (rows * s_ref[...][:, None]).reshape(-1)


def dequant8(q: jax.Array, scales: jax.Array, n: int, block: int = BLOCK):
    """Inverse of quant8; returns flat f32 of length n."""
    nb = nblocks(q.shape[0], block)
    spb = block // QBLOCK
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((spb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=INTERPRET,
    )(q, scales)
    return out[:n]
