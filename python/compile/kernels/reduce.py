"""Pallas partial-reduction kernels: per-block absmax and count(|x| >= t).

These are the building blocks of the communication-avoiding top-k threshold
search (DESIGN.md §4): each grid step reduces one VMEM-resident block to a
scalar; the tiny per-block vectors are combined at L2. This mirrors the
block-local-heap structure GPU top-k kernels use, restated for the TPU VPU
(full-tile reductions instead of warp shuffles).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import BLOCK, INTERPRET, nblocks, pad1d


def _absmax_kernel(x_ref, o_ref):
    o_ref[0] = jnp.max(jnp.abs(x_ref[...]))


def block_absmax(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """Per-block max |x| of a flat padded vector. Returns (nblocks,) f32."""
    nb = nblocks(x.shape[0], block)
    return pl.pallas_call(
        _absmax_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.float32),
        interpret=INTERPRET,
    )(x)


def absmax(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """Global max |x| (combines the per-block partials at L2)."""
    padded, _ = pad1d(x, block)
    return jnp.max(block_absmax(padded, block))


def _count_ge_kernel(x_ref, t_ref, o_ref):
    t = t_ref[0]
    o_ref[0] = jnp.sum((jnp.abs(x_ref[...]) >= t).astype(jnp.int32))


def block_count_ge(x: jax.Array, t: jax.Array, block: int = BLOCK) -> jax.Array:
    """Per-block count of |x| >= t. x must be padded; t is a (1,) f32.

    Zero-padding is harmless as long as t > 0 (padding never counts); the
    threshold search below keeps t strictly positive.
    """
    nb = nblocks(x.shape[0], block)
    return pl.pallas_call(
        _count_ge_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.int32),
        interpret=INTERPRET,
    )(x, t)


def count_ge(x: jax.Array, t: jax.Array, block: int = BLOCK) -> jax.Array:
    """Global count of |x| >= t (scalar int32)."""
    padded, _ = pad1d(x, block)
    t = jnp.asarray(t, jnp.float32).reshape(1)
    return jnp.sum(block_count_ge(padded, t, block))
