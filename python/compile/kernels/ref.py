"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (python/tests/) asserts the
Pallas kernels (interpret=True) match these within tolerance, and the Rust
side's storage codecs are tested against dumps produced from these.
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- top-k ----
def topk_mask_ref(g: jax.Array, k: int) -> jax.Array:
    """Dense top-k sparsification: keep the k largest-|.| entries of flat g.

    Returns g * mask (same shape). Exact selection via jax.lax.top_k.
    """
    absg = jnp.abs(g.reshape(-1))
    _, idx = jax.lax.top_k(absg, k)
    mask = jnp.zeros_like(absg, dtype=bool).at[idx].set(True)
    return (g.reshape(-1) * mask).reshape(g.shape)


def threshold_mask_ref(g: jax.Array, t) -> jax.Array:
    """Keep entries with |g| >= t (the kernel's sparsification primitive)."""
    return jnp.where(jnp.abs(g) >= t, g, jnp.zeros_like(g))


def count_ge_ref(x_abs: jax.Array, t) -> jax.Array:
    """Number of entries with x_abs >= t."""
    return jnp.sum(x_abs >= t).astype(jnp.int32)


def kth_magnitude_ref(g: jax.Array, k: int) -> jax.Array:
    """The k-th largest |g| — the exact top-k threshold."""
    vals, _ = jax.lax.top_k(jnp.abs(g.reshape(-1)), k)
    return vals[-1]


def sparsify_ef_ref(g: jax.Array, residual: jax.Array, k: int):
    """Top-k sparsification with error feedback.

    corrected = g + residual; masked = topk(corrected);
    new_residual = corrected - masked.
    Invariant: masked + new_residual == g + residual (exactly).
    """
    corrected = g + residual
    masked = topk_mask_ref(corrected, k)
    return masked, corrected - masked


# ----------------------------------------------------------------- adam ----
def adam_ref(p, m, v, g, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step (Kingma & Ba). `step` is 1-based.

    Returns (p', m', v'). Matches the paper's Eq.(4) M_{t+1} = M_t + Adam(G_t)
    with M = (params, m, v) — a full model state is 3*Psi (Finding 2).
    """
    step = jnp.asarray(step, dtype=jnp.float32)
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 / (1.0 - b1**step)
    bc2 = 1.0 / (1.0 - b2**step)
    update = lr * (m2 * bc1) / (jnp.sqrt(v2 * bc2) + eps)
    return p - update, m2, v2


# ---------------------------------------------------------------- quant ----
def quant8_ref(g: jax.Array, block: int = 256):
    """Per-block symmetric int8 quantization of a flat vector.

    Pads to a multiple of `block`. Returns (q int8 [n_pad], scales f32
    [n_pad/block]). scale = absmax/127 per block (0 -> scale 0).
    """
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequant8_ref(q: jax.Array, scale: jax.Array, n: int, block: int = 256):
    """Inverse of quant8_ref (up to rounding error <= scale/2 per element)."""
    blocks = q.reshape(-1, block).astype(jnp.float32) * scale[:, None]
    return blocks.reshape(-1)[:n]
