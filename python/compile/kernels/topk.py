"""Top-k gradient sparsification as Pallas kernels (threshold formulation).

GPU top-k compressors (the paper's rho-sparsification, §II-C) use warp-level
radix select and per-thread scatters. Neither exists on a TPU, so we restate
top-k as *threshold selection* (DESIGN.md §4 Hardware-Adaptation):

  1. `reduce.block_absmax` gives the global magnitude range [0, amax].
  2. A fixed-trip bisection (lax.fori_loop at L2) narrows a threshold t so
     that count(|g| >= t) ~= k, with each count a Pallas full-tile
     reduction (`reduce.block_count_ge`).
  3. `threshold_mask` applies the mask element-wise in one VMEM pass.

The selected count lands in [k, k * (1+eps)] for continuous-valued
gradients (ties and float-resolution limits can leave it slightly above k;
tests bound the deviation). The *wire/storage* compaction to (indices,
values) happens in Rust at checkpoint-write time — the training path only
needs the dense masked tensor.

Error feedback: `sparsify_ef` maintains the standard residual accumulator so
dropped mass re-enters later iterations (cited compressors [30],[51] all do
this; required for sane convergence in the E2E run).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import BLOCK, EF_MAX_BLOCK, INTERPRET, nblocks, pad1d
from .reduce import block_absmax, block_count_ge

# Bisection trip count (§Perf iteration 2): 20 passes give 2^-20 relative
# threshold resolution — far below the spacing of adjacent gradient
# magnitudes in practice, and 33% fewer count-reduction passes over the
# full vector than the initial 30 (each pass re-reads |g| from HBM, so the
# trip count directly scales the kernel's dominant bytes-moved term).
BISECT_ITERS = 20


def _mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0]
    o_ref[...] = jnp.where(jnp.abs(x) >= t, x, jnp.zeros_like(x))


def threshold_mask(x: jax.Array, t: jax.Array, block: int = BLOCK) -> jax.Array:
    """Element-wise |x| >= t mask-apply over a flat (possibly unpadded) x."""
    padded, n = pad1d(x, block)
    nb = nblocks(padded.shape[0], block)
    t = jnp.asarray(t, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _mask_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, jnp.float32),
        interpret=INTERPRET,
    )(padded, t)
    return out[:n].reshape(x.shape)


def find_threshold(x: jax.Array, k: int, block: int = BLOCK) -> jax.Array:
    """Bisection for t with count(|x| >= t) ~= k. Returns scalar f32 > 0.

    Monotone invariant maintained: count(lo) >= k >= count(hi) - so the
    returned lo always selects at least k elements and hi selects at most k;
    we return lo (selects >= k, erring on keeping slightly more mass, the
    conservative side for error feedback).
    """
    padded, _ = pad1d(x, block)
    amax = jnp.max(block_absmax(padded, block))

    def count(t):
        return jnp.sum(block_count_ge(padded, t.reshape(1), block))

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        c = count(mid)
        lo2 = jnp.where(c >= k, mid, lo)
        hi2 = jnp.where(c >= k, hi, mid)
        return lo2, hi2

    # lo starts at a tiny positive epsilon so zero padding never selects.
    eps0 = jnp.float32(1e-38)
    lo, hi = jax.lax.fori_loop(
        0, BISECT_ITERS, body, (eps0, amax + jnp.float32(1e-30))
    )
    return lo


def sparsify(x: jax.Array, k: int, block: int = BLOCK):
    """Top-k(ish) sparsification: (masked dense tensor, threshold)."""
    t = find_threshold(x, k, block)
    return threshold_mask(x, t, block), t


def _ef_kernel(g_ref, r_ref, t_ref, o_ref, nr_ref):
    corrected = g_ref[...] + r_ref[...]
    t = t_ref[0]
    kept = jnp.where(jnp.abs(corrected) >= t, corrected, jnp.zeros_like(corrected))
    o_ref[...] = kept
    nr_ref[...] = corrected - kept


def sparsify_ef(g: jax.Array, residual: jax.Array, k: int, block: int = BLOCK):
    """Error-feedback sparsification: returns (masked, new_residual, t).

    Invariant (tested): masked + new_residual == g + residual exactly,
    because the kernel computes both from the same `corrected` value in one
    VMEM pass (a fused two-output element-wise kernel).
    """
    block = min(block, EF_MAX_BLOCK)  # VMEM cap (common.py §Perf)
    corrected_t = find_threshold(g.reshape(-1) + residual.reshape(-1), k, block)
    gp, n = pad1d(g, block)
    rp, _ = pad1d(residual, block)
    nb = nblocks(gp.shape[0], block)
    t = corrected_t.reshape(1)
    masked, new_r = pl.pallas_call(
        _ef_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(gp.shape, jnp.float32),
            jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        ],
        interpret=INTERPRET,
    )(gp, rp, t)
    return (
        masked[:n].reshape(g.shape),
        new_r[:n].reshape(g.shape),
        corrected_t,
    )
