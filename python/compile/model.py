"""Layer-2: JAX transformer LM (fwd/bwd) over a single flat parameter vector.

The entire model state lives in ONE flat f32 vector so the Rust coordinator
(L3) can treat parameters, Adam moments, gradients, and compressed
differentials as opaque same-length buffers — exactly the view a
checkpointing system needs. The (name, offset, len) layout is exported to
`artifacts/<model>.layout.txt` and is what LowDiff+ uses for *layer-wise*
gradient streaming (paper §VI-A): a "layer" is a contiguous flat slice.

Architecture: pre-LN causal transformer decoder, learned positions, tied
output head — a GPT-2-shaped model scaled by config (Table II analogues).
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int  # tokens per sample (targets are the 1-shifted sequence)
    batch: int
    # Pallas 1-D block for the element-wise kernels lowered into this
    # model's artifacts. Coarser for big models to bound unrolled-grid HLO
    # size under interpret=True (DESIGN.md §4).
    block: int = 65536


# The model zoo. `tiny` drives unit tests, `small` the quickstart,
# `e2e` the end-to-end training example (EXPERIMENTS.md §E2E), `gpt2s`
# is a ~GPT2-S-class config for scale checks (artifact built on demand).
CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4,
                        d_ff=256, seq_len=32, batch=4, block=16384),
    "small": ModelConfig("small", vocab=1024, d_model=192, n_layers=4,
                         n_heads=6, d_ff=768, seq_len=64, batch=8,
                         block=262144),
    "e2e": ModelConfig("e2e", vocab=8192, d_model=512, n_layers=8, n_heads=8,
                       d_ff=2048, seq_len=128, batch=8, block=1048576),
    "gpt2s": ModelConfig("gpt2s", vocab=16384, d_model=768, n_layers=12,
                         n_heads=12, d_ff=3072, seq_len=256, batch=4,
                         block=4194304),
}


# ------------------------------------------------------------- layout ------
def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list; flat offsets follow this order."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes += [
            (p + "ln1.scale", (cfg.d_model,)),
            (p + "ln1.bias", (cfg.d_model,)),
            (p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "attn.wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2.scale", (cfg.d_model,)),
            (p + "ln2.bias", (cfg.d_model,)),
            (p + "mlp.w1", (cfg.d_model, cfg.d_ff)),
            (p + "mlp.b1", (cfg.d_ff,)),
            (p + "mlp.w2", (cfg.d_ff, cfg.d_model)),
            (p + "mlp.b2", (cfg.d_model,)),
        ]
    shapes += [
        ("lnf.scale", (cfg.d_model,)),
        ("lnf.bias", (cfg.d_model,)),
    ]
    return shapes


def layout(cfg: ModelConfig) -> List[Tuple[str, int, int]]:
    """(name, offset, len) per tensor in the flat vector."""
    out, off = [], 0
    for name, shape in param_shapes(cfg):
        n = 1
        for s in shape:
            n *= s
        out.append((name, off, n))
        off += n
    return out


def num_params(cfg: ModelConfig) -> int:
    return sum(n for _, _, n in layout(cfg))


def unflatten(cfg: ModelConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    params = {}
    for (name, shape), (_, off, n) in zip(param_shapes(cfg), layout(cfg)):
        params[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
    return params


def init_params(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """Flat init vector from an int32[1] seed (lowered to HLO so Rust can
    self-initialize without a Python runtime)."""
    key = jax.random.PRNGKey(seed[0].astype(jnp.uint32))
    chunks = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        n_in = shape[0] if len(shape) > 1 else shape[0]
        if name.endswith(("scale",)):
            chunk = jnp.ones(shape, jnp.float32)
        elif name.endswith(("bias", "b1", "b2")):
            chunk = jnp.zeros(shape, jnp.float32)
        else:
            std = 0.02 if name in ("embed", "pos") else (2.0 / (n_in + shape[-1])) ** 0.5
            chunk = std * jax.random.normal(sub, shape, jnp.float32)
        chunks.append(chunk.reshape(-1))
    return jnp.concatenate(chunks)


# ------------------------------------------------------------ forward ------
def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(cfg: ModelConfig, x, wqkv, wo):
    b, s, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    qkv = x @ wqkv  # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    logits = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(causal, logits, jnp.float32(-1e9))
    probs = jax.nn.softmax(logits, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def forward_logits(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array):
    """tokens int32 [batch, seq_len] -> logits [batch, seq_len, vocab]."""
    p = unflatten(cfg, flat)
    x = p["embed"][tokens] + p["pos"][None, : tokens.shape[1], :]
    for i in range(cfg.n_layers):
        q = f"layer{i}."
        a = _attention(cfg, _layer_norm(x, p[q + "ln1.scale"], p[q + "ln1.bias"]),
                       p[q + "attn.wqkv"], p[q + "attn.wo"])
        x = x + a
        hmid = jax.nn.gelu(_layer_norm(x, p[q + "ln2.scale"], p[q + "ln2.bias"])
                           @ p[q + "mlp.w1"] + p[q + "mlp.b1"])
        x = x + hmid @ p[q + "mlp.w2"] + p[q + "mlp.b2"]
    x = _layer_norm(x, p["lnf.scale"], p["lnf.bias"])
    return x @ p["embed"].T  # tied head


def loss_fn(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array):
    """Next-token cross-entropy; tokens [batch, seq_len], predicts t+1."""
    logits = forward_logits(cfg, flat, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def grad_fn(cfg: ModelConfig):
    """(flat, tokens) -> (loss, flat_grads). The paper's Backward (Eq.(2))."""
    return jax.value_and_grad(lambda f, t: loss_fn(cfg, f, t))


# ----------------------------------------------------- composed steps ------
def fused_step(cfg: ModelConfig, rho: float = 0.01, lr: float = 1e-3):
    """Full LowDiff training iteration as ONE lowered computation:

      (p, m, v, residual, tokens, step) ->
          (loss, p', m', v', residual', compressed_grad, threshold)

    Backward (L2 autodiff) -> top-k compress with error feedback (L1 Pallas)
    -> fused Adam (L1 Pallas). The compressed (dense-masked) gradient comes
    out as a first-class output precisely so the Rust coordinator can reuse
    it as the differential checkpoint (paper Eq.(7)) with zero extra
    computation — the core LowDiff idea.
    """
    from .kernels import adam as adam_k
    from .kernels import topk as topk_k

    k = max(1, int(rho * num_params(cfg)))

    def step_fn(p, m, v, residual, tokens, step):
        loss, g = grad_fn(cfg)(p, tokens)
        masked, new_res, t = topk_k.sparsify_ef(g, residual, k, block=cfg.block)
        p2, m2, v2 = adam_k.adam_update(p, m, v, masked, step[0],
                                        lr=lr, block=cfg.block)
        return loss, p2, m2, v2, new_res, masked, t

    return step_fn


def adam_step(cfg: ModelConfig, lr: float = 1e-3):
    """(p, m, v, g, step) -> (p', m', v') — update only (Pallas Adam).

    Also the recovery-path diff-merge: applying a stored compressed gradient
    to a full checkpoint is exactly this computation (Alg.1 line 18).
    """
    from .kernels import adam as adam_k

    def fn(p, m, v, g, step):
        return adam_k.adam_update(p, m, v, g, step[0], lr=lr, block=cfg.block)

    return fn


def compress_step(cfg: ModelConfig, rho: float = 0.01):
    """(g, residual) -> (masked, residual', threshold) — Pallas top-k EF."""
    from .kernels import topk as topk_k

    k = max(1, int(rho * num_params(cfg)))

    def fn(g, residual):
        return topk_k.sparsify_ef(g, residual, k, block=cfg.block)

    return fn
