"""L1/L2 performance analysis (EXPERIMENTS.md §Perf).

Pallas interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so
L1 is analyzed structurally: VMEM footprint per grid step and the
bytes-moved roofline of each kernel, per model block size. L2 is profiled
via HLO op counts of the lowered artifacts (fusion sanity: no exploded op
counts, no duplicated backward subgraphs).

Usage: cd python && python -m compile.perf_analysis
"""

import os
import re

from . import model as M

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM budget (v4-class)
HBM_BW = 1.2e12  # ~1.2 TB/s HBM (A100-class translate: 2 TB/s; ratio holds)


def kernel_vmem_report(cfg: M.ModelConfig):
    """Fused Adam: 4 in + 3 out tiles; EF-sparsify: 2 in + 2 out + scalar;
    count/absmax reductions: 1 in + tiny out."""
    from .kernels.common import ADAM_MAX_BLOCK, EF_MAX_BLOCK

    rows = []
    for name, n_tiles, cap in [
        ("adam (p,m,v,g -> p',m',v')", 7, ADAM_MAX_BLOCK),
        ("sparsify_ef (g,r -> masked,r')", 4, EF_MAX_BLOCK),
        ("count_ge / absmax (reduce)", 1, None),
        ("quant8 (x -> q,scales)", 2, None),
    ]:
        b = min(cfg.block, cap) if cap else cfg.block
        vmem = n_tiles * b * 4
        rows.append((name, b, vmem, vmem / VMEM_BYTES))
    return rows


def kernel_roofline(cfg: M.ModelConfig):
    """Bytes moved per full-vector invocation (HBM<->VMEM), and the
    roofline time at HBM bandwidth. All kernels are element-wise/reduction
    (VPU): bandwidth-bound, zero MXU use — the efficiency target is
    bytes-moved/peak-BW, matching the paper's 'DC time << iteration'."""
    n = M.num_params(cfg)
    out = {}
    out["adam"] = 7 * n * 4  # read p,m,v,g; write p,m,v
    from .kernels.topk import BISECT_ITERS
    out["sparsify_ef"] = (2 + 2) * n * 4 + BISECT_ITERS * n * 4  # + bisection passes
    out["sparsify_ef_note"] = f"{BISECT_ITERS} bisection count passes re-read |g|"
    out["quant8"] = n * 4 + n + n // 256 * 4
    return n, out


def hlo_op_counts(path: str):
    ops = {}
    with open(path) as f:
        for line in f:
            m = re.search(r"=\s+\w+\[?[^=]*\]?\s+(\w+)\(", line)
            if m:
                ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return ops


def main():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in ["tiny", "small", "e2e"]:
        cfg = M.CONFIGS[name]
        n, roof = kernel_roofline(cfg)
        print(f"\n=== {name} ({n/1e6:.2f}M params, block={cfg.block}) ===")
        print("L1 VMEM per grid step (budget 16 MiB):")
        for kname, b, vmem, frac in kernel_vmem_report(cfg):
            print(f"  {kname:<34} block {b:>8} -> {vmem/1e6:7.2f} MB ({frac*100:5.1f}% VMEM)")
        print("L1 HBM roofline per invocation (@1.2 TB/s):")
        for k in ["adam", "sparsify_ef", "quant8"]:
            by = roof[k]
            print(f"  {k:<12} {by/1e6:9.1f} MB moved -> {by/HBM_BW*1e6:8.1f} µs")
        print(f"  note: {roof['sparsify_ef_note']}")
        print("L2 HLO op profile (lowered artifacts):")
        for a in ["grads", "fused"]:
            p = os.path.join(art, f"{name}.{a}.hlo.txt")
            if not os.path.exists(p):
                continue
            ops = hlo_op_counts(p)
            total = sum(ops.values())
            top = sorted(ops.items(), key=lambda kv: -kv[1])[:6]
            print(f"  {a:<6} {total:5d} ops; top: " + ", ".join(f"{k}={v}" for k, v in top))


if __name__ == "__main__":
    main()
