import os
import sys

import numpy as np
import pytest

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
