"""Fused Pallas Adam kernel vs jnp oracle and analytic facts."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.adam import adam_update, bias_correction, B1, B2

BLK = 1024


def _state(rng, n):
    return (
        jnp.asarray(rng.normal(size=n).astype("float32")),
        jnp.asarray((rng.normal(size=n) * 0.01).astype("float32")),
        jnp.asarray(np.abs(rng.normal(size=n) * 1e-4).astype("float32")),
        jnp.asarray(rng.normal(size=n).astype("float32")),
    )


def test_matches_ref_step1(rng):
    p, m, v, g = _state(rng, 3000)
    got = adam_update(p, m, v, g, 1, block=BLK)
    want = ref.adam_ref(p, m, v, g, 1)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-8)


def test_matches_ref_late_step(rng):
    p, m, v, g = _state(rng, 2000)
    got = adam_update(p, m, v, g, 1000, block=BLK)
    want = ref.adam_ref(p, m, v, g, 1000)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-8)


def test_zero_grad_decays_moments_only(rng):
    p, m, v, _ = _state(rng, 500)
    g = jnp.zeros(500)
    p2, m2, v2 = adam_update(p, m, v, g, 5, block=BLK)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(B1 * m), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(B2 * v), rtol=1e-6)


def test_step1_update_magnitude_near_lr(rng):
    # At t=1 with zero moments, |update| ~= lr * sign(g) for g != 0
    n = 1000
    p = jnp.zeros(n)
    g = jnp.asarray(rng.normal(size=n).astype("float32")) + jnp.float32(3.0)
    p2, _, _ = adam_update(p, jnp.zeros(n), jnp.zeros(n), g, 1, lr=1e-3, block=BLK)
    np.testing.assert_allclose(np.asarray(jnp.abs(p2)), 1e-3, rtol=1e-3)


def test_bias_correction_values():
    bc = np.asarray(bias_correction(1))
    np.testing.assert_allclose(bc[0], 1.0 / (1 - B1), rtol=1e-6)
    # f32: 1/(1-0.999) carries ~1e-5 relative error
    np.testing.assert_allclose(bc[1], 1.0 / (1 - B2), rtol=5e-5)


def test_unaligned_length(rng):
    """Length not a block multiple: padding must not leak into outputs."""
    p, m, v, g = _state(rng, BLK + 37)
    got = adam_update(p, m, v, g, 3, block=BLK)
    want = ref.adam_ref(p, m, v, g, 3)
    for a, b in zip(got, want):
        assert a.shape == (BLK + 37,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-8)


@settings(deadline=None, max_examples=15)
@given(
    n=st.integers(min_value=1, max_value=4000),
    step=st.integers(min_value=1, max_value=10000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_matches_ref(n, step, seed):
    p, m, v, g = _state(np.random.default_rng(seed), n)
    got = adam_update(p, m, v, g, step, block=BLK)
    want = ref.adam_ref(p, m, v, g, step)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


def test_sequence_of_updates_converges_quadratic(rng):
    """Minimize f(x) = x^2/2: Adam should move toward 0."""
    x = jnp.full((16,), 5.0)
    m = jnp.zeros(16)
    v = jnp.zeros(16)
    for t in range(1, 400):
        g = x  # grad of x^2/2
        x, m, v = adam_update(x, m, v, g, t, lr=0.05, block=BLK)
    assert float(jnp.max(jnp.abs(x))) < 1.0
