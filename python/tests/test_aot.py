"""AOT artifacts: HLO text is parseable-shaped and numerically faithful.

Rust-side execution of the same files is covered by `cargo test`
(rust/tests/); here we verify the lowering itself.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
CFG = M.CONFIGS["tiny"]
NAMES = ["init", "grads", "eval", "adam", "compress", "fused"]


@pytest.mark.parametrize("name", NAMES)
def test_artifact_exists_and_is_hlo_text(name):
    path = os.path.join(ART, f"tiny.{name}.hlo.txt")
    assert os.path.exists(path), f"run `make artifacts` first: {path}"
    text = open(path).read()
    assert "ENTRY" in text and "HloModule" in text
    # no Mosaic custom-calls: interpret=True must lower to plain HLO
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_layout_file_round_trips():
    path = os.path.join(ART, "tiny.layout.txt")
    lines = open(path).read().strip().splitlines()
    kv = {}
    tensors = []
    in_tensors = False
    for ln in lines[1:]:
        if ln == "tensors":
            in_tensors = True
            continue
        parts = ln.split()
        if in_tensors:
            tensors.append((parts[0], int(parts[1]), int(parts[2])))
        else:
            kv[parts[0]] = parts[1]
    assert int(kv["n_params"]) == M.num_params(CFG)
    assert tensors == M.layout(CFG)
    assert float(kv["rho"]) == aot.RHO


def test_to_hlo_text_matches_eager():
    """The lowered eval computation equals eager execution."""
    rng = np.random.default_rng(0)
    p = M.init_params(CFG, jnp.array([1], jnp.int32))
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    eager = float(M.loss_fn(CFG, p, toks))
    jitted = float(jax.jit(lambda a, b: M.loss_fn(CFG, a, b))(p, toks))
    np.testing.assert_allclose(eager, jitted, rtol=1e-6)


def test_hlo_text_has_tupled_root():
    # return_tuple=True: the entry root must be a tuple so rust can
    # unwrap with to_tuple()
    text = open(os.path.join(ART, "tiny.adam.hlo.txt")).read()
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert root_lines and any("tuple" in l or "(f32" in l for l in root_lines)
