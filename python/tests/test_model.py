"""L2 model: layout, shapes, gradients, and the fused LowDiff step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jnp.array([7], jnp.int32))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(3)
    return jnp.asarray(
        rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32
    )


def test_layout_is_contiguous_and_complete():
    lay = M.layout(CFG)
    off = 0
    for name, o, n in lay:
        assert o == off, name
        assert n > 0
        off += n
    assert off == M.num_params(CFG)


def test_layout_matches_artifact_file():
    with open("../artifacts/tiny.layout.txt") as f:
        text = f.read()
    assert f"n_params {M.num_params(CFG)}" in text
    for name, off, n in M.layout(CFG):
        assert f"{name} {off} {n}" in text


def test_unflatten_shapes(params):
    p = M.unflatten(CFG, params)
    assert p["embed"].shape == (CFG.vocab, CFG.d_model)
    assert p["layer0.attn.wqkv"].shape == (CFG.d_model, 3 * CFG.d_model)
    assert p["lnf.scale"].shape == (CFG.d_model,)


def test_init_deterministic():
    a = M.init_params(CFG, jnp.array([7], jnp.int32))
    b = M.init_params(CFG, jnp.array([7], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = M.init_params(CFG, jnp.array([8], jnp.int32))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_initial_loss_near_uniform(params, tokens):
    loss = M.loss_fn(CFG, params, tokens)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.3


def test_grads_finite_and_full_coverage(params, tokens):
    loss, g = M.grad_fn(CFG)(params, tokens)
    g = np.asarray(g)
    assert np.all(np.isfinite(g))
    # "general DNN models are updated entirely": every tensor gets gradient
    for name, off, n in M.layout(CFG):
        if name == "pos":
            # positions beyond seq_len-1 (inputs are [:, :-1]) get no grad
            continue
        assert np.any(g[off : off + n] != 0), f"no gradient for {name}"


def test_loss_decreases_with_training(params, tokens):
    p = params
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    loss0 = float(M.loss_fn(CFG, p, tokens))
    step_fn = jax.jit(M.fused_step(CFG, rho=0.05, lr=1e-2))
    for t in range(1, 16):
        res = jnp.zeros_like(p) if t == 1 else res
        loss, p, m, v, res, _, _ = step_fn(p, m, v, res, tokens, jnp.array([float(t)]))
    assert float(loss) < loss0 - 0.5


def test_fused_step_consistency(params, tokens):
    """fused == grads -> compress_ef -> adam composed manually."""
    p = params
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    res = jnp.zeros_like(p)
    step = jnp.array([1.0])

    loss_f, p_f, m_f, v_f, res_f, cg_f, t_f = M.fused_step(CFG)(p, m, v, res, tokens, step)

    loss_g, g = M.grad_fn(CFG)(p, tokens)
    cg, res2, t = M.compress_step(CFG)(g, res)
    p2, m2, v2 = M.adam_step(CFG)(p, m, v, cg, step)

    np.testing.assert_allclose(float(loss_f), float(loss_g), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(cg_f), np.asarray(cg))
    np.testing.assert_array_equal(np.asarray(p_f), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(m_f), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v2))


def test_compressed_grad_sparsity(params, tokens):
    _, g = M.grad_fn(CFG)(params, tokens)
    cg, _, _ = M.compress_step(CFG, rho=0.01)(g, jnp.zeros_like(g))
    k = max(1, int(0.01 * M.num_params(CFG)))
    assert int(jnp.sum(cg != 0)) == k


def test_adam_step_matches_oracle(params, tokens):
    _, g = M.grad_fn(CFG)(params, tokens)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    got = M.adam_step(CFG)(params, m, v, g, jnp.array([1.0]))
    want = ref.adam_ref(params, m, v, g, 1)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


def test_recovery_replay_equivalence(params, tokens):
    """Paper Eq.(6)/(7): replaying stored compressed grads through Adam
    reconstructs the exact post-training state (concat/replay mode)."""
    p = params
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    res = jnp.zeros_like(p)
    step_fn = jax.jit(M.fused_step(CFG))
    diffs = []
    for t in range(1, 5):
        _, p, m, v, res, cg, _ = step_fn(p, m, v, res, tokens, jnp.array([float(t)]))
        diffs.append(cg)

    # recover from the initial full checkpoint + stored differentials
    rp, rm, rv = params, jnp.zeros_like(p), jnp.zeros_like(p)
    adam = M.adam_step(CFG)
    for t, cg in enumerate(diffs, start=1):
        rp, rm, rv = adam(rp, rm, rv, cg, jnp.array([float(t)]))

    np.testing.assert_array_equal(np.asarray(rp), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(rm), np.asarray(m))
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(v))
