"""Per-block int8 quantization kernels vs oracle and error bounds."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant import QBLOCK, dequant8, quant8

BLK = 1024


def _rand(rng, n, scale=1.0):
    return jnp.asarray((rng.normal(size=n) * scale).astype("float32"))


def test_quant_matches_ref(rng):
    x = _rand(rng, 3000)
    q, s, n = quant8(x, BLK)
    qr, sr = ref.quant8_ref(x, QBLOCK)
    np.testing.assert_array_equal(np.asarray(q)[: qr.shape[0]], np.asarray(qr))
    np.testing.assert_allclose(
        np.asarray(s)[: sr.shape[0]], np.asarray(sr), rtol=1e-7
    )


def test_roundtrip_error_bound(rng):
    x = _rand(rng, 5000)
    q, s, n = quant8(x, BLK)
    d = dequant8(q, s, n, BLK)
    err = np.abs(np.asarray(d) - np.asarray(x))
    # each element's error <= its block's scale / 2 (+ float slack)
    scales = np.repeat(np.asarray(s), QBLOCK)[:n]
    assert np.all(err <= scales / 2 + 1e-7)


def test_zero_block():
    x = jnp.zeros(2 * QBLOCK, jnp.float32)
    q, s, n = quant8(x, BLK)
    assert int(jnp.sum(jnp.abs(q.astype(jnp.int32)))) == 0
    d = dequant8(q, s, n, BLK)
    np.testing.assert_array_equal(np.asarray(d), 0.0)


def test_extreme_range(rng):
    # one huge value per block shouldn't break the others catastrophically
    x = _rand(rng, QBLOCK).at[0].set(1e6)
    q, s, n = quant8(x, BLK)
    d = dequant8(q, s, n, BLK)
    assert abs(float(d[0]) - 1e6) / 1e6 < 1e-2


def test_q_range(rng):
    x = _rand(rng, 4000, scale=100.0)
    q, _, _ = quant8(x, BLK)
    qn = np.asarray(q).astype(np.int32)
    assert qn.min() >= -127 and qn.max() <= 127


@settings(deadline=None, max_examples=15)
@given(
    n=st.integers(min_value=1, max_value=4000),
    scale=st.floats(min_value=1e-4, max_value=1e4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_roundtrip(n, scale, seed):
    x = _rand(np.random.default_rng(seed), n, scale)
    q, s, nn = quant8(x, BLK)
    d = dequant8(q, s, nn, BLK)
    scales = np.repeat(np.asarray(s), QBLOCK)[:n]
    assert np.all(np.abs(np.asarray(d) - np.asarray(x)) <= scales / 2 + 1e-6 * scale)
