"""Pallas partial-reduction kernels vs jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.reduce import absmax, block_absmax, block_count_ge, count_ge
from compile.kernels.common import pad1d

BLK = 1024


def _rand(rng, n):
    return jnp.asarray(rng.normal(size=n).astype("float32"))


def test_absmax_matches_ref(rng):
    x = _rand(rng, 5000)
    assert float(absmax(x, BLK)) == float(jnp.max(jnp.abs(x)))


def test_block_absmax_per_block(rng):
    x = _rand(rng, 4 * BLK)
    per = block_absmax(x, BLK)
    expect = jnp.max(jnp.abs(x.reshape(4, BLK)), axis=1)
    np.testing.assert_allclose(np.asarray(per), np.asarray(expect))


def test_count_ge_matches_ref(rng):
    x = _rand(rng, 3000)
    for t in [0.1, 0.5, 1.0, 2.5]:
        assert int(count_ge(x, t, BLK)) == int(ref.count_ge_ref(jnp.abs(x), t))


def test_count_ge_zero_padding_not_counted(rng):
    # padding is zeros; any t > 0 must not count it
    x = _rand(rng, BLK + 7)
    c = count_ge(x, 1e-30, BLK)
    assert int(c) == int(jnp.sum(jnp.abs(x) >= 1e-30))


def test_block_count_ge_per_block(rng):
    x, _ = pad1d(_rand(rng, 2 * BLK), BLK)
    t = jnp.array([0.7], jnp.float32)
    per = block_count_ge(x, t, BLK)
    expect = jnp.sum(jnp.abs(x.reshape(2, BLK)) >= 0.7, axis=1)
    np.testing.assert_array_equal(np.asarray(per), np.asarray(expect))


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(min_value=1, max_value=6000),
    t=st.floats(min_value=1e-3, max_value=4.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_count_ge_property(n, t, seed):
    x = _rand(np.random.default_rng(seed), n)
    assert int(count_ge(x, t, BLK)) == int(np.sum(np.abs(np.asarray(x)) >= t))


def test_absmax_empty_sign_invariance(rng):
    x = _rand(rng, 100)
    assert float(absmax(x, BLK)) == float(absmax(-x, BLK))
