"""Top-k threshold sparsification kernels vs exact jax.lax.top_k oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.topk import find_threshold, sparsify, sparsify_ef, threshold_mask

BLK = 1024


def _rand(rng, n):
    return jnp.asarray(rng.normal(size=n).astype("float32"))


def test_threshold_mask_matches_ref(rng):
    g = _rand(rng, 3000)
    got = threshold_mask(g, 0.8, BLK)
    want = ref.threshold_mask_ref(g, 0.8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_find_threshold_exact_on_continuous(rng):
    g = _rand(rng, 5000)
    k = 50
    t = find_threshold(g, k, BLK)
    t_ref = ref.kth_magnitude_ref(g, k)
    # bisection lower bound: selects >= k, and t <= kth magnitude
    assert float(t) <= float(t_ref) + 1e-6
    count = int(jnp.sum(jnp.abs(g) >= t))
    assert count == k  # continuous values: no ties, converges exactly


def test_sparsify_selects_topk_set(rng):
    g = _rand(rng, 4000)
    k = 40
    masked, _ = sparsify(g, k, BLK)
    want = ref.topk_mask_ref(g, k)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(want))


def test_sparsify_with_ties():
    # all-equal magnitudes: threshold selection keeps >= k (all of them)
    g = jnp.ones(100, jnp.float32)
    masked, t = sparsify(g, 10, BLK)
    assert int(jnp.sum(masked != 0)) >= 10


def test_sparsify_k_equals_n(rng):
    g = _rand(rng, 500)
    masked, _ = sparsify(g, 500, BLK)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(g))


def test_error_feedback_invariant(rng):
    """masked + new_residual == g + residual exactly (fused kernel)."""
    g = _rand(rng, 3000)
    r = _rand(rng, 3000) * 0.1
    masked, new_r, _ = sparsify_ef(g, r, 30, BLK)
    np.testing.assert_array_equal(
        np.asarray(masked + new_r), np.asarray(g + r)
    )


def test_error_feedback_matches_ref(rng):
    g = _rand(rng, 2000)
    r = _rand(rng, 2000) * 0.05
    masked, new_r, _ = sparsify_ef(g, r, 25, BLK)
    want_m, want_r = ref.sparsify_ef_ref(g, r, 25)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(want_m))
    np.testing.assert_array_equal(np.asarray(new_r), np.asarray(want_r))


def test_residual_accumulates_dropped_mass(rng):
    g = _rand(rng, 1000)
    masked, new_r, _ = sparsify_ef(g, jnp.zeros(1000), 10, BLK)
    # dropped mass ends up in the residual, nothing vanishes
    np.testing.assert_allclose(
        float(jnp.sum(jnp.abs(masked)) + jnp.sum(jnp.abs(new_r))),
        float(jnp.sum(jnp.abs(g))),
        rtol=1e-6,
    )


@settings(deadline=None, max_examples=15)
@given(
    n=st.integers(min_value=10, max_value=5000),
    frac=st.floats(min_value=0.001, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sparsify_property_count_and_dominance(n, frac, seed):
    """Selected count == k and selected set magnitude-dominates dropped."""
    g = _rand(np.random.default_rng(seed), n)
    k = max(1, min(n, int(frac * n)))
    masked, t = sparsify(g, k, BLK)
    m = np.asarray(masked)
    gnp = np.asarray(g)
    nnz = int(np.sum(m != 0))
    assert nnz == k
    kept_min = np.min(np.abs(m[m != 0])) if nnz else np.inf
    dropped = gnp[m == 0]
    if dropped.size:
        assert kept_min >= np.max(np.abs(dropped))


def test_threshold_positive(rng):
    # threshold is strictly positive so zero padding never selects
    g = _rand(rng, 100)
    _, t = sparsify(g, 5, BLK)
    assert float(t) > 0
