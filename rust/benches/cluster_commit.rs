//! Cluster-commit bench: global-commit overhead vs rank count.
//!
//! Drives the same training timeline (anchor full + diff epochs) through
//! the multi-rank cluster runtime at 1/2/4/8 ranks, twice per rank count:
//! once over raw MemStore lanes (coordination overhead only) and once over
//! throttled 256 MB/s devices (the paper's SSD model, where rank fan-out
//! should win wall-clock like sharding does). Reports wall per epoch, the
//! coordinator's phase-2 share (record writes — the *cost of atomicity*),
//! and record bytes.
//!
//! Run: `cargo bench --bench cluster_commit`; baseline in
//! `BENCH_cluster.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lowdiff::checkpoint::format::model_signature;
use lowdiff::checkpoint::manifest::Manifest;
use lowdiff::cluster::{partition_even, Cluster, ClusterConfig, ClusterStats};
use lowdiff::compress::topk_mask;
use lowdiff::optim::ModelState;
use lowdiff::storage::{MemStore, Namespaced, StorageBackend, Throttled};
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

const N_PARAMS: usize = 256 * 1024;
const STEPS: u64 = 16;
const RHO: f64 = 0.01;

/// One run at `ranks`; `throttled_devices` wraps every rank's namespace in
/// its own 256 MB/s token bucket (Checkmate's per-rank device model — one
/// SSD per rank, so rank fan-out multiplies aggregate bandwidth).
fn drive(
    store: Arc<dyn StorageBackend>,
    ranks: usize,
    throttled_devices: bool,
) -> (f64, ClusterStats) {
    let sig = model_signature("cluster-bench", N_PARAMS);
    let cfg = ClusterConfig { model_sig: sig, gc: false, ..ClusterConfig::default() };
    let parts = partition_even(N_PARAMS, ranks);
    let cluster = if throttled_devices {
        let shared = Arc::clone(&store);
        Cluster::spawn_with(Arc::clone(&store), parts, cfg, move |r| {
            Arc::new(Throttled::new(
                Namespaced::new(Arc::clone(&shared), Manifest::gen_rank_prefix(0, r)),
                256e6,
                Duration::from_millis(1),
            )) as Arc<dyn StorageBackend>
        })
    } else {
        Cluster::spawn(Arc::clone(&store), parts, cfg)
    };
    let mut rng = Rng::new(23);
    let state = ModelState::new(Flat(vec![0.1; N_PARAMS]));
    let k = ((N_PARAMS as f64 * RHO) as usize).max(1);
    let t0 = Instant::now();
    cluster.put_full(0, &state);
    for step in 1..=STEPS {
        let mut g = vec![0f32; N_PARAMS];
        rng.fill_normal_f32(&mut g);
        cluster.put_diff_dense(step, &topk_mask(&Flat(g), k));
    }
    let stats = cluster.finish();
    (t0.elapsed().as_secs_f64(), stats)
}

fn report(label: &str, ranks: usize, wall: f64, stats: &ClusterStats) {
    let epochs = STEPS + 1;
    println!(
        "{label:<28} ranks={ranks}  wall {:>7.1} ms ({:>6.2} ms/epoch)  commit {:>6.2} ms \
         ({:>4.1}%)  records {:>5} B  torn {}",
        wall * 1e3,
        wall * 1e3 / epochs as f64,
        stats.commit_secs * 1e3,
        stats.commit_secs / wall * 100.0,
        stats.record_bytes,
        stats.torn_commits,
    );
}

fn main() {
    println!(
        "== cluster_commit: {} params, rho {RHO}, {STEPS} diff epochs + anchor ==\n",
        N_PARAMS
    );

    let mut json_rows = Vec::new();
    println!("-- raw MemStore (coordination overhead only) --");
    for ranks in [1usize, 2, 4, 8] {
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let (wall, stats) = drive(store, ranks, false);
        assert_eq!(stats.global_commits, STEPS + 1, "every epoch must commit");
        assert_eq!(stats.torn_commits, 0);
        report("mem", ranks, wall, &stats);
        json_rows.push(format!(
            "    {{\"lanes\": \"mem\", \"ranks\": {ranks}, \"wall_ms\": {:.2}, \
             \"commit_ms\": {:.3}, \"record_bytes\": {}}}",
            wall * 1e3,
            stats.commit_secs * 1e3,
            stats.record_bytes
        ));
    }

    println!("\n-- one throttled 256 MB/s device per rank (aggregate bandwidth scales with R) --");
    let mut base = None;
    for ranks in [1usize, 2, 4, 8] {
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let (wall, stats) = drive(store, ranks, true);
        assert_eq!(stats.global_commits, STEPS + 1, "every epoch must commit");
        assert_eq!(stats.torn_commits, 0);
        report("per-rank device", ranks, wall, &stats);
        let b = *base.get_or_insert(wall);
        println!("{:>66}{:.2}x vs 1 rank", "", b / wall);
        json_rows.push(format!(
            "    {{\"lanes\": \"per-rank-256MBps\", \"ranks\": {ranks}, \"wall_ms\": {:.2}, \
             \"commit_ms\": {:.3}, \"record_bytes\": {}}}",
            wall * 1e3,
            stats.commit_secs * 1e3,
            stats.record_bytes
        ));
    }

    println!(
        "\nJSON (paste into BENCH_cluster.json \"measurements\"):\n[\n{}\n]",
        json_rows.join(",\n")
    );
    println!("\ncluster_commit bench done");
}
