//! Codec bench: bytes-on-wire and encode throughput for every payload
//! codec over the two workloads the adaptive policy arbitrates between —
//! top-k sparse gradient diffs (Raw / Zstd / Quant8) and periodic fulls
//! (plain Zstd vs XOR delta-vs-previous). The same achieved-ratio signal
//! drives the §V-C bandit's codec arm at runtime.
//!
//! Run: `cargo bench --bench codec`; baseline in `BENCH_codec.json`.
//! Acceptance (asserted below): Quant8 puts >= 2x fewer bytes on the wire
//! than Zstd on top-k values, and delta fulls undercut plain Zstd fulls on
//! slowly-drifting state — both with exact index streams and bounded,
//! non-compounding value error (see rust/tests/codec_roundtrip.rs).

use std::time::Instant;

use lowdiff::checkpoint::diff::{read_diff, write_diff_into_level, DiffPayload};
use lowdiff::checkpoint::format::{model_signature, PayloadCodec, DEFAULT_ZSTD_LEVEL};
use lowdiff::checkpoint::full::{full_raw_payload, write_full_delta_into, write_full_into_level};
use lowdiff::compress::topk_mask;
use lowdiff::optim::ModelState;
use lowdiff::sparse::SparseGrad;
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

const N: usize = 256 * 1024; // params
const RHO: f64 = 0.01; // top-k density
const DIFF_STEPS: u64 = 16;
const FULLS: usize = 8;
const DRIFT: usize = N / 200; // params nudged between consecutive fulls

fn diff_workload() -> (Vec<(u64, DiffPayload)>, u64) {
    let mut rng = Rng::new(42);
    let k = (N as f64 * RHO) as usize;
    let mut grads = Vec::new();
    let mut raw_bytes = 0u64;
    for step in 1..=DIFF_STEPS {
        let mut g = vec![0f32; N];
        rng.fill_normal_f32(&mut g);
        let s = SparseGrad::from_dense(&topk_mask(&Flat(g), k));
        raw_bytes += s.encoded_size() as u64;
        grads.push((step, DiffPayload::Gradient(s)));
    }
    (grads, raw_bytes)
}

/// (wire_bytes, encode_ns_per_nnz) for one codec over the diff workload.
fn run_diff_codec(
    codec: PayloadCodec,
    grads: &[(u64, DiffPayload)],
    sig: u64,
) -> (u64, f64) {
    let mut out = Vec::new();
    let mut wire = 0u64;
    let mut nnz = 0u64;
    let t0 = Instant::now();
    for (step, p) in grads {
        out.clear();
        wire +=
            write_diff_into_level(p, sig, *step, codec, DEFAULT_ZSTD_LEVEL, &mut out).unwrap()
                as u64;
        nnz += p.sparse().nnz() as u64;
    }
    let ns = t0.elapsed().as_nanos() as f64 / nnz as f64;
    // decode sanity: the wire stays readable (indices exact for every codec)
    let (step, back) = read_diff(&out, sig).unwrap();
    let (last_step, last) = grads.last().unwrap();
    assert_eq!(step, *last_step);
    assert_eq!(back.sparse().indices, last.sparse().indices, "{}", codec.name());
    (wire, ns)
}

/// Slowly-drifting model states, as between consecutive periodic fulls.
fn full_workload() -> Vec<ModelState> {
    let mut rng = Rng::new(7);
    let mut state = ModelState::new(Flat({
        let mut p = vec![0f32; N];
        rng.fill_normal_f32(&mut p);
        p
    }));
    let mut states = Vec::with_capacity(FULLS);
    for step in 0..FULLS as u64 {
        state.step = step * 100;
        states.push(state.clone());
        for _ in 0..DRIFT {
            let at = rng.range(0, N);
            state.params.0[at] += (rng.next_f32() - 0.5) * 2e-3;
            state.m.0[at] += (rng.next_f32() - 0.5) * 1e-3;
        }
    }
    states
}

/// (wire_bytes, encode_ns_per_param) for the full chain, plain vs delta.
fn run_fulls(states: &[ModelState], sig: u64, delta: bool) -> (u64, f64) {
    let mut out = Vec::new();
    let mut base_payload = Vec::new();
    full_raw_payload(&states[0], &mut base_payload);
    let mut wire = 0u64;
    let t0 = Instant::now();
    for (i, s) in states.iter().enumerate() {
        out.clear();
        let bytes = if delta && i > 0 {
            write_full_delta_into(
                s,
                sig,
                states[0].step,
                &base_payload,
                DEFAULT_ZSTD_LEVEL,
                &mut out,
            )
            .unwrap()
        } else {
            write_full_into_level(s, sig, PayloadCodec::Zstd, DEFAULT_ZSTD_LEVEL, &mut out)
                .unwrap()
        };
        wire += bytes as u64;
    }
    let ns = t0.elapsed().as_nanos() as f64 / (states.len() * N) as f64;
    (wire, ns)
}

fn main() {
    let sig = model_signature("codec-bench", N);
    println!("== top-k diff codecs ({N} params, rho {RHO}, {DIFF_STEPS} steps) ==");
    let (grads, raw_bytes) = diff_workload();
    let mut by_codec = Vec::new();
    for codec in [PayloadCodec::Raw, PayloadCodec::Zstd, PayloadCodec::Quant8] {
        let (wire, ns) = run_diff_codec(codec, &grads, sig);
        println!(
            "{:<10} wire {:>12} B  ratio {:>5.2}x  encode {:>7.2} ns/nnz",
            codec.name(),
            wire,
            raw_bytes as f64 / wire as f64,
            ns
        );
        by_codec.push((codec, wire, ns));
    }
    let zstd_wire = by_codec[1].1;
    let quant_wire = by_codec[2].1;

    println!("\n== periodic fulls ({N} params, {FULLS} fulls, {DRIFT} drifted/step) ==");
    let states = full_workload();
    let (plain_wire, plain_ns) = run_fulls(&states, sig, false);
    let (delta_wire, delta_ns) = run_fulls(&states, sig, true);
    println!("zstd fulls  wire {plain_wire:>12} B  encode {plain_ns:>6.2} ns/param");
    println!("delta fulls wire {delta_wire:>12} B  encode {delta_ns:>6.2} ns/param");

    // machine-readable block for BENCH_codec.json
    println!("\n{{");
    println!("  \"bench\": \"codec\",");
    println!("  \"diffs\": {{ \"raw_payload_bytes\": {raw_bytes},");
    for (codec, wire, ns) in &by_codec {
        println!(
            "    \"{}\": {{ \"wire_bytes\": {wire}, \"encode_ns_per_nnz\": {ns:.2} }},",
            codec.name()
        );
    }
    println!("    \"quant8_vs_zstd\": {:.2} }},", zstd_wire as f64 / quant_wire as f64);
    println!(
        "  \"fulls\": {{ \"zstd_wire_bytes\": {plain_wire}, \"delta_wire_bytes\": {delta_wire}, \
         \"delta_vs_zstd\": {:.2} }}",
        plain_wire as f64 / delta_wire as f64
    );
    println!("}}");

    // acceptance: the lossy arm must earn its place — >= 2x fewer wire
    // bytes than zstd on top-k values — and delta fulls must undercut
    // plain zstd fulls when the state drifts slowly
    assert!(
        2 * quant_wire <= zstd_wire,
        "quant8 must halve the zstd wire: {quant_wire} vs {zstd_wire}"
    );
    assert!(
        delta_wire < plain_wire,
        "delta fulls must beat plain fulls: {delta_wire} vs {plain_wire}"
    );
    println!(
        "\nacceptance: quant8 {:.2}x under zstd, delta fulls {:.2}x under plain (PASS)",
        zstd_wire as f64 / quant_wire as f64,
        plain_wire as f64 / delta_wire as f64
    );
}
