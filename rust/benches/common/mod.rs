//! Minimal bench harness (criterion is unavailable offline): warmup +
//! timed runs, median-of-N reporting, ns/op and throughput.

// each bench target compiles this module separately and uses a subset
#![allow(dead_code)]

use std::time::Instant;

pub struct Bench {
    pub name: String,
    samples: Vec<f64>,
}

/// Run `f` repeatedly for ~`budget_ms`, collecting per-call seconds.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> Bench {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_millis() < budget_ms as u128 || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    Bench { name: name.to_string(), samples }
}

impl Bench {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>12}/op   ({} samples)",
            self.name,
            lowdiff::util::human_duration(self.median()),
            self.samples.len()
        );
    }

    /// Report with bytes-throughput (for codec / IO benches).
    pub fn report_bytes(&self, bytes_per_op: usize) {
        let gbps = bytes_per_op as f64 / self.median() / 1e9;
        println!(
            "{:<44} {:>12}/op   {:>8.2} GB/s   ({} samples)",
            self.name,
            lowdiff::util::human_duration(self.median()),
            gbps,
            self.samples.len()
        );
    }
}
