//! Chain-compaction bench: recovery replay cost over a 64-diff chain,
//! uncompacted vs background-compacted at merge factors 4 and 8, plus the
//! compactor's own pass cost.
//!
//! The headline metric is **replay objects touched** (deterministic:
//! `⌈n/mf⌉` after a full compaction of a divisible chain, vs `n` raw) —
//! the `R_D`-side quantity the §V-C tuner's `observe_compaction` feedback
//! models. Wall times are machine-dependent and reported for context.
//! Bit-identity of the recovered state is asserted on every run.
//!
//! Run: `cargo bench --bench compaction`; baseline in
//! `BENCH_compaction.json`. Compaction-vs-checkpoint-write *interference*
//! (ungated vs the control plane's idle-triggered token-bucket gate) is
//! measured by the companion `control_loop` bench, baseline in
//! `BENCH_control.json`.

mod common;

use std::sync::Arc;

use lowdiff::checkpoint::format::model_signature;
use lowdiff::checkpoint::manifest::Manifest;
use lowdiff::compress::topk_mask;
use lowdiff::coordinator::checkpointer::{Checkpointer, CkptConfig, CkptItem};
use lowdiff::coordinator::recovery::{recover, RecoveryMode, RecoveryStats};
use lowdiff::optim::{Adam, ModelState};
use lowdiff::storage::{MemStore, StorageBackend};
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

const N_PARAMS: usize = 64 * 1024;
const STEPS: u64 = 64;
const RHO: f64 = 0.01;

/// Persist the fixed timeline through the checkpointer at the given merge
/// factor; returns the store and the compactor's counters.
fn build(compact_every: usize) -> (Arc<dyn StorageBackend>, u64, u64) {
    let sig = model_signature("compaction-bench", N_PARAMS);
    let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
    let ck = Checkpointer::spawn(
        Arc::clone(&store),
        CkptConfig { model_sig: sig, gc: false, compact_every, ..CkptConfig::default() },
    );
    let mut rng = Rng::new(61);
    let k = ((N_PARAMS as f64 * RHO) as usize).max(1);
    ck.queue
        .put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.1; N_PARAMS])))));
    for step in 1..=STEPS {
        let mut g = vec![0f32; N_PARAMS];
        rng.fill_normal_f32(&mut g);
        ck.queue
            .put(step, Arc::new(CkptItem::DiffDense(topk_mask(&Flat(g), k))));
    }
    let stats = ck.finish();
    assert_eq!(stats.errors, 0);
    (store, stats.merged_written, stats.raw_compacted)
}

fn recover_once(store: &Arc<dyn StorageBackend>, sig: u64) -> (ModelState, RecoveryStats) {
    recover(store.as_ref(), sig, &Adam::default(), RecoveryMode::SerialReplay).expect("recover")
}

fn main() {
    let sig = model_signature("compaction-bench", N_PARAMS);
    println!("chain: 1 anchor full + {STEPS} diffs, {N_PARAMS} params, rho {RHO}\n");

    let (baseline_store, _, _) = build(0);
    let (want, base_stats) = recover_once(&baseline_store, sig);
    assert_eq!(base_stats.n_diff_objects, STEPS as usize);

    let mut rows = Vec::new();
    for mf in [0usize, 4, 8] {
        let t0 = std::time::Instant::now();
        let (store, merged, raw_compacted) = build(mf);
        let build_secs = t0.elapsed().as_secs_f64();

        let (state, rstats) = recover_once(&store, sig);
        assert_eq!(state, want, "mf={mf}: compacted replay must be bit-identical");
        if mf >= 2 {
            assert!(
                rstats.n_diff_objects <= (STEPS as usize).div_ceil(mf) + 1,
                "mf={mf}: replay objects {} above the compaction bound",
                rstats.n_diff_objects
            );
            assert_eq!(merged as usize, STEPS as usize / mf);
        }
        let chain_objects = store
            .list()
            .unwrap()
            .iter()
            .filter(|n| Manifest::step_range(n).is_some_and(|(k, _, _)| k != "full"))
            .count();

        let b = common::bench(&format!("recover mf={mf}"), 300, || {
            let _ = recover_once(&store, sig);
        });
        b.report();
        println!(
            "  mf={mf:<3} chain objects {chain_objects:>3}  replay objects {:>3}  \
             merged spans {merged:>2}  raws compacted {raw_compacted:>2}",
            rstats.n_diff_objects
        );
        rows.push((mf, chain_objects, rstats.n_diff_objects, merged, b.median(), build_secs));
    }

    // machine-readable block for BENCH_compaction.json
    println!("\n{{");
    println!("  \"bench\": \"compaction\",");
    for (mf, chain, replay, merged, recover_s, build_s) in &rows {
        println!(
            "  \"mf_{mf}\": {{ \"chain_objects\": {chain}, \"replay_objects\": {replay}, \
             \"merged_spans\": {merged}, \"recover_ms\": {:.3}, \"build_ms\": {:.1} }},",
            recover_s * 1e3,
            build_s * 1e3
        );
    }
    println!("  \"bit_identical\": true");
    println!("}}");

    // acceptance: compaction must cut replay objects by ~mf
    let replay_raw = rows[0].2;
    let replay_mf8 = rows[2].2;
    assert!(
        replay_mf8 * 4 < replay_raw,
        "mf=8 must cut replay objects by >4x ({replay_raw} -> {replay_mf8})"
    );
    println!("\nacceptance: replay objects {replay_raw} -> {replay_mf8} at mf=8 (PASS)");
}
