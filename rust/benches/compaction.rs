//! Hierarchical chain-compaction bench: recovery replay cost over
//! full-free diff chains (one anchor full, `full_every = ∞`), uncompacted
//! vs background-compacted at merge factors 4 and 8, plus a 512-diff
//! full-free section measuring the `mf·⌈log_mf n⌉+1` replay bound and the
//! steady-state write bytes against a periodic-full baseline.
//!
//! The headline metric is **replay objects touched** (deterministic: the
//! level-k hierarchy leaves at most `mf−1` spans per level, so a replay
//! fetches O(log_mf n) objects on an unbounded chain) — the `R_D`-side
//! quantity the §V-C tuner's hierarchical merge-factor policy targets.
//! Wall times are machine-dependent and reported for context. Bit-identity
//! of the recovered state is asserted on every run.
//!
//! Run: `cargo bench --bench compaction`; baseline in
//! `BENCH_compaction.json`. Compaction-vs-checkpoint-write *interference*
//! (ungated vs the control plane's idle-triggered token-bucket gate) is
//! measured by the companion `control_loop` bench, baseline in
//! `BENCH_control.json`.

mod common;

use std::sync::Arc;

use lowdiff::checkpoint::format::{model_signature, PayloadCodec};
use lowdiff::checkpoint::manifest::Manifest;
use lowdiff::compress::topk_mask;
use lowdiff::control::replay_bound;
use lowdiff::coordinator::checkpointer::{Checkpointer, CkptConfig, CkptItem};
use lowdiff::coordinator::recovery::{recover, RecoveryMode, RecoveryStats};
use lowdiff::optim::{Adam, ModelState};
use lowdiff::pipeline::{compact_hierarchy, CompactStats, CompactorConfig, DEFAULT_MAX_LEVEL};
use lowdiff::storage::{MemStore, StorageBackend};
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

const N_PARAMS: usize = 64 * 1024;
const STEPS: u64 = 64;
const STEPS_LONG: u64 = 512;
const RHO: f64 = 0.01;

/// Persist a fixed timeline through the checkpointer: one anchor full,
/// `steps` diffs, a periodic full every `full_every` steps (0 = full-free),
/// hierarchical compaction at `compact_every`. Returns the store, write-path
/// bytes, merged spans written, and the deepest level.
fn build(
    compact_every: usize,
    steps: u64,
    full_every: u64,
) -> (Arc<dyn StorageBackend>, u64, u64, u16) {
    let sig = model_signature("compaction-bench", N_PARAMS);
    let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
    let ck = Checkpointer::spawn(
        Arc::clone(&store),
        CkptConfig { model_sig: sig, gc: false, compact_every, ..CkptConfig::default() },
    );
    let mut rng = Rng::new(61);
    let k = ((N_PARAMS as f64 * RHO) as usize).max(1);
    ck.queue
        .put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.1; N_PARAMS])))));
    for step in 1..=steps {
        let mut g = vec![0f32; N_PARAMS];
        rng.fill_normal_f32(&mut g);
        ck.queue
            .put(step, Arc::new(CkptItem::DiffDense(topk_mask(&Flat(g), k))));
        if full_every != 0 && step % full_every == 0 {
            let mut s = ModelState::new(Flat(vec![0.1; N_PARAMS]));
            s.step = step;
            ck.queue.put(step, Arc::new(CkptItem::Full(s)));
        }
    }
    let stats = ck.finish();
    assert_eq!(stats.errors, 0);
    (store, stats.bytes_written, stats.merged_written, stats.max_level)
}

fn recover_once(store: &Arc<dyn StorageBackend>, sig: u64) -> (ModelState, RecoveryStats) {
    recover(store.as_ref(), sig, &Adam::default(), RecoveryMode::SerialReplay).expect("recover")
}

fn main() {
    let sig = model_signature("compaction-bench", N_PARAMS);
    println!("chain: 1 anchor full + {STEPS} diffs, {N_PARAMS} params, rho {RHO}\n");

    let (baseline_store, _, _, _) = build(0, STEPS, 0);
    let (want, base_stats) = recover_once(&baseline_store, sig);
    assert_eq!(base_stats.n_diff_objects, STEPS as usize);

    let mut rows = Vec::new();
    for mf in [0usize, 4, 8] {
        let t0 = std::time::Instant::now();
        let (store, _, merged, max_level) = build(mf, STEPS, 0);
        let build_secs = t0.elapsed().as_secs_f64();

        let (state, rstats) = recover_once(&store, sig);
        assert_eq!(state, want, "mf={mf}: compacted replay must be bit-identical");
        if mf >= 2 {
            assert!(
                rstats.n_diff_objects as u64 <= replay_bound(STEPS, mf),
                "mf={mf}: replay objects {} above the hierarchical bound {}",
                rstats.n_diff_objects,
                replay_bound(STEPS, mf)
            );
            assert!(max_level >= 1, "the hierarchy must engage at mf={mf}");
        }
        let chain_objects = store
            .list()
            .unwrap()
            .iter()
            .filter(|n| Manifest::step_range(n).is_some_and(|(k, _, _)| k != "full"))
            .count();

        let b = common::bench(&format!("recover mf={mf}"), 300, || {
            let _ = recover_once(&store, sig);
        });
        b.report();
        println!(
            "  mf={mf:<3} chain objects {chain_objects:>3}  replay objects {:>3}  \
             merged spans {merged:>2}  max level {max_level}",
            rstats.n_diff_objects
        );
        rows.push((
            mf,
            chain_objects,
            rstats.n_diff_objects,
            merged,
            max_level,
            b.median(),
            build_secs,
        ));
    }

    // ---- full-free section: 512 diffs, no periodic fulls ever ----------
    // periodic-full baseline for the write-bytes comparison (full every 64)
    let (_, periodic_bytes, _, _) = build(0, STEPS_LONG, 64);
    // full-free raw chain: anchor + 512 diffs, nothing else
    let (ff_store, ff_write_bytes, _, _) = build(0, STEPS_LONG, 0);
    let (ff_want, _) = recover_once(&ff_store, sig);
    let diff_bytes: u64 = ff_store
        .list()
        .unwrap()
        .iter()
        .filter(|n| Manifest::step_range(n).is_some_and(|(k, _, _)| k != "full"))
        .map(|n| ff_store.get(n).unwrap().len() as u64)
        .sum();
    // hierarchical compaction run directly, so merge amplification is
    // observable (the checkpointer folds only counters, not bytes)
    let ccfg = CompactorConfig {
        model_sig: sig,
        merge_factor: 4,
        settle_tail: 0,
        codec: PayloadCodec::Raw,
        max_level: DEFAULT_MAX_LEVEL,
    };
    let mut cst = CompactStats::default();
    let t0 = std::time::Instant::now();
    compact_hierarchy(
        ff_store.as_ref(),
        &ccfg,
        &std::collections::HashSet::new(),
        true,
        &mut cst,
        &Manifest::latest_chain,
        &mut || true,
        None,
    )
    .expect("hierarchy");
    let compact_secs = t0.elapsed().as_secs_f64();
    let (ff_state, ff_rstats) = recover_once(&ff_store, sig);
    assert_eq!(ff_state, ff_want, "full-free replay must be bit-identical");
    let bound = replay_bound(STEPS_LONG, 4);
    assert!(
        ff_rstats.n_diff_objects as u64 <= bound,
        "full-free: replay objects {} above mf*ceil(log_mf n)+1 = {bound}",
        ff_rstats.n_diff_objects
    );
    // merge amplification: every level rewrites each payload once, plus a
    // union-sum section never larger than the payloads it summarizes
    let amp_bound = 2 * cst.max_level as u64 * diff_bytes;
    assert!(
        cst.bytes_written <= amp_bound,
        "merge amplification {} above {} (2 * {} levels * {diff_bytes} diff bytes)",
        cst.bytes_written,
        amp_bound,
        cst.max_level
    );
    let ff_total = ff_write_bytes + cst.bytes_written;
    assert!(
        ff_total < periodic_bytes,
        "full-free steady-state bytes {ff_total} must undercut the \
         periodic-full baseline {periodic_bytes}"
    );
    println!(
        "\nfull-free (n={STEPS_LONG}, mf=4): replay objects {} (bound {bound})  \
         max level {}  merged spans {}  compact {:.1}ms",
        ff_rstats.n_diff_objects,
        cst.max_level,
        cst.merged_written,
        compact_secs * 1e3
    );
    println!(
        "write bytes: full-free {ff_total} (chain {ff_write_bytes} + merge {}) \
         vs periodic-full {periodic_bytes}",
        cst.bytes_written
    );

    // machine-readable block for BENCH_compaction.json
    println!("\n{{");
    println!("  \"bench\": \"compaction\",");
    for (mf, chain, replay, merged, max_level, recover_s, build_s) in &rows {
        println!(
            "  \"mf_{mf}\": {{ \"chain_objects\": {chain}, \"replay_objects\": {replay}, \
             \"merged_spans\": {merged}, \"max_level\": {max_level}, \
             \"recover_ms\": {:.3}, \"build_ms\": {:.1} }},",
            recover_s * 1e3,
            build_s * 1e3
        );
    }
    println!(
        "  \"full_free_512\": {{ \"replay_objects\": {}, \"bound\": {bound}, \
         \"max_level\": {}, \"merged_spans\": {}, \"write_bytes\": {ff_total}, \
         \"periodic_full_bytes\": {periodic_bytes} }},",
        ff_rstats.n_diff_objects, cst.max_level, cst.merged_written
    );
    println!("  \"bit_identical\": true");
    println!("}}");

    // acceptance: the hierarchy must bound replay logarithmically
    let replay_raw = rows[0].2;
    let replay_mf8 = rows[2].2;
    assert!(
        replay_mf8 * 4 < replay_raw,
        "mf=8 must cut replay objects by >4x ({replay_raw} -> {replay_mf8})"
    );
    println!("\nacceptance: replay objects {replay_raw} -> {replay_mf8} at mf=8 (PASS)");
    println!(
        "acceptance: full-free 512-diff replay {} <= {bound} and write bytes \
         {ff_total} < {periodic_bytes} (PASS)",
        ff_rstats.n_diff_objects
    );
}
