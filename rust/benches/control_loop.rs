//! Control-plane bench: (1) closed-loop §V-C convergence — ticks for the
//! actuator to land within 20% of the Eq. (10) closed form from a
//! deliberately bad config — and (2) checkpoint-write interference with
//! background compaction I/O, ungated vs shaped through the [`IoGate`]'s
//! idle-triggered token bucket.
//!
//! The interference experiment models one bandwidth-bound device
//! ([`Throttled`]) shared by a foreground persist loop and a background
//! compaction-like read/write loop. Ungated, background bytes queue ahead
//! of foreground persists on the device's token bucket; gated, the
//! background side defers to in-flight persists and pays a byte budget,
//! so foreground persist latency drops. Run:
//! `cargo bench --bench control_loop`; baseline in `BENCH_control.json`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lowdiff::control::{converge_synthetic, GatedStore, IoGate, IoGateConfig, Retune};
use lowdiff::coordinator::config_opt::{optimal_config_integer, SystemParams};
use lowdiff::storage::{MemStore, StorageBackend, Throttled};

const DEVICE_BW: f64 = 200e6; // 200 MB/s device
const OBJ: usize = 1 << 20; // 1 MiB foreground persists
const BG_OBJ: usize = 1 << 20; // 1 MiB background compaction ops
const PERSISTS: usize = 24;

fn convergence() -> (u64, u64, f64, u64) {
    let full_size = 1.5e9;
    let p = SystemParams {
        n_gpus: 8.0,
        mtbf: 900.0,
        write_bw: 2.5e9,
        full_size,
        total_time: 24.0 * 3600.0,
        r_full: full_size / 2.5e9,
        r_diff: 0.2,
    };
    let iter_time = 1.9;
    let (want_f, _) = optimal_config_integer(&p, iter_time);
    let bad = Retune {
        full_every: want_f * 50,
        batch_size: 64,
        compact_every: 0,
        codec: lowdiff::checkpoint::format::PayloadCodec::Raw,
    };
    // find the first tick budget that lands within 20%
    let mut ticks_to_converge = 0u64;
    for ticks in (10usize..=600).step_by(10) {
        let got = converge_synthetic(p, iter_time, bad, ticks).applied();
        let err = (got.full_every as f64 - want_f as f64).abs() / want_f as f64;
        if err <= 0.2 {
            ticks_to_converge = ticks as u64;
            break;
        }
    }
    let a = converge_synthetic(p, iter_time, bad, 600);
    let got = a.applied();
    let final_err = (got.full_every as f64 - want_f as f64).abs() / want_f as f64;
    (want_f, ticks_to_converge, final_err, a.retunes)
}

/// Foreground persist latency (mean ms) while a background thread hammers
/// the same throttled device; `gate` shapes the background side when set.
fn interference(gate: Option<Arc<IoGate>>) -> (f64, f64, u64) {
    let device: Arc<dyn StorageBackend> = Arc::new(Throttled::new(
        MemStore::new(),
        DEVICE_BW,
        Duration::from_millis(1),
    ));
    let bg_store: Arc<dyn StorageBackend> = match &gate {
        Some(g) => Arc::new(GatedStore::new(Arc::clone(&device), Arc::clone(g))),
        None => Arc::clone(&device),
    };
    let stop = Arc::new(AtomicBool::new(false));
    let bg = {
        let stop = Arc::clone(&stop);
        let payload = vec![0x5Au8; BG_OBJ];
        std::thread::spawn(move || {
            let mut i = 0usize;
            let mut bytes = 0u64;
            while !stop.load(Ordering::SeqCst) {
                bg_store.put(&format!("bg-{i:06}"), &payload).unwrap();
                bytes += BG_OBJ as u64;
                i += 1;
            }
            bytes
        })
    };
    let payload = vec![0xA5u8; OBJ];
    let mut lat = Vec::with_capacity(PERSISTS);
    for i in 0..PERSISTS {
        let t0 = Instant::now();
        let _guard = gate.as_ref().map(|g| g.persist_guard());
        device.put(&format!("ckpt-{i:06}"), &payload).unwrap();
        drop(_guard);
        lat.push(t0.elapsed().as_secs_f64());
        std::thread::sleep(Duration::from_millis(5));
    }
    stop.store(true, Ordering::SeqCst);
    let bg_bytes = bg.join().unwrap();
    let mean = lat.iter().sum::<f64>() / lat.len() as f64 * 1e3;
    let mut sorted = lat.clone();
    sorted.sort_by(f64::total_cmp);
    let p90 = sorted[(sorted.len() * 9) / 10 - 1] * 1e3;
    (mean, p90, bg_bytes)
}

fn main() {
    println!("== §V-C closed-loop convergence ==");
    let (want_f, ticks, final_err, retunes) = convergence();
    println!(
        "closed-form FCF* = {want_f}; within 20% after {ticks} ticks; \
         final err {:.1}% after 600 ticks ({retunes} retunes)",
        final_err * 100.0
    );
    assert!(ticks > 0, "never converged within 600 ticks");
    assert!(final_err <= 0.2, "final error {final_err} above the 20% acceptance");

    println!("\n== checkpoint-write interference (200 MB/s device) ==");
    let (u_mean, u_p90, u_bytes) = interference(None);
    println!(
        "ungated : persist mean {u_mean:>7.1} ms  p90 {u_p90:>7.1} ms  bg {:.0} MB",
        u_bytes as f64 / 1e6
    );
    let gate = Arc::new(IoGate::new(IoGateConfig {
        bytes_per_sec: 50e6, // background budget: 25% of the device
        max_defer: Duration::from_millis(50),
        ..IoGateConfig::default()
    }));
    let (g_mean, g_p90, g_bytes) = interference(Some(Arc::clone(&gate)));
    let gs = gate.stats();
    println!(
        "gated   : persist mean {g_mean:>7.1} ms  p90 {g_p90:>7.1} ms  bg {:.0} MB \
         (deferred {} ops / {:.1} ms, contended {:.1} MB)",
        g_bytes as f64 / 1e6,
        gs.deferred_ops,
        gs.deferred_secs * 1e3,
        gs.contended_bytes as f64 / 1e6,
    );

    // machine-readable block for BENCH_control.json
    println!("\n{{");
    println!("  \"bench\": \"control_loop\",");
    println!(
        "  \"convergence\": {{ \"closed_form_fcf\": {want_f}, \"ticks_to_20pct\": {ticks}, \
         \"final_err_pct\": {:.2}, \"retunes\": {retunes} }},",
        final_err * 100.0
    );
    println!(
        "  \"interference\": {{ \"ungated_persist_ms\": {u_mean:.1}, \
         \"gated_persist_ms\": {g_mean:.1}, \"ungated_p90_ms\": {u_p90:.1}, \
         \"gated_p90_ms\": {g_p90:.1}, \"deferred_ops\": {}, \"contended_mb\": {:.1} }}",
        gs.deferred_ops,
        gs.contended_bytes as f64 / 1e6
    );
    println!("}}");

    // acceptance: the gate must cut foreground persist latency — the
    // background side is rate-capped AND yields to in-flight persists
    assert!(
        g_mean < u_mean,
        "gated persists must be faster: {g_mean:.1} ms vs {u_mean:.1} ms ungated"
    );
    println!(
        "\nacceptance: persist mean {u_mean:.1} -> {g_mean:.1} ms under the gate (PASS)"
    );
}
