//! Hot-path microbenchmarks (the §Perf L3 targets in EXPERIMENTS.md):
//! reusing-queue throughput, compression codecs, checkpoint container
//! encode, ring allreduce, Adam, sparse merge / recovery combine.
//!
//! Run: `cargo bench --bench hotpath`

mod common;

use std::sync::Arc;

use common::bench;
use lowdiff::checkpoint::format::{CkptKind, Container, PayloadCodec};
use lowdiff::collective::ring_allreduce_sum;
use lowdiff::compress::{encode, quant8, sparsify_ef, topk_mask, Codec};
use lowdiff::coordinator::recovery::pairwise_merge;
use lowdiff::coordinator::reusing_queue::ReusingQueue;
use lowdiff::optim::{Adam, ModelState};
use lowdiff::sparse::SparseGrad;
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

const N: usize = 1 << 20; // 1M elements = one GPT2-S-scale layer

fn randn(n: usize, seed: u64) -> Flat {
    let mut rng = Rng::new(seed);
    let mut v = vec![0f32; n];
    rng.fill_normal_f32(&mut v);
    Flat(v)
}

fn main() {
    println!("== hotpath microbenchmarks (N = {N} f32) ==\n");
    let g = randn(N, 1);
    let bytes = N * 4;

    // --- compression --------------------------------------------------
    let k = N / 100; // rho = 0.01
    bench("topk_mask (rho=0.01)", 300, || {
        std::hint::black_box(topk_mask(&g, k));
    })
    .report_bytes(bytes);

    let mut residual = Flat::zeros(N);
    bench("sparsify_ef (rho=0.01)", 300, || {
        std::hint::black_box(sparsify_ef(&g, &mut residual, k));
    })
    .report_bytes(bytes);

    bench("quant8", 300, || {
        std::hint::black_box(quant8(&g));
    })
    .report_bytes(bytes);

    // --- sparse codec ---------------------------------------------------
    let masked = topk_mask(&g, k);
    bench("SparseGrad::from_dense (compaction)", 300, || {
        std::hint::black_box(SparseGrad::from_dense(&masked));
    })
    .report_bytes(bytes);

    let sparse = SparseGrad::from_dense(&masked);
    bench("sparse encode (TopK codec)", 300, || {
        std::hint::black_box(encode(Codec::TopK, &masked));
    })
    .report_bytes(sparse.encoded_size());

    let sparse2 = {
        let m2 = topk_mask(&randn(N, 2), k);
        SparseGrad::from_dense(&m2)
    };
    bench("sparse merge_sum (batching combine)", 300, || {
        std::hint::black_box(sparse.merge_sum(&sparse2));
    })
    .report();

    let grads: Vec<SparseGrad> = (0..16)
        .map(|i| SparseGrad::from_dense(&topk_mask(&randn(N, 10 + i), k)))
        .collect();
    bench("pairwise_merge x16 (parallel recovery)", 400, || {
        std::hint::black_box(pairwise_merge(grads.clone()));
    })
    .report();

    // --- container ------------------------------------------------------
    let payload = masked.to_le_bytes();
    bench("container encode (raw)", 300, || {
        let mut c = Container::new(CkptKind::Diff, 1, 1, 1);
        c.push("grad", payload.clone());
        std::hint::black_box(c.to_bytes().unwrap());
    })
    .report_bytes(payload.len());

    bench("container encode (zstd)", 500, || {
        let mut c = Container::new(CkptKind::Diff, 1, 1, 1).with_codec(PayloadCodec::Zstd);
        c.push("grad", payload.clone());
        std::hint::black_box(c.to_bytes().unwrap());
    })
    .report_bytes(payload.len());

    // --- optimizer -------------------------------------------------------
    let mut state = ModelState::new(randn(N, 3));
    let adam = Adam::default();
    bench("rust Adam apply (dense)", 300, || {
        adam.apply(&mut state, &g);
    })
    .report_bytes(bytes * 4); // p, m, v, g streams

    bench("rust Adam apply_sparse (rho=0.01)", 300, || {
        adam.apply_sparse(&mut state, &sparse);
    })
    .report_bytes(bytes * 3);

    // --- collective -------------------------------------------------------
    let workers: Vec<Flat> = (0..4).map(|i| randn(N / 4, 20 + i)).collect();
    bench("ring_allreduce_sum (4 workers, 256K each)", 300, || {
        let mut w = workers.clone();
        ring_allreduce_sum(&mut w);
        std::hint::black_box(w);
    })
    .report_bytes(bytes);

    // --- reusing queue ----------------------------------------------------
    let q: Arc<ReusingQueue<Flat>> = ReusingQueue::new(64);
    let payload = Arc::new(randn(N, 5));
    let mut step = 0u64;
    bench("reusing queue put+get (zero-copy handle)", 200, || {
        step += 1;
        q.put(step, Arc::clone(&payload));
        std::hint::black_box(q.get().unwrap());
    })
    .report();

    println!("\nhotpath bench done");
}
