//! Observed-middleware overhead bench: the storage observability layer
//! (per-op atomic counters + one `LogHistogram` record + the slow-op
//! threshold check) must be invisible next to real I/O. The same put+get
//! workload runs through a raw `MemStore` and through
//! `Observed::new(mem, obs, "durable")`, and the observed path must stay
//! within 5% of the unwrapped store.
//!
//! Run: `cargo bench --bench observed_overhead`; baseline in
//! `BENCH_observed.json`. MemStore is the worst case for the middleware:
//! a memcpy-only backend leaves nowhere for the bookkeeping to hide, so
//! passing here bounds the overhead on any real tier from above.

mod common;

use std::sync::Arc;

use common::bench;
use lowdiff::storage::{MemStore, Observed, StorageBackend, StorageObs};

const OBJ_BYTES: usize = 256 << 10; // a typical batched diff span
const N_OBJECTS: usize = 32;

fn cycle(store: &Arc<dyn StorageBackend>, payload: &[u8]) {
    for i in 0..N_OBJECTS {
        store.put(&format!("diff-{i:08}-{i:08}.ckpt"), payload).unwrap();
    }
    for i in 0..N_OBJECTS {
        let got = store.get(&format!("diff-{i:08}-{i:08}.ckpt")).unwrap();
        assert_eq!(got.len(), payload.len());
    }
}

fn main() {
    let payload = vec![0x5Au8; OBJ_BYTES];
    let bytes_per_op = 2 * OBJ_BYTES * N_OBJECTS; // one put + one get per object

    let raw: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
    // slow threshold far above any MemStore op: the hot path pays the
    // comparison on every op, never the trace emission
    let obs = Arc::new(StorageObs::new(1_000));
    let inner: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
    let observed: Arc<dyn StorageBackend> =
        Arc::new(Observed::new(inner, Arc::clone(&obs), "durable"));

    println!("== observed middleware overhead ({N_OBJECTS} x {OBJ_BYTES} B put+get) ==");
    let b_raw = bench("memstore put+get (raw)", 600, || cycle(&raw, &payload));
    b_raw.report_bytes(bytes_per_op);
    let b_obs = bench("memstore put+get (observed)", 600, || cycle(&observed, &payload));
    b_obs.report_bytes(bytes_per_op);

    let raw_s = b_raw.median();
    let obs_s = b_obs.median();
    let overhead = obs_s / raw_s - 1.0;
    println!("overhead: {:.2}%", overhead * 100.0);

    // the middleware really recorded every op it was supposed to
    let tiers = obs.tiers();
    assert_eq!(tiers.len(), 1, "one tier label in play");
    let ops = tiers[0].total_ops();
    assert!(ops >= 2 * N_OBJECTS as u64, "puts and gets must be recorded: {ops}");
    assert_eq!(obs.slow_ops(), 0, "nothing crosses a 1000ms threshold in memory");

    // machine-readable block for BENCH_observed.json
    println!("\n{{");
    println!("  \"bench\": \"observed_overhead\",");
    println!("  \"obj_bytes\": {OBJ_BYTES}, \"objects\": {N_OBJECTS},");
    println!("  \"raw_secs_per_cycle\": {raw_s:.6},");
    println!("  \"observed_secs_per_cycle\": {obs_s:.6},");
    println!("  \"overhead_fraction\": {overhead:.4}");
    println!("}}");

    assert!(
        overhead < 0.05,
        "observed path must stay within 5% of raw: {:.2}%",
        overhead * 100.0
    );
    println!("\nacceptance: observed overhead {:.2}% < 5% (PASS)", overhead * 100.0);
}
