//! Regenerates every table and figure of the paper's evaluation (§VIII).
//!
//! - Simulated experiments (Fig. 1/4, Table I, Exp. 1-4, 7-10) replay the
//!   strategy logic on the calibrated A100/V100 cluster model (sim/).
//! - Real-path experiments (Exp. 5 recovery scaling, Exp. 6 batched-write
//!   timing + buffer accounting) run the actual checkpoint/recovery code
//!   on this machine.
//!
//! Run: `cargo bench --bench paper_tables`

mod common;

use std::sync::Arc;
use std::time::Instant;

use lowdiff::checkpoint::batched::{finalize, BatchBuffer, BatchMode};
use lowdiff::checkpoint::diff::{write_diff, DiffPayload};
use lowdiff::checkpoint::format::{model_signature, PayloadCodec};
use lowdiff::checkpoint::full::write_full;
use lowdiff::checkpoint::manifest::Manifest;
use lowdiff::compress::topk_mask;
use lowdiff::coordinator::recovery::{recover, RecoveryMode};
use lowdiff::exp::{self, Table};
use lowdiff::optim::{Adam, ModelState};
use lowdiff::sparse::SparseGrad;
use lowdiff::storage::{MemStore, StorageBackend};
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

fn main() {
    println!("################ simulated experiments (paper-scale testbed) ################\n");
    for t in exp::all_simulated() {
        println!("{}", t.render());
    }
    println!("################ real-path experiments (this machine) ################\n");
    println!("{}", exp5_real().render());
    println!("{}", exp6_real().render());
}

/// Exp. 5 (Fig. 15), real path: recovery time vs full-checkpoint interval
/// using the actual container decode + Adam replay / parallel merge.
fn exp5_real() -> Table {
    let n = 1_000_000usize; // 1M-param synthetic state
    let sig = model_signature("bench", n);
    let adam = Adam::default();
    let mut rng = Rng::new(42);
    let k = n / 100;

    let mut t = Table::new(
        "Exp. 5 (Fig. 15, real path) — recovery time vs #diffs (1M params)",
        &["diffs since full", "serial replay (ms)", "parallel merge (ms)", "rounds"],
    );
    for n_diffs in [5usize, 10, 20, 50] {
        // build a chain: full at 0 + n_diffs gradient diffs
        let store = MemStore::new();
        let mut p = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        let state = ModelState::new(Flat(p));
        store
            .put(&Manifest::full_name(0), &write_full(&state, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        for step in 1..=n_diffs as u64 {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            let sparse = SparseGrad::from_dense(&topk_mask(&Flat(g), k));
            store
                .put(
                    &Manifest::diff_name(step),
                    &write_diff(&DiffPayload::Gradient(sparse), sig, step, PayloadCodec::Raw)
                        .unwrap(),
                )
                .unwrap();
        }
        let t0 = Instant::now();
        let (_, s_stats) = recover(&store, sig, &adam, RecoveryMode::SerialReplay).unwrap();
        let serial = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let (_, p_stats) = recover(&store, sig, &adam, RecoveryMode::ParallelMerge).unwrap();
        let parallel = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(s_stats.n_diff_steps, n_diffs);
        t.row(vec![
            n_diffs.to_string(),
            format!("{serial:.1}"),
            format!("{parallel:.1}"),
            p_stats.full_merge_rounds.to_string(),
        ]);
    }
    t
}

/// Exp. 6 (Fig. 16a), real path: average per-diff checkpoint write time vs
/// batching size through the real BatchBuffer + storage, plus the CPU
/// buffer bytes the offloaded batching holds (Fig. 16b's GPU-side saving).
fn exp6_real() -> Table {
    let n = 2_000_000usize;
    let k = n / 100;
    let sig = model_signature("bench6", n);
    let mut rng = Rng::new(7);
    let n_diffs = 40u64;

    // pre-generate sparse gradients
    let grads: Vec<SparseGrad> = (0..n_diffs)
        .map(|_| {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            SparseGrad::from_dense(&topk_mask(&Flat(g), k))
        })
        .collect();

    let mut t = Table::new(
        "Exp. 6 (Fig. 16, real path) — batched writes: time/diff + buffer bytes",
        &["batch size", "writes", "avg ms/diff", "peak CPU buffer", "reduction %"],
    );
    // throttled store models a slow disk so the per-write cost is visible
    let mut base_ms = 0.0f64;
    for bs in [1usize, 2, 5, 10, 20] {
        let store: Arc<dyn StorageBackend> = Arc::new(lowdiff::storage::Throttled::new(
            MemStore::new(),
            2.0e9,
            std::time::Duration::from_millis(3),
        ));
        let mut buf = BatchBuffer::new(BatchMode::Concat, bs);
        let mut peak = 0usize;
        let mut writes = 0u64;
        let t0 = Instant::now();
        for (i, g) in grads.iter().enumerate() {
            let maybe = buf.push(i as u64 + 1, g.clone());
            peak = peak.max(buf.buffered_bytes());
            if let Some(c) = maybe {
                let (lo, hi) = (c.step_lo, c.step_hi);
                let bytes = finalize(c, sig, PayloadCodec::Raw).unwrap();
                store.put(&Manifest::batch_name(lo, hi), &bytes).unwrap();
                writes += 1;
            }
        }
        if let Some(c) = buf.flush() {
            let (lo, hi) = (c.step_lo, c.step_hi);
            let bytes = finalize(c, sig, PayloadCodec::Raw).unwrap();
            store.put(&Manifest::batch_name(lo, hi), &bytes).unwrap();
            writes += 1;
        }
        let avg_ms = t0.elapsed().as_secs_f64() * 1e3 / n_diffs as f64;
        if bs == 1 {
            base_ms = avg_ms;
        }
        t.row(vec![
            bs.to_string(),
            writes.to_string(),
            format!("{avg_ms:.2}"),
            lowdiff::util::human_bytes(peak as u64),
            format!("{:.1}", (base_ms - avg_ms) / base_ms * 100.0),
        ]);
    }
    t
}
