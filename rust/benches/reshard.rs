//! Elastic-reshard bench: bytes moved and wall time for 8→12 and 8→4.
//!
//! Seeds an 8-rank run (anchor full + diff epochs over consistent-hash
//! partitions), then fires one elastic event per scenario and classifies
//! every byte the reshard writes by name family: carry bases (the moved
//! state — the cost that scales with |ΔR|), re-cut merged spans (diff
//! history carried across the event), and the global record (the commit
//! point). The headline number is the carry traffic as a fraction of
//! total optimizer state (params + m + v), asserted against the
//! consistent-hash bound |ΔR|/max(R, R′) + ε — versus 1.00 for the full
//! re-anchor burst this replaced.
//!
//! Run: `cargo bench --bench reshard`; baseline in `BENCH_reshard.json`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use lowdiff::checkpoint::format::model_signature;
use lowdiff::cluster::{
    elastic_restart, partition_hash, recover_cluster, Cluster, ClusterConfig,
};
use lowdiff::compress::topk_mask;
use lowdiff::optim::{Adam, ModelState};
use lowdiff::sparse::SparseGrad;
use lowdiff::storage::{MemStore, StorageBackend};
use lowdiff::tensor::Flat;
use lowdiff::util::rng::Rng;

const N_PARAMS: usize = 256 * 1024;
const STEPS: u64 = 8;
const RHO: f64 = 0.01;
const OLD_RANKS: usize = 8;

/// Bytes written so far, keyed by checkpoint name family.
#[derive(Default)]
struct PutBytes {
    carry: AtomicU64,
    span: AtomicU64,
    record: AtomicU64,
    full: AtomicU64,
    diff: AtomicU64,
}

impl PutBytes {
    fn snapshot(&self) -> [u64; 5] {
        [
            self.carry.load(Ordering::Relaxed),
            self.span.load(Ordering::Relaxed),
            self.record.load(Ordering::Relaxed),
            self.full.load(Ordering::Relaxed),
            self.diff.load(Ordering::Relaxed),
        ]
    }
}

/// MemStore wrapper that meters every put by name family.
struct Classified {
    inner: MemStore,
    counts: Arc<PutBytes>,
}

impl StorageBackend for Classified {
    fn put(&self, name: &str, bytes: &[u8]) -> anyhow::Result<()> {
        let slot = if name.contains("/carry-") {
            &self.counts.carry
        } else if name.contains("/merged-") {
            &self.counts.span
        } else if name.starts_with("global-") {
            &self.counts.record
        } else if name.contains("/full-") {
            &self.counts.full
        } else {
            &self.counts.diff
        };
        slot.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.inner.put(name, bytes)
    }
    fn get(&self, name: &str) -> anyhow::Result<Vec<u8>> {
        self.inner.get(name)
    }
    fn delete(&self, name: &str) -> anyhow::Result<()> {
        self.inner.delete(name)
    }
    fn list(&self) -> anyhow::Result<Vec<String>> {
        self.inner.list()
    }
}

/// Seed the 8-rank timeline and return the oracle state at the cut.
fn seed(store: &Arc<dyn StorageBackend>, cfg: &ClusterConfig) -> ModelState {
    let cluster =
        Cluster::spawn(Arc::clone(store), partition_hash(N_PARAMS, OLD_RANKS), cfg.clone());
    let adam = Adam::default();
    let mut rng = Rng::new(29);
    let mut state = ModelState::new(Flat(vec![0.1; N_PARAMS]));
    let k = ((N_PARAMS as f64 * RHO) as usize).max(1);
    cluster.put_full(0, &state);
    for step in 1..=STEPS {
        let mut g = vec![0f32; N_PARAMS];
        rng.fill_normal_f32(&mut g);
        let g = topk_mask(&Flat(g), k);
        cluster.put_diff_dense(step, &g);
        adam.apply_sparse(&mut state, &SparseGrad::from_dense(&g));
    }
    let stats = cluster.finish();
    assert_eq!(stats.global_commits, STEPS + 1, "every seed epoch must commit");
    assert_eq!(stats.torn_commits, 0);
    state
}

struct EventRow {
    label: &'static str,
    new_ranks: usize,
    wall: f64,
    carry: u64,
    span: u64,
    record: u64,
    state_frac: f64,
    bound: f64,
}

fn event(label: &'static str, new_ranks: usize) -> EventRow {
    let counts = Arc::new(PutBytes::default());
    let store: Arc<dyn StorageBackend> =
        Arc::new(Classified { inner: MemStore::new(), counts: Arc::clone(&counts) });
    let sig = model_signature("reshard-bench", N_PARAMS);
    // Raw codec so carry bytes track moved state one-for-one (12 B per
    // moved parameter: value + Adam m + v)
    let cfg = ClusterConfig { model_sig: sig, gc: false, ..ClusterConfig::default() };
    let oracle = seed(&store, &cfg);

    let pre = counts.snapshot();
    let t0 = Instant::now();
    let (c2, st, cut) =
        elastic_restart(&store, &Adam::default(), partition_hash(N_PARAMS, new_ranks), cfg)
            .expect("elastic restart");
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!((cut.cut_gen, cut.cut_step), (0, STEPS), "cut must land on the seed tip");
    assert_eq!(st, oracle, "resharded state must be bit-identical to the cut");
    c2.finish();
    let post = counts.snapshot();
    let [carry, span, record, full, diff] =
        [post[0] - pre[0], post[1] - pre[1], post[2] - pre[2], post[3] - pre[3], post[4] - pre[4]];
    assert_eq!(full, 0, "{label}: incremental reshard must not write a full re-anchor burst");
    assert_eq!(diff, 0, "{label}: reshard writes only carries, spans, and the record");

    // the recovered cluster must read back bit-identically on gen 1
    let (got, rcut) = recover_cluster(&store, sig, &Adam::default()).unwrap();
    assert_eq!((rcut.cut_gen, rcut.cut_step), (1, STEPS));
    assert_eq!(got, oracle, "{label}: post-reshard recovery diverged");

    // params + m + v, 4 bytes each — what a full re-anchor would move
    let state_bytes = (3 * N_PARAMS * 4) as f64;
    let state_frac = carry as f64 / state_bytes;
    let bound =
        (OLD_RANKS as f64 - new_ranks as f64).abs() / (OLD_RANKS as f64).max(new_ranks as f64);
    assert!(
        state_frac <= bound + 0.10,
        "{label}: carried {state_frac:.3} of state, consistent-hash bound is {bound:.3}+0.10"
    );
    EventRow { label, new_ranks, wall, carry, span, record, state_frac, bound }
}

fn main() {
    println!(
        "== reshard: {N_PARAMS} params, rho {RHO}, {STEPS} diff epochs on {OLD_RANKS} ranks, \
         then one elastic event ==\n"
    );
    let mut json_rows = Vec::new();
    for (label, new_ranks) in [("grow 8->12", 12usize), ("shrink 8->4", 4)] {
        let r = event(label, new_ranks);
        println!(
            "{:<12} wall {:>7.1} ms  carry {:>9} B ({:.3} of state, bound {:.3}, full \
             re-anchor 1.000)  spans {:>8} B  record {:>5} B",
            r.label,
            r.wall * 1e3,
            r.carry,
            r.state_frac,
            r.bound,
            r.span,
            r.record,
        );
        json_rows.push(format!(
            "    {{\"event\": \"{}\", \"old_ranks\": {OLD_RANKS}, \"new_ranks\": {}, \
             \"wall_ms\": {:.2}, \"carry_bytes\": {}, \"span_bytes\": {}, \"record_bytes\": {}, \
             \"state_frac\": {:.4}, \"bound\": {:.4}}}",
            r.label, r.new_ranks, r.wall * 1e3, r.carry, r.span, r.record, r.state_frac, r.bound
        ));
    }
    println!(
        "\nJSON (paste into BENCH_reshard.json \"measurements\"):\n[\n{}\n]",
        json_rows.join(",\n")
    );
    println!("\nreshard bench done");
}
