//! Storage-engine bench: single-object synchronous writes vs the sharded
//! async writer pool, across shard counts × pool sizes, under throttled
//! per-lane bandwidth (the paper's SSD model) and raw MemStore (pure
//! engine overhead).
//!
//! Run: `cargo bench --bench storage_shard`

use std::sync::Arc;
use std::time::{Duration, Instant};

use lowdiff::storage::{MemStore, Sharded, StorageBackend, Throttled};

const OBJ_BYTES: usize = 4 << 20; // one batched gradient write
const N_OBJECTS: usize = 8;

fn run_sync(dev: Arc<dyn StorageBackend>, payload: &[u8]) -> f64 {
    let t0 = Instant::now();
    for i in 0..N_OBJECTS {
        dev.put(&format!("batch-{i:03}"), payload).unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn run_sharded(lanes: Vec<Arc<dyn StorageBackend>>, shards: usize, writers: usize, payload: &[u8]) -> f64 {
    let eng = Sharded::with_lanes(lanes, shards, writers);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..N_OBJECTS)
        .map(|i| eng.put_async(&format!("batch-{i:03}"), payload.to_vec()))
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn report(label: &str, secs: f64, base: f64) {
    let mb = (OBJ_BYTES * N_OBJECTS) as f64 / 1e6;
    println!(
        "{label:<38} {:>8.1} ms   {:>8.0} MB/s   {:>5.2}x",
        secs * 1e3,
        mb / secs,
        base / secs
    );
}

fn main() {
    let payload = vec![0x5Au8; OBJ_BYTES];
    println!(
        "== storage_shard: {N_OBJECTS} x {} MiB batched writes ==\n",
        OBJ_BYTES >> 20
    );

    // throttled-device scan: same driver as `lowdiff exp sharded` — one
    // implementation, two entry points
    println!("{}", lowdiff::exp::exp_sharded().render());

    println!("-- extra shard/pool points on throttled lanes --");
    let mk_dev = || -> Arc<dyn StorageBackend> {
        Arc::new(Throttled::new(MemStore::new(), 256e6, Duration::from_millis(2)))
    };
    let base = run_sync(mk_dev(), &payload);
    report("single object, synchronous", base, base);
    for (shards, writers) in [(1usize, 2usize), (2, 4), (4, 2), (16, 8)] {
        let lanes: Vec<Arc<dyn StorageBackend>> = (0..shards).map(|_| mk_dev()).collect();
        let secs = run_sharded(lanes, shards, writers, &payload);
        report(&format!("sharded x{shards}, {writers} writers"), secs, base);
    }

    println!("\n-- raw MemStore (engine overhead only) --");
    let mem_base = run_sync(Arc::new(MemStore::new()), &payload);
    report("single object, synchronous", mem_base, mem_base);
    for (shards, writers) in [(4usize, 4usize), (8, 8)] {
        let lanes: Vec<Arc<dyn StorageBackend>> =
            (0..shards).map(|_| Arc::new(MemStore::new()) as Arc<dyn StorageBackend>).collect();
        let secs = run_sharded(lanes, shards, writers, &payload);
        report(&format!("sharded x{shards}, {writers} writers"), secs, mem_base);
    }

    println!("\nstorage_shard bench done");
}
