//! Write-path copy accounting and throughput: the pre-change multi-copy
//! pipeline (sparse→section vec → payload concat → container splice →
//! sync-put `to_vec`) vs the pooled single-pass pipeline
//! (`write_diff_into` / `BatchBuffer::flush_into` + `Sharded::put_async`
//! over a shared `PutBuf`).
//!
//! The legacy pipeline is reimplemented here verbatim (the library's old
//! encoders live on only as `#[cfg(test)]` oracles) so both its wall time
//! and its bytes-copied count are *measured*, not estimated.
//!
//! Copy accounting: serialization copies (heap buffer -> heap buffer on
//! the way to storage). Sum-mode accumulation traffic is reported too but
//! excluded from the acceptance ratio — both pipelines move those bytes;
//! the new one just does it without allocating.
//!
//! Run: `cargo bench --bench write_path`
//! Acceptance (ISSUE 2): pooled path copies each differential checkpoint
//! <= 1/2 the legacy bytes; results recorded in BENCH_write_path.json.

mod common;

use std::sync::Arc;

use common::bench;
use lowdiff::checkpoint::batched::{BatchBuffer, BatchMode};
use lowdiff::checkpoint::diff::{write_diff_into, DiffPayload};
use lowdiff::checkpoint::format::PayloadCodec;
use lowdiff::compress::topk_mask;
use lowdiff::sparse::SparseGrad;
use lowdiff::storage::{MemStore, Sharded, StorageBackend};
use lowdiff::tensor::Flat;
use lowdiff::util::bufpool::BufPool;
use lowdiff::util::rng::Rng;

const N_PARAMS: usize = 1 << 16;
const RHO: f64 = 0.01;
const BATCH: usize = 4;
const N_SHARDS: usize = 4;
const WRITERS: usize = 2;

fn gradient(rng: &mut Rng) -> SparseGrad {
    let mut g = vec![0f32; N_PARAMS];
    rng.fill_normal_f32(&mut g);
    let k = ((N_PARAMS as f64 * RHO) as usize).max(1);
    SparseGrad::from_dense(&topk_mask(&Flat(g), k))
}

// ---- the pre-change pipeline, reimplemented for measurement -------------

/// Old `SparseGrad::to_bytes` → container section vec (copy 1).
fn legacy_sparse_bytes(g: &SparseGrad, copied: &mut u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(g.encoded_size());
    out.extend_from_slice(&g.dense_len.to_le_bytes());
    out.extend_from_slice(&(g.nnz() as u32).to_le_bytes());
    for i in &g.indices {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for v in &g.values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    *copied += out.len() as u64;
    out
}

/// Old `Container::to_bytes` (Raw codec): payload concat (copy 2) + splice
/// into the container buffer (copy 3).
fn legacy_container_bytes(
    kind: u8,
    model_sig: u64,
    step_lo: u64,
    step_hi: u64,
    sections: &[(String, Vec<u8>)],
    copied: &mut u64,
) -> Vec<u8> {
    let raw_payload: Vec<u8> = {
        let mut p = Vec::with_capacity(sections.iter().map(|(_, b)| b.len()).sum());
        for (_, b) in sections {
            p.extend_from_slice(b);
        }
        p
    };
    *copied += raw_payload.len() as u64;
    let crc = crc32fast::hash(&raw_payload);
    let mut out = Vec::with_capacity(raw_payload.len() + 64);
    out.extend_from_slice(b"LDCK");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.push(kind);
    out.push(0); // raw codec
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&model_sig.to_le_bytes());
    out.extend_from_slice(&step_lo.to_le_bytes());
    out.extend_from_slice(&step_hi.to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for (name, bytes) in sections {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
    }
    out.extend_from_slice(&raw_payload);
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(b"KCDL");
    *copied += out.len() as u64;
    out
}

/// One legacy Concat-batch checkpoint, ending in the old sync sharded
/// put's `bytes.to_vec()` (copy 4).
fn legacy_concat_batch(grads: &[SparseGrad], eng: &Sharded, step: u64, copied: &mut u64) {
    let sections: Vec<(String, Vec<u8>)> = grads
        .iter()
        .enumerate()
        .map(|(i, g)| (format!("step-{}", step + i as u64), legacy_sparse_bytes(g, copied)))
        .collect();
    let hi = step + grads.len() as u64 - 1;
    let bytes = legacy_container_bytes(2, 1, step, hi, &sections, copied);
    *copied += bytes.len() as u64; // old sync put: bytes.to_vec()
    eng.put_async("batch-bench", bytes).wait().unwrap();
}

/// One legacy Sum-batch checkpoint: reallocating merge chain + the same
/// serialization copies. Returns (serialization, accumulation) bytes.
/// Accumulation counts merge outputs only — the old code *moved* the
/// first gradient in, where the pooled path copies it into the persistent
/// accumulator (so pooled accumulation reads ~8*nnz higher by design).
fn legacy_sum_batch(grads: &[SparseGrad], eng: &Sharded, step: u64) -> (u64, u64) {
    let (mut ser, mut acc_traffic) = (0u64, 0u64);
    let mut acc = grads[0].clone(); // stand-in for the old move-in (uncounted)
    for g in &grads[1..] {
        acc = acc.merge_sum(g); // fresh union allocation per merge
        acc_traffic += 8 * acc.nnz() as u64;
    }
    let sec = legacy_sparse_bytes(&acc, &mut ser);
    let bytes = legacy_container_bytes(
        2,
        1,
        step,
        step + grads.len() as u64 - 1,
        &[("sum".into(), sec)],
        &mut ser,
    );
    ser += bytes.len() as u64; // old sync put: bytes.to_vec()
    eng.put_async("batch-bench", bytes).wait().unwrap();
    (ser, acc_traffic)
}

// ---- the pooled single-pass pipeline ------------------------------------

/// One pooled batch checkpoint (either mode). Returns (serialization,
/// accumulation) bytes as counted by the production counters.
fn pooled_batch(
    grads: &[SparseGrad],
    pool: &BufPool,
    batch: &mut BatchBuffer,
    eng: &Sharded,
    step: u64,
) -> (u64, u64) {
    for (i, g) in grads.iter().enumerate() {
        batch.offer(step + i as u64, g.clone());
    }
    let mut buf = pool.checkout();
    let (_, _, appended) =
        batch.flush_into(1, PayloadCodec::Raw, &mut buf).unwrap().expect("batch");
    eng.put_async("batch-bench", buf).wait().unwrap();
    (appended as u64, batch.take_copied())
}

fn mk_eng() -> Sharded {
    Sharded::new(Arc::new(MemStore::new()) as Arc<dyn StorageBackend>, N_SHARDS, WRITERS)
}

fn main() {
    let mut rng = Rng::new(42);
    let grads: Vec<SparseGrad> = (0..BATCH).map(|_| gradient(&mut rng)).collect();
    let nnz: usize = grads.iter().map(|g| g.nnz()).sum();
    println!(
        "== write_path: {BATCH}-step batches, {N_PARAMS} params, rho={RHO} ({nnz} nnz total), \
         {N_SHARDS} shards x {WRITERS} writers ==\n"
    );

    // ---- bytes copied per checkpoint ------------------------------------
    // single unbatched diff (batch_size = 1 path)
    let mut diff_legacy = 0u64;
    let sec = legacy_sparse_bytes(&grads[0], &mut diff_legacy);
    let bytes = legacy_container_bytes(1, 1, 1, 1, &[("grad".into(), sec)], &mut diff_legacy);
    diff_legacy += bytes.len() as u64; // old sync put to_vec
    let pool = BufPool::new(8);
    let mut out = pool.checkout();
    let diff_pooled =
        write_diff_into(&DiffPayload::Gradient(grads[0].clone()), 1, 1, PayloadCodec::Raw, &mut out)
            .unwrap() as u64;
    drop(out);
    let diff_ratio = diff_legacy as f64 / diff_pooled as f64;

    // Concat batch
    let mut concat_legacy = 0u64;
    let eng = mk_eng();
    legacy_concat_batch(&grads, &eng, 1, &mut concat_legacy);
    let eng = mk_eng();
    let mut concat_buf = BatchBuffer::new(BatchMode::Concat, BATCH);
    let (concat_pooled, _) = pooled_batch(&grads, &pool, &mut concat_buf, &eng, 1);
    let concat_ratio = concat_legacy as f64 / concat_pooled as f64;

    // Sum batch (accumulation traffic reported separately — it is
    // inherent to the scheme and identical in both pipelines)
    let eng = mk_eng();
    let (sum_legacy, sum_legacy_acc) = legacy_sum_batch(&grads, &eng, 1);
    let eng = mk_eng();
    let mut sum_buf = BatchBuffer::new(BatchMode::Sum, BATCH);
    let (sum_pooled, sum_pooled_acc) = pooled_batch(&grads, &pool, &mut sum_buf, &eng, 1);
    let sum_ratio = sum_legacy as f64 / sum_pooled as f64;

    println!("bytes copied per differential checkpoint (serialization copies):");
    println!("  single diff : legacy {diff_legacy:>8} B   pooled {diff_pooled:>8} B   {diff_ratio:>5.2}x");
    println!("  concat x{BATCH}   : legacy {concat_legacy:>8} B   pooled {concat_pooled:>8} B   {concat_ratio:>5.2}x");
    println!("  sum x{BATCH}      : legacy {sum_legacy:>8} B   pooled {sum_pooled:>8} B   {sum_ratio:>5.2}x");
    println!(
        "  (sum accumulation: legacy {sum_legacy_acc} B merge output w/ per-merge allocs, \
         pooled {sum_pooled_acc} B refill+merge output, alloc-free)\n"
    );

    // ---- wall time, steady state ----------------------------------------
    let eng = mk_eng();
    let legacy = bench("legacy sum: merge+encode+concat+put", 400, || {
        let _ = legacy_sum_batch(&grads, &eng, 1);
    });
    legacy.report_bytes((sum_legacy + sum_legacy_acc) as usize);

    let eng = mk_eng();
    let mut buf = BatchBuffer::new(BatchMode::Sum, BATCH);
    let pooled = bench("pooled sum: offer+flush_into+put_async", 400, || {
        let _ = pooled_batch(&grads, &pool, &mut buf, &eng, 1);
    });
    pooled.report_bytes((sum_pooled + sum_pooled_acc) as usize);

    println!(
        "\nJSON (paste into BENCH_write_path.json):\n{{\n  \"workload\": {{\"n_params\": {N_PARAMS}, \"rho\": {RHO}, \"batch\": {BATCH}, \"n_shards\": {N_SHARDS}, \"writers\": {WRITERS}}},\n  \"bytes_copied\": {{\n    \"single_diff\": {{\"legacy\": {diff_legacy}, \"pooled\": {diff_pooled}, \"reduction_x\": {diff_ratio:.2}}},\n    \"concat_batch\": {{\"legacy\": {concat_legacy}, \"pooled\": {concat_pooled}, \"reduction_x\": {concat_ratio:.2}}},\n    \"sum_batch\": {{\"legacy\": {sum_legacy}, \"pooled\": {sum_pooled}, \"reduction_x\": {sum_ratio:.2}}}\n  }},\n  \"wall_per_sum_batch_ns\": {{\"legacy\": {:.0}, \"pooled\": {:.0}}}\n}}",
        legacy.median() * 1e9,
        pooled.median() * 1e9,
    );

    assert!(
        diff_ratio >= 2.0 && concat_ratio >= 2.0,
        "copy-reduction acceptance failed: diff {diff_ratio:.2}x / concat {concat_ratio:.2}x < 2x"
    );
    println!("\nwrite_path bench done (acceptance >= 2x copy reduction: PASS)");
}
