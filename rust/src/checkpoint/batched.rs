//! Batched gradient writing (paper §V-B, Fig. 6).
//!
//! The checkpointing process offloads compressed gradients to a CPU-memory
//! buffer (step ①), groups `batch_size` of them (step ②), and persists the
//! batch in ONE I/O (step ③) — amortizing the per-write cost that dominates
//! at per-iteration frequency (Exp. 6 shows up to 30.9% ckpt-time savings).
//!
//! Two accumulation modes (DESIGN.md §8):
//! - [`BatchMode::Sum`]: merge by index-union summation — the paper's
//!   "gradient accumulation" scheme. Smallest writes; recovery applies the
//!   summed gradient in one Adam step (approximate for non-linear Adam,
//!   exactly as in the paper; drift is quantified in rust/tests/).
//! - [`BatchMode::Concat`]: store each step's gradient as its own section.
//!   Slightly larger, but recovery replays steps exactly (bit-faithful).

use anyhow::{ensure, Result};

use crate::checkpoint::format::{CkptKind, Container, PayloadCodec};
use crate::sparse::SparseGrad;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    Sum,
    Concat,
}

/// CPU-side batch buffer for differential checkpoints.
#[derive(Debug)]
pub struct BatchBuffer {
    mode: BatchMode,
    batch_size: usize,
    pending: Vec<(u64, SparseGrad)>,
}

impl BatchBuffer {
    pub fn new(mode: BatchMode, batch_size: usize) -> BatchBuffer {
        assert!(batch_size >= 1);
        BatchBuffer { mode, batch_size, pending: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Buffered payload bytes awaiting the batch write (the CPU-memory
    /// cost that offloading moves off the GPU — Fig. 16b).
    pub fn buffered_bytes(&self) -> usize {
        self.pending.iter().map(|(_, g)| g.encoded_size()).sum()
    }

    /// Offer one step's compressed gradient; returns `Some(container)` when
    /// the batch is full and must be written.
    pub fn push(&mut self, step: u64, grad: SparseGrad) -> Option<Container> {
        if let Some((last, _)) = self.pending.last() {
            assert!(step > *last, "steps must arrive in order: {step} after {last}");
        }
        self.pending.push((step, grad));
        if self.pending.len() >= self.batch_size {
            Some(self.flush().expect("non-empty"))
        } else {
            None
        }
    }

    /// Drain whatever is pending into a batch container (e.g. right before
    /// a full checkpoint resets the chain). None if empty.
    pub fn flush(&mut self) -> Option<Container> {
        if self.pending.is_empty() {
            return None;
        }
        let step_lo = self.pending.first().unwrap().0;
        let step_hi = self.pending.last().unwrap().0;
        let mut c = Container::new(CkptKind::BatchedDiff, 0, step_lo, step_hi);
        match self.mode {
            BatchMode::Sum => {
                let mut it = self.pending.drain(..);
                let (_, mut acc) = it.next().unwrap();
                for (_, g) in it {
                    acc = acc.merge_sum(&g);
                }
                c.push("sum", acc.to_bytes());
            }
            BatchMode::Concat => {
                for (step, g) in self.pending.drain(..) {
                    c.push(format!("step-{step}"), g.to_bytes());
                }
            }
        }
        Some(c)
    }
}

/// Decode a batched container back to (step, gradient) pairs.
/// `Sum` batches decode to a single pair at `step_hi` carrying the sum.
pub fn read_batched(bytes: &[u8], model_sig: u64) -> Result<Vec<(u64, SparseGrad)>> {
    let c = Container::from_bytes(bytes)?;
    ensure!(c.kind == CkptKind::BatchedDiff, "not a batched diff: {:?}", c.kind);
    // model_sig 0 containers come from pre-finalize buffers in tests
    ensure!(
        c.model_sig == model_sig || c.model_sig == 0,
        "batch from a different model"
    );
    let mut out = Vec::new();
    for s in &c.sections {
        if s.name == "sum" {
            out.push((c.step_hi, SparseGrad::from_bytes(&s.bytes)?));
        } else if let Some(step) = s.name.strip_prefix("step-") {
            out.push((step.parse()?, SparseGrad::from_bytes(&s.bytes)?));
        }
    }
    ensure!(!out.is_empty(), "empty batch container");
    Ok(out)
}

/// Attach the model signature and encode (the writer path helper).
pub fn finalize(mut c: Container, model_sig: u64, codec: PayloadCodec) -> Result<Vec<u8>> {
    c.model_sig = model_sig;
    c = c.with_codec(codec);
    c.to_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::tensor::Flat;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn grad(rng: &mut Rng, n: usize) -> SparseGrad {
        let mut d = Flat::zeros(n);
        for i in 0..n {
            if rng.next_f64() < 0.2 {
                d.0[i] = rng.normal() as f32;
            }
        }
        SparseGrad::from_dense(&d)
    }

    #[test]
    fn emits_exactly_at_batch_size() {
        let mut rng = Rng::new(1);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 3);
        assert!(buf.push(1, grad(&mut rng, 50)).is_none());
        assert!(buf.push(2, grad(&mut rng, 50)).is_none());
        let c = buf.push(3, grad(&mut rng, 50)).unwrap();
        assert_eq!((c.step_lo, c.step_hi), (1, 3));
        assert_eq!(c.sections.len(), 3);
        assert!(buf.is_empty());
    }

    #[test]
    fn concat_roundtrip_preserves_steps() {
        let mut rng = Rng::new(2);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 4);
        let grads: Vec<_> = (1..=4).map(|s| (s, grad(&mut rng, 80))).collect();
        let mut out = None;
        for (s, g) in &grads {
            out = buf.push(*s, g.clone());
        }
        let bytes = finalize(out.unwrap(), 7, PayloadCodec::Raw).unwrap();
        let back = read_batched(&bytes, 7).unwrap();
        assert_eq!(back, grads);
    }

    #[test]
    fn sum_mode_conserves_dense_sum_property() {
        prop_check("batch_sum_conservation", 32, |rng| {
            let n = rng.range(1, 150);
            let b = rng.range(1, 7);
            let mut buf = BatchBuffer::new(BatchMode::Sum, b);
            let mut want = Flat::zeros(n);
            let mut out = None;
            for s in 1..=b as u64 {
                let g = grad(rng, n);
                want.add_assign(&g.to_dense());
                out = buf.push(s, g);
            }
            let c = out.expect("batch full");
            let bytes = finalize(c, 1, PayloadCodec::Raw).unwrap();
            let got = read_batched(&bytes, 1).unwrap();
            prop_assert!(got.len() == 1);
            prop_assert!(got[0].0 == b as u64);
            prop_assert!(got[0].1.to_dense().max_abs_diff(&want) < 1e-5);
            Ok(())
        });
    }

    #[test]
    fn flush_drains_partial_batch() {
        let mut rng = Rng::new(3);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 10);
        buf.push(1, grad(&mut rng, 20));
        buf.push(2, grad(&mut rng, 20));
        let c = buf.flush().unwrap();
        assert_eq!((c.step_lo, c.step_hi), (1, 2));
        assert!(buf.flush().is_none());
    }

    #[test]
    #[should_panic(expected = "steps must arrive in order")]
    fn out_of_order_rejected() {
        let mut rng = Rng::new(4);
        let mut buf = BatchBuffer::new(BatchMode::Sum, 10);
        buf.push(5, grad(&mut rng, 10));
        buf.push(4, grad(&mut rng, 10));
    }

    #[test]
    fn buffered_bytes_tracks_pending() {
        let mut rng = Rng::new(5);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 10);
        assert_eq!(buf.buffered_bytes(), 0);
        let g = grad(&mut rng, 100);
        let sz = g.encoded_size();
        buf.push(1, g);
        assert_eq!(buf.buffered_bytes(), sz);
    }
}
