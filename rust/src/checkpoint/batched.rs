//! Batched gradient writing (paper §V-B, Fig. 6).
//!
//! The checkpointing process offloads compressed gradients to a CPU-memory
//! buffer (step ①), groups `batch_size` of them (step ②), and persists the
//! batch in ONE I/O (step ③) — amortizing the per-write cost that dominates
//! at per-iteration frequency (Exp. 6 shows up to 30.9% ckpt-time savings).
//!
//! Two accumulation modes (DESIGN.md §8):
//! - [`BatchMode::Sum`]: merge by index-union summation — the paper's
//!   "gradient accumulation" scheme. Smallest writes; recovery applies the
//!   summed gradient in one Adam step (approximate for non-linear Adam,
//!   exactly as in the paper; drift is quantified in rust/tests/).
//! - [`BatchMode::Concat`]: store each step's gradient as its own section.
//!   Slightly larger, but recovery replays steps exactly (bit-faithful).
//!
//! Write-path note: `Sum` accumulates **in place** at [`offer`] time into a
//! persistent accumulator/scratch pair — capacities ratchet up during the
//! first batch and the steady-state loop performs zero heap allocations —
//! and [`flush_into`] encodes the finalized container straight into a
//! caller-provided (pooled) buffer in a single pass. The old
//! `push`/`flush` + `finalize` sequence is kept as the compatible (and
//! test-oracle) surface.
//!
//! [`offer`]: BatchBuffer::offer
//! [`flush_into`]: BatchBuffer::flush_into

use anyhow::{ensure, Result};

use crate::checkpoint::format::{
    encode_container_level_into, CkptKind, Container, ContainerView, PayloadCodec, SectionSrc,
    DEFAULT_ZSTD_LEVEL,
};
use crate::sparse::SparseGrad;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    Sum,
    Concat,
}

/// CPU-side batch buffer for differential checkpoints.
#[derive(Debug)]
pub struct BatchBuffer {
    mode: BatchMode,
    batch_size: usize,
    /// Concat mode: every step's gradient, retained separately.
    pending: Vec<(u64, SparseGrad)>,
    /// Sum mode: persistent accumulator + merge scratch.
    acc: SparseGrad,
    scratch: SparseGrad,
    count: usize,
    step_lo: u64,
    step_hi: u64,
    /// bytes moved by in-buffer accumulation (acc refill + merge output);
    /// drained into `CkptStats::bytes_copied` via [`take_copied`].
    ///
    /// [`take_copied`]: BatchBuffer::take_copied
    copied: u64,
}

impl BatchBuffer {
    pub fn new(mode: BatchMode, batch_size: usize) -> BatchBuffer {
        assert!(batch_size >= 1);
        let empty = SparseGrad { dense_len: 0, indices: Vec::new(), values: Vec::new() };
        BatchBuffer {
            mode,
            batch_size,
            pending: Vec::new(),
            acc: empty.clone(),
            scratch: empty,
            count: 0,
            step_lo: 0,
            step_hi: 0,
            copied: 0,
        }
    }

    /// Retune the batching size (§V-C actuation). Callers must flush the
    /// pending batch first — resizing mid-batch would change the steps a
    /// half-built container covers.
    pub fn set_batch_size(&mut self, batch_size: usize) {
        assert!(batch_size >= 1);
        debug_assert!(self.is_empty(), "retune must flush the pending batch first");
        self.batch_size = batch_size;
    }

    /// Current batching size.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Gradients absorbed since the last flush.
    pub fn len(&self) -> usize {
        match self.mode {
            BatchMode::Sum => self.count,
            BatchMode::Concat => self.pending.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_full(&self) -> bool {
        self.len() >= self.batch_size
    }

    /// Buffered payload bytes awaiting the batch write (the CPU-memory
    /// cost that offloading moves off the GPU — Fig. 16b). For `Sum` this
    /// is the accumulator itself, which is why the paper calls the scheme
    /// memory-light.
    pub fn buffered_bytes(&self) -> usize {
        match self.mode {
            BatchMode::Sum => {
                if self.count == 0 {
                    0
                } else {
                    self.acc.encoded_size()
                }
            }
            BatchMode::Concat => self.pending.iter().map(|(_, g)| g.encoded_size()).sum(),
        }
    }

    /// Bytes moved by in-buffer accumulation since the last call.
    pub fn take_copied(&mut self) -> u64 {
        std::mem::take(&mut self.copied)
    }

    /// Absorb one step's compressed gradient; returns `true` when the
    /// batch is full and must be flushed. `Sum` mode folds the gradient
    /// into the accumulator immediately (allocation-free once warm).
    pub fn offer(&mut self, step: u64, grad: SparseGrad) -> bool {
        match self.mode {
            BatchMode::Concat => {
                if let Some((last, _)) = self.pending.last() {
                    assert!(step > *last, "steps must arrive in order: {step} after {last}");
                }
                self.pending.push((step, grad));
            }
            BatchMode::Sum => {
                if self.count == 0 {
                    self.step_lo = step;
                    // refill the persistent accumulator (copy, no alloc
                    // once its capacity covers a batch's union)
                    self.acc.dense_len = grad.dense_len;
                    self.acc.indices.clear();
                    self.acc.values.clear();
                    self.acc.indices.extend_from_slice(&grad.indices);
                    self.acc.values.extend_from_slice(&grad.values);
                    self.copied += 8 * grad.nnz() as u64;
                } else {
                    assert!(
                        step > self.step_hi,
                        "steps must arrive in order: {step} after {}",
                        self.step_hi
                    );
                    self.acc.merge_sum_into(&grad, &mut self.scratch);
                    self.copied += 8 * self.acc.nnz() as u64;
                }
                self.step_hi = step;
                self.count += 1;
            }
        }
        self.is_full()
    }

    /// Offer one step's compressed gradient; returns `Some(container)` when
    /// the batch is full. Compatibility wrapper over [`offer`] +
    /// [`flush`]; the pooled write path uses those directly.
    ///
    /// [`offer`]: BatchBuffer::offer
    /// [`flush`]: BatchBuffer::flush
    pub fn push(&mut self, step: u64, grad: SparseGrad) -> Option<Container> {
        if self.offer(step, grad) {
            Some(self.flush().expect("non-empty"))
        } else {
            None
        }
    }

    /// Single-pass drain: encode whatever is pending as a **finalized**
    /// batch container (signature + codec applied) straight into `out`,
    /// typically a pooled buffer. Returns `(step_lo, step_hi,
    /// bytes_appended)`, or `None` if empty. The encoded bytes are
    /// bit-identical to `finalize(flush(), ..)` (property-tested).
    pub fn flush_into(
        &mut self,
        model_sig: u64,
        codec: PayloadCodec,
        out: &mut Vec<u8>,
    ) -> Result<Option<(u64, u64, usize)>> {
        self.flush_into_level(model_sig, codec, DEFAULT_ZSTD_LEVEL, out)
    }

    /// [`flush_into`](BatchBuffer::flush_into) with an explicit zstd level.
    pub fn flush_into_level(
        &mut self,
        model_sig: u64,
        codec: PayloadCodec,
        zstd_level: i32,
        out: &mut Vec<u8>,
    ) -> Result<Option<(u64, u64, usize)>> {
        let encoded = self.encode_pending_into_level(model_sig, codec, zstd_level, out)?;
        if encoded.is_some() {
            match self.mode {
                BatchMode::Sum => {
                    self.count = 0;
                    self.acc.indices.clear(); // capacities survive for the next batch
                    self.acc.values.clear();
                }
                BatchMode::Concat => self.pending.clear(),
            }
        }
        Ok(encoded)
    }

    /// Encode the pending batch into `out` **without draining it** —
    /// `flush_into_level` is this plus the drain. The non-draining form is
    /// what bandit probes use: the encoder measures an alternate codec
    /// against the very same pending batch, then flushes for real with the
    /// chosen one.
    pub fn encode_pending_into_level(
        &self,
        model_sig: u64,
        codec: PayloadCodec,
        zstd_level: i32,
        out: &mut Vec<u8>,
    ) -> Result<Option<(u64, u64, usize)>> {
        if self.is_empty() {
            return Ok(None);
        }
        match self.mode {
            BatchMode::Sum => {
                let (lo, hi) = (self.step_lo, self.step_hi);
                let n = encode_container_level_into(
                    CkptKind::BatchedDiff,
                    codec,
                    zstd_level,
                    model_sig,
                    lo,
                    hi,
                    &[SectionSrc::sparse("sum", &self.acc)],
                    out,
                )?;
                Ok(Some((lo, hi, n)))
            }
            BatchMode::Concat => {
                let lo = self.pending.first().unwrap().0;
                let hi = self.pending.last().unwrap().0;
                let names: Vec<String> =
                    self.pending.iter().map(|(s, _)| format!("step-{s}")).collect();
                let secs: Vec<SectionSrc<'_>> = names
                    .iter()
                    .zip(self.pending.iter())
                    .map(|(name, (_, g))| SectionSrc::sparse(name, g))
                    .collect();
                let n = encode_container_level_into(
                    CkptKind::BatchedDiff,
                    codec,
                    zstd_level,
                    model_sig,
                    lo,
                    hi,
                    &secs,
                    out,
                )?;
                Ok(Some((lo, hi, n)))
            }
        }
    }

    /// Drain whatever is pending into a batch container (e.g. right before
    /// a full checkpoint resets the chain). None if empty. Compatibility
    /// surface: the pooled path is [`flush_into`](BatchBuffer::flush_into).
    pub fn flush(&mut self) -> Option<Container> {
        if self.is_empty() {
            return None;
        }
        match self.mode {
            BatchMode::Sum => {
                let mut c = Container::new(CkptKind::BatchedDiff, 0, self.step_lo, self.step_hi);
                c.push("sum", self.acc.to_bytes());
                self.count = 0;
                self.acc.indices.clear();
                self.acc.values.clear();
                Some(c)
            }
            BatchMode::Concat => {
                let step_lo = self.pending.first().unwrap().0;
                let step_hi = self.pending.last().unwrap().0;
                let mut c = Container::new(CkptKind::BatchedDiff, 0, step_lo, step_hi);
                for (step, g) in self.pending.drain(..) {
                    c.push(format!("step-{step}"), g.to_bytes());
                }
                Some(c)
            }
        }
    }
}

/// Decode a batched container back to (step, gradient) pairs.
/// `Sum` batches decode to a single pair at `step_hi` carrying the sum.
pub fn read_batched(bytes: &[u8], model_sig: u64) -> Result<Vec<(u64, SparseGrad)>> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::BatchedDiff, "not a batched diff: {:?}", c.kind);
    // model_sig 0 containers come from pre-finalize buffers in tests
    ensure!(
        c.model_sig == model_sig || c.model_sig == 0,
        "batch from a different model"
    );
    let mut out = Vec::new();
    for (name, bytes) in c.sections() {
        if name == "sum" {
            out.push((c.step_hi, SparseGrad::from_bytes(bytes)?));
        } else if let Some(step) = name.strip_prefix("step-") {
            out.push((step.parse()?, SparseGrad::from_bytes(bytes)?));
        }
    }
    ensure!(!out.is_empty(), "empty batch container");
    Ok(out)
}

/// Attach the model signature and encode (the writer path helper).
pub fn finalize(mut c: Container, model_sig: u64, codec: PayloadCodec) -> Result<Vec<u8>> {
    c.model_sig = model_sig;
    c = c.with_codec(codec);
    c.to_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::tensor::Flat;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn grad(rng: &mut Rng, n: usize) -> SparseGrad {
        let mut d = Flat::zeros(n);
        for i in 0..n {
            if rng.next_f64() < 0.2 {
                d.0[i] = rng.normal() as f32;
            }
        }
        SparseGrad::from_dense(&d)
    }

    #[test]
    fn emits_exactly_at_batch_size() {
        let mut rng = Rng::new(1);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 3);
        assert!(buf.push(1, grad(&mut rng, 50)).is_none());
        assert!(buf.push(2, grad(&mut rng, 50)).is_none());
        let c = buf.push(3, grad(&mut rng, 50)).unwrap();
        assert_eq!((c.step_lo, c.step_hi), (1, 3));
        assert_eq!(c.sections.len(), 3);
        assert!(buf.is_empty());
    }

    #[test]
    fn concat_roundtrip_preserves_steps() {
        // gradients are moved into the buffer (no clone on offer); the
        // expected pairs are regenerated from the same seeded RNG
        let mut rng = Rng::new(2);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 4);
        let mut out = None;
        for s in 1..=4u64 {
            out = buf.push(s, grad(&mut rng, 80));
        }
        let bytes = finalize(out.unwrap(), 7, PayloadCodec::Raw).unwrap();
        let back = read_batched(&bytes, 7).unwrap();
        let mut rng = Rng::new(2);
        let want: Vec<_> = (1..=4u64).map(|s| (s, grad(&mut rng, 80))).collect();
        assert_eq!(back, want);
    }

    #[test]
    fn sum_mode_conserves_dense_sum_property() {
        prop_check("batch_sum_conservation", 32, |rng| {
            let n = rng.range(1, 150);
            let b = rng.range(1, 7);
            let mut buf = BatchBuffer::new(BatchMode::Sum, b);
            let mut want = Flat::zeros(n);
            let mut out = None;
            for s in 1..=b as u64 {
                let g = grad(rng, n);
                want.add_assign(&g.to_dense());
                out = buf.push(s, g);
            }
            let c = out.expect("batch full");
            let bytes = finalize(c, 1, PayloadCodec::Raw).unwrap();
            let got = read_batched(&bytes, 1).unwrap();
            prop_assert!(got.len() == 1);
            prop_assert!(got[0].0 == b as u64);
            prop_assert!(got[0].1.to_dense().max_abs_diff(&want) < 1e-5);
            Ok(())
        });
    }

    #[test]
    fn flush_into_bit_identical_to_finalize_flush_property() {
        prop_check("batch_flush_into_oracle", 32, |rng| {
            for mode in [BatchMode::Sum, BatchMode::Concat] {
                for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
                    let n = rng.range(1, 120);
                    let b = rng.range(1, 6);
                    let grads: Vec<SparseGrad> = (0..b).map(|_| grad(rng, n)).collect();
                    let mut legacy = BatchBuffer::new(mode, b + 1); // no auto-flush
                    let mut pooled = BatchBuffer::new(mode, b + 1);
                    for (i, g) in grads.iter().enumerate() {
                        legacy.offer(i as u64 + 1, g.clone());
                        pooled.offer(i as u64 + 1, g.clone());
                    }
                    let want = finalize(legacy.flush().unwrap(), 9, codec)
                        .map_err(|e| format!("finalize: {e:#}"))?;
                    let mut out = Vec::new();
                    let (lo, hi, appended) = pooled
                        .flush_into(9, codec, &mut out)
                        .map_err(|e| format!("flush_into: {e:#}"))?
                        .expect("non-empty");
                    prop_assert!(out == want);
                    prop_assert!(appended == out.len());
                    prop_assert!(lo == 1 && hi == b as u64);
                    prop_assert!(pooled.is_empty());
                    let empty = pooled.flush_into(9, codec, &mut Vec::new()).unwrap();
                    prop_assert!(empty.is_none());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sum_offer_accumulates_in_place_and_counts_copies() {
        let mut rng = Rng::new(9);
        let mut buf = BatchBuffer::new(BatchMode::Sum, 8);
        assert_eq!(buf.take_copied(), 0);
        let g1 = grad(&mut rng, 100);
        let n1 = g1.nnz() as u64;
        buf.offer(1, g1);
        assert_eq!(buf.take_copied(), 8 * n1, "refill copies the first gradient");
        let g2 = grad(&mut rng, 100);
        buf.offer(2, g2);
        assert!(buf.take_copied() > 0, "merge output is accounted");
        assert_eq!(buf.len(), 2);
        assert!(buf.buffered_bytes() > 0);
    }

    #[test]
    fn encode_pending_does_not_drain() {
        let mut rng = Rng::new(11);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 4);
        buf.offer(1, grad(&mut rng, 60));
        buf.offer(2, grad(&mut rng, 60));
        let mut probe = Vec::new();
        let (lo, hi, n) = buf
            .encode_pending_into_level(9, PayloadCodec::Quant8, 1, &mut probe)
            .unwrap()
            .expect("non-empty");
        assert_eq!((lo, hi), (1, 2));
        assert_eq!(n, probe.len());
        assert_eq!(buf.len(), 2, "probe encode must not drain");
        let mut real = Vec::new();
        buf.flush_into(9, PayloadCodec::Raw, &mut real).unwrap().expect("non-empty");
        assert!(buf.is_empty());
    }

    #[test]
    fn flush_drains_partial_batch() {
        let mut rng = Rng::new(3);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 10);
        buf.push(1, grad(&mut rng, 20));
        buf.push(2, grad(&mut rng, 20));
        let c = buf.flush().unwrap();
        assert_eq!((c.step_lo, c.step_hi), (1, 2));
        assert!(buf.flush().is_none());
    }

    #[test]
    #[should_panic(expected = "steps must arrive in order")]
    fn out_of_order_rejected() {
        let mut rng = Rng::new(4);
        let mut buf = BatchBuffer::new(BatchMode::Sum, 10);
        buf.push(5, grad(&mut rng, 10));
        buf.push(4, grad(&mut rng, 10));
    }

    #[test]
    fn buffered_bytes_tracks_pending() {
        let mut rng = Rng::new(5);
        let mut buf = BatchBuffer::new(BatchMode::Concat, 10);
        assert_eq!(buf.buffered_bytes(), 0);
        let g = grad(&mut rng, 100);
        let sz = g.encoded_size();
        buf.push(1, g);
        assert_eq!(buf.buffered_bytes(), sz);
    }
}
