//! Reshard carry bases: the chain base a fresh namespace generation
//! starts from after an elastic R→R′ event.
//!
//! A naive reshard re-anchors every new rank with a full checkpoint —
//! a 3Ψ write burst that repays the full-checkpoint cost the paper's
//! differential scheme exists to avoid. A carry base instead records, per
//! new rank, the partition's split into:
//!
//! - **moved-in intervals**: parameters this rank did not own under the
//!   old partitioning — their 3·len state words are stored *inline*
//!   (someone must move those bytes; under consistent hashing they are
//!   ~|ΔR|/max(R, R′) of the model);
//! - **reference intervals**: parameters the rank retains — stored as
//!   `(offset, len)` pairs pointing into the rank's *own* base object of
//!   the previous generation (consistent hashing keeps retained slices on
//!   the same rank id, so the reference target is always
//!   `gen-{g:04}/rank-{r:04}/(full|carry)-{F:012}.ldck` for the same `r`).
//!
//! Recovery materializes a carry by reading the referenced old-generation
//! base (recursively, if that base is itself a carry) and splicing the
//! inline data over it. The carry is sealed with the *new* partition's
//! rank signature and step `F` — the uniform base step of the old chains
//! — so the re-cut merged span `(F, S]` replays on top of it exactly like
//! a diff chain on a full base.

use anyhow::{bail, ensure, Context, Result};
use byteorder::{ByteOrder, LittleEndian as LE};

use crate::checkpoint::format::{
    encode_container_into, CkptKind, ContainerView, PayloadCodec, SectionSrc,
};
use crate::cluster::{Partition, Slice};
use crate::optim::ModelState;
use crate::tensor::Flat;

/// A decoded carry base (inline data still in concatenated form; see
/// [`materialize`](Carry::materialize)).
#[derive(Clone, Debug, PartialEq)]
pub struct Carry {
    /// base step `F` this carry anchors at
    pub step: u64,
    /// generation of the committed record the reshard recovered from
    pub src_gen: u64,
    /// step of that committed record (the consistent cut `S`)
    pub src_step: u64,
    /// store-level name of the previous generation's base object the
    /// reference intervals resolve against
    pub src_base: String,
    /// global intervals stored inline, sorted by offset
    pub moved: Vec<Slice>,
    /// global intervals referencing `src_base`, sorted by offset
    pub refs: Vec<Slice>,
    /// inline state: the moved intervals' params/m/v concatenated in
    /// offset order
    pub inline: ModelState,
}

fn encode_intervals(out: &mut Vec<u8>, intervals: &[Slice]) {
    out.extend_from_slice(&(intervals.len() as u32).to_le_bytes());
    for s in intervals {
        out.extend_from_slice(&(s.offset as u64).to_le_bytes());
        out.extend_from_slice(&(s.len as u64).to_le_bytes());
    }
}

fn decode_intervals(bytes: &[u8], pos: &mut usize) -> Result<Vec<Slice>> {
    ensure!(*pos + 4 <= bytes.len(), "carry meta truncated");
    let n = LE::read_u32(&bytes[*pos..*pos + 4]) as usize;
    *pos += 4;
    ensure!(n <= 1 << 20, "implausible carry interval count");
    let mut out = Vec::with_capacity(n);
    let mut prev_end = 0usize;
    for i in 0..n {
        ensure!(*pos + 16 <= bytes.len(), "carry meta truncated");
        let offset = LE::read_u64(&bytes[*pos..*pos + 8]) as usize;
        let len = LE::read_u64(&bytes[*pos + 8..*pos + 16]) as usize;
        *pos += 16;
        ensure!(len > 0, "carry interval {i} is empty");
        ensure!(i == 0 || offset >= prev_end, "carry intervals unsorted or overlapping");
        prev_end = offset + len;
        out.push(Slice { offset, len });
    }
    Ok(out)
}

/// Encode a carry base for one new rank. `global` is the cluster state at
/// the uniform base step `F` — only the `moved` intervals are read from
/// it (the whole point: the `refs` intervals never travel).
pub fn write_carry(
    global: &ModelState,
    moved: &[Slice],
    refs: &[Slice],
    src_gen: u64,
    src_step: u64,
    src_base: &str,
    rank_sig: u64,
    codec: PayloadCodec,
) -> Result<Vec<u8>> {
    ensure!(!moved.is_empty() || !refs.is_empty(), "carry with no intervals");
    let inline_len: usize = moved.iter().map(|s| s.len).sum();
    let mut params = Vec::with_capacity(inline_len);
    let mut m = Vec::with_capacity(inline_len);
    let mut v = Vec::with_capacity(inline_len);
    for s in moved {
        ensure!(s.end() <= global.params.len(), "moved interval beyond the model");
        params.extend_from_slice(&global.params.0[s.offset..s.end()]);
        m.extend_from_slice(&global.m.0[s.offset..s.end()]);
        v.extend_from_slice(&global.v.0[s.offset..s.end()]);
    }
    let params = Flat(params);
    let m = Flat(m);
    let v = Flat(v);

    let mut meta = Vec::new();
    meta.extend_from_slice(&src_gen.to_le_bytes());
    meta.extend_from_slice(&src_step.to_le_bytes());
    ensure!(src_base.len() <= u16::MAX as usize, "src base name too long");
    meta.extend_from_slice(&(src_base.len() as u16).to_le_bytes());
    meta.extend_from_slice(src_base.as_bytes());
    encode_intervals(&mut meta, moved);
    encode_intervals(&mut meta, refs);

    let mut out = Vec::new();
    encode_container_into(
        CkptKind::CarryFull,
        codec,
        rank_sig,
        global.step,
        global.step,
        &[
            SectionSrc::bytes("meta", &meta),
            SectionSrc::flat("params", &params),
            SectionSrc::flat("adam_m", &m),
            SectionSrc::flat("adam_v", &v),
        ],
        &mut out,
    )?;
    Ok(out)
}

/// Decode a carry base, verifying the (new-partition) rank signature.
pub fn read_carry(bytes: &[u8], rank_sig: u64) -> Result<Carry> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::CarryFull, "not a carry base: {:?}", c.kind);
    ensure!(
        c.model_sig == rank_sig,
        "carry belongs to a different partitioning (sig {:#x} != {:#x})",
        c.model_sig,
        rank_sig
    );
    let meta = c.section("meta")?;
    ensure!(meta.len() >= 18, "carry meta too short");
    let src_gen = LE::read_u64(&meta[0..8]);
    let src_step = LE::read_u64(&meta[8..16]);
    let name_len = LE::read_u16(&meta[16..18]) as usize;
    ensure!(18 + name_len <= meta.len(), "carry meta truncated");
    let src_base = std::str::from_utf8(&meta[18..18 + name_len])
        .context("carry src base name")?
        .to_string();
    let mut pos = 18 + name_len;
    let moved = decode_intervals(meta, &mut pos)?;
    let refs = decode_intervals(meta, &mut pos)?;
    ensure!(pos == meta.len(), "carry meta has trailing bytes");

    let params = Flat::from_le_bytes(c.section("params")?);
    let m = Flat::from_le_bytes(c.section("adam_m")?);
    let v = Flat::from_le_bytes(c.section("adam_v")?);
    let inline_len: usize = moved.iter().map(|s| s.len).sum();
    ensure!(
        params.len() == inline_len && m.len() == inline_len && v.len() == inline_len,
        "carry inline sections don't match the moved intervals"
    );
    Ok(Carry {
        step: c.step_lo,
        src_gen,
        src_step,
        src_base,
        moved,
        refs,
        inline: ModelState { params, m, v, step: c.step_lo },
    })
}

impl Carry {
    /// Materialize the new rank's local base state at step `F`:
    /// moved intervals come from the inline payload, reference intervals
    /// from `old_state` — the *same rank's* previous-generation base
    /// (local to `old_part`). `new_part` defines the output index space;
    /// its slices must be tiled exactly by `moved ∪ refs`.
    pub fn materialize(
        &self,
        new_part: &Partition,
        old_part: &Partition,
        old_state: &ModelState,
    ) -> Result<ModelState> {
        ensure!(
            old_state.params.len() == old_part.len(),
            "old base state has {} params, partition owns {}",
            old_state.params.len(),
            old_part.len()
        );
        // moved ∪ refs must tile the new partition exactly
        let mut union: Vec<(Slice, bool)> = self
            .moved
            .iter()
            .map(|s| (*s, true))
            .chain(self.refs.iter().map(|s| (*s, false)))
            .collect();
        union.sort_by_key(|(s, _)| s.offset);
        {
            let mut covered = 0usize;
            let mut ranges = new_part.ranges();
            let mut cur = ranges.next();
            for (s, _) in &union {
                let r = cur.clone().context("carry intervals overrun the partition")?;
                ensure!(
                    s.offset == r.start + covered && s.end() <= r.end,
                    "carry interval [{}, {}) does not tile partition range [{}, {})",
                    s.offset,
                    s.end(),
                    r.start,
                    r.end
                );
                covered += s.len;
                if r.start + covered == r.end {
                    covered = 0;
                    cur = ranges.next();
                }
            }
            ensure!(
                cur.is_none() && covered == 0,
                "carry intervals leave part of the partition uncovered"
            );
        }

        let n = new_part.len();
        let mut out = ModelState {
            params: Flat(vec![0.0; n]),
            m: Flat(vec![0.0; n]),
            v: Flat(vec![0.0; n]),
            step: self.step,
        };
        let mut inline_pos = 0usize;
        for (s, is_moved) in &union {
            let dst = new_part
                .local_of_global(s.offset)
                .context("carry interval outside the new partition")?;
            if *is_moved {
                let src = inline_pos..inline_pos + s.len;
                out.params.0[dst..dst + s.len].copy_from_slice(&self.inline.params.0[src.clone()]);
                out.m.0[dst..dst + s.len].copy_from_slice(&self.inline.m.0[src.clone()]);
                out.v.0[dst..dst + s.len].copy_from_slice(&self.inline.v.0[src]);
                inline_pos += s.len;
            } else {
                // a globally-contiguous ref interval may map to
                // discontiguous old-local runs; copy run by run
                let mut g = s.offset;
                let mut d = dst;
                while g < s.end() {
                    let ol = old_part
                        .local_of_global(g)
                        .with_context(|| format!("ref interval at {g} not in the old partition"))?;
                    // length of the contiguous old-local run from g
                    let old_slice = old_part
                        .slices
                        .iter()
                        .find(|sl| sl.offset <= g && g < sl.end())
                        .expect("local_of_global succeeded");
                    let run = (old_slice.end() - g).min(s.end() - g);
                    out.params.0[d..d + run]
                        .copy_from_slice(&old_state.params.0[ol..ol + run]);
                    out.m.0[d..d + run].copy_from_slice(&old_state.m.0[ol..ol + run]);
                    out.v.0[d..d + run].copy_from_slice(&old_state.v.0[ol..ol + run]);
                    g += run;
                    d += run;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{partition_even, slice_state};

    fn global(n: usize, step: u64) -> ModelState {
        ModelState {
            params: Flat((0..n).map(|i| i as f32).collect()),
            m: Flat((0..n).map(|i| 100.0 + i as f32).collect()),
            v: Flat((0..n).map(|i| 200.0 + i as f32).collect()),
            step,
        }
    }

    #[test]
    fn carry_roundtrip_and_materialize() {
        let n = 12;
        let g = global(n, 7);
        // old: rank 0 owned [0, 6); new: rank 0 owns [0, 6) ∪ [9, 12)
        let old_part = Partition::contiguous(0, 0, 6);
        let new_part = Partition {
            rank: 0,
            slices: vec![Slice { offset: 0, len: 6 }, Slice { offset: 9, len: 3 }],
        };
        let moved = vec![Slice { offset: 9, len: 3 }];
        let refs = vec![Slice { offset: 0, len: 6 }];
        let bytes = write_carry(
            &g,
            &moved,
            &refs,
            0,
            9,
            "gen-0000/rank-0000/full-000000000007.ldck",
            42,
            PayloadCodec::Raw,
        )
        .unwrap();
        let carry = read_carry(&bytes, 42).unwrap();
        assert_eq!(carry.step, 7);
        assert_eq!(carry.src_gen, 0);
        assert_eq!(carry.src_step, 9);
        assert_eq!(carry.moved, moved);
        assert_eq!(carry.refs, refs);
        assert!(read_carry(&bytes, 43).is_err(), "wrong sig rejected");

        let old_state = slice_state(&g, &old_part);
        let out = carry.materialize(&new_part, &old_part, &old_state).unwrap();
        assert_eq!(out, slice_state(&g, &new_part), "bit-identical to direct slicing");
    }

    #[test]
    fn carry_with_discontiguous_refs() {
        // old rank owned two scattered slices; new partition retains both
        // plus a moved-in middle
        let n = 20;
        let g = global(n, 3);
        let old_part = Partition {
            rank: 1,
            slices: vec![Slice { offset: 2, len: 3 }, Slice { offset: 12, len: 4 }],
        };
        let new_part = Partition {
            rank: 1,
            slices: vec![
                Slice { offset: 2, len: 3 },
                Slice { offset: 8, len: 2 },
                Slice { offset: 12, len: 4 },
            ],
        };
        let moved = vec![Slice { offset: 8, len: 2 }];
        let refs = vec![Slice { offset: 2, len: 3 }, Slice { offset: 12, len: 4 }];
        let bytes =
            write_carry(&g, &moved, &refs, 2, 5, "gen-0002/rank-0001/carry-000000000003.ldck", 7, PayloadCodec::Zstd)
                .unwrap();
        let carry = read_carry(&bytes, 7).unwrap();
        let out = carry
            .materialize(&new_part, &old_part, &slice_state(&g, &old_part))
            .unwrap();
        assert_eq!(out, slice_state(&g, &new_part));
    }

    #[test]
    fn carry_inline_is_only_the_moved_bytes() {
        // the size claim behind the whole design: a carry's payload is
        // ~3·moved, not 3·len(partition)
        let n = 1000;
        let g = global(n, 1);
        let moved = vec![Slice { offset: 990, len: 10 }];
        let refs = vec![Slice { offset: 0, len: 990 }];
        let bytes =
            write_carry(&g, &moved, &refs, 0, 1, "x", 1, PayloadCodec::Raw).unwrap();
        let inline = 3 * 10 * 4;
        assert!(bytes.len() < inline + 300, "carry is {} bytes for {inline} inline", bytes.len());
    }

    #[test]
    fn materialize_rejects_incomplete_tiling() {
        let n = 10;
        let g = global(n, 1);
        let old_part = Partition::contiguous(0, 0, 5);
        let new_part = Partition::contiguous(0, 0, 10);
        // refs + moved cover only [0, 8)
        let bytes = write_carry(
            &g,
            &[Slice { offset: 5, len: 3 }],
            &[Slice { offset: 0, len: 5 }],
            0,
            1,
            "x",
            1,
            PayloadCodec::Raw,
        )
        .unwrap();
        let carry = read_carry(&bytes, 1).unwrap();
        let old_state = slice_state(&g, &old_part);
        assert!(carry.materialize(&new_part, &old_part, &old_state).is_err());
    }

    #[test]
    fn full_container_rejected_as_carry() {
        let g = global(4, 1);
        let bytes = crate::checkpoint::full::write_full(&g, 9, PayloadCodec::Raw).unwrap();
        assert!(read_carry(&bytes, 9).is_err());
    }

    #[test]
    fn carry_detects_corruption() {
        let g = global(16, 2);
        let bytes = write_carry(
            &g,
            &[Slice { offset: 0, len: 8 }],
            &[Slice { offset: 8, len: 8 }],
            0,
            2,
            "base",
            5,
            PayloadCodec::Raw,
        )
        .unwrap();
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        assert!(read_carry(&bad, 5).is_err());
        assert!(read_carry(&bytes[..bytes.len() - 3], 5).is_err());
    }

    #[test]
    fn partition_even_reshard_materializes_via_carry() {
        // 4→2 over even partitions: new rank 0 = old ranks 0+1 merged
        let n = 16;
        let g = global(n, 5);
        let old = partition_even(n, 4);
        let new = partition_even(n, 2);
        // new rank 0 retains old rank 0's [0,4), moves in old rank 1's [4,8)
        let moved = vec![Slice { offset: 4, len: 4 }];
        let refs = vec![Slice { offset: 0, len: 4 }];
        let bytes = write_carry(&g, &moved, &refs, 0, 5, "b", 3, PayloadCodec::Raw).unwrap();
        let carry = read_carry(&bytes, 3).unwrap();
        let out = carry
            .materialize(&new[0], &old[0], &slice_state(&g, &old[0]))
            .unwrap();
        assert_eq!(out, slice_state(&g, &new[0]));
    }
}
