//! Differential checkpoints C^D.
//!
//! Two payload flavors, matching the two systems under comparison:
//! - [`DiffPayload::Gradient`]: a **reused compressed gradient** — LowDiff's
//!   differential (Eq. (7): C^D_t = Adam(G̃_t) semantically; the container
//!   stores G̃_t itself and recovery replays it through the optimizer).
//! - [`DiffPayload::StateDelta`]: a compressed **state delta**
//!   M_{t+1} − M_t over the full 3Ψ state — the Naive DC / Check-N-Run
//!   baseline (Eq. (5)); recovery adds deltas (linear, Eq. (6)).

use anyhow::{bail, ensure, Result};

use crate::checkpoint::format::{
    encode_container_level_into, CkptKind, ContainerView, PayloadCodec, SectionSrc,
    DEFAULT_ZSTD_LEVEL,
};
use crate::sparse::SparseGrad;

/// What a differential carries.
#[derive(Clone, Debug, PartialEq)]
pub enum DiffPayload {
    /// k-sparse compressed gradient over Ψ params (LowDiff).
    Gradient(SparseGrad),
    /// k-sparse compressed delta over the 3Ψ state (Naive DC).
    StateDelta(SparseGrad),
}

impl DiffPayload {
    fn tag(&self) -> &'static str {
        match self {
            DiffPayload::Gradient(_) => "grad",
            DiffPayload::StateDelta(_) => "delta",
        }
    }

    pub fn sparse(&self) -> &SparseGrad {
        match self {
            DiffPayload::Gradient(s) | DiffPayload::StateDelta(s) => s,
        }
    }
}

/// Encode one differential checkpoint for step `step`.
pub fn write_diff(
    payload: &DiffPayload,
    model_sig: u64,
    step: u64,
    codec: PayloadCodec,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_diff_into(payload, model_sig, step, codec, &mut out)?;
    Ok(out)
}

/// Single-pass encode into `out` (typically a pooled buffer): the sparse
/// payload is serialized straight into the container — one copy from the
/// in-memory gradient to the write buffer. Returns bytes appended.
pub fn write_diff_into(
    payload: &DiffPayload,
    model_sig: u64,
    step: u64,
    codec: PayloadCodec,
    out: &mut Vec<u8>,
) -> Result<usize> {
    write_diff_into_level(payload, model_sig, step, codec, DEFAULT_ZSTD_LEVEL, out)
}

/// [`write_diff_into`] with an explicit zstd level (the `--zstd-level`
/// knob; only the Zstd codec reads it).
pub fn write_diff_into_level(
    payload: &DiffPayload,
    model_sig: u64,
    step: u64,
    codec: PayloadCodec,
    zstd_level: i32,
    out: &mut Vec<u8>,
) -> Result<usize> {
    encode_container_level_into(
        CkptKind::Diff,
        codec,
        zstd_level,
        model_sig,
        step,
        step,
        &[SectionSrc::sparse(payload.tag(), payload.sparse())],
        out,
    )
}

/// Decode a differential checkpoint (borrowing reader; the sparse payload
/// is parsed straight off the section slice).
pub fn read_diff(bytes: &[u8], model_sig: u64) -> Result<(u64, DiffPayload)> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::Diff, "not a diff checkpoint: {:?}", c.kind);
    ensure!(c.model_sig == model_sig, "diff from a different model");
    let payload = if let Ok(b) = c.section("grad") {
        DiffPayload::Gradient(SparseGrad::from_bytes(b)?)
    } else if let Ok(b) = c.section("delta") {
        DiffPayload::StateDelta(SparseGrad::from_bytes(b)?)
    } else {
        bail!("diff container has neither `grad` nor `delta` section");
    };
    Ok((c.step_lo, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Flat;

    fn sparse() -> SparseGrad {
        SparseGrad::from_dense(&Flat(vec![0.0, 1.0, 0.0, -2.0]))
    }

    #[test]
    fn gradient_roundtrip() {
        let p = DiffPayload::Gradient(sparse());
        let b = write_diff(&p, 9, 5, PayloadCodec::Raw).unwrap();
        let (step, back) = read_diff(&b, 9).unwrap();
        assert_eq!(step, 5);
        assert_eq!(back, p);
    }

    #[test]
    fn state_delta_roundtrip() {
        let p = DiffPayload::StateDelta(sparse());
        let b = write_diff(&p, 9, 6, PayloadCodec::Zstd).unwrap();
        let (step, back) = read_diff(&b, 9).unwrap();
        assert_eq!(step, 6);
        assert_eq!(back, p);
    }

    #[test]
    fn payload_kind_preserved() {
        let g = write_diff(&DiffPayload::Gradient(sparse()), 1, 1, PayloadCodec::Raw).unwrap();
        let (_, p) = read_diff(&g, 1).unwrap();
        assert!(matches!(p, DiffPayload::Gradient(_)));
    }

    #[test]
    fn quant8_diff_roundtrip_within_contract() {
        // Quant8 reconstructs the standard sparse wire at parse time, so
        // read_diff needs no codec-specific path: indices exact, values
        // dequantized (here scale-exact: integer values, absmax 127)
        let s = SparseGrad {
            dense_len: 8,
            indices: vec![1, 3, 6],
            values: vec![127.0, -64.0, 32.0],
        };
        let p = DiffPayload::Gradient(s.clone());
        let b = write_diff(&p, 9, 5, PayloadCodec::Quant8).unwrap();
        let (step, back) = read_diff(&b, 9).unwrap();
        assert_eq!(step, 5);
        assert_eq!(back, p);
    }

    #[test]
    fn wrong_sig_rejected() {
        let b = write_diff(&DiffPayload::Gradient(sparse()), 1, 1, PayloadCodec::Raw).unwrap();
        assert!(read_diff(&b, 2).is_err());
    }
}
