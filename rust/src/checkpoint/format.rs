//! On-disk checkpoint container.
//!
//! Layout (all little-endian):
//! ```text
//! magic "LDCK" | version u32 | kind u8 | payload_codec u8 | reserved u16
//! model_sig u64 | step_lo u64 | step_hi u64 | n_sections u32
//! per section: name_len u16 | name bytes | byte_len u64
//! payload (all section bytes concatenated, optionally zstd-compressed)
//! crc32 u32 (of the *encoded* payload) | magic "KCDL"
//! ```
//! CRC covers the payload; header corruption is caught by magic/version and
//! bounds checks. `model_sig` ties a checkpoint to the model layout that
//! produced it (mixing checkpoints across models is a recovery-time error,
//! not a silent state corruption).

use std::borrow::Cow;

use anyhow::{bail, ensure, Context, Result};
use byteorder::{ByteOrder, LittleEndian as LE};

use crate::sparse::SparseGrad;
use crate::tensor::Flat;

pub const MAGIC: &[u8; 4] = b"LDCK";
pub const MAGIC_END: &[u8; 4] = b"KCDL";
pub const VERSION: u32 = 1;
/// Container version for the codec-extension wire format (Quant8 /
/// DeltaFull). Readers accept both; writers stamp the lowest version that
/// can express the codec, so Raw/Zstd containers stay bit-identical to the
/// v1 encoder and pre-extension readers reject the new codecs twice over
/// (unknown version AND unknown codec byte).
pub const VERSION_CODEC_EXT: u32 = 2;
/// Default zstd compression level (the value the encoder always used; now
/// a knob — `CkptConfig::zstd_level`, CLI `--zstd-level`).
pub const DEFAULT_ZSTD_LEVEL: i32 = 1;

/// What the container holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptKind {
    /// Full model state (params + Adam moments), 3Ψ.
    Full = 0,
    /// One differential checkpoint.
    Diff = 1,
    /// Batched differential checkpoint (C^B, §V-B).
    BatchedDiff = 2,
    /// Compacted span of differentials (incremental-merging persistence,
    /// §VI-B): the background compactor's rewrite of a run of raw
    /// diff/batch objects into one container that preserves every
    /// per-step payload (see `checkpoint::merged`).
    MergedDiff = 3,
    /// Reshard carry base (see `checkpoint::carry`): a new generation's
    /// chain base holding the rank's *moved-in* slices inline and its
    /// *retained* slices as by-interval references into the previous
    /// generation's base — what lets an elastic restart move ~1/R of the
    /// state instead of rewriting all of it.
    CarryFull = 4,
}

impl CkptKind {
    fn from_u8(v: u8) -> Result<CkptKind> {
        Ok(match v {
            0 => CkptKind::Full,
            1 => CkptKind::Diff,
            2 => CkptKind::BatchedDiff,
            3 => CkptKind::MergedDiff,
            4 => CkptKind::CarryFull,
            _ => bail!("unknown checkpoint kind {v}"),
        })
    }
}

/// Payload-level compression of the container bytes.
///
/// `Raw`/`Zstd` are lossless byte-stream codecs (container v1). The
/// codec-extension codecs (container v2) transform *typed* payloads:
///
/// * `Quant8` — per-block scale u8 quantization of sparse top-k *values*
///   with a lossless delta+varint *index* stream (Check-N-Run style).
///   Lossy, but with a hard contract: the decode is a pure function of
///   the stored bytes, so every replay of the same container dequantizes
///   to exactly the same f32s — the error is fixed at encode time and
///   never compounds across a chain (see docs/FORMAT.md).
/// * `DeltaFull` — dense full state XOR'd against the previous persisted
///   full, then zstd. Lossless, but decoding needs the base payload
///   (`ContainerView::parse_with_base`); `step_lo` in the header names
///   the base step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadCodec {
    Raw = 0,
    Zstd = 1,
    Quant8 = 2,
    DeltaFull = 3,
}

/// Number of wire codecs (sizing per-codec counter arrays).
pub const N_CODECS: usize = 4;

impl PayloadCodec {
    pub const ALL: [PayloadCodec; N_CODECS] = [
        PayloadCodec::Raw,
        PayloadCodec::Zstd,
        PayloadCodec::Quant8,
        PayloadCodec::DeltaFull,
    ];

    pub fn from_u8(v: u8) -> Result<PayloadCodec> {
        Ok(match v {
            0 => PayloadCodec::Raw,
            1 => PayloadCodec::Zstd,
            2 => PayloadCodec::Quant8,
            3 => PayloadCodec::DeltaFull,
            _ => bail!("unknown payload codec {v}"),
        })
    }

    /// Dense index into per-codec counter arrays.
    pub fn idx(self) -> usize {
        self as usize
    }

    /// Stable lowercase name (metrics labels, CLI, sidecar state).
    pub fn name(self) -> &'static str {
        match self {
            PayloadCodec::Raw => "raw",
            PayloadCodec::Zstd => "zstd",
            PayloadCodec::Quant8 => "quant8",
            PayloadCodec::DeltaFull => "delta-full",
        }
    }

    /// Inverse of [`name`](PayloadCodec::name), tolerant of common aliases.
    pub fn parse_name(s: &str) -> Option<PayloadCodec> {
        match s.to_ascii_lowercase().as_str() {
            "raw" => Some(PayloadCodec::Raw),
            "zstd" => Some(PayloadCodec::Zstd),
            "quant8" | "q8" => Some(PayloadCodec::Quant8),
            "delta-full" | "deltafull" | "delta" => Some(PayloadCodec::DeltaFull),
            _ => None,
        }
    }

    /// True if decode may differ from the encoder's input (bounded,
    /// non-compounding quantization error — the codec contract).
    pub fn is_lossy(self) -> bool {
        matches!(self, PayloadCodec::Quant8)
    }

    /// Lowest container version able to express this codec; the encoder
    /// stamps exactly this, so v1 containers stay bit-identical.
    pub fn container_version(self) -> u32 {
        match self {
            PayloadCodec::Raw | PayloadCodec::Zstd => VERSION,
            PayloadCodec::Quant8 | PayloadCodec::DeltaFull => VERSION_CODEC_EXT,
        }
    }
}

/// Named byte blob inside a container.
#[derive(Clone, Debug, PartialEq)]
pub struct Section {
    pub name: String,
    pub bytes: Vec<u8>,
}

/// A decoded checkpoint container.
#[derive(Clone, Debug, PartialEq)]
pub struct Container {
    pub kind: CkptKind,
    pub codec: PayloadCodec,
    /// layout signature (FNV-1a of model name + n_params)
    pub model_sig: u64,
    /// first training step covered (inclusive, 1-based Adam step)
    pub step_lo: u64,
    /// last training step covered (== step_lo except for batches)
    pub step_hi: u64,
    pub sections: Vec<Section>,
}

/// FNV-1a signature binding checkpoints to a model layout.
pub fn model_signature(model: &str, n_params: usize) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in model.bytes().chain(n_params.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Container {
    pub fn new(kind: CkptKind, model_sig: u64, step_lo: u64, step_hi: u64) -> Container {
        Container { kind, codec: PayloadCodec::Raw, model_sig, step_lo, step_hi, sections: Vec::new() }
    }

    pub fn with_codec(mut self, codec: PayloadCodec) -> Container {
        self.codec = codec;
        self
    }

    pub fn push(&mut self, name: impl Into<String>, bytes: Vec<u8>) {
        self.sections.push(Section { name: name.into(), bytes });
    }

    pub fn section(&self, name: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.bytes.as_slice())
            .with_context(|| format!("container missing section `{name}`"))
    }

    pub fn payload_bytes(&self) -> usize {
        self.sections.iter().map(|s| s.bytes.len()).sum()
    }

    /// Serialize to the container wire format (single-pass; see
    /// [`encode_container_into`]).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.encode_into(&mut out)?;
        Ok(out)
    }

    /// Single-pass append of the wire encoding to `out` (typically a
    /// pooled buffer). Returns the bytes appended.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> Result<usize> {
        let secs: Vec<SectionSrc<'_>> = self
            .sections
            .iter()
            .map(|s| SectionSrc::bytes(&s.name, &s.bytes))
            .collect();
        encode_container_into(
            self.kind,
            self.codec,
            self.model_sig,
            self.step_lo,
            self.step_hi,
            &secs,
            out,
        )
    }

    /// Pre-change two-copy encoder (raw payload concat, then splice), kept
    /// verbatim as the bit-identity oracle for the single-pass encoder.
    #[cfg(test)]
    pub fn to_bytes_reference(&self) -> Result<Vec<u8>> {
        let raw_payload: Vec<u8> = {
            let mut p = Vec::with_capacity(self.payload_bytes());
            for s in &self.sections {
                p.extend_from_slice(&s.bytes);
            }
            p
        };
        let payload = match self.codec {
            PayloadCodec::Raw => raw_payload,
            PayloadCodec::Zstd => zstd::encode_all(raw_payload.as_slice(), 1)?,
            other => bail!("no reference encoder for v2 codec {}", other.name()),
        };
        let crc = crc32fast::hash(&payload);

        let mut out = Vec::with_capacity(payload.len() + 64);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind as u8);
        out.push(self.codec as u8);
        out.extend_from_slice(&[0u8; 2]);
        out.extend_from_slice(&self.model_sig.to_le_bytes());
        out.extend_from_slice(&self.step_lo.to_le_bytes());
        out.extend_from_slice(&self.step_hi.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for s in &self.sections {
            ensure!(s.name.len() <= u16::MAX as usize, "section name too long");
            out.extend_from_slice(&(s.name.len() as u16).to_le_bytes());
            out.extend_from_slice(s.name.as_bytes());
            out.extend_from_slice(&(s.bytes.len() as u64).to_le_bytes());
        }
        out.extend_from_slice(&payload);
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(MAGIC_END);
        Ok(out)
    }

    /// Parse and verify a container (owning decode; the zero-copy variant
    /// is [`ContainerView::parse`]).
    pub fn from_bytes(bytes: &[u8]) -> Result<Container> {
        Ok(ContainerView::parse(bytes)?.to_container())
    }
}

/// Borrowed payload source for single-pass container encoding: either
/// bytes that already exist, or a typed object that knows how to serialize
/// itself straight into the output buffer — which is what lets a
/// differential checkpoint go from its in-memory sparse form to container
/// bytes in exactly one copy.
pub enum PayloadSrc<'a> {
    Bytes(&'a [u8]),
    Sparse(&'a SparseGrad),
    FlatF32(&'a Flat),
}

impl PayloadSrc<'_> {
    /// Encoded length of this payload on the wire.
    pub fn encoded_len(&self) -> usize {
        match self {
            PayloadSrc::Bytes(b) => b.len(),
            PayloadSrc::Sparse(s) => s.encoded_size(),
            PayloadSrc::FlatF32(f) => 4 * f.len(),
        }
    }

    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            PayloadSrc::Bytes(b) => out.extend_from_slice(b),
            PayloadSrc::Sparse(s) => s.encode_into(out),
            PayloadSrc::FlatF32(f) => {
                out.reserve(4 * f.len());
                for x in &f.0 {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
}

/// One named section source for [`encode_container_into`].
pub struct SectionSrc<'a> {
    pub name: &'a str,
    pub payload: PayloadSrc<'a>,
}

impl<'a> SectionSrc<'a> {
    pub fn bytes(name: &'a str, b: &'a [u8]) -> SectionSrc<'a> {
        SectionSrc { name, payload: PayloadSrc::Bytes(b) }
    }
    pub fn sparse(name: &'a str, s: &'a SparseGrad) -> SectionSrc<'a> {
        SectionSrc { name, payload: PayloadSrc::Sparse(s) }
    }
    pub fn flat(name: &'a str, f: &'a Flat) -> SectionSrc<'a> {
        SectionSrc { name, payload: PayloadSrc::FlatF32(f) }
    }
}

// Staging buffer for the Zstd payload (the compressor needs the raw
// stream; reusing one thread-local keeps even that path alloc-free in
// steady state). Raw-codec encoding never touches it.
thread_local! {
    static ZSTD_SCRATCH: std::cell::RefCell<Vec<u8>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Single-pass container encoder: header, section table, payload, CRC and
/// end magic are appended to `out` in one forward pass. For the Raw codec
/// the CRC is fused into the payload copy (each section is hashed as it
/// lands in `out`) and **no intermediate payload buffer exists**; for Zstd
/// the raw stream is staged once in a reusable thread-local scratch and
/// compressed straight into `out`; for Quant8 each section is transformed
/// straight into `out` (tagged blob, see module docs) with the CRC fused
/// like Raw. Bit-identical to the pre-change two-copy encoder for
/// Raw/Zstd (property-tested against it). Returns bytes appended.
///
/// Encodes at [`DEFAULT_ZSTD_LEVEL`]; the level knob is
/// [`encode_container_level_into`].
pub fn encode_container_into(
    kind: CkptKind,
    codec: PayloadCodec,
    model_sig: u64,
    step_lo: u64,
    step_hi: u64,
    sections: &[SectionSrc<'_>],
    out: &mut Vec<u8>,
) -> Result<usize> {
    encode_container_level_into(
        kind,
        codec,
        DEFAULT_ZSTD_LEVEL,
        model_sig,
        step_lo,
        step_hi,
        sections,
        out,
    )
}

/// [`encode_container_into`] with an explicit zstd level (`--zstd-level`
/// knob; ignored by Raw/Quant8). The level is not stored in the header —
/// the decoder does not need it.
#[allow(clippy::too_many_arguments)]
pub fn encode_container_level_into(
    kind: CkptKind,
    codec: PayloadCodec,
    zstd_level: i32,
    model_sig: u64,
    step_lo: u64,
    step_hi: u64,
    sections: &[SectionSrc<'_>],
    out: &mut Vec<u8>,
) -> Result<usize> {
    ensure!(
        codec != PayloadCodec::DeltaFull,
        "delta-full containers are written by encode_delta_full_into (need a base payload)"
    );
    let start = out.len();
    let payload_len: usize = sections.iter().map(|s| s.payload.encoded_len()).sum();
    let meta_len: usize = sections.iter().map(|s| 2 + s.name.len() + 8).sum();
    // reserve the exact output for Raw; for the compressing codecs only the
    // header — the encoded size is unknown and reserving raw_len would
    // permanently inflate recycled pool buffers to uncompressed capacity
    let reserve_payload = match codec {
        PayloadCodec::Raw => payload_len,
        _ => 0,
    };
    out.reserve(40 + meta_len + reserve_payload + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&codec.container_version().to_le_bytes());
    out.push(kind as u8);
    out.push(codec as u8);
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&model_sig.to_le_bytes());
    out.extend_from_slice(&step_lo.to_le_bytes());
    out.extend_from_slice(&step_hi.to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for s in sections {
        ensure!(s.name.len() <= u16::MAX as usize, "section name too long");
        out.extend_from_slice(&(s.name.len() as u16).to_le_bytes());
        out.extend_from_slice(s.name.as_bytes());
        // always the *decoded* (raw) length: what the section yields after
        // ContainerView::parse, independent of the payload codec
        out.extend_from_slice(&(s.payload.encoded_len() as u64).to_le_bytes());
    }
    let payload_start = out.len();
    let crc = match codec {
        PayloadCodec::Raw => {
            let mut hasher = crc32fast::Hasher::new();
            for s in sections {
                let sec_start = out.len();
                s.payload.write_to(out);
                hasher.update(&out[sec_start..]);
            }
            hasher.finalize()
        }
        PayloadCodec::Zstd => {
            ZSTD_SCRATCH.with(|cell| -> Result<()> {
                let mut scratch = cell.borrow_mut();
                scratch.clear();
                scratch.reserve(payload_len);
                for s in sections {
                    s.payload.write_to(&mut scratch);
                }
                // same streaming path `zstd::encode_all` uses internally,
                // so the compressed bytes are identical to the old encoder
                zstd::stream::copy_encode(scratch.as_slice(), &mut *out, zstd_level)?;
                Ok(())
            })?;
            crc32fast::hash(&out[payload_start..])
        }
        PayloadCodec::Quant8 => {
            let mut hasher = crc32fast::Hasher::new();
            for s in sections {
                let sec_start = out.len();
                write_quant_section(&s.payload, out);
                hasher.update(&out[sec_start..]);
            }
            hasher.finalize()
        }
        PayloadCodec::DeltaFull => unreachable!("rejected above"),
    };
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(MAGIC_END);
    Ok(out.len() - start)
}

// ---- Quant8 section transform -------------------------------------------
//
// Stored payload = concatenation of self-delimiting per-section blobs:
//
// ```text
// tag u8 = 0 | raw section bytes (exactly the header-table length)
// tag u8 = 1 | nnz u32 | dense_len u32 | nb u32
//            | q u8 × nnz | scales f32 × nb           (nb = ⌈nnz/QBLOCK⌉)
//            | uvarint index deltas × nnz             (d0 = idx0, di = idxi − idxi−1)
// ```
//
// Only typed sparse sources quantize (tag 1); byte/dense sections pass
// through verbatim (tag 0), so a Quant8 container holding only opaque
// bytes round-trips losslessly. The section table in the header records
// the *decoded* raw lengths, so downstream section readers are untouched.

/// LEB128 unsigned varint append.
fn write_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// LEB128 unsigned varint read; returns (value, next position).
fn read_uvarint(buf: &[u8], mut pos: usize) -> Result<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        ensure!(pos < buf.len(), "varint truncated");
        let b = buf[pos];
        pos += 1;
        ensure!(shift < 64, "varint overflow");
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok((v, pos));
        }
        shift += 7;
    }
}

/// Append one Quant8 section blob (tag 1 for sparse sources, tag 0
/// passthrough otherwise).
fn write_quant_section(p: &PayloadSrc<'_>, out: &mut Vec<u8>) {
    match p {
        PayloadSrc::Sparse(s) => {
            let nnz = s.nnz();
            let nb = nnz.div_ceil(crate::compress::QBLOCK);
            out.reserve(13 + nnz + 4 * nb + 2 * nnz);
            out.push(1u8);
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&s.dense_len.to_le_bytes());
            out.extend_from_slice(&(nb as u32).to_le_bytes());
            // quantized values land straight in `out`; scales are a tiny
            // per-block side vector appended after
            let mut scales: Vec<f32> = Vec::with_capacity(nb);
            crate::compress::quant8_into(&s.values, out, &mut scales);
            for sc in &scales {
                out.extend_from_slice(&sc.to_le_bytes());
            }
            let mut prev = 0u32;
            for (i, &idx) in s.indices.iter().enumerate() {
                let d = if i == 0 { idx } else { idx - prev };
                write_uvarint(out, d as u64);
                prev = idx;
            }
        }
        other => {
            out.push(0u8);
            other.write_to(out);
        }
    }
}

/// Decode one tag-1 blob starting at `*pos`, appending the reconstructed
/// standard sparse wire bytes (`[dense_len u32][nnz u32][indices][values]`)
/// to `out`. Advances `*pos` past the blob.
fn read_quant_sparse(buf: &[u8], pos: &mut usize, out: &mut Vec<u8>) -> Result<()> {
    let p = *pos;
    ensure!(p + 12 <= buf.len(), "quant section header truncated");
    let nnz = LE::read_u32(&buf[p..p + 4]) as usize;
    let dense_len = LE::read_u32(&buf[p + 4..p + 8]);
    let nb = LE::read_u32(&buf[p + 8..p + 12]) as usize;
    ensure!(nnz as u64 <= dense_len as u64, "quant nnz {nnz} > dense_len {dense_len}");
    ensure!(
        nb == nnz.div_ceil(crate::compress::QBLOCK),
        "quant block count {nb} inconsistent with nnz {nnz}"
    );
    let q_at = p + 12;
    ensure!(q_at + nnz + 4 * nb <= buf.len(), "quant value streams truncated");
    let qbytes = &buf[q_at..q_at + nnz];
    let scales = &buf[q_at + nnz..q_at + nnz + 4 * nb];

    out.reserve(8 + 8 * nnz);
    out.extend_from_slice(&dense_len.to_le_bytes());
    out.extend_from_slice(&(nnz as u32).to_le_bytes());
    let mut vpos = q_at + nnz + 4 * nb;
    let mut prev: u64 = 0;
    for i in 0..nnz {
        let (d, np) = read_uvarint(buf, vpos)?;
        vpos = np;
        let idx = if i == 0 {
            d
        } else {
            ensure!(d >= 1, "quant index stream not strictly ascending");
            prev + d
        };
        ensure!(idx < dense_len as u64, "quant index {idx} out of range {dense_len}");
        out.extend_from_slice(&(idx as u32).to_le_bytes());
        prev = idx;
    }
    for (i, &q) in qbytes.iter().enumerate() {
        let sc = LE::read_f32(&scales[4 * (i / crate::compress::QBLOCK)..]);
        let v = crate::compress::dequant8_at(q, sc);
        out.extend_from_slice(&v.to_le_bytes());
    }
    *pos = vpos;
    Ok(())
}

/// Decode a full Quant8 payload into the reconstructed raw payload, given
/// the per-section decoded lengths from the header table.
fn decode_quant_payload(payload: &[u8], lens: &[usize]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(lens.iter().sum());
    let mut pos = 0usize;
    for &want in lens {
        ensure!(pos < payload.len(), "quant payload truncated");
        let tag = payload[pos];
        pos += 1;
        let sec_start = out.len();
        match tag {
            0 => {
                ensure!(pos + want <= payload.len(), "quant raw section truncated");
                out.extend_from_slice(&payload[pos..pos + want]);
                pos += want;
            }
            1 => read_quant_sparse(payload, &mut pos, &mut out)?,
            t => bail!("unknown quant section tag {t}"),
        }
        let got = out.len() - sec_start;
        ensure!(got == want, "quant section decodes to {got} != header length {want}");
    }
    ensure!(pos == payload.len(), "quant payload has {} trailing bytes", payload.len() - pos);
    Ok(out)
}

// ---- DeltaFull ----------------------------------------------------------

/// Encode a delta-vs-previous full: the raw payload is staged, XOR'd
/// byte-wise against `base_payload` (the *raw* payload of the base full,
/// which must have the identical section layout), then zstd'd. The header
/// carries `step_lo = base_step` (which full to fetch at decode) and
/// `step_hi = step`; plain fulls keep `step_lo == step_hi`, so readers key
/// on `step_hi`. Decode with [`ContainerView::parse_with_base`].
#[allow(clippy::too_many_arguments)]
pub fn encode_delta_full_into(
    kind: CkptKind,
    zstd_level: i32,
    model_sig: u64,
    base_step: u64,
    step: u64,
    sections: &[SectionSrc<'_>],
    base_payload: &[u8],
    out: &mut Vec<u8>,
) -> Result<usize> {
    let start = out.len();
    let payload_len: usize = sections.iter().map(|s| s.payload.encoded_len()).sum();
    ensure!(
        payload_len == base_payload.len(),
        "delta-full layout mismatch: payload {payload_len} != base {}",
        base_payload.len()
    );
    let meta_len: usize = sections.iter().map(|s| 2 + s.name.len() + 8).sum();
    out.reserve(40 + meta_len + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&PayloadCodec::DeltaFull.container_version().to_le_bytes());
    out.push(kind as u8);
    out.push(PayloadCodec::DeltaFull as u8);
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&model_sig.to_le_bytes());
    out.extend_from_slice(&base_step.to_le_bytes());
    out.extend_from_slice(&step.to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for s in sections {
        ensure!(s.name.len() <= u16::MAX as usize, "section name too long");
        out.extend_from_slice(&(s.name.len() as u16).to_le_bytes());
        out.extend_from_slice(s.name.as_bytes());
        out.extend_from_slice(&(s.payload.encoded_len() as u64).to_le_bytes());
    }
    let payload_start = out.len();
    ZSTD_SCRATCH.with(|cell| -> Result<()> {
        let mut scratch = cell.borrow_mut();
        scratch.clear();
        scratch.reserve(payload_len);
        for s in sections {
            s.payload.write_to(&mut scratch);
        }
        for (b, &base) in scratch.iter_mut().zip(base_payload.iter()) {
            *b ^= base;
        }
        zstd::stream::copy_encode(scratch.as_slice(), &mut *out, zstd_level)?;
        Ok(())
    })?;
    let crc = crc32fast::hash(&out[payload_start..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(MAGIC_END);
    Ok(out.len() - start)
}

/// Cheap header peek: the payload codec byte, validated only by magic and
/// minimum length (no CRC walk). Lets the manifest GC and the full-reader
/// decide whether a full needs its base without a full parse.
pub fn peek_codec(bytes: &[u8]) -> Result<PayloadCodec> {
    ensure!(bytes.len() >= 48, "container too short ({} bytes)", bytes.len());
    ensure!(&bytes[0..4] == MAGIC, "bad magic");
    PayloadCodec::from_u8(bytes[9])
}

/// Cheap header peek: `(step_lo, step_hi)`. For a DeltaFull container
/// `step_lo` is the base full's step.
pub fn peek_steps(bytes: &[u8]) -> Result<(u64, u64)> {
    ensure!(bytes.len() >= 48, "container too short ({} bytes)", bytes.len());
    ensure!(&bytes[0..4] == MAGIC, "bad magic");
    Ok((LE::read_u64(&bytes[20..28]), LE::read_u64(&bytes[28..36])))
}

/// Byte offset of the span-level field inside the container header (the
/// u16 that was reserved padding before hierarchical compaction).
const LEVEL_OFFSET: usize = 10;

/// Stamp a compaction level into an already-encoded container starting at
/// `container[start..]`. The level lives in the header, which the payload
/// CRC does not cover, so patching after [`encode_container_into`] keeps
/// the object verifiable — and every non-merged encoder keeps writing the
/// zero it always wrote, preserving bit-identity with the reference
/// encoder.
pub fn set_container_level(container: &mut [u8], start: usize, level: u16) {
    container[start + LEVEL_OFFSET..start + LEVEL_OFFSET + 2]
        .copy_from_slice(&level.to_le_bytes());
}

/// A parsed container whose sections *borrow* the input buffer (Raw codec;
/// Zstd payloads are decompressed into one owned buffer, still without the
/// per-section `to_vec` of the owning decode). Section names borrow the
/// header region. This is the recovery-path reader: a chain replay decodes
/// every differential without duplicating its payload.
pub struct ContainerView<'a> {
    pub kind: CkptKind,
    pub codec: PayloadCodec,
    /// Compaction level of a [`CkptKind::MergedDiff`] span (stored in the
    /// header bytes that were reserved before hierarchical compaction):
    /// 0 for every non-merged container and for spans written by pre-level
    /// encoders, k ≥ 1 for a level-k span. See [`span_level_from_header`].
    pub level: u16,
    pub model_sig: u64,
    pub step_lo: u64,
    pub step_hi: u64,
    names: Vec<&'a str>,
    ranges: Vec<(usize, usize)>,
    payload: Cow<'a, [u8]>,
}

impl<'a> ContainerView<'a> {
    /// Parse and verify; identical validation (and error wording) to the
    /// owning [`Container::from_bytes`], which now delegates here.
    ///
    /// Fails on a [`PayloadCodec::DeltaFull`] container — its payload is
    /// meaningless without the base full; callers that can fetch the base
    /// use [`parse_with_base`](ContainerView::parse_with_base).
    pub fn parse(bytes: &'a [u8]) -> Result<ContainerView<'a>> {
        Self::parse_inner(bytes, None)
    }

    /// Parse a [`PayloadCodec::DeltaFull`] container, reconstructing the
    /// raw payload by XOR against `base_payload` (the raw payload of the
    /// base full named by `step_lo`). Also accepts non-delta containers
    /// (the base is then ignored).
    pub fn parse_with_base(bytes: &'a [u8], base_payload: &[u8]) -> Result<ContainerView<'a>> {
        Self::parse_inner(bytes, Some(base_payload))
    }

    fn parse_inner(bytes: &'a [u8], base: Option<&[u8]>) -> Result<ContainerView<'a>> {
        ensure!(bytes.len() >= 48, "container too short ({} bytes)", bytes.len());
        ensure!(&bytes[0..4] == MAGIC, "bad magic");
        ensure!(&bytes[bytes.len() - 4..] == MAGIC_END, "bad end magic (truncated?)");
        let version = LE::read_u32(&bytes[4..8]);
        ensure!(
            version == VERSION || version == VERSION_CODEC_EXT,
            "unsupported version {version}"
        );
        let kind = CkptKind::from_u8(bytes[8])?;
        let codec = PayloadCodec::from_u8(bytes[9])?;
        ensure!(
            version >= codec.container_version(),
            "codec {} needs container version {}, header says {version}",
            codec.name(),
            codec.container_version()
        );
        let level = LE::read_u16(&bytes[10..12]);
        let model_sig = LE::read_u64(&bytes[12..20]);
        let step_lo = LE::read_u64(&bytes[20..28]);
        let step_hi = LE::read_u64(&bytes[28..36]);
        let n_sections = LE::read_u32(&bytes[36..40]) as usize;
        ensure!(n_sections <= 1 << 20, "implausible section count");

        let mut pos = 40usize;
        let mut names: Vec<&'a str> = Vec::with_capacity(n_sections);
        let mut lens: Vec<usize> = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            ensure!(pos + 2 <= bytes.len(), "truncated section header");
            let nlen = LE::read_u16(&bytes[pos..pos + 2]) as usize;
            pos += 2;
            ensure!(pos + nlen + 8 <= bytes.len(), "truncated section name");
            names.push(std::str::from_utf8(&bytes[pos..pos + nlen])?);
            pos += nlen;
            lens.push(LE::read_u64(&bytes[pos..pos + 8]) as usize);
            pos += 8;
        }
        let payload_end = bytes.len() - 8;
        ensure!(pos <= payload_end, "header overruns payload");
        let payload = &bytes[pos..payload_end];
        let crc_stored = LE::read_u32(&bytes[payload_end..payload_end + 4]);
        let crc = crc32fast::hash(payload);
        ensure!(crc == crc_stored, "payload CRC mismatch: {crc:#x} != {crc_stored:#x}");

        let raw: Cow<'a, [u8]> = match codec {
            PayloadCodec::Raw => Cow::Borrowed(payload),
            PayloadCodec::Zstd => Cow::Owned(zstd::decode_all(payload)?),
            PayloadCodec::Quant8 => Cow::Owned(decode_quant_payload(payload, &lens)?),
            PayloadCodec::DeltaFull => {
                let base = base.with_context(|| {
                    format!(
                        "delta-full container (base step {step_lo}) requires its base payload"
                    )
                })?;
                let mut decoded = zstd::decode_all(payload)?;
                ensure!(
                    decoded.len() == base.len(),
                    "delta-full payload {} != base payload {}",
                    decoded.len(),
                    base.len()
                );
                for (b, &base_b) in decoded.iter_mut().zip(base.iter()) {
                    *b ^= base_b;
                }
                Cow::Owned(decoded)
            }
        };
        let expected: usize = lens.iter().sum();
        ensure!(raw.len() == expected, "payload {} != sections total {expected}", raw.len());

        let mut ranges = Vec::with_capacity(n_sections);
        let mut off = 0usize;
        for blen in lens {
            ranges.push((off, off + blen));
            off += blen;
        }
        Ok(ContainerView {
            kind,
            codec,
            level,
            model_sig,
            step_lo,
            step_hi,
            names,
            ranges,
            payload: raw,
        })
    }

    pub fn n_sections(&self) -> usize {
        self.names.len()
    }

    /// Borrowed bytes of the named section.
    pub fn section(&self, name: &str) -> Result<&[u8]> {
        self.names
            .iter()
            .position(|n| *n == name)
            .map(|i| {
                let (a, b) = self.ranges[i];
                &self.payload[a..b]
            })
            .with_context(|| format!("container missing section `{name}`"))
    }

    /// Iterate `(name, bytes)` pairs in wire order, borrowing both.
    pub fn sections(&self) -> impl Iterator<Item = (&str, &[u8])> + '_ {
        self.names
            .iter()
            .zip(self.ranges.iter())
            .map(|(n, &(a, b))| (*n, &self.payload[a..b]))
    }

    /// Materialize an owning [`Container`] (one copy per section).
    pub fn to_container(&self) -> Container {
        Container {
            kind: self.kind,
            codec: self.codec,
            model_sig: self.model_sig,
            step_lo: self.step_lo,
            step_hi: self.step_hi,
            sections: self
                .sections()
                .map(|(name, bytes)| Section { name: name.to_string(), bytes: bytes.to_vec() })
                .collect(),
        }
    }
}

/// Magic for the shard index (commit record) written by the sharded
/// storage engine alongside the shard data objects.
pub const SHARD_MAGIC: &[u8; 4] = b"LDSI";
pub const SHARD_VERSION: u32 = 1;

/// Per-shard metadata inside a [`ShardIndex`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    pub len: u64,
    pub crc32: u32,
}

/// Commit record for one logical object split into `n` shards
/// (`Sharded` engine, crate::storage). Records the shard count, total
/// length, and a per-shard (length, CRC32) pair so recovery can read
/// shards in parallel and detect torn or partial writes. The index is
/// written only after every shard is durable: its presence *is* the
/// commit point.
///
/// Wire layout (little-endian):
/// ```text
/// magic "LDSI" | version u32 | n_shards u32 | total_len u64
/// per shard: len u64 | crc32 u32
/// crc32 u32 (of all preceding bytes)
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardIndex {
    pub total_len: u64,
    pub shards: Vec<ShardMeta>,
}

impl ShardIndex {
    /// Build the index for `bytes` split into the given shard slices.
    pub fn build(shards: &[&[u8]]) -> ShardIndex {
        ShardIndex {
            total_len: shards.iter().map(|s| s.len() as u64).sum(),
            shards: shards
                .iter()
                .map(|s| ShardMeta { len: s.len() as u64, crc32: crc32fast::hash(s) })
                .collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + 12 * self.shards.len() + 4);
        out.extend_from_slice(SHARD_MAGIC);
        out.extend_from_slice(&SHARD_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.shards.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.total_len.to_le_bytes());
        for s in &self.shards {
            out.extend_from_slice(&s.len.to_le_bytes());
            out.extend_from_slice(&s.crc32.to_le_bytes());
        }
        let crc = crc32fast::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<ShardIndex> {
        ensure!(bytes.len() >= 24, "shard index too short ({} bytes)", bytes.len());
        ensure!(&bytes[0..4] == SHARD_MAGIC, "bad shard index magic");
        let version = LE::read_u32(&bytes[4..8]);
        ensure!(version == SHARD_VERSION, "unsupported shard index version {version}");
        let n = LE::read_u32(&bytes[8..12]) as usize;
        ensure!(n >= 1 && n <= 1 << 16, "implausible shard count {n}");
        let want = 20 + 12 * n + 4;
        ensure!(bytes.len() == want, "shard index length {} != {want}", bytes.len());
        let crc_stored = LE::read_u32(&bytes[want - 4..]);
        let crc = crc32fast::hash(&bytes[..want - 4]);
        ensure!(crc == crc_stored, "shard index CRC mismatch (torn index write?)");
        let total_len = LE::read_u64(&bytes[12..20]);
        let mut shards = Vec::with_capacity(n);
        let mut pos = 20;
        for _ in 0..n {
            let len = LE::read_u64(&bytes[pos..pos + 8]);
            let crc32 = LE::read_u32(&bytes[pos + 8..pos + 12]);
            shards.push(ShardMeta { len, crc32 });
            pos += 12;
        }
        let sum: u64 = shards.iter().map(|s| s.len).sum();
        ensure!(sum == total_len, "shard lengths {sum} != total {total_len}");
        Ok(ShardIndex { total_len, shards })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn sample(codec: PayloadCodec) -> Container {
        let mut c = Container::new(CkptKind::Diff, model_signature("tiny", 100), 7, 7)
            .with_codec(codec);
        c.push("grad", vec![1, 2, 3, 4, 5]);
        c.push("meta", vec![9; 100]);
        c
    }

    #[test]
    fn roundtrip_raw_and_zstd() {
        for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
            let c = sample(codec);
            let b = c.to_bytes().unwrap();
            let d = Container::from_bytes(&b).unwrap();
            assert_eq!(c, d);
        }
    }

    #[test]
    fn zstd_compresses_redundant_payload() {
        let raw = sample(PayloadCodec::Raw).to_bytes().unwrap();
        let z = sample(PayloadCodec::Zstd).to_bytes().unwrap();
        assert!(z.len() < raw.len());
    }

    #[test]
    fn crc_detects_payload_corruption() {
        let mut b = sample(PayloadCodec::Raw).to_bytes().unwrap();
        let mid = b.len() / 2;
        b[mid] ^= 0xFF;
        let err = Container::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("CRC") || err.contains("magic"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let b = sample(PayloadCodec::Raw).to_bytes().unwrap();
        for cut in [1, 10, b.len() / 2, b.len() - 1] {
            assert!(Container::from_bytes(&b[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn wrong_magic_rejected() {
        let mut b = sample(PayloadCodec::Raw).to_bytes().unwrap();
        b[0] = b'X';
        assert!(Container::from_bytes(&b).is_err());
    }

    #[test]
    fn model_signature_distinguishes() {
        assert_ne!(model_signature("a", 10), model_signature("b", 10));
        assert_ne!(model_signature("a", 10), model_signature("a", 11));
        assert_eq!(model_signature("a", 10), model_signature("a", 10));
    }

    #[test]
    fn roundtrip_property() {
        prop_check("container_roundtrip", 32, |rng| {
            let mut c = Container::new(
                CkptKind::BatchedDiff,
                rng.next_u64(),
                rng.next_u64() % 1000,
                rng.next_u64() % 1000,
            );
            let nsec = rng.range(0, 6);
            for i in 0..nsec {
                let len = rng.range(0, 500);
                let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                c.push(format!("s{i}"), bytes);
            }
            let back = Container::from_bytes(&c.to_bytes().unwrap()).unwrap();
            prop_assert!(back == c);
            Ok(())
        });
    }

    #[test]
    fn single_pass_encoder_bit_identical_to_reference_property() {
        prop_check("container_encoder_oracle", 64, |rng| {
            for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
                let mut c = Container::new(
                    CkptKind::BatchedDiff,
                    rng.next_u64(),
                    rng.next_u64() % 1000,
                    rng.next_u64() % 1000,
                )
                .with_codec(codec);
                let nsec = rng.range(0, 6);
                for i in 0..nsec {
                    let len = rng.range(0, 500);
                    let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                    c.push(format!("s{i}"), bytes);
                }
                prop_assert!(c.to_bytes().unwrap() == c.to_bytes_reference().unwrap());
            }
            Ok(())
        });
    }

    #[test]
    fn typed_payload_sources_match_pushed_bytes_property() {
        use crate::tensor::Flat;
        prop_check("container_typed_src_oracle", 64, |rng| {
            // a sparse gradient and a dense flat, via typed sources
            let n = rng.range(1, 200);
            let mut dense = Flat::zeros(n);
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    dense.0[i] = rng.normal() as f32;
                }
            }
            let sparse = crate::sparse::SparseGrad::from_dense(&dense);
            for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
                let mut out = Vec::new();
                let appended = encode_container_into(
                    CkptKind::Diff,
                    codec,
                    7,
                    3,
                    3,
                    &[SectionSrc::sparse("grad", &sparse), SectionSrc::flat("dense", &dense)],
                    &mut out,
                )
                .unwrap();
                prop_assert!(appended == out.len());
                let mut want = Container::new(CkptKind::Diff, 7, 3, 3).with_codec(codec);
                want.push("grad", sparse.to_bytes_reference());
                want.push("dense", dense.to_le_bytes());
                prop_assert!(out == want.to_bytes_reference().unwrap());
            }
            Ok(())
        });
    }

    #[test]
    fn container_view_borrows_sections() {
        for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
            let c = sample(codec);
            let bytes = c.to_bytes().unwrap();
            let view = ContainerView::parse(&bytes).unwrap();
            assert_eq!(view.kind, c.kind);
            assert_eq!(view.n_sections(), 2);
            assert_eq!(view.section("grad").unwrap(), &[1, 2, 3, 4, 5]);
            assert_eq!(view.section("meta").unwrap(), &[9; 100]);
            assert!(view.section("nope").unwrap_err().to_string().contains("nope"));
            let names: Vec<&str> = view.sections().map(|(n, _)| n).collect();
            assert_eq!(names, vec!["grad", "meta"]);
            assert_eq!(view.to_container(), c);
            if codec == PayloadCodec::Raw {
                // raw sections alias the input buffer — the zero-copy claim
                let sec = view.section("grad").unwrap();
                let base = bytes.as_ptr() as usize;
                let p = sec.as_ptr() as usize;
                assert!(p >= base && p + sec.len() <= base + bytes.len());
            }
        }
    }

    fn arb_sparse_grad(rng: &mut crate::util::rng::Rng, max_len: usize) -> SparseGrad {
        let n = rng.range(8, max_len);
        let mut dense = Flat::zeros(n);
        for i in 0..n {
            if rng.next_f64() < 0.1 {
                dense.0[i] = rng.normal() as f32;
            }
        }
        SparseGrad::from_dense(&dense)
    }

    /// What the Quant8 wire contract promises a sparse section decodes to:
    /// exact indices, values quantized per QBLOCK then dequantized.
    fn quant_expected(s: &SparseGrad) -> SparseGrad {
        let mut q = Vec::new();
        let mut scales = Vec::new();
        crate::compress::quant8_into(&s.values, &mut q, &mut scales);
        let values = q
            .iter()
            .enumerate()
            .map(|(i, &b)| crate::compress::dequant8_at(b, scales[i / crate::compress::QBLOCK]))
            .collect();
        SparseGrad { dense_len: s.dense_len, indices: s.indices.clone(), values }
    }

    #[test]
    fn quant8_sparse_roundtrip_property() {
        prop_check("quant8_sparse_roundtrip", 64, |rng| {
            let sparse = arb_sparse_grad(rng, 2000);
            let mut out = Vec::new();
            encode_container_into(
                CkptKind::Diff,
                PayloadCodec::Quant8,
                7,
                3,
                3,
                &[SectionSrc::sparse("grad", &sparse)],
                &mut out,
            )
            .unwrap();
            let view = ContainerView::parse(&out).map_err(|e| format!("parse: {e:#}"))?;
            prop_assert!(view.codec == PayloadCodec::Quant8);
            let back = SparseGrad::from_bytes(view.section("grad").unwrap())
                .map_err(|e| format!("sparse: {e:#}"))?;
            let want = quant_expected(&sparse);
            // index stream is exactly lossless; values match the quantizer
            // bit-for-bit (the codec contract)
            prop_assert!(back.indices == want.indices);
            prop_assert!(back.dense_len == want.dense_len);
            prop_assert!(back.values == want.values);
            // decode is idempotent: parsing the same bytes again yields the
            // same f32s (what makes replay error non-compounding)
            let view2 = ContainerView::parse(&out).unwrap();
            prop_assert!(view2.section("grad").unwrap() == view.section("grad").unwrap());
            Ok(())
        });
    }

    #[test]
    fn quant8_error_within_per_block_bound() {
        prop_check("quant8_error_bound", 32, |rng| {
            let sparse = arb_sparse_grad(rng, 1500);
            let want = quant_expected(&sparse);
            for blk in (0..sparse.values.len()).step_by(crate::compress::QBLOCK) {
                let end = (blk + crate::compress::QBLOCK).min(sparse.values.len());
                let absmax =
                    sparse.values[blk..end].iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let bound = absmax / 127.0 * 0.5 + 1e-6;
                for i in blk..end {
                    prop_assert!((sparse.values[i] - want.values[i]).abs() <= bound);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quant8_bytes_sections_are_lossless_passthrough() {
        // a Quant8 container with only opaque byte sections (e.g. meta)
        // round-trips bit-identically — tag-0 passthrough
        let c = sample(PayloadCodec::Quant8);
        let b = c.to_bytes().unwrap();
        let d = Container::from_bytes(&b).unwrap();
        assert_eq!(c, d);
        assert_eq!(LE::read_u32(&b[4..8]), VERSION_CODEC_EXT);
    }

    #[test]
    fn quant8_shrinks_topk_diff_below_zstd() {
        // the acceptance workload shape: random top-k values, ~1% density
        let mut rng = crate::util::rng::Rng::new(0x51dec0de);
        let n = 1 << 16;
        let mut dense = Flat::zeros(n);
        for i in 0..n {
            if rng.next_f64() < 0.01 {
                dense.0[i] = rng.normal() as f32;
            }
        }
        let sparse = SparseGrad::from_dense(&dense);
        let mut sizes = [0usize; 2];
        for (slot, codec) in [PayloadCodec::Zstd, PayloadCodec::Quant8].iter().enumerate() {
            let mut out = Vec::new();
            encode_container_into(
                CkptKind::Diff,
                *codec,
                7,
                3,
                3,
                &[SectionSrc::sparse("grad", &sparse)],
                &mut out,
            )
            .unwrap();
            sizes[slot] = out.len();
        }
        assert!(
            sizes[1] * 2 <= sizes[0],
            "quant8 {} not ≥2x smaller than zstd {}",
            sizes[1],
            sizes[0]
        );
    }

    #[test]
    fn quant8_corruption_and_truncation_rejected() {
        let mut rng = crate::util::rng::Rng::new(99);
        let sparse = arb_sparse_grad(&mut rng, 800);
        let mut out = Vec::new();
        encode_container_into(
            CkptKind::Diff,
            PayloadCodec::Quant8,
            7,
            3,
            3,
            &[SectionSrc::sparse("grad", &sparse)],
            &mut out,
        )
        .unwrap();
        for cut in [1, 20, out.len() / 2, out.len() - 1] {
            assert!(ContainerView::parse(&out[..cut]).is_err(), "cut {cut}");
        }
        let mid = out.len() / 2;
        let mut bad = out.clone();
        bad[mid] ^= 0xFF;
        assert!(ContainerView::parse(&bad).is_err());
    }

    #[test]
    fn v1_header_with_v2_codec_rejected() {
        // a corrupted/forged header claiming v1 but carrying a v2 codec
        // byte must not parse (CRC does not cover the header)
        let c = sample(PayloadCodec::Quant8);
        let mut b = c.to_bytes().unwrap();
        b[4..8].copy_from_slice(&VERSION.to_le_bytes());
        let err = ContainerView::parse(&b).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn raw_zstd_headers_stay_v1() {
        for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
            let b = sample(codec).to_bytes().unwrap();
            assert_eq!(LE::read_u32(&b[4..8]), VERSION);
        }
    }

    #[test]
    fn delta_full_roundtrip_and_requires_base() {
        let mut rng = crate::util::rng::Rng::new(7);
        let n = 512;
        let mut base = Flat::zeros(n);
        let mut next = Flat::zeros(n);
        for i in 0..n {
            base.0[i] = rng.normal() as f32;
            // mostly-unchanged dense state — the delta-full workload
            next.0[i] = if rng.next_f64() < 0.05 { rng.normal() as f32 } else { base.0[i] };
        }
        let mut base_payload = Vec::new();
        PayloadSrc::FlatF32(&base).write_to(&mut base_payload);

        let mut delta = Vec::new();
        encode_delta_full_into(
            CkptKind::Full,
            1,
            7,
            10, // base step
            20, // this step
            &[SectionSrc::flat("state", &next)],
            &base_payload,
            &mut delta,
        )
        .unwrap();

        // header peeks
        assert_eq!(peek_codec(&delta).unwrap(), PayloadCodec::DeltaFull);
        assert_eq!(peek_steps(&delta).unwrap(), (10, 20));

        // no base → a named error, not garbage
        let err = ContainerView::parse(&delta).unwrap_err().to_string();
        assert!(err.contains("base"), "{err}");

        // with base → bit-exact reconstruction
        let view = ContainerView::parse_with_base(&delta, &base_payload).unwrap();
        assert_eq!(view.step_lo, 10);
        assert_eq!(view.step_hi, 20);
        let mut want = Vec::new();
        PayloadSrc::FlatF32(&next).write_to(&mut want);
        assert_eq!(view.section("state").unwrap(), want.as_slice());

        // a delta against mostly-unchanged state beats a plain zstd full
        let mut plain = Vec::new();
        encode_container_into(
            CkptKind::Full,
            PayloadCodec::Zstd,
            7,
            20,
            20,
            &[SectionSrc::flat("state", &next)],
            &mut plain,
        )
        .unwrap();
        assert!(delta.len() < plain.len(), "delta {} >= plain {}", delta.len(), plain.len());

        // wrong-length base rejected
        assert!(ContainerView::parse_with_base(&delta, &base_payload[..100]).is_err());
    }

    #[test]
    fn codec_name_roundtrip() {
        for codec in PayloadCodec::ALL {
            assert_eq!(PayloadCodec::parse_name(codec.name()), Some(codec));
            assert_eq!(PayloadCodec::from_u8(codec as u8).unwrap(), codec);
        }
        assert_eq!(PayloadCodec::parse_name("Q8"), Some(PayloadCodec::Quant8));
        assert_eq!(PayloadCodec::parse_name("bogus"), None);
    }

    #[test]
    fn missing_section_error_names_it() {
        let c = sample(PayloadCodec::Raw);
        let err = c.section("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
    }

    #[test]
    fn shard_index_roundtrip() {
        let a = b"hello".as_slice();
        let b = b"world!!".as_slice();
        let idx = ShardIndex::build(&[a, b]);
        assert_eq!(idx.n_shards(), 2);
        assert_eq!(idx.total_len, 12);
        let back = ShardIndex::from_bytes(&idx.to_bytes()).unwrap();
        assert_eq!(back, idx);
        assert_eq!(back.shards[0].crc32, crc32fast::hash(a));
    }

    #[test]
    fn shard_index_detects_corruption_and_truncation() {
        let idx = ShardIndex::build(&[b"abc".as_slice(), b"defg".as_slice()]);
        let bytes = idx.to_bytes();
        for cut in [0, 4, bytes.len() - 1] {
            assert!(ShardIndex::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut bad = bytes.clone();
        bad[14] ^= 0xFF;
        let err = ShardIndex::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC") || err.contains("length") || err.contains("total"), "{err}");
    }

    #[test]
    fn shard_index_roundtrip_property() {
        prop_check("shard_index_roundtrip", 32, |rng| {
            let n = rng.range(1, 9);
            let blobs: Vec<Vec<u8>> = (0..n)
                .map(|_| {
                    let len = rng.range(0, 200);
                    (0..len).map(|_| rng.next_u64() as u8).collect()
                })
                .collect();
            let slices: Vec<&[u8]> = blobs.iter().map(|b| b.as_slice()).collect();
            let idx = ShardIndex::build(&slices);
            let back = ShardIndex::from_bytes(&idx.to_bytes())
                .map_err(|e| format!("decode: {e:#}"))?;
            prop_assert!(back == idx);
            Ok(())
        });
    }
}
