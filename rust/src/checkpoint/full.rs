//! Full checkpoints C^F: the complete 3Ψ model state (params, adam_m,
//! adam_v) plus the step counter. Written "regularly" (Alg. 1 line 12) at
//! the tuned full-checkpoint frequency f* (§V-C).

use anyhow::{ensure, Result};

use crate::checkpoint::format::{
    encode_container_into, CkptKind, ContainerView, PayloadCodec, SectionSrc,
};
use crate::optim::ModelState;
use crate::tensor::Flat;

/// Encode a model state as a full-checkpoint container.
pub fn write_full(state: &ModelState, model_sig: u64, codec: PayloadCodec) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_full_into(state, model_sig, codec, &mut out)?;
    Ok(out)
}

/// Single-pass encode into `out`: the 3Ψ state tensors are serialized
/// straight into the container (no per-section byte vectors). Returns
/// bytes appended.
pub fn write_full_into(
    state: &ModelState,
    model_sig: u64,
    codec: PayloadCodec,
    out: &mut Vec<u8>,
) -> Result<usize> {
    encode_container_into(
        CkptKind::Full,
        codec,
        model_sig,
        state.step,
        state.step,
        &[
            SectionSrc::flat("params", &state.params),
            SectionSrc::flat("adam_m", &state.m),
            SectionSrc::flat("adam_v", &state.v),
        ],
        out,
    )
}

/// Decode a full checkpoint, verifying the model signature.
pub fn read_full(bytes: &[u8], model_sig: u64) -> Result<ModelState> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::Full, "not a full checkpoint: {:?}", c.kind);
    ensure!(
        c.model_sig == model_sig,
        "checkpoint belongs to a different model (sig {:#x} != {:#x})",
        c.model_sig,
        model_sig
    );
    let params = Flat::from_le_bytes(c.section("params")?);
    let m = Flat::from_le_bytes(c.section("adam_m")?);
    let v = Flat::from_le_bytes(c.section("adam_v")?);
    ensure!(params.len() == m.len() && m.len() == v.len(), "section length mismatch");
    Ok(ModelState { params, m, v, step: c.step_lo })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::{model_signature, Container};
    use crate::util::rng::Rng;

    fn state(n: usize) -> ModelState {
        let mut rng = Rng::new(3);
        let mut p = vec![0f32; n];
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        rng.fill_normal_f32(&mut m);
        for x in v.iter_mut() {
            *x = rng.next_f32();
        }
        ModelState { params: Flat(p), m: Flat(m), v: Flat(v), step: 42 }
    }

    #[test]
    fn roundtrip_bit_exact() {
        let sig = model_signature("t", 100);
        let s = state(100);
        for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
            let bytes = write_full(&s, sig, codec).unwrap();
            let back = read_full(&bytes, sig).unwrap();
            assert_eq!(back, s);
        }
    }

    #[test]
    fn full_is_3psi_bytes_raw() {
        // Finding 2: full checkpoint carries 3Ψ of payload
        let s = state(1000);
        let bytes = write_full(&s, 1, PayloadCodec::Raw).unwrap();
        let payload = 3 * 1000 * 4;
        assert!(bytes.len() >= payload && bytes.len() < payload + 200);
    }

    #[test]
    fn wrong_model_rejected() {
        let s = state(10);
        let bytes = write_full(&s, model_signature("a", 10), PayloadCodec::Raw).unwrap();
        let err = read_full(&bytes, model_signature("b", 10)).unwrap_err().to_string();
        assert!(err.contains("different model"), "{err}");
    }

    #[test]
    fn diff_container_rejected_as_full() {
        let mut c = Container::new(CkptKind::Diff, 1, 1, 1);
        c.push("grad", vec![0; 8]);
        let bytes = c.to_bytes().unwrap();
        assert!(read_full(&bytes, 1).is_err());
    }
}
