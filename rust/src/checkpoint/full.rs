//! Full checkpoints C^F: the complete 3Ψ model state (params, adam_m,
//! adam_v) plus the step counter. Written "regularly" (Alg. 1 line 12) at
//! the tuned full-checkpoint frequency f* (§V-C).

use anyhow::{ensure, Result};

use crate::checkpoint::format::{
    encode_container_level_into, encode_delta_full_into, peek_codec, CkptKind, ContainerView,
    PayloadCodec, SectionSrc, DEFAULT_ZSTD_LEVEL,
};
use crate::optim::ModelState;
use crate::tensor::Flat;

/// Encode a model state as a full-checkpoint container.
pub fn write_full(state: &ModelState, model_sig: u64, codec: PayloadCodec) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_full_into(state, model_sig, codec, &mut out)?;
    Ok(out)
}

/// Single-pass encode into `out`: the 3Ψ state tensors are serialized
/// straight into the container (no per-section byte vectors). Returns
/// bytes appended.
pub fn write_full_into(
    state: &ModelState,
    model_sig: u64,
    codec: PayloadCodec,
    out: &mut Vec<u8>,
) -> Result<usize> {
    write_full_into_level(state, model_sig, codec, DEFAULT_ZSTD_LEVEL, out)
}

fn full_sections(state: &ModelState) -> [SectionSrc<'_>; 3] {
    [
        SectionSrc::flat("params", &state.params),
        SectionSrc::flat("adam_m", &state.m),
        SectionSrc::flat("adam_v", &state.v),
    ]
}

/// [`write_full_into`] with an explicit zstd level.
pub fn write_full_into_level(
    state: &ModelState,
    model_sig: u64,
    codec: PayloadCodec,
    zstd_level: i32,
    out: &mut Vec<u8>,
) -> Result<usize> {
    encode_container_level_into(
        CkptKind::Full,
        codec,
        zstd_level,
        model_sig,
        state.step,
        state.step,
        &full_sections(state),
        out,
    )
}

/// Encode a **delta-vs-previous** full: the 3Ψ state XOR'd against the raw
/// payload of the base full at `base_step` (held by the encoder in a
/// pooled buffer), then zstd'd. Wire codec [`PayloadCodec::DeltaFull`];
/// the header records `step_lo = base_step`, `step_hi = state.step`, so
/// recovery knows which plain full to fetch. The base must be a *plain*
/// (non-delta) full — delta chains are depth ≤ 1 by construction.
pub fn write_full_delta_into(
    state: &ModelState,
    model_sig: u64,
    base_step: u64,
    base_raw_payload: &[u8],
    zstd_level: i32,
    out: &mut Vec<u8>,
) -> Result<usize> {
    encode_delta_full_into(
        CkptKind::Full,
        zstd_level,
        model_sig,
        base_step,
        state.step,
        &full_sections(state),
        base_raw_payload,
        out,
    )
}

/// Serialize just the raw full payload (sections concatenated, no
/// container framing) — the base the delta encoder XORs against.
pub fn full_raw_payload(state: &ModelState, out: &mut Vec<u8>) {
    out.reserve(12 * state.params.len());
    for f in [&state.params, &state.m, &state.v] {
        for x in &f.0 {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Decode a full checkpoint, verifying the model signature. Rejects
/// delta-encoded fulls (use [`read_full_resolving`] when a base fetcher is
/// available).
pub fn read_full(bytes: &[u8], model_sig: u64) -> Result<ModelState> {
    read_full_view(ContainerView::parse(bytes)?, model_sig)
}

/// Decode a full checkpoint that may be delta-encoded: `fetch_base(step)`
/// returns the bytes of the plain full named by the delta header's
/// `step_lo`. Delta chains are depth ≤ 1 (the encoder only deltas against
/// plain fulls), so at most one fetch happens.
pub fn read_full_resolving(
    bytes: &[u8],
    model_sig: u64,
    fetch_base: impl FnOnce(u64) -> Result<Vec<u8>>,
) -> Result<ModelState> {
    if peek_codec(bytes)? != PayloadCodec::DeltaFull {
        return read_full(bytes, model_sig);
    }
    let (base_step, _) = crate::checkpoint::format::peek_steps(bytes)?;
    let base_bytes = fetch_base(base_step)?;
    let base = ContainerView::parse(&base_bytes)?;
    ensure!(
        base.kind == CkptKind::Full && base.codec != PayloadCodec::DeltaFull,
        "delta-full base at step {base_step} is not a plain full"
    );
    ensure!(base.model_sig == model_sig, "delta-full base from a different model");
    // the stored delta is against the base's *raw payload* (all sections
    // concatenated), which is exactly what the parsed view holds
    let mut base_payload = Vec::new();
    for (_, sec) in base.sections() {
        base_payload.extend_from_slice(sec);
    }
    read_full_view(ContainerView::parse_with_base(bytes, &base_payload)?, model_sig)
}

fn read_full_view(c: ContainerView<'_>, model_sig: u64) -> Result<ModelState> {
    ensure!(c.kind == CkptKind::Full, "not a full checkpoint: {:?}", c.kind);
    ensure!(
        c.model_sig == model_sig,
        "checkpoint belongs to a different model (sig {:#x} != {:#x})",
        c.model_sig,
        model_sig
    );
    let params = Flat::from_le_bytes(c.section("params")?);
    let m = Flat::from_le_bytes(c.section("adam_m")?);
    let v = Flat::from_le_bytes(c.section("adam_v")?);
    ensure!(params.len() == m.len() && m.len() == v.len(), "section length mismatch");
    // step_hi: == step_lo for plain fulls; the checkpointed step for
    // delta fulls (whose step_lo names the base)
    Ok(ModelState { params, m, v, step: c.step_hi })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::{model_signature, Container};
    use crate::util::rng::Rng;

    fn state(n: usize) -> ModelState {
        let mut rng = Rng::new(3);
        let mut p = vec![0f32; n];
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        rng.fill_normal_f32(&mut m);
        for x in v.iter_mut() {
            *x = rng.next_f32();
        }
        ModelState { params: Flat(p), m: Flat(m), v: Flat(v), step: 42 }
    }

    #[test]
    fn roundtrip_bit_exact() {
        let sig = model_signature("t", 100);
        let s = state(100);
        for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
            let bytes = write_full(&s, sig, codec).unwrap();
            let back = read_full(&bytes, sig).unwrap();
            assert_eq!(back, s);
        }
    }

    #[test]
    fn full_is_3psi_bytes_raw() {
        // Finding 2: full checkpoint carries 3Ψ of payload
        let s = state(1000);
        let bytes = write_full(&s, 1, PayloadCodec::Raw).unwrap();
        let payload = 3 * 1000 * 4;
        assert!(bytes.len() >= payload && bytes.len() < payload + 200);
    }

    #[test]
    fn delta_full_roundtrip_bit_exact() {
        let sig = model_signature("t", 200);
        let base = state(200);
        let mut next = base.clone();
        next.step = 50;
        for i in (0..200).step_by(7) {
            next.params.0[i] += 0.25;
            next.m.0[i] -= 0.5;
        }
        let base_bytes = write_full(&base, sig, PayloadCodec::Zstd).unwrap();
        let mut base_payload = Vec::new();
        full_raw_payload(&base, &mut base_payload);

        let mut delta = Vec::new();
        write_full_delta_into(&next, sig, base.step, &base_payload, 1, &mut delta).unwrap();
        // delta fulls are smaller than a plain zstd full of the same state
        let plain = write_full(&next, sig, PayloadCodec::Zstd).unwrap();
        assert!(delta.len() < plain.len(), "delta {} >= plain {}", delta.len(), plain.len());

        // plain read rejects; resolving read reconstructs bit-exactly
        assert!(read_full(&delta, sig).is_err());
        let back = read_full_resolving(&delta, sig, |step| {
            assert_eq!(step, base.step);
            Ok(base_bytes.clone())
        })
        .unwrap();
        assert_eq!(back, next);
        assert_eq!(back.step, 50);
    }

    #[test]
    fn read_full_resolving_passes_plain_fulls_through() {
        let sig = model_signature("t", 64);
        let s = state(64);
        let bytes = write_full(&s, sig, PayloadCodec::Raw).unwrap();
        let back =
            read_full_resolving(&bytes, sig, |_| panic!("plain full must not fetch a base"))
                .unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn wrong_model_rejected() {
        let s = state(10);
        let bytes = write_full(&s, model_signature("a", 10), PayloadCodec::Raw).unwrap();
        let err = read_full(&bytes, model_signature("b", 10)).unwrap_err().to_string();
        assert!(err.contains("different model"), "{err}");
    }

    #[test]
    fn diff_container_rejected_as_full() {
        let mut c = Container::new(CkptKind::Diff, 1, 1, 1);
        c.push("grad", vec![0; 8]);
        let bytes = c.to_bytes().unwrap();
        assert!(read_full(&bytes, 1).is_err());
    }
}
