//! Checkpoint object naming, recovery-chain discovery, and GC.
//!
//! Objects in a [`StorageBackend`](crate::storage::StorageBackend):
//! ```text
//! full-{step:012}.ldck            full checkpoint at Adam step `step`
//! diff-{step:012}.ldck            one differential for step `step`
//! batch-{lo:012}-{hi:012}.ldck    batched differentials for steps lo..=hi
//! merged-{lo:012}-{hi:012}.ldck   level-1 compacted span: the background
//!                                 chain compactor's rewrite of raw
//!                                 diff/batch objects covering steps lo..=hi
//! merged-{lo:012}-{hi:012}.l{k:02}.ldck
//!                                 level-k super-span (k ≥ 2): the
//!                                 hierarchical compactor's rewrite of
//!                                 `merge_factor` level-(k-1) spans —
//!                                 level 1 keeps the suffix-free name, so
//!                                 spans written before the hierarchy
//!                                 existed parse unchanged
//! ```
//! The recovery chain for the latest state is: the newest full checkpoint,
//! plus a **non-overlapping cover** of diff/batch/merged objects carrying
//! steps after its step (hi-based — a compacted span may straddle the
//! base full; replay skips its steps at or before the base), in step
//! order (paper Eq. (6)). Merged spans and the raw
//! objects they supersede can coexist for a moment (a crash between the
//! merged write and the raw deletes); [`select_cover`](Manifest::select_cover)
//! prefers the merged span and drops anything its range already covers. GC
//! drops objects made obsolete by a newer full checkpoint — keeping the
//! previous chain until the new full is durable (never delete the chain
//! you would recover from).
//!
//! The multi-rank cluster runtime ([`crate::cluster`]) adds more name
//! families on the same store:
//! ```text
//! gen-{g:04}/rank-{r:04}/<object>   rank r's private chain in generation g
//! gen-{g:04}/rank-{r:04}/carry-{step:012}.ldck
//!                                   reshard carry base: inline moved-in
//!                                   slices + by-interval references into
//!                                   the previous generation's bases
//! global-{g:04}-{step:012}.gck      two-phase global commit record
//! ```
//! A *generation* is one immutable namespace epoch: every elastic reshard
//! (or re-anchor after failure) bumps the generation and writes only into
//! the fresh `gen-{g+1:04}/` prefix, so committed names are never
//! overwritten in place and a crash mid-reshard trivially falls back to
//! the last committed record of the old generation.
//!
//! Flat discovery/GC ([`latest_chain`](Manifest::latest_chain),
//! [`gc`](Manifest::gc), [`truncate_after`](Manifest::truncate_after)) is
//! blind to all of them: namespaced names don't parse as checkpoint
//! objects and `.gck` is not `.ldck`. Cluster-aware discovery uses
//! [`gen_rank_chain`](Manifest::gen_rank_chain); cluster GC (which must
//! never delete anything reachable from the newest *complete* global
//! record) lives in [`crate::cluster::commit`].

use anyhow::{Context, Result};

use crate::storage::StorageBackend;

/// One recovery chain: a full checkpoint and its subsequent differentials.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Chain {
    pub full: Option<(u64, String)>,
    /// (step_lo, step_hi, object name), sorted by step_lo
    pub diffs: Vec<(u64, u64, String)>,
}

impl Chain {
    /// Latest step reconstructable from this chain.
    pub fn latest_step(&self) -> u64 {
        self.diffs
            .last()
            .map(|(_, hi, _)| *hi)
            .or(self.full.as_ref().map(|(s, _)| *s))
            .unwrap_or(0)
    }

    /// The chain's step stride — the hole-detection heuristic shared by
    /// recovery, cluster chain loading, and the compactor: the smallest
    /// spacing between *adjacent chain objects*, seeded by the
    /// base→first hop for single-object chains. The base→first hop may
    /// legitimately be shorter than the stride (a full checkpoint off the
    /// diff cadence), so it never folds into the minimum; any jump larger
    /// than the stride is treated as a hole — recovery truncates there
    /// and the compactor refuses to merge across it.
    pub fn stride(&self, base_step: u64) -> u64 {
        let mut stride = self
            .diffs
            .first()
            .map(|(lo, _, _)| lo.saturating_sub(base_step).max(1))
            .unwrap_or(1);
        if self.diffs.len() >= 2 {
            let mut adj = u64::MAX;
            for w in self.diffs.windows(2) {
                adj = adj.min(w[1].0.saturating_sub(w[0].1));
            }
            stride = adj.max(1);
        }
        stride
    }
}

/// Naming + discovery over a storage backend.
pub struct Manifest;

/// Suffix of the shard-index (commit record) object for a sharded write.
pub const SHARD_INDEX_SUFFIX: &str = ".shards";

impl Manifest {
    pub fn full_name(step: u64) -> String {
        format!("full-{step:012}.ldck")
    }

    /// Name of the commit record for a logical object written sharded.
    pub fn shard_index_name(name: &str) -> String {
        format!("{name}{SHARD_INDEX_SUFFIX}")
    }

    /// Name of shard `i` (0-based) of `n` for a logical object.
    pub fn shard_name(name: &str, i: usize, n: usize) -> String {
        format!("{name}.s{i:03}of{n:03}")
    }

    /// Logical object name if `name` is a shard-index object.
    pub fn shard_index_base(name: &str) -> Option<&str> {
        name.strip_suffix(SHARD_INDEX_SUFFIX)
    }

    /// True for physical shard artifacts (`*.sNNNofMMM` data or `*.shards`
    /// index objects) — chain discovery and GC must look through the
    /// sharded view, never treat these as checkpoint objects.
    pub fn is_shard_artifact(name: &str) -> bool {
        if name.ends_with(SHARD_INDEX_SUFFIX) {
            return true;
        }
        match name.rfind(".s") {
            Some(pos) => {
                let tail = &name[pos + 2..];
                tail.len() == 8
                    && &tail[3..5] == "of"
                    && tail[..3].bytes().all(|b| b.is_ascii_digit())
                    && tail[5..].bytes().all(|b| b.is_ascii_digit())
            }
            None => false,
        }
    }

    pub fn diff_name(step: u64) -> String {
        format!("diff-{step:012}.ldck")
    }

    pub fn batch_name(lo: u64, hi: u64) -> String {
        format!("batch-{lo:012}-{hi:012}.ldck")
    }

    /// Name of a level-1 compacted differential span covering steps
    /// `lo..=hi`.
    pub fn merged_name(lo: u64, hi: u64) -> String {
        format!("merged-{lo:012}-{hi:012}.ldck")
    }

    /// Name of a compacted span at an explicit hierarchy level. Level 1
    /// keeps the historical suffix-free name ([`merged_name`]
    /// (Manifest::merged_name)); levels ≥ 2 carry an `.l{k:02}` suffix so
    /// the replay cover can rank same-range spans without reading them.
    pub fn merged_level_name(lo: u64, hi: u64, level: u16) -> String {
        debug_assert!(level < 100, "level {level} overflows the 2-digit name suffix");
        if level <= 1 {
            Self::merged_name(lo, hi)
        } else {
            format!("merged-{lo:012}-{hi:012}.l{level:02}.ldck")
        }
    }

    /// Compaction level of a span name, looking through namespace
    /// prefixes: k for a level-k merged span (1 when the suffix is
    /// absent), 0 for raw diff/batch objects and anything else. Purely
    /// name-based — the authoritative copy lives in the span header
    /// ([`read_merged_level`](crate::checkpoint::merged::read_merged_level)),
    /// but discovery and the cover ranking must not read every object.
    pub fn span_level(name: &str) -> u16 {
        let inner = Self::parse_gen(name).map(|(_, n)| n).unwrap_or(name);
        let inner = Self::parse_rank(inner).map(|(_, n)| n).unwrap_or(inner);
        match Self::parse(inner) {
            Some(("merged", _, _)) => {}
            _ => return 0,
        }
        let stem = inner.strip_suffix(".ldck").unwrap_or(inner);
        match stem.rsplit_once(".l") {
            Some((_, lvl)) => lvl.parse().unwrap_or(1),
            None => 1,
        }
    }

    /// Name of a reshard carry base at `step`: the chain base a new
    /// generation starts from. Carries the moved-in slices inline and the
    /// retained slices as by-interval references into the previous
    /// generation's bases (see `checkpoint::carry`).
    pub fn carry_name(step: u64) -> String {
        format!("carry-{step:012}.ldck")
    }

    /// Name of the two-phase global commit record for `step` of namespace
    /// generation `gen` (cluster runtime; its presence is the commit
    /// point of a cross-rank epoch). Generation-qualified so a reshard's
    /// anchor record can never overwrite the committed record it falls
    /// back to.
    pub fn global_name(gen: u64, step: u64) -> String {
        debug_assert!(gen < 10_000, "generation {gen} overflows the 4-digit namespace");
        format!("global-{gen:04}-{step:012}.gck")
    }

    /// `(generation, step)` of a global commit record, `None` for any
    /// other name.
    pub fn parse_global(name: &str) -> Option<(u64, u64)> {
        let stem = name.strip_prefix("global-")?.strip_suffix(".gck")?;
        let (gen, step) = stem.split_once('-')?;
        if gen.len() != 4 || !gen.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        if step.len() != 12 || !step.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        Some((gen.parse().ok()?, step.parse().ok()?))
    }

    /// Namespace prefix of generation `g`. Fixed-width 4 digits, same
    /// discipline as [`rank_prefix`](Manifest::rank_prefix).
    pub fn gen_prefix(gen: u64) -> String {
        debug_assert!(gen < 10_000, "generation {gen} overflows the 4-digit namespace");
        format!("gen-{gen:04}/")
    }

    /// Object-namespace prefix of rank `r` inside generation `g` — where
    /// the cluster runtime writes every per-rank chain object.
    pub fn gen_rank_prefix(gen: u64, rank: usize) -> String {
        format!("{}{}", Self::gen_prefix(gen), Self::rank_prefix(rank))
    }

    /// Split a generation-namespaced name into `(gen, inner name)`;
    /// `None` for anything else.
    pub fn parse_gen(name: &str) -> Option<(u64, &str)> {
        let rest = name.strip_prefix("gen-")?;
        let (digits, inner) = rest.split_once('/')?;
        if digits.len() != 4 || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        Some((digits.parse().ok()?, inner))
    }

    /// Split a `gen-{g:04}/rank-{r:04}/` name into `(gen, rank, inner)`.
    pub fn parse_gen_rank(name: &str) -> Option<(u64, usize, &str)> {
        let (gen, rest) = Self::parse_gen(name)?;
        let (rank, inner) = Self::parse_rank(rest)?;
        Some((gen, rank, inner))
    }

    /// Object-namespace prefix of cluster rank `r`. The namespace is
    /// fixed-width 4 digits — [`parse_rank`](Manifest::parse_rank) rejects
    /// anything else, and the cluster runtime refuses to spawn more than
    /// 10000 ranks, so a wider prefix can never be written.
    pub fn rank_prefix(rank: usize) -> String {
        debug_assert!(rank < 10_000, "rank {rank} overflows the 4-digit namespace");
        format!("rank-{rank:04}/")
    }

    /// Split a namespaced name into `(rank, inner name)`; `None` for
    /// top-level objects.
    pub fn parse_rank(name: &str) -> Option<(usize, &str)> {
        let rest = name.strip_prefix("rank-")?;
        let (digits, inner) = rest.split_once('/')?;
        if digits.len() != 4 || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        Some((digits.parse().ok()?, inner))
    }

    /// Step range `(kind, lo, hi)` of a checkpoint object name, looking
    /// through generation- and rank-namespace prefixes if present. `None`
    /// for shard artifacts, global records, and foreign names.
    pub fn step_range(name: &str) -> Option<(&'static str, u64, u64)> {
        let inner = Self::parse_gen(name).map(|(_, n)| n).unwrap_or(name);
        let inner = Self::parse_rank(inner).map(|(_, n)| n).unwrap_or(inner);
        Self::parse(inner)
    }

    /// Namespaced discovery: rank `r`'s newest recovery chain *at or
    /// before* `cut`, from a listing of the shared store's logical names.
    /// Returned object names keep their `rank-{r:04}/` prefix, so they can
    /// be fetched directly through the same (shard-aware) view that
    /// produced the listing. Diffs strictly after `cut` — stragglers of a
    /// torn global commit — are excluded.
    pub fn rank_chain(names: &[String], rank: usize, cut: u64) -> Chain {
        Self::chain_from(
            names.iter().filter_map(|name| {
                let (r, inner) = Self::parse_rank(name)?;
                (r == rank).then_some((inner, name))
            }),
            cut,
        )
    }

    /// Generation-namespaced discovery: rank `r`'s newest recovery chain
    /// at or before `cut` *within generation `gen`* — chains never span
    /// generations through name discovery; a carry base references the
    /// previous generation explicitly (see `checkpoint::carry`).
    pub fn gen_rank_chain(names: &[String], gen: u64, rank: usize, cut: u64) -> Chain {
        Self::chain_from(
            names.iter().filter_map(|name| {
                let (g, r, inner) = Self::parse_gen_rank(name)?;
                (g == gen && r == rank).then_some((inner, name))
            }),
            cut,
        )
    }

    /// Shared chain assembly over `(inner name, full name)` pairs: newest
    /// base (full *or* carry) at or before `cut`, plus a non-overlapping
    /// cover of diff/batch/merged objects after it.
    fn chain_from<'a>(names: impl Iterator<Item = (&'a str, &'a String)>, cut: u64) -> Chain {
        let mut fulls: Vec<(u64, String)> = Vec::new();
        let mut diffs: Vec<(u64, u64, String)> = Vec::new();
        for (inner, name) in names {
            match Self::parse(inner) {
                Some(("full", step, _)) | Some(("carry", step, _)) if step <= cut => {
                    fulls.push((step, name.clone()))
                }
                Some(("diff", lo, hi)) | Some(("batch", lo, hi)) | Some(("merged", lo, hi))
                    if hi <= cut =>
                {
                    diffs.push((lo, hi, name.clone()))
                }
                _ => {}
            }
        }
        fulls.sort();
        let full = fulls.last().cloned();
        let base = full.as_ref().map(|(s, _)| *s).unwrap_or(0);
        // hi-based: a merged/batch span can STRADDLE the base full (the
        // compactor ran before a mid-chain full became visible); it still
        // carries the live steps after the base, so it stays in the chain
        // and replay skips the steps at or before the base
        diffs.retain(|(_, hi, _)| *hi > base);
        Chain { full, diffs: Self::select_cover(diffs) }
    }

    fn parse(name: &str) -> Option<(&'static str, u64, u64)> {
        let stem = name.strip_suffix(".ldck")?;
        if let Some(s) = stem.strip_prefix("full-") {
            let step = s.parse().ok()?;
            Some(("full", step, step))
        } else if let Some(s) = stem.strip_prefix("carry-") {
            let step = s.parse().ok()?;
            Some(("carry", step, step))
        } else if let Some(s) = stem.strip_prefix("diff-") {
            let step = s.parse().ok()?;
            Some(("diff", step, step))
        } else if let Some(s) = stem.strip_prefix("batch-") {
            let (lo, hi) = s.split_once('-')?;
            Some(("batch", lo.parse().ok()?, hi.parse().ok()?))
        } else if let Some(s) = stem.strip_prefix("merged-") {
            // optional hierarchy suffix: `{lo}-{hi}` (level 1) or
            // `{lo}-{hi}.l{k:02}` (level k ≥ 2)
            let range = match s.rsplit_once(".l") {
                Some((range, lvl)) => {
                    if lvl.len() != 2 || !lvl.bytes().all(|b| b.is_ascii_digit()) {
                        return None;
                    }
                    range
                }
                None => s,
            };
            let (lo, hi) = range.split_once('-')?;
            Some(("merged", lo.parse().ok()?, hi.parse().ok()?))
        } else {
            None
        }
    }

    /// Choose a non-overlapping replay cover from (possibly redundant)
    /// differential objects. A crash between the compactor's merged write
    /// and its raw deletes leaves both the merged span and (some of) the
    /// raw objects it supersedes on the store; the cover prefers the
    /// longest span starting earliest and drops anything whose range is
    /// already covered. With the compaction hierarchy the same crash
    /// window exists at every level — a level-(k+1) super-span can coexist
    /// with the level-k spans (and raws) it supersedes — so at equal range
    /// the higher level wins (it is the newer rewrite; both replay
    /// bit-identically, but GC retires the lower one). Plain chains
    /// (strictly increasing, disjoint objects) pass through unchanged.
    pub fn select_cover(mut diffs: Vec<(u64, u64, String)>) -> Vec<(u64, u64, String)> {
        diffs.sort_by(|a, b| {
            a.0.cmp(&b.0)
                .then(b.1.cmp(&a.1))
                .then(Self::span_level(&b.2).cmp(&Self::span_level(&a.2)))
                .then(a.2.cmp(&b.2))
        });
        let mut out: Vec<(u64, u64, String)> = Vec::with_capacity(diffs.len());
        for d in diffs {
            match out.last() {
                Some(prev) if d.0 <= prev.1 => {} // redundant: range already covered
                _ => out.push(d),
            }
        }
        out
    }

    /// Discover the newest recovery chain on a backend.
    pub fn latest_chain(store: &dyn StorageBackend) -> Result<Chain> {
        let mut fulls: Vec<(u64, String)> = Vec::new();
        let mut diffs: Vec<(u64, u64, String)> = Vec::new();
        for name in store.list().context("listing checkpoint store")? {
            match Self::parse(&name) {
                Some(("full", step, _)) => fulls.push((step, name)),
                Some(("diff", lo, hi)) | Some(("batch", lo, hi)) | Some(("merged", lo, hi)) => {
                    diffs.push((lo, hi, name))
                }
                _ => {}
            }
        }
        fulls.sort();
        let full = fulls.last().cloned();
        let base = full.as_ref().map(|(s, _)| *s).unwrap_or(0);
        // hi-based so spans straddling the base full stay live (see
        // `rank_chain`); replay filters out their steps <= base
        diffs.retain(|(_, hi, _)| *hi > base);
        Ok(Chain { full, diffs: Self::select_cover(diffs) })
    }

    /// True for names the flat manifest must NEVER touch: anything under a
    /// generation or cluster rank namespace and global commit records.
    /// Flat GC and truncation are *blind* to the cluster runtime's
    /// objects — deleting them would hole a per-rank chain a committed
    /// global record still references. `parse()` already fails on these
    /// names today; this guard makes the invariant explicit (and
    /// future-proof against new name families parsing accidentally).
    fn is_cluster_name(name: &str) -> bool {
        Self::parse_gen(name).is_some()
            || Self::parse_rank(name).is_some()
            || Self::parse_global(name).is_some()
    }

    /// Delete every diff/batch/merged object covering steps strictly after
    /// `step` — they belong to a timeline lost to a failure (the run was
    /// rolled back to `step`) and must not pollute future recoveries.
    pub fn truncate_after(store: &dyn StorageBackend, step: u64) -> Result<usize> {
        let mut removed = 0;
        for name in store.list()? {
            if Self::is_cluster_name(&name) {
                continue; // rank-namespaced chains are the cluster GC's
            }
            if let Some((kind, lo, _)) = Self::parse(&name) {
                if kind != "full" && lo > step {
                    store.delete(&name)?;
                    removed += 1;
                }
            }
        }
        Ok(removed)
    }

    /// Delete every object made obsolete by the newest full checkpoint:
    /// older fulls and all differentials whose entire step range lies at
    /// or before its step (hi-based, matching discovery: a span straddling
    /// the newest full still carries live steps and must survive). Returns
    /// the number of objects removed.
    pub fn gc(store: &dyn StorageBackend) -> Result<usize> {
        let mut fulls: Vec<(u64, String)> = Vec::new();
        let mut others: Vec<(u64, String)> = Vec::new();
        for name in store.list()? {
            if Self::is_cluster_name(&name) {
                continue; // never collect under a rank namespace
            }
            match Self::parse(&name) {
                Some(("full", step, _)) => fulls.push((step, name)),
                Some((_, _, hi)) => others.push((hi, name)),
                _ => {}
            }
        }
        fulls.sort();
        let Some((newest, newest_name)) = fulls.last().cloned() else {
            return Ok(0);
        };
        // a delta-encoded full (`PayloadCodec::DeltaFull`) replays through
        // its plain base full: pin that base so GC never strands the chain
        // it would recover from. One header peek of the newest full; delta
        // depth is ≤ 1, so one pin always suffices.
        let pinned_base: Option<u64> = store
            .get(&newest_name)
            .ok()
            .filter(|b| {
                crate::checkpoint::format::peek_codec(b).ok()
                    == Some(crate::checkpoint::format::PayloadCodec::DeltaFull)
            })
            .and_then(|b| crate::checkpoint::format::peek_steps(&b).ok())
            .map(|(base, _)| base);
        let mut removed = 0;
        for (step, name) in fulls.iter().take(fulls.len() - 1) {
            if Some(*step) == pinned_base {
                continue; // the delta full's base stays live
            }
            store.delete(name)?;
            removed += 1;
        }
        for (hi, name) in others {
            if hi <= newest {
                store.delete(&name)?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    #[test]
    fn names_sort_numerically() {
        assert!(Manifest::full_name(9) < Manifest::full_name(10));
        assert!(Manifest::diff_name(99) < Manifest::diff_name(100));
    }

    #[test]
    fn chain_discovery_orders_and_filters() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(10), b"f").unwrap();
        s.put(&Manifest::full_name(20), b"f").unwrap();
        s.put(&Manifest::diff_name(15), b"d").unwrap(); // obsolete (< full 20)
        s.put(&Manifest::diff_name(21), b"d").unwrap();
        s.put(&Manifest::batch_name(22, 25), b"b").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain.full.as_ref().unwrap().0, 20);
        assert_eq!(
            chain.diffs,
            vec![
                (21, 21, Manifest::diff_name(21)),
                (22, 25, Manifest::batch_name(22, 25)),
            ]
        );
        assert_eq!(chain.latest_step(), 25);
    }

    #[test]
    fn chain_with_no_checkpoints_is_empty() {
        let s = MemStore::new();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain, Chain::default());
        assert_eq!(chain.latest_step(), 0);
    }

    #[test]
    fn gc_keeps_live_chain_only() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(10), b"f").unwrap();
        s.put(&Manifest::diff_name(11), b"d").unwrap();
        s.put(&Manifest::full_name(20), b"f").unwrap();
        s.put(&Manifest::diff_name(20), b"d").unwrap(); // <= 20: obsolete
        s.put(&Manifest::diff_name(21), b"d").unwrap(); // live
        let removed = Manifest::gc(&s).unwrap();
        assert_eq!(removed, 3);
        assert_eq!(
            s.list().unwrap(),
            vec![Manifest::diff_name(21), Manifest::full_name(20)]
        );
    }

    #[test]
    fn gc_noop_without_full() {
        let s = MemStore::new();
        s.put(&Manifest::diff_name(5), b"d").unwrap();
        assert_eq!(Manifest::gc(&s).unwrap(), 0);
    }

    #[test]
    fn truncate_after_drops_lost_timeline() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(8), b"f").unwrap();
        s.put(&Manifest::diff_name(9), b"d").unwrap(); // <= 9: keep
        s.put(&Manifest::diff_name(10), b"d").unwrap(); // > 9: lost timeline
        s.put(&Manifest::batch_name(10, 12), b"b").unwrap();
        let removed = Manifest::truncate_after(&s, 9).unwrap();
        assert_eq!(removed, 2);
        assert_eq!(
            s.list().unwrap(),
            vec![Manifest::diff_name(9), Manifest::full_name(8)]
        );
    }

    #[test]
    fn unknown_objects_ignored() {
        let s = MemStore::new();
        s.put("random.bin", b"x").unwrap();
        s.put(&Manifest::full_name(1), b"f").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain.full.as_ref().unwrap().0, 1);
    }

    #[test]
    fn shard_names_roundtrip_and_classify() {
        let base = Manifest::diff_name(7);
        let idx = Manifest::shard_index_name(&base);
        assert_eq!(Manifest::shard_index_base(&idx), Some(base.as_str()));
        assert!(Manifest::is_shard_artifact(&idx));
        assert!(Manifest::is_shard_artifact(&Manifest::shard_name(&base, 2, 4)));
        assert!(!Manifest::is_shard_artifact(&base));
        assert!(!Manifest::is_shard_artifact("random.bin"));
        assert!(!Manifest::is_shard_artifact("x.s12of4")); // malformed widths
    }

    #[test]
    fn global_and_rank_names_parse() {
        assert_eq!(Manifest::global_name(2, 7), "global-0002-000000000007.gck");
        assert_eq!(Manifest::parse_global(&Manifest::global_name(2, 7)), Some((2, 7)));
        assert_eq!(Manifest::parse_global("global-xx.gck"), None);
        assert_eq!(Manifest::parse_global("global-000000000007.gck"), None, "legacy un-gen'd");
        assert_eq!(Manifest::parse_global(&Manifest::full_name(7)), None);
        assert_eq!(Manifest::rank_prefix(3), "rank-0003/");
        let name = format!("{}{}", Manifest::rank_prefix(12), Manifest::diff_name(5));
        assert_eq!(Manifest::parse_rank(&name), Some((12, Manifest::diff_name(5).as_str())));
        assert_eq!(Manifest::parse_rank("rank-12/x"), None, "width must be 4");
        assert_eq!(Manifest::parse_rank("full-000000000001.ldck"), None);
        assert_eq!(Manifest::step_range(&name), Some(("diff", 5, 5)));
        assert_eq!(Manifest::step_range(&Manifest::batch_name(2, 4)), Some(("batch", 2, 4)));
        assert_eq!(Manifest::step_range(&Manifest::global_name(0, 1)), None);
    }

    #[test]
    fn generation_names_parse() {
        assert_eq!(Manifest::gen_prefix(3), "gen-0003/");
        assert_eq!(Manifest::gen_rank_prefix(3, 12), "gen-0003/rank-0012/");
        let name = format!("{}{}", Manifest::gen_rank_prefix(3, 12), Manifest::carry_name(5));
        assert_eq!(Manifest::parse_gen(&name), Some((3, "rank-0012/carry-000000000005.ldck")));
        assert_eq!(
            Manifest::parse_gen_rank(&name),
            Some((3, 12, Manifest::carry_name(5).as_str()))
        );
        assert_eq!(Manifest::step_range(&name), Some(("carry", 5, 5)));
        assert_eq!(Manifest::parse_rank(&name), None, "gen names are not rank names");
        assert_eq!(Manifest::parse_gen("gen-12/x"), None, "width must be 4");
        assert_eq!(Manifest::parse_gen("gen-0001x"), None, "missing separator");
        assert_eq!(Manifest::parse_gen_rank("gen-0001/full-000000000001.ldck"), None);
    }

    #[test]
    fn name_families_are_mutually_exclusive_property() {
        // satellite: flat GC can never see a generation name, generation
        // discovery can never see a flat one — each generated name parses
        // under exactly one family classifier.
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check("manifest_name_family_exclusive", 128, |rng| {
            let step = rng.next_u64() % 1_000_000;
            let hi = step + rng.next_u64() % 100;
            let gen = rng.next_u64() % 10_000;
            let rank = (rng.next_u64() % 10_000) as usize;
            let obj = match rng.range(0, 6) {
                0 => Manifest::full_name(step),
                1 => Manifest::diff_name(step),
                2 => Manifest::batch_name(step, hi),
                3 => Manifest::merged_name(step, hi),
                4 => Manifest::merged_level_name(step, hi, 2 + (rng.next_u64() % 8) as u16),
                _ => Manifest::carry_name(step),
            };
            let name = match rng.range(0, 4) {
                0 => obj.clone(),
                1 => format!("{}{obj}", Manifest::rank_prefix(rank)),
                2 => format!("{}{obj}", Manifest::gen_rank_prefix(gen, rank)),
                _ => Manifest::global_name(gen, step),
            };
            let classes = [
                Manifest::parse(&name).is_some(),
                Manifest::parse_rank(&name).is_some(),
                Manifest::parse_gen(&name).is_some(),
                Manifest::parse_global(&name).is_some(),
            ];
            let hits = classes.iter().filter(|c| **c).count();
            prop_assert!(hits == 1);
            // and the namespaced classifiers agree on their payloads
            if let Some((g, rest)) = Manifest::parse_gen(&name) {
                prop_assert!(g == gen);
                prop_assert!(Manifest::parse_rank(rest).is_some());
                prop_assert!(Manifest::parse_gen_rank(&name).is_some());
            }
            if let Some((g, s)) = Manifest::parse_global(&name) {
                prop_assert!(g == gen && s == step);
            }
            Ok(())
        });
    }

    #[test]
    fn flat_discovery_and_gc_ignore_cluster_objects() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(4), b"f").unwrap();
        s.put(&Manifest::global_name(0, 9), b"g").unwrap();
        let ns_full = format!("{}{}", Manifest::rank_prefix(0), Manifest::full_name(9));
        s.put(&ns_full, b"nf").unwrap();
        let gen_full = format!("{}{}", Manifest::gen_rank_prefix(1, 0), Manifest::full_name(9));
        s.put(&gen_full, b"gf").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain.full.as_ref().unwrap().0, 4, "cluster names are invisible");
        assert_eq!(Manifest::gc(&s).unwrap(), 0);
        assert_eq!(Manifest::truncate_after(&s, 0).unwrap(), 0);
        assert!(s.exists(&ns_full) && s.exists(&gen_full) && s.exists(&Manifest::global_name(0, 9)));
    }

    #[test]
    fn rank_chain_filters_namespace_and_cut() {
        let ns = |r: usize, n: String| format!("{}{n}", Manifest::rank_prefix(r));
        let names = vec![
            ns(1, Manifest::full_name(0)),
            ns(1, Manifest::full_name(4)),
            ns(1, Manifest::diff_name(3)), // obsolete (< full 4)
            ns(1, Manifest::diff_name(5)),
            ns(1, Manifest::diff_name(6)),
            ns(1, Manifest::diff_name(7)), // beyond the cut: straggler
            ns(2, Manifest::diff_name(5)), // other rank
            Manifest::global_name(0, 6),   // top level
        ];
        let chain = Manifest::rank_chain(&names, 1, 6);
        assert_eq!(chain.full.as_ref().unwrap().0, 4);
        assert_eq!(
            chain.diffs,
            vec![
                (5, 5, ns(1, Manifest::diff_name(5))),
                (6, 6, ns(1, Manifest::diff_name(6))),
            ]
        );
        assert_eq!(chain.latest_step(), 6);
        // a cut before the newest full falls back to the older full
        let older = Manifest::rank_chain(&names, 1, 3);
        assert_eq!(older.full.as_ref().unwrap().0, 0);
        assert_eq!(older.diffs, vec![(3, 3, ns(1, Manifest::diff_name(3)))]);
        // unknown rank: empty chain
        assert_eq!(Manifest::rank_chain(&names, 7, 6), Chain::default());
    }

    #[test]
    fn gen_rank_chain_scopes_generation_and_accepts_carry_bases() {
        let gns = |g: u64, r: usize, n: String| format!("{}{n}", Manifest::gen_rank_prefix(g, r));
        let names = vec![
            gns(1, 0, Manifest::carry_name(4)), // generation 1's base
            gns(1, 0, Manifest::merged_name(5, 8)),
            gns(1, 0, Manifest::diff_name(9)), // beyond the cut
            gns(0, 0, Manifest::full_name(4)), // previous generation
            gns(0, 0, Manifest::diff_name(5)),
            gns(1, 1, Manifest::carry_name(4)), // other rank
            format!("{}{}", Manifest::rank_prefix(0), Manifest::full_name(4)), // legacy flat rank
        ];
        let chain = Manifest::gen_rank_chain(&names, 1, 0, 8);
        assert_eq!(chain.full, Some((4, gns(1, 0, Manifest::carry_name(4)))));
        assert_eq!(chain.diffs, vec![(5, 8, gns(1, 0, Manifest::merged_name(5, 8)))]);
        assert_eq!(chain.latest_step(), 8);
        // a full at the same step outranks the carry (it is self-contained)
        let mut with_full = names.clone();
        with_full.push(gns(1, 0, Manifest::full_name(4)));
        let chain = Manifest::gen_rank_chain(&with_full, 1, 0, 8);
        assert_eq!(chain.full, Some((4, gns(1, 0, Manifest::full_name(4)))));
        // other generations are invisible
        let old = Manifest::gen_rank_chain(&names, 0, 0, 8);
        assert_eq!(old.full, Some((4, gns(0, 0, Manifest::full_name(4)))));
        assert_eq!(old.diffs, vec![(5, 5, gns(0, 0, Manifest::diff_name(5)))]);
    }

    #[test]
    fn merged_names_parse_and_discover() {
        assert_eq!(Manifest::merged_name(2, 5), "merged-000000000002-000000000005.ldck");
        assert_eq!(
            Manifest::step_range(&Manifest::merged_name(2, 5)),
            Some(("merged", 2, 5))
        );
        let s = MemStore::new();
        s.put(&Manifest::full_name(0), b"f").unwrap();
        s.put(&Manifest::merged_name(1, 4), b"m").unwrap();
        s.put(&Manifest::diff_name(5), b"d").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(
            chain.diffs,
            vec![
                (1, 4, Manifest::merged_name(1, 4)),
                (5, 5, Manifest::diff_name(5)),
            ]
        );
        assert_eq!(chain.latest_step(), 5);
    }

    #[test]
    fn select_cover_prefers_merged_spans_over_covered_raws() {
        // crash between the merged write and the raw deletes: both coexist
        let diffs = vec![
            (3, 3, Manifest::diff_name(3)),
            (1, 4, Manifest::merged_name(1, 4)),
            (1, 1, Manifest::diff_name(1)),
            (5, 5, Manifest::diff_name(5)),
            (2, 2, Manifest::diff_name(2)),
        ];
        let cover = Manifest::select_cover(diffs);
        assert_eq!(
            cover,
            vec![
                (1, 4, Manifest::merged_name(1, 4)),
                (5, 5, Manifest::diff_name(5)),
            ]
        );
        // plain chains pass through unchanged (just sorted)
        let plain = vec![
            (2, 2, Manifest::diff_name(2)),
            (1, 1, Manifest::diff_name(1)),
        ];
        assert_eq!(
            Manifest::select_cover(plain),
            vec![
                (1, 1, Manifest::diff_name(1)),
                (2, 2, Manifest::diff_name(2)),
            ]
        );
    }

    #[test]
    fn leveled_merged_names_parse_and_rank() {
        assert_eq!(Manifest::merged_level_name(2, 5, 1), Manifest::merged_name(2, 5));
        let l3 = Manifest::merged_level_name(2, 17, 3);
        assert_eq!(l3, "merged-000000000002-000000000017.l03.ldck");
        assert_eq!(Manifest::step_range(&l3), Some(("merged", 2, 17)));
        assert_eq!(Manifest::span_level(&l3), 3);
        assert_eq!(Manifest::span_level(&Manifest::merged_name(2, 5)), 1);
        assert_eq!(Manifest::span_level(&Manifest::diff_name(5)), 0);
        assert_eq!(Manifest::span_level(&Manifest::batch_name(2, 5)), 0);
        assert_eq!(Manifest::span_level("random.bin"), 0);
        assert!(!Manifest::is_shard_artifact(&l3));
        // namespaced spans rank the same
        let ns = format!("{}{l3}", Manifest::gen_rank_prefix(1, 2));
        assert_eq!(Manifest::step_range(&ns), Some(("merged", 2, 17)));
        assert_eq!(Manifest::span_level(&ns), 3);
        // malformed level suffixes are not merged spans at all
        assert_eq!(Manifest::step_range("merged-000000000002-000000000005.l3.ldck"), None);
        assert_eq!(Manifest::step_range("merged-000000000002-000000000005.lxx.ldck"), None);
    }

    #[test]
    fn select_cover_prefers_higher_levels_and_stays_disjoint() {
        // crash mid-hierarchy: the level-2 super-span coexists with the
        // level-1 spans and raw diffs it supersedes; one cover, no overlap
        let diffs = vec![
            (1, 4, Manifest::merged_name(1, 4)),
            (1, 8, Manifest::merged_level_name(1, 8, 2)),
            (5, 8, Manifest::merged_name(5, 8)),
            (3, 3, Manifest::diff_name(3)),
            (9, 9, Manifest::diff_name(9)),
        ];
        let cover = Manifest::select_cover(diffs);
        assert_eq!(
            cover,
            vec![
                (1, 8, Manifest::merged_level_name(1, 8, 2)),
                (9, 9, Manifest::diff_name(9)),
            ]
        );
        // at an IDENTICAL range the higher level wins (newer rewrite)
        let tied = vec![
            (1, 4, Manifest::merged_name(1, 4)),
            (1, 4, Manifest::merged_level_name(1, 4, 2)),
        ];
        assert_eq!(
            Manifest::select_cover(tied),
            vec![(1, 4, Manifest::merged_level_name(1, 4, 2))]
        );
    }

    #[test]
    fn select_cover_adversarial_property() {
        // satellite: overlapping spans at mixed levels, crash leftovers,
        // and junk cut points — the chosen cover must always be
        // non-overlapping, cover every step some candidate covers (no step
        // silently lost), and be minimal (no object whose range the rest
        // of the cover already provides).
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check("select_cover_adversarial", 256, |rng| {
            let mf = rng.range(2, 5) as u64;
            let n_steps = rng.range(1, 60) as u64;
            let mut cands: Vec<(u64, u64, String)> = Vec::new();
            // raw diffs, some missing (compacted away)
            for s in 1..=n_steps {
                if rng.next_f64() < 0.7 {
                    cands.push((s, s, Manifest::diff_name(s)));
                }
            }
            // the hierarchy's aligned spans: level k covers mf^k steps.
            // Crash leftovers = any subset may coexist with any other —
            // exactly the nested/disjoint shapes raced compaction leaves
            let mut span = mf;
            for level in 1..=3u16 {
                let mut lo = 1;
                while lo + span - 1 <= n_steps {
                    if rng.next_f64() < 0.5 {
                        let hi = lo + span - 1;
                        cands.push((hi - span + 1, hi, Manifest::merged_level_name(lo, hi, level)));
                    }
                    lo += span;
                }
                span *= mf;
            }
            let cover = Manifest::select_cover(cands.clone());
            // non-overlapping and ordered
            for w in cover.windows(2) {
                prop_assert!(w[0].1 < w[1].0);
            }
            // every step covered by SOME candidate that extends past the
            // cover's frontier is reachable through the cover: the cover's
            // high watermark must reach the candidates' maximum hi
            let max_hi = cands.iter().map(|c| c.1).max().unwrap_or(0);
            if let Some(last) = cover.last() {
                prop_assert!(last.1 == max_hi);
            } else {
                prop_assert!(cands.is_empty());
            }
            // minimal: dropping any element must lose at least one covered
            // step (no element is fully contained in the union of others)
            for i in 0..cover.len() {
                let covered_elsewhere = cover
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .any(|(_, c)| c.0 <= cover[i].0 && cover[i].1 <= c.1);
                prop_assert!(!covered_elsewhere);
            }
            Ok(())
        });
    }

    #[test]
    fn straddling_merged_span_is_discovered_and_kept() {
        // the async-engine race: a span compacted before a mid-chain full
        // became visible straddles the base; it carries the live steps
        // 5..6 and must stay in the chain and survive GC
        let s = MemStore::new();
        s.put(&Manifest::merged_name(3, 6), b"m").unwrap();
        s.put(&Manifest::full_name(4), b"f").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain.full.as_ref().unwrap().0, 4);
        assert_eq!(chain.diffs, vec![(3, 6, Manifest::merged_name(3, 6))]);
        assert_eq!(chain.latest_step(), 6);
        assert_eq!(Manifest::gc(&s).unwrap(), 0, "live straddling span must survive GC");
    }

    #[test]
    fn gc_collects_merged_spans_below_the_newest_full() {
        let s = MemStore::new();
        s.put(&Manifest::merged_name(1, 4), b"m").unwrap();
        s.put(&Manifest::full_name(4), b"f").unwrap();
        s.put(&Manifest::merged_name(5, 8), b"m").unwrap();
        let removed = Manifest::gc(&s).unwrap();
        assert_eq!(removed, 1, "only the superseded span goes");
        assert_eq!(
            s.list().unwrap(),
            vec![Manifest::full_name(4), Manifest::merged_name(5, 8)]
        );
        assert_eq!(Manifest::truncate_after(&s, 4).unwrap(), 1, "lost-timeline merged span");
    }

    #[test]
    fn flat_gc_and_truncate_never_touch_rank_namespaces_regression() {
        // PR-3 noted gap, now an explicit guard: whatever lives under a
        // rank namespace (including names whose inner part parses as a
        // perfectly ordinary checkpoint object) must survive flat GC and
        // flat truncation — those chains belong to the cluster runtime.
        let s = MemStore::new();
        let ns = |r: usize, n: String| format!("{}{n}", Manifest::rank_prefix(r));
        let cluster_objects = vec![
            ns(0, Manifest::full_name(1)),       // older than the flat full
            ns(0, Manifest::diff_name(2)),       // "obsolete" step
            ns(3, Manifest::batch_name(2, 6)),   // spans the flat full step
            ns(3, Manifest::merged_name(7, 9)),  // beyond the flat timeline
            Manifest::global_name(0, 9),         // commit record
            format!("{}{}", Manifest::gen_rank_prefix(2, 0), Manifest::carry_name(4)),
            format!("{}{}", Manifest::gen_rank_prefix(2, 0), Manifest::diff_name(2)),
        ];
        for name in &cluster_objects {
            s.put(name, b"cluster").unwrap();
        }
        s.put(&Manifest::full_name(2), b"old-full").unwrap();
        s.put(&Manifest::full_name(5), b"new-full").unwrap();
        s.put(&Manifest::diff_name(3), b"obsolete").unwrap();
        s.put(&Manifest::diff_name(7), b"lost-timeline").unwrap();

        let removed = Manifest::gc(&s).unwrap();
        assert_eq!(removed, 2, "old flat full + obsolete flat diff only");
        let removed = Manifest::truncate_after(&s, 5).unwrap();
        assert_eq!(removed, 1, "flat lost-timeline diff only");
        for name in &cluster_objects {
            assert!(s.exists(name), "flat GC/truncate deleted cluster object {name}");
        }
    }

    #[test]
    fn gc_pins_the_base_of_a_delta_encoded_newest_full() {
        use crate::checkpoint::format::{model_signature, DEFAULT_ZSTD_LEVEL};
        use crate::checkpoint::full::{full_raw_payload, write_full, write_full_delta_into};
        use crate::checkpoint::format::PayloadCodec;
        use crate::optim::ModelState;
        use crate::tensor::Flat;
        let sig = model_signature("t", 16);
        let base = ModelState::new(Flat(vec![1.0; 16]));
        let mut mid = base.clone();
        mid.step = 2;
        let mut tip = base.clone();
        tip.step = 4;
        tip.params.0[3] = 9.0;
        let s = MemStore::new();
        s.put(&Manifest::full_name(0), &write_full(&base, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        s.put(&Manifest::full_name(2), &write_full(&mid, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        let mut payload = Vec::new();
        full_raw_payload(&base, &mut payload);
        let mut delta = Vec::new();
        write_full_delta_into(&tip, sig, 0, &payload, DEFAULT_ZSTD_LEVEL, &mut delta).unwrap();
        s.put(&Manifest::full_name(4), &delta).unwrap();
        s.put(&Manifest::diff_name(3), b"d").unwrap(); // superseded
        let removed = Manifest::gc(&s).unwrap();
        assert_eq!(removed, 2, "mid full + stale diff; the @0 base is pinned");
        let left = s.list().unwrap();
        assert!(left.contains(&Manifest::full_name(0)), "{left:?}");
        assert!(left.contains(&Manifest::full_name(4)), "{left:?}");
        assert!(!left.contains(&Manifest::full_name(2)), "{left:?}");
    }

    #[test]
    fn chain_discovery_skips_shard_artifacts() {
        // a raw inner store holds shard data + index objects; discovery on
        // it must not mistake them for checkpoint objects
        let s = MemStore::new();
        let full = Manifest::full_name(3);
        s.put(&Manifest::shard_name(&full, 0, 2), b"a").unwrap();
        s.put(&Manifest::shard_name(&full, 1, 2), b"b").unwrap();
        s.put(&Manifest::shard_index_name(&full), b"i").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert!(chain.full.is_none(), "shard artifacts are not logical objects");
    }
}
