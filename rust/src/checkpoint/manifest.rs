//! Checkpoint object naming, recovery-chain discovery, and GC.
//!
//! Objects in a [`StorageBackend`](crate::storage::StorageBackend):
//! ```text
//! full-{step:012}.ldck          full checkpoint at Adam step `step`
//! diff-{step:012}.ldck          one differential for step `step`
//! batch-{lo:012}-{hi:012}.ldck  batched differentials for steps lo..=hi
//! ```
//! The recovery chain for the latest state is: the newest full checkpoint,
//! plus every diff/batch object strictly after its step, in step order
//! (paper Eq. (6)). GC drops objects made obsolete by a newer full
//! checkpoint — keeping the previous chain until the new full is durable
//! (never delete the chain you would recover from).

use anyhow::{Context, Result};

use crate::storage::StorageBackend;

/// One recovery chain: a full checkpoint and its subsequent differentials.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Chain {
    pub full: Option<(u64, String)>,
    /// (step_lo, step_hi, object name), sorted by step_lo
    pub diffs: Vec<(u64, u64, String)>,
}

impl Chain {
    /// Latest step reconstructable from this chain.
    pub fn latest_step(&self) -> u64 {
        self.diffs
            .last()
            .map(|(_, hi, _)| *hi)
            .or(self.full.as_ref().map(|(s, _)| *s))
            .unwrap_or(0)
    }
}

/// Naming + discovery over a storage backend.
pub struct Manifest;

/// Suffix of the shard-index (commit record) object for a sharded write.
pub const SHARD_INDEX_SUFFIX: &str = ".shards";

impl Manifest {
    pub fn full_name(step: u64) -> String {
        format!("full-{step:012}.ldck")
    }

    /// Name of the commit record for a logical object written sharded.
    pub fn shard_index_name(name: &str) -> String {
        format!("{name}{SHARD_INDEX_SUFFIX}")
    }

    /// Name of shard `i` (0-based) of `n` for a logical object.
    pub fn shard_name(name: &str, i: usize, n: usize) -> String {
        format!("{name}.s{i:03}of{n:03}")
    }

    /// Logical object name if `name` is a shard-index object.
    pub fn shard_index_base(name: &str) -> Option<&str> {
        name.strip_suffix(SHARD_INDEX_SUFFIX)
    }

    /// True for physical shard artifacts (`*.sNNNofMMM` data or `*.shards`
    /// index objects) — chain discovery and GC must look through the
    /// sharded view, never treat these as checkpoint objects.
    pub fn is_shard_artifact(name: &str) -> bool {
        if name.ends_with(SHARD_INDEX_SUFFIX) {
            return true;
        }
        match name.rfind(".s") {
            Some(pos) => {
                let tail = &name[pos + 2..];
                tail.len() == 8
                    && &tail[3..5] == "of"
                    && tail[..3].bytes().all(|b| b.is_ascii_digit())
                    && tail[5..].bytes().all(|b| b.is_ascii_digit())
            }
            None => false,
        }
    }

    pub fn diff_name(step: u64) -> String {
        format!("diff-{step:012}.ldck")
    }

    pub fn batch_name(lo: u64, hi: u64) -> String {
        format!("batch-{lo:012}-{hi:012}.ldck")
    }

    fn parse(name: &str) -> Option<(&'static str, u64, u64)> {
        let stem = name.strip_suffix(".ldck")?;
        if let Some(s) = stem.strip_prefix("full-") {
            let step = s.parse().ok()?;
            Some(("full", step, step))
        } else if let Some(s) = stem.strip_prefix("diff-") {
            let step = s.parse().ok()?;
            Some(("diff", step, step))
        } else if let Some(s) = stem.strip_prefix("batch-") {
            let (lo, hi) = s.split_once('-')?;
            Some(("batch", lo.parse().ok()?, hi.parse().ok()?))
        } else {
            None
        }
    }

    /// Discover the newest recovery chain on a backend.
    pub fn latest_chain(store: &dyn StorageBackend) -> Result<Chain> {
        let mut fulls: Vec<(u64, String)> = Vec::new();
        let mut diffs: Vec<(u64, u64, String)> = Vec::new();
        for name in store.list().context("listing checkpoint store")? {
            match Self::parse(&name) {
                Some(("full", step, _)) => fulls.push((step, name)),
                Some(("diff", lo, hi)) | Some(("batch", lo, hi)) => {
                    diffs.push((lo, hi, name))
                }
                _ => {}
            }
        }
        fulls.sort();
        let full = fulls.last().cloned();
        let base = full.as_ref().map(|(s, _)| *s).unwrap_or(0);
        diffs.retain(|(lo, _, _)| *lo > base);
        diffs.sort();
        Ok(Chain { full, diffs })
    }

    /// Delete every diff/batch object covering steps strictly after
    /// `step` — they belong to a timeline lost to a failure (the run was
    /// rolled back to `step`) and must not pollute future recoveries.
    pub fn truncate_after(store: &dyn StorageBackend, step: u64) -> Result<usize> {
        let mut removed = 0;
        for name in store.list()? {
            if let Some((kind, lo, _)) = Self::parse(&name) {
                if kind != "full" && lo > step {
                    store.delete(&name)?;
                    removed += 1;
                }
            }
        }
        Ok(removed)
    }

    /// Delete every object made obsolete by the newest full checkpoint:
    /// older fulls and all differentials at or before its step. Returns the
    /// number of objects removed.
    pub fn gc(store: &dyn StorageBackend) -> Result<usize> {
        let mut fulls: Vec<(u64, String)> = Vec::new();
        let mut others: Vec<(u64, String)> = Vec::new();
        for name in store.list()? {
            match Self::parse(&name) {
                Some(("full", step, _)) => fulls.push((step, name)),
                Some((_, lo, _)) => others.push((lo, name)),
                _ => {}
            }
        }
        fulls.sort();
        let Some((newest, _)) = fulls.last().cloned() else {
            return Ok(0);
        };
        let mut removed = 0;
        for (step, name) in fulls.iter().take(fulls.len() - 1) {
            let _ = step;
            store.delete(name)?;
            removed += 1;
        }
        for (lo, name) in others {
            if lo <= newest {
                store.delete(&name)?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    #[test]
    fn names_sort_numerically() {
        assert!(Manifest::full_name(9) < Manifest::full_name(10));
        assert!(Manifest::diff_name(99) < Manifest::diff_name(100));
    }

    #[test]
    fn chain_discovery_orders_and_filters() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(10), b"f").unwrap();
        s.put(&Manifest::full_name(20), b"f").unwrap();
        s.put(&Manifest::diff_name(15), b"d").unwrap(); // obsolete (< full 20)
        s.put(&Manifest::diff_name(21), b"d").unwrap();
        s.put(&Manifest::batch_name(22, 25), b"b").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain.full.as_ref().unwrap().0, 20);
        assert_eq!(
            chain.diffs,
            vec![
                (21, 21, Manifest::diff_name(21)),
                (22, 25, Manifest::batch_name(22, 25)),
            ]
        );
        assert_eq!(chain.latest_step(), 25);
    }

    #[test]
    fn chain_with_no_checkpoints_is_empty() {
        let s = MemStore::new();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain, Chain::default());
        assert_eq!(chain.latest_step(), 0);
    }

    #[test]
    fn gc_keeps_live_chain_only() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(10), b"f").unwrap();
        s.put(&Manifest::diff_name(11), b"d").unwrap();
        s.put(&Manifest::full_name(20), b"f").unwrap();
        s.put(&Manifest::diff_name(20), b"d").unwrap(); // <= 20: obsolete
        s.put(&Manifest::diff_name(21), b"d").unwrap(); // live
        let removed = Manifest::gc(&s).unwrap();
        assert_eq!(removed, 3);
        assert_eq!(
            s.list().unwrap(),
            vec![Manifest::diff_name(21), Manifest::full_name(20)]
        );
    }

    #[test]
    fn gc_noop_without_full() {
        let s = MemStore::new();
        s.put(&Manifest::diff_name(5), b"d").unwrap();
        assert_eq!(Manifest::gc(&s).unwrap(), 0);
    }

    #[test]
    fn truncate_after_drops_lost_timeline() {
        let s = MemStore::new();
        s.put(&Manifest::full_name(8), b"f").unwrap();
        s.put(&Manifest::diff_name(9), b"d").unwrap(); // <= 9: keep
        s.put(&Manifest::diff_name(10), b"d").unwrap(); // > 9: lost timeline
        s.put(&Manifest::batch_name(10, 12), b"b").unwrap();
        let removed = Manifest::truncate_after(&s, 9).unwrap();
        assert_eq!(removed, 2);
        assert_eq!(
            s.list().unwrap(),
            vec![Manifest::diff_name(9), Manifest::full_name(8)]
        );
    }

    #[test]
    fn unknown_objects_ignored() {
        let s = MemStore::new();
        s.put("random.bin", b"x").unwrap();
        s.put(&Manifest::full_name(1), b"f").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert_eq!(chain.full.as_ref().unwrap().0, 1);
    }

    #[test]
    fn shard_names_roundtrip_and_classify() {
        let base = Manifest::diff_name(7);
        let idx = Manifest::shard_index_name(&base);
        assert_eq!(Manifest::shard_index_base(&idx), Some(base.as_str()));
        assert!(Manifest::is_shard_artifact(&idx));
        assert!(Manifest::is_shard_artifact(&Manifest::shard_name(&base, 2, 4)));
        assert!(!Manifest::is_shard_artifact(&base));
        assert!(!Manifest::is_shard_artifact("random.bin"));
        assert!(!Manifest::is_shard_artifact("x.s12of4")); // malformed widths
    }

    #[test]
    fn chain_discovery_skips_shard_artifacts() {
        // a raw inner store holds shard data + index objects; discovery on
        // it must not mistake them for checkpoint objects
        let s = MemStore::new();
        let full = Manifest::full_name(3);
        s.put(&Manifest::shard_name(&full, 0, 2), b"a").unwrap();
        s.put(&Manifest::shard_name(&full, 1, 2), b"b").unwrap();
        s.put(&Manifest::shard_index_name(&full), b"i").unwrap();
        let chain = Manifest::latest_chain(&s).unwrap();
        assert!(chain.full.is_none(), "shard artifacts are not logical objects");
    }
}
