//! Merged differential containers C^M — the chain compactor's output
//! (incremental-merging persistence, paper §VI-B spirit; Check-N-Run and
//! "On Efficient Constructions of Checkpoints" both consolidate
//! incrementals in the background to keep frequent differentials
//! sustainable).
//!
//! A merged container rewrites a run of raw diff/batch objects covering
//! steps `lo..=hi` as ONE storage object while preserving **every
//! per-step payload** — recovery replays the same Adam applications in
//! the same order, so the reconstructed state is bit-identical to
//! replaying the raw chain; only the number of objects fetched shrinks
//! (⌈n/merge_factor⌉ instead of n). Sections, in step order:
//!
//! ```text
//! g-{step}   a gradient payload   (LowDiff differential)
//! d-{step}   a state-delta payload (Naive DC differential)
//! sum        optional: the index-union sum of an all-gradient span,
//!            folded with `SparseGrad::merge_sum_into` — the precomputed
//!            partial that parallel-merge recovery (Fig. 10) would build
//!            from the per-step payloads anyway
//! ```
//!
//! The span header additionally carries a **compaction level** (in the
//! u16 that was reserved padding): level 1 merges raw diffs, level k+1
//! merges `merge_factor` level-k spans — the LSM-style hierarchy that
//! bounds replay at O(log_mf n) objects on an unbounded diff chain. A
//! level-k span still carries every per-step payload of its subtree, so
//! replay stays bit-identical regardless of which levels survive a crash.

use anyhow::{bail, ensure, Result};

use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::format::{
    encode_container_into, set_container_level, CkptKind, ContainerView, PayloadCodec, SectionSrc,
};
use crate::sparse::SparseGrad;

/// Encode a level-1 merged span. `items` must be step-ascending and inside
/// `lo..=hi`.
pub fn write_merged(
    items: &[(u64, DiffPayload)],
    model_sig: u64,
    lo: u64,
    hi: u64,
    codec: PayloadCodec,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_merged_into(items, model_sig, lo, hi, codec, &mut out)?;
    Ok(out)
}

/// Single-pass encode of a level-1 merged span into `out`. Returns bytes
/// appended.
pub fn write_merged_into(
    items: &[(u64, DiffPayload)],
    model_sig: u64,
    lo: u64,
    hi: u64,
    codec: PayloadCodec,
    out: &mut Vec<u8>,
) -> Result<usize> {
    write_merged_level_into(items, model_sig, lo, hi, 1, codec, out)
}

/// Encode a merged span at an explicit compaction level (the hierarchical
/// compactor's writer: level k+1 spans are re-encoded from the per-step
/// payloads of `merge_factor` level-k inputs).
pub fn write_merged_level(
    items: &[(u64, DiffPayload)],
    model_sig: u64,
    lo: u64,
    hi: u64,
    level: u16,
    codec: PayloadCodec,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_merged_level_into(items, model_sig, lo, hi, level, codec, &mut out)?;
    Ok(out)
}

/// Single-pass encode of a merged span at `level` into `out`. The level is
/// stamped into the container header after encoding (the header is outside
/// the payload CRC), so every other encoder keeps emitting the zeroed
/// reserved bytes it always did. Returns bytes appended.
pub fn write_merged_level_into(
    items: &[(u64, DiffPayload)],
    model_sig: u64,
    lo: u64,
    hi: u64,
    level: u16,
    codec: PayloadCodec,
    out: &mut Vec<u8>,
) -> Result<usize> {
    ensure!(level >= 1, "merged spans start at level 1");
    ensure!(!items.is_empty(), "empty merged span");
    ensure!(items.windows(2).all(|w| w[0].0 < w[1].0), "merged steps must ascend");
    ensure!(
        lo <= items[0].0 && items[items.len() - 1].0 <= hi,
        "span [{lo},{hi}] does not cover steps {}..{}",
        items[0].0,
        items[items.len() - 1].0
    );
    let sum = all_gradient_sum(items);
    let names: Vec<String> = items
        .iter()
        .map(|(s, p)| match p {
            DiffPayload::Gradient(_) => format!("g-{s}"),
            DiffPayload::StateDelta(_) => format!("d-{s}"),
        })
        .collect();
    let mut secs: Vec<SectionSrc<'_>> = names
        .iter()
        .zip(items)
        .map(|(n, (_, p))| SectionSrc::sparse(n, p.sparse()))
        .collect();
    if let Some(s) = &sum {
        secs.push(SectionSrc::sparse("sum", s));
    }
    let start = out.len();
    let appended =
        encode_container_into(CkptKind::MergedDiff, codec, model_sig, lo, hi, &secs, out)?;
    set_container_level(out, start, level);
    Ok(appended)
}

/// Compaction level recorded in a merged span's header. Spans written
/// before the hierarchy existed carry 0 in the reserved bytes; they are
/// level-1 spans by construction, so 0 normalizes to 1.
pub fn read_merged_level(bytes: &[u8]) -> Result<u16> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::MergedDiff, "not a merged diff: {:?}", c.kind);
    Ok(c.level.max(1))
}

/// The union-sum summary of an all-gradient span (≥ 2 items), folded
/// left-to-right with the zero-alloc merge core.
fn all_gradient_sum(items: &[(u64, DiffPayload)]) -> Option<SparseGrad> {
    if items.len() < 2 || !items.iter().all(|(_, p)| matches!(p, DiffPayload::Gradient(_))) {
        return None;
    }
    let mut acc = items[0].1.sparse().clone();
    let mut scratch = SparseGrad { dense_len: 0, indices: Vec::new(), values: Vec::new() };
    for (_, p) in &items[1..] {
        acc.merge_sum_into(p.sparse(), &mut scratch);
    }
    Some(acc)
}

/// Decode a merged span back to its per-step payloads (replay order).
pub fn read_merged(bytes: &[u8], model_sig: u64) -> Result<Vec<(u64, DiffPayload)>> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::MergedDiff, "not a merged diff: {:?}", c.kind);
    ensure!(c.model_sig == model_sig, "merged diff from a different model");
    let mut out = Vec::new();
    for (name, b) in c.sections() {
        if let Some(s) = name.strip_prefix("g-") {
            out.push((s.parse::<u64>()?, DiffPayload::Gradient(SparseGrad::from_bytes(b)?)));
        } else if let Some(s) = name.strip_prefix("d-") {
            out.push((s.parse::<u64>()?, DiffPayload::StateDelta(SparseGrad::from_bytes(b)?)));
        } else if name == "sum" {
            // summary section, not a replay step
        } else {
            bail!("unknown merged section `{name}`");
        }
    }
    ensure!(!out.is_empty(), "empty merged container");
    ensure!(out.windows(2).all(|w| w[0].0 < w[1].0), "merged steps out of order");
    Ok(out)
}

/// The precomputed gradient sum of an all-gradient merged span, if the
/// writer included one.
pub fn read_merged_sum(bytes: &[u8], model_sig: u64) -> Result<Option<SparseGrad>> {
    let c = ContainerView::parse(bytes)?;
    ensure!(c.kind == CkptKind::MergedDiff, "not a merged diff: {:?}", c.kind);
    ensure!(c.model_sig == model_sig, "merged diff from a different model");
    match c.section("sum") {
        Ok(b) => Ok(Some(SparseGrad::from_bytes(b)?)),
        Err(_) => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::tensor::Flat;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn grad(rng: &mut Rng, n: usize) -> SparseGrad {
        let mut d = Flat::zeros(n);
        for i in 0..n {
            if rng.next_f64() < 0.25 {
                d.0[i] = rng.normal() as f32;
            }
        }
        SparseGrad::from_dense(&d)
    }

    #[test]
    fn roundtrip_mixed_payloads_property() {
        prop_check("merged_roundtrip", 32, |rng| {
            let n = rng.range(1, 120);
            let k = rng.range(1, 6);
            let items: Vec<(u64, DiffPayload)> = (0..k)
                .map(|i| {
                    let p = if rng.next_f64() < 0.7 {
                        DiffPayload::Gradient(grad(rng, n))
                    } else {
                        DiffPayload::StateDelta(grad(rng, n))
                    };
                    (i as u64 + 1, p)
                })
                .collect();
            let (lo, hi) = (1, k as u64);
            for codec in [PayloadCodec::Raw, PayloadCodec::Zstd] {
                let bytes = write_merged(&items, 9, lo, hi, codec).unwrap();
                let back = read_merged(&bytes, 9).map_err(|e| format!("{e:#}"))?;
                prop_assert!(back == items);
            }
            Ok(())
        });
    }

    #[test]
    fn sum_section_equals_left_fold_merge() {
        let mut rng = Rng::new(4);
        let n = 80;
        let items: Vec<(u64, DiffPayload)> = (1..=4u64)
            .map(|s| (s, DiffPayload::Gradient(grad(&mut rng, n))))
            .collect();
        let bytes = write_merged(&items, 3, 1, 4, PayloadCodec::Raw).unwrap();
        let sum = read_merged_sum(&bytes, 3).unwrap().expect("all-gradient span has a sum");
        // identical fold order => exact equality, not just dense-equivalent
        let mut want = items[0].1.sparse().clone();
        for (_, p) in &items[1..] {
            want = want.merge_sum(p.sparse());
        }
        assert_eq!(sum, want);
    }

    #[test]
    fn no_sum_for_single_or_delta_spans() {
        let mut rng = Rng::new(5);
        let single = vec![(1u64, DiffPayload::Gradient(grad(&mut rng, 40)))];
        let b = write_merged(&single, 1, 1, 1, PayloadCodec::Raw).unwrap();
        assert!(read_merged_sum(&b, 1).unwrap().is_none());
        let mixed = vec![
            (1u64, DiffPayload::Gradient(grad(&mut rng, 40))),
            (2u64, DiffPayload::StateDelta(grad(&mut rng, 40))),
        ];
        let b = write_merged(&mixed, 1, 1, 2, PayloadCodec::Raw).unwrap();
        assert!(read_merged_sum(&b, 1).unwrap().is_none());
        assert_eq!(read_merged(&b, 1).unwrap().len(), 2);
    }

    #[test]
    fn level_roundtrips_in_the_header_and_defaults_to_one() {
        let mut rng = Rng::new(9);
        let items: Vec<(u64, DiffPayload)> = (1..=3u64)
            .map(|s| (s, DiffPayload::Gradient(grad(&mut rng, 30))))
            .collect();
        // write_merged = level 1; explicit levels round-trip through the
        // reserved header bytes without disturbing payload or CRC
        let l1 = write_merged(&items, 2, 1, 3, PayloadCodec::Raw).unwrap();
        assert_eq!(read_merged_level(&l1).unwrap(), 1);
        for level in [1u16, 2, 7] {
            let b = write_merged_level(&items, 2, 1, 3, level, PayloadCodec::Raw).unwrap();
            assert_eq!(read_merged_level(&b).unwrap(), level);
            assert_eq!(read_merged(&b, 2).unwrap(), items, "payload identical at any level");
        }
        // a pre-hierarchy span (zeroed reserved bytes) normalizes to 1
        let mut legacy = l1.clone();
        legacy[10] = 0;
        legacy[11] = 0;
        assert_eq!(read_merged_level(&legacy).unwrap(), 1);
        assert!(write_merged_level(&items, 2, 1, 3, 0, PayloadCodec::Raw).is_err());
    }

    #[test]
    fn quant8_merged_span_replays_within_contract() {
        // exactly-representable values (|v| ≤ 127, integral) round-trip
        // bit-exactly through Quant8; the index streams are always exact
        let mk = |step: u64, idx: Vec<u32>, vals: Vec<f32>| {
            (step, DiffPayload::Gradient(SparseGrad { dense_len: 64, indices: idx, values: vals }))
        };
        let items = vec![
            mk(1, vec![0, 9, 33], vec![127.0, -3.0, 64.0]),
            mk(2, vec![4, 9], vec![1.0, -127.0]),
            mk(3, vec![33, 60], vec![2.0, 127.0]),
        ];
        let bytes = write_merged(&items, 9, 1, 3, PayloadCodec::Quant8).unwrap();
        let back = read_merged(&bytes, 9).unwrap();
        assert_eq!(back, items);
        // the sum summary section survives the codec too
        assert!(read_merged_sum(&bytes, 9).unwrap().is_some());
    }

    #[test]
    fn wrong_sig_and_misordered_rejected() {
        let mut rng = Rng::new(6);
        let items = vec![
            (1u64, DiffPayload::Gradient(grad(&mut rng, 20))),
            (2u64, DiffPayload::Gradient(grad(&mut rng, 20))),
        ];
        let b = write_merged(&items, 7, 1, 2, PayloadCodec::Raw).unwrap();
        assert!(read_merged(&b, 8).is_err(), "foreign model sig");
        let misordered = vec![items[1].clone(), items[0].clone()];
        assert!(write_merged(&misordered, 7, 1, 2, PayloadCodec::Raw).is_err());
        assert!(write_merged(&items, 7, 2, 2, PayloadCodec::Raw).is_err(), "span must cover");
        assert!(write_merged(&[], 7, 1, 2, PayloadCodec::Raw).is_err(), "empty span");
    }
}
