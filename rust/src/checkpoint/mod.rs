//! Checkpoint containers and writers (DESIGN.md §5).
//!
//! - [`format`]: the on-disk container (magic, kind, steps, CRC32, optional
//!   zstd) shared by all checkpoint types.
//! - [`full`]: full checkpoints C^F — the 3Ψ model state.
//! - [`diff`]: differential checkpoints C^D — a *reused compressed
//!   gradient* (LowDiff, Eq. (7)) or a state delta (Naive DC, Eq. (5)).
//! - [`batched`]: the §V-B batched gradient write buffer.
//! - [`manifest`]: object naming, discovery of the recovery chain, GC.

pub mod batched;
pub mod diff;
pub mod format;
pub mod full;
pub mod manifest;

pub use batched::{BatchBuffer, BatchMode};
pub use diff::{read_diff, write_diff, write_diff_into, DiffPayload};
pub use format::{
    encode_container_into, CkptKind, Container, ContainerView, PayloadCodec, PayloadSrc, Section,
    SectionSrc,
};
pub use full::{read_full, write_full, write_full_into};
pub use manifest::Manifest;
