//! Checkpoint containers and writers (DESIGN.md §5).
//!
//! - [`format`]: the on-disk container (magic, kind, steps, CRC32, optional
//!   zstd) shared by all checkpoint types.
//! - [`full`]: full checkpoints C^F — the 3Ψ model state.
//! - [`diff`]: differential checkpoints C^D — a *reused compressed
//!   gradient* (LowDiff, Eq. (7)) or a state delta (Naive DC, Eq. (5)).
//! - [`batched`]: the §V-B batched gradient write buffer.
//! - [`merged`]: compacted differential spans C^M — the background chain
//!   compactor's output (incremental-merging persistence).
//! - [`carry`]: reshard carry bases — a new generation's chain base with
//!   moved-in slices inline and retained slices by reference.
//! - [`manifest`]: object naming, discovery of the recovery chain, GC.

pub mod batched;
pub mod carry;
pub mod diff;
pub mod format;
pub mod full;
pub mod manifest;
pub mod merged;

pub use batched::{BatchBuffer, BatchMode};
pub use carry::{read_carry, write_carry, Carry};
pub use diff::{read_diff, write_diff, write_diff_into, DiffPayload};
pub use format::{
    encode_container_into, CkptKind, Container, ContainerView, PayloadCodec, PayloadSrc, Section,
    SectionSrc,
};
pub use full::{read_full, write_full, write_full_into};
pub use manifest::Manifest;
pub use merged::{
    read_merged, read_merged_level, read_merged_sum, write_merged, write_merged_into,
    write_merged_level, write_merged_level_into,
};

use anyhow::{bail, Result};

/// Decode any diff-chain object — plain [`CkptKind::Diff`], batched, or a
/// compacted [`CkptKind::MergedDiff`] span — to its per-step payloads in
/// replay order. The single kind-dispatch shared by recovery
/// (`coordinator::recovery::load_diffs`), cluster chain loading
/// (`cluster::commit::load_chains`), and the compactor
/// (`pipeline::compact`): adding a new chain kind means extending exactly
/// this function.
pub fn read_chain_object(
    bytes: &[u8],
    model_sig: u64,
) -> Result<(CkptKind, Vec<(u64, DiffPayload)>)> {
    let kind = ContainerView::parse(bytes)?.kind;
    let items = match kind {
        CkptKind::Diff => {
            let (step, payload) = read_diff(bytes, model_sig)?;
            vec![(step, payload)]
        }
        CkptKind::BatchedDiff => batched::read_batched(bytes, model_sig)?
            .into_iter()
            .map(|(s, g)| (s, DiffPayload::Gradient(g)))
            .collect(),
        CkptKind::MergedDiff => read_merged(bytes, model_sig)?,
        CkptKind::Full => bail!("full checkpoint container in a diff chain"),
        CkptKind::CarryFull => bail!("carry base container in a diff chain"),
    };
    Ok((kind, items))
}
