//! Two-phase global commit and consistent-cut recovery.
//!
//! **Phase 1** — every rank persists its own object for the epoch (diff or
//! full, through its namespace and — if configured — its sharded engine)
//! and acks with the object's name, length and CRC. **Phase 2** — the
//! coordinator, having collected all R acks for the epoch *and committed
//! every earlier epoch first*, writes one [`GlobalRecord`] as
//! `global-{step:012}.gck`. The record's presence is the commit point
//! (Check-N-Run's decoupled-shards-need-an-atomic-commit-record lesson);
//! an epoch with any failed rank write is *torn*: no record is written and
//! the per-rank stragglers are garbage awaiting truncation. A torn *diff*
//! epoch also **poisons** later diff epochs (no records for them either)
//! until a full epoch re-bases every rank's chain — so a committed record
//! always references hole-free chains by construction (see
//! `rank.rs::coordinator_loop`); recovery's chain verification is defense
//! in depth against external damage.
//!
//! **Consistent cut**: the newest step whose global record parses, whose
//! referenced per-rank objects all read back with the recorded CRC, and
//! whose per-rank chains (newest full ≤ cut, diffs up to the cut) are
//! complete — [`find_consistent_cut`] walks records newest→oldest and
//! returns the first that verifies; torn or damaged newer records are
//! skipped, never partially applied. [`recover_cluster`] then replays each
//! rank's diffs through Adam and flattens the slices — bit-identical to
//! single-state recovery because Adam is element-wise.
//!
//! [`gc_cluster`] deletes only what is *unreachable* from the newest
//! complete record (older records, superseded per-rank objects, defunct
//! rank namespaces after an elastic reshard), and never touches objects
//! beyond the cut — they are phase 1 of an epoch still being committed.
//! The "never delete the chain you would recover from" invariant is
//! property-tested in `rust/tests/cluster_recovery.rs`.

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};
use byteorder::{ByteOrder, LittleEndian as LE};

use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::full::read_full;
use crate::checkpoint::manifest::{Chain, Manifest};
use crate::checkpoint::read_chain_object;
use crate::cluster::{rank_sig, validate_partitions, Partition};
use crate::optim::{Adam, ModelState};
use crate::sparse::SparseGrad;
use crate::storage::{Sharded, StorageBackend};

pub const GLOBAL_MAGIC: &[u8; 4] = b"LDGC";
pub const GLOBAL_VERSION: u32 = 1;

/// What a rank persisted for one committed epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitKind {
    Full = 0,
    Diff = 1,
}

impl CommitKind {
    fn from_u8(v: u8) -> Result<CommitKind> {
        Ok(match v {
            0 => CommitKind::Full,
            1 => CommitKind::Diff,
            _ => bail!("unknown commit kind {v}"),
        })
    }
}

/// One rank's entry in a [`GlobalRecord`]: its partition and the durable
/// object it contributed to this epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct RankObject {
    pub rank: u32,
    /// partition range over the flat parameter vector
    pub offset: u64,
    pub len: u64,
    pub kind: CommitKind,
    /// namespaced logical object name (`rank-{r:04}/diff-…`)
    pub name: String,
    /// length and CRC32 of the logical object bytes — re-verified at
    /// recovery so an overwritten or torn object can't impersonate the
    /// committed one
    pub obj_len: u64,
    pub obj_crc: u32,
}

impl RankObject {
    pub fn partition(&self) -> Partition {
        Partition { rank: self.rank as usize, offset: self.offset as usize, len: self.len as usize }
    }
}

/// The phase-2 epoch record: every rank's object + CRC, plus the partition
/// table that produced them (which is what makes elastic resharded
/// recovery possible — a restart with different rank count reads R from
/// the record, not from its own config).
///
/// Wire layout (little-endian):
/// ```text
/// magic "LDGC" | version u32 | model_sig u64 | step u64 | seq u64 | n_ranks u32
/// per rank: rank u32 | offset u64 | len u64 | kind u8 | name_len u16
///           | name bytes | obj_len u64 | obj_crc u32
/// crc32 u32 (of all preceding bytes)
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct GlobalRecord {
    pub model_sig: u64,
    /// training step this epoch captured
    pub step: u64,
    /// commit sequence number (strictly increasing; records are written in
    /// seq order, so commit order is a prefix of epoch order)
    pub seq: u64,
    pub ranks: Vec<RankObject>,
}

impl GlobalRecord {
    /// Total parameters covered by the partition table.
    pub fn n_params(&self) -> usize {
        self.ranks.iter().map(|r| r.len as usize).sum()
    }

    pub fn partitions(&self) -> Vec<Partition> {
        self.ranks.iter().map(|r| r.partition()).collect()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let meta: usize = self.ranks.iter().map(|r| 4 + 8 + 8 + 1 + 2 + r.name.len() + 8 + 4).sum();
        let mut out = Vec::with_capacity(36 + meta + 4);
        out.extend_from_slice(GLOBAL_MAGIC);
        out.extend_from_slice(&GLOBAL_VERSION.to_le_bytes());
        out.extend_from_slice(&self.model_sig.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.ranks.len() as u32).to_le_bytes());
        for r in &self.ranks {
            out.extend_from_slice(&r.rank.to_le_bytes());
            out.extend_from_slice(&r.offset.to_le_bytes());
            out.extend_from_slice(&r.len.to_le_bytes());
            out.push(r.kind as u8);
            debug_assert!(r.name.len() <= u16::MAX as usize);
            out.extend_from_slice(&(r.name.len() as u16).to_le_bytes());
            out.extend_from_slice(r.name.as_bytes());
            out.extend_from_slice(&r.obj_len.to_le_bytes());
            out.extend_from_slice(&r.obj_crc.to_le_bytes());
        }
        let crc = crc32fast::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<GlobalRecord> {
        ensure!(bytes.len() >= 40, "global record too short ({} bytes)", bytes.len());
        ensure!(&bytes[0..4] == GLOBAL_MAGIC, "bad global record magic");
        let version = LE::read_u32(&bytes[4..8]);
        ensure!(version == GLOBAL_VERSION, "unsupported global record version {version}");
        let crc_stored = LE::read_u32(&bytes[bytes.len() - 4..]);
        let crc = crc32fast::hash(&bytes[..bytes.len() - 4]);
        ensure!(crc == crc_stored, "global record CRC mismatch (torn commit write?)");
        let model_sig = LE::read_u64(&bytes[8..16]);
        let step = LE::read_u64(&bytes[16..24]);
        let seq = LE::read_u64(&bytes[24..32]);
        let n = LE::read_u32(&bytes[32..36]) as usize;
        ensure!(n >= 1 && n <= 1 << 16, "implausible rank count {n}");
        let end = bytes.len() - 4;
        let mut pos = 36usize;
        let mut ranks = Vec::with_capacity(n);
        for _ in 0..n {
            ensure!(pos + 23 <= end, "truncated rank entry");
            let rank = LE::read_u32(&bytes[pos..pos + 4]);
            let offset = LE::read_u64(&bytes[pos + 4..pos + 12]);
            let len = LE::read_u64(&bytes[pos + 12..pos + 20]);
            let kind = CommitKind::from_u8(bytes[pos + 20])?;
            let name_len = LE::read_u16(&bytes[pos + 21..pos + 23]) as usize;
            pos += 23;
            ensure!(pos + name_len + 12 <= end, "truncated rank entry name");
            let name = std::str::from_utf8(&bytes[pos..pos + name_len])?.to_string();
            pos += name_len;
            let obj_len = LE::read_u64(&bytes[pos..pos + 8]);
            let obj_crc = LE::read_u32(&bytes[pos + 8..pos + 12]);
            pos += 12;
            ranks.push(RankObject { rank, offset, len, kind, name, obj_len, obj_crc });
        }
        ensure!(pos == end, "global record trailing bytes");
        let rec = GlobalRecord { model_sig, step, seq, ranks };
        validate_partitions(&rec.partitions(), rec.n_params())
            .context("global record partition table")?;
        Ok(rec)
    }
}

/// One rank's verified, loaded recovery chain at the cut.
pub struct RankChain {
    pub part: Partition,
    /// the rank's newest full checkpoint at or before the cut
    pub base: ModelState,
    /// gradient diffs in `(base, cut]`, step order
    pub diffs: Vec<(u64, SparseGrad)>,
    /// every namespaced logical object this chain depends on (the GC
    /// reachability set): base full + diff objects
    pub objects: Vec<String>,
}

/// How the consistent cut was found.
#[derive(Clone, Debug, Default)]
pub struct ClusterCutStats {
    pub cut_step: u64,
    pub cut_seq: u64,
    /// ranks in the committed epoch (R at commit time, not restart time)
    pub ranks: usize,
    /// global records on the store
    pub records_seen: usize,
    /// newer records skipped as torn/unverifiable before the cut was found
    pub records_skipped: usize,
    /// diff steps replayed across all ranks
    pub diff_steps_applied: usize,
}

/// Shard-aware logical view over the shared store (reads both sharded and
/// plain per-rank objects). Each view carries a 1-thread writer pool, so
/// callers build one per pass and share it, never one per operation.
fn logical_view(store: &Arc<dyn StorageBackend>) -> Sharded {
    Sharded::new(Arc::clone(store), 1, 1)
}

/// Walk global records newest→oldest; return the first whose referenced
/// objects and per-rank chains fully verify, with the chains loaded.
pub fn find_consistent_cut(
    store: &Arc<dyn StorageBackend>,
    model_sig: u64,
) -> Result<Option<(GlobalRecord, Vec<RankChain>, ClusterCutStats)>> {
    let logical = logical_view(store);
    let names = logical.list().context("listing cluster store")?;
    let mut steps: Vec<u64> = names.iter().filter_map(|n| Manifest::parse_global(n)).collect();
    steps.sort_unstable();
    let mut stats = ClusterCutStats { records_seen: steps.len(), ..Default::default() };
    for &step in steps.iter().rev() {
        let rec = logical
            .get(&Manifest::global_name(step))
            .map_err(|e| format!("{e:#}"))
            .and_then(|b| GlobalRecord::from_bytes(&b).map_err(|e| format!("{e:#}")));
        let rec = match rec {
            Ok(r) if r.model_sig == model_sig => r,
            Ok(r) => {
                log::warn!(
                    "global record {step}: foreign model sig {:#x}, skipping",
                    r.model_sig
                );
                stats.records_skipped += 1;
                continue;
            }
            Err(e) => {
                log::warn!("global record {step} unreadable ({e}); skipping");
                stats.records_skipped += 1;
                continue;
            }
        };
        match load_chains(&logical, &names, &rec, model_sig) {
            Ok(chains) => {
                stats.cut_step = rec.step;
                stats.cut_seq = rec.seq;
                stats.ranks = rec.ranks.len();
                stats.diff_steps_applied = chains.iter().map(|c| c.diffs.len()).sum();
                return Ok(Some((rec, chains, stats)));
            }
            Err(e) => {
                log::warn!("global record {step} not recoverable ({e:#}); falling back");
                stats.records_skipped += 1;
            }
        }
    }
    Ok(None)
}

/// Verify and load every rank chain referenced by `rec`. Any damaged,
/// missing, torn, or discontinuous piece fails the whole record. Bases
/// are resilient: a full checkpoint written by a *different* partitioning
/// (an elastic re-anchor racing this record) carries a foreign rank
/// signature and is skipped in favor of an older base of this chain's own
/// generation, instead of failing the record.
fn load_chains(
    logical: &Sharded,
    names: &[String],
    rec: &GlobalRecord,
    model_sig: u64,
) -> Result<Vec<RankChain>> {
    let cut = rec.step;
    let mut out = Vec::with_capacity(rec.ranks.len());
    for ro in &rec.ranks {
        let part = ro.partition();
        let rsig = rank_sig(model_sig, &part);
        let rank = ro.rank as usize;
        // the committed tip must still be the committed bytes
        let tip = logical
            .get(&ro.name)
            .with_context(|| format!("rank {rank} tip {}", ro.name))?;
        ensure!(
            tip.len() as u64 == ro.obj_len && crc32fast::hash(&tip) == ro.obj_crc,
            "rank {rank} tip {} does not match the committed CRC",
            ro.name
        );
        // every chain object is fetched exactly once: the tip (base full
        // or last diff) was just read, so hand its bytes back when the
        // chain walk reaches it instead of re-reading through storage
        let mut tip_bytes = Some(tip);
        let mut fetch = |name: &str| -> Result<Vec<u8>> {
            if name == ro.name {
                if let Some(b) = tip_bytes.take() {
                    return Ok(b);
                }
            }
            logical.get(name)
        };

        // candidate bases, tried newest→oldest
        let mut fulls: Vec<(u64, String)> = names
            .iter()
            .filter(|n| Manifest::parse_rank(n).map(|(r, _)| r) == Some(rank))
            .filter_map(|n| match Manifest::step_range(n) {
                Some(("full", s, _)) if s <= cut => Some((s, n.clone())),
                _ => None,
            })
            .collect();
        fulls.sort();
        let mut found: Option<(u64, String, ModelState)> = None;
        for (s, name) in fulls.iter().rev() {
            match fetch(name).and_then(|b| read_full(&b, rsig)) {
                Ok(st) if st.n_params() == part.len => {
                    found = Some((*s, name.clone(), st));
                    break;
                }
                _ => log::debug!("rank {rank}: base {name} foreign/unusable; trying older"),
            }
        }
        let (base_step, base_name, base) = found.with_context(|| {
            format!("rank {rank}: no readable full checkpoint at or before {cut}")
        })?;

        let chain_diffs: Vec<(u64, u64, String)> = names
            .iter()
            .filter(|n| Manifest::parse_rank(n).map(|(r, _)| r) == Some(rank))
            .filter_map(|n| match Manifest::step_range(n) {
                // hi-based like flat discovery: a compacted span may
                // straddle the base full; its steps <= base are skipped
                // at replay below
                Some(("diff", lo, hi)) | Some(("batch", lo, hi)) | Some(("merged", lo, hi))
                    if hi > base_step && hi <= cut =>
                {
                    Some((lo, hi, n.clone()))
                }
                _ => None,
            })
            .collect();
        // non-overlapping replay cover: compacted `MergedDiff` spans win
        // over any leftover raws they supersede (crash mid-compaction)
        let chain_diffs = Manifest::select_cover(chain_diffs);

        let mut objects = vec![base_name];
        let mut diffs: Vec<(u64, SparseGrad)> = Vec::with_capacity(chain_diffs.len());
        // a complete chain steps uniformly from the base to the cut; the
        // stride heuristic is shared with flat recovery and the compactor
        // (see `Chain::stride` for the off-cadence-base rationale)
        let span_chain = Chain { full: None, diffs: chain_diffs };
        let stride = span_chain.stride(base_step);
        let chain_diffs = &span_chain.diffs;
        let mut prev_hi = base_step;
        for (i, (lo, hi, name)) in chain_diffs.iter().enumerate() {
            let hole = if i == 0 { *lo > base_step + stride } else { *lo != prev_hi + stride };
            ensure!(!hole, "rank {rank} chain hole before {name}");
            let bytes = fetch(name).with_context(|| format!("rank {rank} {name}"))?;
            let (_, items) = read_chain_object(&bytes, rsig)
                .with_context(|| format!("rank {rank} {name}"))?;
            for (step, payload) in items {
                if step <= base_step {
                    continue; // straddling span: the base already covers it
                }
                match payload {
                    DiffPayload::Gradient(g) => diffs.push((step, g)),
                    DiffPayload::StateDelta(_) => {
                        bail!("rank {rank} {name}: state-delta diff in a cluster chain")
                    }
                }
            }
            objects.push(name.clone());
            prev_hi = *hi;
        }
        ensure!(prev_hi == cut, "rank {rank} chain ends at {prev_hi}, cut is {cut}");
        diffs.sort_by_key(|(s, _)| *s);
        out.push(RankChain { part, base, diffs, objects });
    }
    Ok(out)
}

/// Recover the newest consistent cluster cut as one flattened global
/// state: per-rank serial replay (exact — Adam is element-wise, so slice
/// recovery concatenates bit-identically), then flatten in rank order.
pub fn recover_cluster(
    store: &Arc<dyn StorageBackend>,
    model_sig: u64,
    adam: &Adam,
) -> Result<(ModelState, ClusterCutStats)> {
    let (rec, chains, stats) = find_consistent_cut(store, model_sig)?
        .context("no consistent cluster cut — no complete global commit record found")?;
    let mut slices = Vec::with_capacity(chains.len());
    for ch in chains {
        let mut st = ch.base;
        for (_, g) in &ch.diffs {
            adam.apply_sparse(&mut st, g);
        }
        st.step = rec.step;
        slices.push((ch.part, st));
    }
    let state = crate::cluster::reshard::flatten(&slices)?;
    Ok((state, stats))
}

/// Cluster recovery with the **reshard safety-net fail-safe**: also read
/// the dedicated net object
/// ([`Manifest::reshard_net_name`] — written by
/// [`elastic_restart`](crate::cluster::reshard::elastic_restart) before
/// its re-anchor can overwrite any step-keyed `rank-*/full-{S}` name,
/// deleted once the anchor record commits) and return whichever
/// reconstructs the newer step. Only that one object is consulted —
/// never the general flat chain — so a stale flat timeline left on a
/// reused store can never hijack cluster recovery. Returns `None` cut
/// stats when the net won.
pub fn recover_cluster_or_net(
    store: &Arc<dyn StorageBackend>,
    model_sig: u64,
    adam: &Adam,
) -> Result<(ModelState, Option<ClusterCutStats>)> {
    let cluster = recover_cluster(store, model_sig, adam);
    let net = logical_view(store)
        .get(Manifest::reshard_net_name())
        .ok()
        .and_then(|b| read_full(&b, model_sig).ok());
    match (cluster, net) {
        (Ok((cs, stats)), Some(ns)) => {
            if ns.step > cs.step {
                log::warn!(
                    "reshard safety net (step {}) is newer than the cluster cut (step {}); \
                     a re-anchor crashed mid-window — recovering from the net",
                    ns.step,
                    cs.step
                );
                Ok((ns, None))
            } else {
                Ok((cs, Some(stats)))
            }
        }
        (Ok((cs, stats)), None) => Ok((cs, Some(stats))),
        (Err(e), Some(ns)) => {
            log::warn!("no consistent cluster cut ({e:#}); recovering from the reshard net");
            Ok((ns, None))
        }
        (Err(e), None) => Err(e),
    }
}

/// Delete per-rank objects and global records from timelines beyond the
/// cut (stragglers of torn commits, or a lost timeline after a rollback).
/// Run after recovery, before new ranks resume writing.
pub fn truncate_stragglers(store: &Arc<dyn StorageBackend>, cut: u64) -> Result<usize> {
    let logical = logical_view(store);
    let mut removed = 0;
    for name in logical.list()? {
        let doomed = match Manifest::parse_global(&name) {
            Some(step) => step > cut,
            None => {
                Manifest::parse_rank(&name).is_some()
                    && matches!(Manifest::step_range(&name), Some((_, lo, _)) if lo > cut)
            }
        };
        if doomed {
            logical.delete(&name)?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// Cluster GC: keep exactly the newest complete global record and every
/// object reachable from it (each rank's base full + diffs up to the
/// cut), plus any per-rank object *beyond* the cut (phase 1 of an epoch
/// still committing). Everything else — older records, torn newer
/// records, superseded per-rank objects, defunct namespaces left behind
/// by an elastic reshard — is deleted. Returns objects removed; no-op
/// when no complete record exists (never delete the chain you might still
/// recover from).
pub fn gc_cluster(store: &Arc<dyn StorageBackend>, model_sig: u64) -> Result<usize> {
    let Some((rec, chains, _)) = find_consistent_cut(store, model_sig)? else {
        return Ok(0);
    };
    let keep: HashSet<String> = chains
        .into_iter()
        .flat_map(|c| c.objects)
        .chain(std::iter::once(Manifest::global_name(rec.step)))
        .collect();
    let logical = logical_view(store);
    let names = logical.list()?;
    sweep(&logical, &names, rec.step, &keep)
}

/// Commit-path GC: same sweep as [`gc_cluster`], but the keep set is
/// built **by name only** from the record the coordinator just wrote —
/// every referenced object was acked durable moments ago, so re-reading
/// and CRC-verifying the whole checkpoint (what `gc_cluster` does for an
/// untrusted store) would double storage traffic per full epoch for
/// nothing. Crate-private: only sound when `rec` is the newest record on
/// the store, which the coordinator's in-order commits guarantee.
pub(crate) fn gc_with_record(store: &Arc<dyn StorageBackend>, rec: &GlobalRecord) -> Result<usize> {
    let logical = logical_view(store);
    let names = logical.list()?;
    let mut keep: HashSet<String> = HashSet::new();
    keep.insert(Manifest::global_name(rec.step));
    for ro in &rec.ranks {
        keep.insert(ro.name.clone());
        let chain = Manifest::rank_chain(&names, ro.rank as usize, rec.step);
        if let Some((_, full)) = chain.full {
            keep.insert(full);
        }
        for (_, _, diff) in chain.diffs {
            keep.insert(diff);
        }
    }
    sweep(&logical, &names, rec.step, &keep)
}

/// Delete everything except `keep` and in-flight objects beyond `cut`,
/// over an already-listed logical view (one view + one listing per pass).
/// Deletes are best-effort per object: the background compaction
/// scheduler legitimately races this sweep (it deletes raws it just
/// superseded with a merged span), so an already-gone object is skipped,
/// never a sweep abort.
fn sweep(logical: &Sharded, names: &[String], cut: u64, keep: &HashSet<String>) -> Result<usize> {
    let mut removed = 0;
    for name in names {
        if keep.contains(name) {
            continue;
        }
        let doomed = if Manifest::parse_global(name).is_some() {
            // the kept record is the only live one: older records are
            // superseded, newer ones failed verification (torn)
            true
        } else if Manifest::parse_rank(name).is_some() {
            // keep in-flight phase-1 objects beyond the cut
            matches!(Manifest::step_range(name), Some((_, _, hi)) if hi <= cut)
        } else {
            false // top-level (non-cluster) objects are not ours to collect
        };
        if doomed {
            match logical.delete(name) {
                Ok(()) => removed += 1,
                Err(e) => log::debug!("gc sweep: {name} already gone? ({e:#})"),
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(ranks: usize) -> GlobalRecord {
        let mut pos = 0u64;
        let objs = (0..ranks)
            .map(|r| {
                let len = 10 + r as u64;
                let ro = RankObject {
                    rank: r as u32,
                    offset: pos,
                    len,
                    kind: if r % 2 == 0 { CommitKind::Diff } else { CommitKind::Full },
                    name: format!("{}{}", Manifest::rank_prefix(r), Manifest::diff_name(7)),
                    obj_len: 100 + r as u64,
                    obj_crc: 0xABCD + r as u32,
                };
                pos += len;
                ro
            })
            .collect();
        GlobalRecord { model_sig: 0xFEED, step: 7, seq: 9, ranks: objs }
    }

    #[test]
    fn record_roundtrip() {
        for ranks in [1usize, 2, 5] {
            let rec = record(ranks);
            let back = GlobalRecord::from_bytes(&rec.to_bytes()).unwrap();
            assert_eq!(back, rec);
            assert_eq!(back.partitions().len(), ranks);
        }
    }

    #[test]
    fn record_detects_corruption_and_truncation() {
        let bytes = record(3).to_bytes();
        for cut in [0, 10, bytes.len() / 2, bytes.len() - 1] {
            assert!(GlobalRecord::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        let err = GlobalRecord::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC") || err.contains("kind") || err.contains("utf-8"), "{err}");
    }

    #[test]
    fn record_rejects_non_contiguous_partitions() {
        let mut rec = record(2);
        rec.ranks[1].offset += 1;
        let err = GlobalRecord::from_bytes(&rec.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("partition"), "{err}");
    }

    #[test]
    fn commit_kind_decodes() {
        assert_eq!(CommitKind::from_u8(0).unwrap(), CommitKind::Full);
        assert_eq!(CommitKind::from_u8(1).unwrap(), CommitKind::Diff);
        assert!(CommitKind::from_u8(9).is_err());
    }
}
