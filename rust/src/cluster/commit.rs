//! Two-phase global commit and consistent-cut recovery.
//!
//! **Phase 1** — every rank persists its own object for the epoch (diff or
//! full, through its generation namespace and — if configured — its
//! sharded engine) and acks with the object's name, length and CRC.
//! **Phase 2** — the coordinator, having collected all R acks for the
//! epoch *and committed every earlier epoch first*, writes one
//! [`GlobalRecord`] as `global-{g:04}-{step:012}.gck`. The record's
//! presence is the commit point (Check-N-Run's
//! decoupled-shards-need-an-atomic-commit-record lesson); an epoch with
//! any failed rank write is *torn*: no record is written and the per-rank
//! stragglers are garbage awaiting truncation. A torn *diff* epoch also
//! **poisons** later diff epochs (no records for them either) until a
//! full epoch re-bases every rank's chain — so a committed record always
//! references hole-free chains by construction (see
//! `rank.rs::coordinator_loop`); recovery's chain verification is defense
//! in depth against external damage.
//!
//! **Consistent cut**: the newest step whose global record parses, whose
//! referenced per-rank objects all read back with the recorded CRC, and
//! whose per-rank chains (newest base ≤ cut, diffs up to the cut) are
//! complete — [`find_consistent_cut`] walks records newest→oldest
//! (ties between generations at the same step go to the newer
//! generation) and returns the first that verifies; torn or damaged
//! newer records are skipped, never partially applied. A chain base may
//! be a plain full *or* a reshard carry
//! ([`CkptKind::CarryFull`](crate::checkpoint::format::CkptKind)) whose
//! reference intervals resolve into the previous generation's bases.
//! [`recover_cluster`] then replays each rank's diffs through Adam and
//! flattens the slices — bit-identical to single-state recovery because
//! Adam is element-wise.
//!
//! [`gc_cluster`] deletes only what is *unreachable* from the newest
//! complete record (older records, superseded per-rank objects, whole
//! defunct generations after an elastic reshard), and never touches
//! objects beyond the cut — they are phase 1 of an epoch still being
//! committed. While the live chain's base is a carry, every foreign
//! generation is frozen (the carry's references reach into it); the
//! first committed full epoch after a reshard drops the old generation
//! wholesale. The "never delete the chain you would recover from"
//! invariant is property-tested in `rust/tests/cluster_recovery.rs`.

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};
use byteorder::{ByteOrder, LittleEndian as LE};

use crate::checkpoint::carry::read_carry;
use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::format::{CkptKind, ContainerView};
use crate::checkpoint::full::read_full;
use crate::checkpoint::manifest::{Chain, Manifest};
use crate::checkpoint::read_chain_object;
use crate::cluster::{rank_sig, validate_partitions, Partition, Slice};
use crate::optim::{Adam, ModelState};
use crate::sparse::SparseGrad;
use crate::storage::{Sharded, StorageBackend};

pub const GLOBAL_MAGIC: &[u8; 4] = b"LDGC";
pub const GLOBAL_VERSION: u32 = 2;

/// Maximum carry-base indirection depth: each reshard without an
/// intervening full epoch adds one level; deeper than this and recovery
/// refuses rather than loop on a corrupt reference cycle.
const MAX_CARRY_DEPTH: usize = 16;

/// What a rank persisted for one committed epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitKind {
    Full = 0,
    Diff = 1,
    /// reshard carry base (first record of a fresh generation)
    Carry = 2,
}

impl CommitKind {
    fn from_u8(v: u8) -> Result<CommitKind> {
        Ok(match v {
            0 => CommitKind::Full,
            1 => CommitKind::Diff,
            2 => CommitKind::Carry,
            _ => bail!("unknown commit kind {v}"),
        })
    }
}

/// One rank's entry in a [`GlobalRecord`]: its partition slices and the
/// durable object it contributed to this epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct RankObject {
    pub rank: u32,
    /// partition slices `(offset, len)` over the flat parameter vector,
    /// sorted by offset
    pub slices: Vec<(u64, u64)>,
    pub kind: CommitKind,
    /// namespaced logical object name (`gen-{g:04}/rank-{r:04}/diff-…`)
    pub name: String,
    /// length and CRC32 of the logical object bytes — re-verified at
    /// recovery so an overwritten or torn object can't impersonate the
    /// committed one
    pub obj_len: u64,
    pub obj_crc: u32,
}

impl RankObject {
    pub fn partition(&self) -> Partition {
        Partition {
            rank: self.rank as usize,
            slices: self
                .slices
                .iter()
                .map(|&(o, l)| Slice { offset: o as usize, len: l as usize })
                .collect(),
        }
    }

    /// Total parameters this rank owns.
    pub fn n_params(&self) -> usize {
        self.slices.iter().map(|&(_, l)| l as usize).sum()
    }
}

/// The phase-2 epoch record: every rank's object + CRC, plus the partition
/// table that produced them (which is what makes elastic resharded
/// recovery possible — a restart with different rank count reads R from
/// the record, not from its own config) and the namespace generation the
/// epoch was written into.
///
/// Wire layout (little-endian):
/// ```text
/// magic "LDGC" | version u32 | model_sig u64 | generation u64
/// step u64 | seq u64 | n_ranks u32
/// per rank: rank u32 | n_slices u32 | (offset u64 | len u64)* | kind u8
///           | name_len u16 | name bytes | obj_len u64 | obj_crc u32
/// crc32 u32 (of all preceding bytes)
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct GlobalRecord {
    pub model_sig: u64,
    /// namespace generation this epoch's objects live in
    pub generation: u64,
    /// training step this epoch captured
    pub step: u64,
    /// commit sequence number (strictly increasing; records are written in
    /// seq order, so commit order is a prefix of epoch order)
    pub seq: u64,
    pub ranks: Vec<RankObject>,
}

impl GlobalRecord {
    /// Total parameters covered by the partition table.
    pub fn n_params(&self) -> usize {
        self.ranks.iter().map(|r| r.n_params()).sum()
    }

    pub fn partitions(&self) -> Vec<Partition> {
        self.ranks.iter().map(|r| r.partition()).collect()
    }

    /// The record's own object name on the store.
    pub fn name(&self) -> String {
        Manifest::global_name(self.generation, self.step)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let meta: usize = self
            .ranks
            .iter()
            .map(|r| 4 + 4 + 16 * r.slices.len() + 1 + 2 + r.name.len() + 8 + 4)
            .sum();
        let mut out = Vec::with_capacity(44 + meta + 4);
        out.extend_from_slice(GLOBAL_MAGIC);
        out.extend_from_slice(&GLOBAL_VERSION.to_le_bytes());
        out.extend_from_slice(&self.model_sig.to_le_bytes());
        out.extend_from_slice(&self.generation.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.ranks.len() as u32).to_le_bytes());
        for r in &self.ranks {
            out.extend_from_slice(&r.rank.to_le_bytes());
            out.extend_from_slice(&(r.slices.len() as u32).to_le_bytes());
            for &(o, l) in &r.slices {
                out.extend_from_slice(&o.to_le_bytes());
                out.extend_from_slice(&l.to_le_bytes());
            }
            out.push(r.kind as u8);
            debug_assert!(r.name.len() <= u16::MAX as usize);
            out.extend_from_slice(&(r.name.len() as u16).to_le_bytes());
            out.extend_from_slice(r.name.as_bytes());
            out.extend_from_slice(&r.obj_len.to_le_bytes());
            out.extend_from_slice(&r.obj_crc.to_le_bytes());
        }
        let crc = crc32fast::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<GlobalRecord> {
        ensure!(bytes.len() >= 48, "global record too short ({} bytes)", bytes.len());
        ensure!(&bytes[0..4] == GLOBAL_MAGIC, "bad global record magic");
        let version = LE::read_u32(&bytes[4..8]);
        ensure!(version == GLOBAL_VERSION, "unsupported global record version {version}");
        let crc_stored = LE::read_u32(&bytes[bytes.len() - 4..]);
        let crc = crc32fast::hash(&bytes[..bytes.len() - 4]);
        ensure!(crc == crc_stored, "global record CRC mismatch (torn commit write?)");
        let model_sig = LE::read_u64(&bytes[8..16]);
        let generation = LE::read_u64(&bytes[16..24]);
        let step = LE::read_u64(&bytes[24..32]);
        let seq = LE::read_u64(&bytes[32..40]);
        let n = LE::read_u32(&bytes[40..44]) as usize;
        ensure!(n >= 1 && n <= 1 << 16, "implausible rank count {n}");
        let end = bytes.len() - 4;
        let mut pos = 44usize;
        let mut ranks = Vec::with_capacity(n);
        for _ in 0..n {
            ensure!(pos + 8 <= end, "truncated rank entry");
            let rank = LE::read_u32(&bytes[pos..pos + 4]);
            let n_slices = LE::read_u32(&bytes[pos + 4..pos + 8]) as usize;
            pos += 8;
            ensure!(n_slices >= 1 && n_slices <= 1 << 20, "implausible slice count {n_slices}");
            ensure!(pos + 16 * n_slices + 3 <= end, "truncated rank slices");
            let mut slices = Vec::with_capacity(n_slices);
            for _ in 0..n_slices {
                let o = LE::read_u64(&bytes[pos..pos + 8]);
                let l = LE::read_u64(&bytes[pos + 8..pos + 16]);
                slices.push((o, l));
                pos += 16;
            }
            let kind = CommitKind::from_u8(bytes[pos])?;
            let name_len = LE::read_u16(&bytes[pos + 1..pos + 3]) as usize;
            pos += 3;
            ensure!(pos + name_len + 12 <= end, "truncated rank entry name");
            let name = std::str::from_utf8(&bytes[pos..pos + name_len])?.to_string();
            pos += name_len;
            let obj_len = LE::read_u64(&bytes[pos..pos + 8]);
            let obj_crc = LE::read_u32(&bytes[pos + 8..pos + 12]);
            pos += 12;
            ranks.push(RankObject { rank, slices, kind, name, obj_len, obj_crc });
        }
        ensure!(pos == end, "global record trailing bytes");
        let rec = GlobalRecord { model_sig, generation, step, seq, ranks };
        validate_partitions(&rec.partitions(), rec.n_params())
            .context("global record partition table")?;
        Ok(rec)
    }
}

/// One rank's verified, loaded recovery chain at the cut.
pub struct RankChain {
    pub part: Partition,
    /// the rank's newest base (full or materialized carry) at or before
    /// the cut
    pub base: ModelState,
    /// gradient diffs in `(base, cut]`, step order
    pub diffs: Vec<(u64, SparseGrad)>,
    /// every namespaced logical object this chain depends on within its
    /// own generation (the GC reachability set): base + diff objects.
    /// Cross-generation dependencies of a carry base are protected by
    /// freezing the foreign generations, not by this list.
    pub objects: Vec<String>,
    /// true when the base is a reshard carry (its references pin the
    /// previous generation)
    pub base_is_carry: bool,
}

/// How the consistent cut was found.
#[derive(Clone, Debug, Default)]
pub struct ClusterCutStats {
    pub cut_step: u64,
    pub cut_seq: u64,
    /// namespace generation of the committed record
    pub cut_gen: u64,
    /// ranks in the committed epoch (R at commit time, not restart time)
    pub ranks: usize,
    /// global records on the store
    pub records_seen: usize,
    /// newer records skipped as torn/unverifiable before the cut was found
    pub records_skipped: usize,
    /// diff steps replayed across all ranks
    pub diff_steps_applied: usize,
    /// chain objects replayed across all ranks (bases + diff/span
    /// objects) — with hierarchical compaction this is bounded by
    /// `R·(mf·⌈log_mf n⌉ + 3)` even with fulls disabled
    pub replay_objects: usize,
    /// deepest hierarchical span level among the replayed chain objects
    pub max_level: u16,
}

/// Outcome of one GC sweep: objects deleted, plus objects that *should*
/// have been deleted but could not be (a real I/O failure, not
/// already-gone) — surfaced instead of silently leaking garbage forever.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcSweepStats {
    pub removed: usize,
    pub leaked: usize,
}

/// Shard-aware logical view over the shared store (reads both sharded and
/// plain per-rank objects). Each view carries a 1-thread writer pool, so
/// callers build one per pass and share it, never one per operation.
fn logical_view(store: &Arc<dyn StorageBackend>) -> Sharded {
    Sharded::new(Arc::clone(store), 1, 1)
}

/// Walk global records newest→oldest; return the first whose referenced
/// objects and per-rank chains fully verify, with the chains loaded. At
/// equal step the newer generation wins (a reshard anchors its first
/// record at the old generation's cut step).
pub fn find_consistent_cut(
    store: &Arc<dyn StorageBackend>,
    model_sig: u64,
) -> Result<Option<(GlobalRecord, Vec<RankChain>, ClusterCutStats)>> {
    let logical = logical_view(store);
    let names = logical.list().context("listing cluster store")?;
    let mut records: Vec<(u64, u64)> = names
        .iter()
        .filter_map(|n| Manifest::parse_global(n))
        .map(|(gen, step)| (step, gen))
        .collect();
    records.sort_unstable();
    let mut stats = ClusterCutStats { records_seen: records.len(), ..Default::default() };
    for &(step, gen) in records.iter().rev() {
        let rec = logical
            .get(&Manifest::global_name(gen, step))
            .map_err(|e| format!("{e:#}"))
            .and_then(|b| GlobalRecord::from_bytes(&b).map_err(|e| format!("{e:#}")));
        let rec = match rec {
            Ok(r) if r.model_sig == model_sig && r.generation == gen => r,
            Ok(r) => {
                log::warn!(
                    "global record {gen}/{step}: foreign sig {:#x} or generation {}, skipping",
                    r.model_sig,
                    r.generation
                );
                stats.records_skipped += 1;
                continue;
            }
            Err(e) => {
                log::warn!("global record {gen}/{step} unreadable ({e}); skipping");
                stats.records_skipped += 1;
                continue;
            }
        };
        match load_chains(&logical, &names, &rec, model_sig) {
            Ok(chains) => {
                stats.cut_step = rec.step;
                stats.cut_seq = rec.seq;
                stats.cut_gen = rec.generation;
                stats.ranks = rec.ranks.len();
                stats.diff_steps_applied = chains.iter().map(|c| c.diffs.len()).sum();
                stats.replay_objects = chains.iter().map(|c| c.objects.len()).sum();
                stats.max_level = chains
                    .iter()
                    .flat_map(|c| c.objects.iter().map(|n| Manifest::span_level(n)))
                    .max()
                    .unwrap_or(0);
                return Ok(Some((rec, chains, stats)));
            }
            Err(e) => {
                log::warn!("global record {gen}/{step} not recoverable ({e:#}); falling back");
                stats.records_skipped += 1;
            }
        }
    }
    Ok(None)
}

/// Read a chain base object — a plain full, or a carry whose reference
/// intervals are resolved against the previous generation (recursively,
/// bounded by [`MAX_CARRY_DEPTH`]). Returns the rank's local state and
/// whether the outermost object was a carry.
fn resolve_base(
    logical: &Sharded,
    bytes: &[u8],
    part: &Partition,
    rsig: u64,
    model_sig: u64,
    depth: usize,
) -> Result<(ModelState, bool)> {
    match ContainerView::parse(bytes)?.kind {
        CkptKind::Full => {
            let st = read_full(bytes, rsig)?;
            ensure!(
                st.n_params() == part.len(),
                "base holds {} params, partition owns {}",
                st.n_params(),
                part.len()
            );
            Ok((st, false))
        }
        CkptKind::CarryFull => {
            ensure!(depth < MAX_CARRY_DEPTH, "carry base nested deeper than {MAX_CARRY_DEPTH}");
            let carry = read_carry(bytes, rsig)?;
            let st = if carry.refs.is_empty() {
                // a fully moved-in rank (new under the reshard): nothing
                // to resolve, the inline payload is the whole base
                let empty_part = Partition { rank: part.rank, slices: Vec::new() };
                let empty = ModelState {
                    params: crate::tensor::Flat(Vec::new()),
                    m: crate::tensor::Flat(Vec::new()),
                    v: crate::tensor::Flat(Vec::new()),
                    step: carry.step,
                };
                carry.materialize(part, &empty_part, &empty)?
            } else {
                let rec_name = Manifest::global_name(carry.src_gen, carry.src_step);
                let old_rec = GlobalRecord::from_bytes(
                    &logical.get(&rec_name).with_context(|| format!("carry src {rec_name}"))?,
                )?;
                ensure!(old_rec.model_sig == model_sig, "carry src record foreign model");
                let old_ro = old_rec
                    .ranks
                    .get(part.rank)
                    .with_context(|| format!("carry src record has no rank {}", part.rank))?;
                let old_part = old_ro.partition();
                let old_sig = rank_sig(model_sig, &old_part);
                let old_bytes = logical
                    .get(&carry.src_base)
                    .with_context(|| format!("carry src base {}", carry.src_base))?;
                let (old_state, _) =
                    resolve_base(logical, &old_bytes, &old_part, old_sig, model_sig, depth + 1)?;
                ensure!(
                    old_state.step == carry.step,
                    "carry at step {} references a base at step {}",
                    carry.step,
                    old_state.step
                );
                carry.materialize(part, &old_part, &old_state)?
            };
            Ok((st, true))
        }
        kind => bail!("unexpected base container kind {kind:?}"),
    }
}

/// Verify and load every rank chain referenced by `rec`. Any damaged,
/// missing, torn, or discontinuous piece fails the whole record. Bases
/// are resilient: a base written by a *different* partitioning carries a
/// foreign rank signature and is skipped in favor of an older base of
/// this chain's own generation, instead of failing the record.
fn load_chains(
    logical: &Sharded,
    names: &[String],
    rec: &GlobalRecord,
    model_sig: u64,
) -> Result<Vec<RankChain>> {
    let cut = rec.step;
    let gen = rec.generation;
    let mut out = Vec::with_capacity(rec.ranks.len());
    for ro in &rec.ranks {
        let part = ro.partition();
        let rsig = rank_sig(model_sig, &part);
        let rank = ro.rank as usize;
        // the committed tip must still be the committed bytes
        let tip = logical
            .get(&ro.name)
            .with_context(|| format!("rank {rank} tip {}", ro.name))?;
        ensure!(
            tip.len() as u64 == ro.obj_len && crc32fast::hash(&tip) == ro.obj_crc,
            "rank {rank} tip {} does not match the committed CRC",
            ro.name
        );
        // every chain object is fetched exactly once: the tip (base or
        // last diff) was just read, so hand its bytes back when the
        // chain walk reaches it instead of re-reading through storage
        let mut tip_bytes = Some(tip);
        let mut fetch = |name: &str| -> Result<Vec<u8>> {
            if name == ro.name {
                if let Some(b) = tip_bytes.take() {
                    return Ok(b);
                }
            }
            logical.get(name)
        };

        // candidate bases (fulls and carries), tried newest→oldest; a
        // full at the same step outranks a carry (it is self-contained)
        let mut bases: Vec<(u64, String)> = names
            .iter()
            .filter(|n| {
                Manifest::parse_gen_rank(n).map(|(g, r, _)| (g, r)) == Some((gen, rank))
            })
            .filter_map(|n| match Manifest::step_range(n) {
                Some(("full", s, _)) | Some(("carry", s, _)) if s <= cut => Some((s, n.clone())),
                _ => None,
            })
            .collect();
        bases.sort();
        let mut found: Option<(u64, String, ModelState, bool)> = None;
        for (s, name) in bases.iter().rev() {
            match fetch(name)
                .and_then(|b| resolve_base(logical, &b, &part, rsig, model_sig, 0))
            {
                Ok((st, is_carry)) if st.n_params() == part.len() => {
                    found = Some((*s, name.clone(), st, is_carry));
                    break;
                }
                _ => log::debug!("rank {rank}: base {name} foreign/unusable; trying older"),
            }
        }
        let (base_step, base_name, base, base_is_carry) = found.with_context(|| {
            format!("rank {rank}: no readable base checkpoint at or before {cut}")
        })?;

        let chain_diffs: Vec<(u64, u64, String)> = names
            .iter()
            .filter(|n| {
                Manifest::parse_gen_rank(n).map(|(g, r, _)| (g, r)) == Some((gen, rank))
            })
            .filter_map(|n| match Manifest::step_range(n) {
                // hi-based like flat discovery: a compacted span may
                // straddle the base full; its steps <= base are skipped
                // at replay below
                Some(("diff", lo, hi)) | Some(("batch", lo, hi)) | Some(("merged", lo, hi))
                    if hi > base_step && hi <= cut =>
                {
                    Some((lo, hi, n.clone()))
                }
                _ => None,
            })
            .collect();
        // non-overlapping replay cover: compacted `MergedDiff` spans win
        // over any leftover raws they supersede (crash mid-compaction)
        let chain_diffs = Manifest::select_cover(chain_diffs);

        let mut objects = vec![base_name];
        let mut diffs: Vec<(u64, SparseGrad)> = Vec::with_capacity(chain_diffs.len());
        // a complete chain steps uniformly from the base to the cut; the
        // stride heuristic is shared with flat recovery and the compactor
        // (see `Chain::stride` for the off-cadence-base rationale)
        let span_chain = Chain { full: None, diffs: chain_diffs };
        let stride = span_chain.stride(base_step);
        let chain_diffs = &span_chain.diffs;
        let mut prev_hi = base_step;
        for (i, (lo, hi, name)) in chain_diffs.iter().enumerate() {
            let hole = if i == 0 { *lo > base_step + stride } else { *lo != prev_hi + stride };
            ensure!(!hole, "rank {rank} chain hole before {name}");
            let bytes = fetch(name).with_context(|| format!("rank {rank} {name}"))?;
            let (_, items) = read_chain_object(&bytes, rsig)
                .with_context(|| format!("rank {rank} {name}"))?;
            for (step, payload) in items {
                if step <= base_step {
                    continue; // straddling span: the base already covers it
                }
                match payload {
                    DiffPayload::Gradient(g) => diffs.push((step, g)),
                    DiffPayload::StateDelta(_) => {
                        bail!("rank {rank} {name}: state-delta diff in a cluster chain")
                    }
                }
            }
            objects.push(name.clone());
            prev_hi = *hi;
        }
        ensure!(prev_hi == cut, "rank {rank} chain ends at {prev_hi}, cut is {cut}");
        diffs.sort_by_key(|(s, _)| *s);
        out.push(RankChain { part, base, diffs, objects, base_is_carry });
    }
    Ok(out)
}

/// Recover the newest consistent cluster cut as one flattened global
/// state: per-rank serial replay (exact — Adam is element-wise, so slice
/// recovery scatters bit-identically), then flatten in rank order.
pub fn recover_cluster(
    store: &Arc<dyn StorageBackend>,
    model_sig: u64,
    adam: &Adam,
) -> Result<(ModelState, ClusterCutStats)> {
    let (rec, chains, stats) = find_consistent_cut(store, model_sig)?
        .context("no consistent cluster cut — no complete global commit record found")?;
    let mut slices = Vec::with_capacity(chains.len());
    for ch in chains {
        let mut st = ch.base;
        for (_, g) in &ch.diffs {
            adam.apply_sparse(&mut st, g);
        }
        st.step = rec.step;
        slices.push((ch.part, st));
    }
    let state = crate::cluster::reshard::flatten(&slices)?;
    Ok((state, stats))
}

/// Smallest unused namespace generation on the store: one past the
/// newest generation referenced by any global record **or** any
/// gen-namespaced object (a crashed reshard may have left namespace
/// `g+1` half-written with no record). A fresh spawn that intends to
/// re-anchor writes here, so it can never overwrite a committed — or
/// even partially-written — name.
pub fn next_generation(store: &Arc<dyn StorageBackend>) -> Result<u64> {
    let logical = logical_view(store);
    let mut max: Option<u64> = None;
    for name in logical.list()? {
        let g = Manifest::parse_global(&name)
            .map(|(g, _)| g)
            .or_else(|| Manifest::parse_gen(&name).map(|(g, _)| g));
        if let Some(g) = g {
            max = Some(max.map_or(g, |m| m.max(g)));
        }
    }
    Ok(max.map_or(0, |g| g + 1))
}

/// Delete per-rank objects and global records from timelines beyond the
/// cut (stragglers of torn commits, or a lost timeline after a rollback).
/// Run after recovery, before new ranks resume writing.
pub fn truncate_stragglers(store: &Arc<dyn StorageBackend>, cut: u64) -> Result<usize> {
    let logical = logical_view(store);
    let mut removed = 0;
    for name in logical.list()? {
        let doomed = match Manifest::parse_global(&name) {
            Some((_, step)) => step > cut,
            None => {
                (Manifest::parse_gen_rank(&name).is_some() || Manifest::parse_rank(&name).is_some())
                    && matches!(Manifest::step_range(&name), Some((_, lo, _)) if lo > cut)
            }
        };
        if doomed {
            logical.delete(&name)?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// Cluster GC: keep exactly the newest complete global record and every
/// object reachable from it (each rank's base + diffs up to the cut),
/// plus any per-rank object *beyond* the cut (phase 1 of an epoch still
/// committing). Everything else — older records, torn newer records,
/// superseded per-rank objects, whole foreign generations — is deleted.
/// While the live chain's base is a carry its reference targets live in
/// older generations, so foreign generations (and all older records,
/// which the resolver walks through) are frozen until a full epoch
/// re-bases the chain. No-op when no complete record exists (never
/// delete the chain you might still recover from).
pub fn gc_cluster(store: &Arc<dyn StorageBackend>, model_sig: u64) -> Result<GcSweepStats> {
    let Some((rec, chains, _)) = find_consistent_cut(store, model_sig)? else {
        return Ok(GcSweepStats::default());
    };
    let has_carry = chains.iter().any(|c| c.base_is_carry);
    let keep: HashSet<String> = chains
        .into_iter()
        .flat_map(|c| c.objects)
        .chain(std::iter::once(rec.name()))
        .collect();
    let logical = logical_view(store);
    let names = logical.list()?;
    sweep(&logical, &names, rec.step, rec.generation, has_carry, &keep)
}

/// Commit-path GC: same sweep as [`gc_cluster`], but the keep set is
/// built **by name only** from the record the coordinator just wrote —
/// every referenced object was acked durable moments ago, so re-reading
/// and CRC-verifying the whole checkpoint (what `gc_cluster` does for an
/// untrusted store) would double storage traffic per full epoch for
/// nothing. Crate-private: only sound when `rec` is the newest record on
/// the store, which the coordinator's in-order commits guarantee.
pub(crate) fn gc_with_record(
    store: &Arc<dyn StorageBackend>,
    rec: &GlobalRecord,
) -> Result<GcSweepStats> {
    let logical = logical_view(store);
    let names = logical.list()?;
    let mut keep: HashSet<String> = HashSet::new();
    let mut has_carry = false;
    keep.insert(rec.name());
    for ro in &rec.ranks {
        keep.insert(ro.name.clone());
        has_carry |= ro.kind == CommitKind::Carry;
        let chain = Manifest::gen_rank_chain(&names, rec.generation, ro.rank as usize, rec.step);
        if let Some((_, base)) = chain.full {
            has_carry |= matches!(Manifest::step_range(&base), Some(("carry", _, _)));
            keep.insert(base);
        }
        for (_, _, diff) in chain.diffs {
            keep.insert(diff);
        }
    }
    sweep(&logical, &names, rec.step, rec.generation, has_carry, &keep)
}

/// Delete everything except `keep` and in-flight objects beyond `cut`,
/// over an already-listed logical view (one view + one listing per pass).
/// Generation scoping: names in generations other than `current_gen`
/// (and global records other than the kept one) are dropped **wholesale**
/// once the live chain is self-contained, but frozen entirely while
/// `frozen_foreign` is set (a carry base still references them).
///
/// Deletes are per object: the background compaction scheduler
/// legitimately races this sweep (it deletes raws it just superseded
/// with a merged span), so an object that is *gone* after a failed
/// delete is counted as already collected — but a delete failure with
/// the object still present is a real leak, retried once and then
/// surfaced in [`GcSweepStats::leaked`] instead of being silently
/// swallowed.
fn sweep(
    logical: &Sharded,
    names: &[String],
    cut: u64,
    current_gen: u64,
    frozen_foreign: bool,
    keep: &HashSet<String>,
) -> Result<GcSweepStats> {
    let mut stats = GcSweepStats::default();
    for name in names {
        if keep.contains(name) {
            continue;
        }
        let doomed = if Manifest::parse_global(name).is_some() {
            // the kept record is the only live one: older records are
            // superseded, newer ones failed verification (torn) — but
            // all of them stay while a carry still resolves through them
            !frozen_foreign
        } else if let Some((g, _, _)) = Manifest::parse_gen_rank(name) {
            if g == current_gen {
                // keep in-flight phase-1 objects beyond the cut
                matches!(Manifest::step_range(name), Some((_, _, hi)) if hi <= cut)
            } else {
                // foreign generation: frozen under a carry, dropped
                // wholesale once the live chain is self-contained
                !frozen_foreign
            }
        } else if Manifest::parse_rank(name).is_some() || Manifest::parse_gen(name).is_some() {
            // legacy flat-rank names and malformed generation leftovers
            // belong to no live chain
            !frozen_foreign
        } else {
            false // top-level (non-cluster) objects are not ours to collect
        };
        if doomed {
            match delete_checked(logical, name) {
                DeleteOutcome::Removed => stats.removed += 1,
                DeleteOutcome::AlreadyGone => {}
                DeleteOutcome::Leaked(e) => {
                    log::warn!("gc sweep: failed to delete {name}, leaking it ({e:#})");
                    stats.leaked += 1;
                }
            }
        }
    }
    Ok(stats)
}

enum DeleteOutcome {
    Removed,
    AlreadyGone,
    Leaked(anyhow::Error),
}

/// Delete with not-found/IO-failure discrimination: retry a failed
/// delete once, then check whether the object is actually gone (a racing
/// compactor legitimately deletes superseded raws) before declaring a
/// leak.
fn delete_checked(logical: &Sharded, name: &str) -> DeleteOutcome {
    match logical.delete(name) {
        Ok(()) => DeleteOutcome::Removed,
        Err(first) => {
            if !logical.exists(name) {
                return DeleteOutcome::AlreadyGone;
            }
            match logical.delete(name) {
                Ok(()) => DeleteOutcome::Removed,
                Err(_) if !logical.exists(name) => DeleteOutcome::AlreadyGone,
                Err(_) => DeleteOutcome::Leaked(first),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(ranks: usize) -> GlobalRecord {
        let mut pos = 0u64;
        let objs = (0..ranks)
            .map(|r| {
                let len = 10 + r as u64;
                let ro = RankObject {
                    rank: r as u32,
                    slices: vec![(pos, len)],
                    kind: if r % 2 == 0 { CommitKind::Diff } else { CommitKind::Full },
                    name: format!(
                        "{}{}",
                        Manifest::gen_rank_prefix(1, r),
                        Manifest::diff_name(7)
                    ),
                    obj_len: 100 + r as u64,
                    obj_crc: 0xABCD + r as u32,
                };
                pos += len;
                ro
            })
            .collect();
        GlobalRecord { model_sig: 0xFEED, generation: 1, step: 7, seq: 9, ranks: objs }
    }

    #[test]
    fn record_roundtrip() {
        for ranks in [1usize, 2, 5] {
            let rec = record(ranks);
            let back = GlobalRecord::from_bytes(&rec.to_bytes()).unwrap();
            assert_eq!(back, rec);
            assert_eq!(back.partitions().len(), ranks);
            assert_eq!(back.generation, 1);
        }
    }

    #[test]
    fn record_roundtrip_with_multi_slice_partitions() {
        let rec = GlobalRecord {
            model_sig: 5,
            generation: 3,
            step: 4,
            seq: 2,
            ranks: vec![
                RankObject {
                    rank: 0,
                    slices: vec![(0, 5), (10, 5)],
                    kind: CommitKind::Carry,
                    name: format!(
                        "{}{}",
                        Manifest::gen_rank_prefix(3, 0),
                        Manifest::carry_name(4)
                    ),
                    obj_len: 64,
                    obj_crc: 1,
                },
                RankObject {
                    rank: 1,
                    slices: vec![(5, 5)],
                    kind: CommitKind::Full,
                    name: format!(
                        "{}{}",
                        Manifest::gen_rank_prefix(3, 1),
                        Manifest::full_name(4)
                    ),
                    obj_len: 65,
                    obj_crc: 2,
                },
            ],
        };
        let back = GlobalRecord::from_bytes(&rec.to_bytes()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.n_params(), 15);
        assert_eq!(back.partitions()[0].slices.len(), 2);
        assert_eq!(back.ranks[0].kind, CommitKind::Carry);
    }

    #[test]
    fn record_detects_corruption_and_truncation() {
        let bytes = record(3).to_bytes();
        for cut in [0, 10, bytes.len() / 2, bytes.len() - 1] {
            assert!(GlobalRecord::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        let err = GlobalRecord::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC") || err.contains("kind") || err.contains("utf-8"), "{err}");
    }

    #[test]
    fn record_rejects_non_contiguous_partitions() {
        let mut rec = record(2);
        rec.ranks[1].slices[0].0 += 1;
        let err = GlobalRecord::from_bytes(&rec.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("partition") || err.contains("gap"), "{err}");
    }

    #[test]
    fn commit_kind_decodes() {
        assert_eq!(CommitKind::from_u8(0).unwrap(), CommitKind::Full);
        assert_eq!(CommitKind::from_u8(1).unwrap(), CommitKind::Diff);
        assert_eq!(CommitKind::from_u8(2).unwrap(), CommitKind::Carry);
        assert!(CommitKind::from_u8(9).is_err());
    }
}
