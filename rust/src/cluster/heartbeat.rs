//! Heartbeat-based failure detection for the cluster runtime.
//!
//! Every rank thread publishes a monotonic heartbeat — its current step
//! and last durably-acked step — into a shared [`HeartbeatTable`] at the
//! top of its command loop and after every durable ack. A background
//! [`Detector`] thread polls the table and declares a rank dead once its
//! newest beat lags the newest beat *anywhere in the table* by more than
//! a tunable silence threshold.
//!
//! The staleness rule is **activity-relative**, not wall-clock-relative:
//! a rank is dead iff `newest_beat_across_ranks − rank_beat > timeout`.
//! A cluster that is merely idle (nobody beating — paused training, a
//! long synchronous phase) declares nobody dead; detection needs at
//! least one live peer still making progress. That is exactly the regime
//! the consistent-cut recovery path can act in: if *every* rank is
//! silent the job itself is gone and there is no coordinator left to
//! recover it.
//!
//! Detections are deduplicated per table *epoch*: [`HeartbeatTable::reset`]
//! (called after a recovery rewires the cluster) bumps the epoch, clears
//! all beats and un-silences every rank, so the same rank can be detected
//! again in a later incarnation but only once per incarnation.
//!
//! [`HeartbeatTable::silence`] is the test/fault-injection hook: a
//! silenced rank's beats become no-ops, so it goes stale exactly like a
//! crashed process whose heart stopped — the detector cannot tell the
//! difference, which is what the detection-vs-injection equivalence test
//! pins.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Per-rank health slot: all-atomic so rank threads beat without locks.
#[derive(Debug, Default)]
struct RankHealth {
    /// nanoseconds since table start of the newest beat; 0 = never beat
    last_nanos: AtomicU64,
    /// training step the rank reported in its newest beat
    step: AtomicU64,
    /// last durably-acked step the rank reported
    acked: AtomicU64,
    /// total beats recorded (monotone; survives nothing — reset zeroes it)
    beats: AtomicU64,
    /// fault-injection: beats from a silenced rank are dropped
    silenced: AtomicBool,
}

/// One rank's row in a [`HeartbeatTable::snapshot`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankBeat {
    pub rank: usize,
    pub beats: u64,
    pub step: u64,
    pub acked: u64,
    /// seconds since this rank's newest beat (`f64::INFINITY` if never)
    pub age_secs: f64,
    pub silenced: bool,
}

/// Lock-free table of per-rank heartbeats, shared between rank threads
/// (writers), the [`Detector`] (reader) and the HTTP observability plane
/// (reader).
#[derive(Debug)]
pub struct HeartbeatTable {
    start: Instant,
    ranks: Vec<RankHealth>,
    epoch: AtomicU64,
}

impl HeartbeatTable {
    pub fn new(n_ranks: usize) -> HeartbeatTable {
        HeartbeatTable {
            start: Instant::now(),
            ranks: (0..n_ranks).map(|_| RankHealth::default()).collect(),
            epoch: AtomicU64::new(0),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.ranks.len()
    }

    /// Current table epoch; bumped by every [`reset`](Self::reset).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Record a beat for `rank`. No-op for out-of-range ranks and for
    /// silenced ranks (the fault-injection hook — a stopped heart).
    pub fn beat(&self, rank: usize, step: u64, acked: u64) {
        let Some(h) = self.ranks.get(rank) else { return };
        if h.silenced.load(Ordering::Acquire) {
            return;
        }
        h.step.store(step, Ordering::Relaxed);
        h.acked.store(acked, Ordering::Relaxed);
        h.beats.fetch_add(1, Ordering::Relaxed);
        // .max(1) keeps a beat in the first nanosecond distinguishable
        // from "never beat"
        let nanos = (self.start.elapsed().as_nanos() as u64).max(1);
        h.last_nanos.store(nanos, Ordering::Release);
    }

    /// Silence (`on = true`) or revive a rank. Silencing does not clear
    /// the rank's previous beats — it just stops new ones, so the rank
    /// ages out exactly like a crash.
    pub fn silence(&self, rank: usize, on: bool) {
        if let Some(h) = self.ranks.get(rank) {
            h.silenced.store(on, Ordering::Release);
        }
    }

    pub fn is_silenced(&self, rank: usize) -> bool {
        self.ranks
            .get(rank)
            .map(|h| h.silenced.load(Ordering::Acquire))
            .unwrap_or(false)
    }

    /// Clear every slot, un-silence every rank and bump the epoch. Called
    /// after a recovery rewires the cluster so stale pre-failure beats
    /// (and per-epoch detection dedupe) start fresh.
    pub fn reset(&self) {
        for h in &self.ranks {
            h.last_nanos.store(0, Ordering::Relaxed);
            h.step.store(0, Ordering::Relaxed);
            h.acked.store(0, Ordering::Relaxed);
            h.beats.store(0, Ordering::Relaxed);
            h.silenced.store(false, Ordering::Relaxed);
        }
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Read-only view of every rank's newest beat.
    pub fn snapshot(&self) -> Vec<RankBeat> {
        let now = (self.start.elapsed().as_nanos() as u64).max(1);
        self.ranks
            .iter()
            .enumerate()
            .map(|(rank, h)| {
                let last = h.last_nanos.load(Ordering::Acquire);
                RankBeat {
                    rank,
                    beats: h.beats.load(Ordering::Relaxed),
                    step: h.step.load(Ordering::Relaxed),
                    acked: h.acked.load(Ordering::Relaxed),
                    age_secs: if last == 0 {
                        f64::INFINITY
                    } else {
                        Duration::from_nanos(now.saturating_sub(last)).as_secs_f64()
                    },
                    silenced: h.silenced.load(Ordering::Acquire),
                }
            })
            .collect()
    }

    /// Ranks whose newest beat lags the newest beat across the whole
    /// table by more than `timeout` (activity-relative staleness; see
    /// module docs). An all-silent table declares nobody dead.
    pub fn dead_ranks(&self, timeout: Duration) -> Vec<usize> {
        let lasts: Vec<u64> = self
            .ranks
            .iter()
            .map(|h| h.last_nanos.load(Ordering::Acquire))
            .collect();
        let newest = lasts.iter().copied().max().unwrap_or(0);
        if newest == 0 {
            return Vec::new();
        }
        let timeout_nanos = timeout.as_nanos().min(u128::from(u64::MAX)) as u64;
        lasts
            .iter()
            .enumerate()
            .filter(|&(_, &last)| newest.saturating_sub(last) > timeout_nanos)
            .map(|(rank, _)| rank)
            .collect()
    }
}

/// One rank declared dead by the [`Detector`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Detection {
    pub rank: usize,
    /// seconds since detector start when the rank was declared dead
    pub at_secs: f64,
    /// last step the rank reported before going silent
    pub step: u64,
    /// last durably-acked step the rank reported before going silent
    pub acked: u64,
}

/// Background failure detector: polls a [`HeartbeatTable`] and queues one
/// [`Detection`] per `(epoch, rank)`. The driver drains detections with
/// [`take`](Detector::take) beside its `FailureInjector` poll and routes
/// both through the same consistent-cut recovery path.
#[derive(Debug)]
pub struct Detector {
    stop: Arc<AtomicBool>,
    found: Arc<Mutex<VecDeque<Detection>>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Detector {
    /// Spawn the detector thread. `poll` bounds detection latency from
    /// below; the driver uses `timeout / 4` clamped to `[1ms, 100ms]`.
    pub fn spawn(table: Arc<HeartbeatTable>, timeout: Duration, poll: Duration) -> Detector {
        let stop = Arc::new(AtomicBool::new(false));
        let found: Arc<Mutex<VecDeque<Detection>>> = Arc::new(Mutex::new(VecDeque::new()));
        let t0 = Instant::now();
        let handle = {
            let (stop, found) = (Arc::clone(&stop), Arc::clone(&found));
            thread::Builder::new()
                .name("ckpt-detect".into())
                .spawn(move || {
                    let mut seen: HashSet<usize> = HashSet::new();
                    let mut seen_epoch = table.epoch();
                    while !stop.load(Ordering::Acquire) {
                        let epoch = table.epoch();
                        if epoch != seen_epoch {
                            seen.clear();
                            seen_epoch = epoch;
                        }
                        let beats = table.snapshot();
                        for rank in table.dead_ranks(timeout) {
                            // re-check the epoch so a reset racing the
                            // scan can't leak a stale-table detection in
                            if table.epoch() != epoch {
                                break;
                            }
                            if seen.insert(rank) {
                                let b = &beats[rank];
                                found.lock().expect("detector queue").push_back(Detection {
                                    rank,
                                    at_secs: t0.elapsed().as_secs_f64(),
                                    step: b.step,
                                    acked: b.acked,
                                });
                            }
                        }
                        thread::sleep(poll);
                    }
                })
                .expect("spawn detector thread")
        };
        Detector { stop, found, handle: Some(handle) }
    }

    /// Pop the oldest undelivered detection, if any.
    pub fn take(&self) -> Option<Detection> {
        self.found.lock().expect("detector queue").pop_front()
    }

    /// Stop and join the detector thread (idempotent).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Detector {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beats_update_the_snapshot() {
        let t = HeartbeatTable::new(3);
        assert_eq!(t.n_ranks(), 3);
        t.beat(1, 42, 40);
        t.beat(1, 43, 40);
        t.beat(99, 1, 1); // out of range: ignored
        let snap = t.snapshot();
        assert_eq!(snap[1].beats, 2);
        assert_eq!(snap[1].step, 43);
        assert_eq!(snap[1].acked, 40);
        assert!(snap[1].age_secs.is_finite());
        assert_eq!(snap[0].beats, 0);
        assert!(snap[0].age_secs.is_infinite(), "never beat");
    }

    #[test]
    fn staleness_is_activity_relative() {
        let t = HeartbeatTable::new(2);
        // nobody has beaten: an idle table declares nobody dead
        assert!(t.dead_ranks(Duration::from_millis(1)).is_empty());
        t.beat(0, 1, 0);
        t.beat(1, 1, 0);
        std::thread::sleep(Duration::from_millis(20));
        // both silent: still nobody dead — staleness is peer-relative
        assert!(t.dead_ranks(Duration::from_millis(5)).is_empty());
        // rank 0 advances; rank 1 now lags the newest beat
        t.beat(0, 2, 1);
        assert_eq!(t.dead_ranks(Duration::from_millis(5)), vec![1]);
        // a huge timeout tolerates the same lag
        assert!(t.dead_ranks(Duration::from_secs(60)).is_empty());
        // rank 1 revives
        t.beat(1, 2, 1);
        assert!(t.dead_ranks(Duration::from_millis(5)).is_empty());
    }

    #[test]
    fn silence_drops_beats_and_reset_revives() {
        let t = HeartbeatTable::new(2);
        t.beat(0, 1, 0);
        t.silence(0, true);
        assert!(t.is_silenced(0));
        t.beat(0, 2, 1);
        let snap = t.snapshot();
        assert_eq!(snap[0].beats, 1, "silenced beat dropped");
        assert_eq!(snap[0].step, 1);
        let e0 = t.epoch();
        t.reset();
        assert_eq!(t.epoch(), e0 + 1);
        assert!(!t.is_silenced(0));
        let snap = t.snapshot();
        assert_eq!(snap[0].beats, 0);
        assert!(snap[0].age_secs.is_infinite());
        t.beat(0, 5, 5);
        assert_eq!(t.snapshot()[0].beats, 1, "revived after reset");
    }

    #[test]
    fn detector_fires_once_per_epoch() {
        let table = Arc::new(HeartbeatTable::new(2));
        let det = Detector::spawn(
            Arc::clone(&table),
            Duration::from_millis(15),
            Duration::from_millis(2),
        );
        // rank 0 beats steadily; rank 1 beat once, then went silent
        table.beat(1, 7, 6);
        let t0 = Instant::now();
        let mut first = None;
        while first.is_none() && t0.elapsed() < Duration::from_secs(5) {
            table.beat(0, 1, 1);
            std::thread::sleep(Duration::from_millis(2));
            first = det.take();
        }
        let d = first.expect("silent rank detected");
        assert_eq!(d.rank, 1);
        assert_eq!(d.step, 7);
        assert_eq!(d.acked, 6);
        // deduped: no second detection for the same incarnation
        for _ in 0..10 {
            table.beat(0, 2, 2);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(det.take().is_none(), "one detection per (epoch, rank)");
        // a reset starts a new incarnation: the same rank can die again
        table.reset();
        let t0 = Instant::now();
        let mut second = None;
        while second.is_none() && t0.elapsed() < Duration::from_secs(5) {
            table.beat(0, 3, 3);
            std::thread::sleep(Duration::from_millis(2));
            second = det.take();
        }
        assert_eq!(second.expect("re-detected after reset").rank, 1);
    }
}
