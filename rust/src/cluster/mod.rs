//! Multi-rank cluster runtime: per-rank differential chains, two-phase
//! global commit, and elastic resharded recovery.
//!
//! The single-process coordinator treats `TrainConfig::workers` as logical
//! replicas of one global state; a *distributed* training system
//! checkpoints differently (Checkmate, Check-N-Run): every rank owns a
//! partition of the model + optimizer state, persists its own differential
//! chain concurrently, and a coordinator stitches the per-rank chains into
//! recoverable cross-rank epochs. This module is that orchestration layer,
//! built on the storage engine of PRs 1–2:
//!
//! - [`Partition`] / [`partition_hash`] / [`partition_even`]: each rank
//!   owns a set of fixed-boundary *slices* of the flat parameter vector.
//!   [`partition_hash`] assigns slices by virtual-node consistent
//!   hashing, so an elastic R→R′ event remaps only the slices claimed by
//!   added ranks (or orphaned by removed ones) — ~|ΔR|/max(R,R′) of the
//!   parameters — instead of all of them.
//! - [`rank::Cluster`]: N rank threads, each writing its chain under an
//!   immutable `gen-{g:04}/rank-{r:04}/` namespace
//!   ([`Namespaced`](crate::storage::Namespaced)) through its own
//!   [`BufPool`](crate::util::bufpool::BufPool) and — when configured —
//!   its own [`Sharded`](crate::storage::Sharded) engine.
//! - [`commit`]: the two-phase global commit (phase 1: every rank's
//!   object durable; phase 2: one `global-{g:04}-{step:012}.gck` record
//!   listing every rank's object + CRC), consistent-cut recovery,
//!   straggler truncation, and cluster GC.
//! - [`reshard`]: elastic restart with R′ ≠ R ranks — recover the cut,
//!   open a fresh generation, and carry state + merged spans across
//!   incrementally (moved slices inline, retained slices by reference).
//!
//! Because Adam is element-wise, recovering each rank's slices
//! independently and scattering is **bit-identical** to recovering the
//! global state in one piece — the property the integration tests pin.
//! Ordering rules and the consistent-cut definition are documented in
//! `docs/CLUSTER.md`.

pub mod commit;
pub mod heartbeat;
pub mod rank;
pub mod reshard;

pub use commit::{
    find_consistent_cut, gc_cluster, next_generation, recover_cluster, truncate_stragglers,
    ClusterCutStats, CommitKind, GcSweepStats, GlobalRecord, RankObject,
};
pub use heartbeat::{Detection, Detector, HeartbeatTable, RankBeat};
pub use rank::{Cluster, ClusterStats};
pub use reshard::{elastic_restart, flatten, repartition};

use anyhow::{ensure, Result};

use crate::checkpoint::format::PayloadCodec;
use crate::optim::ModelState;
use crate::tensor::Flat;

/// One contiguous interval of the flat parameter vector (the optimizer
/// moments are sliced with the same range — a slice owns 3·len state
/// words).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Slice {
    pub offset: usize,
    pub len: usize,
}

impl Slice {
    pub fn end(&self) -> usize {
        self.offset + self.len
    }
}

/// One rank's share of the flat parameter vector: a sorted set of
/// disjoint [`Slice`]s. A rank's *local* state is the concatenation of
/// its slices in offset order; `local_of_global`/`global_of_local`
/// translate between the two index spaces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub rank: usize,
    pub slices: Vec<Slice>,
}

impl Partition {
    /// A single-slice partition (the classic contiguous layout).
    pub fn contiguous(rank: usize, offset: usize, len: usize) -> Partition {
        Partition { rank, slices: vec![Slice { offset, len }] }
    }

    /// Total parameters owned.
    pub fn len(&self) -> usize {
        self.slices.iter().map(|s| s.len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Global index ranges in offset order.
    pub fn ranges(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        self.slices.iter().map(|s| s.offset..s.end())
    }

    /// Local (concatenated) index of global index `g`, `None` if this
    /// partition does not own it.
    pub fn local_of_global(&self, g: usize) -> Option<usize> {
        let mut base = 0usize;
        // binary search for the last slice starting at or before g
        let i = self.slices.partition_point(|s| s.offset <= g);
        for s in &self.slices[..i] {
            base += s.len;
        }
        let s = self.slices.get(i.checked_sub(1)?)?;
        (g < s.end()).then(|| base - s.len + (g - s.offset))
    }

    /// Global index of local (concatenated) index `l`.
    pub fn global_of_local(&self, l: usize) -> usize {
        let mut rem = l;
        for s in &self.slices {
            if rem < s.len {
                return s.offset + rem;
            }
            rem -= s.len;
        }
        panic!("local index {l} out of range for partition of {} params", self.len());
    }
}

/// Split `n` parameters across `ranks` contiguous near-equal partitions
/// (first partitions take the remainder). For synthetic states without
/// elastic events; every partition is non-empty.
pub fn partition_even(n: usize, ranks: usize) -> Vec<Partition> {
    assert!(ranks >= 1, "need at least one rank");
    assert!(n >= ranks, "need at least one parameter per rank");
    let base = n / ranks;
    let rem = n % ranks;
    let mut out = Vec::with_capacity(ranks);
    let mut pos = 0;
    for rank in 0..ranks {
        let len = base + usize::from(rank < rem);
        out.push(Partition::contiguous(rank, pos, len));
        pos += len;
    }
    out
}

/// Virtual nodes per rank on the consistent-hash ring. More vnodes means
/// better balance per rank at a slightly larger ring.
const VNODES_PER_RANK: usize = 64;

/// Hash-domain separators for ring vnodes vs. slice keys.
const SEED_VNODE: u64 = 0x7A_D0DE;
const SEED_SLICE: u64 = 0x51_1CE3;

/// Hash slices the parameter vector is cut into (upper bound; small
/// models get one-parameter slices).
const HASH_SLICES: usize = 512;

fn fnv1a(seed: u64, words: &[u64]) -> u64 {
    let mut h = seed ^ 0xcbf29ce484222325;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    // FNV-1a alone clusters badly on short sequential-integer inputs —
    // vnodes of one rank bunch together on the ring, which inflates the
    // moved fraction of an elastic event well past |ΔR|/max(R, R′). The
    // splitmix64 finalizer restores avalanche while staying seed-free
    // and deterministic.
    h = h.wrapping_add(0x9E3779B97F4A7C15);
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
    h ^ (h >> 31)
}

/// Boundaries of the fixed hash slices for an `n`-parameter vector. The
/// cut points depend only on `n` — never on the rank count — which is
/// what makes reassignment incremental: an R→R′ event moves whole slices
/// between ranks, it never re-cuts them.
fn hash_slice_bounds(n: usize) -> Vec<Slice> {
    let slice_len = n.div_ceil(HASH_SLICES).max(1);
    let mut out = Vec::with_capacity(n.div_ceil(slice_len));
    let mut off = 0;
    while off < n {
        let len = slice_len.min(n - off);
        out.push(Slice { offset: off, len });
        off += len;
    }
    out
}

/// Assign the flat parameter vector to `ranks` ranks by virtual-node
/// consistent hashing: every rank plants [`VNODES_PER_RANK`] points on a
/// hash ring, every fixed slice of the vector hashes to a ring position,
/// and the slice belongs to the first vnode clockwise. Growing or
/// shrinking the rank set moves only the slices whose closest vnode
/// changed — in expectation |ΔR|/max(R, R′) of the parameters — while
/// every retained rank keeps the rest of its share untouched.
///
/// Deterministic (pure hashing, no RNG): the same `(n, ranks)` always
/// yields the same table, so an elastic restart recomputes the old
/// partitioning from the rank count alone. Adjacent same-owner slices
/// are coalesced; a rank left empty by the ring (rare, but possible for
/// small `n`) deterministically steals a slice from the richest rank, so
/// the table always validates.
pub fn partition_hash(n: usize, ranks: usize) -> Vec<Partition> {
    assert!(ranks >= 1, "need at least one rank");
    assert!(n >= ranks, "need at least one parameter per rank");
    // ring of (position, rank) vnodes, position ties broken by rank
    let mut ring: Vec<(u64, usize)> = (0..ranks)
        .flat_map(|r| {
            (0..VNODES_PER_RANK).map(move |v| (fnv1a(SEED_VNODE, &[r as u64, v as u64]), r))
        })
        .collect();
    ring.sort_unstable();
    let owner_of = |h: u64| -> usize {
        let i = ring.partition_point(|&(pos, _)| pos < h);
        ring[i % ring.len()].1
    };

    let bounds = hash_slice_bounds(n);
    let mut owners: Vec<usize> = (0..bounds.len())
        .map(|i| owner_of(fnv1a(SEED_SLICE, &[i as u64])))
        .collect();

    // every rank must own at least one slice: deterministically steal the
    // highest-index slice from the (lowest-id) richest rank
    loop {
        let mut counts = vec![0usize; ranks];
        for &o in &owners {
            counts[o] += 1;
        }
        let Some(empty) = counts.iter().position(|&c| c == 0) else { break };
        let rich = counts
            .iter()
            .enumerate()
            .max_by_key(|&(r, &c)| (c, std::cmp::Reverse(r)))
            .map(|(r, _)| r)
            .expect("ranks >= 1");
        let steal = owners
            .iter()
            .rposition(|&o| o == rich)
            .expect("richest rank owns a slice");
        owners[steal] = empty;
    }

    // coalesce adjacent same-owner slices into runs per rank
    let mut out: Vec<Partition> =
        (0..ranks).map(|rank| Partition { rank, slices: Vec::new() }).collect();
    for (s, &o) in bounds.iter().zip(&owners) {
        match out[o].slices.last_mut() {
            Some(last) if last.end() == s.offset => last.len += s.len,
            _ => out[o].slices.push(*s),
        }
    }
    out
}

/// Validate that `parts` tile `[0, n)` exactly in rank order: one entry
/// per rank, each non-empty with sorted disjoint slices, and the union of
/// all slices covering every parameter exactly once.
pub fn validate_partitions(parts: &[Partition], n: usize) -> Result<()> {
    ensure!(!parts.is_empty(), "empty partition table");
    let mut all: Vec<Slice> = Vec::new();
    for (i, p) in parts.iter().enumerate() {
        ensure!(p.rank == i, "partition {i} labeled rank {}", p.rank);
        ensure!(!p.is_empty(), "partition {i} is empty");
        let mut end = 0usize;
        let mut first = true;
        for s in &p.slices {
            ensure!(s.len > 0, "partition {i} has an empty slice");
            ensure!(
                first || s.offset > end,
                "partition {i} slices unsorted or overlapping at {}",
                s.offset
            );
            first = false;
            end = s.end();
            all.push(*s);
        }
    }
    all.sort_unstable();
    let mut pos = 0usize;
    for s in &all {
        ensure!(s.offset == pos, "slice at {} leaves a gap or overlap at {pos}", s.offset);
        pos = s.end();
    }
    ensure!(pos == n, "partitions cover {pos} of {n} params");
    Ok(())
}

/// Layout signature of one rank's share: the model signature mixed with
/// every slice range (FNV-1a). Binds a rank's chain objects to both the
/// model *and* the partitioning that produced them, so chains from a
/// differently-sharded timeline can never be silently mixed.
pub fn rank_sig(model_sig: u64, part: &Partition) -> u64 {
    let mut h = model_sig ^ 0x9E37_79B9_7F4A_7C15;
    for s in &part.slices {
        for b in (s.offset as u64)
            .to_le_bytes()
            .into_iter()
            .chain((s.len as u64).to_le_bytes())
        {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Extract one rank's local state: its slices of params/m/v concatenated
/// in offset order (the step travels along).
pub fn slice_state(state: &ModelState, part: &Partition) -> ModelState {
    let len = part.len();
    let mut params = Vec::with_capacity(len);
    let mut m = Vec::with_capacity(len);
    let mut v = Vec::with_capacity(len);
    for r in part.ranges() {
        params.extend_from_slice(&state.params.0[r.clone()]);
        m.extend_from_slice(&state.m.0[r.clone()]);
        v.extend_from_slice(&state.v.0[r]);
    }
    ModelState { params: Flat(params), m: Flat(m), v: Flat(v), step: state.step }
}

/// Slice a dense (masked) gradient per partition — the training thread's
/// only per-rank cost is this one Ψ-sized copy, fanned out to the rank
/// threads which compact their slices off the training path.
pub fn split_dense(grad: &Flat, parts: &[Partition]) -> Vec<Flat> {
    parts
        .iter()
        .map(|p| {
            let mut out = Vec::with_capacity(p.len());
            for r in p.ranges() {
                out.extend_from_slice(&grad.0[r]);
            }
            Flat(out)
        })
        .collect()
}

/// Configuration shared by every rank thread and the commit coordinator.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub model_sig: u64,
    pub codec: PayloadCodec,
    /// namespace generation the cluster writes into (`gen-{g:04}/…`).
    /// Bumped by every elastic restart so committed names of the previous
    /// generation are never overwritten in place
    pub generation: u64,
    /// shards per rank object; >1 (or `writers` > 1) gives each rank its
    /// own sharded async engine over its namespace
    pub n_shards: usize,
    /// storage writer-pool threads per rank engine
    pub writers: usize,
    /// run cluster GC after every committed full-checkpoint epoch
    pub gc: bool,
    /// per-rank command-queue depth (training-thread backpressure)
    pub queue_capacity: usize,
    /// background chain compaction: every this many committed diff epochs
    /// the scheduler merges runs of that many raw per-rank diff objects
    /// (strictly below the cut) into `MergedDiff` spans; < 2 disables.
    /// Retunable at runtime via [`Cluster::set_compact_every`] — applied
    /// by the coordinator at the next committed epoch boundary so every
    /// rank switches at the same committed epoch
    pub compact_every: usize,
    /// background-I/O byte budget for the compaction scheduler's
    /// token-bucket gate (`--io-budget`); <= 0 leaves the bucket open
    pub io_budget: f64,
    /// control-plane telemetry bus: rank persists, the commit thread and
    /// the compaction scheduler feed it; its presence spawns the
    /// scheduler thread even at `compact_every < 2` so actuation can
    /// enable compaction live
    pub telemetry: Option<std::sync::Arc<crate::control::telemetry::TelemetryBus>>,
    /// shared I/O gate for the compaction scheduler; when set it is used
    /// instead of building a private gate from `io_budget`, so live
    /// budget retunes ([`IoGate::set_rate`](crate::control::IoGate)) made
    /// by the driver reach cluster compaction too
    pub gate: Option<std::sync::Arc<crate::control::IoGate>>,
    /// event tracer: rank encode/persist spans, commit phase-1/phase-2
    /// events and scheduler compaction passes are recorded when set
    pub trace: Option<std::sync::Arc<crate::control::Tracer>>,
    /// heartbeat table: each rank thread beats at loop start and after
    /// every durable ack; a silenced rank also stops acking (full death
    /// simulation for the failure detector)
    pub heartbeats: Option<std::sync::Arc<heartbeat::HeartbeatTable>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            model_sig: 0,
            codec: PayloadCodec::Raw,
            generation: 0,
            n_shards: 1,
            writers: 1,
            gc: true,
            queue_capacity: 8,
            compact_every: 0,
            io_budget: 0.0,
            telemetry: None,
            gate: None,
            trace: None,
            heartbeats: None,
        }
    }
}

impl ClusterConfig {
    /// True when the runtime control plane is attached.
    pub fn uses_control(&self) -> bool {
        self.telemetry.is_some() || self.io_budget > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_partitions_tile_exactly() {
        for (n, r) in [(10usize, 3usize), (7, 7), (100, 4), (5, 1)] {
            let parts = partition_even(n, r);
            assert_eq!(parts.len(), r);
            validate_partitions(&parts, n).unwrap();
            let spread = parts.iter().map(|p| p.len()).max().unwrap()
                - parts.iter().map(|p| p.len()).min().unwrap();
            assert!(spread <= 1, "near-equal split");
        }
    }

    #[test]
    fn hash_partitions_tile_and_are_deterministic() {
        for (n, r) in [(10_000usize, 8usize), (10_000, 12), (10_000, 4), (513, 3), (8, 8)] {
            let parts = partition_hash(n, r);
            assert_eq!(parts.len(), r);
            validate_partitions(&parts, n).unwrap();
            assert_eq!(parts, partition_hash(n, r), "pure function of (n, ranks)");
        }
    }

    /// Per-parameter owner table for a partitioning.
    fn owner_table(parts: &[Partition], n: usize) -> Vec<usize> {
        let mut owners = vec![usize::MAX; n];
        for p in parts {
            for r in p.ranges() {
                for o in &mut owners[r] {
                    *o = p.rank;
                }
            }
        }
        owners
    }

    #[test]
    fn hash_partitions_move_few_params_on_elastic_events() {
        let n = 100_000;
        let old = owner_table(&partition_hash(n, 8), n);
        for new_ranks in [12usize, 4] {
            let new = owner_table(&partition_hash(n, new_ranks), n);
            let moved = old.iter().zip(&new).filter(|(a, b)| a != b).count();
            let frac = moved as f64 / n as f64;
            // theory: growth 8→12 moves ~4/12, shrink 8→4 moves ~4/8 of
            // parameters; allow generous slack for ring imbalance
            let expect = (new_ranks as f64 - 8.0).abs() / 8.0f64.max(new_ranks as f64);
            assert!(
                frac < expect + 0.15,
                "8→{new_ranks} moved {frac:.3} of params (theory ~{expect:.3})"
            );
            assert!(frac > 0.0, "an elastic event must move something");
        }
    }

    #[test]
    fn hash_partitions_are_roughly_balanced() {
        let n = 100_000;
        for ranks in [4usize, 8, 12] {
            let parts = partition_hash(n, ranks);
            let mean = n as f64 / ranks as f64;
            for p in &parts {
                let share = p.len() as f64 / mean;
                assert!(
                    (0.3..3.0).contains(&share),
                    "rank {} owns {:.2}x its fair share",
                    p.rank,
                    share
                );
            }
        }
    }

    #[test]
    fn hash_partitions_fill_empty_ranks() {
        // tiny models force the steal path: every rank still owns a slice
        for (n, r) in [(8usize, 8usize), (20, 16), (512, 100)] {
            let parts = partition_hash(n, r);
            validate_partitions(&parts, n).unwrap();
            assert!(parts.iter().all(|p| !p.is_empty()));
        }
    }

    #[test]
    fn partition_index_maps_roundtrip() {
        let part = Partition {
            rank: 0,
            slices: vec![Slice { offset: 3, len: 2 }, Slice { offset: 10, len: 3 }],
        };
        assert_eq!(part.len(), 5);
        for l in 0..part.len() {
            let g = part.global_of_local(l);
            assert_eq!(part.local_of_global(g), Some(l));
        }
        assert_eq!(part.local_of_global(0), None);
        assert_eq!(part.local_of_global(5), None);
        assert_eq!(part.local_of_global(9), None);
        assert_eq!(part.local_of_global(13), None);
        assert_eq!(part.local_of_global(3), Some(0));
        assert_eq!(part.local_of_global(12), Some(4));
    }

    #[test]
    fn rank_sig_distinguishes_partitionings() {
        let a = Partition::contiguous(0, 0, 50);
        let b = Partition::contiguous(0, 0, 60);
        let c = Partition::contiguous(1, 50, 50);
        let d = Partition {
            rank: 0,
            slices: vec![Slice { offset: 0, len: 25 }, Slice { offset: 25, len: 25 }],
        };
        assert_ne!(rank_sig(7, &a), rank_sig(7, &b));
        assert_ne!(rank_sig(7, &a), rank_sig(7, &c));
        assert_ne!(rank_sig(7, &a), rank_sig(8, &a));
        assert_ne!(rank_sig(7, &a), rank_sig(7, &d), "slice structure is part of the sig");
        assert_eq!(rank_sig(7, &a), rank_sig(7, &a));
    }

    #[test]
    fn slice_and_split_cover_the_state() {
        let n = 10;
        let state = ModelState {
            params: Flat((0..n).map(|i| i as f32).collect()),
            m: Flat((0..n).map(|i| 10.0 + i as f32).collect()),
            v: Flat((0..n).map(|i| 20.0 + i as f32).collect()),
            step: 3,
        };
        let parts = partition_even(n, 3);
        let slices: Vec<ModelState> = parts.iter().map(|p| slice_state(&state, p)).collect();
        assert_eq!(slices[0].params.0, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(slices[2].v.0, vec![27.0, 28.0, 29.0]);
        assert!(slices.iter().all(|s| s.step == 3));
        let dense = Flat((0..n).map(|i| -(i as f32)).collect());
        let split = split_dense(&dense, &parts);
        let total: usize = split.iter().map(|f| f.len()).sum();
        assert_eq!(total, n);
        assert_eq!(split[1].0, vec![-4.0, -5.0, -6.0]);
        // a discontiguous partition concatenates its slices in order
        let scattered = Partition {
            rank: 0,
            slices: vec![Slice { offset: 1, len: 2 }, Slice { offset: 7, len: 1 }],
        };
        let st = slice_state(&state, &scattered);
        assert_eq!(st.params.0, vec![1.0, 2.0, 7.0]);
        assert_eq!(st.m.0, vec![11.0, 12.0, 17.0]);
        assert_eq!(split_dense(&dense, &[scattered])[0].0, vec![-1.0, -2.0, -7.0]);
    }

    #[test]
    fn validate_rejects_gaps_overlaps_and_mislabels() {
        let mut parts = partition_even(10, 2);
        assert!(validate_partitions(&parts, 11).is_err());
        parts[1].slices[0].offset = 6;
        assert!(validate_partitions(&parts, 10).is_err());
        let mut relabeled = partition_even(10, 2);
        relabeled[1].rank = 0;
        assert!(validate_partitions(&relabeled, 10).is_err());
        // unsorted slices within one partition
        let bad = vec![Partition {
            rank: 0,
            slices: vec![Slice { offset: 5, len: 5 }, Slice { offset: 0, len: 5 }],
        }];
        assert!(validate_partitions(&bad, 10).is_err());
        // overlap across ranks
        let overlap = vec![
            Partition::contiguous(0, 0, 6),
            Partition::contiguous(1, 5, 5),
        ];
        assert!(validate_partitions(&overlap, 10).is_err());
    }
}
