//! Multi-rank cluster runtime: per-rank differential chains, two-phase
//! global commit, and elastic resharded recovery.
//!
//! The single-process coordinator treats `TrainConfig::workers` as logical
//! replicas of one global state; a *distributed* training system
//! checkpoints differently (Checkmate, Check-N-Run): every rank owns a
//! partition of the model + optimizer state, persists its own differential
//! chain concurrently, and a coordinator stitches the per-rank chains into
//! recoverable cross-rank epochs. This module is that orchestration layer,
//! built on the storage engine of PRs 1–2:
//!
//! - [`Partition`] / [`partition_layout`] / [`partition_even`]: contiguous
//!   slices of the flat parameter vector, split at tensor boundaries.
//! - [`rank::Cluster`]: N rank threads, each writing its chain under a
//!   `rank-{r:04}/` namespace ([`Namespaced`](crate::storage::Namespaced))
//!   through its own [`BufPool`](crate::util::bufpool::BufPool) and —
//!   when configured — its own [`Sharded`](crate::storage::Sharded)
//!   engine.
//! - [`commit`]: the two-phase global commit (phase 1: every rank's
//!   object durable; phase 2: one `global-{step:012}.gck` record listing
//!   every rank's object + CRC), consistent-cut recovery, straggler
//!   truncation, and cluster GC.
//! - [`reshard`]: elastic restart with R′ ≠ R ranks — recover the cut,
//!   flatten, repartition.
//!
//! Because Adam is element-wise, recovering each rank's slice
//! independently and concatenating is **bit-identical** to recovering the
//! global state in one piece — the property the integration tests pin.
//! Ordering rules and the consistent-cut definition are documented in
//! `docs/CLUSTER.md`.

pub mod commit;
pub mod rank;
pub mod reshard;

pub use commit::{
    gc_cluster, recover_cluster, recover_cluster_or_net, truncate_stragglers, ClusterCutStats,
    GlobalRecord,
};
pub use rank::{Cluster, ClusterStats};
pub use reshard::{elastic_restart, flatten, repartition};

use anyhow::{ensure, Result};

use crate::checkpoint::format::PayloadCodec;
use crate::model::Layout;
use crate::optim::ModelState;
use crate::tensor::Flat;

/// One rank's contiguous slice of the flat parameter vector (the optimizer
/// moments are sliced with the same range — a partition owns 3·len state
/// words).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    pub rank: usize,
    pub offset: usize,
    pub len: usize,
}

impl Partition {
    pub fn end(&self) -> usize {
        self.offset + self.len
    }
}

/// Split `n` parameters across `ranks` contiguous near-equal partitions
/// (first partitions take the remainder). For synthetic states without a
/// tensor layout; every partition is non-empty.
pub fn partition_even(n: usize, ranks: usize) -> Vec<Partition> {
    assert!(ranks >= 1, "need at least one rank");
    assert!(n >= ranks, "need at least one parameter per rank");
    let base = n / ranks;
    let rem = n % ranks;
    let mut out = Vec::with_capacity(ranks);
    let mut pos = 0;
    for rank in 0..ranks {
        let len = base + usize::from(rank < rem);
        out.push(Partition { rank, offset: pos, len });
        pos += len;
    }
    out
}

/// Split a model layout across `ranks` at **tensor boundaries**, greedily
/// balancing parameter counts: each rank takes whole tensors until it
/// reaches its proportional share, while always leaving at least one
/// tensor per remaining rank.
pub fn partition_layout(layout: &Layout, ranks: usize) -> Result<Vec<Partition>> {
    ensure!(ranks >= 1, "need at least one rank");
    ensure!(
        layout.n_tensors() >= ranks,
        "cannot split {} tensors across {ranks} ranks",
        layout.n_tensors()
    );
    let n = layout.n_params;
    let n_tensors = layout.tensors.len();
    let mut out = Vec::with_capacity(ranks);
    let mut t = 0usize; // next unassigned tensor
    for rank in 0..ranks {
        let start = layout.tensors[t].offset;
        let remaining = ranks - rank - 1;
        let target_end = n * (rank + 1) / ranks;
        let mut end_t = t;
        if remaining == 0 {
            end_t = n_tensors - 1;
        } else {
            while end_t + 1 < n_tensors - remaining {
                let tensor = &layout.tensors[end_t];
                if tensor.offset + tensor.len >= target_end {
                    break;
                }
                end_t += 1;
            }
        }
        let last = &layout.tensors[end_t];
        out.push(Partition { rank, offset: start, len: last.offset + last.len - start });
        t = end_t + 1;
    }
    Ok(out)
}

/// Validate that `parts` tile `[0, n)` contiguously in rank order.
pub fn validate_partitions(parts: &[Partition], n: usize) -> Result<()> {
    ensure!(!parts.is_empty(), "empty partition table");
    let mut pos = 0usize;
    for (i, p) in parts.iter().enumerate() {
        ensure!(p.rank == i, "partition {i} labeled rank {}", p.rank);
        ensure!(p.offset == pos, "partition {i} starts at {} != {pos}", p.offset);
        ensure!(p.len > 0, "partition {i} is empty");
        pos = p.end();
    }
    ensure!(pos == n, "partitions cover {pos} of {n} params");
    Ok(())
}

/// Layout signature of one rank's slice: the model signature mixed with
/// the partition range (FNV-1a). Binds a rank's chain objects to both the
/// model *and* the partitioning that produced them, so chains from a
/// differently-sharded timeline can never be silently mixed.
pub fn rank_sig(model_sig: u64, part: &Partition) -> u64 {
    let mut h = model_sig ^ 0x9E37_79B9_7F4A_7C15;
    for b in (part.offset as u64)
        .to_le_bytes()
        .into_iter()
        .chain((part.len as u64).to_le_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Extract one rank's slice of the global state (params, m, v share the
/// partition range; the step travels along).
pub fn slice_state(state: &ModelState, part: &Partition) -> ModelState {
    let r = part.offset..part.end();
    ModelState {
        params: Flat(state.params.0[r.clone()].to_vec()),
        m: Flat(state.m.0[r.clone()].to_vec()),
        v: Flat(state.v.0[r].to_vec()),
        step: state.step,
    }
}

/// Slice a dense (masked) gradient per partition — the training thread's
/// only per-rank cost is this one Ψ-sized copy, fanned out to the rank
/// threads which compact their slices off the training path.
pub fn split_dense(grad: &Flat, parts: &[Partition]) -> Vec<Flat> {
    parts
        .iter()
        .map(|p| Flat(grad.0[p.offset..p.end()].to_vec()))
        .collect()
}

/// Configuration shared by every rank thread and the commit coordinator.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub model_sig: u64,
    pub codec: PayloadCodec,
    /// shards per rank object; >1 (or `writers` > 1) gives each rank its
    /// own sharded async engine over its namespace
    pub n_shards: usize,
    /// storage writer-pool threads per rank engine
    pub writers: usize,
    /// run cluster GC after every committed full-checkpoint epoch
    pub gc: bool,
    /// per-rank command-queue depth (training-thread backpressure)
    pub queue_capacity: usize,
    /// background chain compaction: every this many committed diff epochs
    /// the scheduler merges runs of that many raw per-rank diff objects
    /// (strictly below the cut) into `MergedDiff` spans; < 2 disables.
    /// Retunable at runtime via [`Cluster::set_compact_every`] — applied
    /// by the coordinator at the next committed epoch boundary so every
    /// rank switches at the same committed epoch
    pub compact_every: usize,
    /// background-I/O byte budget for the compaction scheduler's
    /// token-bucket gate (`--io-budget`); <= 0 leaves the bucket open
    pub io_budget: f64,
    /// control-plane telemetry bus: rank persists, the commit thread and
    /// the compaction scheduler feed it; its presence spawns the
    /// scheduler thread even at `compact_every < 2` so actuation can
    /// enable compaction live
    pub telemetry: Option<std::sync::Arc<crate::control::telemetry::TelemetryBus>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            model_sig: 0,
            codec: PayloadCodec::Raw,
            n_shards: 1,
            writers: 1,
            gc: true,
            queue_capacity: 8,
            compact_every: 0,
            io_budget: 0.0,
            telemetry: None,
        }
    }
}

impl ClusterConfig {
    /// True when the runtime control plane is attached.
    pub fn uses_control(&self) -> bool {
        self.telemetry.is_some() || self.io_budget > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    fn layout(lens: &[usize]) -> Layout {
        let mut tensors = Vec::new();
        let mut off = 0;
        for (i, &len) in lens.iter().enumerate() {
            tensors.push(TensorSpec { name: format!("t{i}"), offset: off, len });
            off += len;
        }
        Layout {
            model: "test".into(),
            n_params: off,
            vocab: 16,
            seq_len: 8,
            batch: 1,
            rho: 0.01,
            k: 1,
            lr: 1e-3,
            tensors,
        }
    }

    #[test]
    fn even_partitions_tile_exactly() {
        for (n, r) in [(10usize, 3usize), (7, 7), (100, 4), (5, 1)] {
            let parts = partition_even(n, r);
            assert_eq!(parts.len(), r);
            validate_partitions(&parts, n).unwrap();
            let spread = parts.iter().map(|p| p.len).max().unwrap()
                - parts.iter().map(|p| p.len).min().unwrap();
            assert!(spread <= 1, "near-equal split");
        }
    }

    #[test]
    fn layout_partitions_respect_tensor_boundaries() {
        let l = layout(&[10, 30, 20, 25, 15]);
        for ranks in 1..=5usize {
            let parts = partition_layout(&l, ranks).unwrap();
            assert_eq!(parts.len(), ranks);
            validate_partitions(&parts, l.n_params).unwrap();
            // every boundary coincides with a tensor start
            for p in &parts[1..] {
                assert!(
                    l.tensors.iter().any(|t| t.offset == p.offset),
                    "partition at {} splits a tensor",
                    p.offset
                );
            }
        }
        assert!(partition_layout(&l, 6).is_err(), "more ranks than tensors");
    }

    #[test]
    fn layout_partitions_are_roughly_balanced() {
        let l = layout(&[25, 25, 25, 25]);
        let parts = partition_layout(&l, 2).unwrap();
        assert_eq!(parts[0].len, 50);
        assert_eq!(parts[1].len, 50);
    }

    #[test]
    fn rank_sig_distinguishes_partitionings() {
        let a = Partition { rank: 0, offset: 0, len: 50 };
        let b = Partition { rank: 0, offset: 0, len: 60 };
        let c = Partition { rank: 1, offset: 50, len: 50 };
        assert_ne!(rank_sig(7, &a), rank_sig(7, &b));
        assert_ne!(rank_sig(7, &a), rank_sig(7, &c));
        assert_ne!(rank_sig(7, &a), rank_sig(8, &a));
        assert_eq!(rank_sig(7, &a), rank_sig(7, &a));
    }

    #[test]
    fn slice_and_split_cover_the_state() {
        let n = 10;
        let state = ModelState {
            params: Flat((0..n).map(|i| i as f32).collect()),
            m: Flat((0..n).map(|i| 10.0 + i as f32).collect()),
            v: Flat((0..n).map(|i| 20.0 + i as f32).collect()),
            step: 3,
        };
        let parts = partition_even(n, 3);
        let slices: Vec<ModelState> = parts.iter().map(|p| slice_state(&state, p)).collect();
        assert_eq!(slices[0].params.0, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(slices[2].v.0, vec![27.0, 28.0, 29.0]);
        assert!(slices.iter().all(|s| s.step == 3));
        let dense = Flat((0..n).map(|i| -(i as f32)).collect());
        let split = split_dense(&dense, &parts);
        let total: usize = split.iter().map(|f| f.len()).sum();
        assert_eq!(total, n);
        assert_eq!(split[1].0, vec![-4.0, -5.0, -6.0]);
    }

    #[test]
    fn validate_rejects_gaps_and_mislabels() {
        let mut parts = partition_even(10, 2);
        assert!(validate_partitions(&parts, 11).is_err());
        parts[1].offset = 6;
        assert!(validate_partitions(&parts, 10).is_err());
        let mut relabeled = partition_even(10, 2);
        relabeled[1].rank = 0;
        assert!(validate_partitions(&relabeled, 10).is_err());
    }
}
