//! Rank threads and the cluster runtime handle.
//!
//! [`Cluster::spawn`] starts one thread per rank plus one commit
//! coordinator. Each rank owns a [`Partition`] of the flat state and a
//! private `gen-{g:04}/rank-{r:04}/` namespace on the shared store
//! ([`Namespaced`], generation from [`ClusterConfig::generation`]); it
//! compacts its slice of each masked gradient off the training path,
//! encodes into its own pooled buffer ([`BufPool`]), persists through
//! its own [`Sharded`] engine when `n_shards`/`writers` ask for one, and
//! acks the durable object (name, length, CRC) to the coordinator —
//! phase 1 of the two-phase commit. The coordinator assembles acks per
//! epoch, **strictly in epoch order**, and writes the
//! `global-{g:04}-{step:012}.gck` record once every rank is durable —
//! phase 2 (see [`crate::cluster::commit`]). Committed names are never
//! rewritten: a restart that re-anchors, and every elastic reshard,
//! bumps the generation and writes into a fresh namespace.
//!
//! The training thread's cost per checkpoint is one Ψ-sized slice fan-out
//! ([`Cluster::put_diff_dense`]) or one state snapshot slice
//! ([`Cluster::put_full`]); everything else overlaps with training on the
//! rank threads, exactly like the single-rank checkpointer — but R-wide.

use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::manifest::Manifest;
use crate::cluster::commit::{gc_with_record, CommitKind, GlobalRecord, RankObject};
use crate::cluster::{
    rank_sig, slice_state, split_dense, validate_partitions, ClusterConfig, Partition,
};
use crate::control::iosched::{GatedStore, IoGate, IoGateConfig};
use crate::control::Tracer;
use crate::coordinator::checkpointer::CkptStats;
use crate::optim::ModelState;
use crate::pipeline::{
    compact_hierarchy, CompactStats, CompactorConfig, Encoder, Sink, DEFAULT_MAX_LEVEL,
};
use crate::storage::{Namespaced, Sharded, StorageBackend};
use crate::tensor::Flat;

/// What the training thread hands a rank.
enum RankCmd {
    /// dense-masked gradient slice (compacted on the rank thread)
    Diff { seq: u64, step: u64, dense: Flat },
    /// full state slice snapshot
    Full { seq: u64, step: u64, state: ModelState },
}

/// Phase-1 completion report from a rank to the coordinator.
struct RankAck {
    rank: usize,
    seq: u64,
    step: u64,
    kind: CommitKind,
    /// `(namespaced logical name, bytes, crc32)` of the durable object
    result: Result<(String, u64, u32), String>,
}

/// Aggregated result of a cluster run.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// per-rank write-path counters, rank order
    pub per_rank: Vec<CkptStats>,
    /// epochs whose global record was written (phase 2 reached)
    pub global_commits: u64,
    /// epochs abandoned: a rank write failed, a rank died, or the record
    /// write itself failed
    pub torn_commits: u64,
    /// bytes of global commit records written
    pub record_bytes: u64,
    /// coordinator wall time in phase 2 (record writes + cluster GC).
    /// Compaction passes run on the dedicated scheduler thread and are
    /// accounted in [`compact_secs`](ClusterStats::compact_secs), NOT
    /// here — commit latency no longer pays for background maintenance
    pub commit_secs: f64,
    /// objects removed by coordinator-run cluster GC
    pub gc_removed: u64,
    /// GC deletes that failed with the object still present (leaked
    /// garbage surfaced instead of silently swallowed; see
    /// [`GcSweepStats`](crate::cluster::commit::GcSweepStats))
    pub gc_leaked: u64,
    /// merged spans written by scheduler-run chain compaction (all levels)
    pub merged_written: u64,
    /// raw per-rank diff objects superseded by merged spans
    pub raw_compacted: u64,
    /// level-k spans absorbed into level-(k+1) super-spans
    pub spans_compacted: u64,
    /// deepest span level the scheduler's hierarchical compaction wrote
    pub max_level: u16,
    /// wall seconds the background scheduler spent in compaction passes
    /// (off the commit thread, shaped by the I/O gate)
    pub compact_secs: f64,
    /// protected record tips demoted out of a tiered store's fast tier
    /// after compaction (write-cold, kept durable for fallback recovery)
    pub tips_demoted: u64,
    /// §V-C actuation: merge-factor retunes applied at committed epoch
    /// boundaries
    pub retunes: u64,
}

impl ClusterStats {
    /// Cluster-wide totals (the numbers `RunReport` and the exp tables
    /// aggregate — all ranks, not rank 0 only).
    pub fn total(&self) -> CkptStats {
        let mut out = CkptStats::default();
        for s in &self.per_rank {
            out.merge(s);
        }
        out
    }
}

#[derive(Clone, Debug, Default)]
struct CoordStats {
    commits: u64,
    torn: u64,
    record_bytes: u64,
    commit_secs: f64,
    gc_removed: u64,
    gc_leaked: u64,
    retunes: u64,
    sched: SchedStats,
}

/// Counters owned by the background compaction scheduler thread.
#[derive(Clone, Debug, Default)]
struct SchedStats {
    compact: CompactStats,
    busy_secs: f64,
    tips_demoted: u64,
}

/// Handle to a running rank cluster.
pub struct Cluster {
    partitions: Vec<Partition>,
    txs: Vec<SyncSender<RankCmd>>,
    rank_handles: Vec<JoinHandle<CkptStats>>,
    coord: Option<JoinHandle<CoordStats>>,
    /// for synthetic torn-acks on behalf of dead ranks (a failed send
    /// means the rank thread is gone and will never ack this epoch);
    /// dropped before joining the coordinator so its recv loop can end
    ack_tx: Option<Sender<RankAck>>,
    next_seq: AtomicU64,
    /// epochs fully processed by the coordinator (committed + torn)
    processed: Arc<AtomicU64>,
    committed: Arc<AtomicU64>,
    /// live compaction merge factor (§V-C actuation): read by the
    /// coordinator after each committed record, so a retune takes effect
    /// at a committed epoch boundary for every rank at once
    compact_every: Arc<AtomicUsize>,
}

impl Cluster {
    /// Spawn ranks over `store` with the conventional
    /// `gen-{g:04}/rank-{r:04}/` namespaces (generation from
    /// `cfg.generation`).
    pub fn spawn(
        store: Arc<dyn StorageBackend>,
        partitions: Vec<Partition>,
        cfg: ClusterConfig,
    ) -> Cluster {
        let shared = Arc::clone(&store);
        let gen = cfg.generation;
        Cluster::spawn_with(store, partitions, cfg, move |r| {
            Arc::new(Namespaced::new(Arc::clone(&shared), Manifest::gen_rank_prefix(gen, r)))
                as Arc<dyn StorageBackend>
        })
    }

    /// Spawn with a caller-provided per-rank store factory — the hook the
    /// fault-injection tests use to wrap a single rank's namespace in a
    /// [`FaultyStore`](crate::storage::FaultyStore). The returned store
    /// MUST still map names into `gen-{g:04}/rank-{r:04}/` on the shared
    /// store (wrap a [`Namespaced`], don't replace it): the global record
    /// addresses objects by their namespaced names.
    pub fn spawn_with<F>(
        store: Arc<dyn StorageBackend>,
        partitions: Vec<Partition>,
        cfg: ClusterConfig,
        rank_store: F,
    ) -> Cluster
    where
        F: Fn(usize) -> Arc<dyn StorageBackend>,
    {
        assert!(!partitions.is_empty(), "cluster needs at least one rank");
        assert!(
            partitions.len() <= 10_000,
            "rank namespaces are 4-digit (`rank-{{r:04}}/`): at most 10000 ranks, got {}",
            partitions.len()
        );
        assert!(
            cfg.generation < 10_000,
            "generation namespaces are 4-digit (`gen-{{g:04}}/`): got {}",
            cfg.generation
        );
        // fail fast on malformed tables: the coordinator trusts rank
        // labels and the record's reader would reject gaps/overlaps only
        // at recovery time, when nothing can be re-written
        let total: usize = partitions.iter().map(|p| p.len()).sum();
        validate_partitions(&partitions, total).expect("cluster partition table");
        // the control plane: ONE gate shared by every rank's persist path
        // (guards) and the compaction scheduler (shaped I/O) — background
        // passes yield to any rank's in-flight phase-1 write. A driver-
        // provided gate (cfg.gate) wins so live `set_rate` retunes reach
        // the cluster's scheduler through the same token bucket.
        let gate: Option<Arc<IoGate>> = cfg.gate.clone().or_else(|| {
            (cfg.compact_every >= 2 || cfg.uses_control()).then(|| {
                Arc::new(IoGate::with_obs(
                    IoGateConfig { bytes_per_sec: cfg.io_budget, ..IoGateConfig::default() },
                    cfg.telemetry.clone(),
                    cfg.trace.clone(),
                ))
            })
        });
        let (ack_tx, ack_rx) = channel::<RankAck>();
        let mut txs = Vec::with_capacity(partitions.len());
        let mut rank_handles = Vec::with_capacity(partitions.len());
        for part in &partitions {
            let (tx, rx) = sync_channel::<RankCmd>(cfg.queue_capacity.max(1));
            let rstore = rank_store(part.rank);
            let acks = ack_tx.clone();
            let rcfg = cfg.clone();
            let rgate = gate.clone();
            let rpart = part.clone();
            let h = std::thread::Builder::new()
                .name(format!("rank-{:04}", part.rank))
                .spawn(move || rank_loop(rpart, rstore, rcfg, rx, acks, rgate))
                .expect("spawning rank thread");
            txs.push(tx);
            rank_handles.push(h);
        }
        let cluster_acks = ack_tx.clone();
        drop(ack_tx); // coordinator exits once rank + cluster senders are gone
        let processed = Arc::new(AtomicU64::new(0));
        let committed = Arc::new(AtomicU64::new(0));
        let compact_every = Arc::new(AtomicUsize::new(cfg.compact_every));
        let coord = {
            let parts = partitions.clone();
            let pr = Arc::clone(&processed);
            let cm = Arc::clone(&committed);
            let mf = Arc::clone(&compact_every);
            std::thread::Builder::new()
                .name("cluster-commit".into())
                .spawn(move || coordinator_loop(store, cfg, parts, ack_rx, pr, cm, mf, gate))
                .expect("spawning commit coordinator")
        };
        Cluster {
            partitions,
            txs,
            rank_handles,
            coord: Some(coord),
            ack_tx: Some(cluster_acks),
            next_seq: AtomicU64::new(0),
            processed,
            committed,
            compact_every,
        }
    }

    /// §V-C actuation: retune the compaction merge factor (`< 2`
    /// disables). The coordinator reads the knob after each committed
    /// phase-2 record, so the switch is piggybacked on the global commit
    /// stream — every rank's chain sees the new factor from the same
    /// committed epoch; nothing below an already-committed cut is
    /// re-interpreted.
    pub fn set_compact_every(&self, mf: usize) {
        self.compact_every.store(mf, Ordering::SeqCst);
    }

    pub fn n_ranks(&self) -> usize {
        self.partitions.len()
    }

    pub fn partitions(&self) -> &[Partition] {
        &self.partitions
    }

    /// Epochs the coordinator has resolved (committed or torn).
    pub fn epochs_processed(&self) -> u64 {
        self.processed.load(Ordering::SeqCst)
    }

    /// Epochs whose global record is durable.
    pub fn epochs_committed(&self) -> u64 {
        self.committed.load(Ordering::SeqCst)
    }

    /// Block until at least `n` epochs are resolved (test/example
    /// barrier; the run path never waits).
    pub fn wait_epochs(&self, n: u64) {
        while self.epochs_processed() < n {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Fan a dense-masked global gradient out as one differential epoch.
    /// Cost on the caller: one Ψ-sized slice copy; compaction, encoding
    /// and I/O happen on the rank threads. Returns time blocked on full
    /// rank queues (transmission-stall backpressure).
    pub fn put_diff_dense(&self, step: u64, grad: &Flat) -> Duration {
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let t0 = Instant::now();
        let slices = split_dense(grad, &self.partitions);
        for ((tx, part), dense) in self.txs.iter().zip(&self.partitions).zip(slices) {
            if tx.send(RankCmd::Diff { seq, step, dense }).is_err() {
                self.ack_dead_rank(part.rank, seq, step, CommitKind::Diff);
            }
        }
        t0.elapsed()
    }

    /// Snapshot the global state as one full-checkpoint epoch (each rank
    /// persists its slice; the commit record makes the set atomic).
    pub fn put_full(&self, step: u64, state: &ModelState) -> Duration {
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let t0 = Instant::now();
        for (tx, part) in self.txs.iter().zip(&self.partitions) {
            let mut slice = slice_state(state, part);
            slice.step = step;
            if tx.send(RankCmd::Full { seq, step, state: slice }).is_err() {
                self.ack_dead_rank(part.rank, seq, step, CommitKind::Full);
            }
        }
        t0.elapsed()
    }

    /// A failed send means the rank thread is gone and will never ack;
    /// tear the epoch on its behalf so epochs *sent after the death* can
    /// still resolve. This is a partial mitigation: commands that were
    /// already queued inside the dead rank were accepted but will never
    /// be acked, so epochs from that window (and everything after them,
    /// given in-order commits) resolve only at shutdown — an in-process
    /// rank death is crash territory, handled by restart + consistent-cut
    /// recovery, not by the live coordinator.
    fn ack_dead_rank(&self, rank: usize, seq: u64, step: u64, kind: CommitKind) {
        log::error!("rank {rank} is gone; epoch {seq} (step {step}) will be torn");
        if let Some(acks) = &self.ack_tx {
            let _ = acks.send(RankAck {
                rank,
                seq,
                step,
                kind,
                result: Err("rank thread dead".into()),
            });
        }
    }

    /// Graceful shutdown: drain every rank queue, let the coordinator
    /// resolve every epoch, and return the aggregated stats.
    pub fn finish(mut self) -> ClusterStats {
        self.txs.clear(); // close command queues; ranks drain and exit
        let per_rank: Vec<CkptStats> = self
            .rank_handles
            .drain(..)
            .map(|h| h.join().unwrap_or_default())
            .collect();
        self.ack_tx = None; // last sender gone: the coordinator can stop
        let c = self
            .coord
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default();
        ClusterStats {
            per_rank,
            global_commits: c.commits,
            torn_commits: c.torn,
            record_bytes: c.record_bytes,
            commit_secs: c.commit_secs,
            gc_removed: c.gc_removed,
            gc_leaked: c.gc_leaked,
            merged_written: c.sched.compact.merged_written,
            raw_compacted: c.sched.compact.raw_compacted,
            spans_compacted: c.sched.compact.spans_compacted,
            max_level: c.sched.compact.max_level,
            compact_secs: c.sched.busy_secs,
            tips_demoted: c.sched.tips_demoted,
            retunes: c.retunes,
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.txs.clear();
        for h in self.rank_handles.drain(..) {
            let _ = h.join();
        }
        self.ack_tx = None;
        if let Some(h) = self.coord.take() {
            let _ = h.join();
        }
    }
}

/// One rank's write loop, composed from the shared pipeline stages
/// ([`crate::pipeline`]): compact → encode (pooled) → persist-durable →
/// ack. The [`Sink::persist_durable`] call blocks until the object is on
/// disk — the ack must mean "durable", or the commit record could
/// reference bytes that never landed.
fn rank_loop(
    part: Partition,
    store: Arc<dyn StorageBackend>,
    cfg: ClusterConfig,
    rx: Receiver<RankCmd>,
    acks: Sender<RankAck>,
    gate: Option<Arc<IoGate>>,
) -> CkptStats {
    let sig = rank_sig(cfg.model_sig, &part);
    let prefix = Manifest::gen_rank_prefix(cfg.generation, part.rank);
    let enc = Encoder::new(sig, cfg.codec, 4);
    let mut sink = Sink::new(Arc::clone(&store), cfg.n_shards, cfg.writers, 4)
        .with_control(gate, cfg.telemetry.clone())
        .with_trace(cfg.trace.clone());
    let mut stats = CkptStats::default();
    let tid = part.rank as u64;
    let mut acked = 0u64;

    while let Ok(cmd) = rx.recv() {
        if let Some(hb) = &cfg.heartbeats {
            // a silenced rank models a hung process: it stops beating AND
            // stops acking, so its epochs tear exactly like a real death
            // and the detector sees the same silence recovery will see
            if hb.is_silenced(part.rank) {
                continue;
            }
        }
        let mut sp = Tracer::maybe_span(&cfg.trace, "encode").map(|s| s.tid(tid));
        let (seq, step, kind, encoded) = match cmd {
            RankCmd::Diff { seq, step, dense } => {
                let t0 = Instant::now();
                let sparse = enc.compact(&dense); // offload stage
                drop(dense);
                stats.offload_secs += t0.elapsed().as_secs_f64();
                stats.diff_ckpts += 1;
                let res = enc
                    .encode_diff(step, &DiffPayload::Gradient(sparse))
                    .map_err(|e| format!("encode diff {step}: {e:#}"));
                (seq, step, CommitKind::Diff, res)
            }
            RankCmd::Full { seq, step, state } => {
                stats.full_ckpts += 1;
                let res = enc
                    .encode_full(&state)
                    .map_err(|e| format!("encode full {step}: {e:#}"));
                (seq, step, CommitKind::Full, res)
            }
        };
        if let Some(s) = sp.as_mut() {
            s.set_step(step);
            if let Ok(obj) = &encoded {
                s.set_bytes(obj.buf.len() as u64);
            }
        }
        drop(sp); // the encode span ends before the persist stage begins
        let result = match encoded {
            Err(e) => {
                log::error!("rank {}: {e}", part.rank);
                stats.errors += 1;
                Err(e)
            }
            Ok(obj) => {
                let name = obj.name.clone();
                sink.persist_durable(obj, &mut stats)
                    .map(|(len, crc)| (format!("{prefix}{name}"), len, crc))
            }
        };
        if result.is_ok() {
            acked += 1;
        }
        if let Some(hb) = &cfg.heartbeats {
            // liveness = "made durable progress recently"; beat() is a
            // no-op while silenced, so a mid-epoch silence stays silent
            hb.beat(part.rank, step, acked);
        }
        if acks.send(RankAck { rank: part.rank, seq, step, kind, result }).is_err() {
            log::warn!("rank {}: coordinator gone; stopping", part.rank);
            break;
        }
    }
    stats.pool_hits = enc.pool_hits();
    stats.pool_misses = enc.pool_misses();
    sink.finish_local(&mut stats);
    stats
}

/// One epoch's phase-1 ledger.
struct Pending {
    step: u64,
    kind: CommitKind,
    objects: Vec<Option<RankObject>>,
    received: usize,
    failed: bool,
}

/// One unit of background maintenance handed from the commit thread to
/// the scheduler: compact every rank's chain strictly below `rec`'s cut.
struct SchedJob {
    rec: GlobalRecord,
    prev_tips: HashSet<String>,
    merge_factor: usize,
}

/// Phase 2: assemble acks per epoch and write records strictly in epoch
/// order — a record for epoch k is written only after epochs `..k` were
/// each either committed or declared torn, so commit order is always a
/// prefix of epoch order (the consistent-cut walk relies on this).
///
/// A torn **diff** epoch poisons the pipeline: that rank's chain now has
/// a hole, so committing any later diff epoch would certify a cut whose
/// chain misses a gradient (a hole the recovery-side stride heuristic
/// cannot always see — e.g. a single diff after the base looks like a
/// legitimate longer cadence). Diff epochs are declared torn while
/// poisoned; the next phase-1-complete **full** epoch re-bases every
/// rank's chain and clears the poison. A torn full epoch loses only its
/// own record — it holes no chain.
///
/// **Compaction is NOT run here.** The commit thread only *enqueues*
/// [`SchedJob`]s to the dedicated `cluster-iosched` thread (mirroring the
/// flat runtime's [`Compactor`](crate::pipeline::Compactor)), so
/// `commit_secs` measures the commit protocol alone and compaction reads
/// never serialize behind record writes. Jobs execute FIFO with the
/// (record, protected-tips) snapshot captured at commit time, so the
/// merged spans produced are the same objects the old inline passes
/// produced — only off-thread and shaped by the I/O gate.
#[allow(clippy::too_many_arguments)]
fn coordinator_loop(
    store: Arc<dyn StorageBackend>,
    cfg: ClusterConfig,
    partitions: Vec<Partition>,
    ack_rx: Receiver<RankAck>,
    processed: Arc<AtomicU64>,
    committed: Arc<AtomicU64>,
    mf_knob: Arc<AtomicUsize>,
    gate: Option<Arc<IoGate>>,
) -> CoordStats {
    let n = partitions.len();
    let mut pending: BTreeMap<u64, Pending> = BTreeMap::new();
    let mut next_seq = 0u64;
    let mut poisoned = false;
    let mut diffs_since_compact = 0usize;
    // tips of the PREVIOUS committed record: the compactor must not
    // consume them either, or the newest record's one-deep fallback (a
    // later torn/damaged record) would lose its CRC-pinned tip objects
    let mut prev_tips: HashSet<String> = HashSet::new();
    // the dedicated background scheduler (exists whenever compaction is
    // configured or the control plane could enable it live)
    // queued level-0 jobs, shared with the scheduler: while a job waits
    // here, the scheduler's hierarchical (level ≥ 1) passes yield so raw
    // compaction under the IoGate budget is never starved
    let queued = Arc::new(AtomicUsize::new(0));
    let sched: Option<(Sender<SchedJob>, JoinHandle<SchedStats>)> = gate.map(|g| {
        let (tx, rx) = channel::<SchedJob>();
        let sstore = Arc::clone(&store);
        let scfg = cfg.clone();
        let q = Arc::clone(&queued);
        let h = std::thread::Builder::new()
            .name("cluster-iosched".into())
            .spawn(move || scheduler_loop(sstore, scfg, g, rx, q))
            .expect("spawning cluster I/O scheduler");
        (tx, h)
    });
    let mut active_mf = cfg.compact_every;
    let mut out = CoordStats::default();
    while let Ok(ack) = ack_rx.recv() {
        if let Some(t) = &cfg.trace {
            // phase-1 completion: one instant per (rank, epoch); extra
            // carries the epoch seq so tears are visible in the journal
            t.instant("commit.ack", ack.rank as u64, ack.step, ack.seq);
        }
        let e = pending.entry(ack.seq).or_insert_with(|| Pending {
            step: ack.step,
            kind: ack.kind,
            objects: vec![None; n],
            received: 0,
            failed: false,
        });
        e.received += 1;
        match ack.result {
            Ok((name, obj_len, obj_crc)) => {
                let part = &partitions[ack.rank];
                e.objects[ack.rank] = Some(RankObject {
                    rank: ack.rank as u32,
                    slices: part.slices.iter().map(|s| (s.offset as u64, s.len as u64)).collect(),
                    kind: ack.kind,
                    name,
                    obj_len,
                    obj_crc,
                });
            }
            Err(err) => {
                let (seq, step) = (ack.seq, ack.step);
                log::warn!("epoch {seq} (step {step}): rank {} failed: {err}", ack.rank);
                e.failed = true;
            }
        }
        while pending.get(&next_seq).is_some_and(|p| p.received == n) {
            let p = pending.remove(&next_seq).unwrap();
            let kind = p.kind;
            let commit_secs_before = out.commit_secs;
            let rec = commit_epoch(&store, &cfg, next_seq, p, &committed, &mut poisoned, &mut out);
            if let Some(bus) = &cfg.telemetry {
                bus.record_commit(out.commit_secs - commit_secs_before);
            }
            if let Some(rec) = rec {
                // §V-C actuation safe point: the knob is sampled right
                // after a committed record, so every rank's chain switches
                // merge factor from the same committed epoch
                let mf = mf_knob.load(Ordering::SeqCst);
                if mf != active_mf {
                    log::debug!(
                        "cluster retune at committed step {}: compact_every {active_mf} -> {mf}",
                        rec.step
                    );
                    active_mf = mf;
                    diffs_since_compact = 0;
                    out.retunes += 1;
                }
                // background incremental merging: every `compact_every`
                // committed diff epochs, enqueue a pass compacting each
                // rank's chain below the newly-committed cut
                if let Some((tx, _)) = &sched {
                    if kind == CommitKind::Diff && active_mf >= 2 {
                        diffs_since_compact += 1;
                        if diffs_since_compact >= active_mf {
                            diffs_since_compact = 0;
                            queued.fetch_add(1, Ordering::SeqCst);
                            let _ = tx.send(SchedJob {
                                rec: rec.clone(),
                                prev_tips: prev_tips.clone(),
                                merge_factor: active_mf,
                            });
                        }
                    }
                }
                prev_tips = rec.ranks.iter().map(|r| r.name.clone()).collect();
            }
            next_seq += 1;
            processed.fetch_add(1, Ordering::SeqCst);
        }
    }
    // every rank sender is gone; epochs still missing acks are torn
    if !pending.is_empty() {
        log::warn!("{} epochs never completed phase 1 (torn)", pending.len());
        out.torn += pending.len() as u64;
        processed.fetch_add(pending.len() as u64, Ordering::SeqCst);
    }
    // drain the scheduler: every enqueued pass completes before finish()
    if let Some((tx, h)) = sched {
        drop(tx);
        if let Ok(stats) = h.join() {
            out.sched = stats;
        }
    }
    out
}

/// The dedicated background-maintenance thread (`cluster-iosched`): runs
/// compaction passes FIFO off the commit thread, every read/write shaped
/// through the I/O gate so it yields to in-flight rank persists and pays
/// the `--io-budget` token bucket.
fn scheduler_loop(
    store: Arc<dyn StorageBackend>,
    cfg: ClusterConfig,
    gate: Arc<IoGate>,
    rx: Receiver<SchedJob>,
    queued: Arc<AtomicUsize>,
) -> SchedStats {
    // one logical view shared by every pass. Mirror the rank write path:
    // wrap in a shard-aware view ONLY when ranks shard — `Sharded::put`
    // always writes shard + index objects, which would turn plain-layout
    // merged spans into shard artifacts invisible to raw store listings
    // (and each Sharded carries a writer thread; never build one per pass)
    let logical_inner: Arc<dyn StorageBackend> = if cfg.n_shards > 1 || cfg.writers > 1 {
        Arc::new(Sharded::new(Arc::clone(&store), 1, 1))
    } else {
        Arc::clone(&store)
    };
    let logical: Arc<dyn StorageBackend> = Arc::new(GatedStore::new(logical_inner, gate));
    let mut out = SchedStats::default();
    while let Ok(job) = rx.recv() {
        queued.fetch_sub(1, Ordering::SeqCst);
        let t0 = Instant::now();
        let _sp = Tracer::maybe_span(&cfg.trace, "sched.pass").map(|s| s.step(job.rec.step));
        let before = out.compact.clone();
        // hierarchical passes run only while no newer level-0 job waits —
        // raw compaction keeps strict priority under the IoGate budget;
        // the span ladder resumes from the cover on the next idle job
        let mut keep_going = || queued.load(Ordering::SeqCst) == 0;
        compact_cluster_chains(
            logical.as_ref(),
            &cfg,
            job.merge_factor,
            &job.rec,
            &job.prev_tips,
            &mut keep_going,
            &mut out,
        );
        out.busy_secs += t0.elapsed().as_secs_f64();
        if let Some(bus) = &cfg.telemetry {
            bus.record_compaction(
                out.compact.merged_written - before.merged_written,
                out.compact.raw_compacted - before.raw_compacted,
                (out.compact.bytes_read - before.bytes_read)
                    + (out.compact.bytes_written - before.bytes_written),
            );
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn commit_epoch(
    store: &Arc<dyn StorageBackend>,
    cfg: &ClusterConfig,
    seq: u64,
    p: Pending,
    committed: &AtomicU64,
    poisoned: &mut bool,
    out: &mut CoordStats,
) -> Option<GlobalRecord> {
    let t0 = Instant::now();
    if p.failed || p.objects.iter().any(Option::is_none) {
        // phase 1 incomplete. A torn DIFF epoch holes that rank's chain —
        // poison. A torn FULL epoch holes nothing (the diff progression is
        // untouched; later recoveries just use an older base), so it only
        // loses its own record.
        if p.kind == CommitKind::Diff {
            *poisoned = true;
        }
        out.torn += 1;
        out.commit_secs += t0.elapsed().as_secs_f64();
        return None;
    }
    if *poisoned && p.kind == CommitKind::Diff {
        // chains are holed upstream; a record here would certify an
        // unrecoverable cut — wait for a full epoch to re-base
        out.torn += 1;
        out.commit_secs += t0.elapsed().as_secs_f64();
        return None;
    }
    if p.kind == CommitKind::Full {
        // every rank's chain re-bases at this durable full, whether or
        // not the record write below succeeds
        *poisoned = false;
    }
    let rec = GlobalRecord {
        model_sig: cfg.model_sig,
        generation: cfg.generation,
        step: p.step,
        seq,
        ranks: p.objects.into_iter().map(Option::unwrap).collect(),
    };
    let bytes = rec.to_bytes();
    let committed_rec = match store.put(&rec.name(), &bytes) {
        Ok(()) => {
            out.commits += 1;
            out.record_bytes += bytes.len() as u64;
            committed.fetch_add(1, Ordering::SeqCst);
            if cfg.gc && p.kind == CommitKind::Full {
                match gc_with_record(store, &rec) {
                    Ok(gc) => {
                        out.gc_removed += gc.removed as u64;
                        out.gc_leaked += gc.leaked as u64;
                    }
                    Err(e) => log::warn!("cluster gc failed: {e:#}"),
                }
            }
            Some(rec)
        }
        Err(e) => {
            // phase 2 failed: no record, but every rank chain is intact,
            // so later epochs stay committable (no poison)
            log::warn!("global record for step {} failed: {e:#}", rec.step);
            out.torn += 1;
            None
        }
    };
    if let Some(rec) = &committed_rec {
        if let Some(t) = &cfg.trace {
            let secs = t0.elapsed().as_secs_f64();
            t.complete("commit.phase2", secs, 0, rec.step, bytes.len() as u64, seq);
        }
    }
    out.commit_secs += t0.elapsed().as_secs_f64();
    committed_rec
}

/// Scheduler-run background compaction (incremental-merging
/// persistence): for every rank in a committed record, merge runs of raw
/// diff objects **strictly below the cut** into `MergedDiff` spans, then
/// climb the span hierarchy ([`compact_hierarchy`]) — level-k spans into
/// level-(k+1) super-spans — while `keep_going` allows (no newer level-0
/// job queued). Protected from consumption: the record's tip objects AND
/// the previous record's (both have CRC-pinned tips a fallback may need
/// to re-verify), so recovery keeps at least one-deep record fallback.
/// An object becomes collectible at every level only through the
/// durable-and-verified-before-delete rule (docs/PIPELINE.md). The
/// protected previous tips are write-cold from here on: on a tiered
/// store they are demoted out of the fast tier (kept durable — fallback
/// recovery still reads them, just slower).
#[allow(clippy::too_many_arguments)]
fn compact_cluster_chains(
    logical: &dyn StorageBackend,
    cfg: &ClusterConfig,
    merge_factor: usize,
    rec: &GlobalRecord,
    prev_tips: &HashSet<String>,
    keep_going: &mut dyn FnMut() -> bool,
    out: &mut SchedStats,
) {
    let mut protect: HashSet<String> = rec.ranks.iter().map(|r| r.name.clone()).collect();
    protect.extend(prev_tips.iter().cloned());
    for ro in &rec.ranks {
        let part = ro.partition();
        let ccfg = CompactorConfig {
            model_sig: rank_sig(cfg.model_sig, &part),
            codec: cfg.codec,
            merge_factor,
            // phase-1 acks are blocking-durable and the record committed,
            // so everything at or below the cut is settled
            settle_tail: 0,
            max_level: DEFAULT_MAX_LEVEL,
        };
        // the chain strictly below the cut: tips at the cut stay raw.
        // Re-listed per level — each level rewrites the cover
        let (gen, rank, cut) = (rec.generation, ro.rank as usize, rec.step.saturating_sub(1));
        let discover = move |s: &dyn StorageBackend| {
            Ok(Manifest::gen_rank_chain(&s.list()?, gen, rank, cut))
        };
        // tail merging keeps the replayable set within mf·⌈log_mf n⌉ + 2
        // (the two protected record tips stay raw alongside the spans)
        if let Err(e) = compact_hierarchy(
            logical,
            &ccfg,
            &protect,
            true,
            &mut out.compact,
            &discover,
            keep_going,
            cfg.trace.as_deref(),
        ) {
            log::warn!("rank {} compaction failed: {e:#}", ro.rank);
        }
    }
    // tiered placement: the previous record's tips were kept only for
    // one-deep fallback — write-cold, demote their fast-tier copies
    for tip in prev_tips {
        if logical.demote(tip).unwrap_or(false) {
            out.tips_demoted += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::model_signature;
    use crate::cluster::{partition_even, recover_cluster};
    use crate::compress::topk_mask;
    use crate::optim::Adam;
    use crate::sparse::SparseGrad;
    use crate::storage::{FaultConfig, FaultyStore, MemStore};
    use crate::util::rng::Rng;

    fn grad(rng: &mut Rng, n: usize) -> Flat {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        topk_mask(&Flat(g), n / 8 + 1)
    }

    fn drive(
        cluster: &Cluster,
        n: usize,
        steps: u64,
        seed: u64,
    ) -> Vec<ModelState> {
        // expected global state per step, via the same element-wise Adam
        let adam = Adam::default();
        let mut rng = Rng::new(seed);
        let mut state = ModelState::new(Flat(vec![0.5; n]));
        let mut timeline = vec![state.clone()];
        cluster.put_full(0, &state);
        for step in 1..=steps {
            let g = grad(&mut rng, n);
            cluster.put_diff_dense(step, &g);
            adam.apply_sparse(&mut state, &SparseGrad::from_dense(&g));
            timeline.push(state.clone());
        }
        timeline
    }

    #[test]
    fn two_ranks_commit_every_epoch_and_recover_exactly() {
        let n = 96;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let cfg = ClusterConfig { model_sig: model_signature("t", n), ..Default::default() };
        let cluster = Cluster::spawn(Arc::clone(&store), partition_even(n, 2), cfg.clone());
        let timeline = drive(&cluster, n, 5, 11);
        let stats = cluster.finish();
        assert_eq!(stats.global_commits, 6, "anchor + 5 diffs all committed");
        assert_eq!(stats.torn_commits, 0);
        assert_eq!(stats.per_rank.len(), 2);
        assert_eq!(stats.total().writes, 12, "2 ranks x 6 objects");
        assert!(stats.record_bytes > 0);

        let (got, cut) = recover_cluster(&store, cfg.model_sig, &Adam::default()).unwrap();
        assert_eq!(cut.cut_step, 5);
        assert_eq!(cut.ranks, 2);
        assert_eq!(got, timeline[5], "slice recovery must be bit-identical");
    }

    #[test]
    fn records_are_committed_in_epoch_order() {
        let n = 64;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let cfg = ClusterConfig {
            model_sig: model_signature("t", n),
            gc: false,
            ..Default::default()
        };
        let cluster = Cluster::spawn(Arc::clone(&store), partition_even(n, 3), cfg);
        drive(&cluster, n, 4, 3);
        cluster.wait_epochs(5);
        assert_eq!(cluster.epochs_committed(), 5);
        drop(cluster);
        let mut steps: Vec<(u64, u64)> = store
            .list()
            .unwrap()
            .iter()
            .filter_map(|s| Manifest::parse_global(s))
            .collect();
        steps.sort_unstable();
        assert_eq!(steps, vec![(0, 0), (0, 1), (0, 2), (0, 3), (0, 4)]);
    }

    #[test]
    fn failed_rank_write_tears_the_epoch_not_the_run() {
        let n = 80;
        let inner: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let sig = model_signature("t", n);
        let cfg = ClusterConfig { model_sig: sig, gc: false, ..Default::default() };
        let shared = Arc::clone(&inner);
        // rank 1's namespace dies after 3 writes (anchor + 2 diffs)
        let cluster = Cluster::spawn_with(
            Arc::clone(&inner),
            partition_even(n, 2),
            cfg,
            move |r| {
                let ns = Namespaced::new(Arc::clone(&shared), Manifest::gen_rank_prefix(0, r));
                if r == 1 {
                    Arc::new(FaultyStore::new(
                        ns,
                        FaultConfig { put_fail: 1.0, grace_ops: 3, ..FaultConfig::default() },
                    )) as Arc<dyn StorageBackend>
                } else {
                    Arc::new(ns) as Arc<dyn StorageBackend>
                }
            },
        );
        let timeline = drive(&cluster, n, 6, 7);
        let stats = cluster.finish();
        assert_eq!(stats.global_commits, 3, "anchor + diffs 1,2");
        assert_eq!(stats.torn_commits, 4, "diffs 3..=6 torn");
        assert_eq!(stats.total().errors, 4);

        let (got, cut) = recover_cluster(&inner, sig, &Adam::default()).unwrap();
        assert_eq!(cut.cut_step, 2, "consistent cut = last fully-committed epoch");
        assert_eq!(got, timeline[2]);
        assert_eq!(cut.records_skipped, 0, "torn epochs never wrote records");
    }

    #[test]
    fn off_cadence_base_full_does_not_reject_the_chain() {
        // diff cadence 3, but a full checkpoint lands OFF the grid (step
        // 7): the base→first-diff hop (2) is shorter than the chain
        // stride (3). The stride heuristic must take the inter-diff gap,
        // not fold the first hop into the minimum — otherwise committed
        // epochs 9 and 12 would be rejected as holed and silently lost.
        let n = 64;
        let sig = model_signature("t", n);
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let cfg = ClusterConfig { model_sig: sig, gc: false, ..Default::default() };
        let cluster = Cluster::spawn(Arc::clone(&store), partition_even(n, 2), cfg);
        let adam = Adam::default();
        let mut rng = Rng::new(41);
        let state0 = ModelState::new(Flat(vec![0.5; n]));
        cluster.put_full(0, &state0);
        let g3 = grad(&mut rng, n);
        cluster.put_diff_dense(3, &g3);
        let mut base7 = state0.clone();
        adam.apply_sparse(&mut base7, &SparseGrad::from_dense(&g3));
        base7.step = 7;
        cluster.put_full(7, &base7);
        let g9 = grad(&mut rng, n);
        cluster.put_diff_dense(9, &g9);
        let g12 = grad(&mut rng, n);
        cluster.put_diff_dense(12, &g12);
        let stats = cluster.finish();
        assert_eq!(stats.global_commits, 5);
        assert_eq!(stats.torn_commits, 0);

        // recovery-style oracle from the step-7 base
        let mut expect = base7.clone();
        adam.apply_sparse(&mut expect, &SparseGrad::from_dense(&g9));
        adam.apply_sparse(&mut expect, &SparseGrad::from_dense(&g12));
        expect.step = 12;

        let (got, cut) = recover_cluster(&store, sig, &Adam::default()).unwrap();
        assert_eq!(cut.cut_step, 12, "off-cadence base must not truncate committed epochs");
        assert_eq!(got, expect);
    }

    /// Fails exactly the puts whose name contains `needle`; everything
    /// else passes — models a rank that drops one write and then heals.
    struct FailName<B: StorageBackend> {
        inner: B,
        needle: String,
    }

    impl<B: StorageBackend> StorageBackend for FailName<B> {
        fn put(&self, name: &str, bytes: &[u8]) -> anyhow::Result<()> {
            anyhow::ensure!(!name.contains(&self.needle), "injected put failure for {name}");
            self.inner.put(name, bytes)
        }
        fn get(&self, name: &str) -> anyhow::Result<Vec<u8>> {
            self.inner.get(name)
        }
        fn delete(&self, name: &str) -> anyhow::Result<()> {
            self.inner.delete(name)
        }
        fn list(&self) -> anyhow::Result<Vec<String>> {
            self.inner.list()
        }
    }

    #[test]
    fn torn_epoch_poisons_diff_commits_until_a_full_rebases() {
        // rank 1 fails ONLY its diff-1 write, then heals. Without the
        // poison rule the coordinator would commit records for diffs 2,3
        // whose rank-1 chain silently misses gradient 1 (a single diff
        // after the base looks like a legitimate longer cadence to the
        // recovery-side stride heuristic) — a certified wrong state.
        let n = 80;
        let sig = model_signature("t", n);
        let inner: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let cfg = ClusterConfig { model_sig: sig, gc: false, ..Default::default() };
        let shared = Arc::clone(&inner);
        let cluster = Cluster::spawn_with(
            Arc::clone(&inner),
            partition_even(n, 2),
            cfg,
            move |r| {
                let ns = Namespaced::new(Arc::clone(&shared), Manifest::gen_rank_prefix(0, r));
                if r == 1 {
                    Arc::new(FailName { inner: ns, needle: Manifest::diff_name(1) })
                        as Arc<dyn StorageBackend>
                } else {
                    Arc::new(ns) as Arc<dyn StorageBackend>
                }
            },
        );
        let adam = Adam::default();
        let mut rng = Rng::new(13);
        let mut state = ModelState::new(Flat(vec![0.5; n]));
        cluster.put_full(0, &state);
        for step in 1..=3u64 {
            let g = grad(&mut rng, n);
            cluster.put_diff_dense(step, &g);
            adam.apply_sparse(&mut state, &SparseGrad::from_dense(&g));
        }
        cluster.put_full(3, &state); // re-bases every chain
        let stats = cluster.finish();
        assert_eq!(stats.global_commits, 2, "anchor + the re-basing full only");
        assert_eq!(stats.torn_commits, 3, "torn epoch 1 + poisoned diffs 2,3");
        assert!(!inner.exists(&Manifest::global_name(0, 2)), "poisoned diff must not commit");

        let (got, cut) = recover_cluster(&inner, sig, &Adam::default()).unwrap();
        assert_eq!(cut.cut_step, 3);
        assert_eq!(got, state, "recovery lands on the re-based full, never a holed chain");
    }

    #[test]
    fn sharded_rank_engines_recover_identically_to_direct() {
        let n = 120;
        let sig = model_signature("t", n);
        let run = |n_shards: usize, writers: usize| -> (Arc<dyn StorageBackend>, ModelState) {
            let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
            let cfg = ClusterConfig { model_sig: sig, n_shards, writers, ..Default::default() };
            let cluster = Cluster::spawn(Arc::clone(&store), partition_even(n, 2), cfg);
            let timeline = drive(&cluster, n, 4, 21);
            let stats = cluster.finish();
            assert_eq!(stats.torn_commits, 0);
            if n_shards > 1 {
                assert!(stats.total().shard_writes > 0, "per-rank engines must be exercised");
            }
            let (got, _) = recover_cluster(&store, sig, &Adam::default()).unwrap();
            assert_eq!(got, *timeline.last().unwrap());
            (store, got)
        };
        let (_, direct) = run(1, 1);
        let (_, sharded) = run(3, 2);
        assert_eq!(direct, sharded, "engine topology must not change recovered bits");
    }
}
