//! Elastic resharded recovery: restart with R′ ≠ R ranks.
//!
//! A cluster checkpoint is R per-rank chains plus a global record carrying
//! the partition table that produced them. An elastic restart therefore
//! does not need the old rank count configured anywhere: it reads all R
//! chains at the consistent cut (merging each rank's diffs into its base —
//! [`recover_cluster`](crate::cluster::commit::recover_cluster)), flattens
//! the slices into one global state, and [`repartition`]s that state
//! across the new R′ partitions. [`elastic_restart`] wraps the whole
//! sequence and re-anchors the new cluster: each new rank writes a full
//! checkpoint of its (re-cut) slice at the cut step and the coordinator
//! commits a fresh global record with the **new** partition table — from
//! that point the old namespaces are garbage that the next cluster GC
//! sweep reclaims.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::checkpoint::full::write_full;
use crate::checkpoint::manifest::Manifest;
use crate::cluster::commit::{recover_cluster, truncate_stragglers, ClusterCutStats};
use crate::cluster::rank::Cluster;
use crate::cluster::{slice_state, validate_partitions, ClusterConfig, Partition};
use crate::optim::{Adam, ModelState};
use crate::storage::StorageBackend;
use crate::tensor::Flat;

/// Concatenate per-rank state slices (in partition order) back into one
/// global state. The slices must tile the parameter vector contiguously
/// and agree on the step.
pub fn flatten(slices: &[(Partition, ModelState)]) -> Result<ModelState> {
    ensure!(!slices.is_empty(), "nothing to flatten");
    let mut order: Vec<usize> = (0..slices.len()).collect();
    order.sort_by_key(|&i| slices[i].0.offset);
    let n: usize = slices.iter().map(|(p, _)| p.len).sum();
    let step = slices[0].1.step;
    let mut params = Vec::with_capacity(n);
    let mut m = Vec::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    let mut pos = 0usize;
    for &i in &order {
        let (p, s) = &slices[i];
        ensure!(p.offset == pos, "slice at {} leaves a gap at {pos}", p.offset);
        ensure!(s.n_params() == p.len, "slice state {} != partition {}", s.n_params(), p.len);
        ensure!(s.step == step, "slice steps disagree: {} != {step}", s.step);
        params.extend_from_slice(&s.params.0);
        m.extend_from_slice(&s.m.0);
        v.extend_from_slice(&s.v.0);
        pos = p.end();
    }
    Ok(ModelState { params: Flat(params), m: Flat(m), v: Flat(v), step })
}

/// Cut a flattened global state into slices for a (new) partition table.
pub fn repartition(state: &ModelState, parts: &[Partition]) -> Result<Vec<ModelState>> {
    validate_partitions(parts, state.n_params())?;
    Ok(parts.iter().map(|p| slice_state(state, p)).collect())
}

/// Recover the consistent cut written by R ranks and restart the cluster
/// with the given R′ partitions (R′ may differ from R — the record, not
/// the caller, knows R). Stragglers beyond the cut are truncated, the new
/// cluster is spawned, and the cut state is re-anchored as a full epoch
/// under the new partitioning; the call **blocks until that anchor epoch
/// commits** and errors if it tears, so the caller never trains on top of
/// an unanchored reshard. Returns the running cluster, the recovered
/// global state, and cut statistics.
///
/// Crash-window fail-safe: when the cut epoch was itself a *full* at step
/// S, the re-anchor overwrites `rank-*/full-{S}` in place (names are
/// step-keyed), so a crash inside this call — after the first overwrite,
/// before the new record lands — invalidates the old record's tip CRCs.
/// The recovered cut is therefore persisted as a dedicated top-level
/// **safety-net full** ([`Manifest::reshard_net_name`], not a chain
/// object) *before* the new cluster touches any rank-namespaced name;
/// [`recover_cluster_or_net`](crate::cluster::commit::recover_cluster_or_net)
/// falls back to it whenever the cluster walk lands on an older step. The
/// net is deleted once the re-anchor record is durable. Diff-kind cuts
/// never had the window (the anchor writes new names, and chain loading
/// skips foreign-generation bases), but the net is written
/// unconditionally — one full write per restart removes the case
/// analysis. See docs/CLUSTER.md.
pub fn elastic_restart(
    store: &Arc<dyn StorageBackend>,
    adam: &Adam,
    new_parts: Vec<Partition>,
    cfg: ClusterConfig,
) -> Result<(Cluster, ModelState, ClusterCutStats)> {
    let (state, cut) = recover_cluster(store, cfg.model_sig, adam)
        .context("elastic restart: recovering the consistent cut")?;
    validate_partitions(&new_parts, state.n_params())
        .context("elastic restart: new partition table")?;
    truncate_stragglers(store, cut.cut_step)
        .context("elastic restart: truncating torn-commit stragglers")?;
    // fail-safe net: the cut survives as a dedicated top-level full until
    // the re-anchor commits, closing the step-keyed overwrite window
    // (recover_cluster_or_net reads exactly this object and nothing else)
    let net_name = Manifest::reshard_net_name();
    let net = write_full(&state, cfg.model_sig, cfg.codec)
        .context("elastic restart: encoding the safety-net full")?;
    store
        .put(net_name, &net)
        .context("elastic restart: writing the safety-net full")?;
    let cluster = Cluster::spawn(Arc::clone(store), new_parts, cfg);
    // re-anchor: every new rank needs a base full under ITS partitioning
    // before it can extend the chain (old chains use the old rank sigs)
    cluster.put_full(state.step, &state);
    cluster.wait_epochs(1);
    ensure!(
        cluster.epochs_committed() >= 1,
        "elastic restart: the re-anchor epoch tore (a rank write failed); \
         recover_cluster_or_net still restores the cut via the safety-net full"
    );
    // the anchor record is durable: the net is redundant now
    let _ = store.delete(net_name);
    Ok((cluster, state, cut))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition_even;
    use crate::util::rng::Rng;

    fn state(n: usize, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut p = vec![0f32; n];
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        rng.fill_normal_f32(&mut m);
        for x in v.iter_mut() {
            *x = rng.next_f32();
        }
        ModelState { params: Flat(p), m: Flat(m), v: Flat(v), step: 9 }
    }

    #[test]
    fn flatten_inverts_repartition_for_any_rank_counts() {
        let n = 103;
        let want = state(n, 5);
        for r in [1usize, 2, 3, 7] {
            let parts = partition_even(n, r);
            let slices = repartition(&want, &parts).unwrap();
            let pairs: Vec<(Partition, ModelState)> =
                parts.iter().copied().zip(slices).collect();
            assert_eq!(flatten(&pairs).unwrap(), want, "r={r}");
        }
    }

    #[test]
    fn flatten_accepts_any_slice_order() {
        let n = 30;
        let want = state(n, 8);
        let parts = partition_even(n, 3);
        let slices = repartition(&want, &parts).unwrap();
        let mut pairs: Vec<(Partition, ModelState)> =
            parts.iter().copied().zip(slices).collect();
        pairs.reverse();
        assert_eq!(flatten(&pairs).unwrap(), want);
    }

    #[test]
    fn flatten_rejects_gaps_and_step_skew() {
        let n = 20;
        let s = state(n, 2);
        let parts = partition_even(n, 2);
        let slices = repartition(&s, &parts).unwrap();
        // gap: drop one slice
        let gap = vec![(parts[1], slices[1].clone())];
        assert!(flatten(&gap).is_err());
        // step skew
        let mut skew = slices[1].clone();
        skew.step += 1;
        assert!(flatten(&[(parts[0], slices[0].clone()), (parts[1], skew)]).is_err());
    }

    #[test]
    fn reshard_4_to_2_preserves_every_coordinate() {
        let n = 64;
        let want = state(n, 4);
        let four = repartition(&want, &partition_even(n, 4)).unwrap();
        let pairs: Vec<(Partition, ModelState)> =
            partition_even(n, 4).into_iter().zip(four).collect();
        let flat = flatten(&pairs).unwrap();
        let two = repartition(&flat, &partition_even(n, 2)).unwrap();
        let pairs2: Vec<(Partition, ModelState)> =
            partition_even(n, 2).into_iter().zip(two).collect();
        assert_eq!(flatten(&pairs2).unwrap(), want);
    }
}
