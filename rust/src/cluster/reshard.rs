//! Elastic resharded recovery: restart with R′ ≠ R ranks.
//!
//! A cluster checkpoint is R per-rank chains plus a global record carrying
//! the partition table that produced them. An elastic restart therefore
//! does not need the old rank count configured anywhere: it reads all R
//! chains at the consistent cut
//! ([`find_consistent_cut`](crate::cluster::commit::find_consistent_cut)),
//! replays them to the cut state, and restarts the cluster over the new
//! R′ partitions — **in a fresh namespace generation** (`generation + 1`),
//! so not a single committed old-generation byte is overwritten. A crash
//! anywhere inside [`elastic_restart`] trivially falls back to the old
//! generation's record: the new generation either has a complete record
//! of its own (commit point) or is dead weight the next restart's
//! truncation sweeps away.
//!
//! The reshard is **incremental**, not a full-write burst:
//!
//! - each new rank's chain base is a [`Carry`](crate::checkpoint::carry)
//!   at the old chains' uniform base step `F`: moved-in intervals inline
//!   (~|ΔR|/max(R, R′) of the model under the consistent-hash
//!   partitioner, [`partition_hash`](crate::cluster::partition_hash)),
//!   retained intervals as references into the rank's own old-generation
//!   base;
//! - the committed diff history `(F, S]` is carried across by *re-cutting*
//!   the old ranks' sparse gradients into the new partitions (pure index
//!   mapping — every per-element value-update sequence is preserved, so
//!   replay stays bit-identical) and writing one merged span per new
//!   rank;
//! - one new global record at the cut step `S`, generation `g+1`, commits
//!   the whole event atomically.
//!
//! When the old bases are *not* at a uniform step (a rank's newest base
//! was damaged and chain loading fell back to an older one), the carry
//! fast path is unsound — the fallback re-anchors each new rank with a
//! plain full of its slice at `S`, still into the fresh generation.

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::checkpoint::carry::write_carry;
use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::full::write_full;
use crate::checkpoint::manifest::Manifest;
use crate::checkpoint::merged::write_merged;
use crate::cluster::commit::{
    find_consistent_cut, truncate_stragglers, ClusterCutStats, CommitKind, GlobalRecord,
    RankObject,
};
use crate::cluster::rank::Cluster;
use crate::cluster::{rank_sig, slice_state, validate_partitions, ClusterConfig, Partition, Slice};
use crate::optim::{Adam, ModelState};
use crate::sparse::SparseGrad;
use crate::storage::StorageBackend;
use crate::tensor::Flat;

/// Scatter per-rank state slices back into one global state. The
/// partitions (any order, possibly multi-slice) must tile the parameter
/// vector exactly and the slices must agree on the step.
pub fn flatten(slices: &[(Partition, ModelState)]) -> Result<ModelState> {
    ensure!(!slices.is_empty(), "nothing to flatten");
    let mut parts: Vec<Partition> = slices.iter().map(|(p, _)| p.clone()).collect();
    parts.sort_by_key(|p| p.rank);
    let n: usize = parts.iter().map(|p| p.len()).sum();
    validate_partitions(&parts, n).context("flatten partition table")?;
    let step = slices[0].1.step;
    let mut params = vec![0f32; n];
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    for (p, s) in slices {
        ensure!(s.n_params() == p.len(), "slice state {} != partition {}", s.n_params(), p.len());
        ensure!(s.step == step, "slice steps disagree: {} != {step}", s.step);
        let mut local = 0usize;
        for r in p.ranges() {
            let run = r.end - r.start;
            params[r.clone()].copy_from_slice(&s.params.0[local..local + run]);
            m[r.clone()].copy_from_slice(&s.m.0[local..local + run]);
            v[r.clone()].copy_from_slice(&s.v.0[local..local + run]);
            local += run;
        }
    }
    Ok(ModelState { params: Flat(params), m: Flat(m), v: Flat(v), step })
}

/// Cut a flattened global state into slices for a (new) partition table.
pub fn repartition(state: &ModelState, parts: &[Partition]) -> Result<Vec<ModelState>> {
    validate_partitions(parts, state.n_params())?;
    Ok(parts.iter().map(|p| slice_state(state, p)).collect())
}

/// Intersection of two sorted disjoint interval lists.
pub(crate) fn intersect_slices(a: &[Slice], b: &[Slice]) -> Vec<Slice> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let lo = a[i].offset.max(b[j].offset);
        let hi = a[i].end().min(b[j].end());
        if lo < hi {
            out.push(Slice { offset: lo, len: hi - lo });
        }
        if a[i].end() <= b[j].end() {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

/// `a` minus `b`, both sorted disjoint interval lists.
pub(crate) fn subtract_slices(a: &[Slice], b: &[Slice]) -> Vec<Slice> {
    let mut out = Vec::new();
    let mut j = 0usize;
    for s in a {
        let mut lo = s.offset;
        while j < b.len() && b[j].end() <= lo {
            j += 1;
        }
        let mut k = j;
        while k < b.len() && b[k].offset < s.end() {
            if b[k].offset > lo {
                out.push(Slice { offset: lo, len: b[k].offset - lo });
            }
            lo = lo.max(b[k].end());
            k += 1;
        }
        if lo < s.end() {
            out.push(Slice { offset: lo, len: s.end() - lo });
        }
    }
    out
}

/// Global-index → (rank, local-index) lookup over a partition table,
/// built once per reshard (binary search per gradient entry).
struct SliceMap {
    /// (offset, end, rank, local index of `offset`), sorted by offset
    entries: Vec<(usize, usize, usize, usize)>,
}

impl SliceMap {
    fn new(parts: &[Partition]) -> SliceMap {
        let mut entries = Vec::new();
        for p in parts {
            let mut local = 0usize;
            for s in &p.slices {
                entries.push((s.offset, s.end(), p.rank, local));
                local += s.len;
            }
        }
        entries.sort_unstable();
        SliceMap { entries }
    }

    fn locate(&self, g: usize) -> Option<(usize, usize)> {
        let i = self.entries.partition_point(|e| e.1 <= g);
        let &(off, end, rank, local) = self.entries.get(i)?;
        (off <= g && g < end).then_some((rank, local + (g - off)))
    }
}

/// Recover the consistent cut written by R ranks and restart the cluster
/// with the given R′ partitions (R′ may differ from R — the record, not
/// the caller, knows R). The restart writes **only into generation
/// `g+1`** (the caller's `cfg.generation` is overridden): a carry base
/// plus one re-cut merged span per new rank, then a new global record at
/// the cut step — the single commit point of the whole event. A crash
/// before the record leaves the old generation's record fully intact
/// (nothing of it was touched); a crash after it recovers onto the new
/// generation. Stragglers beyond the cut are truncated first. Returns
/// the running cluster (spawned over the new generation), the recovered
/// global state, and cut statistics.
pub fn elastic_restart(
    store: &Arc<dyn StorageBackend>,
    adam: &Adam,
    new_parts: Vec<Partition>,
    cfg: ClusterConfig,
) -> Result<(Cluster, ModelState, ClusterCutStats)> {
    let mut cfg = cfg;
    let (rec, chains, cut) = find_consistent_cut(store, cfg.model_sig)
        .context("elastic restart: searching for a consistent cut")?
        .context("elastic restart: no complete global commit record found")?;
    validate_partitions(&new_parts, rec.n_params())
        .context("elastic restart: new partition table")?;
    let new_gen = rec.generation + 1;
    ensure!(new_gen < 10_000, "generation namespace exhausted ({new_gen})");
    cfg.generation = new_gen;
    truncate_stragglers(store, rec.step)
        .context("elastic restart: truncating torn-commit stragglers")?;

    // the cut state S (needed for the fallback path and returned to the
    // caller for training to resume from)
    let replayed: Vec<(Partition, ModelState)> = chains
        .iter()
        .map(|ch| {
            let mut st = ch.base.clone();
            for (_, g) in &ch.diffs {
                adam.apply_sparse(&mut st, g);
            }
            st.step = rec.step;
            (ch.part.clone(), st)
        })
        .collect();
    let state = flatten(&replayed).context("elastic restart: flattening the cut state")?;

    let uniform_f = chains
        .windows(2)
        .all(|w| w[0].base.step == w[1].base.step)
        .then(|| chains[0].base.step);
    let tips: Vec<RankObject> = match uniform_f {
        Some(f) => write_incremental_reshard(store, &cfg, &rec, &chains, &new_parts, f)
            .context("elastic restart: incremental carry + re-cut")?,
        None => {
            // divergent base steps (a damaged base forced an older one):
            // the carry construction has no single F to anchor at — pay
            // the full re-anchor, still into the fresh generation
            log::warn!("elastic restart: old base steps diverge; re-anchoring with fulls");
            write_full_reshard(store, &cfg, &rec, &state, &new_parts)
                .context("elastic restart: full re-anchor")?
        }
    };
    // THE commit point: the new generation's record at the cut step
    let rec2 = GlobalRecord {
        model_sig: cfg.model_sig,
        generation: new_gen,
        step: rec.step,
        seq: rec.seq + 1,
        ranks: tips,
    };
    store
        .put(&rec2.name(), &rec2.to_bytes())
        .context("elastic restart: committing the reshard record")?;
    let cluster = Cluster::spawn(Arc::clone(store), new_parts, cfg);
    Ok((cluster, state, cut))
}

/// The incremental fast path: per new rank, a carry base at the uniform
/// old base step `F` (moved intervals inline, retained by reference) and
/// one merged span of the old diff history `(F, S]` re-cut into the new
/// partition. Returns the per-rank record entries (tip = the span, or
/// the carry when `F == S`).
fn write_incremental_reshard(
    store: &Arc<dyn StorageBackend>,
    cfg: &ClusterConfig,
    rec: &GlobalRecord,
    chains: &[crate::cluster::commit::RankChain],
    new_parts: &[Partition],
    f: u64,
) -> Result<Vec<RankObject>> {
    // global base state at F — only its moved intervals are serialized
    let base_pairs: Vec<(Partition, ModelState)> =
        chains.iter().map(|c| (c.part.clone(), c.base.clone())).collect();
    let global_f = flatten(&base_pairs).context("flattening the old bases at F")?;

    // re-cut the diff history: old-local → global → new-local, preserving
    // every (element, step, value) triple exactly
    let steps: BTreeSet<u64> = chains.iter().flat_map(|c| c.diffs.iter().map(|(s, _)| *s)).collect();
    let map = SliceMap::new(new_parts);
    let mut recut: Vec<std::collections::BTreeMap<u64, Vec<(u32, f32)>>> =
        new_parts.iter().map(|_| Default::default()).collect();
    for ch in chains {
        for (step, g) in &ch.diffs {
            for (&idx, &val) in g.indices.iter().zip(&g.values) {
                let gidx = ch.part.global_of_local(idx as usize);
                let (r, l) = map
                    .locate(gidx)
                    .with_context(|| format!("gradient index {gidx} outside the new partitions"))?;
                recut[r].entry(*step).or_default().push((l as u32, val));
            }
        }
    }

    let mut tips = Vec::with_capacity(new_parts.len());
    for (part, mut per_step) in new_parts.iter().zip(recut) {
        let rsig = rank_sig(cfg.model_sig, part);
        let prefix = Manifest::gen_rank_prefix(cfg.generation, part.rank);
        // retained = still owned by the same rank id under the old table
        // (consistent hashing keeps these large); moved = everything else
        let old_slices: &[Slice] =
            chains.get(part.rank).map(|c| c.part.slices.as_slice()).unwrap_or(&[]);
        let refs = intersect_slices(&part.slices, old_slices);
        let moved = subtract_slices(&part.slices, &refs);
        let src_base =
            if refs.is_empty() { String::new() } else { chains[part.rank].objects[0].clone() };
        let carry_bytes = write_carry(
            &global_f,
            &moved,
            &refs,
            rec.generation,
            rec.step,
            &src_base,
            rsig,
            cfg.codec,
        )
        .with_context(|| format!("encoding rank {} carry", part.rank))?;
        let carry_name = format!("{prefix}{}", Manifest::carry_name(f));
        store.put(&carry_name, &carry_bytes)?;

        let (tip_name, tip_bytes, kind) = if f < rec.step {
            // one span covering (F, S]: every committed step appears
            // (empty where this rank's slice got no gradient mass), so
            // the span validates and replays like any compacted chain
            let items: Vec<(u64, DiffPayload)> = steps
                .iter()
                .map(|&s| {
                    let mut pairs = per_step.remove(&s).unwrap_or_default();
                    pairs.sort_unstable_by_key(|&(i, _)| i);
                    let g = SparseGrad {
                        dense_len: part.len() as u32,
                        indices: pairs.iter().map(|&(i, _)| i).collect(),
                        values: pairs.iter().map(|&(_, v)| v).collect(),
                    };
                    (s, DiffPayload::Gradient(g))
                })
                .collect();
            let span_bytes = write_merged(&items, rsig, f + 1, rec.step, cfg.codec)
                .with_context(|| format!("encoding rank {} re-cut span", part.rank))?;
            let span_name = format!("{prefix}{}", Manifest::merged_name(f + 1, rec.step));
            store.put(&span_name, &span_bytes)?;
            (span_name, span_bytes, CommitKind::Diff)
        } else {
            // the cut was a full epoch: the carry IS the tip
            (carry_name, carry_bytes, CommitKind::Carry)
        };
        tips.push(RankObject {
            rank: part.rank as u32,
            slices: part.slices.iter().map(|s| (s.offset as u64, s.len as u64)).collect(),
            kind,
            name: tip_name,
            obj_len: tip_bytes.len() as u64,
            obj_crc: crc32fast::hash(&tip_bytes),
        });
    }
    Ok(tips)
}

/// The fallback: re-anchor each new rank with a plain full of its slice
/// at the cut step, into the fresh generation.
fn write_full_reshard(
    store: &Arc<dyn StorageBackend>,
    cfg: &ClusterConfig,
    rec: &GlobalRecord,
    state: &ModelState,
    new_parts: &[Partition],
) -> Result<Vec<RankObject>> {
    let mut tips = Vec::with_capacity(new_parts.len());
    for part in new_parts {
        let rsig = rank_sig(cfg.model_sig, part);
        let slice = slice_state(state, part);
        let bytes = write_full(&slice, rsig, cfg.codec)
            .with_context(|| format!("encoding rank {} re-anchor full", part.rank))?;
        let name = format!(
            "{}{}",
            Manifest::gen_rank_prefix(cfg.generation, part.rank),
            Manifest::full_name(rec.step)
        );
        store.put(&name, &bytes)?;
        tips.push(RankObject {
            rank: part.rank as u32,
            slices: part.slices.iter().map(|s| (s.offset as u64, s.len as u64)).collect(),
            kind: CommitKind::Full,
            name,
            obj_len: bytes.len() as u64,
            obj_crc: crc32fast::hash(&bytes),
        });
    }
    Ok(tips)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{partition_even, partition_hash};
    use crate::util::rng::Rng;

    fn state(n: usize, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut p = vec![0f32; n];
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        rng.fill_normal_f32(&mut m);
        for x in v.iter_mut() {
            *x = rng.next_f32();
        }
        ModelState { params: Flat(p), m: Flat(m), v: Flat(v), step: 9 }
    }

    #[test]
    fn flatten_inverts_repartition_for_any_rank_counts() {
        let n = 103;
        let want = state(n, 5);
        for r in [1usize, 2, 3, 7] {
            for parts in [partition_even(n, r), partition_hash(n, r)] {
                let slices = repartition(&want, &parts).unwrap();
                let pairs: Vec<(Partition, ModelState)> =
                    parts.iter().cloned().zip(slices).collect();
                assert_eq!(flatten(&pairs).unwrap(), want, "r={r}");
            }
        }
    }

    #[test]
    fn flatten_accepts_any_slice_order() {
        let n = 30;
        let want = state(n, 8);
        let parts = partition_hash(n, 3);
        let slices = repartition(&want, &parts).unwrap();
        let mut pairs: Vec<(Partition, ModelState)> = parts.iter().cloned().zip(slices).collect();
        pairs.reverse();
        assert_eq!(flatten(&pairs).unwrap(), want);
    }

    #[test]
    fn flatten_rejects_gaps_and_step_skew() {
        let n = 20;
        let s = state(n, 2);
        let parts = partition_even(n, 2);
        let slices = repartition(&s, &parts).unwrap();
        // gap: drop one slice
        let gap = vec![(parts[1].clone(), slices[1].clone())];
        assert!(flatten(&gap).is_err());
        // step skew
        let mut skew = slices[1].clone();
        skew.step += 1;
        assert!(
            flatten(&[(parts[0].clone(), slices[0].clone()), (parts[1].clone(), skew)]).is_err()
        );
    }

    #[test]
    fn reshard_4_to_2_preserves_every_coordinate() {
        let n = 64;
        let want = state(n, 4);
        let four = repartition(&want, &partition_hash(n, 4)).unwrap();
        let pairs: Vec<(Partition, ModelState)> =
            partition_hash(n, 4).into_iter().zip(four).collect();
        let flat = flatten(&pairs).unwrap();
        let two = repartition(&flat, &partition_hash(n, 2)).unwrap();
        let pairs2: Vec<(Partition, ModelState)> =
            partition_hash(n, 2).into_iter().zip(two).collect();
        assert_eq!(flatten(&pairs2).unwrap(), want);
    }

    #[test]
    fn interval_intersect_and_subtract_partition_the_input() {
        let a = vec![Slice { offset: 0, len: 10 }, Slice { offset: 20, len: 10 }];
        let b = vec![
            Slice { offset: 5, len: 3 },
            Slice { offset: 15, len: 7 }, // overlaps [20, 22)
            Slice { offset: 28, len: 10 },
        ];
        let inter = intersect_slices(&a, &b);
        assert_eq!(
            inter,
            vec![
                Slice { offset: 5, len: 3 },
                Slice { offset: 20, len: 2 },
                Slice { offset: 28, len: 2 },
            ]
        );
        let diff = subtract_slices(&a, &inter);
        assert_eq!(
            diff,
            vec![
                Slice { offset: 0, len: 5 },
                Slice { offset: 8, len: 2 },
                Slice { offset: 22, len: 6 },
            ]
        );
        // inter ∪ diff tiles a exactly
        let mut union: Vec<Slice> = inter.iter().chain(&diff).cloned().collect();
        union.sort();
        let total: usize = union.iter().map(|s| s.len).sum();
        assert_eq!(total, a.iter().map(|s| s.len).sum::<usize>());
    }

    #[test]
    fn interval_ops_property() {
        crate::util::prop::prop_check("reshard_interval_ops", 64, |rng| {
            // random sorted disjoint interval lists over [0, 200)
            let mk = |rng: &mut Rng| {
                let mut out: Vec<Slice> = Vec::new();
                let mut pos = 0usize;
                while pos + 2 < 200 {
                    pos += rng.range(0, 10);
                    let len = rng.range(1, 12);
                    if pos + len > 200 {
                        break;
                    }
                    out.push(Slice { offset: pos, len });
                    pos += len;
                }
                out
            };
            let a = mk(rng);
            let b = mk(rng);
            let inter = intersect_slices(&a, &b);
            let sub = subtract_slices(&a, &inter);
            // element-wise oracle
            let in_set = |set: &[Slice], x: usize| set.iter().any(|s| s.offset <= x && x < s.end());
            for x in 0..200 {
                let want_inter = in_set(&a, x) && in_set(&b, x);
                let want_sub = in_set(&a, x) && !in_set(&b, x);
                crate::prop_assert!(in_set(&inter, x) == want_inter);
                crate::prop_assert!(in_set(&sub, x) == want_sub);
            }
            Ok(())
        });
    }

    #[test]
    fn slice_map_locates_every_element() {
        let n = 500;
        let parts = partition_hash(n, 5);
        let map = SliceMap::new(&parts);
        for g in 0..n {
            let (r, l) = map.locate(g).expect("every element is owned");
            assert_eq!(parts[r].global_of_local(l), g);
        }
        assert!(map.locate(n).is_none());
    }
}
