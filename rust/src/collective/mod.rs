//! In-process collective communication — the NCCL stand-in.
//!
//! The real engine runs W logical workers inside one process; gradient
//! synchronization (paper Eq. (3)) is a genuine ring allreduce over chunked
//! buffers, not a shortcut mean, so the dataflow (reduce-scatter +
//! all-gather, W-1 steps each) matches what the α-β model in [`crate::simnet`]
//! prices for the simulator.

use crate::sparse::SparseGrad;
use crate::tensor::Flat;

/// Ring allreduce (sum) over `workers` equal-length buffers, in place.
///
/// Implements the standard two-phase ring: reduce-scatter then all-gather,
/// with each buffer split into `workers` chunks. After return every worker
/// holds the element-wise sum.
pub fn ring_allreduce_sum(workers: &mut [Flat]) {
    let w = workers.len();
    assert!(w > 0);
    if w == 1 {
        return;
    }
    let n = workers[0].len();
    assert!(workers.iter().all(|b| b.len() == n), "length mismatch");
    // chunk boundaries (last chunk absorbs the remainder)
    let bounds: Vec<(usize, usize)> = (0..w)
        .map(|c| {
            let lo = c * n / w;
            let hi = (c + 1) * n / w;
            (lo, hi)
        })
        .collect();

    // reduce-scatter: step s, worker r sends chunk (r - s) to (r + 1)
    for s in 0..w - 1 {
        for r in 0..w {
            let src = r;
            let dst = (r + 1) % w;
            let chunk = (r + w - s) % w;
            let (lo, hi) = bounds[chunk];
            // dst.chunk += src.chunk  (simultaneous ring step: buffer the
            // sends so a step's reads all see pre-step values)
            let data: Vec<f32> = workers[src].0[lo..hi].to_vec();
            for (i, v) in data.into_iter().enumerate() {
                workers[dst].0[lo + i] += v;
            }
        }
    }
    // NOTE: the naive in-place loop above is *sequential* per step, which
    // is fine because each chunk is touched by exactly one (src, dst) pair
    // per step — no worker reads a chunk another worker writes this step.

    // all-gather: worker (c + 1) now owns the fully-reduced chunk c
    for s in 0..w - 1 {
        for r in 0..w {
            let src = r;
            let dst = (r + 1) % w;
            let chunk = (r + 1 + w - s) % w;
            let (lo, hi) = bounds[chunk];
            let data: Vec<f32> = workers[src].0[lo..hi].to_vec();
            workers[dst].0[lo..hi].copy_from_slice(&data);
        }
    }
}

/// Allreduce-mean (the synchronized gradient of data-parallel training).
pub fn ring_allreduce_mean(workers: &mut [Flat]) {
    let w = workers.len() as f32;
    ring_allreduce_sum(workers);
    for b in workers.iter_mut() {
        b.scale(1.0 / w);
    }
}

/// Sparse allgather-sum: union-merge per-worker compressed gradients —
/// what "synchronize the compressed gradient" (Alg. 1 line 5) means for
/// sparsified training: every worker ends with the merged k-sparse sum.
pub fn sparse_allgather_sum(workers: &[SparseGrad]) -> SparseGrad {
    assert!(!workers.is_empty());
    let mut acc = workers[0].clone();
    // in-place fold: one scratch ping-pongs with the accumulator instead of
    // allocating a fresh union per merge (per-iteration sync hot path)
    let mut scratch =
        SparseGrad { dense_len: acc.dense_len, indices: Vec::new(), values: Vec::new() };
    for w in &workers[1..] {
        acc.merge_sum_into(w, &mut scratch);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn make_workers(w: usize, n: usize, seed: u64) -> Vec<Flat> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..w)
            .map(|_| {
                let mut v = vec![0f32; n];
                rng.fill_normal_f32(&mut v);
                Flat(v)
            })
            .collect()
    }

    #[test]
    fn allreduce_sum_matches_reference() {
        prop_check("ring_allreduce_sum", 32, |rng| {
            let w = rng.range(1, 9);
            let n = rng.range(1, 200);
            let mut workers = make_workers(w, n, rng.next_u64());
            let mut want = Flat::zeros(n);
            for b in &workers {
                want.add_assign(b);
            }
            ring_allreduce_sum(&mut workers);
            for (r, b) in workers.iter().enumerate() {
                prop_assert!(
                    b.max_abs_diff(&want) < 1e-4,
                    "worker {r} diverges by {}",
                    b.max_abs_diff(&want)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn allreduce_all_workers_identical() {
        let mut workers = make_workers(4, 1003, 5);
        ring_allreduce_sum(&mut workers);
        for r in 1..4 {
            assert_eq!(workers[0].0, workers[r].0);
        }
    }

    #[test]
    fn mean_scales() {
        let mut workers = vec![Flat(vec![2.0, 4.0]), Flat(vec![4.0, 0.0])];
        ring_allreduce_mean(&mut workers);
        assert_eq!(workers[0].0, vec![3.0, 2.0]);
        assert_eq!(workers[1].0, vec![3.0, 2.0]);
    }

    #[test]
    fn single_worker_identity() {
        let mut workers = vec![Flat(vec![1.0, 2.0, 3.0])];
        ring_allreduce_sum(&mut workers);
        assert_eq!(workers[0].0, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn n_smaller_than_workers() {
        let mut workers = make_workers(5, 2, 9);
        let mut want = Flat::zeros(2);
        for b in &workers {
            want.add_assign(b);
        }
        ring_allreduce_sum(&mut workers);
        for b in &workers {
            assert!(b.max_abs_diff(&want) < 1e-5);
        }
    }

    #[test]
    fn sparse_allgather_matches_dense() {
        prop_check("sparse_allgather", 32, |rng| {
            let w = rng.range(1, 6);
            let n = rng.range(1, 200);
            let mut dense_sum = Flat::zeros(n);
            let mut sparses = Vec::new();
            for _ in 0..w {
                let mut d = Flat::zeros(n);
                for i in 0..n {
                    if rng.next_f64() < 0.15 {
                        d.0[i] = rng.normal() as f32;
                    }
                }
                dense_sum.add_assign(&d);
                sparses.push(SparseGrad::from_dense(&d));
            }
            let merged = sparse_allgather_sum(&sparses);
            prop_assert!(merged.to_dense().max_abs_diff(&dense_sum) < 1e-5);
            Ok(())
        });
    }
}
