//! Rust-side gradient compression codecs.
//!
//! The *training-path* compressor is the L1 Pallas kernel (lowered into the
//! HLO artifacts). This module provides the equivalent CPU codecs the
//! coordinator needs outside the PJRT graph:
//!
//! - [`topk_mask`]: exact top-k selection — used by the **Naive DC baseline**
//!   (Check-N-Run style), whose defining cost is doing this compression on
//!   the 3Ψ state *differential* every checkpoint (paper Challenge 1).
//! - [`TopKCodec`] / [`Quant8Codec`]: checkpoint payload encoders mirroring
//!   the Pallas kernels' semantics (tested against dumps of `ref.py`).

use crate::sparse::SparseGrad;
use crate::tensor::Flat;

// Default magnitude scratch for `topk_mask` callers that don't own one;
// reused across calls on the same thread, so the full-model-size `Vec<f32>`
// is allocated once, not per checkpoint.
thread_local! {
    static TOPK_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Exact top-k by |value|: returns the dense-masked tensor.
/// O(n) average via quickselect on magnitudes, then one masking pass.
/// Uses a thread-local magnitude scratch; hot loops that want full control
/// pass their own via [`topk_mask_with_scratch`].
pub fn topk_mask(x: &Flat, k: usize) -> Flat {
    TOPK_SCRATCH.with(|cell| topk_mask_with_scratch(x, k, &mut cell.borrow_mut()))
}

/// [`topk_mask`] with a caller-owned magnitude scratch: `scratch` is
/// cleared and refilled (capacity reused), never reallocated once it has
/// grown to the model size.
pub fn topk_mask_with_scratch(x: &Flat, k: usize, scratch: &mut Vec<f32>) -> Flat {
    let n = x.len();
    if k >= n {
        return x.clone();
    }
    if k == 0 {
        return Flat::zeros(n);
    }
    // §Perf iteration 3: std introselect (select_nth_unstable) replaced the
    // hand-rolled three-way quickselect — 16.7 ms -> see EXPERIMENTS.md.
    scratch.clear();
    scratch.reserve(n);
    scratch.extend(x.0.iter().map(|v| v.abs()));
    let mags = scratch;
    let kth = {
        let (_, kth, _) =
            mags.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
        *kth
    };
    // keep |v| > kth fully; fill remaining quota from |v| == kth in order
    let mut out = Flat::zeros(n);
    let mut kept = 0usize;
    for (i, &v) in x.0.iter().enumerate() {
        if v.abs() > kth {
            out.0[i] = v;
            kept += 1;
        }
    }
    for (i, &v) in x.0.iter().enumerate() {
        if kept >= k {
            break;
        }
        if v.abs() == kth && out.0[i] == 0.0 && v != 0.0 {
            out.0[i] = v;
            kept += 1;
        }
    }
    out
}

/// k-th largest (0-based rank) via in-place quickselect (descending).
/// Retained as the reference implementation for the std-introselect fast
/// path above (cross-checked in tests); not on the hot path anymore.
#[allow(dead_code)]
fn quickselect_desc(v: &mut [f32], rank: usize) -> f32 {
    let (mut lo, mut hi) = (0usize, v.len());
    let mut r = rank;
    loop {
        if hi - lo <= 1 {
            return v[lo];
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let pivot = {
            let (a, b, c) = (v[lo], v[mid], v[hi - 1]);
            a.max(b).min(a.min(b).max(c))
        };
        // three-way partition descending: [> pivot | == pivot | < pivot]
        let (mut i, mut j, mut k) = (lo, lo, hi);
        while j < k {
            if v[j] > pivot {
                v.swap(i, j);
                i += 1;
                j += 1;
            } else if v[j] < pivot {
                k -= 1;
                v.swap(j, k);
            } else {
                j += 1;
            }
        }
        let gt = i - lo;
        let eq = j - i;
        if r < gt {
            hi = i;
        } else if r < gt + eq {
            return pivot;
        } else {
            r -= gt + eq;
            lo = j;
        }
    }
}

/// Top-k with error feedback (matches `kernels/topk.py::sparsify_ef`):
/// corrected = g + residual; masked = topk(corrected); residual' = rest.
pub fn sparsify_ef(g: &Flat, residual: &mut Flat, k: usize) -> Flat {
    assert_eq!(g.len(), residual.len());
    let mut corrected = g.clone();
    corrected.add_assign(residual);
    let masked = topk_mask(&corrected, k);
    for i in 0..g.len() {
        residual.0[i] = corrected.0[i] - masked.0[i];
    }
    masked
}

/// Elements per int8 quantization scale (matches `kernels/quant.py`).
pub const QBLOCK: usize = 256;

/// Round half-to-even, the IEEE default `jnp.round`/`np.round` use. The
/// Pallas kernels and `ref.py` quantize with it; `f32::round` rounds half
/// away from zero, which the golden-vector suite caught as a one-ulp drift
/// on exact `.5` ties (e.g. 2.5 -> 3 instead of the reference's 2).
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d < 0.5 {
        f
    } else if d > 0.5 {
        f + 1.0
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Quantize `vals` (no padding) in [`QBLOCK`] blocks straight into byte and
/// scale sinks — the allocation-light form the wire codec
/// ([`crate::checkpoint::format::PayloadCodec::Quant8`]) encodes sparse
/// value streams with. Appends exactly `vals.len()` bytes to `q` and
/// `ceil(len/QBLOCK)` scales to `scales`.
pub fn quant8_into(vals: &[f32], q: &mut Vec<u8>, scales: &mut Vec<f32>) {
    for block in vals.chunks(QBLOCK) {
        let absmax = block.iter().fold(0f32, |m, v| m.max(v.abs()));
        let scale = absmax / 127.0;
        scales.push(scale);
        let safe = if scale > 0.0 { scale } else { 1.0 };
        for &v in block {
            q.push(round_half_even(v / safe).clamp(-127.0, 127.0) as i8 as u8);
        }
    }
}

/// Inverse of one [`quant8_into`] lane.
#[inline]
pub fn dequant8_at(q: u8, scale: f32) -> f32 {
    (q as i8) as f32 * scale
}

/// Per-block symmetric int8 quantization payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Quant8 {
    pub n: u32,
    pub q: Vec<i8>,
    pub scales: Vec<f32>,
}

/// Quantize (matches `quant8_ref`): scale = absmax/127 per QBLOCK.
pub fn quant8(x: &Flat) -> Quant8 {
    let n = x.len();
    let nb = n.div_ceil(QBLOCK);
    let mut q = vec![0i8; nb * QBLOCK];
    let mut scales = vec![0f32; nb];
    for b in 0..nb {
        let lo = b * QBLOCK;
        let hi = ((b + 1) * QBLOCK).min(n);
        let absmax = x.0[lo..hi].iter().fold(0f32, |m, v| m.max(v.abs()));
        let scale = absmax / 127.0;
        scales[b] = scale;
        let safe = if scale > 0.0 { scale } else { 1.0 };
        for i in lo..hi {
            q[i] = round_half_even(x.0[i] / safe).clamp(-127.0, 127.0) as i8;
        }
    }
    Quant8 { n: n as u32, q, scales }
}

pub fn dequant8(qx: &Quant8) -> Flat {
    let mut out = Flat::zeros(qx.n as usize);
    for i in 0..qx.n as usize {
        out.0[i] = qx.q[i] as f32 * qx.scales[i / QBLOCK];
    }
    out
}

/// Checkpoint payload codec selector (what goes inside a diff container).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// k-sparse indices+values (LowDiff's format).
    TopK,
    /// int8 + per-block scales (quantization family).
    Quant8,
    /// raw dense f32 (no compression — LowDiff+ / full checkpoints).
    Dense,
}

/// Compressed bytes of a gradient under a codec (storage accounting and
/// the actual checkpoint payload).
pub fn encode(codec: Codec, g: &Flat) -> Vec<u8> {
    match codec {
        Codec::TopK => SparseGrad::from_dense(g).to_bytes(),
        Codec::Dense => g.to_le_bytes(),
        Codec::Quant8 => {
            let qx = quant8(g);
            let mut out = Vec::with_capacity(8 + qx.q.len() + 4 * qx.scales.len());
            out.extend_from_slice(&qx.n.to_le_bytes());
            out.extend_from_slice(&(qx.scales.len() as u32).to_le_bytes());
            out.extend(qx.q.iter().map(|&b| b as u8));
            for s in &qx.scales {
                out.extend_from_slice(&s.to_le_bytes());
            }
            out
        }
    }
}

/// Decode back to dense (inverse of [`encode`]; lossy only for Quant8).
pub fn decode(codec: Codec, bytes: &[u8]) -> anyhow::Result<Flat> {
    match codec {
        Codec::TopK => Ok(SparseGrad::from_bytes(bytes)?.to_dense()),
        Codec::Dense => Ok(Flat::from_le_bytes(bytes)),
        Codec::Quant8 => {
            anyhow::ensure!(bytes.len() >= 8, "quant8 truncated");
            let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
            let nb = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
            let qlen = nb * QBLOCK;
            anyhow::ensure!(bytes.len() == 8 + qlen + 4 * nb, "quant8 length");
            let q: Vec<i8> = bytes[8..8 + qlen].iter().map(|&b| b as i8).collect();
            let scales: Vec<f32> = bytes[8 + qlen..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(dequant8(&Quant8 { n, q, scales }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{arb_vec_f32, prop_check};

    #[test]
    fn topk_selects_largest() {
        let x = Flat(vec![0.1, -5.0, 2.0, 0.0, 3.0]);
        let m = topk_mask(&x, 2);
        assert_eq!(m.0, vec![0.0, -5.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn topk_k_zero_and_full() {
        let x = Flat(vec![1.0, 2.0]);
        assert_eq!(topk_mask(&x, 0).count_nonzero(), 0);
        assert_eq!(topk_mask(&x, 5), x);
    }

    #[test]
    fn topk_exact_count_property() {
        prop_check("topk_count", 64, |rng| {
            let v = Flat(arb_vec_f32(rng, 400));
            let k = rng.range(1, v.len() + 1);
            let m = topk_mask(&v, k);
            prop_assert!(m.count_nonzero() == k.min(v.count_nonzero()),
                "k={k} nnz={} vs {}", m.count_nonzero(), k.min(v.count_nonzero()));
            // dominance: min kept magnitude >= max dropped magnitude
            let kept_min = m.0.iter().filter(|&&x| x != 0.0)
                .map(|x| x.abs()).fold(f32::INFINITY, f32::min);
            let dropped_max = v.0.iter().zip(m.0.iter())
                .filter(|(_, &mv)| mv == 0.0)
                .map(|(x, _)| x.abs()).fold(0.0f32, f32::max);
            prop_assert!(kept_min >= dropped_max, "{kept_min} < {dropped_max}");
            Ok(())
        });
    }

    #[test]
    fn topk_with_ties() {
        let x = Flat(vec![1.0; 8]);
        assert_eq!(topk_mask(&x, 3).count_nonzero(), 3);
    }

    #[test]
    fn topk_scratch_variant_matches_and_reuses_capacity() {
        prop_check("topk_scratch_equiv", 32, |rng| {
            let v = Flat(arb_vec_f32(rng, 300));
            let k = rng.range(0, v.len() + 2);
            let mut scratch = Vec::new();
            let a = topk_mask(&v, k);
            let b = topk_mask_with_scratch(&v, k, &mut scratch);
            prop_assert!(a == b);
            // a second call of the same size must not grow the scratch
            let cap = scratch.capacity();
            let _ = topk_mask_with_scratch(&v, k, &mut scratch);
            prop_assert!(scratch.capacity() == cap, "scratch regrew");
            Ok(())
        });
    }

    #[test]
    fn error_feedback_conserves_mass() {
        prop_check("ef_conservation", 64, |rng| {
            let g = Flat(arb_vec_f32(rng, 300));
            let mut residual = Flat(arb_vec_f32(rng, g.len()));
            // force same length
            residual.0.truncate(g.len());
            residual.0.resize(g.len(), 0.0);
            let before: Vec<f32> =
                g.0.iter().zip(residual.0.iter()).map(|(a, b)| a + b).collect();
            let k = rng.range(1, g.len() + 1);
            let masked = sparsify_ef(&g, &mut residual, k);
            for i in 0..g.len() {
                prop_assert!(masked.0[i] + residual.0[i] == before[i],
                    "mass leak at {i}");
            }
            Ok(())
        });
    }

    #[test]
    fn quant8_roundtrip_error_bound() {
        prop_check("quant8_bound", 32, |rng| {
            let x = Flat(arb_vec_f32(rng, 1000));
            let qx = quant8(&x);
            let back = dequant8(&qx);
            for i in 0..x.len() {
                let bound = qx.scales[i / QBLOCK] / 2.0 + 1e-7;
                prop_assert!((back.0[i] - x.0[i]).abs() <= bound,
                    "elem {i}: {} vs {}", back.0[i], x.0[i]);
            }
            Ok(())
        });
    }

    #[test]
    fn codecs_roundtrip() {
        prop_check("codec_roundtrip", 32, |rng| {
            let x = Flat(arb_vec_f32(rng, 600));
            let sparse = topk_mask(&x, x.len() / 10 + 1);
            let d = decode(Codec::TopK, &encode(Codec::TopK, &sparse)).unwrap();
            prop_assert!(d == sparse);
            let d = decode(Codec::Dense, &encode(Codec::Dense, &x)).unwrap();
            prop_assert!(d == x);
            Ok(())
        });
    }

    #[test]
    fn topk_size_is_one_third_of_state_diff() {
        // Finding 2 sanity: compressed gradient (Ψ elements) vs compressed
        // state differential (3Ψ elements) at the same ρ is 3x smaller.
        let psi = 3000;
        let rho = 0.01;
        let g = Flat(arb_vec_f32(&mut crate::util::rng::Rng::new(1), psi));
        let mut state = Flat(arb_vec_f32(&mut crate::util::rng::Rng::new(2), 3 * psi));
        state.0.truncate(3 * psi);
        let k_g = (rho * psi as f64) as usize;
        let k_s = (rho * (3 * psi) as f64) as usize;
        let eg = encode(Codec::TopK, &topk_mask(&g, k_g)).len();
        let es = encode(Codec::TopK, &topk_mask(&state, k_s)).len();
        assert!((es as f64 / eg as f64 - 3.0).abs() < 0.1, "{es} / {eg}");
    }

    // ---- golden vectors vs the Python references ------------------------
    // Inputs are regenerated deterministically (the same LCG the dump
    // script used); expectations were produced by running the numpy mirror
    // of `python/compile/kernels/ref.py::quant8_ref` / `topk_mask_ref`.

    /// The dump script's LCG: `s = s*6364136223846793005 + 1442695040888963407`,
    /// value = `f32((u - 0.5) * 4)` with `u = (s >> 11) / 2^53`.
    fn golden_lcg(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let u = (s >> 11) as f64 / (1u64 << 53) as f64;
                ((u - 0.5) * 4.0) as f32
            })
            .collect()
    }

    /// `quant8_ref` expectation for the 300-element golden input (block 0
    /// crafted to scale exactly 1.0 with `.5` ties, block 1 all zero).
    const GOLDEN_Q: [i8; 300] = [
        127, 2, -2, 4, 0, 2, -2, -1, 0, -2, -1, 0, 2, -1, 1, 0, 1, -2, 0, 1, -1, 0, 0, -1, 0,
        0, 0, 0, 0, 0, 2, 1, 0, 1, -2, 1, -1, -1, 2, 1, -1, 2, 1, 2, 0, 1, 0, -2, -1, -2, 0,
        0, -1, 0, -2, 0, -1, 1, -2, -1, 0, -2, 1, 1, 0, -1, 2, 2, -1, -1, 1, 1, -2, 2, 0, -1,
        -2, 1, -1, 2, 0, 0, 0, -1, 1, 0, 0, 2, 1, 1, 2, 1, -2, 2, -2, -2, 1, -1, 1, -1, -2,
        1, 1, -1, 0, -1, 0, 0, 1, -2, 2, 0, -1, 1, 1, 1, 2, 0, 2, 1, 1, 0, 1, -2, -1, 1, -1,
        2, -1, 0, 1, 0, -1, 0, 2, 1, -2, 1, -2, -2, 0, -2, -1, 2, 0, 2, 0, 1, -1, 0, 1, 0, 0,
        -2, 1, 0, -1, 1, 1, 0, 0, 0, 1, -1, 2, -1, 0, -2, 1, 0, -1, -2, -1, 2, 0, 2, 2, 1, 0,
        -2, 0, 2, 0, -1, -2, -2, 2, 1, 2, 0, 0, 0, 0, 0, 2, 0, -1, 2, -2, 0, -2, -2, 0, 1, 0,
        2, 1, 1, 0, 2, 1, 0, -2, 1, 0, 1, 2, 0, -2, 0, -1, -1, 1, -2, 1, -1, -2, -2, -1, 1,
        -1, 1, -2, 2, 2, 0, 1, 1, 1, 2, 1, 0, -1, 2, 2, 1, 1, 1, -1, 1, 2, 0, 2, 1, 1, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
    ];

    fn golden_quant_input() -> Flat {
        let mut g = golden_lcg(42, 300);
        // crafted head: absmax 127 -> scale exactly 1.0, then `.5` ties
        // that split round-half-even (reference) from round-half-away
        g[0] = 127.0;
        g[1] = 2.5;
        g[2] = -2.5;
        g[3] = 3.5;
        g[4] = 0.5;
        g[5] = 1.5;
        for v in g[256..].iter_mut() {
            *v = 0.0; // block 1 zero: exercises the scale == 0 path
        }
        Flat(g)
    }

    #[test]
    fn quant8_matches_python_reference_dump() {
        let g = golden_quant_input();
        let qx = quant8(&g);
        assert_eq!(qx.scales, vec![1.0, 0.0], "scales drifted from quant8_ref");
        assert_eq!(&qx.q[..300], &GOLDEN_Q[..], "q stream drifted from quant8_ref");
        assert!(qx.q[300..].iter().all(|&b| b == 0), "padding lanes must quantize to 0");
        // and the streaming form the wire codec uses agrees lane-for-lane
        let (mut qs, mut scales) = (Vec::new(), Vec::new());
        quant8_into(&g.0, &mut qs, &mut scales);
        assert_eq!(scales, qx.scales);
        assert!(qs.iter().map(|&b| b as i8).eq(qx.q[..300].iter().copied()));
        for (i, &b) in qs.iter().enumerate() {
            assert_eq!(dequant8_at(b, scales[i / QBLOCK]), qx.q[i] as f32 * qx.scales[i / QBLOCK]);
        }
    }

    #[test]
    fn quant8_reference_error_bound_holds_on_golden_input() {
        let g = golden_quant_input();
        let qx = quant8(&g);
        let back = dequant8(&qx);
        for i in 0..g.len() {
            let bound = qx.scales[i / QBLOCK] / 2.0 + 1e-7;
            assert!((back.0[i] - g.0[i]).abs() <= bound, "elem {i}");
        }
    }

    #[test]
    fn round_half_even_matches_ieee_ties() {
        for (x, want) in [(2.5f32, 2.0f32), (-2.5, -2.0), (3.5, 4.0), (0.5, 0.0), (1.5, 2.0),
            (-0.5, 0.0), (-1.5, -2.0), (2.4, 2.0), (2.6, 3.0), (-126.5, -126.0)]
        {
            assert_eq!(round_half_even(x), want, "x={x}");
        }
    }

    /// `topk_mask_ref` expectation: 24 LCG(7) values (bit patterns below,
    /// no |.| ties), k = 6 keeps exactly indices {1, 8, 15, 18, 19, 20}.
    #[test]
    fn topk_matches_python_reference_dump() {
        const BITS: [u32; 24] = [
            0xbcde6ba2, 0x3fe94c35, 0x3fd02ab5, 0xbf68b523, 0xbf6f3a39, 0xbfb920dc,
            0xbec6e401, 0xbf36363f, 0x3ff66fc3, 0x3f56a534, 0xbea2b9a0, 0x3e724136,
            0xbf9cb33f, 0x3f0ac2a4, 0xbf8bdaf9, 0xbfdfa019, 0x3fc8e9d0, 0xbfafb9c6,
            0xbfd6823f, 0x3feb7e62, 0x3feb91bb, 0xbf0cc423, 0x3f024132, 0xbf91cee3,
        ];
        let x = Flat(BITS.iter().map(|&b| f32::from_bits(b)).collect());
        // cross-check the regenerated input IS the dump script's input
        assert_eq!(x.0, golden_lcg(7, 24));
        let m = topk_mask(&x, 6);
        let kept: Vec<usize> =
            (0..24).filter(|&i| m.0[i] != 0.0).collect();
        assert_eq!(kept, vec![1, 8, 15, 18, 19, 20], "selection drifted from topk_mask_ref");
        for &i in &kept {
            assert_eq!(m.0[i], x.0[i], "kept values must pass through untouched");
        }
    }

    #[test]
    fn quickselect_matches_sort() {
        prop_check("quickselect", 64, |rng| {
            let v = arb_vec_f32(rng, 200);
            let rank = rng.range(0, v.len());
            let mut a = v.clone();
            let got = quickselect_desc(&mut a, rank);
            let mut b: Vec<f32> = v.iter().map(|x| *x).collect();
            b.sort_by(|x, y| y.partial_cmp(x).unwrap());
            prop_assert!(got == b[rank], "{got} != {}", b[rank]);
            Ok(())
        });
    }
}
