//! Closed-loop actuation of the §V-C configuration model.
//!
//! The [`Actuator`] closes the loop the paper describes in §V-C/§VII-A:
//! it differences [`TelemetryBus`] snapshots into observation windows,
//! smooths them through the windowed estimators
//! ([`MtbfEstimator`]/[`BwEstimator`] — never raw samples, see
//! `control/telemetry.rs`), feeds the estimates into
//! [`AdaptiveTuner::observe`] / [`AdaptiveTuner::observe_compaction`],
//! and emits a [`Retune`] when the tuner's target has moved far enough to
//! act on.
//!
//! **Safety points.** A `Retune` is *advice*; where it applies is decided
//! by the runtime so a re-configuration can never tear an in-flight
//! chain:
//! - the driver ticks the actuator only at **full-checkpoint epoch
//!   boundaries** and applies the new `full_every` to subsequent epochs;
//! - the flat checkpointer receives the new batch size / merge factor as
//!   a queue item (`CkptItem::Retune`), so it lands *between* chain
//!   objects, after the pending batch flushed;
//! - the cluster applies a new merge factor on the commit coordinator
//!   **after a committed phase-2 record**, so every rank switches at the
//!   same committed epoch (compaction is coordinator-driven; per-rank
//!   chains never see a half-applied config).
//!
//! **Hysteresis + clamps.** The stepwise tuner moves every tick; actually
//! re-configuring the pipeline costs a batch flush and (in the cluster) a
//! scheduler round-trip, so the actuator fires only when the relative
//! change exceeds [`ActuatorConfig::hysteresis`] and a cooldown of ticks
//! has passed, and every emitted value is clamped to configured bounds —
//! the tuner can drift, the *applied* config cannot thrash.

use crate::checkpoint::format::{PayloadCodec, N_CODECS};
use crate::control::telemetry::{BwEstimator, MtbfEstimator, Snapshot, TelemetryBus};
use crate::coordinator::config_opt::{AdaptiveTuner, SystemParams};
use crate::storage::StorageBackend;

/// Storage object name of the persisted control-plane state sidecar.
/// Deliberately outside every `Manifest` name family, so chain GC,
/// `truncate_after` and the cluster sweep all leave it alone.
pub const CONTROL_STATE_OBJECT: &str = "control-state.v1.txt";

/// One applied (or to-apply) runtime configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Retune {
    /// full-checkpoint interval (FCF), iterations; 0 = fulls disabled
    /// (the `full_every = ∞` full-free mode: one base full, then diffs +
    /// hierarchical merge forever)
    pub full_every: u64,
    /// differential batching size (BS)
    pub batch_size: usize,
    /// chain-compaction merge factor; < 2 disables
    pub compact_every: usize,
    /// diff/batch payload codec in force (the bandit policy moves this
    /// between the configured lossless codec and `Quant8` on *measured*
    /// wins — see [`Actuator::codec_policy`])
    pub codec: PayloadCodec,
}

/// One observation window — what [`Actuator::tick`] derives from bus
/// snapshots, and what simulations/benches feed directly via
/// [`Actuator::tick_window`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Window {
    /// wall seconds covered by this window
    pub dt_secs: f64,
    /// failure events inside the window
    pub failures: u64,
    /// durable checkpoint bytes inside the window
    pub bytes_written: u64,
    /// observed device seconds for those bytes (0 when unobserved)
    pub write_secs: f64,
    /// CUMULATIVE compaction totals as of the window's end (replay-ratio
    /// feedback uses run totals, not deltas)
    pub merged_total: u64,
    pub raw_total: u64,
    /// per-codec raw payload bytes measured inside the window (chosen +
    /// probe encodes), indexed by [`PayloadCodec::idx`]
    pub codec_bytes_in: [u64; N_CODECS],
    /// per-codec achieved wire bytes inside the window
    pub codec_bytes_out: [u64; N_CODECS],
}

/// Actuation policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ActuatorConfig {
    /// minimum relative change of FCF or BS before a retune fires
    pub hysteresis: f64,
    /// minimum ticks between retunes
    pub cooldown_ticks: u32,
    /// `(0, 0)` selects the full-free mode: `full_every` is pinned to 0
    /// (no periodic fulls) and the merge-factor policy switches from the
    /// per-epoch chain-length heuristic to the hierarchical replay bound
    /// (see [`Actuator::note_chain_objects`])
    pub full_every_bounds: (u64, u64),
    pub batch_bounds: (usize, usize),
    /// compaction policy: keep the replayable chain near this many
    /// objects (`mf ≈ chain_len / target`), within `compact_bounds`
    pub target_replay_objects: u64,
    pub compact_bounds: (usize, usize),
    /// iterations between differential checkpoints (the runtime's
    /// `diff_every`): the chain grows one object per `diff_every *
    /// batch_size` iterations, so the policy must know the cadence or it
    /// sizes compaction for a chain `diff_every`× longer than reality
    pub diff_every: u64,
    /// estimator window decay (see [`MtbfEstimator`])
    pub decay: f64,
    /// prior pseudo-weight of the configured MTBF
    pub prior_weight: f64,
    /// adaptive codec selection: move the diff codec between the
    /// configured lossless codec and `Quant8` when the measured wire
    /// ratio sustains a win (no-op until codec telemetry flows)
    pub adapt_codec: bool,
    /// minimum relative wire-ratio win before the codec switches (the
    /// codec knob's hysteresis band)
    pub codec_margin: f64,
    /// consecutive winning windows required before the switch fires
    pub codec_streak_ticks: u32,
}

impl Default for ActuatorConfig {
    fn default() -> Self {
        ActuatorConfig {
            // the applied config can lag the tuner target by up to the
            // hysteresis band; 10% keeps the worst-case total error
            // (estimator bias x lag) within the 20% convergence
            // acceptance while still suppressing per-tick thrash
            hysteresis: 0.1,
            cooldown_ticks: 1,
            full_every_bounds: (1, 1_000_000),
            batch_bounds: (1, 512),
            target_replay_objects: 8,
            compact_bounds: (2, 64),
            diff_every: 1,
            // long estimator memory + a light prior: enough decayed
            // failure mass accumulates for the telemetry to overrule a
            // badly misconfigured prior within a few hundred ticks
            decay: 0.98,
            prior_weight: 0.1,
            adapt_codec: true,
            // a switch costs nothing on the wire but moves the error
            // contract (Quant8 is lossy), so demand a clear, sustained win
            codec_margin: 0.1,
            codec_streak_ticks: 2,
        }
    }
}

/// The closed-loop tuner actuator (one per training run).
#[derive(Debug)]
pub struct Actuator {
    tuner: AdaptiveTuner,
    cfg: ActuatorConfig,
    mtbf: MtbfEstimator,
    bw: BwEstimator,
    last: Snapshot,
    applied: Retune,
    ticks_since_retune: u32,
    /// total diff-chain objects since the base full, as last reported by
    /// the driver ([`Actuator::note_chain_objects`]; full-free mode only)
    chain_objects: u64,
    /// the configured lossless codec — the non-quantized bandit arm (and
    /// what fulls always use)
    lossless: PayloadCodec,
    /// smoothed achieved wire ratio (out/in) per codec; `None` until that
    /// codec has been measured at least once
    codec_ratio: [Option<f64>; N_CODECS],
    /// consecutive windows the non-applied arm has beaten the applied one
    /// by more than `codec_margin`
    codec_win_streak: u32,
    /// retunes emitted so far
    pub retunes: u64,
}

/// The hierarchical replay bound: recovering an `n`-object differential
/// chain compacted at fan-out `mf` (≥ 2) touches at most
/// `mf·⌈log_mf n⌉ + 1` objects — ≤ `mf − 1` surviving spans per level
/// plus the raw tail, plus the base full.
pub fn replay_bound(n: u64, mf: usize) -> u64 {
    let mf = mf.max(2) as u64;
    if n <= 1 {
        return n + 1;
    }
    // ⌈log_mf n⌉ by repeated multiplication — no float drift at the
    // boundaries (exact powers must not count an extra level)
    let mut levels = 0u64;
    let mut cap = 1u64;
    while cap < n {
        cap = cap.saturating_mul(mf);
        levels += 1;
    }
    mf * levels + 1
}

impl Actuator {
    /// `params` seeds the model (its `mtbf`/`write_bw` become the
    /// estimator priors); `initial` is the currently-running config the
    /// tuner walks away from.
    pub fn new(
        params: SystemParams,
        iter_time: f64,
        initial: Retune,
        cfg: ActuatorConfig,
    ) -> Actuator {
        let mut tuner = AdaptiveTuner::new(params, iter_time);
        tuner.fcf_interval = initial.full_every.max(1);
        tuner.batch_size = initial.batch_size.max(1);
        let lossless =
            if initial.codec.is_lossy() { PayloadCodec::Zstd } else { initial.codec };
        Actuator {
            mtbf: MtbfEstimator::new(params.mtbf, cfg.prior_weight, cfg.decay),
            bw: BwEstimator::new(params.write_bw, cfg.decay),
            tuner,
            cfg,
            last: Snapshot::default(),
            applied: initial,
            ticks_since_retune: 0,
            chain_objects: 0,
            lossless,
            codec_ratio: [None; N_CODECS],
            codec_win_streak: 0,
            retunes: 0,
        }
    }

    /// The configuration currently in force.
    pub fn applied(&self) -> Retune {
        self.applied
    }

    /// True when the config pins fulls off entirely (`full_every = ∞`).
    fn full_free(&self) -> bool {
        self.cfg.full_every_bounds == (0, 0)
    }

    /// Chain-length feedback for full-free runs: the driver reports the
    /// diff-chain object count since the base full (steps since base /
    /// (`diff_every`·`batch_size`)) before each tick, and the merge
    /// policy picks the fan-out whose hierarchical bound
    /// ([`replay_bound`]) lands nearest `target_replay_objects` —
    /// replacing the fixed `mf ≈ n/target` heuristic, which has no answer
    /// on an unbounded chain.
    pub fn note_chain_objects(&mut self, n: u64) {
        self.chain_objects = n;
    }

    /// Smoothed estimates `(mtbf, write_bw)` currently driving the tuner.
    pub fn estimates(&self) -> (f64, f64) {
        (self.mtbf.estimate(), self.bw.estimate())
    }

    /// Everything worth carrying across a process restart: the decayed
    /// estimator accumulators plus the knobs in force.
    pub fn export_state(&self) -> ControlState {
        let (mtbf_acc_secs, mtbf_acc_failures) = self.mtbf.export();
        ControlState {
            mtbf_acc_secs,
            mtbf_acc_failures,
            bw_est: self.bw.export(),
            applied: self.applied,
            retunes: self.retunes,
        }
    }

    /// Warm-start the estimators from a persisted [`ControlState`] —
    /// called right after construction on restart, so the cold-start
    /// priors only ever steer the *first* run against a chain. The
    /// applied knobs are NOT overwritten (the runtime was just spawned
    /// with its own config); with warm estimators the tuner re-derives
    /// the right operating point within a tick or two instead of
    /// re-learning MTBF/bandwidth from scratch.
    pub fn warm_start(&mut self, st: &ControlState) {
        self.mtbf.restore(st.mtbf_acc_secs, st.mtbf_acc_failures);
        self.bw.restore(st.bw_est);
        self.tuner.observe(self.mtbf.estimate(), self.bw.estimate());
    }

    /// One control tick against the live bus: difference the snapshot
    /// since the previous tick into a [`Window`] and act on it.
    pub fn tick(&mut self, bus: &TelemetryBus) -> Option<Retune> {
        let s = bus.snapshot();
        let w = Window {
            dt_secs: s.elapsed_secs - self.last.elapsed_secs,
            failures: s.failures.saturating_sub(self.last.failures),
            bytes_written: s.bytes_written.saturating_sub(self.last.bytes_written),
            write_secs: (s.write_secs - self.last.write_secs).max(0.0),
            merged_total: s.merged_written,
            raw_total: s.raw_compacted,
            codec_bytes_in: std::array::from_fn(|i| {
                s.codec_bytes_in[i].saturating_sub(self.last.codec_bytes_in[i])
            }),
            codec_bytes_out: std::array::from_fn(|i| {
                s.codec_bytes_out[i].saturating_sub(self.last.codec_bytes_out[i])
            }),
        };
        self.last = s;
        self.tick_window(&w)
    }

    /// One control tick from an explicit observation window — the
    /// simulation/bench entry point ([`tick`](Actuator::tick) is a thin
    /// wrapper over this).
    pub fn tick_window(&mut self, w: &Window) -> Option<Retune> {
        if w.dt_secs <= 0.0 {
            return None;
        }
        self.mtbf.observe_window(w.dt_secs, w.failures);
        self.bw.observe_window(w.bytes_written, w.write_secs);
        self.tuner.observe(self.mtbf.estimate(), self.bw.estimate());
        if w.raw_total > 0 {
            // cumulative replay-ratio feedback: `raw_total` raw steps are
            // now replayable through `merged_total` merged objects
            self.tuner.observe_compaction(w.raw_total, w.merged_total.max(1));
        }
        self.ticks_since_retune = self.ticks_since_retune.saturating_add(1);

        let want_f = self
            .tuner
            .fcf_interval
            .clamp(self.cfg.full_every_bounds.0, self.cfg.full_every_bounds.1);
        let want_b = self
            .tuner
            .batch_size
            .clamp(self.cfg.batch_bounds.0, self.cfg.batch_bounds.1);
        let want_c = self.compaction_policy(want_f, want_b);
        let want_codec =
            if self.cfg.adapt_codec { self.codec_policy(w) } else { self.applied.codec };

        let significant = rel_change(self.applied.full_every as f64, want_f as f64)
            >= self.cfg.hysteresis
            || rel_change(self.applied.batch_size as f64, want_b as f64) >= self.cfg.hysteresis
            || want_codec != self.applied.codec
            // full-free runs steer through the merge factor alone (the
            // FCF knob is pinned at 0), so fan-out moves must fire too
            || (self.full_free()
                && rel_change(self.applied.compact_every as f64, want_c as f64)
                    >= self.cfg.hysteresis);
        if significant && self.ticks_since_retune >= self.cfg.cooldown_ticks {
            if want_codec != self.applied.codec {
                self.codec_win_streak = 0;
            }
            self.applied = Retune {
                full_every: want_f,
                batch_size: want_b,
                compact_every: want_c,
                codec: want_codec,
            };
            self.ticks_since_retune = 0;
            self.retunes += 1;
            return Some(self.applied);
        }
        None
    }

    /// Bandit-style codec selection over **measured** wire ratios. The two
    /// arms are the configured lossless codec and `Quant8`; the encoder's
    /// probe traffic keeps the non-chosen arm's measurements fresh. Each
    /// window updates a smoothed achieved ratio (out/in) per arm; the
    /// policy switches only when the other arm's ratio beats the applied
    /// one by more than `codec_margin` for `codec_streak_ticks`
    /// consecutive measuring windows — and the shared retune cooldown
    /// still applies on top. No data (or a within-margin race) resets the
    /// streak, so the knob can never thrash on noise.
    fn codec_policy(&mut self, w: &Window) -> PayloadCodec {
        let cur = self.applied.codec;
        let candidates = [self.lossless, PayloadCodec::Quant8];
        for c in candidates {
            let i = c.idx();
            if w.codec_bytes_in[i] > 0 {
                let r = w.codec_bytes_out[i] as f64 / w.codec_bytes_in[i] as f64;
                self.codec_ratio[i] = Some(match self.codec_ratio[i] {
                    Some(prev) => 0.5 * prev + 0.5 * r,
                    None => r,
                });
            }
        }
        let cur_r = match self.codec_ratio[cur.idx()] {
            Some(r) => r,
            None => return cur,
        };
        let best = candidates
            .into_iter()
            .filter(|c| *c != cur)
            .filter_map(|c| self.codec_ratio[c.idx()].map(|r| (c, r)))
            .min_by(|a, b| a.1.total_cmp(&b.1));
        match best {
            Some((c, r)) if r < cur_r * (1.0 - self.cfg.codec_margin) => {
                self.codec_win_streak += 1;
                if self.codec_win_streak >= self.cfg.codec_streak_ticks {
                    c
                } else {
                    cur
                }
            }
            _ => {
                self.codec_win_streak = 0;
                cur
            }
        }
    }

    /// Merge-factor policy: size compaction so a full recovery replays
    /// about `target_replay_objects` chain objects. With `n = full_every
    /// / (diff_every · batch_size)` objects per chain, `mf = ⌈n/target⌉`;
    /// chains already short enough don't pay for a compactor pass at all.
    /// Full-free runs have no per-epoch chain length — they use the
    /// hierarchical bound instead ([`Actuator::hierarchical_policy`]).
    fn compaction_policy(&self, full_every: u64, batch_size: usize) -> usize {
        if self.full_free() {
            return self.hierarchical_policy(self.chain_objects);
        }
        let per_object = self.cfg.diff_every.max(1) * batch_size.max(1) as u64;
        let chain_len = full_every / per_object;
        let target = self.cfg.target_replay_objects.max(1);
        if chain_len <= 2 * target {
            return 0;
        }
        (chain_len.div_ceil(target) as usize)
            .clamp(self.cfg.compact_bounds.0, self.cfg.compact_bounds.1)
    }

    /// Fan-out for an unbounded chain: scan `compact_bounds` for the
    /// merge factor whose hierarchical bound ([`replay_bound`]) lands
    /// nearest `target_replay_objects`. Never 0 — an unbounded chain
    /// without compaction has unbounded replay — and level count falls
    /// out implicitly (⌈log_mf n⌉ at the chosen fan-out).
    fn hierarchical_policy(&self, n: u64) -> usize {
        let (lo, hi) = self.cfg.compact_bounds;
        let lo = lo.max(2);
        let hi = hi.max(lo);
        let target = self.cfg.target_replay_objects.max(2) as f64;
        let mut best = lo;
        let mut best_err = f64::INFINITY;
        for mf in lo..=hi {
            let err = (replay_bound(n, mf) as f64 - target).abs();
            if err < best_err {
                best_err = err;
                best = mf;
            }
        }
        best
    }
}

fn rel_change(applied: f64, want: f64) -> f64 {
    (want - applied).abs() / applied.max(1.0)
}

/// Persistable control-plane state: written beside the chain as
/// [`CONTROL_STATE_OBJECT`] at every actuator tick and at run end, read
/// back on restart to warm-start the estimators
/// ([`Actuator::warm_start`]). Plain `key value` text — hand-parsed like
/// every other sidecar format in this offline crate, and forward-tolerant
/// (unknown keys are skipped; missing keys fail the parse).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControlState {
    /// decayed failure-free seconds ([`MtbfEstimator::export`])
    pub mtbf_acc_secs: f64,
    /// decayed failure count
    pub mtbf_acc_failures: f64,
    /// smoothed write bandwidth ([`BwEstimator::export`])
    pub bw_est: f64,
    /// knobs in force when the state was written
    pub applied: Retune,
    /// retunes emitted so far (cumulative, informational)
    pub retunes: u64,
}

const CONTROL_STATE_HEADER: &str = "lowdiff-control-state v1";

impl ControlState {
    pub fn to_text(&self) -> String {
        format!(
            "{CONTROL_STATE_HEADER}\n\
             mtbf_acc_secs {}\n\
             mtbf_acc_failures {}\n\
             bw_est {}\n\
             full_every {}\n\
             batch_size {}\n\
             compact_every {}\n\
             codec {}\n\
             retunes {}\n",
            self.mtbf_acc_secs,
            self.mtbf_acc_failures,
            self.bw_est,
            self.applied.full_every,
            self.applied.batch_size,
            self.applied.compact_every,
            self.applied.codec.name(),
            self.retunes,
        )
    }

    /// Parse the sidecar text; `None` on any damage (the caller falls
    /// back to cold-start priors — a bad sidecar must never wedge a run).
    /// The `codec` key is optional: sidecars written before the codec
    /// knob existed parse with `Raw`.
    pub fn parse(text: &str) -> Option<ControlState> {
        let mut lines = text.lines();
        if lines.next()?.trim() != CONTROL_STATE_HEADER {
            return None;
        }
        let mut f64s: std::collections::BTreeMap<&str, f64> = Default::default();
        let mut codec = PayloadCodec::Raw;
        for line in lines {
            let mut it = line.split_whitespace();
            if let (Some(k), Some(v)) = (it.next(), it.next()) {
                if k == "codec" {
                    codec = PayloadCodec::parse_name(v)?;
                } else {
                    f64s.insert(k, v.parse().ok()?);
                }
            }
        }
        Some(ControlState {
            mtbf_acc_secs: *f64s.get("mtbf_acc_secs")?,
            mtbf_acc_failures: *f64s.get("mtbf_acc_failures")?,
            bw_est: *f64s.get("bw_est")?,
            applied: Retune {
                full_every: *f64s.get("full_every")? as u64,
                batch_size: *f64s.get("batch_size")? as usize,
                compact_every: *f64s.get("compact_every")? as usize,
                codec,
            },
            retunes: *f64s.get("retunes")? as u64,
        })
    }

    /// Best-effort persist beside the chain.
    pub fn save(&self, store: &dyn StorageBackend) -> anyhow::Result<()> {
        store.put(CONTROL_STATE_OBJECT, self.to_text().as_bytes())
    }

    /// Load the sidecar if present and parseable.
    pub fn load(store: &dyn StorageBackend) -> Option<ControlState> {
        if !store.exists(CONTROL_STATE_OBJECT) {
            return None;
        }
        let bytes = store.get(CONTROL_STATE_OBJECT).ok()?;
        ControlState::parse(std::str::from_utf8(&bytes).ok()?)
    }
}

/// Drive a fresh actuator with synthetic telemetry implying a true
/// `(mtbf, bw)` for `ticks` windows — the convergence harness shared by
/// the unit tests, the `exp control` table and the `control_loop` bench.
/// Priors are deliberately wrong (8× MTBF, ¼ bandwidth): the measured
/// windows must overrule them.
pub fn converge_synthetic(
    mut params: SystemParams,
    iter_time: f64,
    initial: Retune,
    ticks: usize,
) -> Actuator {
    let (true_mtbf, true_bw) = (params.mtbf, params.write_bw);
    params.mtbf *= 8.0;
    params.write_bw /= 4.0;
    let mut a = Actuator::new(
        params,
        iter_time,
        initial,
        ActuatorConfig { cooldown_ticks: 0, ..Default::default() },
    );
    let mut carry = 0.0f64;
    for _ in 0..ticks {
        // each window covers mtbf/3 seconds; failures arrive at the true
        // rate via a deterministic fractional accumulator
        let dt = true_mtbf / 3.0;
        carry += dt / true_mtbf;
        let failures = carry.floor() as u64;
        carry -= failures as f64;
        let _ = a.tick_window(&Window {
            dt_secs: dt,
            failures,
            bytes_written: (true_bw * 0.5) as u64,
            write_secs: 0.5,
            ..Default::default()
        });
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config_opt::optimal_config_integer;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn params(mtbf: f64, bw: f64) -> SystemParams {
        let full_size = 8.7e9;
        SystemParams {
            n_gpus: 8.0,
            mtbf,
            write_bw: bw,
            full_size,
            total_time: 24.0 * 3600.0,
            r_full: full_size / bw,
            r_diff: 0.2,
        }
    }

    #[test]
    fn converges_within_20pct_of_closed_form_from_bad_config() {
        // the ISSUE acceptance: from a deliberately bad initial config
        // (and deliberately wrong priors), the closed loop lands within
        // 20% of the Eq. (10) integer optimum for the TRUE parameters
        let p = params(900.0, 2.5e9);
        let (want_f, want_b) = optimal_config_integer(&p, 1.9);
        let bad = Retune {
            full_every: want_f * 50,
            batch_size: (want_b * 16).min(512),
            compact_every: 0,
            codec: PayloadCodec::Raw,
        };
        let a = converge_synthetic(p, 1.9, bad, 600);
        let got = a.applied();
        let f_err = (got.full_every as f64 - want_f as f64).abs() / want_f as f64;
        let b_err = (got.batch_size as f64 - want_b as f64).abs() / want_b.max(1) as f64;
        assert!(
            f_err <= 0.2,
            "full_every {} vs closed-form {want_f} ({:.0}% off)",
            got.full_every,
            f_err * 100.0
        );
        assert!(
            b_err <= 0.2 || (got.batch_size as i64 - want_b as i64).abs() <= 1,
            "batch {} vs closed-form {want_b}",
            got.batch_size
        );
        assert!(a.retunes > 0);
    }

    #[test]
    fn actuation_monotone_in_estimated_mtbf_property() {
        // the satellite fix pinned as a property: a HIGHER estimated MTBF
        // must never produce a SMALLER full-checkpoint interval (f* is
        // decreasing in M, so the interval 1/f* is increasing). Run the
        // same loop under M and 4M and compare the converged intervals.
        prop_check("actuation_monotone_mtbf", 8, |rng| {
            let mtbf = 200.0 + rng.next_f64() * 2000.0;
            let bw = 5e8 + rng.next_f64() * 4e9;
            let initial = Retune { full_every: 64, batch_size: 4, compact_every: 0, codec: PayloadCodec::Raw };
            let lo = converge_synthetic(params(mtbf, bw), 1.9, initial, 400).applied();
            let hi = converge_synthetic(params(mtbf * 4.0, bw), 1.9, initial, 400).applied();
            prop_assert!(
                hi.full_every >= lo.full_every,
                "fcf must not shrink as MTBF grows: M={mtbf:.0} -> {} vs 4M -> {}",
                lo.full_every,
                hi.full_every
            );
            Ok(())
        });
    }

    #[test]
    fn tick_derives_windows_from_bus_snapshots() {
        let bus = TelemetryBus::new();
        let p = params(100.0, 1e9);
        let mut a = Actuator::new(
            p,
            1.9,
            Retune { full_every: 40, batch_size: 2, compact_every: 0, codec: PayloadCodec::Raw },
            ActuatorConfig::default(),
        );
        let (m0, w0) = a.estimates();
        bus.record_failure();
        bus.record_write(5_000_000_000, 1.0); // 5 GB/s observed
        std::thread::sleep(std::time::Duration::from_millis(5));
        let _ = a.tick(&bus);
        let (m1, w1) = a.estimates();
        assert!(m1 < m0, "a failure in the window lowers the MTBF estimate");
        assert!(w1 > w0, "faster observed writes raise the bandwidth estimate");
        // second tick with an empty window: estimates barely move
        std::thread::sleep(std::time::Duration::from_millis(2));
        let _ = a.tick(&bus);
        let (m2, w2) = a.estimates();
        assert!(m2 >= m1, "failure-free window must not lower MTBF");
        assert_eq!(w1, w2, "no writes observed: bandwidth estimate unchanged");
    }

    #[test]
    fn hysteresis_and_cooldown_prevent_thrash() {
        let p = params(3600.0, 2.5e9);
        let initial = Retune { full_every: 40, batch_size: 2, compact_every: 0, codec: PayloadCodec::Raw };
        let mut a = Actuator::new(
            p,
            1.9,
            initial,
            ActuatorConfig { hysteresis: 10.0, cooldown_ticks: 100, ..Default::default() },
        );
        for _ in 0..50 {
            let none = a.tick_window(&Window { dt_secs: 10.0, ..Default::default() });
            assert!(none.is_none(), "inside hysteresis band: no retune");
        }
        assert_eq!(a.retunes, 0);
        assert_eq!(a.applied(), initial, "applied config untouched");
    }

    #[test]
    fn clamps_bound_every_emitted_value() {
        let mut a = Actuator::new(
            params(1e6, 1e7), // extreme: wants a huge interval
            1.9,
            Retune { full_every: 10, batch_size: 1, compact_every: 0, codec: PayloadCodec::Raw },
            ActuatorConfig {
                full_every_bounds: (5, 50),
                batch_bounds: (1, 4),
                cooldown_ticks: 0,
                ..Default::default()
            },
        );
        let mut last = None;
        for _ in 0..300 {
            if let Some(r) = a.tick_window(&Window { dt_secs: 1000.0, ..Default::default() }) {
                assert!((5..=50).contains(&r.full_every), "{r:?}");
                assert!((1..=4).contains(&r.batch_size), "{r:?}");
                last = Some(r);
            }
        }
        assert!(last.is_some(), "a tuner this far off must eventually act");
    }

    #[test]
    fn compaction_policy_tracks_chain_length() {
        let a = Actuator::new(
            params(3600.0, 2.5e9),
            1.9,
            Retune { full_every: 100, batch_size: 1, compact_every: 0, codec: PayloadCodec::Raw },
            ActuatorConfig::default(),
        );
        assert_eq!(a.compaction_policy(8, 1), 0, "short chain: no compactor");
        assert_eq!(a.compaction_policy(64, 1), 8, "64 objects / target 8");
        assert_eq!(a.compaction_policy(64, 4), 0, "batching already shortens the chain");
        assert_eq!(a.compaction_policy(10_000, 1), 64, "clamped at the upper bound");
        // the diff cadence shortens the chain exactly like batching does
        let sparse = Actuator::new(
            params(3600.0, 2.5e9),
            1.9,
            Retune { full_every: 64, batch_size: 1, compact_every: 0, codec: PayloadCodec::Raw },
            ActuatorConfig { diff_every: 4, ..ActuatorConfig::default() },
        );
        assert_eq!(
            sparse.compaction_policy(64, 1),
            0,
            "diff_every=4: only 16 chain objects per full epoch"
        );
        assert_eq!(sparse.compaction_policy(640, 1), 20, "160 objects / target 8");
    }

    #[test]
    fn replay_bound_matches_the_hierarchy() {
        assert_eq!(replay_bound(0, 4), 1, "empty chain: base only");
        assert_eq!(replay_bound(1, 4), 2, "one raw diff + base");
        assert_eq!(replay_bound(64, 4), 13, "4·⌈log4 64⌉ + 1, exact power");
        assert_eq!(replay_bound(65, 4), 17, "one past the power adds a level");
        assert_eq!(replay_bound(512, 2), 19, "2·9 + 1");
        assert_eq!(replay_bound(512, 8), 25, "8·3 + 1");
    }

    #[test]
    fn full_free_mode_pins_fulls_off_and_steers_the_fan_out() {
        let mut a = Actuator::new(
            params(900.0, 2.5e9),
            1.9,
            Retune { full_every: 0, batch_size: 1, compact_every: 0, codec: PayloadCodec::Raw },
            ActuatorConfig {
                full_every_bounds: (0, 0),
                cooldown_ticks: 0,
                ..Default::default()
            },
        );
        a.note_chain_objects(512);
        let mut last = None;
        for _ in 0..20 {
            if let Some(r) = a.tick_window(&Window { dt_secs: 100.0, ..Default::default() }) {
                last = Some(r);
            }
        }
        let r = last.expect("enabling compaction on an unbounded chain must fire");
        assert_eq!(r.full_every, 0, "full-free: the FCF knob stays pinned at 0");
        assert!(r.compact_every >= 2, "an unbounded chain must compact: {r:?}");
        // target 8 is below any achievable bound at n=512; the policy
        // lands on the fan-out minimizing mf·⌈log_mf n⌉ + 1 (= 19 here)
        assert_eq!(replay_bound(512, r.compact_every), 19, "{r:?}");
    }

    #[test]
    fn control_state_roundtrips_and_warm_starts() {
        use crate::storage::{MemStore, StorageBackend};
        let p = params(900.0, 2.5e9);
        let initial = Retune { full_every: 40, batch_size: 2, compact_every: 4, codec: PayloadCodec::Raw };
        let cfg = ActuatorConfig { cooldown_ticks: 0, ..Default::default() };
        let mut a = Actuator::new(p, 1.9, initial, cfg);
        for _ in 0..30 {
            let _ = a.tick_window(&Window {
                dt_secs: 300.0,
                failures: 1,
                bytes_written: 1_000_000_000,
                write_secs: 1.0,
                ..Default::default()
            });
        }
        let st = a.export_state();
        let text = st.to_text();
        assert_eq!(ControlState::parse(&text), Some(st), "text roundtrip");
        assert_eq!(ControlState::parse("garbage"), None);
        assert_eq!(ControlState::parse(""), None);

        let store = MemStore::new();
        st.save(&store).unwrap();
        assert!(store.exists(CONTROL_STATE_OBJECT));
        let loaded = ControlState::load(&store).unwrap();
        assert_eq!(loaded, st);
        assert_eq!(ControlState::load(&MemStore::new()), None, "first run: no sidecar");

        // a fresh actuator warm-started from the sidecar reproduces the
        // trained estimates instead of the cold priors
        let mut b = Actuator::new(p, 1.9, initial, ActuatorConfig::default());
        let cold = b.estimates();
        b.warm_start(&loaded);
        let warm = b.estimates();
        assert_eq!(warm, a.estimates(), "warm start reproduces trained estimates");
        assert!((warm.0 - cold.0).abs() > 1.0, "and they differ from the cold prior");
    }

    /// A window where both codec arms were measured: `cur` achieved ratio
    /// `r_cur`, quant8 achieved `r_q8` (out of 1000 raw bytes each).
    fn codec_window(r_cur: f64, r_q8: f64, cur: PayloadCodec) -> Window {
        let mut w = Window { dt_secs: 10.0, ..Default::default() };
        w.codec_bytes_in[cur.idx()] = 1000;
        w.codec_bytes_out[cur.idx()] = (1000.0 * r_cur) as u64;
        w.codec_bytes_in[PayloadCodec::Quant8.idx()] = 1000;
        w.codec_bytes_out[PayloadCodec::Quant8.idx()] = (1000.0 * r_q8) as u64;
        w
    }

    #[test]
    fn codec_policy_switches_on_sustained_measured_win() {
        let initial =
            Retune { full_every: 40, batch_size: 2, compact_every: 0, codec: PayloadCodec::Zstd };
        let mut a = Actuator::new(
            params(3600.0, 2.5e9),
            1.9,
            initial,
            ActuatorConfig { cooldown_ticks: 0, ..Default::default() },
        );
        // quant8 measures ~3x better than zstd, sustained: the policy
        // needs codec_streak_ticks (2) winning windows before acting
        let first = a.tick_window(&codec_window(0.6, 0.2, PayloadCodec::Zstd));
        assert!(
            first.is_none() || first.unwrap().codec == PayloadCodec::Zstd,
            "one winning window must not switch yet: {first:?}"
        );
        let mut switched = None;
        for _ in 0..5 {
            if let Some(r) = a.tick_window(&codec_window(0.6, 0.2, PayloadCodec::Zstd)) {
                if r.codec != PayloadCodec::Zstd {
                    switched = Some(r);
                    break;
                }
            }
        }
        let r = switched.expect("a sustained 3x measured win must switch the codec");
        assert_eq!(r.codec, PayloadCodec::Quant8);
        assert_eq!(a.applied().codec, PayloadCodec::Quant8);
    }

    #[test]
    fn codec_policy_holds_inside_margin_and_without_data() {
        let initial =
            Retune { full_every: 40, batch_size: 2, compact_every: 0, codec: PayloadCodec::Zstd };
        let mut a = Actuator::new(
            params(3600.0, 2.5e9),
            1.9,
            initial,
            ActuatorConfig { cooldown_ticks: 0, ..Default::default() },
        );
        // no codec telemetry at all: the knob never moves
        for _ in 0..10 {
            let _ = a.tick_window(&Window { dt_secs: 10.0, ..Default::default() });
        }
        assert_eq!(a.applied().codec, PayloadCodec::Zstd);
        // a win inside the 10% margin: still no switch, ever
        for _ in 0..10 {
            let _ = a.tick_window(&codec_window(0.50, 0.47, PayloadCodec::Zstd));
        }
        assert_eq!(a.applied().codec, PayloadCodec::Zstd, "within-margin win must not switch");
        // alternating winner resets the streak: no switch either
        for i in 0..10 {
            let (rc, rq) = if i % 2 == 0 { (0.6, 0.2) } else { (0.2, 0.9) };
            let _ = a.tick_window(&codec_window(rc, rq, PayloadCodec::Zstd));
        }
        assert_eq!(a.applied().codec, PayloadCodec::Zstd, "noisy measurements must not thrash");
    }

    #[test]
    fn codec_policy_can_switch_back_to_lossless() {
        let initial = Retune {
            full_every: 40,
            batch_size: 2,
            compact_every: 0,
            codec: PayloadCodec::Quant8,
        };
        let mut a = Actuator::new(
            params(3600.0, 2.5e9),
            1.9,
            initial,
            ActuatorConfig { cooldown_ticks: 0, ..Default::default() },
        );
        // dense / incompressible-ish payloads: zstd (the probe arm)
        // measures far better than the quantized sparse path
        let mut back = None;
        for _ in 0..6 {
            let mut w = Window { dt_secs: 10.0, ..Default::default() };
            w.codec_bytes_in[PayloadCodec::Quant8.idx()] = 1000;
            w.codec_bytes_out[PayloadCodec::Quant8.idx()] = 900;
            w.codec_bytes_in[PayloadCodec::Zstd.idx()] = 1000;
            w.codec_bytes_out[PayloadCodec::Zstd.idx()] = 300;
            if let Some(r) = a.tick_window(&w) {
                if r.codec == PayloadCodec::Zstd {
                    back = Some(r);
                    break;
                }
            }
        }
        assert!(back.is_some(), "the bandit must be able to return to the lossless arm");
    }

    #[test]
    fn control_state_codec_key_is_optional_for_old_sidecars() {
        let old = "lowdiff-control-state v1\n\
                   mtbf_acc_secs 100\n\
                   mtbf_acc_failures 2\n\
                   bw_est 1000000\n\
                   full_every 40\n\
                   batch_size 2\n\
                   compact_every 4\n\
                   retunes 3\n";
        let st = ControlState::parse(old).expect("pre-codec sidecars must still parse");
        assert_eq!(st.applied.codec, PayloadCodec::Raw, "missing key defaults to raw");
        // and the new key round-trips
        let mut st2 = st;
        st2.applied.codec = PayloadCodec::Quant8;
        assert_eq!(ControlState::parse(&st2.to_text()), Some(st2));
        // a damaged codec value fails the parse like any other damage
        let bad = format!("{}codec nonsense\n", old);
        assert_eq!(ControlState::parse(&bad), None);
    }

    #[test]
    fn compaction_feedback_flows_into_the_tuner() {
        let p = params(900.0, 2.5e9);
        let mut a = Actuator::new(
            p,
            1.9,
            Retune { full_every: 20, batch_size: 2, compact_every: 4, codec: PayloadCodec::Raw },
            ActuatorConfig { cooldown_ticks: 0, ..Default::default() },
        );
        let _ = a.tick_window(&Window {
            dt_secs: 100.0,
            merged_total: 2,
            raw_total: 8,
            ..Default::default()
        });
        assert!(
            a.tuner.params.r_diff < 0.2,
            "replay-ratio feedback must scale r_diff down: {}",
            a.tuner.params.r_diff
        );
    }
}
