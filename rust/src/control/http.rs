//! Std-only HTTP observability/control plane.
//!
//! A tiny threaded HTTP/1.1 server (no tokio, no hyper — one accept
//! thread plus one short-lived thread per connection) exposing the
//! runtime control plane while a training run is live:
//!
//! - `GET /stats` — [`TelemetryBus`] snapshot + control view (estimator
//!   state, applied knobs) + per-rank heartbeats + trace counters, JSON;
//! - `GET /metrics` — the same counters in Prometheus text exposition
//!   format (`lowdiff_*`);
//! - `GET /trace?n=256` — the newest `n` trace spans as
//!   chrome://tracing event objects, JSON array;
//! - `GET /chain` — live manifest cover computed by name parsing only
//!   (objects, flat chain, per-rank cluster chains, replay bounds);
//! - `GET /storage` — per-tier, per-op storage-plane table from the
//!   [`StorageObs`] registry: counts, bytes, errors, histogram quantiles,
//!   name-family traffic and slow-op counters, JSON;
//! - `GET /health` — machine-readable liveness verdict
//!   (`ok` / `degraded` / `dead` plus a `reasons` array), HTTP 503 when
//!   dead so load-balancer-style probes work unmodified;
//! - `POST /retune?full-every=..&batch-size=..&compact-every=..` — queue
//!   a [`Retune`] request; missing knobs default to the currently
//!   applied values;
//! - `POST /compact?every=N` — queue a cluster merge-factor change;
//! - `POST /scrub` — queue an immediate scrubber pass.
//!
//! The POST endpoints **never** mutate the runtime directly: they park
//! the request in [`ObsState`] and the driver drains it with
//! [`ObsState::take_retune`]/[`ObsState::take_compact`] at the *same
//! safe epoch boundaries* the actuator uses (flat: `CkptItem::Retune`
//! queue order; cluster: committed-record boundaries). An HTTP client
//! therefore gets exactly the crash-consistency guarantees the control
//! loop has — a knob can never change mid-epoch.
//!
//! Reads are lock-light: the bus and heartbeat table are atomics, the
//! control view is one small mutex the driver refreshes at tick
//! boundaries. Endpoint shapes are documented in
//! `docs/OBSERVABILITY.md`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::checkpoint::format::PayloadCodec;
use crate::checkpoint::Manifest;
use crate::cluster::heartbeat::HeartbeatTable;
use crate::control::actuate::Retune;
use crate::control::telemetry::TelemetryBus;
use crate::control::trace::Tracer;
use crate::pipeline::scrub::ScrubStats;
use crate::storage::{StorageBackend, StorageObs, FAMILY_NAMES, OP_NAMES};
use crate::util::json::{string_token, JsonArray, JsonObject};
use crate::util::stats::LogHistogram;

/// What the driver publishes about the control loop for `/stats` and
/// `/metrics` — refreshed at actuator tick boundaries.
#[derive(Clone, Debug, Default)]
pub struct ControlView {
    pub strategy: String,
    pub adaptive: bool,
    /// smoothed MTBF estimate, seconds (0 when no actuator is attached)
    pub mtbf_estimate: f64,
    /// smoothed write-bandwidth estimate, bytes/sec
    pub bw_estimate: f64,
    /// live background-I/O budget, bytes/sec (0 = open bucket)
    pub io_budget: f64,
    /// currently applied knobs, `None` before the first application
    pub applied: Option<Retune>,
    pub retunes: u64,
    pub detected_failures: u64,
}

/// Report-only counters promoted to live gauges: the driver refreshes
/// these at tick boundaries from whatever live stats handles the run's
/// composition exposes, so `/metrics` and `/health` see them mid-run
/// instead of only in the end-of-run [`RunReport`]
/// (`RunReport`: [`crate::coordinator::metrics::RunReport`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReportGauges {
    /// encode-buffer pool recycled checkouts / fresh allocations
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// GC deletes that failed with the object still present
    pub gc_leaks: u64,
}

/// Shared state behind the HTTP plane: read-side handles on the
/// telemetry/trace/heartbeat planes plus the parked control requests the
/// driver drains at safe points.
pub struct ObsState {
    bus: Arc<TelemetryBus>,
    trace: Option<Arc<Tracer>>,
    heartbeats: Option<Arc<HeartbeatTable>>,
    store: Option<Arc<dyn StorageBackend>>,
    storage_obs: Option<Arc<StorageObs>>,
    scrub: Option<Arc<Mutex<ScrubStats>>>,
    /// heartbeat failure-detection timeout, seconds (0 = no dead check)
    hb_timeout: f64,
    control: Mutex<ControlView>,
    gauges: Mutex<ReportGauges>,
    retune_req: Mutex<Option<Retune>>,
    compact_req: Mutex<Option<usize>>,
    scrub_req: Mutex<bool>,
}

impl std::fmt::Debug for ObsState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsState")
            .field("trace", &self.trace.is_some())
            .field("heartbeats", &self.heartbeats.is_some())
            .field("store", &self.store.is_some())
            .field("storage_obs", &self.storage_obs.is_some())
            .field("scrub", &self.scrub.is_some())
            .finish()
    }
}

impl ObsState {
    pub fn new(
        bus: Arc<TelemetryBus>,
        trace: Option<Arc<Tracer>>,
        heartbeats: Option<Arc<HeartbeatTable>>,
        store: Option<Arc<dyn StorageBackend>>,
    ) -> ObsState {
        ObsState {
            bus,
            trace,
            heartbeats,
            store,
            storage_obs: None,
            scrub: None,
            hb_timeout: 0.0,
            control: Mutex::new(ControlView::default()),
            gauges: Mutex::new(ReportGauges::default()),
            retune_req: Mutex::new(None),
            compact_req: Mutex::new(None),
            scrub_req: Mutex::new(false),
        }
    }

    /// Attach the storage-plane registry (`GET /storage`, `/metrics`
    /// histograms, the `/health` slow-I/O check).
    pub fn with_storage_obs(mut self, obs: Arc<StorageObs>) -> ObsState {
        self.storage_obs = Some(obs);
        self
    }

    /// Attach the scrubber's live counters
    /// ([`Scrubber::live_handle`](crate::pipeline::Scrubber::live_handle)).
    pub fn with_scrub(mut self, live: Arc<Mutex<ScrubStats>>) -> ObsState {
        self.scrub = Some(live);
        self
    }

    /// Set the heartbeat failure-detection timeout `/health` uses to
    /// declare ranks (and the run) dead.
    pub fn with_heartbeat_timeout(mut self, secs: f64) -> ObsState {
        self.hb_timeout = secs;
        self
    }

    /// Refresh the published control view (driver, at tick boundaries).
    pub fn set_control(&self, view: ControlView) {
        *self.control.lock().expect("control view") = view;
    }

    pub fn control(&self) -> ControlView {
        self.control.lock().expect("control view").clone()
    }

    /// Park a retune request for the driver's next safe point. A newer
    /// request overwrites an undrained older one (last writer wins).
    pub fn request_retune(&self, r: Retune) {
        *self.retune_req.lock().expect("retune request") = Some(r);
    }

    /// Drain the parked retune request, if any (driver, at safe points).
    pub fn take_retune(&self) -> Option<Retune> {
        self.retune_req.lock().expect("retune request").take()
    }

    /// Park a cluster merge-factor request (`POST /compact`).
    pub fn request_compact(&self, every: usize) {
        *self.compact_req.lock().expect("compact request") = Some(every);
    }

    pub fn take_compact(&self) -> Option<usize> {
        self.compact_req.lock().expect("compact request").take()
    }

    /// Park an on-demand scrub-pass request (`POST /scrub`). The driver
    /// drains it at the same control-tick safe points as `/compact` and
    /// forwards it as a [`Scrubber::notify`](crate::pipeline::Scrubber::notify).
    pub fn request_scrub(&self) {
        *self.scrub_req.lock().expect("scrub request") = true;
    }

    pub fn take_scrub(&self) -> bool {
        std::mem::take(&mut *self.scrub_req.lock().expect("scrub request"))
    }

    /// Refresh the report-only gauges (driver, at tick boundaries).
    pub fn set_gauges(&self, g: ReportGauges) {
        *self.gauges.lock().expect("report gauges") = g;
    }

    pub fn gauges(&self) -> ReportGauges {
        *self.gauges.lock().expect("report gauges")
    }
}

/// The server handle: bind with [`serve`](ObsServer::serve), stop with
/// [`shutdown`](ObsServer::shutdown) (also runs on drop).
#[derive(Debug)]
pub struct ObsServer {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9090`; port 0 picks a free port —
    /// read it back with [`local_addr`](Self::local_addr)) and serve
    /// until shutdown.
    pub fn serve(state: Arc<ObsState>, addr: &str) -> Result<ObsServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("observability local addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let handle = thread::Builder::new()
            .name("obs-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_stop.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(mut stream) = conn else { continue };
                    let state = Arc::clone(&state);
                    let _ = thread::Builder::new().name("obs-conn".into()).spawn(move || {
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                        handle_conn(&state, &mut stream);
                    });
                }
            })
            .context("spawn obs-http thread")?;
        Ok(ObsServer { local, stop, handle: Some(handle) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting and join the accept thread (idempotent). In-flight
    /// connection threads finish their single response on their own.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        // self-connect to unblock the blocking accept
        let _ = TcpStream::connect(self.local);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Read the request head (start line + headers). GET/POST control
/// requests carry no body, so the head is the whole request.
fn read_head(stream: &mut TcpStream) -> Option<String> {
    let mut head: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 16 * 1024 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    String::from_utf8(head).ok()
}

fn respond(stream: &mut TcpStream, status: &str, content_type: &str, body: &str) {
    let mut r = String::new();
    r.push_str(&format!("HTTP/1.1 {status}\r\n"));
    r.push_str(&format!("Content-Type: {content_type}\r\n"));
    r.push_str(&format!("Content-Length: {}\r\n", body.len()));
    r.push_str("Connection: close\r\n\r\n");
    r.push_str(body);
    let _ = stream.write_all(r.as_bytes());
    let _ = stream.flush();
}

fn respond_json(stream: &mut TcpStream, status: &str, body: &str) {
    respond(stream, status, "application/json", body);
}

fn error_json(msg: &str) -> String {
    let mut o = JsonObject::new();
    o.str("error", msg);
    o.finish()
}

/// First `key=value` match in a query string (no URL decoding — every
/// control parameter is numeric).
fn query_get(query: &str, key: &str) -> Option<String> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then(|| v.to_string())
    })
}

fn handle_conn(state: &ObsState, stream: &mut TcpStream) {
    let Some(head) = read_head(stream) else { return };
    let Some(line) = head.lines().next() else { return };
    let mut it = line.split_whitespace();
    let (Some(method), Some(target)) = (it.next(), it.next()) else {
        respond_json(stream, "400 Bad Request", &error_json("malformed request line"));
        return;
    };
    let (path, query) = target.split_once('?').unwrap_or((target, ""));
    match (method, path) {
        ("GET", "/stats") => respond_json(stream, "200 OK", &stats_json(state)),
        ("GET", "/metrics") => {
            respond(stream, "200 OK", "text/plain; version=0.0.4", &metrics_text(state));
        }
        ("GET", "/trace") => match &state.trace {
            Some(t) => {
                let n = query_get(query, "n")
                    .and_then(|s| s.parse::<usize>().ok())
                    .unwrap_or(256);
                respond_json(stream, "200 OK", &trace_json(t, n));
            }
            None => {
                respond_json(stream, "404 Not Found", &error_json("tracing disabled (--trace)"));
            }
        },
        ("GET", "/chain") => match &state.store {
            Some(store) => match chain_json(store.as_ref()) {
                Ok(body) => respond_json(stream, "200 OK", &body),
                Err(e) => {
                    respond_json(stream, "500 Internal Server Error", &error_json(&e.to_string()));
                }
            },
            None => respond_json(stream, "404 Not Found", &error_json("no store attached")),
        },
        ("GET", "/storage") => match &state.storage_obs {
            Some(obs) => respond_json(stream, "200 OK", &storage_json(state, obs)),
            None => {
                respond_json(stream, "404 Not Found", &error_json("storage plane not observed"));
            }
        },
        ("GET", "/health") => {
            let (healthy, body) = health_json(state);
            let status = if healthy { "200 OK" } else { "503 Service Unavailable" };
            respond_json(stream, status, &body);
        }
        ("POST", "/retune") => post_retune(state, query, stream),
        ("POST", "/compact") => post_compact(state, query, stream),
        ("POST", "/scrub") => match &state.scrub {
            Some(_) => {
                state.request_scrub();
                let mut o = JsonObject::new();
                o.str("accepted", "scrub pass").str("applies", "next control tick");
                respond_json(stream, "200 OK", &o.finish());
            }
            None => respond_json(stream, "404 Not Found", &error_json("no scrubber attached")),
        },
        _ => respond_json(stream, "404 Not Found", &error_json("unknown endpoint")),
    }
}

/// `/health` verdict: `dead` (HTTP 503) when the heartbeat plane says a
/// rank stopped beating past the detection timeout; `degraded` when the
/// scrubber currently knows damaged committed objects, GC has leaked
/// objects, or ≥1% of storage ops crossed the slow threshold (after a
/// 100-op warmup); `ok` otherwise. Reasons are machine-readable tokens.
fn health_json(state: &ObsState) -> (bool, String) {
    let mut reasons: Vec<&str> = Vec::new();
    let mut dead_ranks: Vec<usize> = Vec::new();
    if let Some(hb) = &state.heartbeats {
        if state.hb_timeout > 0.0 {
            dead_ranks = hb.dead_ranks(Duration::from_secs_f64(state.hb_timeout));
            if !dead_ranks.is_empty() {
                reasons.push("heartbeat_dead");
            }
        }
    }
    let damaged = state.scrub.as_ref().map(|s| s.lock().expect("scrub stats").damaged);
    if damaged.unwrap_or(0) > 0 {
        reasons.push("scrub_corruption");
    }
    let g = state.gauges();
    if g.gc_leaks > 0 {
        reasons.push("gc_leaks");
    }
    let slow = state.storage_obs.as_ref().map(|o| (o.slow_ops(), o.total_ops()));
    if let Some((slow_ops, total)) = slow {
        if total > 100 && slow_ops.saturating_mul(100) > total {
            reasons.push("slow_io");
        }
    }
    let status = if !dead_ranks.is_empty() {
        "dead"
    } else if reasons.is_empty() {
        "ok"
    } else {
        "degraded"
    };
    let mut o = JsonObject::new();
    o.str("status", status);
    let mut arr = JsonArray::new();
    for r in &reasons {
        arr.push_raw(&string_token(r));
    }
    o.raw("reasons", &arr.finish());
    let mut dr = JsonArray::new();
    for r in &dead_ranks {
        dr.push_raw(&r.to_string());
    }
    o.raw("dead_ranks", &dr.finish());
    match damaged {
        Some(d) => o.u64("scrub_damaged", d),
        None => o.raw("scrub_damaged", "null"),
    };
    o.u64("gc_leaks", g.gc_leaks);
    match slow {
        Some((s, t)) => o.u64("slow_ops", s).u64("storage_ops", t),
        None => o.raw("slow_ops", "null"),
    };
    (status != "dead", o.finish())
}

/// Histogram quantile in seconds for one op's latency histogram (upper
/// bucket bound, i.e. exact to within one power of two).
fn lat_quantile_secs(h: &LogHistogram, q: f64) -> f64 {
    h.quantile_ns(q) as f64 / 1e9
}

fn storage_json(state: &ObsState, obs: &StorageObs) -> String {
    let mut o = JsonObject::new();
    o.u64("slow_ops", obs.slow_ops())
        .u64("total_ops", obs.total_ops())
        .f64("slow_threshold_secs", obs.slow_threshold_ns() as f64 / 1e9);
    let mut tiers = JsonArray::new();
    for t in obs.tiers() {
        let mut to = JsonObject::new();
        to.str("tier", t.tier()).u64("slow_ops", t.slow_ops());
        let mut ops = JsonObject::new();
        for (i, name) in OP_NAMES.iter().enumerate() {
            let s = t.op(i);
            let count = s.count.load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            let mut oo = JsonObject::new();
            oo.u64("count", count)
                .u64("bytes", s.bytes.load(Ordering::Relaxed))
                .u64("errors", s.errors.load(Ordering::Relaxed))
                .f64("mean_secs", s.lat.mean_ns() / 1e9)
                .f64("p50_secs", lat_quantile_secs(&s.lat, 0.5))
                .f64("p99_secs", lat_quantile_secs(&s.lat, 0.99));
            ops.raw(name, &oo.finish());
        }
        to.raw("ops", &ops.finish());
        let mut fams = JsonObject::new();
        for (i, name) in FAMILY_NAMES.iter().enumerate() {
            let f = t.family(i);
            let count = f.ops.load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            let mut fo = JsonObject::new();
            fo.u64("ops", count).u64("bytes", f.bytes.load(Ordering::Relaxed));
            fams.raw(name, &fo.finish());
        }
        to.raw("families", &fams.finish());
        tiers.push_raw(&to.finish());
    }
    o.raw("tiers", &tiers.finish());
    if let Some(s) = &state.scrub {
        let s = s.lock().expect("scrub stats").clone();
        let mut so = JsonObject::new();
        so.u64("passes", s.passes)
            .u64("objects_scrubbed", s.objects_scrubbed)
            .u64("bytes_read", s.bytes_read)
            .u64("corrupt", s.corrupt)
            .u64("repaired", s.repaired)
            .u64("damaged", s.damaged);
        o.raw("scrub", &so.finish());
    } else {
        o.raw("scrub", "null");
    }
    o.finish()
}

fn post_retune(state: &ObsState, query: &str, stream: &mut TcpStream) {
    let fe = query_get(query, "full-every");
    let bs = query_get(query, "batch-size");
    let ce = query_get(query, "compact-every");
    let cd = query_get(query, "codec");
    let base = state.control().applied;
    if base.is_none() && (fe.is_none() || bs.is_none() || ce.is_none()) {
        let msg = "no applied retune to inherit from; \
                   supply all of full-every, batch-size, compact-every";
        respond_json(stream, "409 Conflict", &error_json(msg));
        return;
    }
    let base = base.unwrap_or(Retune {
        full_every: 0,
        batch_size: 1,
        compact_every: 0,
        codec: PayloadCodec::Raw,
    });
    let parsed = (|| -> std::result::Result<Retune, String> {
        let codec = match &cd {
            // DeltaFull is a full-checkpoint wire form, not a diff codec
            // a client may select
            Some(s) => PayloadCodec::parse_name(s)
                .filter(|c| *c != PayloadCodec::DeltaFull)
                .ok_or_else(|| format!("unknown codec {s:?} (raw|zstd|quant8)"))?,
            None => base.codec,
        };
        Ok(Retune {
            full_every: parse_knob(&fe, base.full_every)?,
            batch_size: parse_knob(&bs, base.batch_size)?,
            compact_every: parse_knob(&ce, base.compact_every)?,
            codec,
        })
    })();
    match parsed {
        Ok(r) => {
            state.request_retune(r);
            let mut o = JsonObject::new();
            o.raw("accepted", &retune_json(r)).str("applies", "next safe epoch boundary");
            respond_json(stream, "200 OK", &o.finish());
        }
        Err(msg) => respond_json(stream, "400 Bad Request", &error_json(&msg)),
    }
}

fn post_compact(state: &ObsState, query: &str, stream: &mut TcpStream) {
    match query_get(query, "every").map(|s| s.parse::<usize>()) {
        Some(Ok(every)) => {
            state.request_compact(every);
            let mut o = JsonObject::new();
            o.u64("compact_every", every as u64).str("applies", "next committed epoch");
            respond_json(stream, "200 OK", &o.finish());
        }
        Some(Err(_)) => {
            respond_json(stream, "400 Bad Request", &error_json("every must be an integer"));
        }
        None => respond_json(stream, "400 Bad Request", &error_json("missing query param: every")),
    }
}

fn parse_knob<T: std::str::FromStr>(
    v: &Option<String>,
    current: T,
) -> std::result::Result<T, String> {
    match v {
        Some(s) => s.parse::<T>().map_err(|_| format!("bad knob value {s:?}")),
        None => Ok(current),
    }
}

fn retune_json(r: Retune) -> String {
    let mut o = JsonObject::new();
    o.u64("full_every", r.full_every)
        .u64("batch_size", r.batch_size as u64)
        .u64("compact_every", r.compact_every as u64)
        .str("codec", r.codec.name());
    o.finish()
}

fn stats_json(state: &ObsState) -> String {
    let s = state.bus.snapshot();
    let mut o = JsonObject::new();
    o.f64("uptime_secs", s.elapsed_secs)
        .u64("steps", s.steps)
        .u64("failures", s.failures)
        .f64("stall_secs", s.stall_secs)
        .u64("bytes_written", s.bytes_written)
        .f64("write_secs", s.write_secs)
        .u64("merged_written", s.merged_written)
        .u64("raw_compacted", s.raw_compacted)
        .u64("compact_bytes", s.compact_bytes)
        .f64("commit_secs", s.commit_secs)
        .f64("deferred_secs", s.deferred_secs)
        .u64("contended_bytes", s.contended_bytes);
    {
        // per-codec achieved bytes/time (chosen + probe encodes) — what
        // the bandit policy reads, exposed for operators too
        let mut k = JsonObject::new();
        for codec in PayloadCodec::ALL {
            let i = codec.idx();
            let mut e = JsonObject::new();
            e.u64("bytes_in", s.codec_bytes_in[i])
                .u64("bytes_out", s.codec_bytes_out[i])
                .u64("encode_ns", s.codec_encode_ns[i]);
            k.raw(codec.name(), &e.finish());
        }
        o.raw("codec", &k.finish())
            .u64("codec_probes", s.codec_probes)
            .u64("codec_switches", s.codec_switches);
    }
    let v = state.control();
    let mut c = JsonObject::new();
    c.str("strategy", &v.strategy)
        .bool("adaptive", v.adaptive)
        .f64("mtbf_estimate_secs", v.mtbf_estimate)
        .f64("bw_estimate_bytes_per_sec", v.bw_estimate)
        .f64("io_budget_bytes_per_sec", v.io_budget)
        .u64("retunes", v.retunes)
        .u64("detected_failures", v.detected_failures);
    match v.applied {
        Some(r) => c.raw("applied", &retune_json(r)),
        None => c.raw("applied", "null"),
    };
    o.raw("control", &c.finish());
    match &state.heartbeats {
        Some(hb) => {
            let mut arr = JsonArray::new();
            for b in hb.snapshot() {
                let mut r = JsonObject::new();
                r.u64("rank", b.rank as u64)
                    .u64("beats", b.beats)
                    .u64("step", b.step)
                    .u64("acked", b.acked)
                    .f64("age_secs", b.age_secs)
                    .bool("silenced", b.silenced);
                arr.push_raw(&r.finish());
            }
            o.raw("heartbeats", &arr.finish());
        }
        None => {
            o.raw("heartbeats", "null");
        }
    }
    match &state.trace {
        Some(t) => {
            let (recorded, dropped) = t.counts();
            let mut tr = JsonObject::new();
            tr.u64("recorded", recorded).u64("dropped", dropped);
            let mut arr = JsonArray::new();
            for st in t.summary() {
                let mut e = JsonObject::new();
                e.str("name", st.name)
                    .u64("count", st.count)
                    .u64("total_micros", st.total_micros)
                    .u64("bytes", st.bytes);
                arr.push_raw(&e.finish());
            }
            tr.raw("summary", &arr.finish());
            o.raw("trace", &tr.finish());
        }
        None => {
            o.raw("trace", "null");
        }
    }
    o.finish()
}

fn metrics_text(state: &ObsState) -> String {
    let s = state.bus.snapshot();
    let v = state.control();
    let mut out = String::new();
    {
        let mut c = |name: &str, kind: &str, help: &str, value: String| {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            out.push_str(&format!("{name} {value}\n"));
        };
        c("lowdiff_uptime_seconds", "gauge", "bus uptime", fmt(s.elapsed_secs));
        c("lowdiff_steps_total", "counter", "productive iterations", fi(s.steps));
        c("lowdiff_failures_total", "counter", "failure events", fi(s.failures));
        c("lowdiff_stall_seconds_total", "counter", "checkpoint stall", fmt(s.stall_secs));
        c("lowdiff_bytes_written_total", "counter", "durable bytes", fi(s.bytes_written));
        c("lowdiff_write_seconds_total", "counter", "device write time", fmt(s.write_secs));
        c("lowdiff_merged_written_total", "counter", "merged spans written", fi(s.merged_written));
        c("lowdiff_raw_compacted_total", "counter", "raw objects superseded", fi(s.raw_compacted));
        c("lowdiff_compact_bytes_total", "counter", "compaction I/O bytes", fi(s.compact_bytes));
        c("lowdiff_commit_seconds_total", "counter", "phase-2 commit time", fmt(s.commit_secs));
        c("lowdiff_io_deferred_seconds_total", "counter", "deferred bg I/O", fmt(s.deferred_secs));
        c("lowdiff_io_contended_bytes_total", "counter", "contended", fi(s.contended_bytes));
        c("lowdiff_mtbf_estimate_seconds", "gauge", "MTBF estimate", fmt(v.mtbf_estimate));
        c("lowdiff_bw_estimate_bytes_per_second", "gauge", "bw estimate", fmt(v.bw_estimate));
        c("lowdiff_io_budget_bytes_per_second", "gauge", "live bg I/O budget", fmt(v.io_budget));
        c("lowdiff_retunes_total", "counter", "retunes applied", fi(v.retunes));
        c("lowdiff_detected_failures_total", "counter", "detected deaths", fi(v.detected_failures));
        c("lowdiff_codec_probes_total", "counter", "bandit probe encodes", fi(s.codec_probes));
        c("lowdiff_codec_switches_total", "counter", "live codec switches", fi(s.codec_switches));
        if let Some(r) = v.applied {
            c("lowdiff_full_every", "gauge", "applied full interval", fi(r.full_every));
            c("lowdiff_batch_size", "gauge", "applied batch size", fi(r.batch_size as u64));
            c("lowdiff_compact_every", "gauge", "applied merge factor", fi(r.compact_every as u64));
            out.push_str("# HELP lowdiff_codec_applied applied diff codec (1 = in force)\n");
            out.push_str("# TYPE lowdiff_codec_applied gauge\n");
            out.push_str(&format!("lowdiff_codec_applied{{codec=\"{}\"}} 1\n", r.codec.name()));
        }
        if let Some(t) = &state.trace {
            let (recorded, dropped) = t.counts();
            c("lowdiff_trace_events_total", "counter", "trace events recorded", fi(recorded));
            c("lowdiff_trace_dropped_total", "counter", "trace events dropped", fi(dropped));
        }
    }
    // per-codec measured counters, labelled by codec name
    for (name, help, vals) in [
        ("lowdiff_codec_bytes_in_total", "raw payload bytes offered", &s.codec_bytes_in),
        ("lowdiff_codec_bytes_out_total", "achieved wire bytes", &s.codec_bytes_out),
        ("lowdiff_codec_encode_ns_total", "encode wall nanoseconds", &s.codec_encode_ns),
    ] {
        out.push_str(&format!("# HELP {name} {help} per codec\n"));
        out.push_str(&format!("# TYPE {name} counter\n"));
        for codec in PayloadCodec::ALL {
            out.push_str(&format!(
                "{name}{{codec=\"{}\"}} {}\n",
                codec.name(),
                vals[codec.idx()]
            ));
        }
    }
    if let Some(hb) = &state.heartbeats {
        out.push_str("# HELP lowdiff_heartbeat_age_seconds seconds since each rank's newest beat\n");
        out.push_str("# TYPE lowdiff_heartbeat_age_seconds gauge\n");
        let beats = hb.snapshot();
        for b in &beats {
            if b.age_secs.is_finite() {
                out.push_str(&format!(
                    "lowdiff_heartbeat_age_seconds{{rank=\"{}\"}} {}\n",
                    b.rank,
                    fmt(b.age_secs)
                ));
            }
        }
        out.push_str("# HELP lowdiff_heartbeat_beats_total beats recorded per rank\n");
        out.push_str("# TYPE lowdiff_heartbeat_beats_total counter\n");
        for b in &beats {
            out.push_str(&format!(
                "lowdiff_heartbeat_beats_total{{rank=\"{}\"}} {}\n",
                b.rank, b.beats
            ));
        }
    }
    // report-only counters promoted to live series (driver-refreshed at
    // tick boundaries) plus the scrub plane
    {
        let mut c = |name: &str, kind: &str, help: &str, value: String| {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            out.push_str(&format!("{name} {value}\n"));
        };
        let g = state.gauges();
        c("lowdiff_pool_hits_total", "counter", "pooled encode buffers recycled", fi(g.pool_hits));
        c("lowdiff_pool_misses_total", "counter", "fresh pool allocations", fi(g.pool_misses));
        c("lowdiff_gc_leaked", "gauge", "failed GC deletes still present", fi(g.gc_leaks));
        if let Some(t) = &state.trace {
            let help = "oldest events cut from the persisted journal by the size cap";
            c("lowdiff_trace_journal_dropped", "gauge", help, fi(t.journal_dropped()));
        }
        if let Some(s) = &state.scrub {
            let s = s.lock().expect("scrub stats").clone();
            c("lowdiff_scrub_passes_total", "counter", "scrub passes completed", fi(s.passes));
            let objects = fi(s.objects_scrubbed);
            c("lowdiff_scrub_objects_total", "counter", "object verifications", objects);
            let read = fi(s.bytes_read);
            c("lowdiff_scrub_bytes_total", "counter", "bytes read by the scrubber", read);
            c("lowdiff_scrub_corrupt_total", "counter", "objects flagged corrupt", fi(s.corrupt));
            c("lowdiff_scrub_repaired_total", "counter", "objects repaired", fi(s.repaired));
            c("lowdiff_scrub_damaged", "gauge", "objects currently damaged", fi(s.damaged));
        }
        if let Some(obs) = &state.storage_obs {
            let help = "storage ops at or above the slow threshold";
            c("lowdiff_storage_slow_ops_total", "counter", help, fi(obs.slow_ops()));
        }
    }
    if let Some(obs) = &state.storage_obs {
        out.push_str(&storage_metrics_text(obs));
    }
    out
}

/// Storage-plane series: per-tier/per-op counters plus real Prometheus
/// histogram exposition (`_bucket`/`_sum`/`_count`) straight from the
/// lock-free [`LogHistogram`]s. Empty buckets are elided — the text
/// format accepts any subset of `le` bounds as long as the counts are
/// cumulative and the `+Inf` bucket is present — so output stays
/// proportional to occupied buckets, not the 40-bucket range.
fn storage_metrics_text(obs: &StorageObs) -> String {
    let tiers = obs.tiers();
    let mut out = String::new();
    out.push_str("# HELP lowdiff_storage_ops_total storage ops per tier and op\n");
    out.push_str("# TYPE lowdiff_storage_ops_total counter\n");
    for t in &tiers {
        for (i, op) in OP_NAMES.iter().enumerate() {
            let n = t.op(i).count.load(Ordering::Relaxed);
            if n > 0 {
                let lbl = format!("{{tier=\"{}\",op=\"{op}\"}}", t.tier());
                out.push_str(&format!("lowdiff_storage_ops_total{lbl} {n}\n"));
            }
        }
    }
    out.push_str("# HELP lowdiff_storage_op_bytes_total bytes moved per tier and op\n");
    out.push_str("# TYPE lowdiff_storage_op_bytes_total counter\n");
    for t in &tiers {
        for (i, op) in OP_NAMES.iter().enumerate() {
            if t.op(i).count.load(Ordering::Relaxed) > 0 {
                let lbl = format!("{{tier=\"{}\",op=\"{op}\"}}", t.tier());
                let b = t.op(i).bytes.load(Ordering::Relaxed);
                out.push_str(&format!("lowdiff_storage_op_bytes_total{lbl} {b}\n"));
            }
        }
    }
    out.push_str("# HELP lowdiff_storage_op_errors_total failed storage ops per tier and op\n");
    out.push_str("# TYPE lowdiff_storage_op_errors_total counter\n");
    for t in &tiers {
        for (i, op) in OP_NAMES.iter().enumerate() {
            if t.op(i).count.load(Ordering::Relaxed) > 0 {
                let lbl = format!("{{tier=\"{}\",op=\"{op}\"}}", t.tier());
                let e = t.op(i).errors.load(Ordering::Relaxed);
                out.push_str(&format!("lowdiff_storage_op_errors_total{lbl} {e}\n"));
            }
        }
    }
    out.push_str("# HELP lowdiff_storage_family_ops_total ops per tier and name family\n");
    out.push_str("# TYPE lowdiff_storage_family_ops_total counter\n");
    for t in &tiers {
        for (i, fam) in FAMILY_NAMES.iter().enumerate() {
            let n = t.family(i).ops.load(Ordering::Relaxed);
            if n > 0 {
                let lbl = format!("{{tier=\"{}\",family=\"{fam}\"}}", t.tier());
                out.push_str(&format!("lowdiff_storage_family_ops_total{lbl} {n}\n"));
            }
        }
    }
    out.push_str("# HELP lowdiff_storage_family_bytes_total bytes per tier and name family\n");
    out.push_str("# TYPE lowdiff_storage_family_bytes_total counter\n");
    for t in &tiers {
        for (i, fam) in FAMILY_NAMES.iter().enumerate() {
            if t.family(i).ops.load(Ordering::Relaxed) > 0 {
                let lbl = format!("{{tier=\"{}\",family=\"{fam}\"}}", t.tier());
                let b = t.family(i).bytes.load(Ordering::Relaxed);
                out.push_str(&format!("lowdiff_storage_family_bytes_total{lbl} {b}\n"));
            }
        }
    }
    out.push_str("# HELP lowdiff_storage_op_duration_seconds storage op latency per tier and op\n");
    out.push_str("# TYPE lowdiff_storage_op_duration_seconds histogram\n");
    for t in &tiers {
        for (i, op) in OP_NAMES.iter().enumerate() {
            let h = &t.op(i).lat;
            let total = h.count();
            if total == 0 {
                continue;
            }
            let lbl = format!("tier=\"{}\",op=\"{op}\"", t.tier());
            let mut cum = 0u64;
            for (b, n) in h.bucket_counts().iter().enumerate() {
                if *n == 0 {
                    continue;
                }
                cum += n;
                let le = LogHistogram::bucket_bound_ns(b) as f64 / 1e9;
                out.push_str(&format!(
                    "lowdiff_storage_op_duration_seconds_bucket{{{lbl},le=\"{le}\"}} {cum}\n"
                ));
            }
            out.push_str(&format!(
                "lowdiff_storage_op_duration_seconds_bucket{{{lbl},le=\"+Inf\"}} {total}\n"
            ));
            let sum = h.sum_ns() as f64 / 1e9;
            out.push_str(&format!("lowdiff_storage_op_duration_seconds_sum{{{lbl}}} {sum}\n"));
            out.push_str(&format!("lowdiff_storage_op_duration_seconds_count{{{lbl}}} {total}\n"));
        }
    }
    out
}

/// Prometheus sample formatting for finite f64 values.
fn fmt(x: f64) -> String {
    format!("{x}")
}

fn fi(x: u64) -> String {
    x.to_string()
}

fn trace_json(tracer: &Tracer, n: usize) -> String {
    let mut arr = JsonArray::new();
    for ev in tracer.recent(n) {
        arr.push_raw(&ev.to_chrome_json());
    }
    arr.finish()
}

fn chain_json(store: &dyn StorageBackend) -> Result<String> {
    let names = store.list()?;
    let mut o = JsonObject::new();
    o.u64("objects", names.len() as u64);
    let chain = Manifest::latest_chain(store)?;
    if chain.full.is_some() || !chain.diffs.is_empty() {
        let mut f = JsonObject::new();
        match &chain.full {
            Some((step, name)) => f.u64("full_step", *step).str("full", name),
            None => f.raw("full_step", "null"),
        };
        let max_level = chain
            .diffs
            .iter()
            .map(|(_, _, n)| Manifest::span_level(n))
            .max()
            .unwrap_or(0);
        let replay = usize::from(chain.full.is_some()) + chain.diffs.len();
        f.u64("diffs", chain.diffs.len() as u64)
            .u64("replay_objects", replay as u64)
            .u64("max_level", max_level as u64)
            .u64("latest_step", chain.latest_step());
        o.raw("flat", &f.finish());
    } else {
        o.raw("flat", "null");
    }
    let latest = names.iter().filter_map(|n| Manifest::parse_global(n)).max();
    if let Some((gen, step)) = latest {
        let mut c = JsonObject::new();
        c.u64("generation", gen).u64("committed_step", step);
        let mut ranks: Vec<usize> = names
            .iter()
            .filter_map(|n| Manifest::parse_gen_rank(n))
            .filter(|&(g, _, _)| g == gen)
            .map(|(_, r, _)| r)
            .collect();
        ranks.sort_unstable();
        ranks.dedup();
        let mut arr = JsonArray::new();
        for r in ranks {
            let ch = Manifest::gen_rank_chain(&names, gen, r, u64::MAX);
            let lvl = ch
                .diffs
                .iter()
                .map(|(_, _, n)| Manifest::span_level(n))
                .max()
                .unwrap_or(0);
            let replay = usize::from(ch.full.is_some()) + ch.diffs.len();
            let mut ro = JsonObject::new();
            ro.u64("rank", r as u64)
                .u64("replay_objects", replay as u64)
                .u64("max_level", lvl as u64);
            arr.push_raw(&ro.finish());
        }
        c.raw("ranks", &arr.finish());
        o.raw("cluster", &c.finish());
    } else {
        o.raw("cluster", "null");
    }
    Ok(o.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    fn http(addr: SocketAddr, method: &str, target: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).expect("connect");
        let req = format!("{method} {target} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        s.write_all(req.as_bytes()).expect("send");
        let mut buf = String::new();
        s.read_to_string(&mut buf).expect("read");
        let (head, body) = buf.split_once("\r\n\r\n").expect("http response");
        (head.to_string(), body.to_string())
    }

    fn test_state() -> Arc<ObsState> {
        let bus = Arc::new(TelemetryBus::new());
        bus.record_step(0.1);
        bus.record_step(0.2);
        bus.record_write(1000, 0.01);
        bus.record_codec(PayloadCodec::Quant8.idx(), 100, 40, 5);
        bus.record_codec_probe();
        let trace = Arc::new(Tracer::default());
        trace.complete("persist.submit", 0.001, 0, 7, 128, 0);
        let hb = Arc::new(HeartbeatTable::new(2));
        hb.beat(0, 5, 4);
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        store.put(&Manifest::full_name(10), b"x").unwrap();
        store.put(&Manifest::diff_name(11), b"y").unwrap();
        Arc::new(ObsState::new(bus, Some(trace), Some(hb), Some(store)))
    }

    #[test]
    fn stats_metrics_trace_and_chain_respond() {
        let state = test_state();
        state.set_control(ControlView {
            strategy: "lowdiff+".into(),
            adaptive: true,
            mtbf_estimate: 900.0,
            bw_estimate: 1e9,
            io_budget: 5e8,
            applied: Some(Retune {
                full_every: 64,
                batch_size: 4,
                compact_every: 8,
                codec: PayloadCodec::Quant8,
            }),
            retunes: 3,
            detected_failures: 1,
        });
        let mut srv = ObsServer::serve(Arc::clone(&state), "127.0.0.1:0").unwrap();
        let addr = srv.local_addr();

        let (head, body) = http(addr, "GET", "/stats");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("Content-Length:"));
        assert!(body.contains("\"steps\":2"), "{body}");
        assert!(body.contains("\"strategy\":\"lowdiff+\""));
        assert!(body.contains("\"full_every\":64"));
        assert!(body.contains("\"codec\":\"quant8\""), "applied codec in /stats: {body}");
        assert!(body.contains("\"quant8\":{\"bytes_in\":100"), "per-codec table: {body}");
        assert!(body.contains("\"codec_probes\":1"), "{body}");
        assert!(body.contains("\"heartbeats\":["));
        assert!(body.contains("\"recorded\":1"));

        let (head, body) = http(addr, "GET", "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(body.contains("lowdiff_steps_total 2"), "{body}");
        assert!(body.contains("# TYPE lowdiff_steps_total counter"));
        assert!(body.contains("lowdiff_bytes_written_total 1000"));
        assert!(body.contains("lowdiff_full_every 64"));
        assert!(body.contains("lowdiff_codec_applied{codec=\"quant8\"} 1"), "{body}");
        assert!(body.contains("lowdiff_codec_bytes_out_total{codec=\"quant8\"} 40"), "{body}");
        assert!(body.contains("lowdiff_codec_bytes_in_total{codec=\"raw\"} 0"));
        assert!(body.contains("lowdiff_codec_probes_total 1"));
        assert!(body.contains("lowdiff_heartbeat_beats_total{rank=\"0\"} 1"));

        let (head, body) = http(addr, "GET", "/trace?n=10");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(body.contains("\"name\":\"persist.submit\""), "{body}");

        let (head, body) = http(addr, "GET", "/chain");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(body.contains("\"objects\":2"), "{body}");
        assert!(body.contains("\"full_step\":10"));

        let (head, _) = http(addr, "GET", "/nope");
        assert!(head.starts_with("HTTP/1.1 404"));

        srv.shutdown();
        // shutdown is idempotent
        srv.shutdown();
    }

    #[test]
    fn retune_and_compact_round_trip_through_parked_requests() {
        let bus = Arc::new(TelemetryBus::new());
        let state = Arc::new(ObsState::new(bus, None, None, None));
        let srv = ObsServer::serve(Arc::clone(&state), "127.0.0.1:0").unwrap();
        let addr = srv.local_addr();

        // nothing applied yet: partial retunes have no base to inherit
        let (head, _) = http(addr, "POST", "/retune?full-every=32");
        assert!(head.starts_with("HTTP/1.1 409"), "{head}");
        assert!(state.take_retune().is_none());

        // fully-specified retune works even without a base
        let (head, body) = http(addr, "POST", "/retune?full-every=32&batch-size=2&compact-every=4");
        assert!(head.starts_with("HTTP/1.1 200"), "{head} {body}");
        assert_eq!(
            state.take_retune(),
            Some(Retune {
                full_every: 32,
                batch_size: 2,
                compact_every: 4,
                codec: PayloadCodec::Raw,
            })
        );

        // with an applied base, missing knobs inherit
        state.set_control(ControlView {
            applied: Some(Retune {
                full_every: 100,
                batch_size: 8,
                compact_every: 6,
                codec: PayloadCodec::Zstd,
            }),
            ..Default::default()
        });
        let (head, _) = http(addr, "POST", "/retune?batch-size=16");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert_eq!(
            state.take_retune(),
            Some(Retune {
                full_every: 100,
                batch_size: 16,
                compact_every: 6,
                codec: PayloadCodec::Zstd,
            })
        );

        let (head, _) = http(addr, "POST", "/retune?batch-size=banana");
        assert!(head.starts_with("HTTP/1.1 400"));
        assert!(state.take_retune().is_none());

        let (head, _) = http(addr, "POST", "/compact?every=12");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert_eq!(state.take_compact(), Some(12));
        assert!(state.take_compact().is_none(), "drained");

        let (head, _) = http(addr, "POST", "/compact");
        assert!(head.starts_with("HTTP/1.1 400"));

        // trace/chain/storage/scrub absent: honest 404s
        let (head, _) = http(addr, "GET", "/trace");
        assert!(head.starts_with("HTTP/1.1 404"));
        let (head, _) = http(addr, "GET", "/chain");
        assert!(head.starts_with("HTTP/1.1 404"));
        let (head, _) = http(addr, "GET", "/storage");
        assert!(head.starts_with("HTTP/1.1 404"));
        let (head, _) = http(addr, "POST", "/scrub");
        assert!(head.starts_with("HTTP/1.1 404"));
    }

    #[test]
    fn storage_health_and_scrub_endpoints() {
        let bus = Arc::new(TelemetryBus::new());
        let obs = Arc::new(StorageObs::new(0));
        let observed =
            crate::storage::Observed::new(Arc::new(MemStore::new()), Arc::clone(&obs), "durable");
        observed.put(&Manifest::full_name(1), b"abc").unwrap();
        observed.get(&Manifest::full_name(1)).unwrap();
        let scrub = Arc::new(Mutex::new(ScrubStats::default()));
        let state = Arc::new(
            ObsState::new(bus, None, None, None)
                .with_storage_obs(Arc::clone(&obs))
                .with_scrub(Arc::clone(&scrub)),
        );
        let srv = ObsServer::serve(Arc::clone(&state), "127.0.0.1:0").unwrap();
        let addr = srv.local_addr();

        let (head, body) = http(addr, "GET", "/storage");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("\"tier\":\"durable\""), "{body}");
        assert!(body.contains("\"put\":{\"count\":1"), "{body}");
        assert!(body.contains("\"full\":{\"ops\":2"), "family traffic: {body}");
        assert!(body.contains("\"scrub\":{\"passes\":0"), "{body}");

        let (head, body) = http(addr, "GET", "/health");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(body.contains("\"status\":\"ok\""), "{body}");
        assert!(body.contains("\"reasons\":[]"), "{body}");

        // scrub damage degrades health with a machine-readable reason
        scrub.lock().unwrap().damaged = 2;
        let (head, body) = http(addr, "GET", "/health");
        assert!(head.starts_with("HTTP/1.1 200"), "degraded is not dead: {head}");
        assert!(body.contains("\"status\":\"degraded\""), "{body}");
        assert!(body.contains("\"scrub_corruption\""), "{body}");
        scrub.lock().unwrap().damaged = 0;

        // gc leaks degrade too
        state.set_gauges(ReportGauges { pool_hits: 5, pool_misses: 1, gc_leaks: 3 });
        let (_, body) = http(addr, "GET", "/health");
        assert!(body.contains("\"status\":\"degraded\""), "{body}");
        assert!(body.contains("\"gc_leaks\""), "{body}");

        // /metrics carries the promoted gauges and the real histogram
        let (_, body) = http(addr, "GET", "/metrics");
        assert!(body.contains("lowdiff_pool_hits_total 5"), "{body}");
        assert!(body.contains("lowdiff_gc_leaked 3"));
        assert!(body.contains("lowdiff_scrub_passes_total 0"));
        assert!(body.contains("lowdiff_storage_slow_ops_total 0"));
        assert!(
            body.contains("lowdiff_storage_ops_total{tier=\"durable\",op=\"put\"} 1"),
            "{body}"
        );
        assert!(
            body.contains(
                "lowdiff_storage_op_duration_seconds_bucket{tier=\"durable\",op=\"put\",le=\"+Inf\"} 1"
            ),
            "histogram +Inf bucket: {body}"
        );
        assert!(
            body.contains("lowdiff_storage_op_duration_seconds_count{tier=\"durable\",op=\"get\"} 1"),
            "{body}"
        );

        // POST /scrub parks a request the driver drains
        let (head, _) = http(addr, "POST", "/scrub");
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(state.take_scrub());
        assert!(!state.take_scrub(), "drained");
    }

    #[test]
    fn health_dead_on_stale_heartbeats() {
        let bus = Arc::new(TelemetryBus::new());
        let hb = Arc::new(HeartbeatTable::new(2));
        // rank 1 never beats; rank 0 beats well past the tiny timeout, so
        // activity-relative staleness declares rank 1 dead
        thread::sleep(Duration::from_millis(20));
        hb.beat(0, 1, 0);
        let state =
            Arc::new(ObsState::new(bus, None, Some(hb), None).with_heartbeat_timeout(0.001));
        let srv = ObsServer::serve(Arc::clone(&state), "127.0.0.1:0").unwrap();
        let (head, body) = http(srv.local_addr(), "GET", "/health");
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        assert!(body.contains("\"status\":\"dead\""), "{body}");
        assert!(body.contains("\"heartbeat_dead\""), "{body}");
        assert!(body.contains("\"dead_ranks\":[1]"), "{body}");
    }
}
