//! Interference-aware I/O scheduling for background checkpoint
//! maintenance.
//!
//! Chain compaction reads raw diff objects and writes merged spans on the
//! **same backend** the checkpoint persist path writes — on a bandwidth-
//! bound device every background byte is a foreground byte delayed
//! (TierCheck's lesson: checkpoint I/O and foreground traffic must be
//! actively scheduled, not just tolerated). The [`IoGate`] shapes the
//! background side with two mechanisms:
//!
//! 1. **Idle triggering**: every persist on the write path holds a
//!    [`PersistGuard`] while it occupies the device; background ops
//!    ([`IoGate::throttle`]) yield while any persist is in flight, up to
//!    a bounded defer (so compaction can never be starved forever — past
//!    the bound it proceeds and the contended bytes are *counted*, not
//!    hidden).
//! 2. **Token bucket**: an optional byte-rate budget
//!    ([`IoGateConfig::bytes_per_sec`], the `--io-budget` CLI knob)
//!    serializes background bytes at a fixed rate, exactly like the
//!    device model in [`Throttled`](crate::storage::Throttled).
//!
//! [`GatedStore`] routes a whole [`StorageBackend`] through the gate —
//! the compactor's logical store view is wrapped in one, so every
//! compaction read and merged write is shaped without the compaction code
//! knowing. Interference actually observed (deferred seconds, bytes that
//! proceeded under contention) flows to the
//! [`TelemetryBus`](crate::control::telemetry::TelemetryBus) and the
//! `control_loop` bench.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::control::telemetry::TelemetryBus;
use crate::control::trace::Tracer;
use crate::storage::{StorageBackend, StorageStats};

/// Gate policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct IoGateConfig {
    /// background byte budget; <= 0 disables the token bucket (idle
    /// triggering still applies)
    pub bytes_per_sec: f64,
    /// longest a background op defers to in-flight persists before
    /// proceeding anyway (starvation bound)
    pub max_defer: Duration,
    /// defer-poll interval
    pub poll: Duration,
}

impl Default for IoGateConfig {
    fn default() -> Self {
        IoGateConfig {
            bytes_per_sec: 0.0,
            max_defer: Duration::from_millis(20),
            poll: Duration::from_micros(500),
        }
    }
}

/// Observed gate activity.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IoGateStats {
    /// background ops that yielded to at least one in-flight persist
    pub deferred_ops: u64,
    pub deferred_secs: f64,
    /// background bytes that proceeded while a persist was in flight
    /// (the residual interference after the defer bound)
    pub contended_bytes: u64,
    /// total background bytes admitted through the gate
    pub throttled_bytes: u64,
}

/// The shared gate: persist side marks occupancy, background side asks
/// for admission.
#[derive(Debug)]
pub struct IoGate {
    cfg: IoGateConfig,
    /// live byte budget (f64 bits): [`IoGateConfig::bytes_per_sec`] seeds
    /// it, [`IoGate::set_rate`] retunes it at runtime (the `--adaptive`
    /// autoscaler, see [`autoscale_budget`])
    rate_bits: AtomicU64,
    persists: AtomicU64,
    /// token-bucket state: time before which the background budget is
    /// spoken for (same busy-until scheme as [`Throttled`])
    busy_until: Mutex<Instant>,
    deferred_ops: AtomicU64,
    deferred_nanos: AtomicU64,
    contended_bytes: AtomicU64,
    throttled_bytes: AtomicU64,
    bus: Option<Arc<TelemetryBus>>,
    trace: Option<Arc<Tracer>>,
}

impl IoGate {
    pub fn new(cfg: IoGateConfig) -> IoGate {
        IoGate::with_bus(cfg, None)
    }

    pub fn with_bus(cfg: IoGateConfig, bus: Option<Arc<TelemetryBus>>) -> IoGate {
        IoGate::with_obs(cfg, bus, None)
    }

    /// Full observability hookup: telemetry bus + event tracer.
    pub fn with_obs(
        cfg: IoGateConfig,
        bus: Option<Arc<TelemetryBus>>,
        trace: Option<Arc<Tracer>>,
    ) -> IoGate {
        IoGate {
            rate_bits: AtomicU64::new(cfg.bytes_per_sec.max(0.0).to_bits()),
            cfg,
            persists: AtomicU64::new(0),
            busy_until: Mutex::new(Instant::now()),
            deferred_ops: AtomicU64::new(0),
            deferred_nanos: AtomicU64::new(0),
            contended_bytes: AtomicU64::new(0),
            throttled_bytes: AtomicU64::new(0),
            bus,
            trace,
        }
    }

    /// The live background byte budget (bytes/sec; <= 0 = unlimited).
    pub fn rate(&self) -> f64 {
        f64::from_bits(self.rate_bits.load(Ordering::Relaxed))
    }

    /// Retune the byte budget live; in-flight `charge`s finish at the old
    /// rate, subsequent ones pay the new one.
    pub fn set_rate(&self, bytes_per_sec: f64) {
        let r = if bytes_per_sec.is_finite() { bytes_per_sec.max(0.0) } else { 0.0 };
        self.rate_bits.store(r.to_bits(), Ordering::Relaxed);
    }

    /// Mark one foreground persist in flight for the guard's lifetime.
    pub fn persist_guard(self: &Arc<Self>) -> PersistGuard {
        self.persists.fetch_add(1, Ordering::SeqCst);
        PersistGuard { gate: Arc::clone(self) }
    }

    /// Foreground persists currently holding the device.
    pub fn persists_inflight(&self) -> u64 {
        self.persists.load(Ordering::SeqCst)
    }

    /// Admit `bytes` of background I/O: first yield to in-flight persists
    /// (bounded), then pay the token bucket. For ops whose size is only
    /// known afterwards (reads), call [`yield_to_persists`]
    /// (IoGate::yield_to_persists) BEFORE the op and [`charge`]
    /// (IoGate::charge) after — yielding after the device was already
    /// touched would protect nothing.
    pub fn throttle(&self, bytes: u64) {
        self.yield_to_persists();
        self.charge(bytes);
    }

    /// The idle trigger: block while any persist is in flight, up to the
    /// bounded defer. Must run BEFORE the background op touches the
    /// device.
    pub fn yield_to_persists(&self) {
        let t0 = Instant::now();
        let mut deferred = false;
        while self.persists_inflight() > 0 && t0.elapsed() < self.cfg.max_defer {
            deferred = true;
            std::thread::sleep(self.cfg.poll);
        }
        if deferred {
            let waited = t0.elapsed();
            self.deferred_ops.fetch_add(1, Ordering::Relaxed);
            self.deferred_nanos
                .fetch_add(waited.as_nanos() as u64, Ordering::Relaxed);
            if let Some(bus) = &self.bus {
                bus.record_defer(waited.as_secs_f64());
            }
            if let Some(t) = &self.trace {
                t.complete("iogate.defer", waited.as_secs_f64(), 0, 0, 0, 0);
            }
        }
    }

    /// Account + rate-limit `bytes` of background I/O that is happening
    /// (or just happened) anyway; bytes moved while a persist was in
    /// flight are counted as residual interference.
    pub fn charge(&self, bytes: u64) {
        if self.persists_inflight() > 0 {
            // defer bound hit (or the persist arrived mid-op): the bytes
            // moved under contention — make the interference observable
            self.contended_bytes.fetch_add(bytes, Ordering::Relaxed);
            if let Some(bus) = &self.bus {
                bus.record_contention(bytes);
            }
        }
        let rate = self.rate();
        if rate > 0.0 {
            let cost = Duration::from_secs_f64(bytes as f64 / rate);
            let wake = {
                let mut busy = self.busy_until.lock().unwrap();
                let start = (*busy).max(Instant::now());
                *busy = start + cost;
                *busy
            };
            let now = Instant::now();
            if wake > now {
                std::thread::sleep(wake - now);
            }
        }
        self.throttled_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn stats(&self) -> IoGateStats {
        IoGateStats {
            deferred_ops: self.deferred_ops.load(Ordering::Relaxed),
            deferred_secs: self.deferred_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            contended_bytes: self.contended_bytes.load(Ordering::Relaxed),
            throttled_bytes: self.throttled_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Interference band the autoscaler steers the gate into: background I/O
/// should cost the foreground between 1% and 5% of wall time.
pub const AUTOSCALE_LO: f64 = 0.01;
pub const AUTOSCALE_HI: f64 = 0.05;
/// Autoscaler rate floor — compaction must never be starved outright.
pub const AUTOSCALE_MIN_RATE: f64 = 1e6;

/// Closed-loop `--io-budget` policy: map one interference window (the
/// gate's OWN deferred-seconds / contended-bytes telemetry, differenced
/// by the driver) to the next token-bucket rate. Pure and deterministic
/// so the policy is unit-testable without a device.
///
/// The interference fraction combines time the gate spent deferring with
/// the foreground time the contended bytes displaced (at the estimated
/// device bandwidth `bw_est`). Multiplicative decrease (×0.7) above
/// [`AUTOSCALE_HI`], multiplicative increase (×1.3) below
/// [`AUTOSCALE_LO`] — the classic stable search. A `current` of 0 means
/// "unlimited": the first over-band window replaces it with a real
/// budget derived from `bw_est`; an under-band window leaves unlimited
/// alone (there is nothing to widen). The result is clamped to
/// `[AUTOSCALE_MIN_RATE, 2·bw_est]`.
pub fn autoscale_budget(
    current: f64,
    deferred_secs: f64,
    contended_bytes: u64,
    dt_secs: f64,
    bw_est: f64,
) -> f64 {
    if dt_secs <= 0.0 || !bw_est.is_finite() {
        return current;
    }
    let max_rate = (bw_est * 2.0).max(AUTOSCALE_MIN_RATE);
    let interference =
        deferred_secs / dt_secs + contended_bytes as f64 / (bw_est.max(1.0) * dt_secs);
    if interference > AUTOSCALE_HI {
        let base = if current > 0.0 { current } else { bw_est.max(AUTOSCALE_MIN_RATE) };
        (base * 0.7).clamp(AUTOSCALE_MIN_RATE, max_rate)
    } else if interference < AUTOSCALE_LO && current > 0.0 {
        (current * 1.3).clamp(AUTOSCALE_MIN_RATE, max_rate)
    } else {
        current
    }
}

/// RAII persist marker; see [`IoGate::persist_guard`].
#[derive(Debug)]
pub struct PersistGuard {
    gate: Arc<IoGate>,
}

impl Drop for PersistGuard {
    fn drop(&mut self) {
        self.gate.persists.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A [`StorageBackend`] whose puts and gets pay the gate — background
/// maintenance (compaction) reads/writes through one of these while the
/// foreground write path uses the raw store plus persist guards.
pub struct GatedStore {
    inner: Arc<dyn StorageBackend>,
    gate: Arc<IoGate>,
}

impl GatedStore {
    pub fn new(inner: Arc<dyn StorageBackend>, gate: Arc<IoGate>) -> GatedStore {
        GatedStore { inner, gate }
    }
}

impl StorageBackend for GatedStore {
    fn put(&self, name: &str, bytes: &[u8]) -> Result<()> {
        self.gate.throttle(bytes.len() as u64);
        self.inner.put(name, bytes)
    }

    fn get(&self, name: &str) -> Result<Vec<u8>> {
        // yield BEFORE touching the device (the size is only known after,
        // so the token bucket is charged after the fact)
        self.gate.yield_to_persists();
        let b = self.inner.get(name)?;
        self.gate.charge(b.len() as u64);
        Ok(b)
    }

    fn delete(&self, name: &str) -> Result<()> {
        self.inner.delete(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        self.inner.list()
    }

    fn exists(&self, name: &str) -> bool {
        self.inner.exists(name)
    }

    fn put_vectored(&self, name: &str, parts: &[&[u8]]) -> Result<()> {
        self.gate
            .throttle(parts.iter().map(|p| p.len() as u64).sum());
        self.inner.put_vectored(name, parts)
    }

    fn demote(&self, name: &str) -> Result<bool> {
        self.inner.demote(name)
    }

    fn storage_stats(&self) -> StorageStats {
        self.inner.storage_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    #[test]
    fn token_bucket_enforces_background_budget() {
        let gate = IoGate::new(IoGateConfig { bytes_per_sec: 1e6, ..Default::default() });
        let t0 = Instant::now();
        gate.throttle(100_000); // 0.1 s at 1 MB/s
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.09, "budget not enforced: {dt}");
        assert_eq!(gate.stats().throttled_bytes, 100_000);
        assert_eq!(gate.stats().deferred_ops, 0, "no persists in flight");
    }

    #[test]
    fn background_yields_to_inflight_persists() {
        let gate = Arc::new(IoGate::new(IoGateConfig {
            bytes_per_sec: 0.0,
            max_defer: Duration::from_millis(30),
            poll: Duration::from_micros(200),
        }));
        let g = gate.persist_guard();
        assert_eq!(gate.persists_inflight(), 1);
        let t0 = Instant::now();
        gate.throttle(1000);
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(25), "did not defer: {dt:?}");
        let st = gate.stats();
        assert_eq!(st.deferred_ops, 1);
        assert!(st.deferred_secs > 0.0);
        assert_eq!(st.contended_bytes, 1000, "defer bound hit => contended");
        drop(g);
        assert_eq!(gate.persists_inflight(), 0);
        let t0 = Instant::now();
        gate.throttle(1000);
        assert!(t0.elapsed() < Duration::from_millis(10), "idle device admits immediately");
        assert_eq!(gate.stats().contended_bytes, 1000, "no new contention when idle");
    }

    #[test]
    fn guard_released_mid_defer_unblocks_early() {
        let gate = Arc::new(IoGate::new(IoGateConfig {
            bytes_per_sec: 0.0,
            max_defer: Duration::from_millis(500),
            poll: Duration::from_micros(200),
        }));
        let g = gate.persist_guard();
        let g2 = Arc::clone(&gate);
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            g2.throttle(10);
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(g);
        let waited = h.join().unwrap();
        assert!(waited < Duration::from_millis(400), "defer should end with the persist");
        assert_eq!(gate.stats().contended_bytes, 0, "yielding avoided the contention");
    }

    #[test]
    fn gated_store_charges_puts_and_gets() {
        let inner: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let gate = Arc::new(IoGate::new(IoGateConfig::default()));
        let s = GatedStore::new(inner, Arc::clone(&gate));
        s.put("a", &[0u8; 64]).unwrap();
        assert_eq!(s.get("a").unwrap().len(), 64);
        let parts: [&[u8]; 2] = [b"xy", b"z"];
        s.put_vectored("b", &parts).unwrap();
        assert_eq!(gate.stats().throttled_bytes, 64 + 64 + 3);
        assert!(s.exists("a"));
        s.delete("a").unwrap();
        assert!(!s.exists("a"));
        assert_eq!(s.list().unwrap(), vec!["b"]);
    }

    #[test]
    fn gated_reads_yield_before_touching_the_device() {
        // the defer must happen BEFORE the inner get: a read issued while
        // a persist is in flight waits first (up to the bound), instead
        // of contending immediately and "yielding" after the damage
        let inner: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        inner.put("span", &[1u8; 256]).unwrap();
        let gate = Arc::new(IoGate::new(IoGateConfig {
            bytes_per_sec: 0.0,
            max_defer: Duration::from_millis(30),
            poll: Duration::from_micros(200),
        }));
        let s = GatedStore::new(Arc::clone(&inner), Arc::clone(&gate));
        let _g = gate.persist_guard();
        let t0 = Instant::now();
        assert_eq!(s.get("span").unwrap().len(), 256);
        assert!(t0.elapsed() >= Duration::from_millis(25), "read did not defer");
        let st = gate.stats();
        assert_eq!(st.deferred_ops, 1);
        assert_eq!(st.contended_bytes, 256, "defer bound hit => counted as contended");
    }

    #[test]
    fn live_rate_retunes_the_token_bucket() {
        let gate = IoGate::new(IoGateConfig { bytes_per_sec: 1e6, ..Default::default() });
        assert_eq!(gate.rate(), 1e6);
        gate.set_rate(64e6);
        let t0 = Instant::now();
        gate.throttle(100_000); // 1.5 ms at the retuned 64 MB/s
        assert!(t0.elapsed().as_secs_f64() < 0.05, "old 1 MB/s rate still enforced");
        gate.set_rate(f64::NAN);
        assert_eq!(gate.rate(), 0.0, "garbage rates disable the bucket");
        gate.set_rate(-3.0);
        assert_eq!(gate.rate(), 0.0);
    }

    #[test]
    fn autoscale_backs_off_under_interference_and_recovers() {
        let bw = 1e9;
        // heavy interference: 20% of the window spent deferring
        let down = autoscale_budget(1e8, 2.0, 0, 10.0, bw);
        assert!(down < 1e8, "must back off: {down}");
        assert!((down - 7e7).abs() < 1.0);
        // quiet window: budget widens again
        let up = autoscale_budget(down, 0.0, 0, 10.0, bw);
        assert!(up > down, "must recover: {up}");
        // contended bytes alone also count as interference
        let by_bytes = autoscale_budget(1e8, 0.0, (bw as u64) * 2, 10.0, bw);
        assert!(by_bytes < 1e8, "contended bytes are interference: {by_bytes}");
        // unlimited (0) gets a real budget on the first bad window...
        let capped = autoscale_budget(0.0, 2.0, 0, 10.0, bw);
        assert!(capped > 0.0 && capped <= bw);
        // ...and stays unlimited while quiet
        assert_eq!(autoscale_budget(0.0, 0.0, 0, 10.0, bw), 0.0);
        // clamps: never below the floor, never above 2x bandwidth
        assert!(autoscale_budget(1.5e6, 5.0, 0, 10.0, bw) >= AUTOSCALE_MIN_RATE);
        let mut r = 1e8;
        for _ in 0..100 {
            r = autoscale_budget(r, 0.0, 0, 10.0, bw);
        }
        assert!(r <= 2.0 * bw);
        // degenerate windows change nothing
        assert_eq!(autoscale_budget(1e8, 1.0, 0, 0.0, bw), 1e8);
    }

    #[test]
    fn telemetry_bus_sees_interference() {
        let bus = Arc::new(TelemetryBus::new());
        let gate = Arc::new(IoGate::with_bus(
            IoGateConfig {
                bytes_per_sec: 0.0,
                max_defer: Duration::from_millis(5),
                poll: Duration::from_micros(200),
            },
            Some(Arc::clone(&bus)),
        ));
        let _g = gate.persist_guard();
        gate.throttle(512);
        let s = bus.snapshot();
        assert!(s.deferred_secs > 0.0);
        assert_eq!(s.contended_bytes, 512);
    }
}
