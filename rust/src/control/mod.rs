//! Runtime control plane: closed-loop §V-C tuning + interference-aware
//! I/O scheduling, shared by the flat, cluster, and LowDiff+ runtimes.
//!
//! Before this layer, the §V-C configuration model
//! ([`AdaptiveTuner`](crate::coordinator::config_opt::AdaptiveTuner))
//! was built and property-tested but the training driver ran static
//! `full_every`/`batch_size`/`compact_every`, and cluster compaction
//! executed inline on the commit thread where its reads contended with
//! checkpoint writes. The control plane turns those four static knobs
//! into the paper's *self-tuning* system ("dynamically tunes both the
//! checkpoint frequency and the batching size to maximize performance",
//! §V-C), in three parts:
//!
//! - [`telemetry`] — a lock-light [`TelemetryBus`] fed by the persist
//!   stage, the compactor, the cluster commit thread, the failure path
//!   and the I/O gate, plus the **windowed estimators** that smooth raw
//!   windows into usable MTBF/bandwidth estimates;
//! - [`actuate`] — the closed-loop [`Actuator`]: estimates →
//!   `AdaptiveTuner` → clamped, hysteresis-guarded [`Retune`]s applied
//!   at safe epoch boundaries (driver full epochs, checkpointer queue
//!   order, cluster committed records);
//! - [`iosched`] — the [`IoGate`]/[`GatedStore`] pair that shapes all
//!   background compaction I/O with idle triggering + a token-bucket
//!   byte budget (`--io-budget`), yielding to in-flight checkpoint
//!   persists.
//!
//! PR 8 adds the *observability* half of the control plane:
//!
//! - [`trace`] — a lock-light ring-buffered [`Tracer`] whose spans cover
//!   every pipeline stage (encode, flush, persist, defer, compaction
//!   level, commit phases, replay) and serialize to a
//!   chrome://tracing-compatible trace journal beside the chain;
//! - [`http`] — a std-only threaded mini-HTTP server ([`ObsServer`])
//!   exposing `GET /stats|/metrics|/trace|/chain|/storage|/health` and
//!   `POST /retune|/compact|/scrub`, the mutating verbs routed through
//!   the same safe-point paths the actuator uses.
//!
//! PR 10 deepens the storage plane: `/metrics` grows real Prometheus
//! histograms from the [`Observed`](crate::storage::Observed)
//! middleware's per-tier latency [`LogHistogram`](crate::util::stats::LogHistogram)s,
//! `/storage` tabulates per-tier/per-op/per-family traffic, and
//! `/health` folds heartbeat death, scrub damage
//! ([`Scrubber`](crate::pipeline::Scrubber)), GC leaks and sustained
//! slow I/O into one machine-readable verdict.
//!
//! Wiring, safety points and the scheduler policy are documented in
//! `docs/CONTROL.md`; the observability surface in
//! `docs/OBSERVABILITY.md`.

pub mod actuate;
pub mod http;
pub mod iosched;
pub mod telemetry;
pub mod trace;

pub use actuate::{
    converge_synthetic, replay_bound, Actuator, ActuatorConfig, ControlState, Retune, Window,
    CONTROL_STATE_OBJECT,
};
pub use http::{ControlView, ObsServer, ObsState, ReportGauges};
pub use iosched::{autoscale_budget, GatedStore, IoGate, IoGateConfig, IoGateStats, PersistGuard};
pub use telemetry::{BwEstimator, MtbfEstimator, Snapshot, TelemetryBus};
pub use trace::{Span, StageSummary, TraceEvent, Tracer, TRACE_OBJECT};
