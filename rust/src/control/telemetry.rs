//! Telemetry bus: lock-light runtime counters feeding the §V-C control
//! loop.
//!
//! One [`TelemetryBus`] is shared (via `Arc`) by every component that
//! observes a quantity Eq. (8) models or the I/O scheduler shapes:
//!
//! - the **persist stage** ([`Sink`](crate::pipeline::Sink)) records
//!   durable bytes and device seconds → effective write bandwidth `W`;
//! - the **failure path** ([`FailureInjector`]
//!   (crate::coordinator::failure::FailureInjector) via the driver)
//!   records failure events → measured MTBF `M`;
//! - the **chain compactor** ([`Compactor`](crate::pipeline::Compactor),
//!   cluster scheduler passes) records merged spans vs raws superseded →
//!   the replay-ratio feedback behind `observe_compaction`;
//! - the **cluster commit thread** records phase-2 wall seconds;
//! - the **I/O gate** ([`IoGate`](crate::control::iosched::IoGate))
//!   records deferred background seconds and contended bytes →
//!   read/write interference;
//! - the **driver** records per-step checkpoint stall seconds.
//!
//! Every counter is a monotonic atomic: producers pay one `fetch_add`, no
//! locks, no allocation. Consumers take [`TelemetryBus::snapshot`]s and
//! difference them into windows; the **windowed estimators** below turn
//! windows into smoothed MTBF / bandwidth estimates — the fix for the
//! raw-sample pitfall where one lucky failure-free window (or one quick
//! failure) would let `AdaptiveTuner::observe` overwrite `params.mtbf`
//! with a wild sample and collapse or explode `full_every`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::checkpoint::format::N_CODECS;

const NANOS_PER_SEC: f64 = 1e9;

/// Per-codec achieved-compression counters (indexed by
/// [`PayloadCodec::idx`](crate::checkpoint::format::PayloadCodec::idx)).
/// Probe encodes (the bandit's occasional measurement of the non-chosen
/// codec) are recorded here too — that is the point: the actuator compares
/// *measured* ratios, never assumed ones.
#[derive(Debug, Default)]
struct CodecCounters {
    bytes_in: [AtomicU64; N_CODECS],
    bytes_out: [AtomicU64; N_CODECS],
    encode_nanos: [AtomicU64; N_CODECS],
    probes: AtomicU64,
    switches: AtomicU64,
}

/// Lock-light runtime counters (see module docs for the producers).
#[derive(Debug)]
pub struct TelemetryBus {
    start: Instant,
    failures: AtomicU64,
    steps: AtomicU64,
    stall_nanos: AtomicU64,
    bytes_written: AtomicU64,
    write_nanos: AtomicU64,
    merged_written: AtomicU64,
    raw_compacted: AtomicU64,
    compact_bytes: AtomicU64,
    commit_nanos: AtomicU64,
    deferred_nanos: AtomicU64,
    contended_bytes: AtomicU64,
    codec: CodecCounters,
}

/// One point-in-time reading of every bus counter. Difference two
/// snapshots to get a window.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub elapsed_secs: f64,
    pub failures: u64,
    pub steps: u64,
    pub stall_secs: f64,
    pub bytes_written: u64,
    pub write_secs: f64,
    pub merged_written: u64,
    pub raw_compacted: u64,
    pub compact_bytes: u64,
    pub commit_secs: f64,
    pub deferred_secs: f64,
    pub contended_bytes: u64,
    /// per-codec raw input bytes offered to the encoder
    pub codec_bytes_in: [u64; N_CODECS],
    /// per-codec achieved wire bytes
    pub codec_bytes_out: [u64; N_CODECS],
    /// per-codec encode nanoseconds
    pub codec_encode_ns: [u64; N_CODECS],
    /// bandit probe encodes of the non-chosen codec
    pub codec_probes: u64,
    /// actuator codec switches applied
    pub codec_switches: u64,
}

impl Default for TelemetryBus {
    fn default() -> Self {
        TelemetryBus::new()
    }
}

impl TelemetryBus {
    pub fn new() -> TelemetryBus {
        TelemetryBus {
            start: Instant::now(),
            failures: AtomicU64::new(0),
            steps: AtomicU64::new(0),
            stall_nanos: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
            write_nanos: AtomicU64::new(0),
            merged_written: AtomicU64::new(0),
            raw_compacted: AtomicU64::new(0),
            compact_bytes: AtomicU64::new(0),
            commit_nanos: AtomicU64::new(0),
            deferred_nanos: AtomicU64::new(0),
            contended_bytes: AtomicU64::new(0),
            codec: CodecCounters::default(),
        }
    }

    /// One encode (real or probe) ran codec `idx`
    /// ([`PayloadCodec::idx`](crate::checkpoint::format::PayloadCodec::idx)):
    /// `bytes_in` raw payload became `bytes_out` wire bytes in `encode_ns`.
    pub fn record_codec(&self, idx: usize, bytes_in: u64, bytes_out: u64, encode_ns: u64) {
        self.codec.bytes_in[idx].fetch_add(bytes_in, Ordering::Relaxed);
        self.codec.bytes_out[idx].fetch_add(bytes_out, Ordering::Relaxed);
        self.codec.encode_nanos[idx].fetch_add(encode_ns, Ordering::Relaxed);
    }

    /// One bandit probe (scratch encode of the non-chosen codec) ran.
    pub fn record_codec_probe(&self) {
        self.codec.probes.fetch_add(1, Ordering::Relaxed);
    }

    /// The actuator switched the live diff codec.
    pub fn record_codec_switch(&self) {
        self.codec.switches.fetch_add(1, Ordering::Relaxed);
    }

    /// One failure event (hardware or software) was observed.
    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    /// One productive iteration completed, stalling the training thread
    /// for `stall_secs` on checkpoint work.
    pub fn record_step(&self, stall_secs: f64) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.stall_nanos
            .fetch_add(secs_to_nanos(stall_secs), Ordering::Relaxed);
    }

    /// One checkpoint object became durable. `device_secs` is observed
    /// device time (0 for async engine writes, where the writer only sees
    /// completion, not occupancy) — the bandwidth estimator skips windows
    /// without device time.
    pub fn record_write(&self, bytes: u64, device_secs: f64) {
        self.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.write_nanos
            .fetch_add(secs_to_nanos(device_secs), Ordering::Relaxed);
    }

    /// One compaction pass consolidated `raws` raw chain objects into
    /// `merged` spans, moving `bytes` of storage I/O.
    pub fn record_compaction(&self, merged: u64, raws: u64, bytes: u64) {
        self.merged_written.fetch_add(merged, Ordering::Relaxed);
        self.raw_compacted.fetch_add(raws, Ordering::Relaxed);
        self.compact_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// The cluster commit thread spent `secs` in phase 2.
    pub fn record_commit(&self, secs: f64) {
        self.commit_nanos
            .fetch_add(secs_to_nanos(secs), Ordering::Relaxed);
    }

    /// A background I/O op yielded to in-flight persists for `secs`.
    pub fn record_defer(&self, secs: f64) {
        self.deferred_nanos
            .fetch_add(secs_to_nanos(secs), Ordering::Relaxed);
    }

    /// `bytes` of background I/O proceeded while a persist was in flight
    /// (residual interference the gate could not avoid).
    pub fn record_contention(&self, bytes: u64) {
        self.contended_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            elapsed_secs: self.start.elapsed().as_secs_f64(),
            failures: self.failures.load(Ordering::Relaxed),
            steps: self.steps.load(Ordering::Relaxed),
            stall_secs: nanos_to_secs(self.stall_nanos.load(Ordering::Relaxed)),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            write_secs: nanos_to_secs(self.write_nanos.load(Ordering::Relaxed)),
            merged_written: self.merged_written.load(Ordering::Relaxed),
            raw_compacted: self.raw_compacted.load(Ordering::Relaxed),
            compact_bytes: self.compact_bytes.load(Ordering::Relaxed),
            commit_secs: nanos_to_secs(self.commit_nanos.load(Ordering::Relaxed)),
            deferred_secs: nanos_to_secs(self.deferred_nanos.load(Ordering::Relaxed)),
            contended_bytes: self.contended_bytes.load(Ordering::Relaxed),
            codec_bytes_in: std::array::from_fn(|i| {
                self.codec.bytes_in[i].load(Ordering::Relaxed)
            }),
            codec_bytes_out: std::array::from_fn(|i| {
                self.codec.bytes_out[i].load(Ordering::Relaxed)
            }),
            codec_encode_ns: std::array::from_fn(|i| {
                self.codec.encode_nanos[i].load(Ordering::Relaxed)
            }),
            codec_probes: self.codec.probes.load(Ordering::Relaxed),
            codec_switches: self.codec.switches.load(Ordering::Relaxed),
        }
    }
}

fn secs_to_nanos(secs: f64) -> u64 {
    (secs.max(0.0) * NANOS_PER_SEC) as u64
}

fn nanos_to_secs(nanos: u64) -> f64 {
    nanos as f64 / NANOS_PER_SEC
}

/// Windowed MTBF estimator: exponentially-decayed failure-free time over
/// exponentially-decayed failure count, regularized by a prior
/// pseudo-observation. Telemetry-fed tuning MUST go through this (or an
/// equivalent smoother), never raw inter-failure samples: a raw sample of
/// one lucky failure-free window reads as "MTBF = ∞" and a single quick
/// failure as "MTBF ≈ 0", either of which would let the stepwise tuner
/// walk `full_every` somewhere unrecoverable before reality reasserts
/// itself. Here the estimate is bounded by construction:
/// `(T_w/(1−d) + w·M₀) / w` with no failures, and it moves smoothly as
/// decayed failures accumulate.
#[derive(Clone, Debug)]
pub struct MtbfEstimator {
    decay: f64,
    prior_mtbf: f64,
    prior_weight: f64,
    acc_secs: f64,
    acc_failures: f64,
}

impl MtbfEstimator {
    pub fn new(prior_mtbf: f64, prior_weight: f64, decay: f64) -> MtbfEstimator {
        assert!(prior_mtbf > 0.0 && prior_weight > 0.0);
        assert!((0.0..1.0).contains(&decay));
        MtbfEstimator {
            decay,
            prior_mtbf,
            prior_weight,
            acc_secs: 0.0,
            acc_failures: 0.0,
        }
    }

    /// Fold one observation window (`secs` of wall time, `failures`
    /// events) into the decayed accumulators.
    pub fn observe_window(&mut self, secs: f64, failures: u64) {
        if secs <= 0.0 {
            return;
        }
        self.acc_secs = self.acc_secs * self.decay + secs;
        self.acc_failures = self.acc_failures * self.decay + failures as f64;
    }

    /// Current smoothed MTBF estimate (always finite and positive).
    pub fn estimate(&self) -> f64 {
        (self.acc_secs + self.prior_weight * self.prior_mtbf)
            / (self.acc_failures + self.prior_weight)
    }

    /// The decayed accumulators `(acc_secs, acc_failures)` — everything a
    /// restart needs to warm-start the estimator (the prior/decay knobs
    /// come from config). See `control/actuate.rs::ControlState`.
    pub fn export(&self) -> (f64, f64) {
        (self.acc_secs, self.acc_failures)
    }

    /// Warm-start from persisted accumulators. Non-finite or negative
    /// values are ignored (a damaged sidecar must never poison the
    /// estimate — cold-start priors stay in force instead).
    pub fn restore(&mut self, acc_secs: f64, acc_failures: f64) {
        if acc_secs.is_finite()
            && acc_secs >= 0.0
            && acc_failures.is_finite()
            && acc_failures >= 0.0
        {
            self.acc_secs = acc_secs;
            self.acc_failures = acc_failures;
        }
    }
}

/// EWMA write-bandwidth estimator; windows without observed device time
/// (async engine completions) are skipped rather than read as zero.
#[derive(Clone, Debug)]
pub struct BwEstimator {
    decay: f64,
    est: f64,
}

impl BwEstimator {
    pub fn new(prior_bw: f64, decay: f64) -> BwEstimator {
        assert!(prior_bw > 0.0);
        assert!((0.0..1.0).contains(&decay));
        BwEstimator { decay, est: prior_bw }
    }

    pub fn observe_window(&mut self, bytes: u64, device_secs: f64) {
        if bytes == 0 || device_secs <= 1e-9 {
            return;
        }
        let w = bytes as f64 / device_secs;
        self.est = self.decay * self.est + (1.0 - self.decay) * w;
    }

    pub fn estimate(&self) -> f64 {
        self.est
    }

    /// The smoothed estimate, for cross-run persistence.
    pub fn export(&self) -> f64 {
        self.est
    }

    /// Warm-start from a persisted estimate; non-finite or non-positive
    /// values are ignored (the configured prior stays).
    pub fn restore(&mut self, est: f64) {
        if est.is_finite() && est > 0.0 {
            self.est = est;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_recorded_counters() {
        let bus = TelemetryBus::new();
        bus.record_failure();
        bus.record_step(0.5);
        bus.record_step(0.25);
        bus.record_write(1000, 0.1);
        bus.record_compaction(2, 8, 4096);
        bus.record_commit(0.02);
        bus.record_defer(0.01);
        bus.record_contention(77);
        bus.record_codec(1, 100, 40, 500);
        bus.record_codec(2, 100, 20, 300);
        bus.record_codec_probe();
        bus.record_codec_switch();
        let s = bus.snapshot();
        assert_eq!(s.codec_bytes_in[1], 100);
        assert_eq!(s.codec_bytes_out[2], 20);
        assert_eq!(s.codec_encode_ns[1], 500);
        assert_eq!((s.codec_probes, s.codec_switches), (1, 1));
        assert_eq!(s.failures, 1);
        assert_eq!(s.steps, 2);
        assert!((s.stall_secs - 0.75).abs() < 1e-6);
        assert_eq!(s.bytes_written, 1000);
        assert!((s.write_secs - 0.1).abs() < 1e-6);
        assert_eq!((s.merged_written, s.raw_compacted, s.compact_bytes), (2, 8, 4096));
        assert!((s.commit_secs - 0.02).abs() < 1e-6);
        assert!((s.deferred_secs - 0.01).abs() < 1e-6);
        assert_eq!(s.contended_bytes, 77);
        assert!(s.elapsed_secs >= 0.0);
    }

    #[test]
    fn mtbf_estimator_starts_at_prior_and_tracks_failures() {
        let mut e = MtbfEstimator::new(1000.0, 0.25, 0.98);
        assert_eq!(e.estimate(), 1000.0);
        // failures every 100 s pull the estimate toward 100
        for _ in 0..200 {
            e.observe_window(100.0, 1);
        }
        let m = e.estimate();
        assert!((90.0..200.0).contains(&m), "estimate {m} should approach 100");
    }

    #[test]
    fn single_failure_free_window_cannot_explode_the_estimate() {
        // the raw-sample pitfall: a quiet window would read as MTBF = ∞;
        // the smoothed estimate moves boundedly
        let mut e = MtbfEstimator::new(100.0, 1.0, 0.8);
        for _ in 0..50 {
            e.observe_window(100.0, 1); // converged near 100
        }
        let before = e.estimate();
        e.observe_window(100.0, 0); // one lucky window
        let after = e.estimate();
        assert!(after > before, "quiet window should raise the estimate");
        assert!(
            after < before * 2.0,
            "one window must not explode the estimate: {before} -> {after}"
        );
        // and a single quick failure can't collapse it either
        e.observe_window(1.0, 1);
        assert!(e.estimate() > before / 2.0);
    }

    #[test]
    fn mtbf_estimate_monotone_in_observed_quiet_time() {
        let mut a = MtbfEstimator::new(500.0, 1.0, 0.9);
        let mut b = a.clone();
        a.observe_window(10.0, 0);
        b.observe_window(100.0, 0);
        assert!(b.estimate() > a.estimate());
        // more failures in the same window => lower estimate
        let mut c = MtbfEstimator::new(500.0, 1.0, 0.9);
        let mut d = c.clone();
        c.observe_window(100.0, 1);
        d.observe_window(100.0, 4);
        assert!(d.estimate() < c.estimate());
    }

    #[test]
    fn estimator_state_roundtrips_and_rejects_garbage() {
        let mut e = MtbfEstimator::new(1000.0, 0.25, 0.98);
        for _ in 0..20 {
            e.observe_window(100.0, 1);
        }
        let (s, f) = e.export();
        let mut fresh = MtbfEstimator::new(1000.0, 0.25, 0.98);
        fresh.restore(s, f);
        assert_eq!(fresh.estimate(), e.estimate(), "warm start reproduces the estimate");
        fresh.restore(f64::NAN, 1.0);
        fresh.restore(-1.0, 0.0);
        assert_eq!(fresh.estimate(), e.estimate(), "garbage state is ignored");
        let mut b = BwEstimator::new(1e9, 0.5);
        b.observe_window(250_000_000, 1.0);
        let mut b2 = BwEstimator::new(1e9, 0.5);
        b2.restore(b.export());
        assert_eq!(b2.estimate(), b.estimate());
        b2.restore(-5.0);
        b2.restore(f64::INFINITY);
        assert_eq!(b2.estimate(), b.estimate(), "garbage estimate is ignored");
    }

    #[test]
    fn bw_estimator_skips_empty_windows_and_converges() {
        let mut e = BwEstimator::new(1e9, 0.5);
        e.observe_window(0, 1.0);
        e.observe_window(100, 0.0);
        assert_eq!(e.estimate(), 1e9, "empty windows are skipped");
        for _ in 0..40 {
            e.observe_window(250_000_000, 1.0);
        }
        let w = e.estimate();
        assert!((2.4e8..2.6e8).contains(&w), "estimate {w} should approach 250 MB/s");
    }
}
