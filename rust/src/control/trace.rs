//! Lock-light pipeline event tracing.
//!
//! Every pipeline stage — encode, batch flush, persist submit/complete,
//! I/O-gate defer, compaction pass per level, phase-1 ack, phase-2
//! commit, recovery replay, heartbeat detection — records spans/events
//! into a bounded ring buffer owned by one [`Tracer`] per run. Producers
//! pay one short `Mutex` critical section per *checkpoint-scale*
//! operation (never per tensor element), so tracing is safe to leave on
//! in production runs.
//!
//! Three consumers read the ring:
//! - `GET /trace` ([`crate::control::http`]) serves the recent events
//!   live;
//! - the driver persists the ring as a chrome://tracing-compatible JSONL
//!   journal beside the chain ([`TRACE_OBJECT`],
//!   [`Tracer::to_chrome_jsonl`]) — flat GC, cluster GC and
//!   `truncate_after` all skip names they cannot parse, so the journal
//!   survives every collection path;
//! - [`Tracer::summary`] folds per-stage totals (count, wall, bytes)
//!   into the end-of-run `RunReport`.
//!
//! Span identity: `id` is a process-wide monotone counter, `tid` is the
//! producer's lane (rank number for cluster stages, 0 for the flat
//! pipeline), timestamps are microseconds since the tracer was created.
//! When the ring is full the OLDEST events are dropped (and counted) —
//! the journal is a tail, the summary is exact.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::JsonObject;

/// Storage object name of the persisted trace journal. Deliberately
/// outside every `Manifest` name family so no GC/truncate path can
/// collect it.
pub const TRACE_OBJECT: &str = "trace-journal.jsonl";

/// Default ring capacity (events retained for `/trace` and the journal).
pub const DEFAULT_TRACE_CAP: usize = 4096;

/// One recorded span (`dur_micros > 0` or a timed wait) or instant event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// process-wide monotone event id (assigned at record time)
    pub id: u64,
    /// stage name (static so recording never allocates for it)
    pub name: &'static str,
    /// producer lane: rank number for cluster stages, 0 otherwise
    pub tid: u64,
    /// span start, microseconds since the tracer's epoch
    pub ts_micros: u64,
    /// span duration in microseconds (0 for instants)
    pub dur_micros: u64,
    /// training step the operation belongs to (0 when not applicable)
    pub step: u64,
    /// payload bytes moved by the operation (0 when not applicable)
    pub bytes: u64,
    /// stage-specific counter: compaction level, commit seq, ...
    pub extra: u64,
    /// true for instantaneous events (`ph:"i"` in the chrome format)
    pub instant: bool,
}

impl TraceEvent {
    /// One chrome://tracing "Trace Event Format" JSON object.
    pub fn to_chrome_json(&self) -> String {
        let mut args = JsonObject::new();
        args.u64("id", self.id).u64("step", self.step).u64("bytes", self.bytes).u64(
            "extra",
            self.extra,
        );
        let mut o = JsonObject::new();
        o.str("name", self.name)
            .str("cat", "lowdiff")
            .str("ph", if self.instant { "i" } else { "X" })
            .u64("pid", 0)
            .u64("tid", self.tid)
            .u64("ts", self.ts_micros);
        if self.instant {
            o.str("s", "g");
        } else {
            o.u64("dur", self.dur_micros);
        }
        o.raw("args", &args.finish());
        o.finish()
    }
}

/// Per-stage aggregate, exact over the whole run (never ring-bounded).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageSummary {
    pub name: &'static str,
    pub count: u64,
    pub total_micros: u64,
    pub bytes: u64,
}

/// The ring-buffer span/event recorder. Share one per run via `Arc`.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    cap: usize,
    ring: Mutex<VecDeque<TraceEvent>>,
    agg: Mutex<BTreeMap<&'static str, StageSummary>>,
    next_id: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
    /// events excluded from the MOST RECENT capped journal write
    /// ([`Tracer::to_chrome_jsonl_capped`]) — a gauge, not cumulative:
    /// the journal is rewritten wholesale at every control tick, so
    /// re-dropping the same old events each tick must not double-count
    journal_dropped: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(DEFAULT_TRACE_CAP)
    }
}

impl Tracer {
    pub fn new(capacity: usize) -> Tracer {
        let cap = capacity.max(16);
        Tracer {
            epoch: Instant::now(),
            cap,
            ring: Mutex::new(VecDeque::with_capacity(cap)),
            agg: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            journal_dropped: AtomicU64::new(0),
        }
    }

    /// Open a span; it records itself on drop. Decorate with the builder
    /// setters at creation and the `set_*` setters once values are known.
    pub fn span(self: &Arc<Self>, name: &'static str) -> Span {
        Span {
            tracer: Arc::clone(self),
            name,
            t0: Instant::now(),
            tid: 0,
            step: 0,
            bytes: 0,
            extra: 0,
        }
    }

    /// `span` over an optional tracer — the plumbing-friendly form every
    /// instrumented stage uses (`trace` config fields are `Option`al).
    pub fn maybe_span(t: &Option<Arc<Tracer>>, name: &'static str) -> Option<Span> {
        t.as_ref().map(|t| t.span(name))
    }

    /// Record a completed operation observed externally (no RAII guard —
    /// the I/O gate's defer waits use this).
    pub fn complete(
        &self,
        name: &'static str,
        dur_secs: f64,
        tid: u64,
        step: u64,
        bytes: u64,
        extra: u64,
    ) {
        let dur_micros = (dur_secs.max(0.0) * 1e6) as u64;
        let now = self.epoch.elapsed().as_micros() as u64;
        self.record(TraceEvent {
            id: 0,
            name,
            tid,
            ts_micros: now.saturating_sub(dur_micros),
            dur_micros,
            step,
            bytes,
            extra,
            instant: false,
        });
    }

    /// Record an instantaneous event (phase-1 ack, failure detection...).
    pub fn instant(&self, name: &'static str, tid: u64, step: u64, extra: u64) {
        self.record(TraceEvent {
            id: 0,
            name,
            tid,
            ts_micros: self.epoch.elapsed().as_micros() as u64,
            dur_micros: 0,
            step,
            bytes: 0,
            extra,
            instant: true,
        });
    }

    fn record(&self, mut ev: TraceEvent) {
        ev.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.recorded.fetch_add(1, Ordering::Relaxed);
        {
            let mut ring = self.ring.lock().unwrap();
            if ring.len() >= self.cap {
                ring.pop_front();
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            ring.push_back(ev);
        }
        let mut agg = self.agg.lock().unwrap();
        let e = agg.entry(ev.name).or_insert(StageSummary { name: ev.name, ..Default::default() });
        e.count += 1;
        e.total_micros += ev.dur_micros;
        e.bytes += ev.bytes;
    }

    /// The newest `n` events, oldest first.
    pub fn recent(&self, n: usize) -> Vec<TraceEvent> {
        let ring = self.ring.lock().unwrap();
        let skip = ring.len().saturating_sub(n);
        ring.iter().skip(skip).copied().collect()
    }

    /// `(recorded, dropped)` totals — `recorded - dropped` events remain
    /// in the ring (capped at the capacity).
    pub fn counts(&self) -> (u64, u64) {
        (self.recorded.load(Ordering::Relaxed), self.dropped.load(Ordering::Relaxed))
    }

    /// Exact per-stage totals over the whole run, sorted by stage name.
    /// When the byte-capped journal writer truncated events, a synthetic
    /// `journal.dropped` row carries how many the latest journal lost.
    pub fn summary(&self) -> Vec<StageSummary> {
        let mut out: Vec<StageSummary> = self.agg.lock().unwrap().values().copied().collect();
        let jd = self.journal_dropped();
        if jd > 0 {
            out.push(StageSummary { name: "journal.dropped", count: jd, ..Default::default() });
        }
        out
    }

    /// Ring events that did not fit the byte budget on the most recent
    /// [`Tracer::to_chrome_jsonl_capped`] call.
    pub fn journal_dropped(&self) -> u64 {
        self.journal_dropped.load(Ordering::Relaxed)
    }

    /// The retained ring as chrome://tracing JSONL (one event per line —
    /// wrap in `[...]` or load the file directly in a viewer that accepts
    /// newline-delimited events).
    pub fn to_chrome_jsonl(&self) -> String {
        let events = self.recent(usize::MAX);
        let mut out = String::with_capacity(events.len() * 128);
        for ev in events {
            out.push_str(&ev.to_chrome_json());
            out.push('\n');
        }
        out
    }

    /// [`Tracer::to_chrome_jsonl`] under a byte budget
    /// (`--trace-journal-max-kb`): the NEWEST events that fit are kept,
    /// older ones are truncated away — the persisted journal stays a
    /// bounded tail instead of growing with run length. The number
    /// truncated is published via [`Tracer::journal_dropped`] (and as a
    /// `journal.dropped` summary row).
    pub fn to_chrome_jsonl_capped(&self, max_bytes: usize) -> String {
        let events = self.recent(usize::MAX);
        let mut lines: Vec<String> = Vec::new();
        let mut total = 0usize;
        for ev in events.iter().rev() {
            let line = ev.to_chrome_json();
            if total + line.len() + 1 > max_bytes {
                break;
            }
            total += line.len() + 1;
            lines.push(line);
        }
        self.journal_dropped
            .store((events.len() - lines.len()) as u64, Ordering::Relaxed);
        let mut out = String::with_capacity(total);
        for line in lines.iter().rev() {
            out.push_str(line);
            out.push('\n');
        }
        out
    }
}

/// RAII span guard; records into its tracer on drop.
pub struct Span {
    tracer: Arc<Tracer>,
    name: &'static str,
    t0: Instant,
    tid: u64,
    step: u64,
    bytes: u64,
    extra: u64,
}

impl Span {
    pub fn tid(mut self, tid: u64) -> Span {
        self.tid = tid;
        self
    }

    pub fn step(mut self, step: u64) -> Span {
        self.step = step;
        self
    }

    pub fn bytes(mut self, bytes: u64) -> Span {
        self.bytes = bytes;
        self
    }

    pub fn extra(mut self, extra: u64) -> Span {
        self.extra = extra;
        self
    }

    /// Set the payload size once known (encode output, read length...).
    pub fn set_bytes(&mut self, bytes: u64) {
        self.bytes = bytes;
    }

    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    pub fn set_extra(&mut self, extra: u64) {
        self.extra = extra;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur = self.t0.elapsed();
        let ts = self
            .t0
            .saturating_duration_since(self.tracer.epoch)
            .as_micros() as u64;
        self.tracer.record(TraceEvent {
            id: 0,
            name: self.name,
            tid: self.tid,
            ts_micros: ts,
            dur_micros: dur.as_micros() as u64,
            step: self.step,
            bytes: self.bytes,
            extra: self.extra,
            instant: false,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_on_drop_with_payload() {
        let t = Arc::new(Tracer::new(64));
        {
            let mut sp = t.span("encode").tid(3).step(7);
            sp.set_bytes(512);
        }
        t.instant("ack", 1, 7, 42);
        let evs = t.recent(10);
        assert_eq!(evs.len(), 2);
        assert_eq!((evs[0].name, evs[0].tid, evs[0].step, evs[0].bytes), ("encode", 3, 7, 512));
        assert!(!evs[0].instant);
        assert_eq!((evs[1].name, evs[1].extra, evs[1].instant), ("ack", 42, true));
        assert!(evs[1].id > evs[0].id, "ids are monotone");
        assert_eq!(t.counts(), (2, 0));
    }

    #[test]
    fn ring_drops_oldest_and_counts_drops() {
        let t = Arc::new(Tracer::new(16));
        for i in 0..40u64 {
            t.instant("e", 0, i, 0);
        }
        let (recorded, dropped) = t.counts();
        assert_eq!(recorded, 40);
        assert_eq!(dropped, 24);
        let evs = t.recent(100);
        assert_eq!(evs.len(), 16);
        assert_eq!(evs.first().unwrap().step, 24, "oldest events dropped first");
        assert_eq!(evs.last().unwrap().step, 39);
        // the summary is exact even though the ring is bounded
        let s = t.summary();
        assert_eq!(s.len(), 1);
        assert_eq!((s[0].name, s[0].count), ("e", 40));
    }

    #[test]
    fn summary_aggregates_per_stage() {
        let t = Arc::new(Tracer::new(64));
        t.complete("persist", 0.001, 0, 1, 100, 0);
        t.complete("persist", 0.002, 0, 2, 200, 0);
        t.complete("encode", 0.0, 0, 1, 50, 0);
        let s = t.summary();
        assert_eq!(s.len(), 2);
        let persist = s.iter().find(|x| x.name == "persist").unwrap();
        assert_eq!(persist.count, 2);
        assert_eq!(persist.bytes, 300);
        assert!(persist.total_micros >= 2900, "{}", persist.total_micros);
    }

    #[test]
    fn chrome_jsonl_is_one_valid_object_per_line() {
        let t = Arc::new(Tracer::new(64));
        t.complete("flush \"q\"", 0.001, 2, 9, 64, 1);
        t.instant("detect", 1, 0, 3);
        let out = t.to_chrome_jsonl();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with('{') && lines[0].ends_with('}'));
        assert!(lines[0].contains("\"ph\":\"X\"") && lines[0].contains("\"dur\":"));
        assert!(lines[0].contains("flush \\\"q\\\""), "names are escaped: {}", lines[0]);
        assert!(lines[1].contains("\"ph\":\"i\"") && lines[1].contains("\"s\":\"g\""));
        assert!(lines[1].contains("\"extra\":3"));
    }

    #[test]
    fn capped_journal_keeps_the_newest_tail_and_counts_drops() {
        let t = Arc::new(Tracer::new(256));
        for i in 0..100u64 {
            t.instant("e", 0, i, 0);
        }
        let full = t.to_chrome_jsonl();
        let capped = t.to_chrome_jsonl_capped(full.len() / 2);
        assert!(capped.len() <= full.len() / 2);
        let lines: Vec<&str> = capped.lines().collect();
        assert!(!lines.is_empty() && lines.len() < 100);
        assert!(lines.last().unwrap().contains("\"step\":99"), "newest event kept");
        assert_eq!(t.journal_dropped(), (100 - lines.len()) as u64);
        assert!(
            t.summary().iter().any(|s| s.name == "journal.dropped" && s.count > 0),
            "drops surface in the summary"
        );
        // an uncapped-size budget drops nothing and resets the gauge
        let all = t.to_chrome_jsonl_capped(usize::MAX);
        assert_eq!(all, full);
        assert_eq!(t.journal_dropped(), 0);
    }

    #[test]
    fn maybe_span_is_a_no_op_without_a_tracer() {
        assert!(Tracer::maybe_span(&None, "x").is_none());
        let t = Some(Arc::new(Tracer::new(16)));
        drop(Tracer::maybe_span(&t, "x"));
        assert_eq!(t.unwrap().counts().0, 1);
    }
}
