//! The checkpointing process (paper Fig. 5, Alg. 1 lines 9-12).
//!
//! A dedicated thread consuming the [`ReusingQueue`]:
//! - **Diff items** (reused compressed gradients): "offloaded" (compacted
//!   to the k-sparse wire form — the GPU→CPU offload of Fig. 6 step ①),
//!   buffered in the CPU [`BatchBuffer`] (step ②), and persisted as one
//!   batched write when full (step ③).
//! - **Full items** (model-state snapshots): pending diffs are flushed
//!   first (they belong to the pre-full chain), then the 3Ψ state is
//!   encoded and written; obsolete objects are GC'd.
//!
//! All storage I/O happens on this thread *or* — with `n_shards > 1` or
//! `writers > 1` in [`CkptConfig`] — on the sharded engine's writer pool:
//! the checkpointer then only encodes and enqueues, reaping completions
//! asynchronously and draining the pool before GC and shutdown (GC must
//! never run while the full checkpoint it keys on is still in flight).
//! The training thread's only costs stay the O(1) queue put and the
//! snapshot copy.
//!
//! The snapshot→encode→persist stages are the shared pipeline layer
//! ([`crate::pipeline`]): an [`Encoder`] does pooled single-pass
//! container encoding (sparse payloads serialize straight into container
//! bytes, `Sum` batches accumulate in place at offer time), a [`Sink`]
//! persists (direct or sharded-async, slicing the pooled buffer
//! zero-copy), and `CkptStats { bytes_copied, pool_hits, pool_misses }`
//! keep the copy discipline observable; see docs/STORAGE.md
//! ("Write-path anatomy") and docs/PIPELINE.md (stage model).
//!
//! With `compact_every >= 2` a background [`Compactor`] additionally
//! merges every run of that many persisted raw diff objects into one
//! `MergedDiff` span, bounding recovery replay at `⌈n/merge_factor⌉`
//! objects per chain (docs/PIPELINE.md, "Chain compaction").

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::checkpoint::batched::{BatchBuffer, BatchMode};
use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::format::{PayloadCodec, DEFAULT_ZSTD_LEVEL};
use crate::checkpoint::manifest::Manifest;
use crate::control::iosched::{IoGate, IoGateConfig};
use crate::control::telemetry::TelemetryBus;
use crate::control::Tracer;
use crate::coordinator::reusing_queue::ReusingQueue;
use crate::optim::ModelState;
use crate::pipeline::{Compactor, CompactorConfig, Encoded, Encoder, Sink, DEFAULT_MAX_LEVEL};
use crate::sparse::SparseGrad;
use crate::storage::{Sharded, StorageBackend};
use crate::tensor::Flat;

pub use crate::pipeline::CkptStats;

/// What travels through the reusing queue to the checkpointing process.
pub enum CkptItem {
    /// dense-masked compressed gradient (LowDiff reuse path)
    DiffDense(Flat),
    /// pre-compacted sparse payload (Naive DC's state deltas)
    DiffSparse(DiffPayload),
    /// full model-state snapshot
    Full(ModelState),
    /// §V-C actuation (control plane): apply a new batching size,
    /// compaction merge factor, and (optionally) diff codec. Travels
    /// through the queue so it lands at a deterministic point in the
    /// checkpoint stream — after every preceding diff, with the pending
    /// batch flushed first — and can never tear a half-built batch
    /// container (or switch codecs mid-container).
    Retune { batch_size: usize, compact_every: usize, codec: Option<PayloadCodec> },
}

/// Handle to the running checkpointing process.
pub struct Checkpointer {
    pub queue: Arc<ReusingQueue<CkptItem>>,
    stats: Arc<Mutex<CkptStats>>,
    handle: Option<JoinHandle<()>>,
}

/// Configuration of the checkpointing process.
#[derive(Clone)]
pub struct CkptConfig {
    pub model_sig: u64,
    pub batch_size: usize,
    pub batch_mode: BatchMode,
    pub codec: PayloadCodec,
    /// zstd compression level used wherever the Zstd codec encodes
    /// (`--zstd-level`; default 1 — the paper's latency-first choice)
    pub zstd_level: i32,
    /// encode fulls as XOR-deltas against the previous plain full
    /// (flat LowDiff only; re-anchors every
    /// [`DELTA_REBASE_EVERY`](crate::pipeline::encode::DELTA_REBASE_EVERY)
    /// fulls)
    pub delta_fulls: bool,
    pub queue_capacity: usize,
    /// run GC after each full checkpoint
    pub gc: bool,
    /// shards per checkpoint object; >1 (or `writers` > 1) routes writes
    /// through the sharded async engine ([`Sharded`])
    pub n_shards: usize,
    /// storage writer-pool threads for the sharded engine
    pub writers: usize,
    /// background chain compaction: merge every run of this many persisted
    /// raw diff objects into one `MergedDiff` span; < 2 disables
    pub compact_every: usize,
    /// background-I/O byte budget for the compactor's token-bucket gate
    /// (`--io-budget`); <= 0 leaves the bucket open (idle triggering
    /// still applies whenever the control plane is active)
    pub io_budget: f64,
    /// control-plane telemetry bus: persists and compaction passes feed
    /// it, and its presence keeps a (possibly idle) compactor thread
    /// alive so `CkptItem::Retune` can enable compaction later
    pub telemetry: Option<Arc<TelemetryBus>>,
    /// caller-provided I/O gate: when set it is used instead of building
    /// a private one, so a driver's live `set_rate` retunes (autoscaled
    /// `--io-budget`) reach this write path's token bucket too
    pub gate: Option<Arc<IoGate>>,
    /// event tracer: encode/batch-flush/persist/compaction stages record
    /// spans into the shared ring buffer when set
    pub trace: Option<Arc<Tracer>>,
}

impl Default for CkptConfig {
    fn default() -> Self {
        CkptConfig {
            model_sig: 0,
            batch_size: 1,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            zstd_level: DEFAULT_ZSTD_LEVEL,
            delta_fulls: false,
            queue_capacity: 8,
            gc: true,
            n_shards: 1,
            writers: 1,
            compact_every: 0,
            io_budget: 0.0,
            telemetry: None,
            gate: None,
            trace: None,
        }
    }
}

impl CkptConfig {
    /// True when writes go through the sharded async engine instead of
    /// synchronous single-object puts.
    pub fn uses_engine(&self) -> bool {
        self.n_shards > 1 || self.writers > 1
    }

    /// True when the runtime control plane is attached (telemetry and the
    /// I/O gate come alive; the compactor thread spawns even at
    /// `compact_every < 2`, idle, so actuation can enable it live).
    pub fn uses_control(&self) -> bool {
        self.telemetry.is_some() || self.io_budget > 0.0
    }

    /// Max logical writes allowed in flight before the checkpointer blocks
    /// (engine-mode backpressure). The encode-buffer pool is sized from
    /// this too, so steady-state checkouts always find a recycled buffer.
    pub fn inflight_cap(&self) -> usize {
        (self.writers * 4).max(8)
    }
}

impl Checkpointer {
    /// Spawn the checkpointing thread over `store`.
    pub fn spawn(store: Arc<dyn StorageBackend>, cfg: CkptConfig) -> Checkpointer {
        let queue: Arc<ReusingQueue<CkptItem>> = ReusingQueue::new(cfg.queue_capacity);
        let stats = Arc::new(Mutex::new(CkptStats::default()));
        let q = Arc::clone(&queue);
        let st = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("ckpt".into())
            .spawn(move || run_loop(q, store, cfg, st))
            .expect("spawning checkpointer");
        Checkpointer { queue, stats, handle: Some(handle) }
    }

    pub fn stats(&self) -> CkptStats {
        self.stats.lock().unwrap().clone()
    }

    /// Close the queue and wait for all pending work to be persisted.
    pub fn finish(mut self) -> CkptStats {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The checkpointer's composition of the shared pipeline stages: encode
/// (pooled), persist (direct or sharded-async), and the optional
/// background chain compactor.
struct WritePath {
    enc: Encoder,
    sink: Sink,
    compactor: Option<Compactor>,
    trace: Option<Arc<Tracer>>,
}

impl WritePath {
    fn new(store: &Arc<dyn StorageBackend>, cfg: &CkptConfig) -> WritePath {
        // one encode buffer per possible in-flight write, plus slack for
        // the one being filled: steady state checks out recycled buffers.
        // Codec probing only runs with the control plane attached — the
        // scratch encodes exist to feed the actuator's bandit policy.
        let enc = Encoder::new(cfg.model_sig, cfg.codec, cfg.inflight_cap() + 2)
            .with_zstd_level(cfg.zstd_level)
            .with_bus(cfg.telemetry.clone())
            .with_delta_fulls(cfg.delta_fulls)
            .with_probing(cfg.uses_control());
        // the control plane: one gate shared by the persist path (guards)
        // and the compactor (shaped reads/writes). Built whenever a
        // compactor will exist — shaping is free when nothing contends.
        let with_compactor = cfg.compact_every >= 2 || cfg.uses_control();
        let gate = cfg.gate.clone().or_else(|| {
            with_compactor.then(|| {
                Arc::new(IoGate::with_obs(
                    IoGateConfig { bytes_per_sec: cfg.io_budget, ..IoGateConfig::default() },
                    cfg.telemetry.clone(),
                    cfg.trace.clone(),
                ))
            })
        });
        let sink = Sink::new(Arc::clone(store), cfg.n_shards, cfg.writers, cfg.inflight_cap())
            .with_control(gate.clone(), cfg.telemetry.clone())
            .with_trace(cfg.trace.clone());
        let compactor = with_compactor.then(|| {
            // the compactor reads/writes LOGICAL objects on its own thread;
            // in engine mode it gets its own 1-shard view of the store
            let logical: Arc<dyn StorageBackend> = if cfg.uses_engine() {
                Arc::new(Sharded::new(Arc::clone(store), 1, 1))
            } else {
                Arc::clone(store)
            };
            Compactor::spawn_obs(
                logical,
                CompactorConfig {
                    model_sig: cfg.model_sig,
                    codec: cfg.codec,
                    merge_factor: cfg.compact_every,
                    // engine mode commits writes out of order: the newest
                    // `inflight_cap` objects may sit beyond an invisible
                    // in-flight write, so live passes must not touch them
                    // (the shutdown pass, post-barrier, settles everything)
                    settle_tail: if cfg.uses_engine() { cfg.inflight_cap() } else { 0 },
                    max_level: DEFAULT_MAX_LEVEL,
                },
                gate,
                cfg.telemetry.clone(),
                cfg.trace.clone(),
            )
        });
        WritePath { enc, sink, compactor, trace: cfg.trace.clone() }
    }

    /// Persist one diff-chain object and wake the compactor.
    fn submit_chain_object(&mut self, obj: Encoded, stats: &Mutex<CkptStats>) {
        self.sink.submit(obj, stats);
        if let Some(c) = &self.compactor {
            c.notify();
        }
    }
}

fn run_loop(
    queue: Arc<ReusingQueue<CkptItem>>,
    store: Arc<dyn StorageBackend>,
    cfg: CkptConfig,
    stats: Arc<Mutex<CkptStats>>,
) {
    let mut batch = BatchBuffer::new(cfg.batch_mode, cfg.batch_size);
    let mut wp = WritePath::new(&store, &cfg);

    while let Some(entry) = queue.get() {
        let step = entry.step;
        // the queue hands us the sole surviving Arc once training has moved
        // on; unwrap-or-clone keeps zero-copy in the common case
        let item = Arc::try_unwrap(entry.payload).unwrap_or_else(|_| {
            // training still holds a reference (it shouldn't for Full);
            // fall back to reading through the Arc
            panic!("checkpointer requires exclusive payload ownership")
        });
        match item {
            CkptItem::DiffDense(dense) => {
                let t0 = Instant::now();
                let sparse = wp.enc.compact(&dense); // offload stage
                drop(dense);
                {
                    let mut s = stats.lock().unwrap();
                    s.offload_secs += t0.elapsed().as_secs_f64();
                    s.diff_ckpts += 1;
                }
                handle_sparse(step, sparse, &mut batch, &stats, &mut wp);
            }
            CkptItem::DiffSparse(payload) => {
                stats.lock().unwrap().diff_ckpts += 1;
                match payload {
                    DiffPayload::Gradient(g) => {
                        handle_sparse(step, g, &mut batch, &stats, &mut wp)
                    }
                    delta @ DiffPayload::StateDelta(_) => {
                        // Naive DC writes every delta unbatched (its cost)
                        match wp.enc.encode_diff(step, &delta) {
                            Ok(obj) => wp.submit_chain_object(obj, &stats),
                            Err(e) => log::error!("encode diff {step}: {e:#}"),
                        }
                    }
                }
            }
            CkptItem::Retune { batch_size, compact_every, codec } => {
                // §V-C actuation safe point: the pending batch flushes
                // under the OLD size and codec (its steps were offered
                // under them), then the new config applies to everything
                // after
                flush_batch(&mut batch, &stats, &mut wp);
                batch.set_batch_size(batch_size);
                if let Some(c) = &wp.compactor {
                    c.set_merge_factor(compact_every);
                }
                if let Some(codec) = codec {
                    wp.enc.set_codec(codec);
                }
                log::debug!(
                    "retune applied: batch_size={batch_size} compact_every={compact_every} codec={:?}",
                    codec
                );
            }
            CkptItem::Full(state) => {
                // flush the pre-full chain first (order matters for GC)
                flush_batch(&mut batch, &stats, &mut wp);
                let t0 = Instant::now();
                match wp.enc.encode_full(&state) {
                    Ok(obj) => {
                        if let Some(t) = &wp.trace {
                            let secs = t0.elapsed().as_secs_f64();
                            t.complete("encode", secs, 0, step, obj.buf.len() as u64, 0);
                        }
                        wp.sink.submit(obj, &stats);
                        stats.lock().unwrap().full_ckpts += 1;
                        if cfg.gc {
                            // GC keys on the newest durable full: drain the
                            // pool so it never deletes the chain a not-yet-
                            // committed full is supposed to supersede
                            wp.sink.barrier(&stats);
                            if let Err(e) = Manifest::gc(wp.sink.view()) {
                                log::warn!("gc failed: {e:#}");
                            }
                        }
                    }
                    Err(e) => log::error!("encode full {step}: {e:#}"),
                }
            }
        }
    }
    // drain the final partial batch on close
    flush_batch(&mut batch, &stats, &mut wp);
    // shutdown barrier: every enqueued write must commit (or report) before
    // `finish()` returns to the caller
    wp.sink.barrier(&stats);
    {
        let mut s = stats.lock().unwrap();
        s.pool_hits = wp.enc.pool_hits();
        s.pool_misses = wp.enc.pool_misses();
        let cs = wp.enc.codec_stats();
        s.codec_bytes_in = cs.bytes_in;
        s.codec_bytes_out = cs.bytes_out;
        s.codec_encode_ns = cs.encode_ns;
        s.codec_probes = cs.probes;
        s.codec_switches = cs.switches;
    }
    // the compactor's shutdown pass runs after the barrier, so it sees
    // every durable object and leaves the chain fully compacted
    if let Some(c) = wp.compactor.take() {
        let cst = c.finish();
        let mut s = stats.lock().unwrap();
        s.merged_written += cst.merged_written;
        s.raw_compacted += cst.raw_compacted;
        s.spans_compacted += cst.spans_compacted;
        s.max_level = s.max_level.max(cst.max_level);
    }
    wp.sink.finish(&stats);
}

/// Drain the batch buffer into a pooled buffer in one encoding pass and
/// submit it. No-op when the batch is empty.
fn flush_batch(batch: &mut BatchBuffer, stats: &Arc<Mutex<CkptStats>>, wp: &mut WritePath) {
    let t0 = Instant::now();
    match wp.enc.encode_batch(batch) {
        Ok(Some(obj)) => {
            if let Some(t) = &wp.trace {
                let secs = t0.elapsed().as_secs_f64();
                t.complete("batch.flush", secs, 0, 0, obj.buf.len() as u64, 0);
            }
            wp.submit_chain_object(obj, stats);
        }
        Ok(None) => {}
        Err(e) => log::error!("encode batch: {e:#}"),
    }
}

fn handle_sparse(
    step: u64,
    sparse: SparseGrad,
    batch: &mut BatchBuffer,
    stats: &Arc<Mutex<CkptStats>>,
    wp: &mut WritePath,
) {
    // the LIVE batching size (a `Retune` may have moved it off the
    // configured value), not the spawn-time config
    if batch.batch_size() <= 1 {
        let t0 = Instant::now();
        match wp.enc.encode_diff(step, &DiffPayload::Gradient(sparse)) {
            Ok(obj) => {
                if let Some(t) = &wp.trace {
                    let secs = t0.elapsed().as_secs_f64();
                    t.complete("encode", secs, 0, step, obj.buf.len() as u64, 0);
                }
                wp.submit_chain_object(obj, stats);
            }
            Err(e) => log::error!("encode diff {step}: {e:#}"),
        }
        return;
    }
    let full = batch.offer(step, sparse);
    {
        let mut s = stats.lock().unwrap();
        s.peak_buffered_bytes = s.peak_buffered_bytes.max(batch.buffered_bytes());
    }
    if full {
        flush_batch(batch, stats, wp);
    }
}

/// Convenience: wait until the queue is drained (tests / barriers).
pub fn drain(ckpt: &Checkpointer) {
    while !ckpt.queue.is_empty() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::model_signature;
    use crate::compress::topk_mask;
    use crate::coordinator::recovery::{recover, RecoveryMode};
    use crate::optim::Adam;
    use crate::storage::MemStore;
    use crate::util::rng::Rng;

    fn cfg(n: usize, batch: usize) -> CkptConfig {
        CkptConfig {
            model_sig: model_signature("t", n),
            batch_size: batch,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            queue_capacity: 4,
            gc: false,
            ..CkptConfig::default()
        }
    }

    fn grad(rng: &mut Rng, n: usize) -> Flat {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        topk_mask(&Flat(g), n / 10 + 1)
    }

    #[test]
    fn end_to_end_diff_and_full_then_recover() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 1));
        let adam = Adam::default();
        let mut rng = Rng::new(11);
        let mut state = ModelState::new(Flat(vec![0.5; n]));

        // full checkpoint of the initial state
        ck.queue.put(0, Arc::new(CkptItem::Full(state.clone())));
        let mut want = state.clone();
        for step in 1..=5u64 {
            let g = grad(&mut rng, n);
            let sparse = SparseGrad::from_dense(&g);
            adam.apply_sparse(&mut want, &sparse);
            state = want.clone();
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
        }
        let stats = ck.finish();
        assert_eq!(stats.full_ckpts, 1);
        assert_eq!(stats.diff_ckpts, 5);
        assert_eq!(stats.writes, 6);
        assert_eq!(stats.errors, 0);

        let (rec, rstats) = recover(
            store.as_ref(),
            model_signature("t", n),
            &adam,
            RecoveryMode::SerialReplay,
        )
        .unwrap();
        assert_eq!(rec, want);
        assert_eq!(rstats.recovered_step, 5);
        let _ = state;
    }

    #[test]
    fn batched_writes_reduce_write_count() {
        let n = 100;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 4));
        let mut rng = Rng::new(2);
        for step in 1..=8u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.diff_ckpts, 8);
        assert_eq!(stats.writes, 2, "8 diffs at BS=4 -> 2 batched writes");
        assert!(stats.peak_buffered_bytes > 0);
    }

    #[test]
    fn partial_batch_flushed_on_close() {
        let n = 80;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 10));
        let mut rng = Rng::new(3);
        for step in 1..=3u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.writes, 1, "partial batch must still persist");
        let names = store.list().unwrap();
        assert!(names[0].starts_with("batch-"), "{names:?}");
    }

    #[test]
    fn engine_mode_recovers_identically_to_direct() {
        let n = 150;
        let run = |n_shards: usize, writers: usize| -> (Arc<dyn StorageBackend>, CkptStats) {
            let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
            let mut c = cfg(n, 2);
            c.n_shards = n_shards;
            c.writers = writers;
            let ck = Checkpointer::spawn(Arc::clone(&store), c);
            let mut rng = Rng::new(21);
            let mut state = ModelState::new(Flat(vec![0.25; n]));
            ck.queue.put(0, Arc::new(CkptItem::Full(state.clone())));
            let adam = Adam::default();
            for step in 1..=6u64 {
                let g = grad(&mut rng, n);
                adam.apply_sparse(&mut state, &SparseGrad::from_dense(&g));
                ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
            }
            (store, ck.finish())
        };
        let (direct_store, direct_stats) = run(1, 1);
        let (eng_store, eng_stats) = run(4, 3);
        assert_eq!(direct_stats.writes, eng_stats.writes);
        assert_eq!(direct_stats.errors, 0);
        assert_eq!(eng_stats.errors, 0);
        assert_eq!(eng_stats.shard_writes, 4 * 5, "4 shards + index per object");
        assert!(eng_stats.inflight_peak >= 1);
        assert_eq!(direct_stats.shard_writes, 0);

        let adam = Adam::default();
        let sig = model_signature("t", n);
        let (a, _) =
            recover(direct_store.as_ref(), sig, &adam, RecoveryMode::SerialReplay).unwrap();
        let reader = crate::storage::Sharded::new(eng_store, 1, 1);
        let (b, _) = recover(&reader, sig, &adam, RecoveryMode::SerialReplay).unwrap();
        assert_eq!(a, b, "sharded engine must be bit-identical to direct writes");
    }

    #[test]
    fn engine_mode_gc_waits_for_inflight_full() {
        let n = 100;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let mut c = cfg(n, 1);
        c.gc = true;
        c.n_shards = 2;
        c.writers = 2;
        let ck = Checkpointer::spawn(Arc::clone(&store), c);
        let mut rng = Rng::new(31);
        ck.queue.put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.1; n])))));
        for step in 1..=3u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let mut st = ModelState::new(Flat(vec![0.2; n]));
        st.step = 3;
        ck.queue.put(3, Arc::new(CkptItem::Full(st)));
        let stats = ck.finish();
        assert_eq!(stats.errors, 0);
        // GC ran against the logical view: only the newest full survives
        let reader = crate::storage::Sharded::new(store, 1, 1);
        let names = reader.list().unwrap();
        assert_eq!(names, vec![Manifest::full_name(3)], "{names:?}");
    }

    #[test]
    fn injected_put_failures_hit_the_errors_counter() {
        use crate::storage::{FaultConfig, FaultyStore};
        let n = 120;
        // grace covers the anchor full write; every later put fails
        let store: Arc<dyn StorageBackend> = Arc::new(FaultyStore::new(
            MemStore::new(),
            FaultConfig { put_fail: 1.0, grace_ops: 1, ..FaultConfig::default() },
        ));
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 1));
        let mut rng = Rng::new(17);
        ck.queue.put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.0; n])))));
        for step in 1..=4u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.writes, 1, "only the in-grace anchor landed");
        assert_eq!(stats.errors, 4, "every post-grace diff write must be counted");
    }

    #[test]
    fn steady_state_loop_recycles_pooled_buffers() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let mut c = cfg(n, 2);
        c.n_shards = 2;
        c.writers = 2;
        c.gc = true; // mid-run Full barriers the pool -> deterministic recycle
        let ck = Checkpointer::spawn(Arc::clone(&store), c);
        let mut rng = Rng::new(7);
        ck.queue.put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.1; n])))));
        for step in 1..=8u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let mut mid = ModelState::new(Flat(vec![0.2; n]));
        mid.step = 8;
        ck.queue.put(8, Arc::new(CkptItem::Full(mid)));
        for step in 9..=16u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.errors, 0);
        assert!(stats.pool_hits > 0, "steady-state encode must reuse pooled buffers");
        assert!(
            stats.pool_misses <= 8 + 2,
            "misses bounded by the retention cap, got {}",
            stats.pool_misses
        );
        // Concat batching copies each payload exactly once on its way to
        // storage, so copied bytes == logical bytes written
        assert_eq!(stats.bytes_copied, stats.bytes_written);
    }

    #[test]
    fn compaction_bounds_replay_objects_and_recovers_identically() {
        let n = 150;
        let run = |compact_every: usize| {
            let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
            let mut c = cfg(n, 1);
            c.compact_every = compact_every;
            let ck = Checkpointer::spawn(Arc::clone(&store), c);
            let mut rng = Rng::new(77);
            ck.queue
                .put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.5; n])))));
            for step in 1..=9u64 {
                ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
            }
            (store, ck.finish())
        };
        let (plain_store, plain_stats) = run(0);
        let (cmp_store, cmp_stats) = run(3);
        assert_eq!(plain_stats.merged_written, 0);
        assert_eq!(
            cmp_stats.merged_written, 4,
            "9 diffs at mf=3 -> 3 level-1 spans -> 1 level-2 super-span"
        );
        assert_eq!(cmp_stats.raw_compacted, 9);
        assert_eq!(cmp_stats.spans_compacted, 3, "the level-1 spans were absorbed");
        assert_eq!(cmp_stats.max_level, 2);

        let adam = Adam::default();
        let sig = model_signature("t", n);
        let (a, astats) =
            recover(plain_store.as_ref(), sig, &adam, RecoveryMode::SerialReplay).unwrap();
        let (b, bstats) =
            recover(cmp_store.as_ref(), sig, &adam, RecoveryMode::SerialReplay).unwrap();
        assert_eq!(a, b, "compacted replay must be bit-identical");
        assert_eq!(astats.n_diff_objects, 9);
        assert_eq!(bstats.n_diff_objects, 1, "the whole chain replays from one super-span");
        assert_eq!(bstats.max_level, 2);
        assert_eq!(bstats.n_diff_steps, 9, "every step still replays");
        assert_eq!(bstats.recovered_step, 9);
    }

    #[test]
    fn mid_run_retune_flushes_then_resizes_and_recovers_identically() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 4));
        let adam = Adam::default();
        let mut rng = Rng::new(19);
        let mut want = ModelState::new(Flat(vec![0.5; n]));
        ck.queue.put(0, Arc::new(CkptItem::Full(want.clone())));
        for step in 1..=3u64 {
            let g = grad(&mut rng, n);
            adam.apply_sparse(&mut want, &SparseGrad::from_dense(&g));
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
        }
        // actuation at the epoch boundary: the 3 pending diffs flush as
        // one partial batch under the OLD size, then BS=2 takes effect
        ck.queue
            .put(3, Arc::new(CkptItem::Retune { batch_size: 2, compact_every: 0, codec: None }));
        for step in 4..=7u64 {
            let g = grad(&mut rng, n);
            adam.apply_sparse(&mut want, &SparseGrad::from_dense(&g));
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
        }
        let stats = ck.finish();
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.writes, 4, "full + batch(1-3) + batch(4-5) + batch(6-7)");
        let names = store.list().unwrap();
        assert!(names.contains(&Manifest::batch_name(1, 3)), "{names:?}");
        assert!(names.contains(&Manifest::batch_name(4, 5)), "{names:?}");
        assert!(names.contains(&Manifest::batch_name(6, 7)), "{names:?}");

        let (rec, rstats) = recover(
            store.as_ref(),
            model_signature("t", n),
            &adam,
            RecoveryMode::SerialReplay,
        )
        .unwrap();
        assert_eq!(rec, want, "recovery across a retune must stay bit-identical");
        assert_eq!(rstats.recovered_step, 7);
    }

    #[test]
    fn mid_run_codec_retune_switches_the_wire_format() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 1));
        let mut rng = Rng::new(23);
        ck.queue
            .put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.5; n])))));
        for step in 1..=3u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        ck.queue.put(
            3,
            Arc::new(CkptItem::Retune {
                batch_size: 1,
                compact_every: 0,
                codec: Some(PayloadCodec::Quant8),
            }),
        );
        for step in 4..=6u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.codec_switches, 1);
        assert!(stats.codec_bytes_out[PayloadCodec::Quant8.idx()] > 0);
        assert!(stats.codec_bytes_out[PayloadCodec::Raw.idx()] > 0);
        for step in 1..=6u64 {
            let bytes = store.get(&Manifest::diff_name(step)).unwrap();
            let want = if step <= 3 { PayloadCodec::Raw } else { PayloadCodec::Quant8 };
            assert_eq!(
                crate::checkpoint::format::peek_codec(&bytes).unwrap(),
                want,
                "step {step}"
            );
        }
        // quantized diffs still replay (values within the codec contract)
        let (_, rstats) = recover(
            store.as_ref(),
            model_signature("t", n),
            &Adam::default(),
            RecoveryMode::SerialReplay,
        )
        .unwrap();
        assert_eq!(rstats.recovered_step, 6);
    }

    #[test]
    fn full_flushes_pending_batch_first() {
        let n = 60;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 10));
        let mut rng = Rng::new(4);
        ck.queue.put(1, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        ck.queue.put(2, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        ck.queue
            .put(2, Arc::new(CkptItem::Full(ModelState::new(Flat::zeros(n)))));
        let stats = ck.finish();
        assert_eq!(stats.writes, 2); // batch(1-2) + full(0)
        let names = store.list().unwrap();
        assert!(names.iter().any(|n| n.starts_with("batch-")));
        assert!(names.iter().any(|n| n.starts_with("full-")));
    }
}
