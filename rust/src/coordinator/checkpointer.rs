//! The checkpointing process (paper Fig. 5, Alg. 1 lines 9-12).
//!
//! A dedicated thread consuming the [`ReusingQueue`]:
//! - **Diff items** (reused compressed gradients): "offloaded" (compacted
//!   to the k-sparse wire form — the GPU→CPU offload of Fig. 6 step ①),
//!   buffered in the CPU [`BatchBuffer`] (step ②), and persisted as one
//!   batched write when full (step ③).
//! - **Full items** (model-state snapshots): pending diffs are flushed
//!   first (they belong to the pre-full chain), then the 3Ψ state is
//!   encoded and written; obsolete objects are GC'd.
//!
//! All storage I/O happens on this thread — the training thread's only
//! costs are the O(1) queue put and the snapshot copy.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;



use crate::checkpoint::batched::{finalize, BatchBuffer, BatchMode};
use crate::checkpoint::diff::{write_diff, DiffPayload};
use crate::checkpoint::format::PayloadCodec;
use crate::checkpoint::full::write_full;
use crate::checkpoint::manifest::Manifest;
use crate::coordinator::reusing_queue::ReusingQueue;
use crate::optim::ModelState;
use crate::sparse::SparseGrad;
use crate::storage::StorageBackend;
use crate::tensor::Flat;

/// What travels through the reusing queue to the checkpointing process.
pub enum CkptItem {
    /// dense-masked compressed gradient (LowDiff reuse path)
    DiffDense(Flat),
    /// pre-compacted sparse payload (Naive DC's state deltas)
    DiffSparse(DiffPayload),
    /// full model-state snapshot
    Full(ModelState),
}

/// Counters shared with the training side / report.
#[derive(Clone, Debug, Default)]
pub struct CkptStats {
    pub full_ckpts: u64,
    pub diff_ckpts: u64,
    pub writes: u64,
    pub bytes_written: u64,
    pub write_secs: f64,
    pub offload_secs: f64,
    pub peak_buffered_bytes: usize,
    pub errors: u64,
}

/// Handle to the running checkpointing process.
pub struct Checkpointer {
    pub queue: Arc<ReusingQueue<CkptItem>>,
    stats: Arc<Mutex<CkptStats>>,
    handle: Option<JoinHandle<()>>,
}

/// Configuration of the checkpointing process.
#[derive(Clone)]
pub struct CkptConfig {
    pub model_sig: u64,
    pub batch_size: usize,
    pub batch_mode: BatchMode,
    pub codec: PayloadCodec,
    pub queue_capacity: usize,
    /// run GC after each full checkpoint
    pub gc: bool,
}

impl Default for CkptConfig {
    fn default() -> Self {
        CkptConfig {
            model_sig: 0,
            batch_size: 1,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            queue_capacity: 8,
            gc: true,
        }
    }
}

impl Checkpointer {
    /// Spawn the checkpointing thread over `store`.
    pub fn spawn(store: Arc<dyn StorageBackend>, cfg: CkptConfig) -> Checkpointer {
        let queue: Arc<ReusingQueue<CkptItem>> = ReusingQueue::new(cfg.queue_capacity);
        let stats = Arc::new(Mutex::new(CkptStats::default()));
        let q = Arc::clone(&queue);
        let st = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("ckpt".into())
            .spawn(move || run_loop(q, store, cfg, st))
            .expect("spawning checkpointer");
        Checkpointer { queue, stats, handle: Some(handle) }
    }

    pub fn stats(&self) -> CkptStats {
        self.stats.lock().unwrap().clone()
    }

    /// Close the queue and wait for all pending work to be persisted.
    pub fn finish(mut self) -> CkptStats {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_loop(
    queue: Arc<ReusingQueue<CkptItem>>,
    store: Arc<dyn StorageBackend>,
    cfg: CkptConfig,
    stats: Arc<Mutex<CkptStats>>,
) {
    let mut batch = BatchBuffer::new(cfg.batch_mode, cfg.batch_size);
    let mut put = |bytes: Vec<u8>, name: String, st: &Mutex<CkptStats>| {
        let t0 = Instant::now();
        let res = store.put(&name, &bytes);
        let mut s = st.lock().unwrap();
        s.write_secs += t0.elapsed().as_secs_f64();
        match res {
            Ok(()) => {
                s.writes += 1;
                s.bytes_written += bytes.len() as u64;
            }
            Err(e) => {
                log::error!("checkpoint write {name} failed: {e:#}");
                s.errors += 1;
            }
        }
    };

    while let Some(entry) = queue.get() {
        let step = entry.step;
        // the queue hands us the sole surviving Arc once training has moved
        // on; unwrap-or-clone keeps zero-copy in the common case
        let item = Arc::try_unwrap(entry.payload).unwrap_or_else(|_| {
            // training still holds a reference (it shouldn't for Full);
            // fall back to reading through the Arc
            panic!("checkpointer requires exclusive payload ownership")
        });
        match item {
            CkptItem::DiffDense(dense) => {
                let t0 = Instant::now();
                let sparse = SparseGrad::from_dense(&dense); // offload/compact
                drop(dense);
                {
                    let mut s = stats.lock().unwrap();
                    s.offload_secs += t0.elapsed().as_secs_f64();
                    s.diff_ckpts += 1;
                }
                handle_sparse(step, sparse, &mut batch, &cfg, &stats, &mut put);
            }
            CkptItem::DiffSparse(payload) => {
                stats.lock().unwrap().diff_ckpts += 1;
                match payload {
                    DiffPayload::Gradient(g) => {
                        handle_sparse(step, g, &mut batch, &cfg, &stats, &mut put)
                    }
                    delta @ DiffPayload::StateDelta(_) => {
                        // Naive DC writes every delta unbatched (its cost)
                        match write_diff(&delta, cfg.model_sig, step, cfg.codec) {
                            Ok(bytes) => put(bytes, Manifest::diff_name(step), &stats),
                            Err(e) => log::error!("encode diff {step}: {e:#}"),
                        }
                    }
                }
            }
            CkptItem::Full(state) => {
                // flush the pre-full chain first (order matters for GC)
                if let Some(c) = batch.flush() {
                    let (lo, hi) = (c.step_lo, c.step_hi);
                    match finalize(c, cfg.model_sig, cfg.codec) {
                        Ok(bytes) => put(bytes, Manifest::batch_name(lo, hi), &stats),
                        Err(e) => log::error!("encode batch: {e:#}"),
                    }
                }
                match write_full(&state, cfg.model_sig, cfg.codec) {
                    Ok(bytes) => {
                        put(bytes, Manifest::full_name(state.step), &stats);
                        stats.lock().unwrap().full_ckpts += 1;
                        if cfg.gc {
                            if let Err(e) = Manifest::gc(store.as_ref()) {
                                log::warn!("gc failed: {e:#}");
                            }
                        }
                    }
                    Err(e) => log::error!("encode full {step}: {e:#}"),
                }
            }
        }
    }
    // drain the final partial batch on close
    if let Some(c) = batch.flush() {
        let (lo, hi) = (c.step_lo, c.step_hi);
        if let Ok(bytes) = finalize(c, cfg.model_sig, cfg.codec) {
            put(bytes, Manifest::batch_name(lo, hi), &stats);
        }
    }
}

fn handle_sparse(
    step: u64,
    sparse: SparseGrad,
    batch: &mut BatchBuffer,
    cfg: &CkptConfig,
    stats: &Arc<Mutex<CkptStats>>,
    put: &mut impl FnMut(Vec<u8>, String, &Mutex<CkptStats>),
) {
    if cfg.batch_size <= 1 {
        match write_diff(&DiffPayload::Gradient(sparse), cfg.model_sig, step, cfg.codec) {
            Ok(bytes) => put(bytes, Manifest::diff_name(step), stats),
            Err(e) => log::error!("encode diff {step}: {e:#}"),
        }
        return;
    }
    let maybe = batch.push(step, sparse);
    {
        let mut s = stats.lock().unwrap();
        s.peak_buffered_bytes = s.peak_buffered_bytes.max(batch.buffered_bytes());
    }
    if let Some(c) = maybe {
        let (lo, hi) = (c.step_lo, c.step_hi);
        match finalize(c, cfg.model_sig, cfg.codec) {
            Ok(bytes) => put(bytes, Manifest::batch_name(lo, hi), stats),
            Err(e) => log::error!("encode batch: {e:#}"),
        }
    }
}

/// Convenience: wait until the queue is drained (tests / barriers).
pub fn drain(ckpt: &Checkpointer) {
    while !ckpt.queue.is_empty() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::model_signature;
    use crate::compress::topk_mask;
    use crate::coordinator::recovery::{recover, RecoveryMode};
    use crate::optim::Adam;
    use crate::storage::MemStore;
    use crate::util::rng::Rng;

    fn cfg(n: usize, batch: usize) -> CkptConfig {
        CkptConfig {
            model_sig: model_signature("t", n),
            batch_size: batch,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            queue_capacity: 4,
            gc: false,
        }
    }

    fn grad(rng: &mut Rng, n: usize) -> Flat {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        topk_mask(&Flat(g), n / 10 + 1)
    }

    #[test]
    fn end_to_end_diff_and_full_then_recover() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 1));
        let adam = Adam::default();
        let mut rng = Rng::new(11);
        let mut state = ModelState::new(Flat(vec![0.5; n]));

        // full checkpoint of the initial state
        ck.queue.put(0, Arc::new(CkptItem::Full(state.clone())));
        let mut want = state.clone();
        for step in 1..=5u64 {
            let g = grad(&mut rng, n);
            let sparse = SparseGrad::from_dense(&g);
            adam.apply_sparse(&mut want, &sparse);
            state = want.clone();
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
        }
        let stats = ck.finish();
        assert_eq!(stats.full_ckpts, 1);
        assert_eq!(stats.diff_ckpts, 5);
        assert_eq!(stats.writes, 6);
        assert_eq!(stats.errors, 0);

        let (rec, rstats) = recover(
            store.as_ref(),
            model_signature("t", n),
            &adam,
            RecoveryMode::SerialReplay,
        )
        .unwrap();
        assert_eq!(rec, want);
        assert_eq!(rstats.recovered_step, 5);
        let _ = state;
    }

    #[test]
    fn batched_writes_reduce_write_count() {
        let n = 100;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 4));
        let mut rng = Rng::new(2);
        for step in 1..=8u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.diff_ckpts, 8);
        assert_eq!(stats.writes, 2, "8 diffs at BS=4 -> 2 batched writes");
        assert!(stats.peak_buffered_bytes > 0);
    }

    #[test]
    fn partial_batch_flushed_on_close() {
        let n = 80;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 10));
        let mut rng = Rng::new(3);
        for step in 1..=3u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.writes, 1, "partial batch must still persist");
        let names = store.list().unwrap();
        assert!(names[0].starts_with("batch-"), "{names:?}");
    }

    #[test]
    fn full_flushes_pending_batch_first() {
        let n = 60;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 10));
        let mut rng = Rng::new(4);
        ck.queue.put(1, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        ck.queue.put(2, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        ck.queue
            .put(2, Arc::new(CkptItem::Full(ModelState::new(Flat::zeros(n)))));
        let stats = ck.finish();
        assert_eq!(stats.writes, 2); // batch(1-2) + full(0)
        let names = store.list().unwrap();
        assert!(names.iter().any(|n| n.starts_with("batch-")));
        assert!(names.iter().any(|n| n.starts_with("full-")));
    }
}
