//! The checkpointing process (paper Fig. 5, Alg. 1 lines 9-12).
//!
//! A dedicated thread consuming the [`ReusingQueue`]:
//! - **Diff items** (reused compressed gradients): "offloaded" (compacted
//!   to the k-sparse wire form — the GPU→CPU offload of Fig. 6 step ①),
//!   buffered in the CPU [`BatchBuffer`] (step ②), and persisted as one
//!   batched write when full (step ③).
//! - **Full items** (model-state snapshots): pending diffs are flushed
//!   first (they belong to the pre-full chain), then the 3Ψ state is
//!   encoded and written; obsolete objects are GC'd.
//!
//! All storage I/O happens on this thread *or* — with `n_shards > 1` or
//! `writers > 1` in [`CkptConfig`] — on the sharded engine's writer pool:
//! the checkpointer then only encodes and enqueues, reaping completions
//! asynchronously and draining the pool before GC and shutdown (GC must
//! never run while the full checkpoint it keys on is still in flight).
//! The training thread's only costs stay the O(1) queue put and the
//! snapshot copy.
//!
//! Every write is encoded in a **single pass into a pooled buffer**
//! ([`BufPool`]): sparse payloads serialize straight into the container
//! bytes (one copy), `Sum` batches accumulate in place at offer time, and
//! the sharded engine slices the pooled buffer zero-copy — the buffer
//! recycles when its write commits. `CkptStats { bytes_copied, pool_hits,
//! pool_misses }` make the copy discipline observable; see
//! docs/STORAGE.md, "Write-path anatomy".

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::checkpoint::batched::{BatchBuffer, BatchMode};
use crate::checkpoint::diff::{write_diff_into, DiffPayload};
use crate::checkpoint::format::PayloadCodec;
use crate::checkpoint::full::write_full_into;
use crate::checkpoint::manifest::Manifest;
use crate::coordinator::reusing_queue::ReusingQueue;
use crate::optim::ModelState;
use crate::sparse::SparseGrad;
use crate::storage::{Sharded, StorageBackend, WriteHandle};
use crate::tensor::Flat;
use crate::util::bufpool::{BufPool, PooledBuf};

/// What travels through the reusing queue to the checkpointing process.
pub enum CkptItem {
    /// dense-masked compressed gradient (LowDiff reuse path)
    DiffDense(Flat),
    /// pre-compacted sparse payload (Naive DC's state deltas)
    DiffSparse(DiffPayload),
    /// full model-state snapshot
    Full(ModelState),
}

/// Counters shared with the training side / report.
#[derive(Clone, Debug, Default)]
pub struct CkptStats {
    pub full_ckpts: u64,
    pub diff_ckpts: u64,
    pub writes: u64,
    pub bytes_written: u64,
    /// Direct mode: wall time inside synchronous puts. Engine mode: wall
    /// time the checkpointer spent *blocked* on the writer pool (barriers
    /// before GC / shutdown) — the overlap-visible cost, not device time.
    pub write_secs: f64,
    pub offload_secs: f64,
    pub peak_buffered_bytes: usize,
    pub errors: u64,
    /// peak logical writes simultaneously in flight on the writer pool
    pub inflight_peak: usize,
    /// physical objects written by the sharded engine (shards + commit
    /// records); 0 in direct mode
    pub shard_writes: u64,
    /// fast→durable tier traffic reported by the backend (Tiered), as of
    /// checkpointer shutdown — late spills keep draining afterwards
    pub spill_bytes: u64,
    pub spill_errors: u64,
    /// bytes moved between heap buffers on the write path after the sparse
    /// compaction: encode output + Sum-mode accumulation traffic. The
    /// pooled single-pass pipeline moves each payload once; the pre-change
    /// pipeline moved it 3-4x (see docs/STORAGE.md, "Write-path anatomy").
    pub bytes_copied: u64,
    /// encode-buffer pool counters, as of checkpointer shutdown: hits are
    /// recycled checkouts (steady state should be all hits)
    pub pool_hits: u64,
    pub pool_misses: u64,
}

impl CkptStats {
    /// Component-wise aggregation: sums for counters, max for peaks. Used
    /// to fold per-rank cluster stats into cluster-wide totals (and by
    /// [`RunReport`](crate::coordinator::metrics::RunReport) absorption).
    pub fn merge(&mut self, o: &CkptStats) {
        self.full_ckpts += o.full_ckpts;
        self.diff_ckpts += o.diff_ckpts;
        self.writes += o.writes;
        self.bytes_written += o.bytes_written;
        self.write_secs += o.write_secs;
        self.offload_secs += o.offload_secs;
        self.peak_buffered_bytes = self.peak_buffered_bytes.max(o.peak_buffered_bytes);
        self.errors += o.errors;
        self.inflight_peak = self.inflight_peak.max(o.inflight_peak);
        self.shard_writes += o.shard_writes;
        self.spill_bytes += o.spill_bytes;
        self.spill_errors += o.spill_errors;
        self.bytes_copied += o.bytes_copied;
        self.pool_hits += o.pool_hits;
        self.pool_misses += o.pool_misses;
    }
}

/// Handle to the running checkpointing process.
pub struct Checkpointer {
    pub queue: Arc<ReusingQueue<CkptItem>>,
    stats: Arc<Mutex<CkptStats>>,
    handle: Option<JoinHandle<()>>,
}

/// Configuration of the checkpointing process.
#[derive(Clone)]
pub struct CkptConfig {
    pub model_sig: u64,
    pub batch_size: usize,
    pub batch_mode: BatchMode,
    pub codec: PayloadCodec,
    pub queue_capacity: usize,
    /// run GC after each full checkpoint
    pub gc: bool,
    /// shards per checkpoint object; >1 (or `writers` > 1) routes writes
    /// through the sharded async engine ([`Sharded`])
    pub n_shards: usize,
    /// storage writer-pool threads for the sharded engine
    pub writers: usize,
}

impl Default for CkptConfig {
    fn default() -> Self {
        CkptConfig {
            model_sig: 0,
            batch_size: 1,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            queue_capacity: 8,
            gc: true,
            n_shards: 1,
            writers: 1,
        }
    }
}

impl CkptConfig {
    /// True when writes go through the sharded async engine instead of
    /// synchronous single-object puts.
    pub fn uses_engine(&self) -> bool {
        self.n_shards > 1 || self.writers > 1
    }

    /// Max logical writes allowed in flight before the checkpointer blocks
    /// (engine-mode backpressure). The encode-buffer pool is sized from
    /// this too, so steady-state checkouts always find a recycled buffer.
    pub fn inflight_cap(&self) -> usize {
        (self.writers * 4).max(8)
    }
}

impl Checkpointer {
    /// Spawn the checkpointing thread over `store`.
    pub fn spawn(store: Arc<dyn StorageBackend>, cfg: CkptConfig) -> Checkpointer {
        let queue: Arc<ReusingQueue<CkptItem>> = ReusingQueue::new(cfg.queue_capacity);
        let stats = Arc::new(Mutex::new(CkptStats::default()));
        let q = Arc::clone(&queue);
        let st = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("ckpt".into())
            .spawn(move || run_loop(q, store, cfg, st))
            .expect("spawning checkpointer");
        Checkpointer { queue, stats, handle: Some(handle) }
    }

    pub fn stats(&self) -> CkptStats {
        self.stats.lock().unwrap().clone()
    }

    /// Close the queue and wait for all pending work to be persisted.
    pub fn finish(mut self) -> CkptStats {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One logical write still in flight on the sharded engine.
struct Inflight {
    name: String,
    bytes: u64,
    handle: WriteHandle,
}

/// The checkpointer's storage sink: synchronous single-object puts, or the
/// sharded async engine with completion reaping.
enum Writer {
    Direct(Arc<dyn StorageBackend>),
    Engine { eng: Sharded, inflight: Vec<Inflight>, cap: usize },
}

impl Writer {
    fn new(store: Arc<dyn StorageBackend>, cfg: &CkptConfig) -> Writer {
        if cfg.uses_engine() {
            Writer::Engine {
                eng: Sharded::new(store, cfg.n_shards, cfg.writers),
                inflight: Vec::new(),
                cap: cfg.inflight_cap(),
            }
        } else {
            Writer::Direct(store)
        }
    }

    /// The logical object view (GC, recovery interop must see through the
    /// shard layout).
    fn view(&self) -> &dyn StorageBackend {
        match self {
            Writer::Direct(s) => s.as_ref(),
            Writer::Engine { eng, .. } => eng,
        }
    }

    /// Hand one encoded (pooled) buffer to storage. Direct mode writes
    /// synchronously and the buffer recycles on drop right here; engine
    /// mode shares it with the writer pool zero-copy — it recycles when
    /// the commit finalizer releases the last reference.
    fn submit(&mut self, buf: PooledBuf, name: String, stats: &Mutex<CkptStats>) {
        match self {
            Writer::Direct(store) => {
                let t0 = Instant::now();
                let res = store.put(&name, &buf);
                let mut s = stats.lock().unwrap();
                s.write_secs += t0.elapsed().as_secs_f64();
                match res {
                    Ok(()) => {
                        s.writes += 1;
                        s.bytes_written += buf.len() as u64;
                    }
                    Err(e) => {
                        log::error!("checkpoint write {name} failed: {e:#}");
                        s.errors += 1;
                    }
                }
            }
            Writer::Engine { eng, inflight, cap } => {
                let len = buf.len() as u64;
                let handle = eng.put_async(&name, buf);
                inflight.push(Inflight { name, bytes: len, handle });
                {
                    let mut s = stats.lock().unwrap();
                    s.inflight_peak = s.inflight_peak.max(inflight.len());
                }
                Self::reap(inflight, stats);
                // backpressure: don't let encoded-but-unwritten checkpoints
                // pile up without bound when the device is slower than the
                // trainer — block on the oldest write past the cap, which
                // propagates through the reusing queue as a visible stall
                while inflight.len() > *cap {
                    let w = inflight.remove(0);
                    let t0 = Instant::now();
                    let res = w.handle.wait();
                    let mut dt_stats = stats.lock().unwrap();
                    dt_stats.write_secs += t0.elapsed().as_secs_f64();
                    drop(dt_stats);
                    Self::account(&w.name, w.bytes, res, stats);
                }
            }
        }
    }

    /// Harvest completed handles without blocking.
    fn reap(inflight: &mut Vec<Inflight>, stats: &Mutex<CkptStats>) {
        inflight.retain(|w| match w.handle.try_result() {
            None => true,
            Some(res) => {
                Self::account(&w.name, w.bytes, res, stats);
                false
            }
        });
    }

    /// Block until every in-flight write committed (pre-GC / shutdown
    /// barrier). No-op in direct mode.
    fn barrier(&mut self, stats: &Mutex<CkptStats>) {
        if let Writer::Engine { inflight, .. } = self {
            let t0 = Instant::now();
            for w in inflight.drain(..) {
                let res = w.handle.wait();
                Self::account(&w.name, w.bytes, res, stats);
            }
            stats.lock().unwrap().write_secs += t0.elapsed().as_secs_f64();
        }
    }

    fn account(name: &str, bytes: u64, res: Result<(), String>, stats: &Mutex<CkptStats>) {
        let mut s = stats.lock().unwrap();
        match res {
            Ok(()) => {
                s.writes += 1;
                s.bytes_written += bytes;
            }
            Err(e) => {
                log::error!("checkpoint write {name} failed: {e}");
                s.errors += 1;
            }
        }
    }

    /// Fold backend-level counters (shard fan-out, tier spill) into the
    /// final stats snapshot.
    fn finish(self, stats: &Mutex<CkptStats>) {
        let sst = self.view().storage_stats();
        let mut s = stats.lock().unwrap();
        s.shard_writes = sst.physical_writes;
        s.spill_bytes = sst.spill_bytes;
        s.spill_errors = sst.spill_errors;
    }
}

fn run_loop(
    queue: Arc<ReusingQueue<CkptItem>>,
    store: Arc<dyn StorageBackend>,
    cfg: CkptConfig,
    stats: Arc<Mutex<CkptStats>>,
) {
    let mut batch = BatchBuffer::new(cfg.batch_mode, cfg.batch_size);
    let mut writer = Writer::new(store, &cfg);
    // one encode buffer per possible in-flight write, plus slack for the
    // one being filled: steady state checks out only recycled buffers
    let pool = BufPool::new(cfg.inflight_cap() + 2);

    while let Some(entry) = queue.get() {
        let step = entry.step;
        // the queue hands us the sole surviving Arc once training has moved
        // on; unwrap-or-clone keeps zero-copy in the common case
        let item = Arc::try_unwrap(entry.payload).unwrap_or_else(|_| {
            // training still holds a reference (it shouldn't for Full);
            // fall back to reading through the Arc
            panic!("checkpointer requires exclusive payload ownership")
        });
        match item {
            CkptItem::DiffDense(dense) => {
                let t0 = Instant::now();
                let sparse = SparseGrad::from_dense(&dense); // offload/compact
                drop(dense);
                {
                    let mut s = stats.lock().unwrap();
                    s.offload_secs += t0.elapsed().as_secs_f64();
                    s.diff_ckpts += 1;
                }
                handle_sparse(step, sparse, &mut batch, &cfg, &stats, &mut writer, &pool);
            }
            CkptItem::DiffSparse(payload) => {
                stats.lock().unwrap().diff_ckpts += 1;
                match payload {
                    DiffPayload::Gradient(g) => {
                        handle_sparse(step, g, &mut batch, &cfg, &stats, &mut writer, &pool)
                    }
                    delta @ DiffPayload::StateDelta(_) => {
                        // Naive DC writes every delta unbatched (its cost)
                        let mut buf = pool.checkout();
                        match write_diff_into(&delta, cfg.model_sig, step, cfg.codec, &mut buf) {
                            Ok(copied) => {
                                stats.lock().unwrap().bytes_copied += copied as u64;
                                writer.submit(buf, Manifest::diff_name(step), &stats)
                            }
                            Err(e) => log::error!("encode diff {step}: {e:#}"),
                        }
                    }
                }
            }
            CkptItem::Full(state) => {
                // flush the pre-full chain first (order matters for GC)
                flush_batch(&mut batch, &cfg, &stats, &mut writer, &pool);
                let mut buf = pool.checkout();
                match write_full_into(&state, cfg.model_sig, cfg.codec, &mut buf) {
                    Ok(copied) => {
                        stats.lock().unwrap().bytes_copied += copied as u64;
                        writer.submit(buf, Manifest::full_name(state.step), &stats);
                        stats.lock().unwrap().full_ckpts += 1;
                        if cfg.gc {
                            // GC keys on the newest durable full: drain the
                            // pool so it never deletes the chain a not-yet-
                            // committed full is supposed to supersede
                            writer.barrier(&stats);
                            if let Err(e) = Manifest::gc(writer.view()) {
                                log::warn!("gc failed: {e:#}");
                            }
                        }
                    }
                    Err(e) => log::error!("encode full {step}: {e:#}"),
                }
            }
        }
    }
    // drain the final partial batch on close
    flush_batch(&mut batch, &cfg, &stats, &mut writer, &pool);
    // shutdown barrier: every enqueued write must commit (or report) before
    // `finish()` returns to the caller
    writer.barrier(&stats);
    {
        let mut s = stats.lock().unwrap();
        s.pool_hits = pool.hits();
        s.pool_misses = pool.misses();
    }
    writer.finish(&stats);
}

/// Drain the batch buffer into a pooled buffer in one encoding pass and
/// submit it. No-op when the batch is empty.
fn flush_batch(
    batch: &mut BatchBuffer,
    cfg: &CkptConfig,
    stats: &Arc<Mutex<CkptStats>>,
    writer: &mut Writer,
    pool: &BufPool,
) {
    if batch.is_empty() {
        return;
    }
    let mut buf = pool.checkout();
    match batch.flush_into(cfg.model_sig, cfg.codec, &mut buf) {
        Ok(Some((lo, hi, copied))) => {
            {
                let mut s = stats.lock().unwrap();
                s.bytes_copied += copied as u64 + batch.take_copied();
            }
            writer.submit(buf, Manifest::batch_name(lo, hi), stats);
        }
        Ok(None) => {}
        Err(e) => log::error!("encode batch: {e:#}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_sparse(
    step: u64,
    sparse: SparseGrad,
    batch: &mut BatchBuffer,
    cfg: &CkptConfig,
    stats: &Arc<Mutex<CkptStats>>,
    writer: &mut Writer,
    pool: &BufPool,
) {
    if cfg.batch_size <= 1 {
        let mut buf = pool.checkout();
        let payload = DiffPayload::Gradient(sparse);
        match write_diff_into(&payload, cfg.model_sig, step, cfg.codec, &mut buf) {
            Ok(copied) => {
                stats.lock().unwrap().bytes_copied += copied as u64;
                writer.submit(buf, Manifest::diff_name(step), stats)
            }
            Err(e) => log::error!("encode diff {step}: {e:#}"),
        }
        return;
    }
    let full = batch.offer(step, sparse);
    {
        let mut s = stats.lock().unwrap();
        s.peak_buffered_bytes = s.peak_buffered_bytes.max(batch.buffered_bytes());
    }
    if full {
        flush_batch(batch, cfg, stats, writer, pool);
    }
}

/// Convenience: wait until the queue is drained (tests / barriers).
pub fn drain(ckpt: &Checkpointer) {
    while !ckpt.queue.is_empty() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::model_signature;
    use crate::compress::topk_mask;
    use crate::coordinator::recovery::{recover, RecoveryMode};
    use crate::optim::Adam;
    use crate::storage::MemStore;
    use crate::util::rng::Rng;

    fn cfg(n: usize, batch: usize) -> CkptConfig {
        CkptConfig {
            model_sig: model_signature("t", n),
            batch_size: batch,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            queue_capacity: 4,
            gc: false,
            ..CkptConfig::default()
        }
    }

    fn grad(rng: &mut Rng, n: usize) -> Flat {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        topk_mask(&Flat(g), n / 10 + 1)
    }

    #[test]
    fn end_to_end_diff_and_full_then_recover() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 1));
        let adam = Adam::default();
        let mut rng = Rng::new(11);
        let mut state = ModelState::new(Flat(vec![0.5; n]));

        // full checkpoint of the initial state
        ck.queue.put(0, Arc::new(CkptItem::Full(state.clone())));
        let mut want = state.clone();
        for step in 1..=5u64 {
            let g = grad(&mut rng, n);
            let sparse = SparseGrad::from_dense(&g);
            adam.apply_sparse(&mut want, &sparse);
            state = want.clone();
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
        }
        let stats = ck.finish();
        assert_eq!(stats.full_ckpts, 1);
        assert_eq!(stats.diff_ckpts, 5);
        assert_eq!(stats.writes, 6);
        assert_eq!(stats.errors, 0);

        let (rec, rstats) = recover(
            store.as_ref(),
            model_signature("t", n),
            &adam,
            RecoveryMode::SerialReplay,
        )
        .unwrap();
        assert_eq!(rec, want);
        assert_eq!(rstats.recovered_step, 5);
        let _ = state;
    }

    #[test]
    fn batched_writes_reduce_write_count() {
        let n = 100;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 4));
        let mut rng = Rng::new(2);
        for step in 1..=8u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.diff_ckpts, 8);
        assert_eq!(stats.writes, 2, "8 diffs at BS=4 -> 2 batched writes");
        assert!(stats.peak_buffered_bytes > 0);
    }

    #[test]
    fn partial_batch_flushed_on_close() {
        let n = 80;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 10));
        let mut rng = Rng::new(3);
        for step in 1..=3u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.writes, 1, "partial batch must still persist");
        let names = store.list().unwrap();
        assert!(names[0].starts_with("batch-"), "{names:?}");
    }

    #[test]
    fn engine_mode_recovers_identically_to_direct() {
        let n = 150;
        let run = |n_shards: usize, writers: usize| -> (Arc<dyn StorageBackend>, CkptStats) {
            let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
            let mut c = cfg(n, 2);
            c.n_shards = n_shards;
            c.writers = writers;
            let ck = Checkpointer::spawn(Arc::clone(&store), c);
            let mut rng = Rng::new(21);
            let mut state = ModelState::new(Flat(vec![0.25; n]));
            ck.queue.put(0, Arc::new(CkptItem::Full(state.clone())));
            let adam = Adam::default();
            for step in 1..=6u64 {
                let g = grad(&mut rng, n);
                adam.apply_sparse(&mut state, &SparseGrad::from_dense(&g));
                ck.queue.put(step, Arc::new(CkptItem::DiffDense(g)));
            }
            (store, ck.finish())
        };
        let (direct_store, direct_stats) = run(1, 1);
        let (eng_store, eng_stats) = run(4, 3);
        assert_eq!(direct_stats.writes, eng_stats.writes);
        assert_eq!(direct_stats.errors, 0);
        assert_eq!(eng_stats.errors, 0);
        assert_eq!(eng_stats.shard_writes, 4 * 5, "4 shards + index per object");
        assert!(eng_stats.inflight_peak >= 1);
        assert_eq!(direct_stats.shard_writes, 0);

        let adam = Adam::default();
        let sig = model_signature("t", n);
        let (a, _) =
            recover(direct_store.as_ref(), sig, &adam, RecoveryMode::SerialReplay).unwrap();
        let reader = crate::storage::Sharded::new(eng_store, 1, 1);
        let (b, _) = recover(&reader, sig, &adam, RecoveryMode::SerialReplay).unwrap();
        assert_eq!(a, b, "sharded engine must be bit-identical to direct writes");
    }

    #[test]
    fn engine_mode_gc_waits_for_inflight_full() {
        let n = 100;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let mut c = cfg(n, 1);
        c.gc = true;
        c.n_shards = 2;
        c.writers = 2;
        let ck = Checkpointer::spawn(Arc::clone(&store), c);
        let mut rng = Rng::new(31);
        ck.queue.put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.1; n])))));
        for step in 1..=3u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let mut st = ModelState::new(Flat(vec![0.2; n]));
        st.step = 3;
        ck.queue.put(3, Arc::new(CkptItem::Full(st)));
        let stats = ck.finish();
        assert_eq!(stats.errors, 0);
        // GC ran against the logical view: only the newest full survives
        let reader = crate::storage::Sharded::new(store, 1, 1);
        let names = reader.list().unwrap();
        assert_eq!(names, vec![Manifest::full_name(3)], "{names:?}");
    }

    #[test]
    fn injected_put_failures_hit_the_errors_counter() {
        use crate::storage::{FaultConfig, FaultyStore};
        let n = 120;
        // grace covers the anchor full write; every later put fails
        let store: Arc<dyn StorageBackend> = Arc::new(FaultyStore::new(
            MemStore::new(),
            FaultConfig { put_fail: 1.0, grace_ops: 1, ..FaultConfig::default() },
        ));
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 1));
        let mut rng = Rng::new(17);
        ck.queue.put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.0; n])))));
        for step in 1..=4u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.writes, 1, "only the in-grace anchor landed");
        assert_eq!(stats.errors, 4, "every post-grace diff write must be counted");
    }

    #[test]
    fn steady_state_loop_recycles_pooled_buffers() {
        let n = 150;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let mut c = cfg(n, 2);
        c.n_shards = 2;
        c.writers = 2;
        c.gc = true; // mid-run Full barriers the pool -> deterministic recycle
        let ck = Checkpointer::spawn(Arc::clone(&store), c);
        let mut rng = Rng::new(7);
        ck.queue.put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.1; n])))));
        for step in 1..=8u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let mut mid = ModelState::new(Flat(vec![0.2; n]));
        mid.step = 8;
        ck.queue.put(8, Arc::new(CkptItem::Full(mid)));
        for step in 9..=16u64 {
            ck.queue.put(step, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        }
        let stats = ck.finish();
        assert_eq!(stats.errors, 0);
        assert!(stats.pool_hits > 0, "steady-state encode must reuse pooled buffers");
        assert!(
            stats.pool_misses <= 8 + 2,
            "misses bounded by the retention cap, got {}",
            stats.pool_misses
        );
        // Concat batching copies each payload exactly once on its way to
        // storage, so copied bytes == logical bytes written
        assert_eq!(stats.bytes_copied, stats.bytes_written);
    }

    #[test]
    fn full_flushes_pending_batch_first() {
        let n = 60;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg(n, 10));
        let mut rng = Rng::new(4);
        ck.queue.put(1, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        ck.queue.put(2, Arc::new(CkptItem::DiffDense(grad(&mut rng, n))));
        ck.queue
            .put(2, Arc::new(CkptItem::Full(ModelState::new(Flat::zeros(n)))));
        let stats = ck.finish();
        assert_eq!(stats.writes, 2); // batch(1-2) + full(0)
        let names = store.list().unwrap();
        assert!(names.iter().any(|n| n.starts_with("batch-")));
        assert!(names.iter().any(|n| n.starts_with("full-")));
    }
}
