//! Checkpointing configuration optimization (paper §V-C).
//!
//! Models the wasted time T_wasted(f, b) of Eq. (8) over full-checkpoint
//! frequency `f` (checkpoints per iteration... the paper uses f as full
//! checkpoints per unit work; here f = 1/FCF_interval, i.e. checkpoints
//! per iteration) and batching size `b`, derives the closed-form optimum
//! (f*, b*) of Eq. (10), and provides the runtime stepwise tuner the
//! implementation section (§VII-A) describes.

/// Constant system parameters of Eq. (8).
#[derive(Clone, Copy, Debug)]
pub struct SystemParams {
    /// number of GPUs N
    pub n_gpus: f64,
    /// mean time between failures M (hours or any consistent unit)
    pub mtbf: f64,
    /// checkpoint write bandwidth W (bytes per time-unit)
    pub write_bw: f64,
    /// full checkpoint size S (bytes)
    pub full_size: f64,
    /// total training run time T (same unit as mtbf)
    pub total_time: f64,
    /// time to load a full checkpoint R_F
    pub r_full: f64,
    /// time to merge one differential checkpoint R_D
    pub r_diff: f64,
}

/// Eq. (8): T_wasted(f, b) =
///   NT/M · ( b/2 + R_F + R_D/2·(1/(f·b) − 1) ) + NT·S·f / W
pub fn wasted_time(p: &SystemParams, f: f64, b: f64) -> f64 {
    assert!(f > 0.0 && b > 0.0);
    let recovery = p.n_gpus * p.total_time / p.mtbf
        * (b / 2.0 + p.r_full + p.r_diff / 2.0 * (1.0 / (f * b) - 1.0));
    let steady = p.n_gpus * p.total_time * p.full_size * f / p.write_bw;
    recovery + steady
}

/// Eq. (10): the closed-form stationary point
/// (f*, b*) = ( cbrt(R_D·W² / (4·S²·M²)),  cbrt(2·S·R_D·M / W) ).
pub fn optimal_config(p: &SystemParams) -> (f64, f64) {
    let f = (p.r_diff * p.write_bw * p.write_bw
        / (4.0 * p.full_size * p.full_size * p.mtbf * p.mtbf))
        .cbrt();
    let b = (2.0 * p.full_size * p.r_diff * p.mtbf / p.write_bw).cbrt();
    (f, b)
}

/// Quantize the continuous optimum to usable integers: FCF interval
/// (iterations between full checkpoints, = round(1/f*) clamped) and batch
/// size, searching the 3×3 integer neighborhood for the lowest Eq.(8) value.
pub fn optimal_config_integer(p: &SystemParams, iter_time: f64) -> (u64, usize) {
    // f* is "full checkpoints per time-unit"; convert to an iteration
    // interval via the iteration duration.
    let (f_star, b_star) = optimal_config(p);
    let interval0 = (1.0 / (f_star * iter_time)).max(1.0);
    let b0 = b_star.max(1.0);
    let mut best = (u64::MAX, usize::MAX);
    let mut best_cost = f64::INFINITY;
    for di in [-1.0, 0.0, 1.0] {
        for db in [-1.0, 0.0, 1.0] {
            let interval = (interval0 + di * interval0 * 0.25).round().max(1.0);
            let b = (b0 + db).round().max(1.0);
            let f = 1.0 / (interval * iter_time);
            let cost = wasted_time(p, f, b);
            if cost < best_cost {
                best_cost = cost;
                best = (interval as u64, b as usize);
            }
        }
    }
    best
}

/// Runtime stepwise tuner (§VII-A "Optimal configuration module"):
/// starts from a config, observes runtime metrics (measured MTBF and
/// bandwidth), and nudges (FCF interval, BS) toward the model optimum.
#[derive(Debug)]
pub struct AdaptiveTuner {
    pub params: SystemParams,
    pub iter_time: f64,
    pub fcf_interval: u64,
    pub batch_size: usize,
    /// uncompacted per-diff replay cost (R_D as configured);
    /// [`observe_compaction`](AdaptiveTuner::observe_compaction) scales
    /// `params.r_diff` below this as merged spans shorten the chain
    r_diff_base: f64,
}

impl AdaptiveTuner {
    pub fn new(params: SystemParams, iter_time: f64) -> AdaptiveTuner {
        let (fcf, bs) = optimal_config_integer(&params, iter_time);
        AdaptiveTuner {
            r_diff_base: params.r_diff,
            params,
            iter_time,
            fcf_interval: fcf,
            batch_size: bs,
        }
    }

    /// Feed fresh runtime observations; config moves one step per call
    /// (stepwise adjustment, never a jump — §VII-A).
    ///
    /// `measured_mtbf`/`measured_bw` become the model parameters
    /// *verbatim*, so the telemetry-fed runtime path MUST pass smoothed
    /// **windowed/EWMA estimates**
    /// ([`MtbfEstimator`](crate::control::telemetry::MtbfEstimator) /
    /// [`BwEstimator`](crate::control::telemetry::BwEstimator)), never
    /// raw window samples: one lucky failure-free window reads as
    /// "MTBF = ∞" and would collapse the full-checkpoint frequency (the
    /// interval explodes), while one quick failure reads as "MTBF ≈ 0"
    /// and would collapse the interval to 1. The
    /// [`Actuator`](crate::control::actuate::Actuator) is the only
    /// runtime caller and owns the estimators; monotonicity of the
    /// resulting actuation in the estimated MTBF is property-tested in
    /// `control/actuate.rs`.
    pub fn observe(&mut self, measured_mtbf: f64, measured_bw: f64) {
        self.params.mtbf = measured_mtbf;
        self.params.write_bw = measured_bw;
        let (want_fcf, want_bs) = optimal_config_integer(&self.params, self.iter_time);
        self.fcf_interval = step_toward(self.fcf_interval as i64, want_fcf as i64).max(1) as u64;
        self.batch_size = step_toward(self.batch_size as i64, want_bs as i64).max(1) as usize;
    }

    /// Feedback from the background chain compactor: replaying `raw_steps`
    /// differential steps touched only `objects_replayed` storage objects
    /// (merged spans batch per-object fetch/decode overhead, which is what
    /// R_D models), so the effective per-step merge cost shrinks by that
    /// ratio. Eq. (8)'s `R_D/2·(1/(f·b)−1)` recovery term — the one that
    /// dominates at high checkpoint frequency — shrinks with it, and the
    /// Eq. (10) optimum moves toward *less* frequent full checkpoints
    /// (f* ∝ ∛R_D): compaction lets the same wasted-time budget buy a
    /// longer, cheaper-to-replay chain.
    pub fn observe_compaction(&mut self, raw_steps: u64, objects_replayed: u64) {
        if raw_steps == 0 {
            return;
        }
        let floor = 1.0 / raw_steps.max(1) as f64;
        let ratio = (objects_replayed as f64 / raw_steps as f64).clamp(floor, 1.0);
        self.params.r_diff = self.r_diff_base * ratio;
    }
}

fn step_toward(cur: i64, want: i64) -> i64 {
    // geometric-ish stepping: move at most 25% of the gap, at least 1
    match want.cmp(&cur) {
        std::cmp::Ordering::Equal => cur,
        std::cmp::Ordering::Greater => cur + ((want - cur + 3) / 4).max(1),
        std::cmp::Ordering::Less => cur - ((cur - want + 3) / 4).max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn params() -> SystemParams {
        // GPT2-L-flavored numbers: S = 8.7 GB, W = 2.5 GB/s,
        // R_F = S/W ≈ 3.5 s, R_D small, times in seconds
        SystemParams {
            n_gpus: 8.0,
            mtbf: 3600.0,
            write_bw: 2.5e9,
            full_size: 8.7e9,
            total_time: 24.0 * 3600.0,
            r_full: 3.5,
            r_diff: 0.2,
        }
    }

    #[test]
    fn closed_form_is_stationary_point() {
        // numeric gradient at (f*, b*) vanishes
        let p = params();
        let (f, b) = optimal_config(&p);
        assert!(f > 0.0 && b > 0.0);
        let h = 1e-7;
        let dfdf = (wasted_time(&p, f * (1.0 + h), b) - wasted_time(&p, f * (1.0 - h), b))
            / (2.0 * f * h);
        let dfdb = (wasted_time(&p, f, b * (1.0 + h)) - wasted_time(&p, f, b * (1.0 - h)))
            / (2.0 * b * h);
        let scale = wasted_time(&p, f, b);
        assert!(dfdf.abs() * f / scale < 1e-3, "df/df = {dfdf}");
        assert!(dfdb.abs() * b / scale < 1e-3, "df/db = {dfdb}");
    }

    #[test]
    fn optimum_beats_neighbors() {
        let p = params();
        let (f, b) = optimal_config(&p);
        let best = wasted_time(&p, f, b);
        for (mf, mb) in [(0.5, 1.0), (2.0, 1.0), (1.0, 0.5), (1.0, 2.0), (3.0, 3.0)] {
            assert!(
                wasted_time(&p, f * mf, b * mb) >= best,
                "({mf},{mb}) beats optimum"
            );
        }
    }

    #[test]
    fn wasted_time_u_shape_in_fcf() {
        // Table I row structure: too-low and too-high FCF both hurt
        let p = params();
        let (f, b) = optimal_config(&p);
        let low = wasted_time(&p, f / 10.0, b);
        let high = wasted_time(&p, f * 10.0, b);
        let best = wasted_time(&p, f, b);
        assert!(low > best && high > best);
    }

    #[test]
    fn u_shape_in_batch_size() {
        // Table I column structure
        let p = params();
        let (f, b) = optimal_config(&p);
        assert!(wasted_time(&p, f, b / 8.0) > wasted_time(&p, f, b));
        assert!(wasted_time(&p, f, b * 8.0) > wasted_time(&p, f, b));
    }

    #[test]
    fn more_failures_want_more_frequent_fulls() {
        let p = params();
        let mut p2 = p;
        p2.mtbf = p.mtbf / 4.0;
        let (f1, _) = optimal_config(&p);
        let (f2, _) = optimal_config(&p2);
        assert!(f2 > f1, "lower MTBF should raise full-ckpt frequency");
    }

    #[test]
    fn faster_storage_wants_more_frequent_fulls_smaller_batches() {
        let p = params();
        let mut p2 = p;
        p2.write_bw = p.write_bw * 8.0;
        let (f1, b1) = optimal_config(&p);
        let (f2, b2) = optimal_config(&p2);
        assert!(f2 > f1);
        assert!(b2 < b1);
    }

    #[test]
    fn integer_config_sane() {
        let p = params();
        let (fcf, bs) = optimal_config_integer(&p, 1.9);
        assert!(fcf >= 1 && fcf < 100_000);
        assert!((1..=64).contains(&bs));
    }

    /// Plausible random system parameters for the property tests.
    fn arb_params(rng: &mut Rng) -> SystemParams {
        let write_bw = 1e8 + rng.next_f64() * 1e10;
        let full_size = 1e8 + rng.next_f64() * 2e10;
        SystemParams {
            n_gpus: 1.0 + (rng.range(0, 128) as f64),
            mtbf: 60.0 + rng.next_f64() * 36_000.0,
            write_bw,
            full_size,
            total_time: 1e4 + rng.next_f64() * 1e6,
            r_full: full_size / write_bw,
            r_diff: 0.01 + rng.next_f64() * 2.0,
        }
    }

    #[test]
    fn wasted_time_monotone_in_r_diff_property() {
        // The compaction feedback hook is sound only if lowering the
        // effective R_D can never RAISE modeled wasted time. That holds
        // whenever the chain is longer than one diff per recovery
        // (f·b < 1), which is the entire frequent-checkpointing regime
        // Eq. (8) models.
        prop_check("wasted_time_monotone_r_diff", 64, |rng| {
            let mut p = arb_params(rng);
            let b = 1.0 + (rng.range(0, 8) as f64);
            // f·b < 1 by construction
            let f = (rng.next_f64() * 0.99 / b).max(1e-9);
            let r_lo = 0.01 + rng.next_f64();
            let r_hi = r_lo + 0.01 + rng.next_f64();
            p.r_diff = r_lo;
            let w_lo = wasted_time(&p, f, b);
            p.r_diff = r_hi;
            let w_hi = wasted_time(&p, f, b);
            prop_assert!(
                w_hi >= w_lo,
                "wasted_time must not decrease in r_diff: {w_lo} -> {w_hi} (f={f}, b={b})"
            );
            Ok(())
        });
    }

    #[test]
    fn stepwise_tuner_converges_to_closed_form_property() {
        // from any perturbed start, repeated observations of fixed runtime
        // metrics walk (FCF, BS) to within one step of the Eq. (10)
        // integer optimum
        prop_check("tuner_converges_closed_form", 24, |rng| {
            let p = arb_params(rng);
            let iter_time = 0.1 + rng.next_f64() * 5.0;
            let mut t = AdaptiveTuner::new(p, iter_time);
            let (want_fcf, want_bs) = optimal_config_integer(&p, iter_time);
            t.fcf_interval = (want_fcf * (1 + rng.range(0, 64) as u64)).max(1);
            t.batch_size = rng.range(1, 512);
            for _ in 0..600 {
                t.observe(p.mtbf, p.write_bw);
            }
            prop_assert!(
                (t.fcf_interval as i64 - want_fcf as i64).abs() <= 1,
                "fcf {} !~ {want_fcf}",
                t.fcf_interval
            );
            prop_assert!(
                (t.batch_size as i64 - want_bs as i64).abs() <= 1,
                "bs {} !~ {want_bs}",
                t.batch_size
            );
            Ok(())
        });
    }

    #[test]
    fn compaction_feedback_lowers_r_diff_and_full_frequency() {
        let p = params();
        let mut t = AdaptiveTuner::new(p, 1.9);
        let (f_before, _) = optimal_config(&t.params);
        let w_before = {
            let (f, b) = optimal_config(&t.params);
            wasted_time(&t.params, f, b)
        };
        // the compactor reports: 8 raw steps replayed as 2 merged objects
        t.observe_compaction(8, 2);
        assert!((t.params.r_diff - p.r_diff * 0.25).abs() < 1e-12);
        let (f_after, _) = optimal_config(&t.params);
        assert!(
            f_after < f_before,
            "cheaper replay must lower the optimal full-checkpoint frequency"
        );
        let w_after = {
            let (f, b) = optimal_config(&t.params);
            wasted_time(&t.params, f, b)
        };
        assert!(w_after < w_before, "compaction must lower modeled wasted time at the optimum");
        // uncompacted report restores the base cost; ratios clamp to (0, 1]
        t.observe_compaction(8, 8);
        assert_eq!(t.params.r_diff, p.r_diff);
        t.observe_compaction(8, 20);
        assert_eq!(t.params.r_diff, p.r_diff, "ratio clamps at 1");
        t.observe_compaction(0, 0);
        assert_eq!(t.params.r_diff, p.r_diff, "empty report is a no-op");
    }

    #[test]
    fn tuner_converges_toward_model_optimum() {
        let p = params();
        let mut t = AdaptiveTuner::new(p, 1.9);
        // perturb away from optimum
        t.fcf_interval = 10_000;
        t.batch_size = 64;
        let (want_fcf, want_bs) = optimal_config_integer(&t.params, 1.9);
        for _ in 0..200 {
            t.observe(p.mtbf, p.write_bw);
        }
        assert!(
            (t.fcf_interval as i64 - want_fcf as i64).abs() <= 1,
            "{} vs {want_fcf}",
            t.fcf_interval
        );
        assert!((t.batch_size as i64 - want_bs as i64).abs() <= 1);
    }
}
