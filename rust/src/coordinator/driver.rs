//! The real-engine training driver: actual PJRT compute, actual collectives,
//! actual checkpoint I/O, actual recovery — every strategy of the paper's
//! evaluation behind one loop so their costs are measured identically.
//!
//! Per iteration (paper §II-A):
//!   1. fwd+bwd per worker (`grads` artifact — L2 autodiff)
//!   2. per-worker top-k compression with error feedback (`compress`
//!      artifact — L1 Pallas) unless the strategy is non-compressed
//!   3. gradient sync: sparse union allgather (compressed) or ring
//!      allreduce (dense) — `collective`
//!   4. strategy checkpoint hook (the only part that differs)
//!   5. Adam update (`adam` artifact — L1 Pallas)
//!   6. failure-injector poll → recovery if due
//!
//! Checkpoint-induced time on the *training thread* is what the paper calls
//! stalls; everything the checkpointing thread does overlaps with training.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::batched::BatchMode;
use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::format::{model_signature, PayloadCodec};
use crate::checkpoint::full::write_full;
use crate::checkpoint::manifest::Manifest;
use crate::cluster::{self, Cluster, ClusterConfig, Detector, HeartbeatTable};
use crate::collective::sparse_allgather_sum;
use crate::compress::topk_mask_with_scratch;
use crate::control::actuate::{Actuator, ActuatorConfig, ControlState, Retune};
use crate::control::http::{ControlView, ObsServer, ObsState, ReportGauges};
use crate::control::iosched::{autoscale_budget, IoGate, IoGateConfig};
use crate::control::telemetry::TelemetryBus;
use crate::control::trace::{Tracer, TRACE_OBJECT};
use crate::pipeline::Scrubber;
use crate::coordinator::checkpointer::{Checkpointer, CkptConfig, CkptItem};
use crate::coordinator::config_opt::SystemParams;
use crate::coordinator::failure::{FailureInjector, FailureKind};
use crate::coordinator::lowdiff_plus::{LowDiffPlus, PlusConfig};
use crate::coordinator::metrics::RunReport;
use crate::coordinator::recovery::{recover, RecoveryMode};
use crate::optim::{Adam, ModelState};
use crate::runtime::ModelRuntime;
use crate::sparse::SparseGrad;
use crate::storage::{Namespaced, Observed, StorageBackend, StorageObs};
use crate::tensor::Flat;
use crate::util::rng::Rng;

/// Which checkpointing system runs this training job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    /// no checkpointing (the W/O CKPT upper bound of Exp. 1)
    None,
    /// the paper's system: reuse compressed gradients as differentials
    LowDiff,
    /// §VI: non-compressed, layer-wise reuse + CPU replica
    LowDiffPlus,
    /// Check-N-Run-style: compress the 3Ψ state delta every iteration
    NaiveDc,
    /// CheckFreq-style: decoupled snapshot + async persist of full state
    CheckFreq,
    /// Gemini-style: per-iteration full checkpoint to CPU memory tier +
    /// periodic disk persistence
    Gemini,
    /// torch.save baseline: synchronous full checkpoint on the training path
    TorchSave,
}

impl StrategyKind {
    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::None => "wo-ckpt",
            StrategyKind::LowDiff => "lowdiff",
            StrategyKind::LowDiffPlus => "lowdiff+",
            StrategyKind::NaiveDc => "naive-dc",
            StrategyKind::CheckFreq => "checkfreq",
            StrategyKind::Gemini => "gemini",
            StrategyKind::TorchSave => "torch-save",
        }
    }

    pub fn parse(s: &str) -> Option<StrategyKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" | "wo-ckpt" | "wo" => StrategyKind::None,
            "lowdiff" => StrategyKind::LowDiff,
            "lowdiff+" | "lowdiffplus" | "lowdiff-plus" => StrategyKind::LowDiffPlus,
            "naive-dc" | "naivedc" | "dc" => StrategyKind::NaiveDc,
            "checkfreq" => StrategyKind::CheckFreq,
            "gemini" => StrategyKind::Gemini,
            "torch-save" | "torchsave" | "baseline" => StrategyKind::TorchSave,
            _ => return None,
        })
    }
}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub strategy: StrategyKind,
    /// productive iterations to complete
    pub iters: u64,
    /// data-parallel workers (logical; executed in-process)
    pub workers: usize,
    /// diff checkpoint every iteration (the paper's headline frequency);
    /// >1 lowers the frequency
    pub diff_every: u64,
    /// full-checkpoint interval in iterations (FCF)
    pub full_every: u64,
    /// batching size (BS, §V-B)
    pub batch_size: usize,
    pub batch_mode: BatchMode,
    pub codec: PayloadCodec,
    /// zstd compression level for zstd-backed payload codecs
    /// (`--zstd-level`); higher = smaller objects, more encode CPU
    pub zstd_level: i32,
    /// encode periodic fulls as XOR-vs-previous-full deltas (depth ≤ 1,
    /// re-anchored on a fixed cadence) — flat LowDiff runtime only
    pub delta_fulls: bool,
    pub queue_capacity: usize,
    pub seed: u64,
    /// failure MTBF in wall-seconds (None = no failures)
    pub mtbf_secs: Option<f64>,
    /// fraction of failures that are software (recoverable in-memory)
    pub p_software: f64,
    pub recovery_mode: RecoveryMode,
    /// evaluate loss every this many iterations
    pub eval_every: u64,
    /// snapshot pool size for LowDiff+
    pub snapshot_threads: usize,
    /// shards per checkpoint object (>1 routes persistence through the
    /// sharded async storage engine)
    pub n_shards: usize,
    /// storage writer-pool threads for the sharded engine
    pub writers: usize,
    /// cluster ranks: >1 partitions the state at tensor boundaries and
    /// runs the multi-rank cluster runtime (per-rank differential chains
    /// + two-phase global commit) instead of the single checkpointer —
    /// LowDiff strategy only
    pub ranks: usize,
    /// background chain compaction: merge every run of this many persisted
    /// raw diff objects into one `MergedDiff` span (bounds recovery replay
    /// at ⌈n/compact_every⌉ objects per chain); < 2 disables
    pub compact_every: usize,
    /// closed-loop §V-C control plane (`--adaptive`): measure MTBF /
    /// write bandwidth / replay ratio at runtime and retune
    /// `full_every`, `batch_size` and `compact_every` live at epoch
    /// boundaries (LowDiff strategy, flat and cluster runtimes)
    pub adaptive: bool,
    /// background-I/O byte budget for compaction's token-bucket gate
    /// (`--io-budget`, bytes/sec); <= 0 leaves the bucket open
    pub io_budget: f64,
    /// observability plane (`--serve ADDR`): bind a threaded mini-HTTP
    /// server exposing `/stats`, `/metrics`, `/trace`, `/chain` and the
    /// `POST /retune` / `POST /compact` control endpoints
    pub serve: Option<String>,
    /// event tracing (`--trace`): record per-stage spans into a ring
    /// buffer and persist a chrome://tracing JSONL journal beside the
    /// chain at every control tick and at run end
    pub trace: bool,
    /// heartbeat failure detection (`--heartbeat-timeout SECS`, cluster
    /// runtime): a rank silent for this long past the newest beat is
    /// declared dead and recovered through the same consistent-cut path
    /// injected deaths use; <= 0 disables
    pub heartbeat_timeout: f64,
    /// storage-plane slow-op threshold (`--slow-io-ms`): an observed
    /// storage op at or above this latency bumps the slow counters and
    /// emits an `io.slow.*` trace event; 0 disables
    pub slow_io_ms: u64,
    /// size cap for the persisted trace journal
    /// (`--trace-journal-max-kb`): the newest events that fit are kept,
    /// oldest dropped first, drops reported in the trace summary
    pub trace_journal_max_kb: usize,
    /// background chain-scrubbing interval in seconds (`--scrub-secs`):
    /// every interval the scrubber re-verifies the committed cover and
    /// repairs damaged fast-tier copies from the durable tier; 0 spawns
    /// the scrubber on-demand-only (`POST /scrub`) when the
    /// observability plane is up
    pub scrub_secs: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            strategy: StrategyKind::LowDiff,
            iters: 50,
            workers: 1,
            diff_every: 1,
            full_every: 20,
            batch_size: 2,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            zstd_level: crate::checkpoint::format::DEFAULT_ZSTD_LEVEL,
            delta_fulls: false,
            queue_capacity: 8,
            seed: 42,
            mtbf_secs: None,
            p_software: 0.7,
            recovery_mode: RecoveryMode::SerialReplay,
            eval_every: 10,
            snapshot_threads: 2,
            n_shards: 1,
            writers: 1,
            ranks: 1,
            compact_every: 0,
            adaptive: false,
            io_budget: 0.0,
            serve: None,
            trace: false,
            heartbeat_timeout: 0.0,
            slow_io_ms: 100,
            trace_journal_max_kb: 256,
            scrub_secs: 0.0,
        }
    }
}

impl TrainConfig {
    /// True when persistence runs on the multi-rank cluster runtime.
    pub fn uses_cluster(&self) -> bool {
        self.ranks > 1 && self.strategy == StrategyKind::LowDiff
    }
}

/// Is a periodic-full action due at `target`? `every = 0` is the
/// `full_every = ∞` full-free mode — the base full written at anchor
/// time is the only one; every later persist is a diff plus hierarchical
/// background merging. Shared by every `full_every`-cadenced site so no
/// strategy arm ever computes `target % 0`.
pub fn full_due(target: u64, every: u64) -> bool {
    every != 0 && target % every == 0
}

/// Control ticks need a cadence even with the full-epoch boundary gone
/// (`full_every = 0`): tick every this many iterations in full-free runs
/// (retunes still apply at safe points — checkpointer queue order /
/// committed cluster records — so an off-epoch tick cannot tear a chain).
const FULL_FREE_TICK_EVERY: u64 = 64;

/// Deterministic synthetic corpus: a fixed bank of zipf-token "sentences"
/// the model can actually learn (loss falls well below ln(vocab)).
pub struct Corpus {
    sentences: Vec<Vec<i32>>,
    vocab: usize,
}

impl Corpus {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let n_sentences = 64;
        let sentences = (0..n_sentences)
            .map(|_| {
                (0..seq_len)
                    .map(|_| rng.zipf(vocab, 1.1) as i32)
                    .collect::<Vec<i32>>()
            })
            .collect();
        Corpus { sentences, vocab }
    }

    /// Batch for (step, worker) — deterministic, so re-running a lost
    /// iteration after recovery replays identical data.
    pub fn batch(&self, step: u64, worker: usize, batch: usize, seq_len: usize) -> Vec<i32> {
        let mut rng = Rng::new(step.wrapping_mul(0x9E37_79B9).wrapping_add(worker as u64));
        let mut out = Vec::with_capacity(batch * seq_len);
        for _ in 0..batch {
            let s = &self.sentences[rng.range(0, self.sentences.len())];
            out.extend_from_slice(&s[..seq_len]);
        }
        out
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

/// Run one training job under `cfg`, writing checkpoints to `store`.
pub fn train(
    mrt: &ModelRuntime,
    store: Arc<dyn StorageBackend>,
    cfg: &TrainConfig,
) -> Result<RunReport> {
    let layout = &mrt.layout;
    let n = layout.n_params;
    let sig = model_signature(&layout.model, n);
    let adam = Adam { lr: layout.lr as f32 };
    let corpus = Corpus::new(layout.vocab, layout.seq_len, cfg.seed);
    let mut report = RunReport::new(cfg.strategy.name(), &layout.model, cfg.workers);
    let wall0 = Instant::now();

    // initial state from the lowered init artifact
    let params0 = mrt.init(cfg.seed as i32)?;
    let mut state = ModelState::new(params0.clone());
    let mut residuals: Vec<Flat> = vec![Flat::zeros(n); cfg.workers];

    let mut injector = match cfg.mtbf_secs {
        Some(m) => FailureInjector::new(m, cfg.p_software, cfg.seed ^ 0xFA11),
        None => FailureInjector::never(),
    };

    report.ranks = if cfg.uses_cluster() { cfg.ranks } else { 1 };

    // the runtime control plane (docs/CONTROL.md): a telemetry bus shared
    // with the checkpointing processes, and the closed-loop actuator that
    // retunes the EFFECTIVE config below — `eff` starts as the configured
    // values and is what the loop consults, so a retune applies from the
    // next epoch without mutating the caller's config
    let mut eff = cfg.clone();
    let adaptive_strategy = matches!(
        cfg.strategy,
        StrategyKind::LowDiff
            | StrategyKind::LowDiffPlus
            | StrategyKind::CheckFreq
            | StrategyKind::Gemini
    );
    // the observability plane (docs/OBSERVABILITY.md) rides on the same
    // telemetry bus the §V-C loop uses, so asking for it brings the bus up
    // even in non-adaptive runs; the ACTUATOR stays gated on `--adaptive`
    let wants_obs = cfg.serve.is_some() || cfg.trace || cfg.heartbeat_timeout > 0.0;
    let bus: Option<Arc<TelemetryBus>> =
        ((cfg.adaptive && adaptive_strategy) || wants_obs).then(|| Arc::new(TelemetryBus::new()));
    let mut actuator: Option<Actuator> = None;
    // estimator state persisted by an earlier incarnation beside the chain:
    // warm-starts the actuator so a restart keeps its measured MTBF/BW
    // instead of re-learning from priors
    let saved_control: Option<ControlState> = ControlState::load(store.as_ref());
    let tracer: Option<Arc<Tracer>> = cfg.trace.then(|| Arc::new(Tracer::default()));
    // ONE driver-owned I/O gate shared with every spawned write path, so
    // live `set_rate` retunes (interference autoscaling, POST /retune)
    // reach the token bucket all persists and compaction passes pay
    let gate: Option<Arc<IoGate>> = bus.is_some().then(|| {
        Arc::new(IoGate::with_obs(
            IoGateConfig { bytes_per_sec: cfg.io_budget, ..IoGateConfig::default() },
            bus.clone(),
            tracer.clone(),
        ))
    });
    let with_hb = cfg.heartbeat_timeout > 0.0 && cfg.uses_cluster();
    let heartbeats: Option<Arc<HeartbeatTable>> =
        with_hb.then(|| Arc::new(HeartbeatTable::new(cfg.ranks)));
    let detector: Option<Detector> = heartbeats.as_ref().map(|t| {
        let poll = Duration::from_secs_f64((cfg.heartbeat_timeout / 4.0).clamp(0.001, 0.1));
        Detector::spawn(Arc::clone(t), Duration::from_secs_f64(cfg.heartbeat_timeout), poll)
    });

    // the storage-plane observability registry (docs/OBSERVABILITY.md):
    // wrap the durable root in the [`Observed`] middleware so every
    // physical op below this point is histogrammed per tier/op/family and
    // ops past `--slow-io-ms` are traced; the rank namespaces and the
    // in-memory fast tier get their own labels further down
    let storage_obs: Option<Arc<StorageObs>> =
        wants_obs.then(|| Arc::new(StorageObs::new(cfg.slow_io_ms)));
    let store: Arc<dyn StorageBackend> = match &storage_obs {
        Some(so) => {
            Arc::new(Observed::new(store, Arc::clone(so), "durable").with_trace(tracer.clone()))
        }
        None => store,
    };

    // per-strategy checkpointing processes
    let mem_tier: Arc<dyn StorageBackend> = Arc::new(crate::storage::MemStore::new());
    let mem_tier: Arc<dyn StorageBackend> = match &storage_obs {
        Some(so) => Arc::new(Observed::new(mem_tier, Arc::clone(so), "memory")),
        None => mem_tier,
    };
    // recovery/GC interop must see logical objects even when the
    // checkpointer writes them sharded; the cluster runtime builds its own
    // shard-aware views, so it gets the raw store
    let logical: Arc<dyn StorageBackend> =
        if !cfg.uses_cluster() && (cfg.n_shards > 1 || cfg.writers > 1) {
            Arc::new(crate::storage::Sharded::new(Arc::clone(&store), 1, 1))
        } else {
            Arc::clone(&store)
        };
    // the background chain scrubber (docs/OBSERVABILITY.md): continuous
    // re-verification of the committed cover through the logical view
    // (shard indexes verify transitively), reads shaped through the same
    // I/O gate compaction pays; interval 0 = on-demand only (POST /scrub)
    let scrubber: Option<Scrubber> = (wants_obs || cfg.scrub_secs > 0.0).then(|| {
        Scrubber::spawn_obs(
            Arc::clone(&logical),
            Duration::from_secs_f64(cfg.scrub_secs.max(0.0)),
            gate.clone(),
            tracer.clone(),
        )
    });
    // the observability/control HTTP plane: reads ride the bus/tracer/
    // heartbeat handles directly; writes (POST /retune, /compact, /scrub)
    // park in the ObsState and the driver drains them at the same safe
    // points the §V-C actuator uses — the server itself never touches a
    // knob
    let obs: Option<Arc<ObsState>> = wants_obs.then(|| {
        let obs_bus = Arc::clone(bus.as_ref().expect("observability implies a telemetry bus"));
        let mut st = ObsState::new(
            obs_bus,
            tracer.clone(),
            heartbeats.clone(),
            Some(Arc::clone(&logical)),
        )
        .with_heartbeat_timeout(cfg.heartbeat_timeout);
        if let Some(so) = &storage_obs {
            st = st.with_storage_obs(Arc::clone(so));
        }
        if let Some(s) = &scrubber {
            st = st.with_scrub(s.live_handle());
        }
        Arc::new(st)
    });
    if let Some(o) = &obs {
        o.set_control(ControlView {
            strategy: cfg.strategy.name().into(),
            adaptive: cfg.adaptive,
            io_budget: cfg.io_budget,
            ..ControlView::default()
        });
    }
    let mut server: Option<ObsServer> = match (&cfg.serve, &obs) {
        (Some(addr), Some(st)) => {
            let s = ObsServer::serve(Arc::clone(st), addr)?;
            log::info!("observability plane listening on http://{}", s.local_addr());
            Some(s)
        }
        _ => None,
    };
    let handles = ObsHandles {
        bus: bus.clone(),
        gate: gate.clone(),
        trace: tracer.clone(),
        heartbeats: heartbeats.clone(),
        storage: storage_obs.clone(),
    };
    // interference-autoscaling window trackers (deltas between ticks)
    let mut last_deferred = 0.0f64;
    let mut last_contended = 0u64;
    let mut last_tick_elapsed = 0.0f64;

    let mut procs = spawn_procs(&eff, sig, layout, &state, &store, &mem_tier, &handles);
    // anchor the differential chain: a recovery needs a base full
    // checkpoint (Eq. (6) starts from C^F) — in the full-free mode this is
    // the ONLY full the run ever writes
    anchor_chain(&mut procs, &state, &mut report);
    // step the current chain re-based at, for the full-free actuator's
    // chain-object estimate
    let mut anchor_step: u64 = state.step;

    let mut step: u64 = state.step; // completed productive steps
    let mut prev_state_for_dc: Option<ModelState> = if cfg.strategy == StrategyKind::NaiveDc {
        Some(state.clone())
    } else {
        None
    };
    // caller-owned top-k magnitude scratch: Naive DC compresses a 3Ψ delta
    // every diff interval; the scratch is allocated once, not per iteration
    let mut topk_scratch: Vec<f32> = Vec::new();
    let max_attempts = cfg.iters * 5 + 100;
    let mut attempts = 0u64;

    while step < cfg.iters {
        attempts += 1;
        anyhow::ensure!(attempts < max_attempts, "failure storm: run cannot make progress");
        let target = step + 1;
        let stall_before = report.stall_secs + report.queue_blocked_secs;

        // ---- 1. fwd/bwd per worker --------------------------------------
        let t0 = Instant::now();
        let mut worker_grads: Vec<Flat> = Vec::with_capacity(cfg.workers);
        let mut loss_sum = 0f32;
        for w in 0..cfg.workers {
            let tokens = corpus.batch(target, w, layout.batch, layout.seq_len);
            let (loss, g) = mrt.grads(&state.params, &tokens)?;
            loss_sum += loss;
            worker_grads.push(g);
        }
        let loss = loss_sum / cfg.workers as f32;
        report.compute_secs += t0.elapsed().as_secs_f64();

        // ---- 2+3. compress & sync ---------------------------------------
        let compressed = cfg.strategy != StrategyKind::LowDiffPlus;
        let (grad, cgrad_for_reuse) = if compressed {
            let t0 = Instant::now();
            let mut masked: Vec<SparseGrad> = Vec::with_capacity(cfg.workers);
            for (w, g) in worker_grads.iter().enumerate() {
                let (m, new_res, _t) = mrt.compress(g, &residuals[w])?;
                residuals[w] = new_res;
                masked.push(SparseGrad::from_dense(&m));
            }
            report.compute_secs += t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let mut merged = sparse_allgather_sum(&masked);
            for v in merged.values.iter_mut() {
                *v /= cfg.workers as f32;
            }
            report.sync_secs += t1.elapsed().as_secs_f64();
            let dense = merged.to_dense();
            (dense, Some(merged))
        } else {
            let t1 = Instant::now();
            let mut bufs = worker_grads;
            crate::collective::ring_allreduce_mean(&mut bufs);
            report.sync_secs += t1.elapsed().as_secs_f64();
            (bufs.pop().unwrap(), None)
        };
        drop(cgrad_for_reuse); // reuse path uses `grad` dense-masked below

        // ---- 4. strategy checkpoint hook (pre-update part) --------------
        let grad = Arc::new(grad);
        let tstall = Instant::now();
        match (&mut procs, cfg.strategy) {
            (Procs::LowDiff { ckpt }, StrategyKind::LowDiff) => {
                if target % eff.diff_every == 0 {
                    // the reuse: the synced compressed gradient IS the
                    // differential checkpoint — zero extra computation
                    report.queue_blocked_secs += ckpt
                        .queue
                        .put(target, Arc::new(CkptItem::DiffDense((*grad).clone())))
                        .as_secs_f64();
                    report.diff_ckpts += 1;
                }
            }
            (Procs::Cluster { cluster }, StrategyKind::LowDiff) => {
                if target % eff.diff_every == 0 {
                    // the rank fan-out: one Ψ-sized slice copy on the
                    // training path; compaction/encode/IO on rank threads
                    report.queue_blocked_secs +=
                        cluster.put_diff_dense(target, &grad).as_secs_f64();
                    report.diff_ckpts += 1;
                }
            }
            (Procs::Plus { plus }, StrategyKind::LowDiffPlus) => {
                // layer-wise zero-copy reuse of the raw gradient
                report.queue_blocked_secs +=
                    plus.put_step(target, Arc::clone(&grad), layout).as_secs_f64();
                report.diff_ckpts += 1;
            }
            _ => {}
        }
        report.stall_secs += tstall.elapsed().as_secs_f64();

        // ---- 5. Adam update (L1 Pallas via PJRT) ------------------------
        let t0 = Instant::now();
        let (p2, m2, v2) = mrt.adam(&state.params, &state.m, &state.v, &grad, target)?;
        state = ModelState { params: p2, m: m2, v: v2, step: target };
        report.compute_secs += t0.elapsed().as_secs_f64();
        drop(grad);

        // ---- 4b. post-update checkpoint hooks ---------------------------
        let tstall = Instant::now();
        match (&mut procs, cfg.strategy) {
            (Procs::LowDiff { ckpt }, StrategyKind::LowDiff) => {
                if full_due(target, eff.full_every) {
                    let snap = state.clone(); // snapshot stall
                    ckpt.queue.put(target, Arc::new(CkptItem::Full(snap)));
                    report.full_ckpts += 1;
                    anchor_step = target;
                }
            }
            (Procs::Cluster { cluster }, StrategyKind::LowDiff) => {
                if full_due(target, eff.full_every) {
                    // slice fan-out is the snapshot copy, one rank at a time
                    report.queue_blocked_secs +=
                        cluster.put_full(target, &state).as_secs_f64();
                    report.full_ckpts += 1;
                    anchor_step = target;
                }
            }
            (Procs::NaiveDc { ckpt }, StrategyKind::NaiveDc) => {
                // Challenge 1 made concrete: compress the 3Ψ state delta on
                // the training path, every diff interval
                if target % eff.diff_every == 0 {
                    let prev = prev_state_for_dc.as_ref().unwrap();
                    let mut delta = Vec::with_capacity(3 * n);
                    delta.extend(Flat::diff(&state.params, &prev.params).0);
                    delta.extend(Flat::diff(&state.m, &prev.m).0);
                    delta.extend(Flat::diff(&state.v, &prev.v).0);
                    let k = ((layout.rho * (3 * n) as f64) as usize).max(1);
                    // compression stall (scratch reused across iterations)
                    let masked = topk_mask_with_scratch(&Flat(delta), k, &mut topk_scratch);
                    let sparse = SparseGrad::from_dense(&masked);
                    report.queue_blocked_secs += ckpt
                        .queue
                        .put(
                            target,
                            Arc::new(CkptItem::DiffSparse(DiffPayload::StateDelta(sparse))),
                        )
                        .as_secs_f64();
                    report.diff_ckpts += 1;
                }
                if full_due(target, eff.full_every) {
                    ckpt.queue.put(target, Arc::new(CkptItem::Full(state.clone())));
                    report.full_ckpts += 1;
                }
                prev_state_for_dc = Some(state.clone());
            }
            (Procs::LowDiff { ckpt }, StrategyKind::CheckFreq) => {
                // CheckFreq: snapshot (copy) on the training path every
                // interval; persist decoupled on the checkpointer thread.
                // A busy persist pipeline back-pressures through the queue.
                if full_due(target, eff.full_every) {
                    let snap = state.clone();
                    report.queue_blocked_secs += ckpt
                        .queue
                        .put(target, Arc::new(CkptItem::Full(snap)))
                        .as_secs_f64();
                    report.full_ckpts += 1;
                }
            }
            (Procs::Gemini { mem, disk }, StrategyKind::Gemini) => {
                // per-iteration full snapshot into the CPU-memory tier
                let snap = state.clone();
                report.queue_blocked_secs += mem
                    .queue
                    .put(target, Arc::new(CkptItem::Full(snap)))
                    .as_secs_f64();
                report.full_ckpts += 1;
                if full_due(target, eff.full_every) {
                    disk.queue.put(target, Arc::new(CkptItem::Full(state.clone())));
                }
            }
            (Procs::Sync, StrategyKind::TorchSave) => {
                // fully synchronous torch.save: encode + write on the
                // training path (the Exp. 1 worst case)
                if full_due(target, eff.full_every) {
                    let bytes = write_full(&state, sig, cfg.codec)?;
                    report.bytes_written += bytes.len() as u64;
                    report.writes += 1;
                    store.put(&Manifest::full_name(target), &bytes)?;
                    let _ = Manifest::gc(store.as_ref());
                    report.full_ckpts += 1;
                }
            }
            _ => {}
        }
        report.stall_secs += tstall.elapsed().as_secs_f64();

        // ---- 4c. control plane: telemetry + epoch-boundary actuation ----
        if let Some(bus) = &bus {
            bus.record_step(
                (report.stall_secs + report.queue_blocked_secs - stall_before).max(0.0),
            );
            // safe point: a full-checkpoint epoch boundary — the chain
            // re-bases here, so a new (FCF, BS, mf) can't tear a batch or
            // a committed epoch mid-flight. Full-free runs have no epoch
            // boundary, so they tick on a fixed cadence instead; the knobs
            // still apply at safe points (checkpointer queue order /
            // committed cluster records)
            let tick_due = if eff.full_every == 0 {
                target % FULL_FREE_TICK_EVERY == 0
            } else {
                target % eff.full_every == 0
            };
            if tick_due {
                if cfg.adaptive && adaptive_strategy {
                    let iter_time = (wall0.elapsed().as_secs_f64() / target as f64).max(1e-6);
                    let act = actuator.get_or_insert_with(|| {
                        let mut a = make_actuator(cfg, layout, n, &eff, iter_time);
                        if let Some(st) = &saved_control {
                            // satellite: restored estimator accumulators —
                            // the tuner starts from the chain's measured
                            // MTBF/bandwidth, not the cold-start priors
                            a.warm_start(st);
                            log::info!("actuator warm-started from persisted control state");
                        }
                        a
                    });
                    // the hierarchical merge-factor policy steers off the
                    // live chain length: one chain object lands per batch
                    // flush of `batch_size` diffs, `diff_every` steps apart
                    let per_object = eff.diff_every.max(1) * eff.batch_size.max(1) as u64;
                    act.note_chain_objects(target.saturating_sub(anchor_step) / per_object);
                    if let Some(r) = act.tick(bus) {
                        log::info!(
                            "§V-C retune at step {target}: full_every {} -> {}, batch {} -> \
                             {}, compact {} -> {}, codec {} -> {}",
                            eff.full_every,
                            r.full_every,
                            eff.batch_size,
                            r.batch_size,
                            eff.compact_every,
                            r.compact_every,
                            eff.codec.name(),
                            r.codec.name()
                        );
                        apply_retune(r, target, &mut eff, &procs, &mut report);
                    }
                }
                // POST /retune and /compact: operator requests parked by
                // the HTTP plane drain HERE, the same safe point — never
                // mid-batch, never inside an uncommitted cluster epoch
                if let Some(o) = &obs {
                    if let Some(r) = o.take_retune() {
                        log::info!(
                            "manual retune at step {target}: full_every={} batch={} compact={}",
                            r.full_every,
                            r.batch_size,
                            r.compact_every
                        );
                        apply_retune(r, target, &mut eff, &procs, &mut report);
                    }
                    if let Some(mf) = o.take_compact() {
                        let r = Retune {
                            full_every: eff.full_every,
                            batch_size: eff.batch_size,
                            compact_every: mf,
                            codec: eff.codec,
                        };
                        log::info!("manual compaction retune at step {target}: factor {mf}");
                        apply_retune(r, target, &mut eff, &procs, &mut report);
                    }
                    if o.take_scrub() {
                        if let Some(s) = &scrubber {
                            log::info!("manual scrub pass requested at step {target}");
                            s.notify();
                        }
                    }
                }
                // satellite: interference autoscaling — shrink the
                // background budget when this window deferred persists or
                // contended for bytes, grow it back when the window ran
                // clean; all writers share the gate, so set_rate lands
                // everywhere at once
                if cfg.adaptive {
                    if let Some(g) = &gate {
                        let snap = bus.snapshot();
                        let dt = (snap.elapsed_secs - last_tick_elapsed).max(1e-6);
                        let d_def = (snap.deferred_secs - last_deferred).max(0.0);
                        let d_cont = snap.contended_bytes.saturating_sub(last_contended);
                        let bw = actuator.as_ref().map(|a| a.estimates().1).unwrap_or(0.0);
                        let cur = g.rate();
                        let next = autoscale_budget(cur, d_def, d_cont, dt, bw);
                        if (next - cur).abs() > f64::EPSILON {
                            log::debug!("io budget autoscaled: {cur:.3e} -> {next:.3e}");
                            g.set_rate(next);
                            eff.io_budget = next;
                        }
                        last_tick_elapsed = snap.elapsed_secs;
                        last_deferred = snap.deferred_secs;
                        last_contended = snap.contended_bytes;
                    }
                }
                // persist the control state and trace journal beside the
                // chain, and refresh the published /stats control view
                if let Some(act) = &actuator {
                    if let Err(e) = act.export_state().save(store.as_ref()) {
                        log::warn!("control-state persist failed: {e:#}");
                    }
                }
                if let Some(t) = &tracer {
                    let journal =
                        t.to_chrome_jsonl_capped(cfg.trace_journal_max_kb.saturating_mul(1024));
                    if let Err(e) = store.put(TRACE_OBJECT, journal.as_bytes()) {
                        log::warn!("trace journal persist failed: {e:#}");
                    }
                }
                refresh_obs(&obs, cfg, &eff, &actuator, &gate, &report);
            }
        }

        step = target;
        if step % cfg.eval_every == 0 || step == cfg.iters {
            report.losses.push((step, loss));
        }
        report.iter_times.push(wall0.elapsed().as_secs_f64());

        // ---- 6. failure injection + heartbeat detection -----------------
        let mut failure =
            injector.poll_telemetry(wall0.elapsed().as_secs_f64(), bus.as_deref());
        if failure.is_none() {
            if let Some(d) = detector.as_ref().and_then(|d| d.take()) {
                // a rank silent past the timeout: declare it dead and run
                // the SAME consistent-cut recovery an injected hardware
                // death takes — detection changes when we recover, never
                // what we recover to
                log::warn!(
                    "heartbeat detector: rank {} silent past the timeout (last step {})",
                    d.rank,
                    d.step
                );
                report.detected_failures += 1;
                if let Some(b) = &bus {
                    b.record_failure(); // MTBF estimation sees real deaths
                }
                if let Some(t) = &tracer {
                    t.instant("detect.dead", d.rank as u64, d.step, 0);
                }
                failure = Some(FailureKind::Hardware);
            }
        }
        if let Some(kind) = failure {
            report.recoveries += 1;
            let t0 = Instant::now();
            let sp = Tracer::maybe_span(&tracer, "recover.replay").map(|s| s.step(step));
            let (recovered, from_memory) = handle_failure(
                kind, cfg, procs, &logical, &mem_tier, sig, &adam, &params0, &mut report,
            )?;
            drop(sp);
            let lost = step.saturating_sub(recovered.step);
            report.lost_iters += lost;
            log::info!(
                "{} failure at step {step}: recovered to {} ({}, lost {lost} iters)",
                if kind == FailureKind::Software { "software" } else { "hardware" },
                recovered.step,
                if from_memory { "in-memory" } else { "storage" },
            );
            state = recovered;
            step = state.step;
            for r in residuals.iter_mut() {
                *r = Flat::zeros(n); // residuals are process state: lost
            }
            prev_state_for_dc = (cfg.strategy == StrategyKind::NaiveDc).then(|| state.clone());
            // drop differentials from the lost timeline (steps > recovered)
            let _ = Manifest::truncate_after(logical.as_ref(), state.step);
            // restart the checkpointing process (new process after crash),
            // carrying the retuned effective config forward
            procs = spawn_procs(&eff, sig, layout, &state, &store, &mem_tier, &handles);
            anchor_chain(&mut procs, &state, &mut report);
            anchor_step = state.step;
            if let Some(t) = &heartbeats {
                // fresh rank threads, fresh liveness epoch: stale beats
                // (and the just-fired detection) must not re-trigger
                t.reset();
            }
            report.recovery_secs += t0.elapsed().as_secs_f64();
        }
    }

    // graceful shutdown: drain checkpointers, merge their stats
    let was_cluster = matches!(procs, Procs::Cluster { .. });
    finish_procs(procs, &mut report);
    // satellite: the recovery bound must be observable in EVERY run, not
    // just ones that hit a failure — probe the settled chain's cover
    if !was_cluster && cfg.strategy == StrategyKind::LowDiff {
        if let Ok(chain) = Manifest::latest_chain(logical.as_ref()) {
            let objects = chain.full.is_some() as usize + chain.diffs.len();
            let deepest = chain
                .diffs
                .iter()
                .map(|d| Manifest::span_level(&d.2))
                .max()
                .unwrap_or(0);
            report.replay_objects = report.replay_objects.max(objects);
            report.max_level = report.max_level.max(deepest);
        }
    } else if was_cluster {
        // names-only probe of the newest generation's per-rank covers
        let view = crate::storage::Sharded::new(Arc::clone(&store), 1, 1);
        if let (Ok(g), Ok(names)) = (cluster::next_generation(&store), view.list()) {
            if g > 0 {
                let mut objects = 0usize;
                let mut deepest = 0u16;
                for rank in 0..cfg.ranks {
                    let chain = Manifest::gen_rank_chain(&names, g - 1, rank, u64::MAX);
                    objects += chain.full.is_some() as usize + chain.diffs.len();
                    deepest = deepest.max(
                        chain
                            .diffs
                            .iter()
                            .map(|d| Manifest::span_level(&d.2))
                            .max()
                            .unwrap_or(0),
                    );
                }
                report.replay_objects = report.replay_objects.max(objects);
                report.max_level = report.max_level.max(deepest);
            }
        }
    }
    report.iters = step;
    report.wall_secs = wall0.elapsed().as_secs_f64();
    report.final_full_every = eff.full_every;
    report.final_batch_size = eff.batch_size;
    report.final_compact_every = eff.compact_every;
    report.zstd_level = eff.zstd_level;
    report.final_codec = eff.codec.name();
    report.final_io_budget = gate.as_ref().map(|g| g.rate()).unwrap_or(eff.io_budget);
    // drain the scrubber: one final verification pass over the settled
    // chain (so a clean exit always leaves a freshly verified cover),
    // then fold its lifetime counters into the report
    if let Some(s) = scrubber {
        let st = s.finish();
        report.scrub_passes = st.passes;
        report.scrub_objects = st.objects_scrubbed;
        report.scrub_corrupt = st.corrupt;
        report.scrub_repaired = st.repaired;
        report.scrub_damaged = st.damaged;
    }
    if let Some(so) = &storage_obs {
        report.slow_ops = so.slow_ops();
        report.storage_ops = so.total_ops();
    }
    // final persistence of the run's observability artifacts: the settled
    // trace journal and the estimator state the next incarnation warm-
    // starts from — both beside the chain, both GC-immune sidecars
    if let Some(t) = &tracer {
        let (recorded, dropped) = t.counts();
        report.trace_events = recorded;
        report.trace_dropped = dropped;
        let journal = t.to_chrome_jsonl_capped(cfg.trace_journal_max_kb.saturating_mul(1024));
        if let Err(e) = store.put(TRACE_OBJECT, journal.as_bytes()) {
            log::warn!("trace journal persist failed: {e:#}");
        }
        report.trace_journal_dropped = t.journal_dropped();
    }
    if let Some(act) = &actuator {
        if let Err(e) = act.export_state().save(store.as_ref()) {
            log::warn!("control-state persist failed: {e:#}");
        }
    }
    refresh_obs(&obs, cfg, &eff, &actuator, &gate, &report);
    if let Some(s) = server.as_mut() {
        s.shutdown();
    }
    Ok(report)
}

/// Apply a retune — from the §V-C actuator OR a `POST /retune` request —
/// to the effective config and the live checkpointing process, always
/// through each runtime's safe-point mechanism (checkpointer queue order,
/// committed cluster records, LowDiff+ persist boundaries).
fn apply_retune(
    r: Retune,
    target: u64,
    eff: &mut TrainConfig,
    procs: &Procs,
    report: &mut RunReport,
) {
    eff.full_every = r.full_every;
    eff.batch_size = r.batch_size;
    eff.compact_every = r.compact_every;
    let codec_changed = r.codec != eff.codec;
    eff.codec = r.codec;
    report.retunes += 1;
    match procs {
        Procs::LowDiff { ckpt } => {
            // queue order makes this land after every enqueued diff,
            // with the pending batch flushed first — a codec switch rides
            // the same safe point, so the pending batch persists under
            // the OLD wire format before the encoder flips
            ckpt.queue.put(
                target,
                Arc::new(CkptItem::Retune {
                    batch_size: r.batch_size,
                    compact_every: r.compact_every,
                    codec: codec_changed.then_some(r.codec),
                }),
            );
        }
        Procs::Cluster { cluster } => {
            // applied by the coordinator at the next committed record:
            // all ranks switch at the same committed epoch
            cluster.set_compact_every(r.compact_every);
        }
        Procs::Plus { plus } => {
            // the persist boundary is LowDiff+'s safe point: the
            // assembler reads the knob between applied steps
            plus.set_persist_every(r.full_every);
        }
        _ => {}
    }
}

/// Refresh the `/stats`–`/metrics` control view from the live loop state.
fn refresh_obs(
    obs: &Option<Arc<ObsState>>,
    cfg: &TrainConfig,
    eff: &TrainConfig,
    actuator: &Option<Actuator>,
    gate: &Option<Arc<IoGate>>,
    report: &RunReport,
) {
    let Some(o) = obs else { return };
    // report-only counters published as Prometheus series through the
    // same state the /stats view rides
    o.set_gauges(ReportGauges {
        pool_hits: report.pool_hits,
        pool_misses: report.pool_misses,
        gc_leaks: report.gc_leaks,
    });
    let (mtbf, bw) = actuator.as_ref().map(|a| a.estimates()).unwrap_or((0.0, 0.0));
    o.set_control(ControlView {
        strategy: cfg.strategy.name().into(),
        adaptive: cfg.adaptive,
        mtbf_estimate: mtbf,
        bw_estimate: bw,
        io_budget: gate.as_ref().map(|g| g.rate()).unwrap_or(eff.io_budget),
        applied: Some(Retune {
            full_every: eff.full_every,
            batch_size: eff.batch_size,
            compact_every: eff.compact_every,
            codec: eff.codec,
        }),
        retunes: report.retunes,
        detected_failures: report.detected_failures,
    });
}

/// Seed the closed-loop actuator from the run configuration: the
/// configured MTBF (or a day, when no failures are injected) and a
/// generic device bandwidth become the estimator PRIORS — measured
/// telemetry replaces them within a few windows — and the model's sizes
/// come from the actual state (3Ψ f32 words) and compression ratio.
fn make_actuator(
    cfg: &TrainConfig,
    layout: &crate::model::Layout,
    n: usize,
    eff: &TrainConfig,
    iter_time: f64,
) -> Actuator {
    let full_size = (3 * n * 4) as f64;
    let write_bw = 1e9;
    let params = SystemParams {
        n_gpus: cfg.workers.max(1) as f64,
        mtbf: cfg.mtbf_secs.unwrap_or(24.0 * 3600.0),
        write_bw,
        full_size,
        total_time: (cfg.iters as f64 * iter_time).max(1.0),
        r_full: full_size / write_bw,
        r_diff: (layout.rho * full_size / write_bw).max(1e-6),
    };
    Actuator::new(
        params,
        iter_time,
        Retune {
            full_every: eff.full_every,
            batch_size: eff.batch_size,
            compact_every: eff.compact_every,
            codec: eff.codec,
        },
        ActuatorConfig {
            // the compaction policy sizes merge factors from the REAL
            // chain-object cadence, not raw iterations
            diff_every: cfg.diff_every.max(1),
            // `--full-every 0` opts the whole run into the full-free mode:
            // (0, 0) bounds pin fulls off and switch the compaction policy
            // to the replay-bound-targeting hierarchical fan-out
            full_every_bounds: if cfg.full_every == 0 {
                (0, 0)
            } else {
                ActuatorConfig::default().full_every_bounds
            },
            ..ActuatorConfig::default()
        },
    )
}

/// Write a base full checkpoint so the diff chain is always recoverable
/// (at run start and after every post-failure restart).
fn anchor_chain(procs: &mut Procs, state: &ModelState, report: &mut RunReport) {
    match procs {
        Procs::LowDiff { ckpt } | Procs::NaiveDc { ckpt } => {
            ckpt.queue.put(state.step, Arc::new(CkptItem::Full(state.clone())));
            report.full_ckpts += 1;
        }
        Procs::Cluster { cluster } => {
            // per-rank base fulls + a fresh global record at the anchor
            cluster.put_full(state.step, state);
            report.full_ckpts += 1;
        }
        _ => {}
    }
}

/// Observability/control handles the driver shares with every spawned
/// write path (and re-shares on every post-failure respawn).
#[derive(Clone, Default)]
struct ObsHandles {
    bus: Option<Arc<TelemetryBus>>,
    gate: Option<Arc<IoGate>>,
    trace: Option<Arc<Tracer>>,
    heartbeats: Option<Arc<HeartbeatTable>>,
    storage: Option<Arc<StorageObs>>,
}

/// The per-strategy background processes.
enum Procs {
    NoneAtAll,
    Sync,
    LowDiff { ckpt: Checkpointer },
    NaiveDc { ckpt: Checkpointer },
    Gemini { mem: Checkpointer, disk: Checkpointer },
    Plus { plus: LowDiffPlus },
    Cluster { cluster: Cluster },
}

fn spawn_procs(
    cfg: &TrainConfig,
    sig: u64,
    layout: &crate::model::Layout,
    state: &ModelState,
    store: &Arc<dyn StorageBackend>,
    mem_tier: &Arc<dyn StorageBackend>,
    obs: &ObsHandles,
) -> Procs {
    let base = CkptConfig {
        model_sig: sig,
        batch_size: cfg.batch_size,
        batch_mode: cfg.batch_mode,
        codec: cfg.codec,
        zstd_level: cfg.zstd_level,
        // delta-encoded fulls stay flat-LowDiff-only: the cluster runtime
        // keeps plain per-rank fulls and Gemini's memory tier must stay
        // directly readable for software-failure recovery
        delta_fulls: cfg.delta_fulls && cfg.strategy == StrategyKind::LowDiff,
        queue_capacity: cfg.queue_capacity,
        gc: true,
        n_shards: cfg.n_shards,
        writers: cfg.writers,
        compact_every: cfg.compact_every,
        io_budget: cfg.io_budget,
        telemetry: obs.bus.clone(),
        gate: obs.gate.clone(),
        trace: obs.trace.clone(),
    };
    match cfg.strategy {
        StrategyKind::None => Procs::NoneAtAll,
        StrategyKind::TorchSave => Procs::Sync,
        StrategyKind::LowDiff if cfg.uses_cluster() => {
            // consistent-hash slices: an R→R′ elastic event later remaps
            // only ~|ΔR|/max(R, R′) of the parameters
            let parts = cluster::partition_hash(layout.n_params, cfg.ranks);
            // every spawn that re-anchors gets a fresh namespace
            // generation — committed names of earlier incarnations (and
            // half-written leftovers of crashed reshards) are immutable
            let generation = cluster::next_generation(store).unwrap_or_else(|e| {
                log::warn!("generation scan failed ({e:#}); starting at 0");
                0
            });
            // rank namespaces observed as ONE shared "rank" tier (the
            // label folds all ranks together; the physical ops underneath
            // still count in the wrapped root's "durable" tier)
            let shared = Arc::clone(store);
            let so = obs.storage.clone();
            let tr = obs.trace.clone();
            Procs::Cluster {
                cluster: Cluster::spawn_with(
                    Arc::clone(store),
                    parts,
                    ClusterConfig {
                        model_sig: sig,
                        codec: cfg.codec,
                        n_shards: cfg.n_shards,
                        writers: cfg.writers,
                        gc: true,
                        queue_capacity: cfg.queue_capacity,
                        compact_every: cfg.compact_every,
                        io_budget: cfg.io_budget,
                        telemetry: obs.bus.clone(),
                        generation,
                        gate: obs.gate.clone(),
                        trace: obs.trace.clone(),
                        heartbeats: obs.heartbeats.clone(),
                    },
                    move |r| {
                        let ns: Arc<dyn StorageBackend> = Arc::new(Namespaced::new(
                            Arc::clone(&shared),
                            Manifest::gen_rank_prefix(generation, r),
                        ));
                        match &so {
                            Some(so) => Arc::new(
                                Observed::new(ns, Arc::clone(so), "rank")
                                    .with_trace(tr.clone()),
                            ),
                            None => ns,
                        }
                    },
                ),
            }
        }
        StrategyKind::LowDiff | StrategyKind::CheckFreq => Procs::LowDiff {
            ckpt: Checkpointer::spawn(Arc::clone(store), base),
        },
        StrategyKind::NaiveDc => Procs::NaiveDc {
            ckpt: Checkpointer::spawn(
                Arc::clone(store),
                CkptConfig { batch_size: 1, ..base },
            ),
        },
        StrategyKind::Gemini => Procs::Gemini {
            // the memory tier stays single-object and uncompacted:
            // software-failure recovery reads it raw, and sharding or
            // compacting a memcpy buys nothing
            mem: Checkpointer::spawn(
                Arc::clone(mem_tier),
                CkptConfig {
                    batch_size: 1,
                    n_shards: 1,
                    writers: 1,
                    compact_every: 0,
                    io_budget: 0.0,
                    telemetry: None,
                    gate: None,
                    trace: None,
                    ..base.clone()
                },
            ),
            disk: Checkpointer::spawn(Arc::clone(store), base),
        },
        StrategyKind::LowDiffPlus => Procs::Plus {
            plus: LowDiffPlus::spawn(
                layout,
                state.clone(),
                Arc::clone(store),
                PlusConfig {
                    model_sig: sig,
                    persist_every: cfg.full_every,
                    codec: cfg.codec,
                    queue_capacity: cfg.queue_capacity.max(layout.n_tensors() * 2),
                    snapshot_threads: cfg.snapshot_threads,
                    adam: Adam { lr: layout.lr as f32 },
                },
            ),
        },
    }
}

/// Tear down the (crashed) processes and produce the recovered state.
#[allow(clippy::too_many_arguments)]
fn handle_failure(
    kind: FailureKind,
    cfg: &TrainConfig,
    procs: Procs,
    store: &Arc<dyn StorageBackend>,
    mem_tier: &Arc<dyn StorageBackend>,
    sig: u64,
    adam: &Adam,
    params0: &Flat,
    report: &mut RunReport,
) -> Result<(ModelState, bool)> {
    // software failure: the checkpointing process survives; LowDiff+
    // recovers from its CPU replica, Gemini from the memory tier
    match (procs, kind) {
        (Procs::Plus { plus }, FailureKind::Software) => {
            let latest = plus.applied_step();
            plus.wait_applied(latest);
            let replica = plus.replica();
            plus.finish();
            Ok((replica, true))
        }
        (Procs::Gemini { mem, disk }, FailureKind::Software) => {
            drop(disk);
            mem.finish();
            match recover(mem_tier.as_ref(), sig, adam, cfg.recovery_mode) {
                Ok((s, _)) => Ok((s, true)),
                Err(_) => recover_from_disk(store, sig, adam, cfg, params0, report),
            }
        }
        (Procs::Plus { plus }, FailureKind::Hardware) => {
            plus.abort();
            recover_from_disk(store, sig, adam, cfg, params0, report)
        }
        (Procs::Cluster { cluster }, _) => {
            // any failure kills the rank processes and the coordinator;
            // recovery is the consistent cut over the per-rank chains —
            // generation-tagged namespaces mean a crashed reshard or
            // re-anchor never touched the committed record's objects, so
            // the plain cut walk always lands on a verified record
            drop(cluster);
            match cluster::recover_cluster(store, sig, adam) {
                Ok((s, stats)) => {
                    report.replay_objects = report.replay_objects.max(stats.replay_objects);
                    report.max_level = report.max_level.max(stats.max_level);
                    log::debug!(
                        "cluster recovery: cut step {} (gen {}) across {} ranks ({} diff steps)",
                        stats.cut_step,
                        stats.cut_gen,
                        stats.ranks,
                        stats.diff_steps_applied
                    );
                    // drop torn-commit stragglers from the lost timeline
                    let _ = cluster::truncate_stragglers(store, s.step);
                    Ok((s, false))
                }
                Err(e) => {
                    log::warn!("no consistent cluster cut ({e:#}); restarting from scratch");
                    Ok((ModelState::new(params0.clone()), false))
                }
            }
        }
        (procs, _) => {
            // hardware (or strategies without an in-memory tier): all
            // process memory is gone; in-flight checkpoints are lost
            match procs {
                Procs::LowDiff { ckpt } | Procs::NaiveDc { ckpt } => drop(ckpt),
                Procs::Gemini { mem, disk } => {
                    drop(mem);
                    drop(disk);
                }
                _ => {}
            }
            recover_from_disk(store, sig, adam, cfg, params0, report)
        }
    }
}

fn recover_from_disk(
    store: &Arc<dyn StorageBackend>,
    sig: u64,
    adam: &Adam,
    cfg: &TrainConfig,
    params0: &Flat,
    report: &mut RunReport,
) -> Result<(ModelState, bool)> {
    match recover(store.as_ref(), sig, adam, cfg.recovery_mode) {
        Ok((s, stats)) => {
            log::debug!(
                "storage recovery: {} diffs in {} merge rounds",
                stats.n_diff_steps,
                stats.full_merge_rounds
            );
            // cover objects = the base full + every chain object replayed
            report.replay_objects = report.replay_objects.max(1 + stats.n_diff_objects);
            report.max_level = report.max_level.max(stats.max_level);
            Ok((s, false))
        }
        Err(e) => {
            log::warn!("no usable checkpoint ({e:#}); restarting from scratch");
            Ok((ModelState::new(params0.clone()), false))
        }
    }
}

fn finish_procs(procs: Procs, report: &mut RunReport) {
    match procs {
        Procs::NoneAtAll | Procs::Sync => {}
        Procs::LowDiff { ckpt } | Procs::NaiveDc { ckpt } => {
            report.absorb_ckpt(&ckpt.finish());
        }
        Procs::Gemini { mem, disk } => {
            // memory-tier traffic isn't storage I/O; only disk writes count
            let _ = mem.finish();
            report.absorb_ckpt(&disk.finish());
        }
        Procs::Cluster { cluster } => {
            let cs = cluster.finish();
            // cluster-wide totals: every rank's counters, not rank 0's
            report.absorb_ckpt(&cs.total());
            report.bytes_written += cs.record_bytes;
            report.global_commits += cs.global_commits;
            report.torn_commits += cs.torn_commits;
            report.gc_leaks += cs.gc_leaked;
            // scheduler-run compaction counters live on the cluster, not
            // any one rank's CkptStats
            report.merged_written += cs.merged_written;
            report.raw_compacted += cs.raw_compacted;
            report.spans_compacted += cs.spans_compacted;
            report.compact_secs += cs.compact_secs;
            report.max_level = report.max_level.max(cs.max_level);
        }
        Procs::Plus { plus } => {
            let s = plus.finish();
            report.writes += s.persisted;
            report.bytes_written += s.bytes_written;
        }
    }
}

/// Evaluate the current loss (for reports / examples).
pub fn eval_loss(mrt: &ModelRuntime, state: &ModelState, corpus: &Corpus, step: u64) -> Result<f32> {
    let tokens = corpus.batch(step, usize::MAX / 2, mrt.layout.batch, mrt.layout.seq_len);
    mrt.eval(&state.params, &tokens).context("eval")
}
