//! Failure injection and wasted-time accounting (paper §VIII Exp. 3/9).
//!
//! Failures arrive as a Poisson process with the configured MTBF
//! (exponential inter-arrival, seeded — deterministic experiments). The
//! paper's recovery taxonomy (§VI-C): **hardware** failures lose all
//! process memory (recover from persistent storage); **software** failures
//! kill only the training process, leaving the checkpointing process's CPU
//! memory intact (LowDiff+ recovers from the in-memory replica).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    Hardware,
    Software,
}

/// Deterministic failure schedule generator.
#[derive(Debug)]
pub struct FailureInjector {
    rng: Rng,
    /// MTBF in seconds of simulated wall-clock
    mtbf: f64,
    /// P(failure is software | failure) — the paper treats software bugs
    /// as the common case (§VI-C)
    p_software: f64,
    next_at: f64,
}

impl FailureInjector {
    pub fn new(mtbf_secs: f64, p_software: f64, seed: u64) -> FailureInjector {
        assert!(mtbf_secs > 0.0);
        let mut rng = Rng::new(seed);
        let first = rng.exponential(mtbf_secs);
        FailureInjector { rng, mtbf: mtbf_secs, p_software, next_at: first }
    }

    /// No failures ever (baseline runs).
    pub fn never() -> FailureInjector {
        FailureInjector {
            rng: Rng::new(0),
            mtbf: f64::INFINITY,
            p_software: 0.0,
            next_at: f64::INFINITY,
        }
    }

    /// Time of the next scheduled failure.
    pub fn next_at(&self) -> f64 {
        self.next_at
    }

    /// Poll at simulated/wall time `now`; if a failure is due, consume it,
    /// schedule the next, and return its kind.
    pub fn poll(&mut self, now: f64) -> Option<FailureKind> {
        if now < self.next_at {
            return None;
        }
        self.next_at = now + self.rng.exponential(self.mtbf);
        Some(if self.rng.next_f64() < self.p_software {
            FailureKind::Software
        } else {
            FailureKind::Hardware
        })
    }

    /// [`poll`](FailureInjector::poll) that also records the event on the
    /// control plane's telemetry bus — the measured-MTBF source of the
    /// §V-C closed loop (`docs/CONTROL.md`). The bus only ever sees
    /// *events*; the windowed estimator turns them into an MTBF estimate.
    pub fn poll_telemetry(
        &mut self,
        now: f64,
        bus: Option<&crate::control::telemetry::TelemetryBus>,
    ) -> Option<FailureKind> {
        let kind = self.poll(now);
        if let (Some(_), Some(bus)) = (&kind, bus) {
            bus.record_failure();
        }
        kind
    }
}

/// Wasted-time ledger (§II-B): recovery time + steady-state checkpoint
/// overhead + recomputed work, vs productive training time.
#[derive(Clone, Debug, Default)]
pub struct WastedTime {
    /// GPU time spent on checkpointing while healthy (stalls)
    pub steady_overhead: f64,
    /// time to reload/merge checkpoints after failures
    pub recovery: f64,
    /// progress lost and recomputed (from last covered step to failure)
    pub lost_work: f64,
    /// productive training compute
    pub productive: f64,
    pub n_failures: u64,
}

impl WastedTime {
    pub fn total_wasted(&self) -> f64 {
        self.steady_overhead + self.recovery + self.lost_work
    }

    /// Gemini's effective training time ratio (Exp. 9/10).
    pub fn effective_ratio(&self) -> f64 {
        let total = self.productive + self.total_wasted();
        if total == 0.0 {
            1.0
        } else {
            self.productive / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failures_arrive_at_mtbf_rate() {
        let mut inj = FailureInjector::new(100.0, 0.5, 7);
        let mut t = 0.0;
        let mut count = 0;
        while t < 100_000.0 {
            t += 1.0;
            if inj.poll(t).is_some() {
                count += 1;
            }
        }
        // ~1000 failures expected; Poisson sd ~32
        assert!((800..1200).contains(&count), "count {count}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = FailureInjector::new(50.0, 0.5, 9);
        let mut b = FailureInjector::new(50.0, 0.5, 9);
        for i in 0..10_000 {
            assert_eq!(a.poll(i as f64), b.poll(i as f64));
        }
    }

    #[test]
    fn never_never_fires() {
        let mut inj = FailureInjector::never();
        assert!(inj.poll(1e12).is_none());
    }

    #[test]
    fn poll_telemetry_records_each_failure_event() {
        use crate::control::telemetry::TelemetryBus;
        let bus = TelemetryBus::new();
        let mut inj = FailureInjector::new(10.0, 0.5, 4);
        let mut fired = 0u64;
        let mut t = 0.0;
        for _ in 0..2000 {
            t += 1.0;
            if inj.poll_telemetry(t, Some(&bus)).is_some() {
                fired += 1;
            }
        }
        assert!(fired > 0);
        assert_eq!(bus.snapshot().failures, fired, "every event reaches the bus");
    }

    #[test]
    fn software_fraction_respected() {
        let mut inj = FailureInjector::new(1.0, 0.8, 3);
        let (mut sw, mut hw) = (0u32, 0u32);
        let mut t = 0.0;
        for _ in 0..20_000 {
            t += 1.0;
            match inj.poll(t) {
                Some(FailureKind::Software) => sw += 1,
                Some(FailureKind::Hardware) => hw += 1,
                None => {}
            }
        }
        let frac = sw as f64 / (sw + hw) as f64;
        assert!((0.75..0.85).contains(&frac), "software fraction {frac}");
    }

    #[test]
    fn effective_ratio_bounds() {
        let mut w = WastedTime::default();
        w.productive = 90.0;
        w.steady_overhead = 5.0;
        w.recovery = 3.0;
        w.lost_work = 2.0;
        assert!((w.effective_ratio() - 0.9).abs() < 1e-12);
        assert_eq!(WastedTime::default().effective_ratio(), 1.0);
    }
}
