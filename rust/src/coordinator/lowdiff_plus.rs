//! LowDiff+ (paper §VI): frequent checkpointing *without* gradient
//! compression.
//!
//! - **Layer-wise gradient reusing & snapshotting** (§VI-A, Alg. 2): as
//!   each layer's gradient is finalized, the training side enqueues a
//!   zero-copy layer slice; a pool of snapshot threads copies slices into
//!   CPU staging buffers concurrently (pipelining with later layers).
//! - **CPU-resident replica + asynchronous persistence** (§VI-B): once a
//!   step's slices have all landed, the checkpointing side applies the
//!   gradient to a CPU [`ModelState`] replica via Rust Adam — an in-memory
//!   checkpoint updated every iteration; the replica is persisted to
//!   storage on a cadence, fully decoupled from training.
//! - **Software-failure recovery** (§VI-C): the replica survives training-
//!   process death; [`LowDiffPlus::replica`] hands it back instantly.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::checkpoint::format::PayloadCodec;
use crate::checkpoint::manifest::Manifest;
use crate::coordinator::reusing_queue::ReusingQueue;
use crate::model::Layout;
use crate::optim::{Adam, ModelState};
use crate::pipeline::{CkptStats, Encoder, Sink};
use crate::storage::StorageBackend;
use crate::tensor::Flat;

/// One layer's gradient, shared zero-copy (all layers of a step share the
/// same gradient allocation; the message carries the slice coordinates).
pub struct LayerMsg {
    pub grad: Arc<Flat>,
    pub tensor_idx: usize,
}

#[derive(Clone, Debug, Default)]
pub struct PlusStats {
    pub inmem_ckpts: u64,
    pub persisted: u64,
    pub bytes_written: u64,
    pub write_secs: f64,
    pub snapshot_secs: f64,
    pub cpu_update_secs: f64,
}

/// The LowDiff+ checkpointing process.
pub struct LowDiffPlus {
    pub queue: Arc<ReusingQueue<LayerMsg>>,
    replica: Arc<Mutex<ModelState>>,
    stats: Arc<Mutex<PlusStats>>,
    /// last step fully applied to the replica
    applied_step: Arc<AtomicU64>,
    /// live persistence cadence (control-plane knob; 0 = never persist)
    persist_every: Arc<AtomicU64>,
    discard: Arc<AtomicBool>,
    assembler: Option<JoinHandle<()>>,
    snapshot_pool: Vec<JoinHandle<()>>,
}

pub struct PlusConfig {
    pub model_sig: u64,
    /// replica persistence cadence in applied steps; 0 = never persist
    /// (the replica stays memory-only)
    pub persist_every: u64,
    pub codec: PayloadCodec,
    pub queue_capacity: usize,
    pub snapshot_threads: usize,
    pub adam: Adam,
}

impl LowDiffPlus {
    /// Spawn the checkpointing process. `initial` is the deep-copied GPU
    /// state (the paper's `copy.deepcopy` at process start, §VII-B).
    pub fn spawn(
        layout: &Layout,
        initial: ModelState,
        store: Arc<dyn StorageBackend>,
        cfg: PlusConfig,
    ) -> LowDiffPlus {
        let n_tensors = layout.n_tensors();
        let tensors: Arc<Vec<(usize, usize)>> =
            Arc::new(layout.tensors.iter().map(|t| (t.offset, t.len)).collect());
        let queue: Arc<ReusingQueue<LayerMsg>> = ReusingQueue::new(cfg.queue_capacity);
        let replica = Arc::new(Mutex::new(initial));
        let stats = Arc::new(Mutex::new(PlusStats::default()));
        let applied_step = Arc::new(AtomicU64::new(0));
        let persist_every = Arc::new(AtomicU64::new(cfg.persist_every));
        let discard = Arc::new(AtomicBool::new(false));

        // staging buffer: one slot per tensor, written by the snapshot
        // pool, read by the assembler once a step completes
        let staging: Arc<Vec<Mutex<Vec<f32>>>> = Arc::new(
            tensors.iter().map(|&(_, len)| Mutex::new(vec![0f32; len])).collect(),
        );

        // snapshot pool: copies layer slices GPU->CPU (here: into staging)
        let (work_tx, work_rx) = mpsc::channel::<(u64, LayerMsg)>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (done_tx, done_rx) = mpsc::channel::<u64>();
        let mut snapshot_pool = Vec::new();
        for i in 0..cfg.snapshot_threads.max(1) {
            let rx = Arc::clone(&work_rx);
            let tx = done_tx.clone();
            let staging = Arc::clone(&staging);
            let tensors = Arc::clone(&tensors);
            let stats = Arc::clone(&stats);
            snapshot_pool.push(
                std::thread::Builder::new()
                    .name(format!("snap-{i}"))
                    .spawn(move || {
                        loop {
                            let msg = { rx.lock().unwrap().recv() };
                            let Ok((step, m)) = msg else { break };
                            let t0 = Instant::now();
                            let (off, len) = tensors[m.tensor_idx];
                            staging[m.tensor_idx]
                                .lock()
                                .unwrap()
                                .copy_from_slice(m.grad.slice(off, len));
                            stats.lock().unwrap().snapshot_secs += t0.elapsed().as_secs_f64();
                            let _ = tx.send(step);
                        }
                    })
                    .expect("snapshot thread"),
            );
        }
        drop(done_tx);

        // assembler: drives the queue, dispatches to the pool, applies each
        // completed step to the replica, persists on cadence
        let q = Arc::clone(&queue);
        let rep = Arc::clone(&replica);
        let st = Arc::clone(&stats);
        let applied = Arc::clone(&applied_step);
        let pev = Arc::clone(&persist_every);
        let disc = Arc::clone(&discard);
        let tensors2 = Arc::clone(&tensors);
        let staging2 = Arc::clone(&staging);
        let assembler = std::thread::Builder::new()
            .name("lowdiff+".into())
            .spawn(move || {
                // shared pipeline stages for replica persistence: pooled
                // single-pass full encoding + a direct sink (the replica is
                // one object; sharding a memcpy-sized write buys nothing)
                let enc = Encoder::new(cfg.model_sig, cfg.codec, 2);
                let mut sink = Sink::new(store, 1, 1, 2);
                let mut wstats = CkptStats::default();
                let mut pending = 0usize;
                let mut cur_step = 0u64;
                while let Some(entry) = q.get() {
                    if disc.load(Ordering::Relaxed) {
                        continue; // failure: drain without applying
                    }
                    if entry.step != cur_step {
                        assert_eq!(pending, 0, "step {cur_step} incomplete");
                        cur_step = entry.step;
                    }
                    let msg = Arc::try_unwrap(entry.payload)
                        .unwrap_or_else(|_| panic!("layer msg must be exclusive"));
                    work_tx.send((cur_step, msg)).expect("pool alive");
                    pending += 1;
                    if pending == n_tensors {
                        // wait for all snapshot copies of this step
                        for _ in 0..pending {
                            let s = done_rx.recv().expect("pool alive");
                            debug_assert_eq!(s, cur_step);
                        }
                        pending = 0;
                        // CPU-side Adam update of the replica (§VI-B):
                        // layer-wise application with the step's bias
                        // correction fixed once the full gradient arrived
                        let t0 = Instant::now();
                        let mut r = rep.lock().unwrap();
                        r.step += 1;
                        let step_now = r.step;
                        debug_assert_eq!(step_now, cur_step);
                        for (idx, &(off, _len)) in tensors2.iter().enumerate() {
                            let buf = staging2[idx].lock().unwrap();
                            cfg.adam.apply_range(&mut r, &buf, off, step_now);
                        }
                        // live knob read at the persist boundary — the
                        // §V-C actuator retunes the cadence between
                        // applied steps, never mid-persist; 0 disables
                        let every = pev.load(Ordering::Relaxed);
                        let snapshot_state = if every != 0 && cur_step % every == 0 {
                            Some(r.clone())
                        } else {
                            None
                        };
                        drop(r);
                        {
                            let mut s = st.lock().unwrap();
                            s.cpu_update_secs += t0.elapsed().as_secs_f64();
                            s.inmem_ckpts += 1;
                        }
                        applied.store(cur_step, Ordering::Release);
                        // asynchronous persistence of the replica (the
                        // paper's fused full+diff batching, Fig. 8),
                        // through the shared encode→persist stages
                        if let Some(state) = snapshot_state {
                            let t0 = Instant::now();
                            match enc.encode_full(&state) {
                                Ok(obj) => {
                                    let bytes = obj.buf.len() as u64;
                                    if sink.persist_durable(obj, &mut wstats).is_ok() {
                                        let mut s = st.lock().unwrap();
                                        s.persisted += 1;
                                        s.bytes_written += bytes;
                                        s.write_secs += t0.elapsed().as_secs_f64();
                                    }
                                    // outside the stats lock (GC does
                                    // storage I/O), and even after a failed
                                    // put — obsolete fulls must not pile up
                                    let _ = Manifest::gc(sink.view());
                                }
                                Err(e) => log::error!("persist replica: {e:#}"),
                            }
                        }
                    }
                }
            })
            .expect("assembler thread");

        LowDiffPlus {
            queue,
            replica,
            stats,
            applied_step,
            persist_every,
            discard,
            assembler: Some(assembler),
            snapshot_pool,
        }
    }

    /// Retune the replica-persistence cadence live (§V-C actuation for the
    /// LowDiff+ runtime). Takes effect at the next applied step — the
    /// assembler reads the knob only at its persist boundary, so a retune
    /// can never tear a persist in progress. `0` disables persistence.
    pub fn set_persist_every(&self, every: u64) {
        self.persist_every.store(every, Ordering::Relaxed);
    }

    /// Enqueue every layer of a step's gradient, zero-copy (Alg. 2 line 16).
    /// Returns the total time blocked on the queue (transmission stall).
    pub fn put_step(&self, step: u64, grad: Arc<Flat>, layout: &Layout) -> std::time::Duration {
        let mut blocked = std::time::Duration::ZERO;
        // reverse layer order — gradients are produced back-to-front in the
        // backward pass (Fig. 7)
        for idx in (0..layout.n_tensors()).rev() {
            blocked += self
                .queue
                .put(step, Arc::new(LayerMsg { grad: Arc::clone(&grad), tensor_idx: idx }));
        }
        blocked
    }

    /// Last step fully reflected in the CPU replica.
    pub fn applied_step(&self) -> u64 {
        self.applied_step.load(Ordering::Acquire)
    }

    /// Block until the replica has caught up to `step`.
    pub fn wait_applied(&self, step: u64) {
        while self.applied_step() < step {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    /// Clone of the in-memory checkpoint (software-failure recovery path —
    /// near-instant compared to reloading from storage).
    pub fn replica(&self) -> ModelState {
        self.replica.lock().unwrap().clone()
    }

    pub fn stats(&self) -> PlusStats {
        self.stats.lock().unwrap().clone()
    }

    /// Simulate a *hardware* failure: the checkpointing process dies too;
    /// in-flight work is discarded (only persisted checkpoints survive).
    pub fn abort(mut self) -> PlusStats {
        self.discard.store(true, Ordering::Relaxed);
        self.shutdown();
        self.stats.lock().unwrap().clone()
    }

    /// Graceful finish: drain, apply everything, stop.
    pub fn finish(mut self) -> PlusStats {
        self.shutdown();
        self.stats.lock().unwrap().clone()
    }

    fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.assembler.take() {
            let _ = h.join();
        }
        // assembler drops work_tx on exit, stopping the pool
        for h in self.snapshot_pool.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for LowDiffPlus {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::model_signature;
    use crate::checkpoint::full::read_full;
    use crate::storage::MemStore;
    use crate::util::rng::Rng;

    fn tiny_layout(n_tensors: usize, per: usize) -> Layout {
        Layout {
            model: "t".into(),
            n_params: n_tensors * per,
            vocab: 16,
            seq_len: 8,
            batch: 1,
            rho: 0.01,
            k: 1,
            lr: 1e-3,
            tensors: (0..n_tensors)
                .map(|i| crate::model::TensorSpec {
                    name: format!("l{i}"),
                    offset: i * per,
                    len: per,
                })
                .collect(),
        }
    }

    fn cfg(sig: u64, persist_every: u64) -> PlusConfig {
        PlusConfig {
            model_sig: sig,
            persist_every,
            codec: PayloadCodec::Raw,
            queue_capacity: 16,
            snapshot_threads: 2,
            adam: Adam::default(),
        }
    }

    #[test]
    fn replica_tracks_training_exactly() {
        let layout = tiny_layout(4, 25);
        let n = layout.n_params;
        let sig = model_signature("t", n);
        let mut rng = Rng::new(1);
        let mut p = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        let state0 = ModelState::new(Flat(p));
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let plus = LowDiffPlus::spawn(&layout, state0.clone(), Arc::clone(&store), cfg(sig, 100));

        // "GPU" training loop with the same Adam
        let adam = Adam::default();
        let mut gpu = state0;
        for step in 1..=6u64 {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            let g = Flat(g);
            plus.put_step(step, Arc::new(g.clone()), &layout);
            adam.apply(&mut gpu, &g);
        }
        plus.wait_applied(6);
        let replica = plus.replica();
        assert_eq!(replica.step, 6);
        assert!(
            replica.params.max_abs_diff(&gpu.params) < 1e-6,
            "replica drift {}",
            replica.params.max_abs_diff(&gpu.params)
        );
        plus.finish();
    }

    #[test]
    fn persistence_cadence_and_recovery() {
        let layout = tiny_layout(3, 20);
        let n = layout.n_params;
        let sig = model_signature("t", n);
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let plus = LowDiffPlus::spawn(
            &layout,
            ModelState::new(Flat(vec![0.1; n])),
            Arc::clone(&store),
            cfg(sig, 2),
        );
        let mut rng = Rng::new(2);
        for step in 1..=5u64 {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            plus.put_step(step, Arc::new(Flat(g)), &layout);
        }
        plus.wait_applied(5);
        let replica = plus.replica();
        let stats = plus.finish();
        assert_eq!(stats.inmem_ckpts, 5);
        assert_eq!(stats.persisted, 2, "steps 2 and 4 persist (gc keeps latest)");
        // latest persisted full is step 4 (gc removed step 2)
        let names = store.list().unwrap();
        assert_eq!(names, vec![Manifest::full_name(4)]);
        let disk = read_full(&store.get(&names[0]).unwrap(), sig).unwrap();
        assert_eq!(disk.step, 4);
        assert_eq!(replica.step, 5);
    }

    #[test]
    fn persist_cadence_retunes_live_and_zero_disables() {
        let layout = tiny_layout(3, 20);
        let n = layout.n_params;
        let sig = model_signature("t", n);
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        // spawn with persistence DISABLED (0 = never): the full-free
        // spawn path must not divide by the cadence
        let plus = LowDiffPlus::spawn(
            &layout,
            ModelState::new(Flat(vec![0.1; n])),
            Arc::clone(&store),
            cfg(sig, 0),
        );
        let mut rng = Rng::new(7);
        let mut put = |plus: &LowDiffPlus, step: u64| {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            plus.put_step(step, Arc::new(Flat(g)), &layout);
        };
        for step in 1..=3u64 {
            put(&plus, step);
        }
        plus.wait_applied(3);
        assert_eq!(plus.stats().persisted, 0, "cadence 0 never persists");
        // §V-C actuation: the knob lands at the next persist boundary
        plus.set_persist_every(1);
        for step in 4..=5u64 {
            put(&plus, step);
        }
        plus.wait_applied(5);
        let stats = plus.finish();
        assert_eq!(stats.persisted, 2, "steps 4 and 5 under the retuned cadence");
        assert_eq!(store.list().unwrap(), vec![Manifest::full_name(5)]);
    }

    #[test]
    fn abort_discards_inflight() {
        let layout = tiny_layout(2, 10);
        let n = layout.n_params;
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let plus = LowDiffPlus::spawn(
            &layout,
            ModelState::new(Flat::zeros(n)),
            Arc::clone(&store),
            cfg(1, 1000),
        );
        let mut rng = Rng::new(3);
        for step in 1..=3u64 {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            plus.put_step(step, Arc::new(Flat(g)), &layout);
        }
        let stats = plus.abort();
        assert_eq!(stats.persisted, 0);
        assert!(store.list().unwrap().is_empty());
    }

    #[test]
    fn layer_messages_share_one_allocation() {
        let layout = tiny_layout(5, 8);
        let grad = Arc::new(Flat(vec![1.0; layout.n_params]));
        // 5 layer messages, 1 allocation: Arc strong count goes to 6
        let msgs: Vec<LayerMsg> = (0..5)
            .map(|i| LayerMsg { grad: Arc::clone(&grad), tensor_idx: i })
            .collect();
        assert_eq!(Arc::strong_count(&grad), 6);
        drop(msgs);
        assert_eq!(Arc::strong_count(&grad), 1);
    }
}
