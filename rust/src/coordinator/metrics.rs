//! Run-level metrics: where every second of the training run went.
//!
//! The paper's evaluation splits time into productive compute, checkpoint-
//! induced stalls (compression stalls + transmission stalls, Fig. 2),
//! recovery, and lost work. [`RunReport`] is the common output of the real
//! engine ([`crate::coordinator::driver`]) and feeds the experiment tables.

use crate::checkpoint::format::{PayloadCodec, N_CODECS};
use crate::util::stats::Welford;

/// Aggregate report of one training run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub strategy: String,
    pub model: String,
    pub workers: usize,
    /// productive iterations completed (post-recovery re-runs not counted)
    pub iters: u64,
    pub wall_secs: f64,
    /// PJRT compute (fwd/bwd + update) on the training path
    pub compute_secs: f64,
    /// gradient synchronization (collective) time
    pub sync_secs: f64,
    /// checkpoint-induced stalls on the training path
    /// (snapshot copies, differential compression, sync writes)
    pub stall_secs: f64,
    /// transmission stall: time blocked on a full reusing queue
    pub queue_blocked_secs: f64,
    /// (step, loss) samples
    pub losses: Vec<(u64, f32)>,
    pub full_ckpts: u64,
    pub diff_ckpts: u64,
    /// storage objects written / bytes (from the checkpointer thread)
    pub writes: u64,
    pub bytes_written: u64,
    /// peak bytes pending in the CPU batch buffer
    pub peak_buffered_bytes: usize,
    /// physical shard/commit objects written by the sharded engine
    pub shard_writes: u64,
    /// write-path heap-to-heap traffic (encode + batch accumulation); the
    /// pooled single-pass pipeline keeps this ~= bytes_written
    pub bytes_copied: u64,
    /// encode-buffer pool counters (recycled vs fresh checkouts)
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// merged differential spans written by the background chain compactor
    /// (all levels of the hierarchy)
    pub merged_written: u64,
    /// raw diff objects superseded (and collected) by merged spans
    pub raw_compacted: u64,
    /// level-k (k ≥ 1) spans superseded by level-(k+1) super-spans
    pub spans_compacted: u64,
    /// chain objects a recovery replays (base full included) — observed at
    /// each actual recovery and probed from the settled chain at run end;
    /// with the hierarchy it is bounded by `mf·⌈log_mf n⌉ + 1` per chain
    /// even with fulls disabled (`full_every = ∞`)
    pub replay_objects: usize,
    /// deepest hierarchical-compaction span level reached (0 = all raw)
    pub max_level: u16,
    /// fast→durable tier spill traffic (Tiered backend)
    pub spill_bytes: u64,
    /// peak logical checkpoint writes in flight on the writer pool
    pub inflight_peak: usize,
    /// cluster runtime: rank threads persisting their own state partitions
    /// (1 = classic single-chain checkpointing)
    pub ranks: usize,
    /// cluster runtime: epochs whose global commit record is durable
    pub global_commits: u64,
    /// cluster runtime: epochs abandoned mid-commit (a rank write failed)
    pub torn_commits: u64,
    /// cluster GC: objects it failed to delete with the object still
    /// present afterwards (real I/O failures, not benign races — garbage
    /// the operator should know is accumulating)
    pub gc_leaks: u64,
    pub recoveries: u64,
    pub recovery_secs: f64,
    /// iterations lost to failures and re-run
    pub lost_iters: u64,
    /// per-iteration wall time distribution
    pub iter_times: Welford,
    /// control plane (`--adaptive`): configurations applied by the
    /// closed-loop actuator during the run
    pub retunes: u64,
    /// control plane: the (FCF, BS, merge factor) in force at run end —
    /// equals the configured values when the actuator never fired
    pub final_full_every: u64,
    pub final_batch_size: usize,
    pub final_compact_every: usize,
    /// cluster runtime: background-scheduler wall seconds (compaction
    /// passes moved OFF the commit thread — `commit_secs` excludes them)
    pub compact_secs: f64,
    /// failures declared by the heartbeat detector (silence past the
    /// `--heartbeat-timeout`), as opposed to injected ones; each routes
    /// through the same consistent-cut recovery path
    pub detected_failures: u64,
    /// event tracing (`--trace`): events recorded into the ring buffer
    /// and events dropped because the buffer wrapped
    pub trace_events: u64,
    pub trace_dropped: u64,
    /// oldest events cut from the persisted trace journal by the
    /// `--trace-journal-max-kb` size cap (gauge: as of the last rewrite)
    pub trace_journal_dropped: u64,
    /// storage plane (`Observed`): ops at or above `--slow-io-ms` and
    /// the total ops observed across every tier
    pub slow_ops: u64,
    pub storage_ops: u64,
    /// background scrubber: verification passes, objects verified,
    /// distinct objects flagged corrupt, objects repaired (fast-tier
    /// re-fetch), and the end-of-run damaged gauge
    pub scrub_passes: u64,
    pub scrub_objects: u64,
    pub scrub_corrupt: u64,
    pub scrub_repaired: u64,
    pub scrub_damaged: u64,
    /// the I/O-gate byte budget in force at run end (equals the configured
    /// `--io-budget` unless interference autoscaling moved it)
    pub final_io_budget: f64,
    /// the zstd level zstd-backed codecs encoded with (`--zstd-level`)
    pub zstd_level: i32,
    /// the payload codec in force at run end — equals the configured codec
    /// unless the bandit codec policy (or `POST /retune`) switched it
    pub final_codec: &'static str,
    /// per-codec achieved compression, indexed by [`PayloadCodec::idx`]:
    /// raw input bytes offered, wire bytes produced, encode nanoseconds —
    /// probe (scratch) encodes included, so ratios are measured per arm
    pub codec_bytes_in: [u64; N_CODECS],
    pub codec_bytes_out: [u64; N_CODECS],
    pub codec_encode_ns: [u64; N_CODECS],
    /// bandit probe encodes of the non-chosen codec
    pub codec_probes: u64,
    /// live codec switches applied at retune safe points
    pub codec_switches: u64,
}

impl RunReport {
    pub fn new(strategy: &str, model: &str, workers: usize) -> RunReport {
        RunReport {
            strategy: strategy.to_string(),
            model: model.to_string(),
            workers,
            ranks: 1,
            final_codec: PayloadCodec::Raw.name(),
            ..Default::default()
        }
    }

    /// Fold one checkpointing process's counters into the run totals.
    /// With the cluster runtime this is called once per rank, so every
    /// table reports **cluster-wide** I/O, copy and pool numbers — not
    /// rank 0's.
    pub fn absorb_ckpt(&mut self, s: &crate::coordinator::checkpointer::CkptStats) {
        self.writes += s.writes;
        self.bytes_written += s.bytes_written;
        self.peak_buffered_bytes = self.peak_buffered_bytes.max(s.peak_buffered_bytes);
        self.shard_writes += s.shard_writes;
        self.bytes_copied += s.bytes_copied;
        self.pool_hits += s.pool_hits;
        self.pool_misses += s.pool_misses;
        self.spill_bytes += s.spill_bytes;
        self.inflight_peak = self.inflight_peak.max(s.inflight_peak);
        self.merged_written += s.merged_written;
        self.raw_compacted += s.raw_compacted;
        self.spans_compacted += s.spans_compacted;
        self.max_level = self.max_level.max(s.max_level);
        for i in 0..N_CODECS {
            self.codec_bytes_in[i] += s.codec_bytes_in[i];
            self.codec_bytes_out[i] += s.codec_bytes_out[i];
            self.codec_encode_ns[i] += s.codec_encode_ns[i];
        }
        self.codec_probes += s.codec_probes;
        self.codec_switches += s.codec_switches;
    }

    /// Checkpointing overhead relative to pure compute+sync (the paper's
    /// "runtime overhead" — LowDiff claims <3.1%).
    pub fn overhead_ratio(&self) -> f64 {
        let base = self.compute_secs + self.sync_secs;
        if base == 0.0 {
            0.0
        } else {
            (self.stall_secs + self.queue_blocked_secs) / base
        }
    }

    /// Effective training time ratio (Gemini's metric, Exp. 9/10).
    pub fn effective_ratio(&self) -> f64 {
        if self.wall_secs == 0.0 {
            return 1.0;
        }
        (self.compute_secs + self.sync_secs) / self.wall_secs
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.losses.last().map(|(_, l)| *l)
    }

    /// The full report as one JSON object (`--report-json`): every counter
    /// machine-readable, losses as `[step, loss]` pairs, iteration times
    /// summarized as mean/stddev/min/max seconds.
    pub fn to_json(&self) -> String {
        use crate::util::json::{f64_token, JsonArray, JsonObject};
        let mut losses = JsonArray::new();
        for (step, loss) in &self.losses {
            losses.push_raw(&format!("[{},{}]", step, f64_token(f64::from(*loss))));
        }
        let mut iters = JsonObject::new();
        iters
            .u64("count", self.iter_times.count())
            .f64("mean_secs", self.iter_times.mean())
            .f64("stddev_secs", self.iter_times.stddev())
            .f64("min_secs", self.iter_times.min())
            .f64("max_secs", self.iter_times.max());
        let mut o = JsonObject::new();
        o.str("strategy", &self.strategy)
            .str("model", &self.model)
            .u64("workers", self.workers as u64)
            .u64("ranks", self.ranks as u64)
            .u64("iters", self.iters)
            .f64("wall_secs", self.wall_secs)
            .f64("compute_secs", self.compute_secs)
            .f64("sync_secs", self.sync_secs)
            .f64("stall_secs", self.stall_secs)
            .f64("queue_blocked_secs", self.queue_blocked_secs)
            .f64("overhead_ratio", self.overhead_ratio())
            .f64("effective_ratio", self.effective_ratio())
            .u64("full_ckpts", self.full_ckpts)
            .u64("diff_ckpts", self.diff_ckpts)
            .u64("writes", self.writes)
            .u64("bytes_written", self.bytes_written)
            .u64("peak_buffered_bytes", self.peak_buffered_bytes as u64)
            .u64("shard_writes", self.shard_writes)
            .u64("bytes_copied", self.bytes_copied)
            .u64("pool_hits", self.pool_hits)
            .u64("pool_misses", self.pool_misses)
            .u64("merged_written", self.merged_written)
            .u64("raw_compacted", self.raw_compacted)
            .u64("spans_compacted", self.spans_compacted)
            .u64("replay_objects", self.replay_objects as u64)
            .u64("max_level", u64::from(self.max_level))
            .u64("spill_bytes", self.spill_bytes)
            .u64("inflight_peak", self.inflight_peak as u64)
            .u64("global_commits", self.global_commits)
            .u64("torn_commits", self.torn_commits)
            .u64("gc_leaks", self.gc_leaks)
            .u64("recoveries", self.recoveries)
            .u64("detected_failures", self.detected_failures)
            .f64("recovery_secs", self.recovery_secs)
            .u64("lost_iters", self.lost_iters)
            .u64("retunes", self.retunes)
            .u64("final_full_every", self.final_full_every)
            .u64("final_batch_size", self.final_batch_size as u64)
            .u64("final_compact_every", self.final_compact_every as u64)
            .f64("final_io_budget", self.final_io_budget)
            .u64("zstd_level", self.zstd_level as u64)
            .str("final_codec", self.final_codec)
            .u64("codec_probes", self.codec_probes)
            .u64("codec_switches", self.codec_switches)
            .f64("compact_secs", self.compact_secs)
            .u64("trace_events", self.trace_events)
            .u64("trace_dropped", self.trace_dropped)
            .u64("trace_journal_dropped", self.trace_journal_dropped)
            .u64("slow_ops", self.slow_ops)
            .u64("storage_ops", self.storage_ops)
            .u64("scrub_passes", self.scrub_passes)
            .u64("scrub_objects", self.scrub_objects)
            .u64("scrub_corrupt", self.scrub_corrupt)
            .u64("scrub_repaired", self.scrub_repaired)
            .u64("scrub_damaged", self.scrub_damaged)
            .raw("codec", &{
                let mut codecs = JsonObject::new();
                for c in PayloadCodec::ALL {
                    let i = c.idx();
                    let mut k = JsonObject::new();
                    k.u64("bytes_in", self.codec_bytes_in[i])
                        .u64("bytes_out", self.codec_bytes_out[i])
                        .u64("encode_ns", self.codec_encode_ns[i]);
                    codecs.raw(c.name(), &k.finish());
                }
                codecs.finish()
            })
            .raw("iter_times", &iters.finish())
            .raw("losses", &losses.finish())
            .raw(
                "final_loss",
                &self
                    .final_loss()
                    .map(|l| f64_token(f64::from(l)))
                    .unwrap_or_else(|| "null".into()),
            );
        o.finish()
    }

    /// One-line table row used by examples and the bench harness.
    pub fn row(&self) -> String {
        format!(
            "{:<12} iters={:<5} wall={:>8.2}s compute={:>7.2}s stall={:>6.2}s qblk={:>6.2}s \
             overhead={:>5.1}% full={} diff={} writes={} bytes={} rec={} replay={} lvl={} \
             codec={} loss={}",
            self.strategy,
            self.iters,
            self.wall_secs,
            self.compute_secs,
            self.stall_secs,
            self.queue_blocked_secs,
            self.overhead_ratio() * 100.0,
            self.full_ckpts,
            self.diff_ckpts,
            self.writes,
            crate::util::human_bytes(self.bytes_written),
            self.recoveries,
            self.replay_objects,
            self.max_level,
            self.final_codec,
            self.final_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_ratio_zero_base() {
        let r = RunReport::new("x", "m", 1);
        assert_eq!(r.overhead_ratio(), 0.0);
    }

    #[test]
    fn overhead_and_effective() {
        let mut r = RunReport::new("x", "m", 1);
        r.compute_secs = 90.0;
        r.sync_secs = 5.0;
        r.stall_secs = 4.0;
        r.queue_blocked_secs = 1.0;
        r.wall_secs = 100.0;
        assert!((r.overhead_ratio() - 5.0 / 95.0).abs() < 1e-12);
        assert!((r.effective_ratio() - 0.95).abs() < 1e-12);
    }

    #[test]
    fn absorb_ckpt_sums_counters_and_maxes_peaks() {
        use crate::coordinator::checkpointer::CkptStats;
        let mut r = RunReport::new("x", "m", 1);
        let a = CkptStats {
            writes: 2,
            bytes_written: 10,
            pool_hits: 1,
            inflight_peak: 3,
            ..CkptStats::default()
        };
        let b = CkptStats {
            writes: 1,
            bytes_written: 5,
            pool_misses: 2,
            inflight_peak: 2,
            ..CkptStats::default()
        };
        r.absorb_ckpt(&a);
        r.absorb_ckpt(&b);
        assert_eq!(r.writes, 3);
        assert_eq!(r.bytes_written, 15);
        assert_eq!((r.pool_hits, r.pool_misses), (1, 2));
        assert_eq!(r.inflight_peak, 3);
        assert_eq!(r.ranks, 1, "default rank count");
    }

    #[test]
    fn to_json_carries_counters_and_losses() {
        let mut r = RunReport::new("lowdiff", "tiny", 2);
        r.iters = 10;
        r.detected_failures = 1;
        r.trace_events = 7;
        r.final_io_budget = 1.5e6;
        r.zstd_level = 3;
        r.final_codec = PayloadCodec::Quant8.name();
        r.codec_bytes_in[PayloadCodec::Quant8.idx()] = 100;
        r.codec_bytes_out[PayloadCodec::Quant8.idx()] = 40;
        r.codec_probes = 2;
        r.losses.push((10, 1.5));
        r.iter_times.push(0.25);
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"strategy\":\"lowdiff\""), "{j}");
        assert!(j.contains("\"iters\":10"), "{j}");
        assert!(j.contains("\"detected_failures\":1"), "{j}");
        assert!(j.contains("\"trace_events\":7"), "{j}");
        r.scrub_passes = 3;
        r.scrub_corrupt = 1;
        r.slow_ops = 2;
        let j = r.to_json();
        assert!(j.contains("\"scrub_passes\":3"), "{j}");
        assert!(j.contains("\"scrub_corrupt\":1"), "{j}");
        assert!(j.contains("\"slow_ops\":2"), "{j}");
        assert!(j.contains("\"scrub_damaged\":0"), "{j}");
        assert!(j.contains("\"final_io_budget\":1500000"), "{j}");
        assert!(j.contains("\"zstd_level\":3"), "{j}");
        assert!(j.contains("\"final_codec\":\"quant8\""), "{j}");
        assert!(j.contains("\"quant8\":{\"bytes_in\":100,\"bytes_out\":40"), "{j}");
        assert!(j.contains("\"codec_probes\":2"), "{j}");
        assert!(j.contains("\"losses\":[[10,1.5]]"), "{j}");
        assert!(j.contains("\"final_loss\":1.5"), "{j}");
        assert!(j.contains("\"mean_secs\":0.25"), "{j}");
    }

    #[test]
    fn row_formats() {
        let mut r = RunReport::new("lowdiff", "tiny", 2);
        r.losses.push((10, 1.5));
        assert!(r.row().contains("lowdiff"));
        assert!(r.row().contains("1.500"));
    }
}
