//! The L3 coordinator — the paper's system contribution.
//!
//! - [`reusing_queue`]: the zero-copy FIFO between training and
//!   checkpointing (§V-A).
//! - [`checkpointer`]: the checkpointing process — offload, batch, persist
//!   (§V-A/B, Fig. 6).
//! - [`lowdiff_plus`]: layer-wise reuse + CPU replica + async persistence
//!   (§VI).
//! - [`config_opt`]: Eq. (8)–(10) wasted-time model and the (FCF, BS) tuner
//!   (§V-C, Table I).
//! - [`recovery`]: serial replay and parallel (log n) merge recovery
//!   (Alg. 1, Fig. 10).
//! - [`failure`]: MTBF failure injection + wasted-time ledger (Exp. 3/9).
//! - [`driver`]: the real-engine training loop running every strategy
//!   (LowDiff, LowDiff+, Naive DC, CheckFreq, Gemini, torch.save) over
//!   actual PJRT compute and storage.
//! - [`metrics`]: the per-run time ledger.

pub mod checkpointer;
pub mod config_opt;
pub mod driver;
pub mod failure;
pub mod lowdiff_plus;
pub mod metrics;
pub mod recovery;
pub mod reusing_queue;

pub use driver::{train, Corpus, StrategyKind, TrainConfig};
pub use metrics::RunReport;
