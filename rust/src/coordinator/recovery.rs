//! Failure recovery (paper Alg. 1 lines 13-19, §VII-A parallel recovery).
//!
//! Load the newest full checkpoint, then fold in every subsequent
//! differential:
//! - **Serial replay**: apply diffs in step order. For LowDiff gradient
//!   diffs each application is one Adam step (Eq. (7)) — exact
//!   reconstruction. n diffs → n merges.
//! - **Parallel merge** (Fig. 10): combine diffs pairwise in log₂(n)
//!   rounds, then apply the combined result to the full checkpoint. For
//!   Naive DC state deltas the combine is addition — *exact*. For LowDiff
//!   gradient diffs the combine sums gradients, collapsing several Adam
//!   steps into one — the paper's batched/parallel approximation; the
//!   drift bound is measured in rust/tests/recovery_equivalence.rs.
//!   Compacted all-gradient `MergedDiff` spans contribute one partial per
//!   span: the writer's precomputed union-`sum` section when present
//!   (skipping a whole merge round per span), else the identical
//!   left-fold recomputed from the per-step payloads — bit-identical
//!   either way, pinned by `parallel_recovery_consumes_merged_sums…`.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::diff::DiffPayload;
use crate::checkpoint::format::CkptKind;
use crate::checkpoint::full::read_full_resolving;
use crate::checkpoint::merged::read_merged_sum;
use crate::checkpoint::read_chain_object;
use crate::checkpoint::manifest::Manifest;
use crate::optim::{Adam, ModelState};
use crate::sparse::SparseGrad;
use crate::storage::StorageBackend;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryMode {
    SerialReplay,
    ParallelMerge,
}

#[derive(Clone, Debug, Default)]
pub struct RecoveryStats {
    pub n_diff_objects: usize,
    pub n_diff_steps: usize,
    /// merge operations applied against the full checkpoint (the Fig. 10
    /// metric: n for serial, ~log2(n) rounds for parallel)
    pub full_merge_rounds: usize,
    pub wall_secs: f64,
    pub recovered_step: u64,
    /// diff objects that were unreadable (missing/torn shard, bad CRC) —
    /// the chain was truncated at the first of them
    pub damaged_objects: usize,
    /// diff steps dropped by chain truncation (damage or a step gap)
    pub dropped_diff_steps: usize,
    /// chain objects that were compacted `MergedDiff` spans — with the
    /// background compactor at merge factor m, `n_diff_objects` is bounded
    /// by ⌈steps/m⌉ plus a raw tail while `n_diff_steps` stays the full
    /// replay count
    pub merged_objects: usize,
    /// merged spans whose precomputed union-`sum` section was consumed by
    /// parallel recovery instead of re-merging the per-step payloads
    /// (ParallelMerge only; serial replay always replays per step)
    pub merged_sums_used: usize,
    /// deepest hierarchical-compaction span level in the replayed cover
    /// (0 = all raw): with the hierarchy, `n_diff_objects` is bounded by
    /// `mf·⌈log_mf steps⌉ + 1` even with fulls disabled (`full_every = ∞`)
    pub max_level: u16,
}

/// Parallel object fetch: shard-aware backends ([`Sharded`]
/// (crate::storage::Sharded)) additionally read each object's shards in
/// parallel, so the whole chain loads with two levels of fan-out.
const FETCH_FANOUT: usize = 8;

fn fetch_objects(
    store: &dyn StorageBackend,
    names: &[&str],
) -> Vec<std::result::Result<Vec<u8>, String>> {
    let mut out = Vec::with_capacity(names.len());
    for chunk in names.chunks(FETCH_FANOUT) {
        let mut part: Vec<std::result::Result<Vec<u8>, String>> =
            chunk.iter().map(|_| Err(String::new())).collect();
        std::thread::scope(|s| {
            for (slot, name) in part.iter_mut().zip(chunk) {
                s.spawn(move || {
                    *slot = store.get(name).map_err(|e| format!("{e:#}"));
                });
            }
        });
        out.append(&mut part);
    }
    out
}

/// One loaded chain object: its replayable per-step payloads and — for
/// all-gradient `MergedDiff` spans — the writer's precomputed union-sum
/// section, when it is usable as a drop-in for re-merging the per-step
/// payloads (parallel recovery, Fig. 10).
struct LoadedObject {
    kind: CkptKind,
    /// (step, payload) for steps strictly after the base, ascending
    items: Vec<(u64, DiffPayload)>,
    /// usable only when no step of the span was filtered at the base
    /// boundary (the sum covers the WHOLE span) and every payload is a
    /// gradient — then it bit-equals the left-fold of `items`
    /// (`merged.rs::sum_section_equals_left_fold_merge`)
    sum: Option<SparseGrad>,
}

/// All chain objects after `base_step`, in step order, with torn-chain
/// protection.
///
/// A crash can leave the chain with a *damaged* object (torn shard, CRC
/// mismatch) or a *hole* (a write that never committed while later writes
/// did). Applying diffs across either would silently produce a state that
/// never existed, so the chain is truncated at the first damaged object or
/// step gap and the loss is reported in [`RecoveryStats`].
///
/// Gap detection is heuristic: the chain's step stride is the smallest
/// spacing between *adjacent diff objects*; any larger jump is treated as
/// a hole. The base→first hop may legitimately be shorter than the stride
/// (a full checkpoint at a step unaligned to `diff_every`), so it is
/// accepted when `<= stride` and treated as a hole only when larger.
/// Uniformly spaced chains (any fixed `diff_every`) pass untouched; a
/// chain whose cadence legitimately varies is truncated conservatively —
/// recovery then restores an older-but-correct state, never a wrong one.
fn load_diffs(
    store: &dyn StorageBackend,
    model_sig: u64,
    chain: &crate::checkpoint::manifest::Chain,
    base_step: u64,
    stats: &mut RecoveryStats,
) -> Result<Vec<LoadedObject>> {
    if chain.diffs.is_empty() {
        return Ok(Vec::new());
    }
    let stride = chain.stride(base_step);

    let names: Vec<&str> = chain.diffs.iter().map(|(_, _, n)| n.as_str()).collect();
    let fetched = fetch_objects(store, &names);

    let mut out: Vec<LoadedObject> = Vec::new();
    let mut prev_hi = base_step;
    let mut truncate_from: Option<usize> = None;
    for (i, ((lo, hi, name), bytes)) in chain.diffs.iter().zip(fetched).enumerate() {
        // first hop: full checkpoints may land off the diff cadence, so any
        // spacing <= stride is legitimate; later objects must step exactly
        let hole = if i == 0 { *lo > base_step + stride } else { *lo != prev_hi + stride };
        if hole {
            log::warn!(
                "checkpoint chain hole before {name}: expected step {}, found {lo}; \
                 truncating chain at step {prev_hi}",
                prev_hi + stride
            );
            truncate_from = Some(i);
            break;
        }
        let bytes = match bytes {
            Ok(b) => b,
            Err(e) => {
                log::warn!(
                    "damaged checkpoint object {name} ({e}); truncating chain at step {prev_hi}"
                );
                stats.damaged_objects += 1;
                truncate_from = Some(i);
                break;
            }
        };
        // the shared kind dispatch: batched/merged containers hold several
        // steps, plain diffs one; Full in a diff chain is an error
        match read_chain_object(&bytes, model_sig) {
            Ok((kind, items)) => {
                let total = items.len();
                // a span may straddle the base full (compacted before the
                // full became visible): replay only the steps after it
                let mut items: Vec<(u64, DiffPayload)> =
                    items.into_iter().filter(|(s, _)| *s > base_step).collect();
                items.sort_by_key(|(s, _)| *s);
                let mut sum = None;
                if kind == CkptKind::MergedDiff {
                    stats.merged_objects += 1;
                    stats.max_level = stats.max_level.max(Manifest::span_level(name));
                    // the precomputed union-sum stands in for re-merging
                    // ONLY when it covers exactly the replayed steps
                    if items.len() == total && items.len() >= 2 {
                        sum = read_merged_sum(&bytes, model_sig).unwrap_or(None);
                    }
                }
                out.push(LoadedObject { kind, items, sum });
                prev_hi = *hi;
            }
            Err(e) => {
                log::warn!(
                    "damaged checkpoint object {name} ({e:#}); truncating chain at step {prev_hi}"
                );
                stats.damaged_objects += 1;
                truncate_from = Some(i);
                break;
            }
        }
    }
    if let Some(i) = truncate_from {
        stats.dropped_diff_steps = chain.diffs[i..]
            .iter()
            .map(|(lo, hi, _)| (hi - lo + 1) as usize)
            .sum();
    }
    Ok(out)
}

/// Recover the newest reconstructable state from a checkpoint store.
pub fn recover(
    store: &dyn StorageBackend,
    model_sig: u64,
    adam: &Adam,
    mode: RecoveryMode,
) -> Result<(ModelState, RecoveryStats)> {
    let start = Instant::now();
    let chain = Manifest::latest_chain(store)?;
    let (base_step, full_name) = chain
        .full
        .clone()
        .context("no full checkpoint found — nothing to recover from")?;
    // delta-encoded fulls (XOR vs the previous full, depth ≤ 1) resolve
    // through ONE extra fetch of their plain base; plain fulls pass through
    let mut state = read_full_resolving(&store.get(&full_name)?, model_sig, |base| {
        store
            .get(&Manifest::full_name(base))
            .with_context(|| format!("delta-full base checkpoint at step {base}"))
    })?;
    debug_assert_eq!(state.step, base_step);

    let mut stats = RecoveryStats {
        n_diff_objects: chain.diffs.len(),
        ..Default::default()
    };
    let objects = load_diffs(store, model_sig, &chain, base_step, &mut stats)?;
    stats.n_diff_steps = objects.iter().map(|o| o.items.len()).sum();

    match mode {
        RecoveryMode::SerialReplay => {
            for obj in &objects {
                for (step, payload) in &obj.items {
                    apply_one(adam, &mut state, payload);
                    debug_assert_eq!(state.step, *step);
                    stats.full_merge_rounds += 1;
                }
            }
        }
        RecoveryMode::ParallelMerge => {
            // Fig. 10: per-object partials, then the pairwise tournament.
            // Raw diff/batch objects contribute one gradient per step; a
            // compacted all-gradient span contributes ONE partial — its
            // precomputed `sum` section when usable (bit-identical to the
            // left-fold by construction), else the same left-fold
            // recomputed from the per-step payloads.
            let mut grads: Vec<SparseGrad> = Vec::new();
            let mut deltas: Vec<SparseGrad> = Vec::new();
            let mut last_step = state.step;
            for obj in &objects {
                if let Some((s, _)) = obj.items.last() {
                    last_step = *s;
                }
                let all_gradient = obj
                    .items
                    .iter()
                    .all(|(_, p)| matches!(p, DiffPayload::Gradient(_)));
                if obj.kind == CkptKind::MergedDiff && all_gradient && obj.items.len() >= 2 {
                    if let Some(sum) = &obj.sum {
                        stats.merged_sums_used += 1;
                        grads.push(sum.clone());
                    } else {
                        grads.push(left_fold_sum(&obj.items));
                    }
                    continue;
                }
                for (_, payload) in &obj.items {
                    match payload {
                        DiffPayload::Gradient(g) => grads.push(g.clone()),
                        DiffPayload::StateDelta(d) => deltas.push(d.clone()),
                    }
                }
            }
            if !grads.is_empty() {
                let (combined, rounds) = pairwise_merge(grads);
                // one Adam application of the summed gradient (approximate
                // collapse of k steps — see module docs)
                adam.apply_sparse(&mut state, &combined);
                state.step = last_step;
                stats.full_merge_rounds = rounds + 1;
            }
            if !deltas.is_empty() {
                let (combined, rounds) = pairwise_merge(deltas);
                // state delta over (params, m, v) concatenated — exact
                apply_state_delta(&mut state, &combined);
                state.step = last_step;
                stats.full_merge_rounds += rounds + 1;
            }
        }
    }
    stats.recovered_step = state.step;
    stats.wall_secs = start.elapsed().as_secs_f64();
    Ok((state, stats))
}

/// Left-to-right union-sum of an all-gradient span — the exact fold order
/// [`write_merged`](crate::checkpoint::merged::write_merged) uses for the
/// `sum` section, so the recomputed partial is bit-identical to a stored
/// one.
fn left_fold_sum(items: &[(u64, DiffPayload)]) -> SparseGrad {
    let mut acc = items[0].1.sparse().clone();
    let mut scratch = SparseGrad { dense_len: 0, indices: Vec::new(), values: Vec::new() };
    for (_, p) in &items[1..] {
        acc.merge_sum_into(p.sparse(), &mut scratch);
    }
    acc
}

fn apply_one(adam: &Adam, state: &mut ModelState, payload: &DiffPayload) {
    match payload {
        DiffPayload::Gradient(g) => adam.apply_sparse(state, g),
        DiffPayload::StateDelta(d) => {
            apply_state_delta(state, d);
            state.step += 1;
        }
    }
}

/// A Naive-DC state delta spans the concatenated (params | m | v) vector.
fn apply_state_delta(state: &mut ModelState, delta: &SparseGrad) {
    let n = state.n_params();
    assert_eq!(delta.dense_len as usize, 3 * n, "state delta must cover 3Ψ");
    for (&i, &v) in delta.indices.iter().zip(delta.values.iter()) {
        let i = i as usize;
        if i < n {
            state.params.0[i] += v;
        } else if i < 2 * n {
            state.m.0[i - n] += v;
        } else {
            state.v.0[i - 2 * n] += v;
        }
    }
}

/// Pairwise (tournament) merge — Fig. 10's structure. Returns the combined
/// gradient and the number of *rounds* (the critical-path merge count).
pub fn pairwise_merge(mut items: Vec<SparseGrad>) -> (SparseGrad, usize) {
    assert!(!items.is_empty());
    let mut rounds = 0;
    while items.len() > 1 {
        rounds += 1;
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a.merge_sum(&b)),
                None => next.push(a),
            }
        }
        items = next;
    }
    (items.pop().unwrap(), rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::diff::{read_diff, write_diff};
    use crate::checkpoint::format::{model_signature, PayloadCodec};
    use crate::checkpoint::full::write_full;
    use crate::compress::topk_mask;
    use crate::storage::MemStore;
    use crate::tensor::Flat;
    use crate::util::rng::Rng;

    fn dense_grad(rng: &mut Rng, n: usize, k: usize) -> Flat {
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g);
        topk_mask(&Flat(g), k)
    }

    /// Build a store with a full ckpt at step `base` plus `n_diffs`
    /// gradient diffs; return (store, sig, expected final state).
    fn build_gradient_chain(n: usize, n_diffs: usize) -> (MemStore, u64, ModelState) {
        let sig = model_signature("t", n);
        let mut rng = Rng::new(5);
        let mut p = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        let mut state = ModelState::new(Flat(p));
        let adam = Adam::default();
        let store = MemStore::new();
        store
            .put(&Manifest::full_name(0), &write_full(&state, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        for _ in 0..n_diffs {
            let g = dense_grad(&mut rng, n, n / 10 + 1);
            let sparse = SparseGrad::from_dense(&g);
            adam.apply_sparse(&mut state, &sparse);
            store
                .put(
                    &Manifest::diff_name(state.step),
                    &write_diff(&DiffPayload::Gradient(sparse), sig, state.step, PayloadCodec::Raw)
                        .unwrap(),
                )
                .unwrap();
        }
        (store, sig, state)
    }

    #[test]
    fn serial_replay_is_exact() {
        let (store, sig, want) = build_gradient_chain(200, 6);
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.n_diff_steps, 6);
        assert_eq!(stats.full_merge_rounds, 6);
        assert_eq!(stats.recovered_step, 6);
    }

    #[test]
    fn parallel_merge_log_rounds_and_bounded_drift() {
        let (store, sig, want) = build_gradient_chain(200, 8);
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::ParallelMerge).unwrap();
        // Fig. 10: 8 diffs -> 3 pairwise rounds + 1 full merge
        assert_eq!(stats.full_merge_rounds, 4);
        assert_eq!(got.step, want.step);
        // approximate: parameters close but not exact (Adam non-linearity)
        let drift = got.params.max_abs_diff(&want.params);
        assert!(drift > 0.0, "sum-collapse should differ from exact replay");
        assert!(drift < 0.05, "drift {drift} too large");
    }

    #[test]
    fn state_delta_parallel_recovery_is_exact() {
        // Naive DC: deltas are linear, parallel == serial exactly
        let n = 120;
        let sig = model_signature("d", n);
        let mut rng = Rng::new(8);
        let mut p = vec![0f32; n];
        rng.fill_normal_f32(&mut p);
        let state0 = ModelState::new(Flat(p));
        let store = MemStore::new();
        store
            .put(&Manifest::full_name(0), &write_full(&state0, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        let mut want = state0.clone();
        for step in 1..=5u64 {
            // random sparse delta over 3Ψ
            let mut d = vec![0f32; 3 * n];
            for x in d.iter_mut() {
                if rng.next_f64() < 0.1 {
                    *x = rng.normal() as f32;
                }
            }
            let delta = SparseGrad::from_dense(&Flat(d));
            apply_state_delta(&mut want, &delta);
            want.step += 1;
            store
                .put(
                    &Manifest::diff_name(step),
                    &write_diff(&DiffPayload::StateDelta(delta), sig, step, PayloadCodec::Raw)
                        .unwrap(),
                )
                .unwrap();
        }
        let (serial, _) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        let (parallel, _) =
            recover(&store, sig, &Adam::default(), RecoveryMode::ParallelMerge).unwrap();
        assert_eq!(serial, want);
        // parallel combine reorders f32 additions: equal up to associativity
        assert_eq!(parallel.step, want.step);
        assert!(parallel.params.max_abs_diff(&want.params) < 1e-5);
        assert!(parallel.m.max_abs_diff(&want.m) < 1e-5);
        assert!(parallel.v.max_abs_diff(&want.v) < 1e-5);
    }

    #[test]
    fn recovery_without_full_fails_clearly() {
        let store = MemStore::new();
        let err = recover(&store, 1, &Adam::default(), RecoveryMode::SerialReplay)
            .unwrap_err()
            .to_string();
        assert!(err.contains("no full checkpoint"), "{err}");
    }

    #[test]
    fn pairwise_merge_rounds_are_log2() {
        let g = SparseGrad { dense_len: 4, indices: vec![0], values: vec![1.0] };
        for (n, want) in [(1, 0), (2, 1), (3, 2), (5, 3), (8, 3), (9, 4), (16, 4)] {
            let (_, rounds) = pairwise_merge(vec![g.clone(); n]);
            assert_eq!(rounds, want, "n={n}");
        }
    }

    #[test]
    fn chain_hole_truncates_instead_of_skipping() {
        // diffs 1..=6 exist, diff 4 vanished (uncommitted write): recovery
        // must stop at step 3, never apply 5,6 across the hole
        let (store, sig, _) = build_gradient_chain(150, 6);
        store.delete(&Manifest::diff_name(4)).unwrap();
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got.step, 3);
        assert_eq!(stats.recovered_step, 3);
        assert_eq!(stats.dropped_diff_steps, 2, "diffs 5 and 6 dropped");
        assert_eq!(stats.damaged_objects, 0);
        // and the state equals an honest 3-step replay
        let (store3, sig3, want3) = build_gradient_chain(150, 3);
        let (got3, _) =
            recover(&store3, sig3, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got3, want3);
        assert_eq!(got, want3);
    }

    #[test]
    fn damaged_object_truncates_and_reports() {
        let (store, sig, _) = build_gradient_chain(150, 5);
        // corrupt diff 3's payload: CRC check must catch it
        let name = Manifest::diff_name(3);
        let mut bytes = store.get(&name).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        store.put(&name, &bytes).unwrap();
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got.step, 2, "stop before the damaged object");
        assert_eq!(stats.damaged_objects, 1);
        assert_eq!(stats.dropped_diff_steps, 3, "steps 3,4,5 dropped");
    }

    /// Hand-compact diffs `lo..=hi` of a built chain into one merged span.
    fn compact_by_hand(store: &MemStore, sig: u64, lo: u64, hi: u64) {
        use crate::checkpoint::merged::write_merged;
        let items: Vec<(u64, DiffPayload)> = (lo..=hi)
            .map(|s| read_diff(&store.get(&Manifest::diff_name(s)).unwrap(), sig).unwrap())
            .collect();
        store
            .put(
                &Manifest::merged_name(lo, hi),
                &write_merged(&items, sig, lo, hi, PayloadCodec::Raw).unwrap(),
            )
            .unwrap();
    }

    #[test]
    fn merged_spans_replay_bit_identically_even_with_leftover_raws() {
        let (store, sig, want) = build_gradient_chain(150, 6);
        // compact diffs 1..=4; a "crash" left raw diff 2 undeleted
        compact_by_hand(&store, sig, 1, 4);
        for s in [1u64, 3, 4] {
            store.delete(&Manifest::diff_name(s)).unwrap();
        }
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got, want, "merged replay must be bit-identical");
        assert_eq!(stats.n_diff_objects, 3, "merged(1,4) + diffs 5,6");
        assert_eq!(stats.merged_objects, 1);
        assert_eq!(stats.n_diff_steps, 6);
        assert_eq!(stats.recovered_step, 6);
    }

    #[test]
    fn merged_span_straddling_the_base_full_replays_only_later_steps() {
        // the async-engine race: diffs 3..6 were compacted before the full
        // at step 4 became visible. Discovery keeps the straddling span
        // (hi > base); replay must apply only steps 5,6 — bit-identically.
        let (store, sig, want) = build_gradient_chain(150, 6);
        compact_by_hand(&store, sig, 3, 6);
        for s in 3..=6u64 {
            store.delete(&Manifest::diff_name(s)).unwrap();
        }
        // same seed ⇒ identical prefix: the state after 4 steps is the
        // exact mid-chain full that lands late
        let (_, _, mid) = build_gradient_chain(150, 4);
        store
            .put(&Manifest::full_name(4), &write_full(&mid, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got, want, "steps 5,6 replay from inside the straddling span");
        assert_eq!(stats.recovered_step, 6);
        assert_eq!(stats.n_diff_steps, 2, "steps <= base are skipped, not re-applied");
        assert_eq!(stats.merged_objects, 1);
    }

    /// A merged span encoded WITHOUT a `sum` section (an older writer, or
    /// a mixed span) — only `g-{step}` sections.
    fn write_merged_no_sum(
        items: &[(u64, DiffPayload)],
        sig: u64,
        lo: u64,
        hi: u64,
    ) -> Vec<u8> {
        use crate::checkpoint::format::{encode_container_into, SectionSrc};
        let names: Vec<String> = items.iter().map(|(s, _)| format!("g-{s}")).collect();
        let secs: Vec<SectionSrc<'_>> = names
            .iter()
            .zip(items)
            .map(|(n, (_, p))| SectionSrc::sparse(n, p.sparse()))
            .collect();
        let mut out = Vec::new();
        encode_container_into(CkptKind::MergedDiff, PayloadCodec::Raw, sig, lo, hi, &secs, &mut out)
            .unwrap();
        out
    }

    #[test]
    fn parallel_recovery_consumes_merged_sum_sections_bit_identically() {
        // Store A: compacted spans carry the writer's union-sum sections;
        // store B: identical spans, sum sections stripped. Parallel
        // recovery must consume A's sums (no re-merge round per span) and
        // produce EXACTLY the bytes B's re-merge fallback produces — the
        // sum section is the left-fold the fallback recomputes.
        let (store_a, sig, want_serial) = build_gradient_chain(150, 8);
        compact_by_hand(&store_a, sig, 1, 4);
        compact_by_hand(&store_a, sig, 5, 8);
        let (store_b, _, _) = build_gradient_chain(150, 8); // same seed, same chain
        for (lo, hi) in [(1u64, 4u64), (5, 8)] {
            let items: Vec<(u64, DiffPayload)> = (lo..=hi)
                .map(|s| read_diff(&store_b.get(&Manifest::diff_name(s)).unwrap(), sig).unwrap())
                .collect();
            store_b
                .put(&Manifest::merged_name(lo, hi), &write_merged_no_sum(&items, sig, lo, hi))
                .unwrap();
        }
        for s in 1..=8u64 {
            store_a.delete(&Manifest::diff_name(s)).unwrap();
            store_b.delete(&Manifest::diff_name(s)).unwrap();
        }

        let (a, astats) =
            recover(&store_a, sig, &Adam::default(), RecoveryMode::ParallelMerge).unwrap();
        let (b, bstats) =
            recover(&store_b, sig, &Adam::default(), RecoveryMode::ParallelMerge).unwrap();
        assert_eq!(astats.merged_objects, 2);
        assert_eq!(astats.merged_sums_used, 2, "both sums consumed");
        assert_eq!(bstats.merged_sums_used, 0, "nothing to consume: re-merge fallback");
        assert_eq!(a, b, "sum consumption must be bit-identical to the re-merge path");
        // 2 span partials -> 1 pairwise round + 1 full merge: one whole
        // merge round per span is skipped vs 8 leaves (3 rounds + 1)
        assert_eq!(astats.full_merge_rounds, 2);
        assert_eq!(bstats.full_merge_rounds, 2);
        // and the serial path on the same compacted store is still exact
        let (s, _) =
            recover(&store_a, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(s, want_serial);
    }

    #[test]
    fn straddling_span_never_uses_its_sum() {
        // the sum covers the WHOLE span; when replay skips steps <= base,
        // consuming it would re-apply the skipped gradients
        let (store, sig, want) = build_gradient_chain(150, 6);
        compact_by_hand(&store, sig, 3, 6);
        for s in 3..=6u64 {
            store.delete(&Manifest::diff_name(s)).unwrap();
        }
        let (_, _, mid) = build_gradient_chain(150, 4);
        store
            .put(&Manifest::full_name(4), &write_full(&mid, sig, PayloadCodec::Raw).unwrap())
            .unwrap();
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::ParallelMerge).unwrap();
        assert_eq!(stats.merged_sums_used, 0, "straddling span must re-merge live steps");
        assert_eq!(stats.n_diff_steps, 2);
        assert_eq!(got.step, want.step);
        // parallel collapse of 2 steps: small drift, never the 2 skipped steps
        assert!(got.params.max_abs_diff(&want.params) < 0.05);
    }

    #[test]
    fn damaged_merged_span_truncates_to_the_base() {
        let (store, sig, _) = build_gradient_chain(120, 4);
        compact_by_hand(&store, sig, 1, 4);
        for s in 1..=4u64 {
            store.delete(&Manifest::diff_name(s)).unwrap();
        }
        let name = Manifest::merged_name(1, 4);
        let mut bytes = store.get(&name).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        store.put(&name, &bytes).unwrap();
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got.step, 0, "truncate at the base, never replay a damaged span");
        assert_eq!(stats.damaged_objects, 1);
        assert_eq!(stats.dropped_diff_steps, 4);
    }

    #[test]
    fn recovery_through_sharded_engine_matches_plain() {
        use crate::storage::{MemStore, Sharded};
        use std::sync::Arc;
        // write the same chain through a 4-shard engine and recover via a
        // fresh engine over the surviving inner store
        let n = 160;
        let sig = model_signature("t", n);
        let (plain, _, want) = build_gradient_chain(n, 5);
        let inner: Arc<dyn crate::storage::StorageBackend> = Arc::new(MemStore::new());
        let eng = Sharded::new(Arc::clone(&inner), 4, 3);
        for name in plain.list().unwrap() {
            eng.put(&name, &plain.get(&name).unwrap()).unwrap();
        }
        drop(eng); // graceful: all writes durable
        let reader = Sharded::new(inner, 1, 2);
        let (got, stats) =
            recover(&reader, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.recovered_step, 5);
        assert_eq!(stats.damaged_objects, 0);
    }

    #[test]
    fn delta_encoded_full_recovers_through_its_base() {
        use crate::checkpoint::format::DEFAULT_ZSTD_LEVEL;
        use crate::checkpoint::full::{full_raw_payload, read_full, write_full_delta_into};
        // chain: plain full @0, diffs 1..=4, plus the newest full @4 stored
        // as an XOR delta against the @0 base — recovery starts from the
        // delta full and must resolve its base with one extra fetch
        let (store, sig, want) = build_gradient_chain(150, 4);
        let base = read_full(&store.get(&Manifest::full_name(0)).unwrap(), sig).unwrap();
        let mut base_payload = Vec::new();
        full_raw_payload(&base, &mut base_payload);
        let mut delta = Vec::new();
        write_full_delta_into(&want, sig, 0, &base_payload, DEFAULT_ZSTD_LEVEL, &mut delta)
            .unwrap();
        store.put(&Manifest::full_name(4), &delta).unwrap();
        let (got, stats) =
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap();
        assert_eq!(got, want, "delta full must reconstruct bit-exactly");
        assert_eq!(stats.recovered_step, 4);
        assert_eq!(stats.n_diff_steps, 0, "the full at 4 covers the chain");
        // losing the base makes the delta full unreadable — and the error
        // says which base step recovery needed
        store.delete(&Manifest::full_name(0)).unwrap();
        let err = format!(
            "{:#}",
            recover(&store, sig, &Adam::default(), RecoveryMode::SerialReplay).unwrap_err()
        );
        assert!(err.contains("base"), "{err}");
    }

    #[test]
    fn pairwise_merge_sums_all() {
        let items: Vec<SparseGrad> = (0..7)
            .map(|i| SparseGrad { dense_len: 8, indices: vec![i], values: vec![1.0] })
            .collect();
        let (merged, _) = pairwise_merge(items);
        assert_eq!(merged.nnz(), 7);
        assert!(merged.values.iter().all(|&v| v == 1.0));
    }
}
