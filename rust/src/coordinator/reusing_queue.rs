//! The Reusing Queue (paper §V-A): the FIFO channel through which the
//! training process hands *compressed gradients* to the checkpointing
//! process for reuse as differential checkpoints.
//!
//! Requirements from the paper:
//! - **R1 sequential order**: FIFO delivery so differentials apply in step
//!   order (Eq. (6)); enforced here with monotonically increasing sequence
//!   numbers checked on both ends.
//! - **R2 cheap transmission**: the CUDA-IPC zero-copy of the paper becomes
//!   `Arc` handle passing (DESIGN.md §7) — enqueue cost is O(1) in the
//!   gradient size; the payload is never copied.
//!
//! The queue is bounded: when the checkpointer falls behind, `put` blocks —
//! this IS the paper's *transmission stall* (Challenge 2), surfaced as
//! measurable backpressure instead of hidden buffering. `put_nowait`
//! reports would-block for strategies that prefer dropping frequency.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queue entry: the training step that produced the gradient plus the
/// shared payload handle.
#[derive(Clone, Debug)]
pub struct Entry<T> {
    pub step: u64,
    pub payload: Arc<T>,
}

struct Inner<T> {
    queue: VecDeque<Entry<T>>,
    closed: bool,
    last_put_step: u64,
    last_got_step: u64,
    /// total time producers spent blocked on a full queue
    put_blocked: Duration,
}

/// Bounded MPSC FIFO with step-order enforcement.
pub struct ReusingQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> ReusingQueue<T> {
    pub fn new(capacity: usize) -> Arc<ReusingQueue<T>> {
        assert!(capacity >= 1);
        Arc::new(ReusingQueue {
            inner: Mutex::new(Inner {
                queue: VecDeque::with_capacity(capacity),
                closed: false,
                last_put_step: 0,
                last_got_step: 0,
                put_blocked: Duration::ZERO,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        })
    }

    /// Blocking enqueue. Panics on out-of-order steps (R1) or a closed
    /// queue. Returns how long the call blocked (the transmission stall).
    pub fn put(&self, step: u64, payload: Arc<T>) -> Duration {
        let start = Instant::now();
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "put on closed queue");
        assert!(step >= g.last_put_step, "out-of-order put: {step} after {}", g.last_put_step);
        while g.queue.len() >= self.capacity {
            g = self.not_full.wait(g).unwrap();
            assert!(!g.closed, "queue closed while blocked in put");
        }
        let blocked = start.elapsed();
        g.put_blocked += blocked;
        g.last_put_step = step;
        g.queue.push_back(Entry { step, payload });
        drop(g);
        self.not_empty.notify_one();
        blocked
    }

    /// Non-blocking enqueue; Err(payload) if the queue is full.
    pub fn put_nowait(&self, step: u64, payload: Arc<T>) -> Result<(), Arc<T>> {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "put on closed queue");
        if g.queue.len() >= self.capacity {
            return Err(payload);
        }
        assert!(step >= g.last_put_step, "out-of-order put: {step} after {}", g.last_put_step);
        g.last_put_step = step;
        g.queue.push_back(Entry { step, payload });
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking dequeue; None once the queue is closed AND drained.
    pub fn get(&self) -> Option<Entry<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(e) = g.queue.pop_front() {
                debug_assert!(e.step >= g.last_got_step, "FIFO order violated");
                g.last_got_step = e.step;
                drop(g);
                self.not_full.notify_one();
                return Some(e);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close the producer side; consumers drain then see None.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cumulative producer backpressure (the measured transmission stall).
    pub fn total_put_blocked(&self) -> Duration {
        self.inner.lock().unwrap().put_blocked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Flat;
    use std::thread;

    #[test]
    fn fifo_order_preserved() {
        let q = ReusingQueue::new(16);
        for s in 1..=10u64 {
            q.put(s, Arc::new(s));
        }
        q.close();
        let mut got = Vec::new();
        while let Some(e) = q.get() {
            got.push(e.step);
        }
        assert_eq!(got, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_copy_same_allocation() {
        // R2: the consumer sees the exact same allocation, no copy
        let q = ReusingQueue::new(4);
        let payload = Arc::new(Flat(vec![1.0; 1000]));
        let ptr = payload.0.as_ptr();
        q.put(1, payload);
        let got = q.get().unwrap();
        assert!(std::ptr::eq(ptr, got.payload.0.as_ptr()));
    }

    #[test]
    fn bounded_put_blocks_until_get() {
        let q = ReusingQueue::new(1);
        q.put(1, Arc::new(0u64));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.put(2, Arc::new(0u64)));
        thread::sleep(Duration::from_millis(50));
        assert_eq!(q.len(), 1); // producer still blocked
        let _ = q.get().unwrap();
        h.join().unwrap();
        assert!(q.total_put_blocked() >= Duration::from_millis(40));
        assert_eq!(q.get().unwrap().step, 2);
    }

    #[test]
    fn put_nowait_reports_full() {
        let q = ReusingQueue::new(1);
        assert!(q.put_nowait(1, Arc::new(())).is_ok());
        assert!(q.put_nowait(2, Arc::new(())).is_err());
        let _ = q.get();
        assert!(q.put_nowait(2, Arc::new(())).is_ok());
    }

    #[test]
    fn close_drains_then_none() {
        let q = ReusingQueue::new(8);
        q.put(1, Arc::new(()));
        q.put(2, Arc::new(()));
        q.close();
        assert!(q.get().is_some());
        assert!(q.get().is_some());
        assert!(q.get().is_none());
        assert!(q.get().is_none());
    }

    #[test]
    fn consumer_wakes_on_close() {
        let q: Arc<ReusingQueue<()>> = ReusingQueue::new(1);
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.get());
        thread::sleep(Duration::from_millis(30));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    fn rejects_step_regression() {
        let q = ReusingQueue::new(4);
        q.put(5, Arc::new(()));
        q.put(4, Arc::new(()));
    }

    #[test]
    fn producer_consumer_threads_full_stream() {
        let q = ReusingQueue::new(4);
        let qp = Arc::clone(&q);
        let producer = thread::spawn(move || {
            for s in 1..=500u64 {
                qp.put(s, Arc::new(Flat(vec![s as f32; 10])));
            }
            qp.close();
        });
        let mut expected = 1u64;
        while let Some(e) = q.get() {
            assert_eq!(e.step, expected);
            assert_eq!(e.payload.0[0], expected as f32);
            expected += 1;
        }
        assert_eq!(expected, 501);
        producer.join().unwrap();
    }
}
