//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§VIII) — see DESIGN.md §9 for the experiment index.
//!
//! Simulated experiments (paper-scale hardware) run on [`crate::sim`];
//! real-path experiments (Exp. 5/6/7 and the E2E run) exercise the actual
//! checkpoint/recovery code over real storage. Each function returns a
//! [`Table`] that prints in the same rows/series the paper reports.

use crate::coordinator::config_opt::{wasted_time, SystemParams};
use crate::coordinator::driver::StrategyKind;
use crate::model::{zoo, ZooModel};
use crate::sim::{calib, max_frequency_within, simulate, SimConfig};
use crate::simnet::{A100, V100};

/// A printable experiment result table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out += &fmt_row(&self.headers, &widths);
        out += "\n";
        out += &"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1));
        out += "\n";
        for row in &self.rows {
            out += &fmt_row(row, &widths);
            out += "\n";
        }
        out
    }
}

const STRATS: [StrategyKind; 5] = [
    StrategyKind::None,
    StrategyKind::NaiveDc,
    StrategyKind::CheckFreq,
    StrategyKind::Gemini,
    StrategyKind::LowDiff,
];

fn paper_models() -> Vec<ZooModel> {
    vec![zoo::RESNET101, zoo::VGG19, zoo::BERT_B, zoo::BERT_L, zoo::GPT2_S, zoo::GPT2_L]
}

fn cfg_for(model: ZooModel, s: StrategyKind) -> SimConfig {
    let mut c = SimConfig::new(model, s);
    match s {
        // per-iteration frequency for the frequent-checkpointing systems
        StrategyKind::Gemini => c.full_every = 100,
        StrategyKind::CheckFreq => c.full_every = 1, // forced per-iteration (Exp. 1 setting)
        StrategyKind::NaiveDc => {
            c.diff_every = 1;
            c.full_every = 100;
        }
        StrategyKind::LowDiff | StrategyKind::LowDiffPlus => {
            c.diff_every = 1;
            c.full_every = 100;
        }
        _ => {}
    }
    c
}

/// Fig. 1: impact of Naive DC compression/transmission frequency on GPT2-L.
pub fn fig1() -> Table {
    let mut t = Table::new(
        "Fig. 1 — DC compression & transmission frequency impact (GPT2-L, 1000 iters)",
        &["freq (iters)", "compress slowdown %", "transmit slowdown %"],
    );
    let base = simulate(&SimConfig::new(zoo::GPT2_L, StrategyKind::None)).total_time;
    for freq in [8u64, 4, 2, 1] {
        // compression-only cost
        let mut c = SimConfig::new(zoo::GPT2_L, StrategyKind::NaiveDc);
        c.diff_every = freq;
        c.full_every = u64::MAX / 2;
        let full = simulate(&c).total_time;
        // transmission share: same run minus the modeled compression stalls
        let compress_stall = (1000 / freq) as f64
            * calib::COMPRESS_SEC_PER_ELEM
            * (3 * zoo::GPT2_L.params) as f64;
        let comp_pct = compress_stall / base * 100.0;
        let trans_pct = (full - base - compress_stall) / base * 100.0;
        t.row(vec![
            freq.to_string(),
            format!("{comp_pct:.1}"),
            format!("{trans_pct:.1}"),
        ]);
    }
    t
}

/// Fig. 4: iteration vs full-checkpoint vs differential-checkpoint time.
pub fn fig4() -> Table {
    let mut t = Table::new(
        "Fig. 4 — iteration / full ckpt / DC time (s, A100 model)",
        &["model", "iteration", "full ckpt", "diff ckpt", "DC/iter %"],
    );
    for m in [zoo::BERT_B, zoo::BERT_L, zoo::GPT2_S, zoo::GPT2_L] {
        let full_b = calib::full_bytes(&m);
        let diff_b = calib::lowdiff_diff_bytes(&m, 0.01);
        let full_t = A100.pcie_time(full_b) + A100.ssd_write_time(full_b);
        let diff_t = A100.pcie_time(diff_b) + A100.ssd_write_time(diff_b);
        t.row(vec![
            m.name.to_string(),
            format!("{:.2}", m.iter_time_a100),
            format!("{full_t:.2}"),
            format!("{diff_t:.3}"),
            format!("{:.1}", diff_t / m.iter_time_a100 * 100.0),
        ]);
    }
    t
}

/// Table I: normalized wasted time over the (FCF, BS) grid.
///
/// The paper's Table I is an *accelerated stress measurement* (its optimum
/// sits at FCF = 20 iterations — physically meaningful only under very
/// frequent failures). We reproduce it with the stress parameters that
/// Eq. (10) maps to that optimum (MTBF 25 s, R_D 0.285 s), which is the
/// inverse calibration of the published normalized grid.
pub fn table1() -> Table {
    let full = calib::full_bytes(&zoo::GPT2_S) as f64;
    let p = SystemParams {
        n_gpus: 8.0,
        mtbf: 25.0,
        write_bw: A100.ssd_bw,
        full_size: full,
        total_time: 3600.0,
        r_full: full / A100.ssd_bw,
        r_diff: 0.285,
    };
    let iter_t = zoo::GPT2_S.iter_time_a100;
    let fcfs = [10u64, 20, 50, 100];
    let bss = [1u64, 2, 3, 4, 5, 6];
    let mut grid = Vec::new();
    let mut min = f64::INFINITY;
    for &fcf in &fcfs {
        let mut row = Vec::new();
        for &bs in &bss {
            let f = 1.0 / (fcf as f64 * iter_t);
            let w = wasted_time(&p, f, bs as f64);
            min = min.min(w);
            row.push(w);
        }
        grid.push(row);
    }
    let mut t = Table::new(
        "Table I — normalized wasted time, FCF x BS (GPT2-S, stress failures)",
        &["FCF\\BS", "1", "2", "3", "4", "5", "6"],
    );
    for (i, &fcf) in fcfs.iter().enumerate() {
        let mut cells = vec![fcf.to_string()];
        cells.extend(grid[i].iter().map(|w| format!("{:.3}", w / min)));
        t.row(cells);
    }
    t
}

/// Exp. 1 (Fig. 11): training time, per-iteration checkpointing.
pub fn exp1() -> Table {
    let mut t = Table::new(
        "Exp. 1 (Fig. 11) — training time, 1000 iters, per-iteration ckpt (s)",
        &["model", "wo-ckpt", "naive-dc", "checkfreq", "gemini", "lowdiff", "lowdiff ovh %"],
    );
    for m in paper_models() {
        let times: Vec<f64> = STRATS
            .iter()
            .map(|&s| simulate(&cfg_for(m, s)).total_time)
            .collect();
        let ovh = (times[4] - times[0]) / times[0] * 100.0;
        let mut cells = vec![m.name.to_string()];
        cells.extend(times.iter().map(|x| format!("{x:.0}")));
        cells.push(format!("{ovh:.1}"));
        t.row(cells);
    }
    t
}

/// Exp. 2 (Fig. 12): LowDiff+ training time (no compression).
pub fn exp2() -> Table {
    let mut t = Table::new(
        "Exp. 2 (Fig. 12) — training time without compression (s)",
        &["model", "wo-ckpt", "checkfreq", "gemini", "lowdiff+", "lowdiff+ ovh %"],
    );
    for m in paper_models() {
        let wo = simulate(&cfg_for(m, StrategyKind::None)).total_time;
        let cf = simulate(&cfg_for(m, StrategyKind::CheckFreq)).total_time;
        let gm = simulate(&cfg_for(m, StrategyKind::Gemini)).total_time;
        let lp = simulate(&cfg_for(m, StrategyKind::LowDiffPlus)).total_time;
        t.row(vec![
            m.name.to_string(),
            format!("{wo:.0}"),
            format!("{cf:.0}"),
            format!("{gm:.0}"),
            format!("{lp:.0}"),
            format!("{:.1}", (lp - wo) / wo * 100.0),
        ]);
    }
    t
}

/// Exp. 3 (Fig. 13): wasted time under MTBF ∈ {0.5, 1, 2} h (GPT2-S).
pub fn exp3() -> Table {
    let mut t = Table::new(
        "Exp. 3 (Fig. 13) — wasted time vs MTBF (GPT2-S, hours of waste)",
        &["mtbf (h)", "naive-dc", "checkfreq", "gemini", "lowdiff", "lowdiff+(S)", "lowdiff+(P)"],
    );
    for mtbf_h in [0.5f64, 1.0, 2.0] {
        let run = |s: StrategyKind, p_soft: f64| -> f64 {
            let mut c = cfg_for(zoo::GPT2_S, s);
            c.iters = 50_000;
            c.mtbf_secs = Some(mtbf_h * 3600.0);
            c.p_software = p_soft;
            if s == StrategyKind::LowDiff {
                // paper: LowDiff tunes (FCF, BS) via Eq. (10)
                let p = SystemParams {
                    n_gpus: 8.0,
                    mtbf: mtbf_h * 3600.0,
                    write_bw: A100.ssd_bw,
                    full_size: calib::full_bytes(&zoo::GPT2_S) as f64,
                    total_time: c.iters as f64 * zoo::GPT2_S.iter_time_a100,
                    r_full: calib::full_bytes(&zoo::GPT2_S) as f64 / A100.ssd_bw,
                    r_diff: calib::MERGE_ALPHA,
                };
                let (fcf, bs) = crate::coordinator::config_opt::optimal_config_integer(
                    &p,
                    zoo::GPT2_S.iter_time_a100,
                );
                c.full_every = fcf;
                c.batch_size = bs as u64;
            }
            simulate(&c).wasted.total_wasted() / 3600.0
        };
        t.row(vec![
            format!("{mtbf_h}"),
            format!("{:.3}", run(StrategyKind::NaiveDc, 0.7)),
            format!("{:.3}", run(StrategyKind::CheckFreq, 0.7)),
            format!("{:.3}", run(StrategyKind::Gemini, 0.0)),
            format!("{:.3}", run(StrategyKind::LowDiff, 0.7)),
            format!("{:.3}", run(StrategyKind::LowDiffPlus, 1.0)),
            format!("{:.3}", run(StrategyKind::LowDiffPlus, 0.0)),
        ]);
    }
    t
}

/// Exp. 4 (Fig. 14): max checkpoint frequency within a 3.5% slowdown.
pub fn exp4() -> Table {
    let mut t = Table::new(
        "Exp. 4 (Fig. 14) — smallest ckpt interval (iters) within 3.5% slowdown",
        &["model", "naive-dc", "checkfreq", "gemini", "lowdiff", "lowdiff+(S)", "lowdiff+(P)"],
    );
    for m in [zoo::RESNET101, zoo::BERT_L, zoo::GPT2_S, zoo::GPT2_L] {
        let f = |s: StrategyKind, full_mode: bool| {
            let v = max_frequency_within(&SimConfig::new(m, s), 0.035, full_mode);
            if v == u64::MAX { ">64".to_string() } else { v.to_string() }
        };
        // LowDiff+(S) = in-memory snapshot interval; (P) = persistence interval
        let plus_s = f(StrategyKind::LowDiffPlus, false);
        let plus_p = {
            let mut c = SimConfig::new(m, StrategyKind::LowDiffPlus);
            c.diff_every = 1;
            let base = simulate(&SimConfig::new(m, StrategyKind::None)).total_time;
            let mut ans = ">64".to_string();
            for interval in 1..=64u64 {
                c.full_every = interval;
                let t = simulate(&c).total_time;
                // persistence must also keep up with the SSD (sustained)
                let ssd_ok = calib::full_bytes(&m) as f64 / A100.ssd_bw
                    <= interval as f64 * m.iter_time_a100;
                if (t - base) / base <= 0.035 && ssd_ok {
                    ans = interval.to_string();
                    break;
                }
            }
            ans
        };
        t.row(vec![
            m.name.to_string(),
            f(StrategyKind::NaiveDc, false),
            f(StrategyKind::CheckFreq, true),
            f(StrategyKind::Gemini, false),
            f(StrategyKind::LowDiff, false),
            plus_s,
            plus_p,
        ]);
    }
    t
}

/// Exp. 8 (Fig. 17): compression ratio ρ vs max checkpoint frequency.
pub fn exp8() -> Table {
    let mut t = Table::new(
        "Exp. 8 (Fig. 17) — max ckpt interval (iters) vs compression ratio",
        &["rho", "GPT2-S", "GPT2-L"],
    );
    for rho in [0.001f64, 0.005, 0.01, 0.05, 0.075, 0.1] {
        let f = |m: ZooModel| {
            let mut c = SimConfig::new(m, StrategyKind::LowDiff);
            c.rho = rho;
            let v = max_frequency_within(&c, 0.035, false);
            if v == u64::MAX { ">64".into() } else { v.to_string() }
        };
        t.row(vec![format!("{rho}"), f(zoo::GPT2_S), f(zoo::GPT2_L)]);
    }
    t
}

/// Exp. 9 (Fig. 18): effective training ratio under frequent failures (V100).
pub fn exp9() -> Table {
    let mut t = Table::new(
        "Exp. 9 (Fig. 18) — effective training time ratio vs MTBF (V100, %)",
        &["mtbf (h)", "torch-save", "checkfreq", "gemini", "lowdiff", "lowdiff+(S)", "lowdiff+(P)"],
    );
    for mtbf_h in [0.1f64, 0.3, 0.5, 1.0, 2.0, 5.0] {
        let run = |s: StrategyKind, p_soft: f64| {
            let mut c = cfg_for(zoo::GPT2_S, s);
            c.hw = V100;
            c.iters = 100_000;
            c.mtbf_secs = Some(mtbf_h * 3600.0);
            c.p_software = p_soft;
            if s == StrategyKind::TorchSave {
                c.full_every = 100;
            }
            format!("{:.1}", simulate(&c).wasted.effective_ratio() * 100.0)
        };
        t.row(vec![
            format!("{mtbf_h}"),
            run(StrategyKind::TorchSave, 0.7),
            run(StrategyKind::CheckFreq, 0.7),
            run(StrategyKind::Gemini, 0.0),
            run(StrategyKind::LowDiff, 0.7),
            run(StrategyKind::LowDiffPlus, 1.0),
            run(StrategyKind::LowDiffPlus, 0.0),
        ]);
    }
    t
}

/// Exp. 10 (Fig. 19): effective training ratio vs cluster size.
pub fn exp10() -> Table {
    let mut t = Table::new(
        "Exp. 10 (Fig. 19) — effective training time ratio vs #GPUs (%)",
        &["gpus", "torch-save", "checkfreq", "gemini", "lowdiff", "lowdiff+"],
    );
    for n_gpus in [8u32, 16, 32, 64] {
        // failure rate scales with cluster size: MTBF_cluster = MTBF_node/N
        let mtbf = 3600.0 * 24.0 / n_gpus as f64;
        let run = |s: StrategyKind| {
            let mut c = cfg_for(zoo::GPT2_S, s);
            c.hw = V100;
            c.n_gpus = n_gpus;
            c.iters = 100_000;
            c.mtbf_secs = Some(mtbf);
            if s == StrategyKind::TorchSave {
                c.full_every = 100;
            }
            format!("{:.1}", simulate(&c).wasted.effective_ratio() * 100.0)
        };
        t.row(vec![
            n_gpus.to_string(),
            run(StrategyKind::TorchSave),
            run(StrategyKind::CheckFreq),
            run(StrategyKind::Gemini),
            run(StrategyKind::LowDiff),
            run(StrategyKind::LowDiffPlus),
        ]);
    }
    t
}

/// Exp. 7 (Table III): checkpoint storage bytes per strategy — computed
/// from the real container encoders over synthetic states at zoo sizes is
/// impractical at 762M params on this box, so sizes use the same byte
/// formulas the real writers produce (validated against them in tests).
pub fn exp7() -> Table {
    let mut t = Table::new(
        "Exp. 7 (Table III) — checkpoint storage overhead",
        &["model", "full ckpt", "naive-dc diff", "lowdiff diff", "full/lowdiff"],
    );
    for m in paper_models() {
        let full = calib::full_bytes(&m);
        let dc = calib::naive_dc_diff_bytes(&m, 0.01);
        let ld = calib::lowdiff_diff_bytes(&m, 0.01);
        t.row(vec![
            m.name.to_string(),
            crate::util::human_bytes(full),
            crate::util::human_bytes(dc),
            crate::util::human_bytes(ld),
            format!("{:.0}x", full as f64 / ld as f64),
        ]);
    }
    t
}

/// Sharded-storage scan (real path, not simulated): wall time to persist a
/// run of batched checkpoint writes through the sharded async engine,
/// across shard counts × writer-pool sizes, with every lane a [`Throttled`]
/// (crate::storage::Throttled) device (per-rank SSDs in spirit). The
/// baseline row is the seed's single-object synchronous write path.
pub fn exp_sharded() -> Table {
    use crate::storage::{MemStore, Sharded, StorageBackend, Throttled};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let obj_bytes: usize = 4 << 20; // one 4 MiB batched gradient write
    let n_objects = 6;
    let bw = 256e6; // bytes/sec per device
    let lat = Duration::from_millis(2);
    let payload = vec![0xA5u8; obj_bytes];
    let total_mb = (obj_bytes * n_objects) as f64 / 1e6;

    let mut t = Table::new(
        "Sharded storage engine — batched writes, throttled 256 MB/s devices",
        &["shards", "writers", "wall ms", "speedup", "agg MB/s"],
    );
    let base_secs = {
        let dev: Arc<dyn StorageBackend> = Arc::new(Throttled::new(MemStore::new(), bw, lat));
        let t0 = Instant::now();
        for i in 0..n_objects {
            dev.put(&format!("batch-{i:03}"), &payload).unwrap();
        }
        t0.elapsed().as_secs_f64()
    };
    t.row(vec![
        "1".into(),
        "sync".into(),
        format!("{:.1}", base_secs * 1e3),
        "1.00".into(),
        format!("{:.0}", total_mb / base_secs),
    ]);
    for &(shards, writers) in &[(2usize, 2usize), (4, 4), (8, 4), (8, 8)] {
        let lanes: Vec<Arc<dyn StorageBackend>> = (0..shards)
            .map(|_| {
                Arc::new(Throttled::new(MemStore::new(), bw, lat)) as Arc<dyn StorageBackend>
            })
            .collect();
        let eng = Sharded::with_lanes(lanes, shards, writers);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_objects)
            .map(|i| eng.put_async(&format!("batch-{i:03}"), payload.clone()))
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        t.row(vec![
            shards.to_string(),
            writers.to_string(),
            format!("{:.1}", secs * 1e3),
            format!("{:.2}", base_secs / secs),
            format!("{:.0}", total_mb / secs),
        ]);
    }
    t
}

/// Cluster-runtime scan (real path, not simulated): a fixed training
/// timeline checkpointed through the multi-rank cluster runtime at rank
/// counts 1/2/4/8 — per-rank differential chains + the two-phase global
/// commit. Columns report cluster-wide totals (every rank's counters,
/// aggregated the same way `RunReport` does) plus the commit layer's
/// overhead: records written, record bytes, and the coordinator's
/// phase-2 wall share.
pub fn exp_cluster() -> Table {
    use crate::checkpoint::format::model_signature;
    use crate::cluster::{partition_even, Cluster, ClusterConfig};
    use crate::compress::topk_mask;
    use crate::optim::ModelState;
    use crate::storage::{MemStore, StorageBackend};
    use crate::tensor::Flat;
    use crate::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Instant;

    let n: usize = 64 * 1024;
    let steps: u64 = 8;
    let sig = model_signature("cluster-exp", n);
    let mut t = Table::new(
        "Cluster runtime — per-rank chains + two-phase commit, 8 diff epochs",
        &["ranks", "wall ms", "commits", "torn", "objects", "MiB written", "record B", "commit ms"],
    );
    for ranks in [1usize, 2, 4, 8] {
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let cluster = Cluster::spawn(
            Arc::clone(&store),
            partition_even(n, ranks),
            ClusterConfig { model_sig: sig, gc: false, ..ClusterConfig::default() },
        );
        let mut rng = Rng::new(17);
        let state = ModelState::new(Flat(vec![0.1; n]));
        let t0 = Instant::now();
        cluster.put_full(0, &state);
        for step in 1..=steps {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            let masked = topk_mask(&Flat(g), n / 100 + 1);
            cluster.put_diff_dense(step, &masked);
        }
        let stats = cluster.finish();
        let wall = t0.elapsed().as_secs_f64();
        let total = stats.total();
        t.row(vec![
            ranks.to_string(),
            format!("{:.1}", wall * 1e3),
            stats.global_commits.to_string(),
            stats.torn_commits.to_string(),
            total.writes.to_string(),
            format!("{:.2}", total.bytes_written as f64 / (1 << 20) as f64),
            stats.record_bytes.to_string(),
            format!("{:.1}", stats.commit_secs * 1e3),
        ]);
    }
    t
}

/// Chain-compaction scan (real path, not simulated): full-free training
/// timelines (one anchor full, then only diffs — `full_every = ∞`)
/// persisted through the checkpointer at several hierarchical merge
/// factors, then recovered. Columns report the log-structured payoff:
/// chain objects on the store, objects a replay fetches, the
/// `mf·⌈log_mf n⌉+1` bound, the deepest span level, merged spans written
/// across all levels — and that the recovered state stays bit-identical
/// to the uncompacted chain.
pub fn exp_compaction() -> Table {
    use crate::checkpoint::batched::BatchMode;
    use crate::checkpoint::format::{model_signature, PayloadCodec};
    use crate::compress::topk_mask;
    use crate::control::replay_bound;
    use crate::coordinator::checkpointer::{Checkpointer, CkptConfig, CkptItem};
    use crate::coordinator::recovery::{recover, RecoveryMode};
    use crate::optim::{Adam, ModelState};
    use crate::storage::{MemStore, StorageBackend};
    use crate::tensor::Flat;
    use crate::util::rng::Rng;
    use std::collections::HashMap;
    use std::sync::Arc;

    let n: usize = 8 * 1024;
    let sig = model_signature("compaction-exp", n);
    let run = |compact_every: usize, steps: u64| {
        let store: Arc<dyn StorageBackend> = Arc::new(MemStore::new());
        let cfg = CkptConfig {
            model_sig: sig,
            batch_mode: BatchMode::Concat,
            codec: PayloadCodec::Raw,
            gc: false,
            compact_every,
            ..CkptConfig::default()
        };
        let ck = Checkpointer::spawn(Arc::clone(&store), cfg);
        let mut rng = Rng::new(31);
        ck.queue
            .put(0, Arc::new(CkptItem::Full(ModelState::new(Flat(vec![0.2; n])))));
        for step in 1..=steps {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            ck.queue
                .put(step, Arc::new(CkptItem::DiffDense(topk_mask(&Flat(g), n / 100 + 1))));
        }
        let stats = ck.finish();
        let (state, rstats) =
            recover(store.as_ref(), sig, &Adam::default(), RecoveryMode::SerialReplay)
                .expect("compaction-exp recovery");
        (store, stats, state, rstats)
    };

    let mut t = Table::new(
        "Hierarchical compaction — replay objects vs merge factor (full-free chains)",
        &[
            "merge factor",
            "diffs",
            "chain objects",
            "replay objects",
            "bound",
            "max level",
            "merged spans",
            "bit-identical",
        ],
    );
    // uncompacted runs of the same timeline are the bit-identity oracle
    let mut baselines: HashMap<u64, ModelState> = HashMap::new();
    for (mf, steps) in [(0usize, 24u64), (2, 24), (4, 24), (8, 24), (4, 96)] {
        let (store, stats, state, rstats) = run(mf, steps);
        let baseline = baselines.entry(steps).or_insert_with(|| {
            if mf == 0 {
                state.clone()
            } else {
                run(0, steps).2
            }
        });
        let chain_objects = store
            .list()
            .unwrap()
            .iter()
            .filter(|name| {
                matches!(
                    crate::checkpoint::manifest::Manifest::step_range(name),
                    Some(("diff", _, _)) | Some(("batch", _, _)) | Some(("merged", _, _))
                )
            })
            .count();
        t.row(vec![
            if mf < 2 { "off".into() } else { mf.to_string() },
            steps.to_string(),
            chain_objects.to_string(),
            rstats.n_diff_objects.to_string(),
            if mf < 2 { steps.to_string() } else { replay_bound(steps, mf).to_string() },
            rstats.max_level.to_string(),
            stats.merged_written.to_string(),
            if state == *baseline { "yes".into() } else { "NO".into() },
        ]);
    }
    t
}

/// Control-plane scan (real actuator, synthetic telemetry): the closed
/// §V-C loop from a deliberately bad initial config under a stressed
/// failure rate, against the Eq. (10) closed form for the TRUE system
/// parameters. The `static` row never ticks the actuator (what every run
/// before the control plane did); the `adaptive` rows tick it once per
/// simulated full epoch. Acceptance: the converged FCF/BS land within
/// 20% of the closed form.
pub fn exp_control() -> Table {
    use crate::control::{converge_synthetic, Retune};
    use crate::coordinator::config_opt::optimal_config_integer;

    let iter_time = 1.9;
    let full_size = calib::full_bytes(&zoo::GPT2_S) as f64;
    let p = SystemParams {
        n_gpus: 8.0,
        mtbf: 900.0, // stressed failures: the regime where tuning matters
        write_bw: A100.ssd_bw,
        full_size,
        total_time: 24.0 * 3600.0,
        r_full: full_size / A100.ssd_bw,
        r_diff: 0.2,
    };
    let (want_f, want_b) = optimal_config_integer(&p, iter_time);
    let bad = Retune {
        full_every: want_f * 50,
        batch_size: (want_b * 16).min(512),
        compact_every: 0,
        codec: crate::checkpoint::format::PayloadCodec::Raw,
    };
    let mut t = Table::new(
        "Control plane — closed-loop §V-C tuning vs Eq. (10) closed form (GPT2-S)",
        &["mode", "ticks", "FCF", "BS", "mf", "FCF*", "BS*", "FCF err %", "retunes"],
    );
    let mut row = |mode: &str, ticks: usize| {
        let (got, retunes) = if ticks == 0 {
            (bad, 0u64)
        } else {
            let a = converge_synthetic(p, iter_time, bad, ticks);
            (a.applied(), a.retunes)
        };
        let err = (got.full_every as f64 - want_f as f64).abs() / want_f as f64 * 100.0;
        t.row(vec![
            mode.into(),
            ticks.to_string(),
            got.full_every.to_string(),
            got.batch_size.to_string(),
            got.compact_every.to_string(),
            want_f.to_string(),
            want_b.to_string(),
            format!("{err:.1}"),
            retunes.to_string(),
        ]);
    };
    row("static", 0);
    row("adaptive", 50);
    row("adaptive", 200);
    row("adaptive", 600);
    t
}

/// Codec diversity (docs/FORMAT.md): measured per-codec wire bytes and
/// encode cost on the two real write-path workloads — sparse top-k
/// gradient diffs (every codec) and periodic fulls on a slowly-drifting
/// state (plain zstd vs XOR delta-full). The same achieved-ratio signal
/// the §V-C bandit codec policy steers on, printed as a table.
pub fn exp_codec() -> Table {
    use crate::checkpoint::diff::{write_diff_into_level, DiffPayload};
    use crate::checkpoint::format::{model_signature, PayloadCodec, DEFAULT_ZSTD_LEVEL};
    use crate::checkpoint::full::{full_raw_payload, write_full_delta_into, write_full_into_level};
    use crate::compress::topk_mask;
    use crate::optim::ModelState;
    use crate::sparse::SparseGrad;
    use crate::tensor::Flat;
    use crate::util::rng::Rng;
    use std::time::Instant;

    let n: usize = 16 * 1024;
    let steps = 8u64;
    let sig = model_signature("codec-exp", n);
    let mut rng = Rng::new(77);
    let grads: Vec<(u64, DiffPayload)> = (1..=steps)
        .map(|s| {
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g);
            let sparse = SparseGrad::from_dense(&topk_mask(&Flat(g), n / 100 + 1));
            (s, DiffPayload::Gradient(sparse))
        })
        .collect();
    let raw_diff: u64 = grads.iter().map(|(_, p)| p.sparse().encoded_size() as u64).sum();

    let mut t = Table::new(
        "Codec diversity — measured wire bytes per write-path workload",
        &["codec", "workload", "raw bytes", "wire bytes", "ratio", "ns/elem", "lossless"],
    );
    let mut out = Vec::new();
    for codec in [PayloadCodec::Raw, PayloadCodec::Zstd, PayloadCodec::Quant8] {
        let mut wire = 0u64;
        let t0 = Instant::now();
        for (s, p) in &grads {
            out.clear();
            wire += write_diff_into_level(p, sig, *s, codec, DEFAULT_ZSTD_LEVEL, &mut out)
                .expect("codec-exp diff encode") as u64;
        }
        let ns = t0.elapsed().as_nanos() as f64;
        let elems: u64 = grads.iter().map(|(_, p)| p.sparse().nnz() as u64).sum();
        t.row(vec![
            codec.name().into(),
            "topk diffs".into(),
            raw_diff.to_string(),
            wire.to_string(),
            format!("{:.3}", wire as f64 / raw_diff as f64),
            format!("{:.0}", ns / elems as f64),
            if codec.is_lossy() { "no (values)".into() } else { "yes".into() },
        ]);
    }

    // periodic fulls on a slowly-drifting state: the delta-full regime
    let mut params = vec![0f32; n];
    rng.fill_normal_f32(&mut params);
    let mut states: Vec<ModelState> = Vec::new();
    let mut st = ModelState::new(Flat(params));
    for s in 0..steps {
        st.step = s;
        states.push(st.clone());
        for _ in 0..n / 200 + 1 {
            let i = rng.range(0, n);
            st.params.0[i] += rng.normal() as f32 * 1e-3;
        }
    }
    let raw_full = (12 * n) as u64 * steps;
    for delta in [false, true] {
        let mut wire = 0u64;
        let mut base = Vec::new();
        full_raw_payload(&states[0], &mut base);
        let t0 = Instant::now();
        for (i, s) in states.iter().enumerate() {
            out.clear();
            let bytes = if delta && i > 0 {
                write_full_delta_into(s, sig, states[0].step, &base, DEFAULT_ZSTD_LEVEL, &mut out)
                    .expect("codec-exp delta full")
            } else {
                write_full_into_level(s, sig, PayloadCodec::Zstd, DEFAULT_ZSTD_LEVEL, &mut out)
                    .expect("codec-exp plain full")
            };
            wire += bytes as u64;
        }
        let ns = t0.elapsed().as_nanos() as f64;
        t.row(vec![
            if delta { PayloadCodec::DeltaFull.name().into() } else { "zstd".to_string() },
            "periodic fulls".into(),
            raw_full.to_string(),
            wire.to_string(),
            format!("{:.3}", wire as f64 / raw_full as f64),
            format!("{:.0}", ns / (3 * n) as f64 / steps as f64),
            "yes".into(),
        ]);
    }
    t
}

/// All simulated experiments, in paper order.
pub fn all_simulated() -> Vec<Table> {
    vec![fig1(), fig4(), table1(), exp1(), exp2(), exp3(), exp4(), exp7(), exp8(), exp9(), exp10()]
}

pub fn by_name(name: &str) -> Option<Table> {
    Some(match name {
        "fig1" => fig1(),
        "fig4" => fig4(),
        "table1" => table1(),
        "exp1" => exp1(),
        "exp2" => exp2(),
        "exp3" => exp3(),
        "exp4" => exp4(),
        "exp7" => exp7(),
        "exp8" => exp8(),
        "exp9" => exp9(),
        "exp10" => exp10(),
        "sharded" => exp_sharded(),
        "cluster" => exp_cluster(),
        "compaction" => exp_compaction(),
        "control" => exp_control(),
        "codec" => exp_codec(),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        for t in [fig4(), table1(), exp7()] {
            let s = t.render();
            assert!(s.lines().count() >= 4, "{s}");
        }
    }

    #[test]
    fn codec_table_measures_every_arm() {
        let t = exp_codec();
        assert_eq!(t.rows.len(), 5, "3 diff codecs + 2 full modes");
        let s = t.render();
        assert!(s.contains("quant8") && s.contains("delta-full"), "{s}");
        // quant8 must beat raw on the top-k workload it was built for
        let wire: Vec<u64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(wire[2] < wire[0], "quant8 {} !< raw {}", wire[2], wire[0]);
    }

    #[test]
    fn table1_minimum_at_moderate_config() {
        // Table I shape: the minimum is strictly inside the grid
        let t = table1();
        let vals: Vec<Vec<f64>> = t
            .rows
            .iter()
            .map(|r| r[1..].iter().map(|c| c.parse().unwrap()).collect())
            .collect();
        let mut min_pos = (0, 0);
        let mut min = f64::INFINITY;
        for (i, row) in vals.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                if v < min {
                    min = v;
                    min_pos = (i, j);
                }
            }
        }
        assert_eq!(min, 1.0, "normalization anchors min at 1.0");
        assert!(min_pos.1 > 0, "BS=1 should not be optimal (batching helps)");
    }

    #[test]
    fn exp1_lowdiff_overhead_column_small() {
        let t = exp1();
        for row in &t.rows {
            let ovh: f64 = row.last().unwrap().parse().unwrap();
            assert!(ovh < 5.0, "{}: {ovh}%", row[0]);
        }
    }

    #[test]
    fn exp9_lowdiff_plus_s_wins_under_frequent_failures() {
        // paper Fig. 18: in-memory recovery dominates when failures are
        // frequent (LowDiff+(S) 94.0% vs LowDiff 92% at MTBF 0.3h); at
        // large MTBFs the curves converge and LowDiff's lower steady
        // overhead can edge ahead — we assert the robust low-MTBF claim
        // plus LowDiff > CheckFreq everywhere.
        let t = exp9();
        for row in &t.rows {
            let mtbf: f64 = row[0].parse().unwrap();
            let checkfreq: f64 = row[2].parse().unwrap();
            let lowdiff: f64 = row[4].parse().unwrap();
            let plus_s: f64 = row[5].parse().unwrap();
            assert!(lowdiff > checkfreq, "{row:?}");
            if mtbf <= 0.3 {
                assert!(plus_s > lowdiff, "{row:?}");
            }
        }
    }

    #[test]
    fn by_name_covers_all() {
        let names = [
            "fig1", "fig4", "table1", "exp1", "exp2", "exp3", "exp4", "exp7", "exp8", "exp9",
            "exp10", "sharded", "cluster", "compaction", "control",
        ];
        for n in names {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn compaction_table_bounds_replay_and_stays_bit_identical() {
        use crate::control::replay_bound;
        let t = exp_compaction();
        assert_eq!(t.rows.len(), 5);
        for row in &t.rows {
            assert_eq!(row[7], "yes", "compacted recovery diverged: {row:?}");
            let steps: u64 = row[1].parse().unwrap();
            let replay: u64 = row[3].parse().unwrap();
            if row[0] == "off" {
                assert_eq!(replay, 24, "uncompacted replay touches every diff");
                continue;
            }
            let mf: usize = row[0].parse().unwrap();
            assert!(
                replay <= replay_bound(steps, mf),
                "mf={mf}, n={steps}: replay objects {replay} above the \
                 hierarchical bound {}",
                replay_bound(steps, mf)
            );
            let max_level: u16 = row[5].parse().unwrap();
            assert!(max_level >= 1, "the hierarchy must engage: {row:?}");
            // the settled chain IS the replay cover — nothing extra on disk
            assert_eq!(row[2], row[3], "chain objects == replay objects: {row:?}");
        }
        // the log-structured payoff: quadrupling the chain (24 -> 96 diffs
        // at mf=4) must NOT grow the replay cover — deeper levels absorb it
        let replay_24: u64 = t.rows[2][3].parse().unwrap();
        let replay_96: u64 = t.rows[4][3].parse().unwrap();
        assert_eq!(replay_24, 3, "24 diffs at mf=4 -> L2(1-16) + two L1 tails");
        assert_eq!(replay_96, 3, "96 diffs at mf=4 -> L3(1-64) + two L2 tails");
    }

    #[test]
    fn control_table_adaptive_converges_within_20pct() {
        let t = exp_control();
        assert_eq!(t.rows.len(), 4);
        let static_err: f64 = t.rows[0][7].parse().unwrap();
        assert!(static_err > 100.0, "the bad initial config must be far off");
        let final_err: f64 = t.rows[3][7].parse().unwrap();
        assert!(
            final_err <= 20.0,
            "adaptive must land within 20% of Eq. (10): {final_err}%\n{}",
            t.render()
        );
        let retunes: u64 = t.rows[3][8].parse().unwrap();
        assert!(retunes > 0);
        // convergence is monotone across the tick budgets (50 -> 600)
        let err_50: f64 = t.rows[1][7].parse().unwrap();
        assert!(final_err <= err_50 + 1.0, "more ticks must not diverge");
    }

    #[test]
    fn cluster_table_commits_every_epoch_at_all_rank_counts() {
        let t = exp_cluster();
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            assert_eq!(row[2], "9", "anchor + 8 diff epochs committed: {row:?}");
            assert_eq!(row[3], "0", "no torn epochs: {row:?}");
            let ranks: u64 = row[0].parse().unwrap();
            let objects: u64 = row[4].parse().unwrap();
            assert_eq!(objects, ranks * 9, "one object per rank per epoch: {row:?}");
        }
    }

    #[test]
    fn sharded_engine_beats_sync_baseline_at_4_shards() {
        // throttled-device model: sleeps dominate, so the speedup column
        // is stable enough to assert with margin (acceptance criterion:
        // sharded + pool beats single-object sync at >= 4 shards)
        let t = exp_sharded();
        for row in &t.rows {
            let shards: usize = row[0].parse().unwrap();
            let speedup: f64 = row[3].parse().unwrap();
            if shards >= 4 {
                assert!(speedup > 1.2, "shards={shards}: speedup {speedup} too low\n{}", t.render());
            }
        }
    }
}
