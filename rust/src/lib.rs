//! # LowDiff — frequent differential checkpointing via compressed-gradient reuse
//!
//! Rust + JAX + Pallas reproduction of *"Optimizing Frequent Checkpointing via
//! Low-Cost Differential for Distributed Training Systems"* (Yao et al.,
//! CS.DC 2025).
//!
//! Three layers (DESIGN.md §3):
//! - **L3 (this crate)**: the coordinator — training/checkpointing processes,
//!   reusing queue, batched writes, recovery, configuration tuning, baselines,
//!   storage, collectives, and the discrete-event cluster simulator that
//!   regenerates every figure/table of the paper's evaluation.
//! - **L2** (`python/compile/model.py`): JAX transformer fwd/bwd, AOT-lowered
//!   to HLO text in `artifacts/`, executed here via PJRT ([`runtime`]).
//! - **L1** (`python/compile/kernels/`): Pallas kernels (top-k compress,
//!   fused Adam, int8 quant) lowered inside the L2 computations.
//!
//! Python never runs after `make artifacts`; the hot path is pure Rust.

pub mod checkpoint;
pub mod cluster;
pub mod collective;
pub mod compress;
pub mod control;
pub mod coordinator;
pub mod exp;
pub mod model;
pub mod optim;
pub mod pipeline;
pub mod runtime;
pub mod sim;
pub mod simnet;
pub mod sparse;
pub mod storage;
pub mod tensor;
pub mod util;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
