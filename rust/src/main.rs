//! `lowdiff` — the coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   train     run a real training job with a chosen checkpointing strategy
//!   recover   restore the latest state from a checkpoint directory
//!   exp       regenerate a paper experiment table (or `all`)
//!   info      print artifact/model information

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use lowdiff::checkpoint::batched::BatchMode;
use lowdiff::checkpoint::format::{model_signature, PayloadCodec};
use lowdiff::coordinator::driver::{train, StrategyKind, TrainConfig};
use lowdiff::coordinator::recovery::{recover, RecoveryMode};
use lowdiff::optim::Adam;
use lowdiff::runtime::{artifacts_dir, ModelRuntime};
use lowdiff::storage::{LocalDir, StorageBackend};
use lowdiff::util::cli::Args;

const USAGE: &str = "\
usage: lowdiff <command> [options]

commands:
  train    --model <tiny|small|e2e> --strategy <lowdiff|lowdiff+|naive-dc|checkfreq|gemini|torch-save|none>
           [--iters N] [--workers W] [--full-every F] [--batch-size B]
           [--diff-every D] [--ckpt-dir DIR] [--mtbf SECS] [--zstd]
           [--batch-mode sum|concat] [--seed S]
           [--codec raw|zstd|quant8]  differential payload codec (quant8 =
                          per-block u8-quantized values, lossless indices;
                          overrides --zstd; docs/FORMAT.md)
           [--zstd-level L]  zstd compression level for zstd-backed
                          codecs (default 1; higher = smaller, slower)
           [--delta-fulls]  encode periodic fulls as XOR deltas vs the
                          previous full (depth <= 1, re-anchored every
                          4th full; flat lowdiff runtime only)
                          --full-every 0 = full-free mode (lowdiff): the
                          anchor full is the only one ever written; the
                          hierarchical compactor bounds recovery replay
                          at mf*ceil(log_mf n)+1 objects
           [--shards N]   checkpoint shards per object (>1 = sharded async engine)
           [--writers W]  storage writer-pool threads for the sharded engine
           [--ranks R]    cluster ranks (>1 = per-rank chains + two-phase
                          global commit; lowdiff strategy only)
           [--compact-every M]  background chain compaction: merge every M
                          persisted raw diffs into one MergedDiff span
                          (bounds recovery replay; M < 2 disables)
           [--adaptive]   closed-loop §V-C control plane: measure MTBF /
                          write bandwidth / replay ratio at runtime and
                          retune full-every, batch-size and compact-every
                          live at safe points (lowdiff, lowdiff+,
                          checkfreq, gemini)
           [--io-budget B] background-I/O byte budget (bytes/sec) for the
                          compaction scheduler's token-bucket gate; the
                          gate always yields to in-flight persists
           [--fsync]      fsync files AND parent dir on every put (durable)
           [--serve ADDR] observability/control plane: HTTP server on ADDR
                          (e.g. 127.0.0.1:9090) with GET /stats /metrics
                          /trace /chain /storage /health and POST /retune
                          /compact /scrub
           [--trace]      record per-stage spans to a chrome://tracing
                          JSONL journal persisted beside the chain
           [--trace-journal-max-kb KB]  cap the persisted journal at KB
                          kilobytes, keeping the newest events (default 256)
           [--slow-io-ms MS]  storage ops at or above MS latency count as
                          slow and emit io.slow.* trace events (default
                          100; 0 disables)
           [--scrub-secs SECS]  background chain scrubbing: re-verify the
                          committed cover every SECS and repair damaged
                          fast-tier copies (0 = on-demand via POST /scrub)
           [--heartbeat-timeout SECS]  declare a silent rank dead after
                          SECS and recover via the consistent-cut path
                          (cluster runs; 0 disables)
           [--report-json] print the final RunReport as JSON
  recover  --model <name> --ckpt-dir DIR [--parallel]
           (reads sharded, single-object and compacted layouts transparently)
  exp      <fig1|fig4|table1|exp1|exp2|exp3|exp4|exp7|exp8|exp9|exp10|sharded|cluster|compaction|control|codec|all>
  info     --model <name>
";

fn main() {
    lowdiff::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(
        raw,
        &[
            "zstd",
            "parallel",
            "verbose",
            "fsync",
            "adaptive",
            "trace",
            "report-json",
            "delta-fulls",
        ],
    )?;
    match args.subcommand(USAGE)? {
        "train" => cmd_train(&args),
        "recover" => cmd_recover(&args),
        "exp" => cmd_exp(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny").to_string();
    let strategy = StrategyKind::parse(args.get_or("strategy", "lowdiff"))
        .context("bad --strategy")?;
    let ckpt_dir = PathBuf::from(
        args.get_or("ckpt-dir", &format!("/tmp/lowdiff-ckpt-{model}")),
    );
    let cfg = TrainConfig {
        strategy,
        iters: args.parse_or("iters", 50u64)?,
        workers: args.parse_or("workers", 1usize)?,
        diff_every: args.parse_or("diff-every", 1u64)?,
        full_every: args.parse_or("full-every", 20u64)?,
        batch_size: args.parse_or("batch-size", 2usize)?,
        batch_mode: match args.get_or("batch-mode", "concat") {
            "sum" => BatchMode::Sum,
            _ => BatchMode::Concat,
        },
        codec: match args.get("codec") {
            Some(s) => PayloadCodec::parse_name(s)
                .filter(|c| *c != PayloadCodec::DeltaFull)
                .with_context(|| format!("bad --codec `{s}` (raw|zstd|quant8)"))?,
            None if args.flag("zstd") => PayloadCodec::Zstd,
            None => PayloadCodec::Raw,
        },
        zstd_level: args.parse_or("zstd-level", lowdiff::checkpoint::format::DEFAULT_ZSTD_LEVEL)?,
        delta_fulls: args.flag("delta-fulls"),
        seed: args.parse_or("seed", 42u64)?,
        mtbf_secs: args.get("mtbf").map(|s| s.parse()).transpose()?,
        eval_every: args.parse_or("eval-every", 10u64)?,
        n_shards: args.parse_or("shards", 1usize)?,
        writers: args.parse_or("writers", 1usize)?,
        ranks: args.parse_or("ranks", 1usize)?,
        compact_every: args.parse_or("compact-every", 0usize)?,
        adaptive: args.flag("adaptive"),
        io_budget: args.parse_or("io-budget", 0.0f64)?,
        serve: args.get("serve").map(|s| s.to_string()),
        trace: args.flag("trace"),
        heartbeat_timeout: args.parse_or("heartbeat-timeout", 0.0f64)?,
        slow_io_ms: args.parse_or("slow-io-ms", 100u64)?,
        trace_journal_max_kb: args.parse_or("trace-journal-max-kb", 256usize)?,
        scrub_secs: args.parse_or("scrub-secs", 0.0f64)?,
        ..TrainConfig::default()
    };
    if cfg.ranks > 1 && !cfg.uses_cluster() {
        bail!("--ranks > 1 requires --strategy lowdiff (the cluster runtime)");
    }
    let adaptive_ok = matches!(
        strategy,
        StrategyKind::LowDiff
            | StrategyKind::LowDiffPlus
            | StrategyKind::CheckFreq
            | StrategyKind::Gemini
    );
    if cfg.adaptive && !adaptive_ok {
        bail!(
            "--adaptive requires a checkpointing strategy with a retunable \
             interval (lowdiff, lowdiff+, checkfreq, gemini)"
        );
    }
    if cfg.full_every == 0 && !matches!(strategy, StrategyKind::LowDiff | StrategyKind::LowDiffPlus)
    {
        bail!(
            "--full-every 0 (full-free mode) needs a differential or replica \
             runtime (lowdiff, lowdiff+); periodic-full strategies would \
             never checkpoint"
        );
    }

    let mrt = ModelRuntime::load(&artifacts_dir(), &model)
        .with_context(|| format!("loading model `{model}` (run `make artifacts`?)"))?;
    log::info!(
        "training {model} ({} params) with {} for {} iters -> {}",
        mrt.n_params(),
        strategy.name(),
        cfg.iters,
        ckpt_dir.display()
    );
    let store: Arc<dyn StorageBackend> =
        Arc::new(LocalDir::new(&ckpt_dir)?.with_fsync(args.flag("fsync")));
    let report = train(&mrt, store, &cfg)?;
    if args.flag("report-json") {
        println!("{}", report.to_json());
    } else {
        println!("{}", report.row());
        for (step, loss) in &report.losses {
            println!("  step {step:>6}  loss {loss:.4}");
        }
    }
    Ok(())
}

fn cmd_recover(args: &Args) -> Result<()> {
    let model = args.require("model")?.to_string();
    let ckpt_dir = PathBuf::from(args.require("ckpt-dir")?);
    let mrt = ModelRuntime::load(&artifacts_dir(), &model)?;
    let sig = model_signature(&model, mrt.n_params());
    let mode = if args.flag("parallel") {
        RecoveryMode::ParallelMerge
    } else {
        RecoveryMode::SerialReplay
    };
    // the sharded view reads both layouts: shard sets via their commit
    // record (shards loaded in parallel), plain objects via fallback
    let store = lowdiff::storage::Sharded::new(
        Arc::new(LocalDir::new(&ckpt_dir)?) as Arc<dyn StorageBackend>,
        1,
        2,
    );
    let adam = Adam { lr: mrt.layout.lr as f32 };
    let (state, stats) = recover(&store, sig, &adam, mode)?;
    println!(
        "recovered step {} from {} diffs in {} merge rounds ({:.3}s), |params| = {:.4}",
        state.step,
        stats.n_diff_steps,
        stats.full_merge_rounds,
        stats.wall_secs,
        state.params.l2_norm()
    );
    if stats.damaged_objects > 0 || stats.dropped_diff_steps > 0 {
        println!(
            "warning: chain truncated ({} damaged objects, {} diff steps dropped)",
            stats.damaged_objects, stats.dropped_diff_steps
        );
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    if which == "all" {
        for t in lowdiff::exp::all_simulated() {
            println!("{}", t.render());
        }
        return Ok(());
    }
    match lowdiff::exp::by_name(which) {
        Some(t) => {
            println!("{}", t.render());
            Ok(())
        }
        None => bail!("unknown experiment `{which}`\n{USAGE}"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny");
    let layout = lowdiff::model::Layout::load(
        &artifacts_dir().join(format!("{model}.layout.txt")),
    )?;
    println!(
        "model {}: {} params ({} tensors), vocab {}, seq {}, batch {}, rho {}, k {}",
        layout.model,
        layout.n_params,
        layout.n_tensors(),
        layout.vocab,
        layout.seq_len,
        layout.batch,
        layout.rho,
        layout.k
    );
    println!("full checkpoint: {}", lowdiff::util::human_bytes(layout.full_ckpt_bytes()));
    println!(
        "lowdiff differential: {}",
        lowdiff::util::human_bytes(8 * layout.k as u64)
    );
    Ok(())
}
