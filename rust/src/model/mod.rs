//! Model metadata: artifact layout files + the paper's model zoo.
//!
//! [`Layout`] parses `artifacts/<m>.layout.txt` (emitted by
//! `python/compile/aot.py`) — the contract between the flat-vector L2 world
//! and the L3 coordinator. [`zoo`] carries the paper's Table II models with
//! the sizes the simulator needs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One named tensor's slice of the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub offset: usize,
    pub len: usize,
}

/// Parsed layout + config of one AOT-compiled model.
#[derive(Clone, Debug)]
pub struct Layout {
    pub model: String,
    pub n_params: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub rho: f64,
    /// top-k element count at the artifact's compression ratio
    pub k: usize,
    pub lr: f64,
    pub tensors: Vec<TensorSpec>,
}

impl Layout {
    pub fn load(path: &Path) -> Result<Layout> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading layout {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Layout> {
        let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
        let mut tensors = Vec::new();
        let mut in_tensors = false;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "tensors" {
                in_tensors = true;
                continue;
            }
            let mut parts = line.split_whitespace();
            if in_tensors {
                let name = parts.next().context("tensor name")?;
                let offset: usize = parts.next().context("offset")?.parse()?;
                let len: usize = parts.next().context("len")?.parse()?;
                tensors.push(TensorSpec { name: name.to_string(), offset, len });
            } else {
                let k = parts.next().context("key")?;
                let v = parts.next().context("value")?;
                kv.insert(k, v);
            }
        }
        let get = |k: &str| -> Result<&str> {
            kv.get(k).copied().with_context(|| format!("layout missing key `{k}`"))
        };
        let layout = Layout {
            model: get("model")?.to_string(),
            n_params: get("n_params")?.parse()?,
            vocab: get("vocab")?.parse()?,
            seq_len: get("seq_len")?.parse()?,
            batch: get("batch")?.parse()?,
            rho: get("rho")?.parse()?,
            k: get("k")?.parse()?,
            lr: get("lr")?.parse()?,
            tensors,
        };
        layout.validate()?;
        Ok(layout)
    }

    /// Layout invariants: contiguous, complete, non-empty tensors.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for t in &self.tensors {
            if t.offset != off {
                bail!("tensor {} offset {} != expected {off}", t.name, t.offset);
            }
            if t.len == 0 {
                bail!("tensor {} empty", t.name);
            }
            off += t.len;
        }
        if off != self.n_params {
            bail!("layout covers {off} of {} params", self.n_params);
        }
        Ok(())
    }

    /// Number of "layers" for layer-wise streaming = number of tensors.
    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Full checkpoint bytes: 3Ψ f32 (params + Adam m + v).
    pub fn full_ckpt_bytes(&self) -> u64 {
        3 * self.n_params as u64 * 4
    }
}

/// Paper Table II model zoo entry (used by the simulator and Exp. 7).
#[derive(Clone, Copy, Debug)]
pub struct ZooModel {
    pub name: &'static str,
    /// parameter count Ψ
    pub params: u64,
    /// measured A100 iteration time (s) — calibration, see sim/calib.rs
    pub iter_time_a100: f64,
}

/// Table II + Fig. 4 calibration (derivations in sim/calib.rs).
pub mod zoo {
    use super::ZooModel;

    pub const RESNET50: ZooModel = ZooModel { name: "ResNet-50", params: 25_600_000, iter_time_a100: 0.30 };
    pub const RESNET101: ZooModel = ZooModel { name: "ResNet-101", params: 44_500_000, iter_time_a100: 0.45 };
    pub const VGG16: ZooModel = ZooModel { name: "VGG-16", params: 138_800_000, iter_time_a100: 0.55 };
    pub const VGG19: ZooModel = ZooModel { name: "VGG-19", params: 143_700_000, iter_time_a100: 0.60 };
    pub const BERT_B: ZooModel = ZooModel { name: "BERT-B", params: 110_000_000, iter_time_a100: 0.65 };
    pub const BERT_L: ZooModel = ZooModel { name: "BERT-L", params: 334_000_000, iter_time_a100: 1.10 };
    pub const GPT2_S: ZooModel = ZooModel { name: "GPT2-S", params: 117_000_000, iter_time_a100: 0.70 };
    pub const GPT2_L: ZooModel = ZooModel { name: "GPT2-L", params: 762_000_000, iter_time_a100: 1.90 };

    pub const ALL: [ZooModel; 8] = [
        RESNET50, RESNET101, VGG16, VGG19, BERT_B, BERT_L, GPT2_S, GPT2_L,
    ];

    pub fn by_name(name: &str) -> Option<ZooModel> {
        ALL.iter().copied().find(|m| m.name.eq_ignore_ascii_case(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# lowdiff model layout v1
model tiny
n_params 20
vocab 256
d_model 64
n_layers 2
n_heads 4
d_ff 256
seq_len 32
batch 4
block 16384
rho 0.01
k 1
lr 0.001
tensors
embed 0 12
pos 12 8
";

    #[test]
    fn parses_sample() {
        let l = Layout::parse(SAMPLE).unwrap();
        assert_eq!(l.model, "tiny");
        assert_eq!(l.n_params, 20);
        assert_eq!(l.tensors.len(), 2);
        assert_eq!(l.tensors[1], TensorSpec { name: "pos".into(), offset: 12, len: 8 });
        assert_eq!(l.full_ckpt_bytes(), 240);
    }

    #[test]
    fn rejects_gap() {
        let bad = SAMPLE.replace("pos 12 8", "pos 13 7");
        assert!(Layout::parse(&bad).is_err());
    }

    #[test]
    fn rejects_incomplete_coverage() {
        let bad = SAMPLE.replace("pos 12 8", "pos 12 7");
        assert!(Layout::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_key() {
        let bad = SAMPLE.replace("n_params 20\n", "");
        assert!(Layout::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny.layout.txt");
        if path.exists() {
            let l = Layout::load(&path).unwrap();
            assert_eq!(l.model, "tiny");
            assert!(l.n_params > 100_000);
            assert_eq!(l.k, (l.rho * l.n_params as f64) as usize);
        }
    }

    #[test]
    fn zoo_lookup() {
        assert_eq!(zoo::by_name("gpt2-l").unwrap().params, 762_000_000);
        assert!(zoo::by_name("nope").is_none());
    }
}
